"""Continuous-batching serving engine over the quantized decode path.

The inference counterpart of :mod:`repro.engine`: one object that owns a
fixed pool of decode slots and keeps the jitted one-token step running at
**full static batch** while requests of arbitrary lengths stream through —

  * **scheduler** — a FIFO request queue is drained into free slots
    (``_admit``); each slot carries its own position, sampling parameters,
    and PRNG stream; slots are evicted the moment their request hits EOS,
    its ``max_new`` budget, or the cache length (``_evict``).  The decode
    step never recompiles: inactive slots run on dummy tokens and their
    samples are discarded.
  * **prefill** — per-request (batch 1), right-padded into power-of-two
    length buckets so at most ``log2(max_seq)`` prefill programs are ever
    compiled; the true-last-position logits come via ``prefill(...,
    last_pos=...)`` and only the real rows are inserted into the slot's
    cache (causality makes the padded rows' K/V irrelevant).
  * **int8 KV cache** — ``kv_quant=True`` stores keys/values as per-row
    affine int8 codes (core/kv_cache.py, the ``kv_cache`` registry role):
    ~4x less HBM per resident slot, so ~4x more slots at equal memory
    (benchmarks/bench_serve.py measures both axes).  Dequantization runs
    through the execution backend the policy selects (simulate / native /
    pallas).
  * **checkpoint startup** — :meth:`ServeEngine.from_checkpoint` restores
    the ``params`` subtree of an engine :class:`~repro.engine.TrainState`
    checkpoint (legacy ``{params, opt}`` checkpoints restore identically),
    so a trained run is servable without conversion.

Determinism: sampling keys are ``fold_in(fold_in(seed_key, rid), count)`` —
a pure function of the request, never of slot assignment — so for a fixed
seed, workload, and pool size the engine's outputs are fully reproducible.
One caveat on *traffic* independence: the randomness never depends on what
else is resident, but under per-**tensor** forward quantizers the logits
can — ``Q_f`` computes its dynamic range over the whole decode batch, so
co-resident slots couple at the quantization-noise level (~1e-2 on smoke
logits).  Exact or per-row forward quantization removes the coupling.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..core import (QuantPolicy, RoleOverride, quantize_kv_rows,
                    quantize_ptq_det, resolve_kv_cache_spec)
from ..kernels.pack import PackedTensor, pack_codes, pack_qtensor
from ..models import build_model
from .sampling import sample_tokens, slot_keys

__all__ = ["Request", "Completion", "ServeEngine", "pack_dense_weights",
           "weight_nbytes"]

# bits -> the forward-weight role spec the packed policy advertises
# (8-bit packs to identity bytes but still drops 4x vs fp32 resident
# weights and skips the per-step weight quantize)
_PACKED_WEIGHT_SPECS = {8: "ptq_det:8", 4: "int4w:4", 2: "int4w:2"}


def _pack_leaf(w: jax.Array, bits: int) -> PackedTensor:
    """Quantize one dense kernel to ``bits`` (deterministic per-tensor PTQ,
    the paper's Q_theta) and bit-pack it.  A stacked ``(L, K, N)`` leaf is
    quantized *per layer* — one affine pair per scanned layer, shaped
    ``(L, 1, 1)`` so ``lax.scan`` slices it alongside the packed codes."""
    w = jnp.asarray(w, jnp.float32)
    if w.ndim == 2:
        return pack_qtensor(quantize_ptq_det(w, bits))
    nbins = float((1 << bits) - 1)
    zero = jnp.min(w, axis=(-2, -1), keepdims=True)
    hi = jnp.max(w, axis=(-2, -1), keepdims=True)
    scale = nbins / jnp.maximum(hi - zero, 1e-12)
    codes = jnp.clip(jnp.round(scale * (w - zero)), 0, nbins)
    return PackedTensor(packed=pack_codes(codes.astype(jnp.uint8), bits),
                        scale=scale, zero=zero, bits=bits, kdim=w.shape[-2])


def pack_dense_weights(params, bits: int):
    """Replace every dense kernel leaf (dict key ``"w"``, ndim >= 2) with a
    :class:`PackedTensor` quantized once at load time.

    Embeddings (``"table"``), biases, and norm scales stay fp — they are
    not GEMM operands of the packed kernels.  ``dense`` feeds the packed
    leaf straight into ``fqt_matmul``, which routes pre-packed weights
    through the inference-only packed forward (core/fqt.py).
    """
    if bits not in _PACKED_WEIGHT_SPECS:
        raise ValueError(f"weight_bits={bits!r}: packable widths are "
                         f"{sorted(_PACKED_WEIGHT_SPECS)}")

    def walk(node):
        if isinstance(node, dict):
            return {k: (_pack_leaf(v, bits)
                        if k == "w" and getattr(v, "ndim", 0) >= 2
                        else walk(v))
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(params)


def weight_nbytes(params) -> int:
    """Resident bytes of a params tree (packed leaves count packed)."""
    total = 0
    for leaf in jax.tree.leaves(
            params, is_leaf=lambda x: isinstance(x, PackedTensor)):
        total += int(leaf.nbytes)
    return total


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request.  ``eos_id=None`` inherits the engine's."""

    rid: int
    prompt: tuple                      # token ids, 1 <= len < max_seq
    max_new: int = 32
    temperature: float = 0.0           # <= 0 => greedy
    top_k: int = 0                     # <= 0 => disabled
    top_p: float = 0.0                 # outside (0, 1) => disabled
    eos_id: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class Completion:
    rid: int
    prompt_len: int
    tokens: List[int]                  # includes the terminating EOS, if any
    reason: str                        # "eos" | "length"


class _Slot:
    """Host-side state of one decode slot."""

    __slots__ = ("req", "pos", "tokens")

    def __init__(self):
        self.req: Optional[Request] = None
        self.pos = 0                   # next cache write position
        self.tokens: List[int] = []    # sampled so far (incl. EOS)

    @property
    def active(self) -> bool:
        return self.req is not None


def _bucket(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


class ServeEngine:
    """See module docstring.  Typical lifecycle::

        eng = ServeEngine.from_checkpoint(cfg, "/ckpts", slots=16,
                                          kv_quant=True, eos_id=2)
        for prompt in prompts:
            eng.submit(prompt, max_new=64, temperature=0.8, top_k=40)
        completions = eng.run()          # drains queue + pool

    ``submit``/``run`` may be interleaved — ``run`` returns when the queue
    and every slot are empty; later submissions start a new drain.

    ``paged=True`` swaps in the paged-pool engine (serve/paged.py): the
    same scheduler surface over a shared page pool with block tables,
    prefix reuse, chunked prefill, and optional speculative decode — and
    token-for-token identical output at equal seeds under the default
    single-chunk prefill.  Paged-only knobs (``page_size``, ``pages``,
    ``prefill_chunk``, ``spec_decode``, ``spec_k``, ``draft_policy``) are
    accepted only with ``paged=True``.
    """

    def __new__(cls, *args, paged: bool = False, **kw):
        if paged and cls is ServeEngine:
            from .paged import PagedServeEngine  # late: paged imports us
            return super().__new__(PagedServeEngine)
        return super().__new__(cls)

    def __init__(self, cfg, params, *, policy: Optional[QuantPolicy] = None,
                 slots: int = 4, max_seq: int = 64, kv_quant=False,
                 eos_id: Optional[int] = None, seed: int = 0,
                 weight_bits: Optional[int] = None, paged: bool = False):
        del paged                       # consumed by __new__ dispatch
        if cfg.family in ("vlm", "audio"):
            raise ValueError(
                f"{cfg.name}: the serving engine drives token-input decoder "
                f"LMs; family {cfg.family!r} needs a frontend the stub "
                f"pipeline does not provide")
        if cfg.family == "hybrid" or cfg.ssm_kind:
            raise ValueError(
                f"{cfg.name}: continuous batching needs per-slot KV-cache "
                f"lanes; recurrent-state families (ssm/hybrid) are not "
                f"supported yet")
        self.cfg = cfg
        self.model = build_model(cfg)
        self.policy = policy or QuantPolicy.qat()
        self.params = params
        self.weight_bits = weight_bits
        if weight_bits is not None:
            # pack once at load: the resident weights drop to bits/32 of
            # fp32 and every decode step skips the per-step weight
            # quantize (the packed kernels unpack tiles in VMEM).  The
            # appended catch-all override is applied last, so it wins the
            # fwd_weight role for every path, matching the packed leaves.
            self.params = pack_dense_weights(params, weight_bits)
            self.policy = dataclasses.replace(
                self.policy,
                overrides=tuple(self.policy.overrides) + (
                    ("", RoleOverride.of(
                        {"fwd_weight": _PACKED_WEIGHT_SPECS[weight_bits]})),))
        self.slots = slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.kv_spec = resolve_kv_cache_spec(kv_quant)
        if self.kv_spec is not None and self.model.init_cache_quant is None:
            raise ValueError(f"{cfg.name}: no quantized-cache support for "
                             f"this family (recurrent state)")
        self._base_key = jax.random.PRNGKey(seed)
        self._queue: deque = deque()
        self._slots = [_Slot() for _ in range(slots)]
        self._next_rid = 0
        self._completions: Dict[int, Completion] = {}
        self.step_times: List[tuple] = []       # (seconds, tokens_emitted)

        self._cache = self._init_cache()
        self._decode = jax.jit(self._step_fn, donate_argnums=(1,))
        self._prefill_fns: dict = {}
        self._insert_fns: dict = {}
        self._sample1 = jax.jit(
            lambda lg, key, t, k, p: sample_tokens(
                lg[None], key[None], jnp.float32(t)[None],
                jnp.int32(k)[None], cfg.vocab_size,
                jnp.float32(p)[None])[0])

    # -- construction ------------------------------------------------------
    @classmethod
    def from_checkpoint(cls, cfg, ckpt_dir: str, step: Optional[int] = None,
                        **kw) -> "ServeEngine":
        """Restore ``params`` from an engine ``TrainState`` checkpoint (the
        ``{params, opt}`` legacy layout restores the same subtree)."""
        ckpt = CheckpointManager(ckpt_dir)
        step = step if step is not None else ckpt.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir!r}")
        model = build_model(cfg)
        abstract = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        params = ckpt.restore(step, {"params": abstract})["params"]
        return cls(cfg, params, **kw)

    def _init_cache(self):
        if self.kv_spec is not None:
            return self.model.init_cache_quant(self.cfg, self.slots,
                                               self.max_seq)
        cache = self.model.init_cache(self.cfg, self.slots, self.max_seq)
        # per-slot positions: the engine owns them, but the cache's index
        # leaf must match the (slots,) shape decode returns under vector
        # positions, or the donated jit would retrace once
        cache["index"] = jnp.zeros((self.slots,), jnp.int32)
        return cache

    # -- the jitted full-batch decode step ---------------------------------
    def _step_fn(self, params, cache, tok, pos, rids, counts, temp, topk,
                 topp):
        keys = slot_keys(self._base_key, rids, counts)
        logits, cache = self.model.decode(
            params, cache, {"tokens": tok[:, None]}, self.policy,
            positions=pos, kv_quant=self.kv_spec)
        nxt = sample_tokens(logits[:, -1], keys, temp, topk,
                            self.cfg.vocab_size, topp)
        return cache, nxt

    # -- prefill + slot insertion (compiled per length bucket) -------------
    def _prefill(self, tokens: np.ndarray):
        """(1, Lp) prompt -> (last-real-position logits (1,1,V), kv pytree
        (L, 1, Lb, flat)).  Compiled once per power-of-two bucket."""
        lp = tokens.shape[1]
        lb = min(_bucket(lp), self.max_seq)   # slab must fit the cache lane
        fn = self._prefill_fns.get(lb)
        if fn is None:
            def run(params, toks, last):
                logits, cache = self.model.prefill(
                    params, {"tokens": toks}, self.policy, max_seq=lb,
                    last_pos=last)
                return logits, cache["kv"]
            fn = self._prefill_fns[lb] = jax.jit(run)
        padded = np.zeros((1, lb), np.int32)
        padded[0, :lp] = tokens[0]
        return fn(self.params, jnp.asarray(padded),
                  jnp.asarray([lp - 1], jnp.int32))

    def _insert(self, cache, kv, slot: int, lp: int):
        """Write the prefill bucket's rows of ``kv`` into ``slot``'s cache
        lane (quantizing them when the cache is int8) and set its position
        to the *real* prompt length ``lp``.

        The whole bucket slab is written — compiled once per power-of-two
        bucket, like prefill, not once per prompt length.  Rows >= lp hold
        right-padding garbage, which is never observed: the position mask
        hides them until the decode step overwrites each one (write at
        ``pos`` strictly precedes the mask extending to ``pos``).
        """
        lb = kv["k"].shape[2]
        fn = self._insert_fns.get(lb)
        if fn is None:
            quant = self.kv_spec is not None
            bits = (self.kv_spec.bits or 8) if quant else None

            def ins(cache, kv, slot_idx, lp_arr):
                out = dict(cache)
                out["kv"] = dict(cache["kv"])
                for side in ("k", "v"):
                    rows = kv[side]                        # (L, 1, lb, flat)
                    if quant:
                        codes, scale, zero = quantize_kv_rows(rows, bits)
                        lane = dict(cache["kv"][side])
                        lane["codes"] = jax.lax.dynamic_update_slice(
                            lane["codes"], codes, (0, slot_idx, 0, 0))
                        lane["scale"] = jax.lax.dynamic_update_slice(
                            lane["scale"], scale, (0, slot_idx, 0))
                        lane["zero"] = jax.lax.dynamic_update_slice(
                            lane["zero"], zero, (0, slot_idx, 0))
                        out["kv"][side] = lane
                    else:
                        dst = cache["kv"][side]
                        out["kv"][side] = jax.lax.dynamic_update_slice(
                            dst, rows.astype(dst.dtype), (0, slot_idx, 0, 0))
                out["index"] = cache["index"].at[slot_idx].set(lp_arr)
                return out
            fn = self._insert_fns[lb] = jax.jit(ins, donate_argnums=(0,))
        return fn(cache, kv, jnp.int32(slot), jnp.int32(lp))

    # -- scheduler ---------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new: int = 32,
               temperature: float = 0.0, top_k: int = 0, top_p: float = 0.0,
               eos_id: Optional[int] = None) -> int:
        """Queue one request; returns its request id."""
        prompt = tuple(int(t) for t in prompt)
        if not 1 <= len(prompt) <= self.max_seq - 1:
            raise ValueError(
                f"prompt length {len(prompt)} out of range [1, "
                f"{self.max_seq - 1}] (max_seq={self.max_seq} needs room "
                f"for at least one generated token)")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(
            rid=rid, prompt=prompt, max_new=max_new,
            temperature=temperature, top_k=top_k, top_p=top_p,
            eos_id=self.eos_id if eos_id is None else eos_id))
        return rid

    def _finish(self, slot: _Slot, reason: str):
        req = slot.req
        self._completions[req.rid] = Completion(
            rid=req.rid, prompt_len=len(req.prompt),
            tokens=list(slot.tokens), reason=reason)
        slot.req = None
        slot.tokens = []
        slot.pos = 0

    def _evict(self):
        for slot in self._slots:
            if not slot.active:
                continue
            req = slot.req
            if req.eos_id is not None and slot.tokens \
                    and slot.tokens[-1] == req.eos_id:
                self._finish(slot, "eos")
            elif len(slot.tokens) >= req.max_new:
                self._finish(slot, "length")
            elif slot.pos >= self.max_seq:
                self._finish(slot, "length")     # cache lane full

    def _admit(self):
        for i, slot in enumerate(self._slots):
            if slot.active or not self._queue:
                continue
            req = self._queue.popleft()
            toks = np.asarray(req.prompt, np.int32)[None]
            logits, kv = self._prefill(toks)
            first = int(self._sample1(
                logits[0, -1], slot_keys(
                    self._base_key, jnp.asarray([req.rid], jnp.int32),
                    jnp.asarray([0], jnp.int32))[0],
                req.temperature, req.top_k, req.top_p))
            self._cache = self._insert(self._cache, kv, i, len(req.prompt))
            slot.req = req
            slot.pos = len(req.prompt)
            slot.tokens = [first]
        # a request can terminate straight out of prefill (EOS as the very
        # first sample, or max_new == 1) — evict before it burns a step
        self._evict()

    # -- the loop ----------------------------------------------------------
    def step(self) -> int:
        """Admit waiting requests, run one full-batch decode step, record
        the new tokens.  Returns the number of tokens emitted."""
        self._evict()
        self._admit()
        live = [s for s in self._slots if s.active]
        if not live:
            return 0
        B = self.slots
        tok = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        rids = np.full((B,), -1, np.int32)
        counts = np.zeros((B,), np.int32)
        temp = np.zeros((B,), np.float32)
        topk = np.zeros((B,), np.int32)
        topp = np.zeros((B,), np.float32)
        for i, slot in enumerate(self._slots):
            if not slot.active:
                continue
            tok[i] = slot.tokens[-1]
            pos[i] = slot.pos
            rids[i] = slot.req.rid
            counts[i] = len(slot.tokens)
            temp[i] = slot.req.temperature
            topk[i] = slot.req.top_k
            topp[i] = slot.req.top_p
        t0 = time.perf_counter()
        self._cache, nxt = self._decode(
            self.params, self._cache, jnp.asarray(tok), jnp.asarray(pos),
            jnp.asarray(rids), jnp.asarray(counts), jnp.asarray(temp),
            jnp.asarray(topk), jnp.asarray(topp))
        nxt = np.asarray(jax.block_until_ready(nxt))
        dt = time.perf_counter() - t0
        emitted = 0
        for i, slot in enumerate(self._slots):
            if not slot.active:
                continue
            slot.tokens.append(int(nxt[i]))
            slot.pos += 1
            emitted += 1
        self.step_times.append((dt, emitted))
        return emitted

    def run(self, max_steps: Optional[int] = None) -> Dict[int, Completion]:
        """Drive until the queue and pool drain; returns the completions
        collected by THIS call ({rid: Completion}) and clears them — the
        engine keeps no history, so a long-lived server never accumulates
        past token lists and interleaved submit/run batches stay disjoint.
        """
        steps = 0
        while self._queue or any(s.active for s in self._slots):
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        self._evict()
        done = self._completions
        self._completions = {}
        return done

    # -- introspection -----------------------------------------------------
    @property
    def active_slots(self) -> int:
        return sum(s.active for s in self._slots)

    @property
    def queued(self) -> int:
        return len(self._queue)

    def completions(self) -> Dict[int, Completion]:
        """Completions finished but not yet collected by a ``run`` call."""
        return dict(self._completions)
