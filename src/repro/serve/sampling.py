"""Token sampling for the serving engine: greedy, temperature, top-k, top-p.

All jittable and batched over decode slots, with *per-slot* sampling
parameters (each resident request carries its own temperature/top-k) and
per-slot PRNG keys derived by :func:`slot_keys` — the *randomness* is a
pure function of ``(seed, request id, token index)``, never of slot
assignment or batch composition.  (The logits themselves can still couple
co-resident slots under per-tensor forward quantizers — see the engine
docstring's determinism caveat.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["slot_keys", "sample_tokens"]

_NEG = -1e30


def slot_keys(base_key: jax.Array, rids: jax.Array,
              counts: jax.Array) -> jax.Array:
    """Per-slot sampling keys: ``fold_in(fold_in(base, rid), count)``.

    rids/counts: (B,) int32 — the request id resident in each slot and how
    many tokens it has sampled so far.  Inactive slots may pass any value
    (their samples are discarded by the scheduler).
    """
    def one(r, c):
        return jax.random.fold_in(jax.random.fold_in(base_key, r), c)
    return jax.vmap(one)(rids, counts)


def sample_tokens(logits: jax.Array, keys: jax.Array, temperature: jax.Array,
                  top_k: jax.Array, vocab_size: int,
                  top_p: jax.Array = None) -> jax.Array:
    """Sample one token per slot.  logits: (B, Vp); keys: (B,) PRNG keys
    (stacked); temperature/top_k/top_p: (B,) — ``temperature <= 0`` means
    greedy, ``top_k <= 0`` disables the top-k filter, ``top_p`` outside
    ``(0, 1)`` (or ``None``) disables the nucleus filter.  Returns (B,)
    int32.

    Padded-vocab logits (Vp > vocab_size) are masked before everything else
    so padding rows can never be emitted.  Filters compose in the standard
    warper order — temperature scaling, then top-k, then top-p: the nucleus
    is the smallest set of (surviving) tokens whose temperature-scaled
    probabilities sum past ``top_p``, and the top-1 token always survives.
    The determinism contract is unchanged: the only randomness is
    ``categorical(key, ...)`` under the ``fold_in(fold_in(seed, rid),
    token_idx)`` keys, so adding a nucleus cut never perturbs *which*
    uniform a request's next token consumes.
    """
    B, vp = logits.shape
    logits = logits.astype(jnp.float32)
    if vp > vocab_size:
        logits = logits.at[:, vocab_size:].set(_NEG)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    k = jnp.clip(jnp.where(top_k <= 0, vocab_size, top_k), 1, vocab_size)
    sorted_desc = jnp.sort(logits, axis=-1)[:, ::-1]
    thresh = jnp.take_along_axis(sorted_desc, (k - 1)[:, None], axis=1)
    filtered = jnp.where(logits >= thresh, logits, -jnp.inf)

    temp = jnp.maximum(temperature, 1e-6)[:, None]
    if top_p is not None:
        # nucleus cut on the post-top-k, temperature-scaled distribution:
        # keep the shortest descending-probability prefix whose cumulative
        # mass reaches top_p (ties at the cut probability are all kept)
        probs = jax.nn.softmax(filtered / temp, axis=-1)
        sp = jnp.sort(probs, axis=-1)[:, ::-1]
        cum = jnp.cumsum(sp, axis=-1)
        n_keep = jnp.sum((cum - sp) < top_p[:, None], axis=-1)
        p_thresh = jnp.take_along_axis(sp, jnp.maximum(n_keep - 1, 0)[:, None],
                                       axis=1)
        nucleus = jnp.where(probs >= p_thresh, filtered, -jnp.inf)
        active = ((top_p > 0.0) & (top_p < 1.0))[:, None]
        filtered = jnp.where(active, nucleus, filtered)

    sampled = jax.vmap(jax.random.categorical)(keys, filtered / temp)
    return jnp.where(temperature > 0.0, sampled.astype(jnp.int32), greedy)
