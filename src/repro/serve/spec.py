"""Self-speculative decoding: the draft model is the *same* parameters
under a more aggressive low-bit quantization policy.

Classic speculative decoding needs a second, smaller model; the quantizer
registry makes that free here — ``QuantPolicy.overrides`` can re-resolve
every forward GEMM of the *target* parameters at a lower width (in the
spirit of 1-Bit FQT pushing widths down where error tolerance allows), so
the draft shares weights, KV pages, and compiled layer stack with the
target.  The paged engine (serve/paged.py) runs the loop:

  1. **propose** — ``k`` sequential one-token paged decode steps under the
     draft policy, greedy, writing provisional (draft-coded) KV rows into
     the request's own pages at positions ``pos+1 .. pos+k``;
  2. **verify** — ONE multi-token paged forward of the chunk
     ``[t0, d1 .. dk]`` under the target policy, which overwrites those
     same rows with target-coded KV (so accepted or not, the cache ends
     exactly as target-policy decode would have left it);
  3. **accept** — :func:`greedy_accept`: the target's greedy outputs
     ``g0 .. gk`` are emitted while they confirm the draft
     (``d_j == g_{j-1}``), plus the first disagreeing target token — m in
     [1, k+1] tokens per step, every one of them *exactly* what plain
     target greedy decode would have produced.

Rows past the accepted point hold KV for rejected draft tokens; they are
dead weight, not corruption — the engine's write-before-expose invariant
(a position is rewritten when a real token is fed there, strictly before
the causal mask exposes it) already covers them.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core import QuantPolicy, RoleOverride

__all__ = ["SpecStats", "default_draft_policy", "greedy_accept"]


# The aggressive widths the default self-draft runs at: 4-bit activations
# (and 4-bit weights when the engine has not already bit-packed them at a
# fixed width).  Deterministic PTQ — the draft is inference, Sec. 2.1's
# forward rules apply.
_DRAFT_ACT_SPEC = "ptq_det:4"
_DRAFT_WEIGHT_SPEC = "ptq_det:4"


def default_draft_policy(policy: QuantPolicy,
                         packed_weights: bool = False) -> QuantPolicy:
    """Derive the self-draft policy from the target's: append a catch-all
    override dropping forward activations (and, for fp32-resident weights,
    forward weights) to 4 bits.  Appended last, so it wins every path —
    including any per-path overrides the target policy carries.

    ``packed_weights=True`` (the engine loaded ``weight_bits``-packed
    parameters): the resident weights are already quantized at a fixed
    width and *cannot* be re-quantized per policy, so only the activation
    width drops.
    """
    roles = {"fwd_act": _DRAFT_ACT_SPEC}
    if not packed_weights:
        roles["fwd_weight"] = _DRAFT_WEIGHT_SPEC
    return dataclasses.replace(
        policy,
        overrides=tuple(policy.overrides) + (("", RoleOverride.of(roles)),))


@dataclasses.dataclass
class SpecStats:
    """Acceptance accounting across an engine's lifetime (per-run rates are
    the bench's job — it snapshots and diffs)."""

    proposed: int = 0          # draft tokens proposed (k per spec step/slot)
    accepted: int = 0          # of those, confirmed by the target
    emitted: int = 0           # tokens emitted by spec steps (incl. the +1)
    spec_steps: int = 0        # propose+verify rounds run
    fallback_steps: int = 0    # plain steps taken where spec didn't fit

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / self.proposed if self.proposed else 0.0

    def as_dict(self) -> dict:
        return {"proposed": self.proposed, "accepted": self.accepted,
                "emitted": self.emitted, "spec_steps": self.spec_steps,
                "fallback_steps": self.fallback_steps,
                "acceptance_rate": self.acceptance_rate}


def greedy_accept(drafted: np.ndarray, target_greedy: np.ndarray):
    """Exact greedy acceptance for one slot.

    drafted: (k,) the draft's proposals ``d1 .. dk``; target_greedy: (k+1,)
    the target's greedy picks ``g0 .. gk``, where ``g_j`` conditions on
    ``[.., t0, d1 .. d_j]``.  Returns the emitted tokens ``g0 .. g_m`` with
    ``m`` = the longest prefix where the draft matched — every emitted
    token equals what sequential target greedy decode would produce,
    because ``d_j == g_{j-1}`` means ``g_j`` conditioned on exactly the
    accepted context.
    """
    k = len(drafted)
    out = [int(target_greedy[0])]
    for j in range(k):
        if int(drafted[j]) != int(target_greedy[j]):
            break
        out.append(int(target_greedy[j + 1]))
    return out
