"""Paged int8 KV serving: shared page pool, block tables, prefix reuse,
chunked prefill, self-speculative low-bit decode.

The dense-slot engine (serve/engine.py) pins ``max_seq`` cache rows per
resident request — a request that generates 10 tokens against a 1024-row
lane wastes 99% of its HBM reservation, and that internal fragmentation is
what caps resident requests at scale.  This engine stores the same int8
codec (core/kv_cache.py) as fixed-size **pages** in one shared pool:

  * **pages + block tables** — a request owns a host-side list of physical
    page ids (its block table); pages are allocated on first write and
    freed on eviction, so a request's HBM footprint is
    ``ceil(occupancy / page_size)`` pages, not ``max_seq`` rows.  Page 0 is
    the engine's garbage page: inactive decode lanes and unallocated table
    blocks point at it, its contents are finite-but-meaningless, and the
    position mask hides every read of it.
  * **gather decode** — the jitted step runs the paged multi-token forward
    (models/lm.py ``lm_paged_decode``): quantize-on-write into the owning
    page, then gather the whole table back — the Pallas backend streams
    pages by block-table scalar prefetch (kernels/kv_gather.py), other
    backends run its XLA twin.  A one-token step is arithmetically
    identical to the dense lane step, so ``paged=True`` is token-for-token
    identical to the dense engine at equal seeds (same slots, default
    single-chunk prefill, spec decode off).
  * **prefix reuse** — prompt pages are hash-consed: at prefill completion
    every full-page prompt boundary (and the partial tail) registers
    ``prompt[:m] -> pages`` with a refcount per page.  A later prompt
    adopts the longest registered prefix: full pages are shared read-only
    (refcount++), a partial boundary page is **copied on write** (the
    divergence point gets a private copy), and prefill restarts at ``m``
    instead of 0 — a common system prompt is stored once across all
    requests, and never recomputed.
  * **chunked prefill** — prompts longer than ``prefill_chunk`` stream in
    fixed-size chunks, one chunk per engine step, interleaved with decode
    (the chunk and the decode batch are separate forwards, but no prompt
    ever monopolizes the pool for multiple steps).  A chunk is the same
    paged forward with ``C = prefill_chunk``.
  * **speculative decode** — ``spec_decode=True`` runs serve/spec.py's
    propose/verify loop: the draft is the SAME parameters under an
    aggressive low-bit policy, so k draft steps + 1 verify forward emit up
    to ``k + 1`` exact target-greedy tokens per round.
  * **preemption** — when the pool runs dry the youngest request is
    preempted: its private pages are freed and it re-queues (front) with
    its generated tokens carried, to be re-prefilled later.  Sampling keys
    depend only on ``(seed, rid, token index)``, so a preempted-and-resumed
    request finishes with the tokens it would have had anyway.

HBM arithmetic (the fragmentation win the bench records): at equal pool
bytes the dense engine holds ``slots`` requests, each pinning ``max_seq``
rows; this engine holds ``slots`` *lanes* over ``slots * max_seq / P``
pages and admits as many requests as actually-written pages fit — with
typical occupancy below half of ``max_seq``, twice the resident requests
at equal HBM.
"""

from __future__ import annotations

import time
from collections import Counter, OrderedDict, deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import quantize_kv_rows
from .engine import ServeEngine, _Slot
from .sampling import sample_tokens, slot_keys
from .spec import SpecStats, default_draft_policy, greedy_accept

__all__ = ["PagePool", "PrefixCache", "PagedServeEngine"]

GARBAGE_PAGE = 0


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


class PagePool:
    """Host-side allocator for the shared device page pool: a free list +
    per-page refcounts.  Page 0 (``GARBAGE_PAGE``) is reserved — never
    allocated, never freed — as the write/read target of inactive lanes.

    Refcount protocol: ``alloc`` returns a page at refcount 1 (owned by the
    caller); sharing (a second block table, a prefix-cache entry) takes
    ``incref``; every owner releases with ``decref``, and the page returns
    to the free list when the count hits 0.  Pages with refcount > 1 are
    shared and must be treated read-only past their valid rows (the
    copy-on-write rule lives in the engine).
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError(f"n_pages={n_pages}: need at least the garbage "
                             f"page plus one allocatable page")
        self.n_pages = n_pages
        self.page_size = page_size
        self.refs = np.zeros((n_pages,), np.int32)
        self._free: deque = deque(range(1, n_pages))
        self.peak_in_use = 0

    # -- allocation --------------------------------------------------------
    def alloc(self) -> Optional[int]:
        if not self._free:
            return None
        pid = self._free.popleft()
        self.refs[pid] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return pid

    def incref(self, pid: int) -> None:
        if pid == GARBAGE_PAGE:
            return
        assert self.refs[pid] > 0, f"incref on free page {pid}"
        self.refs[pid] += 1

    def decref(self, pid: int) -> None:
        if pid == GARBAGE_PAGE:
            return
        assert self.refs[pid] > 0, f"decref on free page {pid}"
        self.refs[pid] -= 1
        if self.refs[pid] == 0:
            self._free.append(pid)

    # -- accounting --------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.n_pages - 1 - len(self._free)

    @property
    def utilization(self) -> float:
        return self.in_use / max(self.n_pages - 1, 1)

    def check(self, tables: List[List[int]],
              registry_pages: List[Tuple[int, ...]]) -> None:
        """Invariant check (tests): recompute every page's expected
        refcount from the live block tables + registry entries and compare;
        also verify free pages carry refcount 0 and are not referenced."""
        expect = np.zeros_like(self.refs)
        for table in tables:
            for pid in table:
                if pid != GARBAGE_PAGE:
                    expect[pid] += 1
        for pages in registry_pages:
            for pid in pages:
                expect[pid] += 1
        if not np.array_equal(expect, self.refs):
            bad = np.nonzero(expect != self.refs)[0]
            raise AssertionError(
                f"refcount drift on pages {bad.tolist()}: expected "
                f"{expect[bad].tolist()}, have {self.refs[bad].tolist()}")
        free = set(self._free)
        assert len(free) == len(self._free), "free list holds duplicates"
        for pid in free:
            assert self.refs[pid] == 0, f"free page {pid} has refs"


class PrefixCache:
    """Hash-consed prompt prefixes: ``tokens-tuple -> (n_tokens, pages)``,
    LRU-ordered, holding one refcount on every page of every entry.

    Entries are registered at every full-page prompt boundary plus the
    partial tail (rows past ``n_tokens`` in the tail page are garbage by
    contract — adopters copy-on-write that page and overwrite from the
    divergence point).  ``lookup`` returns the longest registered prefix
    strictly shorter than the prompt, so the admitting request always
    recomputes at least its last position (the first-token logits must
    exist).
    """

    def __init__(self, max_entries: int = 128):
        self.max_entries = max_entries
        self.entries: "OrderedDict[tuple, Tuple[int, Tuple[int, ...]]]" = \
            OrderedDict()
        self._lengths: Counter = Counter()
        self.hits = 0
        self.evictions = 0

    def register(self, tokens: tuple, pages: Tuple[int, ...],
                 pool: PagePool) -> None:
        key = tuple(tokens)
        if key in self.entries:
            self.entries.move_to_end(key)
            return
        for pid in pages:
            pool.incref(pid)
        self.entries[key] = (len(key), pages)
        self._lengths[len(key)] += 1
        while len(self.entries) > self.max_entries:
            self.evict_lru(pool)

    def lookup(self, ctx: tuple) -> Tuple[int, Tuple[int, ...]]:
        """Longest registered prefix of ``ctx`` with ``m <= len(ctx) - 1``;
        returns (0, ()) on miss."""
        for m in sorted((ln for ln in self._lengths if ln <= len(ctx) - 1),
                        reverse=True):
            entry = self.entries.get(tuple(ctx[:m]))
            if entry is not None:
                self.entries.move_to_end(tuple(ctx[:m]))
                self.hits += 1
                return m, entry[1]
        return 0, ()

    def evict_lru(self, pool: PagePool) -> bool:
        if not self.entries:
            return False
        key, (n, pages) = self.entries.popitem(last=False)
        self._lengths[n] -= 1
        if not self._lengths[n]:
            del self._lengths[n]
        for pid in pages:
            pool.decref(pid)
        self.evictions += 1
        return True

    def clear(self, pool: PagePool) -> None:
        while self.evict_lru(pool):
            pass

    def registered_pages(self) -> List[Tuple[int, ...]]:
        return [pages for _n, pages in self.entries.values()]


class _PagedSlot(_Slot):
    """One decode lane plus its paged state."""

    __slots__ = ("table", "ctx", "done", "phase", "needs_first", "admit_seq")

    def __init__(self):
        super().__init__()
        self.table: List[int] = []     # physical page ids, logical order
        self.ctx: tuple = ()           # tokens to prefill (prompt [+carry])
        self.done = 0                  # prefilled positions so far
        self.phase = "decode"          # "prefill" | "decode"
        self.needs_first = True        # sample token 0 from prefill logits?
        self.admit_seq = -1            # admission order (preemption picks max)


class PagedServeEngine(ServeEngine):
    """See module docstring.  Construct via ``ServeEngine(..., paged=True)``
    (or :meth:`ServeEngine.from_checkpoint` with ``paged=True``); the
    scheduler surface — ``submit`` / ``step`` / ``run`` / ``completions`` —
    is the dense engine's unchanged.
    """

    def __init__(self, cfg, params, *, page_size: int = 8,
                 pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 prefix_sharing: bool = True, prefix_entries: int = 128,
                 spec_decode: bool = False, spec_k: int = 3,
                 draft_policy=None, kv_quant=True, paged: bool = True,
                 **kw):
        del paged                      # consumed by ServeEngine.__new__
        if page_size < 1:
            raise ValueError(f"page_size={page_size} must be >= 1")
        self.page_size = page_size
        self._pages_arg = pages
        self.prefill_chunk = prefill_chunk
        self.prefix_sharing = prefix_sharing
        self._prefix_entries = prefix_entries
        self.spec_decode = spec_decode
        self.spec_k = spec_k
        self._draft_policy_arg = draft_policy
        if not kv_quant:
            raise ValueError("the paged engine stores pages in the int8 KV "
                             "codec; kv_quant must name a kv_cache spec "
                             "(True/'kv_int8:8'), not False")
        super().__init__(cfg, params, kv_quant=kv_quant, **kw)
        self._draft_policy = (draft_policy if draft_policy is not None
                              else default_draft_policy(
                                  self.policy, self.weight_bits is not None))
        if spec_k < 1:
            raise ValueError(f"spec_k={spec_k} must be >= 1")
        self.spec_stats = SpecStats()
        self.page_usage: List[int] = []      # pages in use, per step
        self.page_events: Dict[str, int] = {
            "prefix_hits": 0, "cow_copies": 0, "preemptions": 0,
            "registered": 0}
        self._resume: deque = deque()        # (Request, carried tokens)
        self._admit_counter = 0
        self._chunk_fns: dict = {}
        self._insert_page_fns: dict = {}
        self._copy_fn = None
        self._spec_fns: dict = {}

    # -- construction ------------------------------------------------------
    def _init_cache(self):
        if self.kv_spec is None:
            raise ValueError(f"{self.cfg.name}: paged serving requires the "
                             f"int8 KV cache")
        if self.model.init_paged_pool is None:
            raise ValueError(f"{self.cfg.name}: no paged-pool support for "
                             f"this family")
        if self.max_seq % self.page_size:
            raise ValueError(f"max_seq={self.max_seq} must be a multiple of "
                             f"page_size={self.page_size} (block tables "
                             f"cover whole pages)")
        self.nb = self.max_seq // self.page_size
        self.n_pages = (self._pages_arg if self._pages_arg is not None
                        else 1 + self.slots * self.nb)
        if self.prefill_chunk is None:
            self.prefill_chunk = self.max_seq
        self.pool_host = PagePool(self.n_pages, self.page_size)
        self._prefix = PrefixCache(self._prefix_entries)
        # replace the per-slot lanes with paged slots (the base class built
        # plain ones before calling us)
        self._slots = [_PagedSlot() for _ in range(self.slots)]
        return self.model.init_paged_pool(self.cfg, self.n_pages,
                                          self.page_size)

    # -- jitted steps ------------------------------------------------------
    def _step_fn(self, params, pool, tok, pos, table, rids, counts, temp,
                 topk, topp):
        keys = slot_keys(self._base_key, rids, counts)
        logits, pool = self.model.paged_decode(
            params, pool, {"tokens": tok[:, None]}, self.policy, table, pos,
            kv_quant=self.kv_spec)
        nxt = sample_tokens(logits[:, -1], keys, temp, topk,
                            self.cfg.vocab_size, topp)
        return pool, nxt

    def _chunk_fn(self, C: int):
        """Jitted (1, C) chunk forward, compiled once per chunk width."""
        fn = self._chunk_fns.get(C)
        if fn is None:
            def run(params, pool, toks, table, start):
                return self.model.paged_decode(
                    params, pool, {"tokens": toks}, self.policy, table,
                    start, kv_quant=self.kv_spec)
            fn = self._chunk_fns[C] = jax.jit(run, donate_argnums=(1,))
        return fn

    def _insert_pages(self, pool, kv, table_row):
        """Scatter a fp prefill bucket (L, 1, lb, flat) into the slot's
        pages, quantizing rows exactly like the dense engine's lane insert.
        The whole bucket slab is written (compiled per bucket): rows past
        the real context land in the partial tail page or the garbage page
        and stay masked until overwritten — the same write-before-expose
        argument as the dense ``_insert``."""
        lb = kv["k"].shape[2]
        fn = self._insert_page_fns.get(lb)
        if fn is None:
            P = self.page_size
            bits = self.kv_spec.bits or 8

            def ins(pool, kv, table):
                offs = jnp.arange(lb, dtype=jnp.int32)
                pids = table[offs // P]
                rows = offs % P
                out = dict(pool)
                for side in ("k", "v"):
                    codes, scale, zero = quantize_kv_rows(kv[side], bits)
                    lane = dict(pool[side])
                    lane["codes"] = lane["codes"].at[:, pids, rows].set(
                        codes[:, 0])
                    lane["scale"] = lane["scale"].at[:, pids, rows].set(
                        scale[:, 0])
                    lane["zero"] = lane["zero"].at[:, pids, rows].set(
                        zero[:, 0])
                    out[side] = lane
                return out
            fn = self._insert_page_fns[lb] = jax.jit(ins, donate_argnums=(0,))
        return fn(pool, kv, table_row)

    def _copy_page(self, pool, src: int, dst: int):
        """Device copy of one physical page across all layers and both
        sides — the copy-on-write at a shared partial-page divergence."""
        if self._copy_fn is None:
            def cp(pool, src, dst):
                return jax.tree.map(
                    lambda leaf: leaf.at[:, dst].set(leaf[:, src]), pool)
            self._copy_fn = jax.jit(cp, donate_argnums=(0,))
        return self._copy_fn(pool, jnp.int32(src), jnp.int32(dst))

    # -- page pressure -----------------------------------------------------
    def _alloc_page(self, requester: _PagedSlot) -> Optional[int]:
        """One page, applying pressure in order: free list -> evict
        prefix-cache LRU entries -> preempt the youngest request *younger
        than the requester*.  The age bound is the forward-progress
        guarantee: the oldest resident request can never be preempted, so
        it always completes and frees its pages — preemption cascades can
        thrash, but never livelock."""
        while True:
            pid = self.pool_host.alloc()
            if pid is not None:
                return pid
            if self._prefix.evict_lru(self.pool_host):
                continue
            victim = None
            for slot in self._slots:
                if slot.active and slot is not requester \
                        and slot.admit_seq > requester.admit_seq:
                    if victim is None or slot.admit_seq > victim.admit_seq:
                        victim = slot
            if victim is None:
                return None
            self._preempt(victim)

    def _ensure_blocks(self, slot: _PagedSlot, n_blocks: int) -> bool:
        while len(slot.table) < n_blocks:
            pid = self._alloc_page(slot)
            if pid is None:
                return False
            slot.table.append(pid)
        return True

    def _preempt(self, slot: _PagedSlot) -> None:
        """Free the slot's pages and re-queue its request (front) with the
        generated tokens carried; it re-prefills when pages free up.
        Sampling keys are (seed, rid, token index) — resumption emits the
        tokens the request would have gotten anyway."""
        self.page_events["preemptions"] += 1
        for pid in slot.table:
            self.pool_host.decref(pid)
        self._resume.appendleft((slot.req, list(slot.tokens), slot.admit_seq))
        self._reset_slot(slot)

    def _reset_slot(self, slot: _PagedSlot) -> None:
        slot.req = None
        slot.tokens = []
        slot.pos = 0
        slot.table = []
        slot.ctx = ()
        slot.done = 0
        slot.phase = "decode"
        slot.needs_first = True

    def _finish(self, slot: _PagedSlot, reason: str) -> None:
        for pid in slot.table:
            self.pool_host.decref(pid)
        slot.table = []
        super()._finish(slot, reason)
        self._reset_slot(slot)

    # -- admission / prefill -----------------------------------------------
    def _table_row(self, slot: _PagedSlot) -> np.ndarray:
        row = np.zeros((self.nb,), np.int32)
        row[:len(slot.table)] = slot.table
        return row

    def _register_prefix(self, slot: _PagedSlot) -> None:
        if not self.prefix_sharing:
            return
        prompt = slot.req.prompt
        lp, P = len(prompt), self.page_size
        for b in range(P, lp + 1, P):
            self._prefix.register(prompt[:b], tuple(slot.table[:b // P]),
                                  self.pool_host)
            self.page_events["registered"] += 1
        if lp % P:
            self._prefix.register(prompt,
                                  tuple(slot.table[:_ceil_div(lp, P)]),
                                  self.pool_host)
            self.page_events["registered"] += 1

    def _adopt_prefix(self, slot: _PagedSlot, ctx: tuple) -> int:
        """Adopt the longest registered prefix of ``ctx``: share the full
        pages, copy-on-write a partial tail page.  Returns the number of
        positions already materialized (0 on miss)."""
        if not self.prefix_sharing:
            return 0
        m, pages = self._prefix.lookup(ctx)
        if not m:
            return 0
        P = self.page_size
        full, partial = m // P, m % P
        for pid in pages[:full]:
            self.pool_host.incref(pid)
            slot.table.append(pid)
        if partial:
            # pin the divergence page across allocation pressure: the
            # alloc below may evict the very registry entry these pages
            # came from, and an unpinned src could be freed + recycled as
            # our dst before the copy runs
            src = pages[full]
            self.pool_host.incref(src)
            dst = self._alloc_page(slot)
            if dst is None:
                # can't copy the divergence page — fall back to the full
                # boundary (recompute the partial rows instead)
                m = full * P
            else:
                self._cache = self._copy_page(self._cache, src, dst)
                slot.table.append(dst)
                self.page_events["cow_copies"] += 1
            self.pool_host.decref(src)
        if m:
            self.page_events["prefix_hits"] += 1
        return m

    def _bucket_prefill(self, slot: _PagedSlot) -> bool:
        """Whole-context fp prefill through the dense engine's bucket path,
        scattered into pages — bit-identical inputs to the dense engine's
        admission, which is what makes paged↔dense token parity exact."""
        ctx = slot.ctx
        n = len(ctx)
        if not self._ensure_blocks(slot, _ceil_div(n, self.page_size)):
            return False
        logits, kv = self._prefill(np.asarray(ctx, np.int32)[None])
        self._cache = self._insert_pages(self._cache, kv,
                                         jnp.asarray(self._table_row(slot)))
        self._finish_prefill(slot, logits, last_row=None)
        return True

    def _chunk_prefill_step(self, slot: _PagedSlot) -> bool:
        """Advance one fixed-size chunk of a long (or prefix-resumed)
        prompt through the paged multi-token forward."""
        C = self.prefill_chunk
        ctx, n = slot.ctx, len(slot.ctx)
        start = slot.done
        take = min(C, n - start)
        blocks = _ceil_div(start + take, self.page_size)
        if not self._ensure_blocks(slot, blocks):
            return False
        buf = np.zeros((1, C), np.int32)
        buf[0, :take] = ctx[start:start + take]
        logits, self._cache = self._chunk_fn(C)(
            self.params, self._cache, jnp.asarray(buf),
            jnp.asarray(self._table_row(slot)[None]),
            jnp.asarray([start], np.int32))
        slot.done = start + take
        if slot.done >= n:
            self._finish_prefill(slot, logits, last_row=take - 1)
        return True

    def _finish_prefill(self, slot: _PagedSlot, logits, last_row) -> None:
        req = slot.req
        if slot.needs_first:
            lg = logits[0, -1] if last_row is None else logits[0, last_row]
            first = int(self._sample1(
                lg, slot_keys(self._base_key,
                              jnp.asarray([req.rid], jnp.int32),
                              jnp.asarray([0], jnp.int32))[0],
                req.temperature, req.top_k, req.top_p))
            slot.tokens = [first]
        slot.pos = len(slot.ctx)
        slot.done = len(slot.ctx)
        slot.phase = "decode"
        if slot.needs_first:
            self._register_prefix(slot)

    def _admit(self):
        for slot in self._slots:
            if slot.active:
                continue
            if self._resume:
                # a preempted request keeps its original admission age, so
                # on readmission it may reclaim pages from anything that
                # arrived after it (see _alloc_page's progress argument)
                req, carried, seq = self._resume.popleft()
            elif self._queue:
                req, carried, seq = (self._queue.popleft(), [],
                                     self._admit_counter)
                self._admit_counter += 1
            else:
                continue
            slot.req = req
            slot.tokens = list(carried)
            slot.needs_first = not carried
            slot.admit_seq = seq
            ctx = req.prompt + tuple(carried[:-1])
            slot.ctx = ctx
            m = self._adopt_prefix(slot, ctx)
            slot.done = m
            slot.pos = m
            if m == 0 and len(ctx) <= self.prefill_chunk:
                ok = self._bucket_prefill(slot)
            else:
                slot.phase = "prefill"
                ok = self._chunk_prefill_step(slot)
            if not ok:
                # not even with preemption pressure — push back and stop
                # admitting this step
                carried = list(slot.tokens)
                for pid in slot.table:
                    self.pool_host.decref(pid)
                self._resume.appendleft((slot.req, carried, slot.admit_seq))
                self._reset_slot(slot)
                break
        self._evict()

    # -- the loop ----------------------------------------------------------
    def step(self) -> int:
        self._evict()
        self._admit()
        for slot in self._slots:
            if slot.active and slot.phase == "prefill":
                if not self._chunk_prefill_step(slot):
                    self._preempt(slot)
        self._evict()
        decode = [s for s in self._slots
                  if s.active and s.phase == "decode"]
        self.page_usage.append(self.pool_host.in_use)
        if not decode:
            if not any(s.active for s in self._slots) \
                    and (self._queue or self._resume):
                raise RuntimeError(
                    f"page pool ({self.n_pages} pages x {self.page_size} "
                    f"rows) cannot hold a single queued request; grow "
                    f"`pages` or shrink prompts")
            return 0
        if self.spec_decode:
            fits = all(s.pos + self.spec_k <= self.max_seq - 1
                       for s in decode)
            if fits and all(
                    self._ensure_blocks(
                        s, (s.pos + self.spec_k) // self.page_size + 1)
                    for s in decode if s.active):
                decode = [s for s in self._slots
                          if s.active and s.phase == "decode"]
                if decode:
                    return self._spec_step(decode)
                return 0
            self.spec_stats.fallback_steps += 1
        return self._plain_step()

    def _plain_step(self) -> int:
        B = self.slots
        for slot in self._slots:
            if slot.active and slot.phase == "decode":
                if not self._ensure_blocks(slot,
                                           slot.pos // self.page_size + 1):
                    self._preempt(slot)
        tok = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        rids = np.full((B,), -1, np.int32)
        counts = np.zeros((B,), np.int32)
        temp = np.zeros((B,), np.float32)
        topk = np.zeros((B,), np.int32)
        topp = np.zeros((B,), np.float32)
        table = np.zeros((B, self.nb), np.int32)
        live = []
        for i, slot in enumerate(self._slots):
            if not slot.active or slot.phase != "decode":
                continue
            live.append(i)
            tok[i] = slot.tokens[-1]
            pos[i] = slot.pos
            rids[i] = slot.req.rid
            counts[i] = len(slot.tokens)
            temp[i] = slot.req.temperature
            topk[i] = slot.req.top_k
            topp[i] = slot.req.top_p
            table[i] = self._table_row(slot)
        if not live:
            return 0
        t0 = time.perf_counter()
        self._cache, nxt = self._decode(
            self.params, self._cache, jnp.asarray(tok), jnp.asarray(pos),
            jnp.asarray(table), jnp.asarray(rids), jnp.asarray(counts),
            jnp.asarray(temp), jnp.asarray(topk), jnp.asarray(topp))
        nxt = np.asarray(jax.block_until_ready(nxt))
        dt = time.perf_counter() - t0
        emitted = 0
        for i in live:
            slot = self._slots[i]
            slot.tokens.append(int(nxt[i]))
            slot.pos += 1
            emitted += 1
        self.step_times.append((dt, emitted))
        return emitted

    def run(self, max_steps=None):
        """Base drain loop, extended to count preempted requests waiting in
        the resume queue as pending work."""
        steps = 0
        while self._queue or self._resume \
                or any(s.active for s in self._slots):
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        self._evict()
        done = self._completions
        self._completions = {}
        return done

    # -- speculative decode ------------------------------------------------
    def _spec_fn(self, name: str):
        fn = self._spec_fns.get(name)
        if fn is not None:
            return fn
        vocab = self.cfg.vocab_size

        if name == "draft":
            def draft(params, pool, tok, table, pos):
                logits, pool = self.model.paged_decode(
                    params, pool, {"tokens": tok[:, None]},
                    self._draft_policy, table, pos, kv_quant=self.kv_spec)
                g = jnp.argmax(logits[:, -1, :vocab], axis=-1)
                return pool, g.astype(jnp.int32)
            fn = jax.jit(draft, donate_argnums=(1,))
        else:
            def verify(params, pool, prop, table, pos):
                logits, pool = self.model.paged_decode(
                    params, pool, {"tokens": prop}, self.policy, table,
                    pos, kv_quant=self.kv_spec)
                g = jnp.argmax(logits[:, :, :vocab], axis=-1)
                return pool, g.astype(jnp.int32), logits[:, 0]
            fn = jax.jit(verify, donate_argnums=(1,))
        self._spec_fns[name] = fn
        return fn

    def _spec_step(self, decode: List[_PagedSlot]) -> int:
        """One propose/verify round: k draft steps (aggressive policy,
        greedy, provisional KV) + one (B, k+1) verify forward that
        overwrites those rows with target-policy KV, then exact greedy
        acceptance per slot.  Emits 1..k+1 tokens per greedy slot;
        temperature slots take one token sampled from the verify's
        first-position logits."""
        B, k = self.slots, self.spec_k
        tok = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        table = np.zeros((B, self.nb), np.int32)
        lanes = []
        for i, slot in enumerate(self._slots):
            if slot in decode:
                lanes.append(i)
                tok[i] = slot.tokens[-1]
                pos[i] = slot.pos
                table[i] = self._table_row(slot)
        table_dev = jnp.asarray(table)
        pos_dev = jnp.asarray(pos)
        prop = np.zeros((B, k + 1), np.int32)
        prop[:, 0] = tok
        t0 = time.perf_counter()
        cur = tok
        draft = self._spec_fn("draft")
        for j in range(k):
            self._cache, g = draft(self.params, self._cache,
                                   jnp.asarray(cur), table_dev, pos_dev + j)
            cur = np.asarray(g)
            prop[:, j + 1] = cur
        self._cache, gv, logits0 = self._spec_fn("verify")(
            self.params, self._cache, jnp.asarray(prop), table_dev, pos_dev)
        gv = np.asarray(jax.block_until_ready(gv))
        dt = time.perf_counter() - t0
        emitted_total = 0
        for i in lanes:
            slot = self._slots[i]
            req = slot.req
            if req.temperature > 0.0:
                key = slot_keys(self._base_key,
                                jnp.asarray([req.rid], jnp.int32),
                                jnp.asarray([len(slot.tokens)], jnp.int32))[0]
                out = [int(self._sample1(logits0[i], key, req.temperature,
                                         req.top_k, req.top_p))]
            else:
                out = greedy_accept(prop[i, 1:], gv[i])
                self.spec_stats.proposed += k
                self.spec_stats.accepted += len(out) - 1
            if req.eos_id is not None and req.eos_id in out:
                out = out[:out.index(req.eos_id) + 1]
            out = out[:req.max_new - len(slot.tokens)]
            slot.tokens.extend(out)
            slot.pos += len(out)
            emitted_total += len(out)
        self.spec_stats.spec_steps += 1
        self.spec_stats.emitted += emitted_total
        self.step_times.append((dt, emitted_total))
        return emitted_total

    # -- introspection -----------------------------------------------------
    @property
    def queued(self) -> int:
        return len(self._queue) + len(self._resume)

    def pool_stats(self) -> dict:
        usage = self.page_usage or [0]
        return {
            "n_pages": self.n_pages,
            "page_size": self.page_size,
            "pages_in_use": self.pool_host.in_use,
            "peak_pages_in_use": self.pool_host.peak_in_use,
            "mean_utilization": float(np.mean(usage)) /
                                max(self.n_pages - 1, 1),
            "peak_utilization": self.pool_host.peak_in_use /
                                max(self.n_pages - 1, 1),
            "prefix_entries": len(self._prefix.entries),
            **self.page_events,
        }

    def check_invariants(self) -> None:
        """Refcount/table cross-check for the churn tests."""
        self.pool_host.check(
            [s.table for s in self._slots if s.active],
            self._prefix.registered_pages())
