"""Serving: continuous-batching engine over the quantized decode path."""

from .engine import Completion, Request, ServeEngine
from .sampling import sample_tokens, slot_keys

__all__ = ["ServeEngine", "Request", "Completion", "sample_tokens",
           "slot_keys"]
