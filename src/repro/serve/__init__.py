"""Serving: continuous-batching engines over the quantized decode path.

Two engines share one scheduler surface: the dense-slot engine
(serve/engine.py, one ``max_seq`` cache lane per slot) and the paged
engine (serve/paged.py, ``ServeEngine(..., paged=True)`` — shared int8
page pool, block tables, prefix reuse, chunked prefill, and optional
self-speculative decode via serve/spec.py).
"""

from .engine import Completion, Request, ServeEngine
from .paged import PagedServeEngine, PagePool, PrefixCache
from .sampling import sample_tokens, slot_keys
from .spec import SpecStats, default_draft_policy, greedy_accept

__all__ = ["ServeEngine", "PagedServeEngine", "PagePool", "PrefixCache",
           "Request", "Completion", "sample_tokens", "slot_keys",
           "SpecStats", "default_draft_policy", "greedy_accept"]
