from .fault_tolerance import (ElasticController, PreemptionHandler,
                              StragglerMonitor, retry)

__all__ = ["PreemptionHandler", "StragglerMonitor", "retry",
           "ElasticController"]
