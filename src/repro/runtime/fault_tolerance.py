"""Fault-tolerance runtime: preemption handling, straggler monitoring,
bounded retries, and elastic mesh re-configuration.

On a real cluster these hooks are driven by the scheduler (SIGTERM before
preemption, per-host heartbeats).  Everything here is pure library logic —
unit-tested with injected clocks/signals — so the training loop composes it
identically on 1 CPU or 1024 hosts.
"""

from __future__ import annotations

import signal
import threading
import time
from typing import Callable, Dict, List

__all__ = ["PreemptionHandler", "StragglerMonitor", "retry",
           "ElasticController"]


class PreemptionHandler:
    """Converts SIGTERM/SIGINT into a checkpoint-then-exit request.

    Usage::
        prm = PreemptionHandler(install=True)
        for step in ...:
            ...
            if prm.should_stop:
                ckpt.save(step, state); break
    """

    def __init__(self, install: bool = False, signals=(signal.SIGTERM,)):
        self._stop = threading.Event()
        if install:
            for sig in signals:
                signal.signal(sig, self._handler)

    def _handler(self, signum, frame):
        self._stop.set()

    def request_stop(self):
        """Programmatic trigger (tests / external schedulers)."""
        self._stop.set()

    @property
    def should_stop(self) -> bool:
        return self._stop.is_set()


class StragglerMonitor:
    """Flags hosts whose step times exceed ``threshold`` x the fleet median.

    Feed per-host step durations each step; ``stragglers()`` returns hosts
    that were slow for ``patience`` consecutive steps — the signal a real
    deployment uses to trigger hot-spare swap-in (elastic re-shard).
    """

    def __init__(self, n_hosts: int, threshold: float = 2.0,
                 patience: int = 3):
        self.n_hosts = n_hosts
        self.threshold = threshold
        self.patience = patience
        self._slow_streak = [0] * n_hosts
        self.history: List[List[float]] = []

    def record(self, step_times: List[float]):
        assert len(step_times) == self.n_hosts
        self.history.append(list(step_times))
        med = sorted(step_times)[self.n_hosts // 2]
        for h, t in enumerate(step_times):
            if t > self.threshold * med:
                self._slow_streak[h] += 1
            else:
                self._slow_streak[h] = 0

    def stragglers(self) -> List[int]:
        return [h for h, s in enumerate(self._slow_streak)
                if s >= self.patience]


def retry(fn: Callable, max_attempts: int = 3, backoff: float = 0.5,
          retriable=(RuntimeError, OSError), sleep=time.sleep):
    """Bounded retry with exponential backoff (transient collective failures,
    checkpoint-storage hiccups)."""
    last = None
    for attempt in range(max_attempts):
        try:
            return fn()
        except retriable as e:                         # noqa: PERF203
            last = e
            if attempt + 1 < max_attempts:
                sleep(backoff * (2 ** attempt))
    raise last


class ElasticController:
    """Decides the mesh shape when the healthy-host set changes.

    Given the nominal mesh (pods, data, model) and a healthy-chip count,
    returns the largest runnable mesh that keeps the `model` axis intact
    (TP degree is fixed by memory) and shrinks the data axis — the standard
    elastic-DP policy.  The training loop then: checkpoint -> rebuild mesh ->
    restore (CheckpointManager reshards) -> continue.
    """

    def __init__(self, model_parallel: int, chips_per_host: int = 4):
        self.tp = model_parallel
        self.chips_per_host = chips_per_host

    def plan_mesh(self, healthy_chips: int) -> Dict[str, int]:
        dp = healthy_chips // self.tp
        if dp < 1:
            raise RuntimeError(
                f"not enough chips ({healthy_chips}) for TP={self.tp}")
        return {"data": dp, "model": self.tp}

    def should_rescale(self, current_dp: int, healthy_chips: int) -> bool:
        return self.plan_mesh(healthy_chips)["data"] != current_dp
