"""The training step, built once: FQT loss/grad, gradient accumulation,
compressed DP all-reduce, clipping, optimizer update — over a TrainState.

``make_step_fn`` returns the pure ``(state, batch) -> (state, metrics)``
function; ``jit_step`` compiles it the way a production job runs it —
explicit ``in_shardings``/``out_shardings`` from the sharding plan and the
whole state donated.

RNG contract (paper Theorem 1 needs independent SR draws): every step
*splits* ``state.rng`` into (per-step base, next stream).  Microbatch ``i``
quantizes under ``fold_in(base, i)`` — SR noise is independent across
microbatches and across steps, and because the stream lives in the
checkpointed state, a resumed run replays bit-identical draws.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.compression import compressed_grad_allreduce
from ..optim import clip_by_global_norm
from .state import TrainState, state_shardings

__all__ = ["make_step_fn", "jit_step", "split_microbatches"]


def split_microbatches(batch: dict, accum_steps: int) -> dict:
    """Reshape every batch leaf's batch dim into (accum_steps, micro, ...).

    The batch dim is axis 0 for every input except the VLM m-rope
    ``positions`` leaf, which is (3, B, T) — mirrored from
    ``ShardingPlan.batch_spec``.  Raises ValueError naming the first leaf
    whose batch dim doesn't divide.
    """
    def split(path, x):
        ps = jax.tree_util.keystr(path)
        axis = 1 if ("positions" in ps and x.ndim == 3) else 0
        if x.shape[axis] % accum_steps:
            raise ValueError(
                f"batch leaf {ps} dim {axis} ({x.shape[axis]}) not divisible "
                f"by accum_steps={accum_steps}")
        micro = x.shape[axis] // accum_steps
        x = x.reshape(x.shape[:axis] + (accum_steps, micro) + x.shape[axis + 1:])
        return jnp.moveaxis(x, axis, 0) if axis else x

    return jax.tree_util.tree_map_with_path(split, batch)


def make_step_fn(model, policy, opt, lr_fn, *, clip_norm: float = 1.0,
                 remat: bool = True, accum_steps: int = 1, mesh=None,
                 compress_axis: str | None = None,
                 loss_kwargs: dict | None = None):
    """Build the pure training step over a :class:`TrainState`.

    accum_steps: number of microbatches the global batch is split into;
    gradients are accumulated with ``lax.scan`` (activation memory of one
    microbatch) and averaged — identical in expectation to the full-batch
    step, with independent SR draws per microbatch.

    compress_axis: mesh axis over which gradients are exchanged with the
    unbiased int8 compressed all-reduce instead of GSPMD's implicit fp32
    psum (beyond-paper, DESIGN.md Sec. 4).  Requires ``mesh``.
    """
    if accum_steps < 1:
        raise ValueError(f"accum_steps must be >= 1, got {accum_steps}")
    kw = dict(loss_kwargs or {})

    def loss_and_grads(params, batch, key):
        def loss_fn(p):
            return model.loss(p, batch, key, policy, remat=remat, **kw)
        (loss, mets), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        return loss, mets, grads

    def step_fn(state: TrainState, batch):
        # base_key feeds fold_in(., microbatch_i); compress_key is a sibling
        # split so the DP-compression SR draws can never alias a microbatch
        # key, whatever accum_steps is
        base_key, compress_key, next_rng = jax.random.split(state.rng, 3)
        if accum_steps == 1:
            loss, mets, grads = loss_and_grads(
                state.params, batch, jax.random.fold_in(base_key, 0))
        else:
            micro = split_microbatches(batch, accum_steps)

            def micro_step(g_acc, inp):
                i, mb = inp
                l, m, g = loss_and_grads(state.params, mb,
                                         jax.random.fold_in(base_key, i))
                return jax.tree.map(jnp.add, g_acc, g), (l, m)

            zeros = jax.tree.map(jnp.zeros_like, state.params)
            g_sum, (losses, mets_stack) = jax.lax.scan(
                micro_step, zeros, (jnp.arange(accum_steps), micro))
            grads = jax.tree.map(lambda g: g / accum_steps, g_sum)
            loss = jnp.mean(losses)
            mets = jax.tree.map(jnp.mean, mets_stack)

        if compress_axis is not None:
            grads = compressed_grad_allreduce(
                grads, mesh, compress_axis, compress_key,
                bits=policy.dp_grad_bits, mean=True)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(state.step)
        params, opt_state = opt.apply(state.params, grads, state.opt_state, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr, **mets}
        return TrainState(params=params, opt_state=opt_state,
                          step=state.step + 1, rng=next_rng), metrics

    return step_fn


def jit_step(step_fn, *, plan=None, abstract_state: TrainState | None = None,
             batch_shardings=None, donate: bool = True):
    """Compile a step: donated state, plan-derived in/out shardings.

    Without a plan this is plain ``jax.jit`` (single-device path); with one,
    the state round-trips through identical shardings so no resharding
    collectives surround the step.
    """
    donate_argnums = (0,) if donate else ()
    if plan is None:
        return jax.jit(step_fn, donate_argnums=donate_argnums)
    st_sh = state_shardings(plan, abstract_state)
    return jax.jit(step_fn,
                   in_shardings=(st_sh, batch_shardings),
                   out_shardings=(st_sh, None),
                   donate_argnums=donate_argnums)
