"""TrainState: the single pytree a training step consumes and produces.

Bundling ``(params, opt_state, step, rng)`` into one registered-dataclass
pytree is what makes the production step shape possible:

  * ``jax.jit(..., donate_argnums=(0,))`` donates the *whole* state — params
    and optimizer moments are updated in place, halving peak HBM for the
    update (measured by ``benchmarks/bench_train_step.py``);
  * ``state_shardings(plan, abstract)`` derives one sharding tree for the
    state from the :class:`~repro.sharding.ShardingPlan` param rules, so
    ``in_shardings == out_shardings`` and jit never inserts resharding
    collectives around the step;
  * ``step`` and ``rng`` live *inside* the checkpointed state, so a resumed
    run continues the exact same data stream and SR noise stream instead of
    replaying batch 0 with fresh keys.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["TrainState", "init_train_state", "abstract_train_state",
           "state_specs", "state_shardings"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array                  # () int32 — optimizer step count
    rng: jax.Array                   # PRNG key; split every step, never reused

    # Checkpoints store the dict form: stable flat paths ("params/...",
    # "opt/...", "step", "rng") independent of this class's field order.
    def as_dict(self) -> dict:
        return {"params": self.params, "opt": self.opt_state,
                "step": self.step, "rng": self.rng}

    @staticmethod
    def from_dict(d: dict) -> "TrainState":
        return TrainState(params=d["params"], opt_state=d["opt"],
                          step=d["step"], rng=d["rng"])


def init_train_state(model, opt, seed: int = 0) -> TrainState:
    """Fresh state: params from ``model.init``, zeroed opt state, step 0,
    and an rng stream independent of the init key."""
    init_key, rng = jax.random.split(jax.random.PRNGKey(seed))
    params = model.init(init_key)
    return TrainState(params=params, opt_state=opt.init(params),
                      step=jnp.zeros((), jnp.int32), rng=rng)


def abstract_train_state(model, opt, seed: int = 0) -> TrainState:
    """ShapeDtypeStruct skeleton (no allocation) — for shardings, lowering,
    and checkpoint restore targets."""
    return jax.eval_shape(lambda: init_train_state(model, opt, seed))


def state_specs(plan, abstract_state: TrainState) -> TrainState:
    """PartitionSpec tree for a TrainState.

    Optimizer moments mirror the param tree path-for-path, so the plan's
    substring rules apply verbatim; ``step``/``rng`` are replicated scalars.
    """
    return TrainState(
        params=plan.param_specs(abstract_state.params),
        opt_state=plan.param_specs(abstract_state.opt_state),
        step=P(), rng=P())


def state_shardings(plan, abstract_state: TrainState) -> TrainState:
    """NamedSharding tree for jit in/out_shardings and checkpoint restore."""
    return plan.shardings(state_specs(plan, abstract_state))
