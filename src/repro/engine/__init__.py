"""Training engine: the single way a training step is built and run.

Every entry point (``launch/train.py`` CLI, ``launch/dryrun.py`` AOT
compiles, examples, convergence benches) goes through this package instead
of hand-rolling its own loop.  Three layers:

``state``  :class:`TrainState` — one pytree ``(params, opt_state, step,
           rng)`` with a sharding tree derived from the
           :class:`~repro.sharding.ShardingPlan`, so the compiled step runs
           with explicit ``in_shardings == out_shardings`` over a real mesh
           and the whole state donated (no double-buffered update).

``step``   :func:`make_step_fn` — FQT loss/grad (per-layer role policies,
           all three backends), gradient accumulation via ``lax.scan`` with
           per-microbatch SR key folding (quantization noise independent
           across microbatches, Theorem 1's independence requirement), the
           compressed cross-pod all-reduce, clipping, and the optimizer
           update.  :func:`jit_step` compiles it, sharded and donated.

``engine`` :class:`Engine` — ``Engine.run()`` drives the loop with
           prefetch, async whole-state checkpointing, preemption
           checkpoint-and-exit, and straggler monitoring.  Resume is exact:
           loader position and rng stream live in the checkpoint.

TrainState lifecycle::

    init_train_state(model, opt, seed)        # fresh: step=0, split rng
      -> Engine.run() steps it (donated in, new buffers out)
      -> CheckpointManager.save(state.as_dict())  every ckpt_every
      -> restore: Engine.restore_state() device_puts onto THIS mesh's
         shardings (elastic across mesh shapes), loader fast-forwards to
         state.step, rng stream continues -> bit-identical continuation.

Migration from the old ``launch.train`` surface: ``train_loop(...)`` is now
a thin wrapper over ``Engine(...).run()`` (same signature, plus
``mesh=``/``accum_steps=``/``donate=``); ``make_train_step`` is replaced by
:func:`make_step_fn`, which takes/returns a TrainState instead of loose
``(params, opt_state, step, key)``.
"""

from .engine import Engine
from .state import (TrainState, abstract_train_state, init_train_state,
                    state_shardings, state_specs)
from .step import jit_step, make_step_fn, split_microbatches

__all__ = ["Engine", "TrainState", "init_train_state",
           "abstract_train_state", "state_specs", "state_shardings",
           "make_step_fn", "jit_step", "split_microbatches"]
