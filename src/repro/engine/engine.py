"""Engine: one object that owns the production training loop.

Wires together everything previous layers built — the FQT step
(:mod:`repro.engine.step`), the sharding plan, donated-buffer compilation,
the data pipeline with prefetch, async checkpointing of the *whole*
TrainState, preemption handling, and straggler monitoring — behind::

    eng = Engine(cfg, policy, steps=1000, batch_size=32, seq_len=256,
                 mesh=make_test_mesh(2, 2), accum_steps=4,
                 ckpt_dir="/ckpts")
    history = eng.run()

Resume semantics: the checkpoint holds ``(params, opt_state, step, rng)``.
On restore, the data loader fast-forwards to ``step`` (batches are
seed-by-step, so the stream continues exactly where it stopped) and the rng
stream continues from the saved key — a run that is preempted and resumed is
bit-identical to one that never stopped.
"""

from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager
from ..core import QuantPolicy
from ..data import Prefetcher, ShardedLoader, make_batch_for
from ..models import build_model
from ..optim import Optimizer, adamw, cosine_schedule, sgd
from ..runtime import PreemptionHandler, StragglerMonitor
from ..sharding import make_plan
from .state import (TrainState, abstract_train_state, init_train_state,
                    state_shardings)
from .step import jit_step, make_step_fn

__all__ = ["Engine"]


class Engine:
    """Builds the compiled step once and runs the full training loop.

    batch_size is the *global* batch per optimizer step; with
    ``accum_steps=k`` the step consumes it as k sequential microbatches of
    ``batch_size // k`` (lax.scan, independent SR keys per microbatch).

    ``batch_fn(step) -> batch`` must be a *pure, side-effect-free function
    of step* (the repo's determinism contract, data/synthetic.py) — resume
    fast-forwards by re-seeding from ``state.step``, and on the mesh path
    ``batch_fn(0)`` is called once concretely at construction to derive
    batch shardings (that batch is discarded).  Stateful iterators cannot
    resume and are not supported.
    """

    def __init__(self, cfg, policy: QuantPolicy, *, steps: int,
                 batch_size: int, seq_len: int, lr: float = 3e-3,
                 opt_name: str = "adamw", opt: Optional[Optimizer] = None,
                 accum_steps: int = 1, mesh=None, remat: bool = False,
                 donate: bool = True, clip_norm: float = 1.0,
                 compress_axis: Optional[str] = None,
                 loss_kwargs: Optional[dict] = None,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 100,
                 keep: int = 3, log_every: int = 10, seed: int = 0,
                 resume: bool = True,
                 preemption: Optional[PreemptionHandler] = None,
                 straggler: Optional[StragglerMonitor] = None,
                 straggler_probe: Optional[Callable[[float], list]] = None,
                 batch_fn: Optional[Callable[[int], dict]] = None,
                 log_fn=print):
        if batch_size % accum_steps:
            raise ValueError(f"batch_size={batch_size} not divisible by "
                             f"accum_steps={accum_steps}")
        self.cfg = cfg
        self.policy = policy
        self.steps = steps
        self.seed = seed
        self.resume = resume
        self.log_every = log_every
        self.log_fn = log_fn or (lambda *a: None)
        self.preemption = preemption
        # Straggler detection needs the *fleet's* per-host step times — on a
        # real cluster the scheduler's heartbeats supply them via
        # ``straggler_probe(local_dt) -> [dt_host0, ...]``.  Without a probe
        # there is nothing meaningful to feed the monitor (a host can't see
        # the fleet median from its own clock), so it stays idle.
        self.straggler = straggler or StragglerMonitor(
            n_hosts=jax.process_count())
        self.straggler_probe = straggler_probe

        self.model = build_model(cfg)
        self.opt = opt or (adamw() if opt_name == "adamw"
                           else sgd(momentum=0.9))
        self.lr_fn = cosine_schedule(lr, steps,
                                     warmup_steps=max(steps // 20, 1))

        self.mesh = mesh
        self.plan = make_plan(mesh) if mesh is not None else None
        self.abstract_state = abstract_train_state(self.model, self.opt, seed)
        self.shardings = (state_shardings(self.plan, self.abstract_state)
                          if self.plan else None)

        self.batch_fn = batch_fn or (
            lambda s: make_batch_for(cfg, batch_size, seq_len,
                                     step=s, seed=seed))
        batch_sh = None
        if self.plan is not None:
            ab = jax.eval_shape(lambda: self.batch_fn(0))
            batch_sh = self.plan.shardings(self.plan.batch_specs(ab))
        self.loader = ShardedLoader(self.batch_fn, shardings=batch_sh)

        step_fn = make_step_fn(
            self.model, policy, self.opt, self.lr_fn, clip_norm=clip_norm,
            remat=remat, accum_steps=accum_steps, mesh=mesh,
            compress_axis=compress_axis, loss_kwargs=loss_kwargs)
        self.step_fn = jit_step(step_fn, plan=self.plan,
                                abstract_state=self.abstract_state,
                                batch_shardings=batch_sh, donate=donate)

        self.ckpt = (CheckpointManager(ckpt_dir, keep=keep)
                     if ckpt_dir else None)
        self.ckpt_every = ckpt_every
        self.state: Optional[TrainState] = None

    # -- state lifecycle ----------------------------------------------------
    def init_state(self) -> TrainState:
        state = init_train_state(self.model, self.opt, self.seed)
        if self.shardings is not None:
            state = jax.device_put(state, self.shardings)
        return state

    def restore_state(self, step: Optional[int] = None) -> TrainState:
        """Restore the full TrainState (elastic: onto this engine's mesh,
        whatever mesh wrote the checkpoint).

        Pre-engine checkpoints ({params, opt} only, no step/rng leaves)
        migrate: step comes from the checkpoint index, the rng stream
        restarts (SR draws after resume differ from the unpreempted run —
        logged, since the bit-identical-resume guarantee needs a
        full-state checkpoint)."""
        step = step if step is not None else self.ckpt.latest_step()
        target = self.abstract_state.as_dict()
        sh = self.shardings.as_dict() if self.shardings is not None else None
        legacy = "step" not in self.ckpt.load_meta(step)["keys"]
        if legacy:
            target = {k: target[k] for k in ("params", "opt")}
            sh = sh and {k: sh[k] for k in ("params", "opt")}
        tree = self.ckpt.restore(step, target, shardings=sh)
        if legacy:
            self.log_fn(f"[engine] legacy checkpoint (no step/rng) at "
                        f"step {step}: resuming data stream, restarting "
                        f"rng stream")
            tree = {**tree, "step": jnp.asarray(step, jnp.int32),
                    "rng": jax.random.fold_in(
                        jax.random.PRNGKey(self.seed), step)}
        return TrainState.from_dict(tree)

    def _startup_state(self) -> TrainState:
        if self.ckpt and self.resume and self.ckpt.latest_step() is not None:
            state = self.restore_state()
            self.log_fn(f"[engine] resumed from step {int(state.step)}")
            return state
        return self.init_state()

    def _save(self, state: TrainState, asynchronous: bool = True):
        self.ckpt.save(int(state.step), state.as_dict(),
                       extra={"data_step": int(state.step)},
                       asynchronous=asynchronous)

    # -- the loop -----------------------------------------------------------
    def run(self, steps: Optional[int] = None):
        """Train until ``steps``; returns history [(step, loss), ...] with
        one entry per executed step.

        (The pre-engine loop sampled history at ``log_every``; here only
        *logging* is sampled — losses are kept as device scalars during the
        loop so the host syncs only on log/checkpoint steps, preserving
        async dispatch.)"""
        steps = steps if steps is not None else self.steps
        state = self.state if self.state is not None else self._startup_state()
        start = int(state.step)
        pf = Prefetcher(self.loader, depth=2, start_step=start)
        history = []                      # (step, float loss)
        pending = []                      # (step, device-scalar loss)

        def drain():
            # convert at points that sync anyway, so the steady-state loop
            # never blocks on a loss transfer and buffers don't pile up
            history.extend((s, float(l)) for s, l in pending)
            pending.clear()

        t0 = time.time()
        try:
            for step in range(start, steps):
                t_step = time.time()
                batch = pf.next()
                state, mets = self.step_fn(state, batch)
                pending.append((step, mets["loss"]))
                if self.straggler_probe is not None:
                    self.straggler.record(
                        self.straggler_probe(time.time() - t_step))
                    slow = self.straggler.stragglers()
                    if slow:
                        self.log_fn(f"[engine] stragglers: {slow}")
                if step % self.log_every == 0 or step == steps - 1:
                    drain()
                    self.log_fn(
                        f"[engine] step {step:5d} "
                        f"loss {history[-1][1]:8.4f} "
                        f"gnorm {float(mets['grad_norm']):8.3f} "
                        f"({time.time()-t0:.1f}s)")
                if self.ckpt and (step + 1) % self.ckpt_every == 0:
                    drain()
                    self._save(state)
                if self.preemption and self.preemption.should_stop:
                    if self.ckpt:
                        # drain any in-flight async save first — the sync
                        # save path does not, and both write step_<N>.tmp
                        self.ckpt.wait()
                        if (step + 1) % self.ckpt_every != 0:
                            self._save(state, asynchronous=False)
                    self.log_fn(f"[engine] preempted at step {step + 1}; "
                                f"checkpointed")
                    break
        finally:
            pf.stop()
            if self.ckpt:
                self.ckpt.wait()
            self.state = state
            drain()
        return history
