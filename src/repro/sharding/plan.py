"""Sharding plan: logical-parameter roles -> mesh PartitionSpecs.

Megatron-style tensor parallelism over the ``model`` axis; batch over
``(pod, data)``.  Every rule is divisibility-audited against the actual mesh
axis sizes (jax rejects unevenly sharded *inputs*), falling back to
replication when a dim doesn't divide — so the same plan drives the 16x16
production mesh, the 2x16x16 multi-pod mesh, and tiny test meshes.

Role rules (DESIGN.md Sec. 4):
  column-parallel (shard GEMM output):  wq/wk/wv, gate/up/fc1, z/x/dt proj,
                                        rwkv r/k/v/g, lm_head, fuse
  row-parallel    (shard GEMM input):   wo, down/fc2, out_proj, rwkv wo/cm_wv
  expert-parallel (shard expert axis):  moe experts
  vocab-parallel:                       embedding table
  replicated:                           norms, routers, loras, decays, bc_proj
"""

from __future__ import annotations


import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["ShardingPlan", "make_plan"]

# trailing-dims spec per role; leading stacked axes (L / n_outer,inner) get None
_COL2 = ("_", "model")            # (in, out) -> shard out
_ROW2 = ("model", "_")            # (in, out) -> shard in
_VEC = ("model",)
_REP = None                       # fully replicated

_RULES = [
    # (path substring, trailing spec) — first match wins
    ("embed/table", ("model", "_")),
    ("lm_head/w", _COL2),
    ("pos_embed", _REP),
    # attention
    ("attn/wq/w", _COL2), ("attn/wk/w", _COL2), ("attn/wv/w", _COL2),
    ("attn/wq/b", _VEC), ("attn/wk/b", _VEC), ("attn/wv/b", _VEC),
    ("attn/wo/w", _ROW2),
    # dense mlp
    ("mlp/gate/w", _COL2), ("mlp/up/w", _COL2), ("mlp/fc1/w", _COL2),
    ("mlp/down/w", _ROW2), ("mlp/fc2/w", _ROW2),
    ("mlp/gate/b", _VEC), ("mlp/up/b", _VEC), ("mlp/fc1/b", _VEC),
    # moe: expert axis parallel (trailing dims (E, in, out))
    ("moe/router", _REP),
    ("moe/experts", ("model", "_", "_")),
    # rwkv6
    ("wr/w", _COL2), ("wk/w", _COL2), ("wv/w", _COL2), ("wg/w", _COL2),
    ("wo/w", _ROW2),
    ("cm_wk/w", _COL2), ("cm_wv/w", _ROW2), ("cm_wr/w", _REP),
    ("/u", ("model", "_")),
    ("ln_x", _VEC),
    # mamba2
    ("z_proj/w", _COL2), ("x_proj/w", _COL2), ("dt_proj/w", _COL2),
    ("bc_proj", _REP),
    ("conv_x_w", ("_", "model")), ("conv_x_b", _VEC),
    ("conv_bc", _REP),
    ("A_log", _VEC), ("/D", _VEC), ("dt_bias", _VEC),
    ("out_norm", _VEC),
    ("out_proj/w", _ROW2),
    ("fuse/w", _COL2),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/" + "/".join(parts)


class ShardingPlan:
    """Holds the mesh + axis naming and produces shardings for trees."""

    def __init__(self, mesh: Mesh, data_axes=("data",), model_axis="model"):
        self.mesh = mesh
        self.data_axes = tuple(a for a in data_axes if a in mesh.shape)
        self.model_axis = model_axis if model_axis in mesh.shape else None
        self.model_size = mesh.shape.get(model_axis, 1)
        self.dp_size = 1
        for a in self.data_axes:
            self.dp_size *= mesh.shape[a]

    # -- parameters ---------------------------------------------------------
    def _trailing_spec(self, trailing, shape):
        """Map a rule's trailing pattern onto the last len(pattern) dims."""
        n_lead = len(shape) - len(trailing)
        if n_lead < 0:
            return P()
        spec = [None] * n_lead
        for dim, tag in zip(shape[n_lead:], trailing, strict=True):
            if tag == "model" and self.model_axis and dim % self.model_size == 0:
                spec.append(self.model_axis)
            else:
                spec.append(None)
        return P(*spec)

    def param_spec(self, path, leaf) -> P:
        ps = _path_str(path)
        for needle, trailing in _RULES:
            if needle in ps:
                if trailing is None:
                    return P()
                return self._trailing_spec(trailing, leaf.shape)
        return P()  # norms, scalars, anything unmatched -> replicate

    def param_specs(self, abstract_params):
        return jax.tree_util.tree_map_with_path(self.param_spec,
                                                abstract_params)

    # -- batches / caches ----------------------------------------------------
    def _dp(self, batch_dim: int):
        """Data axes tuple if the batch dim divides, else None."""
        if self.data_axes and batch_dim % self.dp_size == 0:
            return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]
        return None

    def batch_spec(self, path, leaf) -> P:
        ps = _path_str(path)
        shape = leaf.shape
        if "positions" in ps and len(shape) == 3:     # (3, B, T) m-rope
            return P(None, self._dp(shape[1]), None)
        dp = self._dp(shape[0])
        return P(dp, *([None] * (len(shape) - 1)))

    def batch_specs(self, abstract_batch):
        return jax.tree_util.tree_map_with_path(self.batch_spec, abstract_batch)

    def cache_spec(self, path, leaf) -> P:
        ps = _path_str(path)
        shape = leaf.shape
        if "index" in ps:
            return P()
        m = self.model_axis

        def md(dim):
            return m if (m and dim % self.model_size == 0) else None

        if "/kv/" in ps or "self_kv" in ps or "cross_kv" in ps:
            # (L, B, S, kv*hd) or hybrid (n_outer, B, S, kv*hd)
            return P(*([None] * (len(shape) - 3)), self._dp(shape[-3]), None,
                     md(shape[-1]))
        if ps.endswith("/h"):                          # mamba (..., B, H, P, N)
            return P(*([None] * (len(shape) - 4)), self._dp(shape[-4]),
                     md(shape[-3]), None, None)
        if "conv_x" in ps:                             # (..., B, k-1, d_inner)
            return P(*([None] * (len(shape) - 3)), self._dp(shape[-3]), None,
                     md(shape[-1]))
        if "conv_bc" in ps:
            return P(*([None] * (len(shape) - 3)), self._dp(shape[-3]), None,
                     None)
        if ps.endswith("/s"):                          # rwkv (L, B, H, hd, hd)
            return P(None, self._dp(shape[1]), md(shape[2]), None, None)
        if "x_tm" in ps or "x_cm" in ps:               # (L, B, d)
            return P(None, self._dp(shape[1]), None)
        return P(*([None] * len(shape)))

    def cache_specs(self, abstract_cache):
        return jax.tree_util.tree_map_with_path(self.cache_spec, abstract_cache)

    # -- attention q/k/v sharding (context parallel; DESIGN.md Sec. 4) -------
    def attn_shardings(self, B: int, T: int, S: int, H: int, KV: int,
                       hd: int):
        """Constraints for q (B,T,H,hd) and k/v (B,S,KV,hd).

        Head counts rarely divide the 16-way model axis (24 heads, kv=8), so
        GSPMD splits head_dim 2-way and pays an O(T*S) score *all-reduce*
        per layer (measured 25.8 GB/dev/layer at prefill_32k — EXPERIMENTS.md
        Perf it. 6).  Context-parallel attention instead shards q over the
        query-time axis (aligning with the sequence-parallel residual
        stream) and gathers the much smaller k/v (S*KV*hd bf16), removing
        the psum entirely.  Returns (q_sharding, kv_sharding) or None.
        """
        m, msz = self.model_axis, self.model_size
        dp = self._dp(B)
        if not (m and msz > 1) or T % msz != 0 or T <= 1:
            return None
        q_sh = NamedSharding(self.mesh, P(dp, m, None, None))
        kv_sh = NamedSharding(self.mesh, P(dp, None, None, None))
        return q_sh, kv_sh

    # -- MoE dispatch sharding (expert x capacity; DESIGN.md Sec. 4) ---------
    def moe_dispatch_sharding(self, E: int, C: int):
        """Sharding for the dispatched expert buffer (E, C, d).

        Expert weights shard E over `model`, but without a constraint the
        capacity axis stays REPLICATED across the data axis — every data
        shard recomputes every expert's full token block (measured 16x
        expert FLOPs on granite-moe — EXPERIMENTS.md Perf).  Sharding C over
        the data axes turns the dispatch scatter into the canonical MoE
        all-to-all."""
        m = self.model_axis if (self.model_axis and E % self.model_size == 0)             else None
        dp = self._dp(C) if C % max(self.dp_size, 1) == 0 else None
        if m is None and dp is None:
            return None
        return NamedSharding(self.mesh, P(m, dp, None))

    # -- materialization -----------------------------------------------------
    def shardings(self, spec_tree):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))


def make_plan(mesh: Mesh) -> ShardingPlan:
    axes = list(mesh.shape.keys())
    data_axes = tuple(a for a in ("pod", "data") if a in axes)
    return ShardingPlan(mesh, data_axes=data_axes, model_axis="model")
