from .plan import ShardingPlan, make_plan

__all__ = ["ShardingPlan", "make_plan"]
