"""Mamba-2 (SSD) mixer block [arXiv:2405.21060], used by Zamba2.

Training/prefill uses the *chunked SSD* formulation — the block-matmul
restatement of the selective-state recurrence that maps onto the MXU
(this is the TPU-native adaptation; a step-by-step scan would waste the
systolic array).  Decode uses the exact O(1) recurrence.

Tensor-parallel layout (DESIGN.md Sec. 4): projections are SPLIT
(z / x / BC / dt) rather than fused so each output shards cleanly on the
`model` axis — heads (H = expand*d/headdim) divide the 16-way axis for the
full config, making the SSD head-parallel; B/C (ngroups=1) are replicated.

FQT applies to all projections (the large GEMMs); the SSD state contractions
act on tiny (headdim x d_state) blocks interleaved with data-dependent decays
and stay full precision (DESIGN.md Sec. 5).

State per layer: ``h``      (B, H, hd, N)        SSM state,
                 ``conv_x`` (B, k-1, d_inner)    causal-conv tail (sharded),
                 ``conv_bc``(B, k-1, 2N)         causal-conv tail (replicated).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import QuantPolicy, fp_exempt
from .common import dense, init_dense

__all__ = ["init_mamba2_layer", "mamba2_layer", "mamba2_decode_step",
           "init_mamba2_state"]

_SSD_REASON = ("SSD state contractions act on tiny (headdim x d_state) "
               "blocks interleaved with data-dependent decays and stay "
               "full precision (DESIGN.md Sec. 5); FQT covers the "
               "projection GEMMs")

_CHUNK = 128


def _dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_headdim
    return d_inner, H


def init_mamba2_layer(key, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    d_inner, H = _dims(cfg)
    N = cfg.ssm_state
    ks = jax.random.split(key, 8)
    return {
        "norm": {"g": jnp.ones((d,))},
        "z_proj": init_dense(ks[0], d, d_inner),
        "x_proj": init_dense(ks[1], d, d_inner),
        "bc_proj": init_dense(ks[2], d, 2 * N),
        "dt_proj": init_dense(ks[3], d, H),
        "conv_x_w": jax.random.normal(ks[4], (cfg.ssm_conv, d_inner)) * 0.2,
        "conv_x_b": jnp.zeros((d_inner,)),
        "conv_bc_w": jax.random.normal(ks[5], (cfg.ssm_conv, 2 * N)) * 0.2,
        "conv_bc_b": jnp.zeros((2 * N,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),
        "D": jnp.ones((H,)),
        "dt_bias": jnp.log(jnp.expm1(jnp.linspace(1e-3, 0.1, H))),
        "out_norm": {"g": jnp.ones((d_inner,))},
        "out_proj": init_dense(ks[6], d_inner, d),
    }


def init_mamba2_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> dict:
    d_inner, H = _dims(cfg)
    # SSM state accumulates in f32; conv tails live in the stream dtype
    return {"h": jnp.zeros((batch, H, cfg.ssm_headdim, cfg.ssm_state),
                           jnp.float32),
            "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, d_inner), dtype),
            "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * cfg.ssm_state),
                                 dtype)}


def _rms(p, x):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), -1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + 1e-5) * p["g"]).astype(x.dtype)


def _causal_conv(w, b, x, tail, act=True):
    """Depthwise causal conv. x: (B, T, C); tail: (B, k-1, C).

    Returns (y, new_tail)."""
    k = w.shape[0]
    xp = jnp.concatenate([tail, x], axis=1)                      # (B, T+k-1, C)
    y = sum(w[j].astype(x.dtype) * jax.lax.dynamic_slice_in_dim(
            xp, j, x.shape[1], 1) for j in range(k))
    y = y + b.astype(x.dtype)
    return (jax.nn.silu(y) if act else y), xp[:, -(k - 1):]


def _segsum(a):
    """Cumulative log-decay lower-triangular matrix: L[i,j] = sum_{j<k<=i} a_k."""
    T = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    L = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, L, -jnp.inf)


def _ssd_chunked(x, dt, A_log, Bm, Cm, h0):
    """Chunked SSD. x: (B,T,H,P); dt: (B,T,H); A_log: (H,);
    Bm/Cm: (B,T,N) (ngroups=1, shared across heads); h0: (B,H,P,N).

    Returns (y (B,T,H,P), h_final)."""
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    cl = min(_CHUNK, T)
    nc = T // cl
    a = dt * (-jnp.exp(A_log))                                   # (B,T,H) log-decay
    xd = x * dt[..., None]
    r = lambda t, s: t.reshape(Bsz, nc, cl, *s)
    ac = r(a, (H,)).transpose(0, 1, 3, 2)                        # (B,nc,H,cl)
    xc = r(xd, (H, P))
    Bc = r(Bm, (N,))
    Cc = r(Cm, (N,))

    with fp_exempt("mamba.ssd", _SSD_REASON):
        # 1) intra-chunk (diagonal block): Y = (C Bᵀ ⊙ L) X
        L = jnp.exp(_segsum(ac))                                 # (B,nc,H,cl,cl)
        scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)           # (B,nc,cl,cl)
        y_diag = jnp.einsum("bchls,bcls,bcshp->bclhp", L, scores, xc)

        # 2) chunk-final states: S_c = sum_s decay_to_end * B_s x_s
        a_cum = jnp.cumsum(ac, axis=-1)                          # (B,nc,H,cl)
        decay_end = jnp.exp(a_cum[..., -1:] - a_cum)
        S = jnp.einsum("bchs,bcsn,bcshp->bchpn", decay_end, Bc, xc)

        # 3) inter-chunk recurrence (tiny scan, T/128 steps)
        chunk_decay = jnp.exp(a_cum[..., -1])                    # (B,nc,H)
        def step(h, inp):
            S_c, dec_c = inp
            return h * dec_c[..., None, None] + S_c, h       # emit pre-chunk state
        h_fin, h_prevs = jax.lax.scan(
            step, h0, (jnp.moveaxis(S, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
        h_prevs = jnp.moveaxis(h_prevs, 0, 1)                    # (B,nc,H,P,N)

        # 4) inter-chunk contribution: y += C_t * decay_in * h_prev
        decay_in = jnp.exp(a_cum)
        y_off = jnp.einsum("bcln,bchl,bchpn->bclhp", Cc, decay_in, h_prevs)

        y = (y_diag + y_off).reshape(Bsz, T, H, P)
        return y, h_fin


def _project(p, x, key, policy, cfg, tag, path):
    d_inner, H = _dims(cfg)
    z = dense(p["z_proj"], x, key, policy, tag + 1, f"{path}.z_proj")
    xs = dense(p["x_proj"], x, key, policy, tag + 2, f"{path}.x_proj")
    bc = dense(p["bc_proj"], x, key, policy, tag + 3, f"{path}.bc_proj")
    dt_raw = dense(p["dt_proj"], x, key, policy, tag + 4, f"{path}.dt_proj")
    return z, xs, bc, dt_raw


def mamba2_layer(p, h, key, policy: QuantPolicy, cfg: ArchConfig,
                 state: dict | None = None, tag: int = 0x50,
                 path: str = "mamba"):
    """Full-sequence Mamba2 block (train/prefill). Returns (h, final_state)."""
    B, T, d = h.shape
    d_inner, H = _dims(cfg)
    P, N = cfg.ssm_headdim, cfg.ssm_state
    res = h
    x = _rms(p["norm"], h)
    z, xs, bc, dt_raw = _project(p, x, key, policy, cfg, tag, path)
    if state is None:
        state = init_mamba2_state(cfg, B, h.dtype)
    xs, conv_x_tail = _causal_conv(p["conv_x_w"], p["conv_x_b"], xs,
                                   state["conv_x"])
    bc, conv_bc_tail = _causal_conv(p["conv_bc_w"], p["conv_bc_b"], bc,
                                    state["conv_bc"])
    xs = xs.reshape(B, T, H, P).astype(jnp.float32)
    Bm, Cm = bc[..., :N].astype(jnp.float32), bc[..., N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    y, h_fin = _ssd_chunked(xs, dt, p["A_log"], Bm, Cm, state["h"])
    y = y + p["D"][None, None, :, None] * xs
    y = y.reshape(B, T, d_inner).astype(z.dtype)
    y = _rms(p["out_norm"], y * jax.nn.silu(z))
    out = dense(p["out_proj"], y, key, policy, tag + 5, f"{path}.out_proj")
    new_state = {"h": h_fin, "conv_x": conv_x_tail, "conv_bc": conv_bc_tail}
    return res + out, new_state


def mamba2_decode_step(p, h, state: dict, key, policy: QuantPolicy,
                       cfg: ArchConfig, tag: int = 0x50,
                       path: str = "mamba"):
    """Exact O(1) recurrence for one token. h: (B, 1, d)."""
    B, _, d = h.shape
    d_inner, H = _dims(cfg)
    P, N = cfg.ssm_headdim, cfg.ssm_state
    res = h
    x = _rms(p["norm"], h)
    z, xs, bc, dt_raw = _project(p, x, key, policy, cfg, tag, path)
    xs, conv_x_tail = _causal_conv(p["conv_x_w"], p["conv_x_b"], xs,
                                   state["conv_x"])
    bc, conv_bc_tail = _causal_conv(p["conv_bc_w"], p["conv_bc_b"], bc,
                                    state["conv_bc"])
    xs = xs[:, 0].reshape(B, H, P).astype(jnp.float32)
    Bm = bc[:, 0, :N].astype(jnp.float32)
    Cm = bc[:, 0, N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    with fp_exempt("mamba.ssd", _SSD_REASON):
        a = jnp.exp(dt * (-jnp.exp(p["A_log"])))
        hs = state["h"] * a[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dt, xs, Bm)
        y = jnp.einsum("bhpn,bn->bhp", hs, Cm) + p["D"][None, :, None] * xs
    y = y.reshape(B, 1, d_inner).astype(z.dtype)
    y = _rms(p["out_norm"], y * jax.nn.silu(z))
    out = dense(p["out_proj"], y, key, policy, tag + 5, f"{path}.out_proj")
    return res + out, {"h": hs, "conv_x": conv_x_tail, "conv_bc": conv_bc_tail}
