"""RWKV-6 "Finch" block: data-dependent-decay linear attention
[arXiv:2404.05892], attention-free.

Structure per layer: time-mix (the WKV linear recurrence) + channel-mix.
All dense projections (R/K/V/G/O and channel-mix) are FQT GEMMs; the WKV
recurrence itself is elementwise/outer-product state math with no GEMM, so it
stays full precision (DESIGN.md Sec. 5 arch-applicability).

State per layer: ``s``  (B, H, hd, hd) WKV state, ``x_tm``/``x_cm`` (B, d)
previous-token shift registers — O(1) decode memory, which is why this arch
runs the long_500k cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import QuantPolicy, fp_exempt
from .common import dense, init_dense

__all__ = ["init_rwkv_layer", "rwkv_layer", "rwkv_decode_step",
           "init_rwkv_state"]

_MIX = ("w", "k", "v", "r", "g")
_LORA_MIX = 32
_LORA_DECAY = 64


def init_rwkv_layer(key, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    hd = cfg.ssm_headdim
    H = d // hd
    ks = jax.random.split(key, 16)
    ramp = jnp.arange(d) / d
    p = {
        "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        # ddlerp token-shift mixing (paper's low-rank data-dependent mix)
        "mu_x": ramp * 0.5,
        "mu": jnp.stack([ramp * 0.5 + 0.1 * i for i in range(5)]),   # (5, d)
        "tm_w1": jax.random.normal(ks[0], (d, 5 * _LORA_MIX)) * 1e-2,
        "tm_w2": jax.random.normal(ks[1], (5, _LORA_MIX, d)) * 1e-2,
        # data-dependent decay
        "w0": -6.0 + 5.0 * ramp,
        "dec_w1": jax.random.normal(ks[2], (d, _LORA_DECAY)) * 1e-2,
        "dec_w2": jax.random.normal(ks[3], (_LORA_DECAY, d)) * 1e-2,
        "u": jax.random.normal(ks[4], (H, hd)) * 0.1,                # bonus
        "wr": init_dense(ks[5], d, d),
        "wk": init_dense(ks[6], d, d),
        "wv": init_dense(ks[7], d, d),
        "wg": init_dense(ks[8], d, d),
        "wo": init_dense(ks[9], d, d),
        "ln_x": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        # channel mix
        "cm_mu_k": ramp * 0.5,
        "cm_mu_r": ramp * 0.5,
        "cm_wk": init_dense(ks[10], d, cfg.d_ff),
        "cm_wv": init_dense(ks[11], cfg.d_ff, d),
        "cm_wr": init_dense(ks[12], d, d),
    }
    return p


def init_rwkv_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    hd = cfg.ssm_headdim
    H = d // hd
    # WKV state accumulates in f32 regardless of the activation stream dtype
    return {"s": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "x_tm": jnp.zeros((batch, d), dtype),
            "x_cm": jnp.zeros((batch, d), dtype)}


def _ln(p, x):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * p["g"] + p["b"]
    return out.astype(x.dtype)


def _head_groupnorm(p, y, H):
    """GroupNorm(H) over the head dim, RWKV's ln_x (f32 stats)."""
    B, T, d = y.shape
    yh = y.reshape(B, T, H, d // H).astype(jnp.float32)
    mu = jnp.mean(yh, -1, keepdims=True)
    var = jnp.var(yh, -1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 1e-5)
    return yh.reshape(B, T, d) * p["g"] + p["b"]


def _time_mix_inputs(p, x, x_prev):
    """ddlerp: five data-dependently mixed views of (x, x_prev)."""
    with fp_exempt("rwkv.ddlerp",
                   "tiny low-rank token-shift mix (rank 32 per view); full "
                   "precision like every non-linear-layer GEMM in the paper"):
        sx = x_prev - x
        xxx = x + sx * p["mu_x"]
        a = jnp.tanh(xxx @ p["tm_w1"])                          # (..., 5*r)
        a = a.reshape(*a.shape[:-1], 5, _LORA_MIX)
        delta = jnp.einsum("...fr,frd->...fd", a, p["tm_w2"])   # (..., 5, d)
        return [(x + sx * (p["mu"][i] + delta[..., i, :])).astype(x.dtype)
                for i in range(len(_MIX))]  # [xw, xk, xv, xr, xg]


def _decay(p, xw):
    with fp_exempt("rwkv.decay",
                   "rank-64 data-dependent decay LoRA feeding exp(-exp(.)); "
                   "precision-critical and tiny next to the R/K/V/G/O GEMMs"):
        return jnp.exp(-jnp.exp(p["w0"]
                                + jnp.tanh(xw @ p["dec_w1"]) @ p["dec_w2"]))


def _wkv_scan(r, k, v, w, u, s0):
    """The RWKV-6 recurrence over time.

    r,k,v,w: (B, T, H, hd); u: (H, hd); s0: (B, H, hd, hd).
    y_t = r_t (S_{t-1} + diag(u) k_tT v_t);  S_t = diag(w_t) S_{t-1} + k_tT v_t.
    """
    def step(s, inp):
        rt, kt, vt, wt = inp                                    # (B, H, hd)
        with fp_exempt("rwkv.wkv",
                       "WKV recurrence: elementwise/outer-product state "
                       "math on (hd x hd) blocks, no linear-layer GEMM "
                       "(DESIGN.md Sec. 5 arch-applicability)"):
            kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
            y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
            s = wt[..., None] * s + kv
        return s, y
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    s, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s                            # (B,T,H,hd), state


def _time_mix(p, x, x_prev, s0, key, policy, cfg, tag=0x30, path="rwkv"):
    B = x.shape[0]
    d = cfg.d_model
    hd = cfg.ssm_headdim
    H = d // hd
    xw, xk, xv, xr, xg = _time_mix_inputs(p, x, x_prev)
    r = dense(p["wr"], xr, key, policy, tag + 1, f"{path}.wr")
    k = dense(p["wk"], xk, key, policy, tag + 2, f"{path}.wk")
    v = dense(p["wv"], xv, key, policy, tag + 3, f"{path}.wv")
    g = jax.nn.silu(dense(p["wg"], xg, key, policy, tag + 4, f"{path}.wg"))
    w = _decay(p, xw)
    T = x.shape[1]
    rs, ks_, vs, ws = (t.reshape(B, T, H, hd).astype(jnp.float32)
                       for t in (r, k, v, w))
    y, s = _wkv_scan(rs, ks_, vs, ws, p["u"], s0)
    y = _head_groupnorm(p["ln_x"], y.reshape(B, T, d), H).astype(x.dtype)
    out = dense(p["wo"], y * g, key, policy, tag + 5, f"{path}.wo")
    return out, s


def _channel_mix(p, x, x_prev, key, policy, tag=0x40, path="rwkv"):
    sx = x_prev - x
    xk = x + sx * p["cm_mu_k"]
    xr = x + sx * p["cm_mu_r"]
    k = jnp.square(jax.nn.relu(dense(p["cm_wk"], xk, key, policy, tag + 1,
                                     f"{path}.cm_wk")))
    kv = dense(p["cm_wv"], k, key, policy, tag + 2, f"{path}.cm_wv")
    return jax.nn.sigmoid(dense(p["cm_wr"], xr, key, policy, tag + 3,
                                f"{path}.cm_wr")) * kv


def _shift(x):
    """Token shift: x_{t-1} with zeros at t=0. x: (B, T, d)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def rwkv_layer(p, h, key, policy: QuantPolicy, cfg: ArchConfig,
               state: dict | None = None, path: str = "rwkv"):
    """Full-sequence RWKV-6 layer (train/prefill). Returns (h, final_state)."""
    B = h.shape[0]
    s0 = (state["s"] if state is not None
          else init_rwkv_state(cfg, B, h.dtype)["s"])
    x1 = _ln(p["ln1"], h)
    x1_prev = _shift(x1)
    if state is not None:
        x1_prev = x1_prev.at[:, 0].set(state["x_tm"])
    att, s = _time_mix(p, x1, x1_prev, s0, key, policy, cfg, path=path)
    h = h + att.astype(h.dtype)
    x2 = _ln(p["ln2"], h)
    x2_prev = _shift(x2)
    if state is not None:
        x2_prev = x2_prev.at[:, 0].set(state["x_cm"])
    h = h + _channel_mix(p, x2, x2_prev, key, policy,
                         path=path).astype(h.dtype)
    new_state = {"s": s, "x_tm": x1[:, -1], "x_cm": x2[:, -1]}
    return h, new_state


def rwkv_decode_step(p, h, state: dict, key, policy: QuantPolicy,
                     cfg: ArchConfig, path: str = "rwkv"):
    """One-token step. h: (B, 1, d). O(1) in sequence length."""
    B = h.shape[0]
    x1 = _ln(p["ln1"], h)
    att, s = _time_mix(p, x1, state["x_tm"][:, None], state["s"],
                       key, policy, cfg, path=path)
    h = h + att.astype(h.dtype)
    x2 = _ln(p["ln2"], h)
    h = h + _channel_mix(p, x2, state["x_cm"][:, None],
                         key, policy, path=path).astype(h.dtype)
    new_state = {"s": s, "x_tm": x1[:, 0], "x_cm": x2[:, 0]}
    return h, new_state
