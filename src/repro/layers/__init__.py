"""Layer zoo — every GEMM routes through repro.core.fqt."""

from .attention import (attention, cross_attention_kv, decode_attention,
                        init_attention, init_kv_cache, init_kv_cache_quant,
                        init_paged_kv_pool, paged_decode_attention)
from .common import dense, init_dense, qkey
from .embeddings import (apply_mrope, apply_rope, embed, init_embedding,
                         init_lm_head, lm_head, sinusoidal_positions)
from .mamba2 import (init_mamba2_layer, init_mamba2_state, mamba2_decode_step,
                     mamba2_layer)
from .mlp import init_mlp, mlp
from .moe import expert_capacity, init_moe, moe_block
from .norms import apply_norm, init_norm
from .rwkv import (init_rwkv_layer, init_rwkv_state, rwkv_decode_step,
                   rwkv_layer)
