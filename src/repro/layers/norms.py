"""Normalization layers (kept in full precision, per the paper's transformer
setting: only linear-layer GEMMs are quantized)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_norm", "apply_norm", "rmsnorm", "layernorm"]

_EPS = 1e-5


def init_norm(d: int, kind: str = "rmsnorm") -> dict:
    p = {"g": jnp.ones((d,))}
    if kind == "layernorm":
        p["b"] = jnp.zeros((d,))
    return p


def rmsnorm(p: dict, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)                    # f32 stats, stream dtype out
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + _EPS) * p["g"]).astype(x.dtype)


def layernorm(p: dict, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + _EPS) * p["g"] + p.get("b", 0.0)
    return out.astype(x.dtype)


def apply_norm(p: dict, x: jax.Array, kind: str) -> jax.Array:
    return layernorm(p, x) if kind == "layernorm" else rmsnorm(p, x)
