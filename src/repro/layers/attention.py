"""GQA attention with FQT projections, KV cache, and cross-attention.

All four projections (Q, K, V, O) are FQT linear layers (the paper quantizes
every linear GEMM); the attention math itself (scores/softmax/value-mix) is
full-precision, exactly like the paper's transformer setting where only
linear layers are quantized.

KV caches are stored *flattened* as ``(B, S, n_kv*head_dim)`` so the tensor-
parallel `model` axis always divides the sharded dim (DESIGN.md Sec. 4).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import QuantPolicy, fp_exempt, get_quantizer, resolve_kv_cache_spec
from .common import dense, init_dense
from .embeddings import apply_mrope, apply_rope

__all__ = ["init_attention", "attention", "decode_attention",
           "init_kv_cache", "init_kv_cache_quant", "cross_attention_kv"]

_NEG = -1e30


def init_attention(key, cfg: ArchConfig) -> dict:
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": init_dense(ks[0], cfg.d_model, cfg.n_heads * hd, cfg.qkv_bias),
        "wk": init_dense(ks[1], cfg.d_model, cfg.n_kv_heads * hd, cfg.qkv_bias),
        "wv": init_dense(ks[2], cfg.d_model, cfg.n_kv_heads * hd, cfg.qkv_bias),
        "wo": init_dense(ks[3], cfg.n_heads * hd, cfg.d_model, False),
    }


def _qkv(p, x, key, policy, cfg, positions, path="attn"):
    B, T, _ = x.shape
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = dense(p["wq"], x, key, policy, 1, f"{path}.wq").reshape(B, T, H, hd)
    k = dense(p["wk"], x, key, policy, 2, f"{path}.wk").reshape(B, T, KV, hd)
    v = dense(p["wv"], x, key, policy, 3, f"{path}.wv").reshape(B, T, KV, hd)
    if cfg.rope == "standard":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask):
    """q: (B,T,KV,G,hd), k/v: (B,S,KV,hd), mask: broadcast (B,1,1,T,S)."""
    with fp_exempt("attn.sdpa",
                   "attention scores/probs GEMMs stay full precision — the "
                   "paper quantizes only linear layers (Sec. 2.1 setting)"):
        scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(q.dtype)
        scores = jnp.einsum("btkgh,bskh->bkgts", q * scale, k)
        scores = jnp.where(mask, scores, _NEG)
        probs = jax.nn.softmax(scores.astype(jnp.float32),
                               axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgts,bskh->btkgh", probs, v)
        return out


def _apply_attn_hint(q, k, v, sdpa_hint):
    """Context-parallel constraint (ShardingPlan.attn_shardings): q sharded
    over query-time on the model axis; k/v gathered.  Removes the score
    all-reduce GSPMD otherwise emits when heads don't divide the TP axis."""
    if sdpa_hint is None:
        return q, k, v
    hint = sdpa_hint(q.shape[0], q.shape[1], k.shape[1], q.shape[2],
                     k.shape[2], q.shape[3])
    if hint is None:
        return q, k, v
    q_sh, kv_sh = hint
    q = jax.lax.with_sharding_constraint(q, q_sh)
    k = jax.lax.with_sharding_constraint(k, kv_sh)
    v = jax.lax.with_sharding_constraint(v, kv_sh)
    return q, k, v


def attention(p: dict, x: jax.Array, key, policy: QuantPolicy,
              cfg: ArchConfig, positions: jax.Array,
              causal: bool = True,
              kv_override: Optional[tuple] = None,
              return_kv: bool = False, sdpa_hint=None, path: str = "attn"):
    """Full-sequence attention (train / prefill / encoder).

    kv_override: (k, v) of shape (B, S, KV, hd) — cross-attention.
    return_kv: also return the (rotated) k, v for cache initialization.
    path: logical position for per-layer policy resolution; the four
    projections resolve as ``{path}.wq/.wk/.wv/.wo``.
    """
    B, T, _ = x.shape
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    G = H // KV
    if kv_override is not None:
        q = dense(p["wq"], x, key, policy, 1, f"{path}.wq").reshape(B, T, H, hd)
        if cfg.rope == "standard":
            q = apply_rope(q, positions, cfg.rope_theta)
        elif cfg.rope == "mrope":
            q = apply_mrope(q, positions, cfg.rope_theta)
        k, v = kv_override
    else:
        q, k, v = _qkv(p, x, key, policy, cfg, positions, path)
    q, k, v = _apply_attn_hint(q, k, v, sdpa_hint)
    S = k.shape[1]
    if causal:
        mask = (jnp.arange(T)[:, None] >= jnp.arange(S)[None, :])
        mask = mask[None, None, None]
    else:
        mask = jnp.ones((1, 1, 1, T, S), bool)
    out = _sdpa(q.reshape(B, T, KV, G, hd), k, v, mask)
    out = out.reshape(B, T, H * hd)
    y = dense(p["wo"], out, key, policy, 4, f"{path}.wo")
    if return_kv:
        return y, (k, v)
    return y


def cross_attention_kv(p: dict, enc_out: jax.Array, key,
                       policy: QuantPolicy, cfg: ArchConfig,
                       path: str = "attn"):
    """Precompute the encoder-side K/V for decoder cross-attention."""
    B, S, _ = enc_out.shape
    hd, KV = cfg.hd, cfg.n_kv_heads
    k = dense(p["wk"], enc_out, key, policy, 2,
              f"{path}.wk").reshape(B, S, KV, hd)
    v = dense(p["wv"], enc_out, key, policy, 3,
              f"{path}.wv").reshape(B, S, KV, hd)
    return k, v


# ---------------------------------------------------------------------------
# Decode path (single new token, flattened KV cache)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ArchConfig, batch: int, max_seq: int,
                  dtype=jnp.float32) -> dict:
    flat = cfg.n_kv_heads * cfg.hd
    return {
        "k": jnp.zeros((batch, max_seq, flat), dtype),
        "v": jnp.zeros((batch, max_seq, flat), dtype),
    }


def init_kv_cache_quant(cfg: ArchConfig, batch: int, max_seq: int) -> dict:
    """int8-quantized KV cache (core/kv_cache.py codec): each of k/v stores
    shifted-signed int8 codes plus one (scale, zero) pair per (batch,
    position) row — ~4x less HBM per resident slot than the fp32 cache.

    Scales initialize to 1 (not 0) so untouched rows dequantize to finite
    values; they are masked out of attention by the position mask anyway.
    """
    flat = cfg.n_kv_heads * cfg.hd

    def one():
        return {"codes": jnp.zeros((batch, max_seq, flat), jnp.int8),
                "scale": jnp.ones((batch, max_seq), jnp.float32),
                "zero": jnp.zeros((batch, max_seq), jnp.float32)}
    return {"k": one(), "v": one()}


def _is_quant_kv(cache: dict) -> bool:
    return isinstance(cache["k"], dict)


def decode_attention(p: dict, x: jax.Array, cache: dict, index: jax.Array,
                     key, policy: QuantPolicy, cfg: ArchConfig,
                     path: str = "attn", kv_quant=None):
    """One-token attention step. x: (B, 1, d).

    ``index``: scalar position shared by the whole batch (the classic
    decode loop) or a ``(B,)`` vector of per-slot positions (continuous
    batching — every slot sits at its own depth in its own sequence).

    ``cache`` is either the fp ``init_kv_cache`` layout or the int8
    ``init_kv_cache_quant`` layout (detected structurally); for the latter
    the new row is quantized on write and the resident cache dequantized on
    read through the execution backend selected by ``policy.backend``
    (``pallas`` = the fused ``kv_dequant_rows`` kernel).  ``kv_quant``
    optionally names the registered cache quantizer (default ``kv_int8:8``).

    Returns (y, new_cache). Each slot attends over positions <= its index.
    """
    B = x.shape[0]
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    G = H // KV
    pos = jnp.broadcast_to(jnp.asarray(index, jnp.int32).reshape(-1), (B,))
    positions = pos[:, None]
    if cfg.rope == "mrope":
        positions = jnp.broadcast_to(positions[None], (3, B, 1))
    q, k_new, v_new = _qkv(p, x, key, policy, cfg, positions, path)
    flat = KV * hd
    bidx = jnp.arange(B)
    rows_k = k_new.reshape(B, flat)
    rows_v = v_new.reshape(B, flat)
    if _is_quant_kv(cache):
        spec = resolve_kv_cache_spec(True if kv_quant is None else kv_quant)
        qz = get_quantizer(spec.name)
        bits = spec.bits or 8

        def put(side, rows):
            codes, scale, zero = qz.quantize_rows(rows, bits)
            return {"codes": side["codes"].at[bidx, pos].set(codes),
                    "scale": side["scale"].at[bidx, pos].set(scale),
                    "zero": side["zero"].at[bidx, pos].set(zero)}
        cache = {"k": put(cache["k"], rows_k), "v": put(cache["v"], rows_v)}
        S = cache["k"]["codes"].shape[1]

        def get(side):
            rows = qz.dequant_rows(side["codes"], side["scale"], side["zero"],
                                   bits, backend=policy.backend,
                                   interpret=policy.pallas_interpret)
            return rows.reshape(B, S, KV, hd).astype(x.dtype)
        k, v = get(cache["k"]), get(cache["v"])
    else:
        cache = {
            "k": cache["k"].at[bidx, pos].set(rows_k.astype(cache["k"].dtype)),
            "v": cache["v"].at[bidx, pos].set(rows_v.astype(cache["v"].dtype)),
        }
        S = cache["k"].shape[1]
        k = cache["k"].reshape(B, S, KV, hd).astype(x.dtype)
        v = cache["v"].reshape(B, S, KV, hd).astype(x.dtype)
    mask = (jnp.arange(S)[None, :] <= pos[:, None])          # (B, S)
    mask = mask[:, None, None, None, :]                      # (B,1,1,1,S)
    out = _sdpa(q.reshape(B, 1, KV, G, hd), k, v, mask)
    y = dense(p["wo"], out.reshape(B, 1, H * hd), key, policy, 4,
              f"{path}.wo")
    return y, cache
