"""GQA attention with FQT projections, KV cache, and cross-attention.

All four projections (Q, K, V, O) are FQT linear layers (the paper quantizes
every linear GEMM); the attention math itself (scores/softmax/value-mix) is
full-precision, exactly like the paper's transformer setting where only
linear layers are quantized.

KV caches are stored *flattened* as ``(B, S, n_kv*head_dim)`` so the tensor-
parallel `model` axis always divides the sharded dim (DESIGN.md Sec. 4).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import (QuantPolicy, fp_exempt, get_quantizer, kv_fresh_code,
                    resolve_kv_cache_spec)
from .common import dense, init_dense
from .embeddings import apply_mrope, apply_rope

__all__ = ["init_attention", "attention", "decode_attention",
           "init_kv_cache", "init_kv_cache_quant", "cross_attention_kv",
           "init_paged_kv_pool", "paged_decode_attention"]

_NEG = -1e30


def init_attention(key, cfg: ArchConfig) -> dict:
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": init_dense(ks[0], cfg.d_model, cfg.n_heads * hd, cfg.qkv_bias),
        "wk": init_dense(ks[1], cfg.d_model, cfg.n_kv_heads * hd, cfg.qkv_bias),
        "wv": init_dense(ks[2], cfg.d_model, cfg.n_kv_heads * hd, cfg.qkv_bias),
        "wo": init_dense(ks[3], cfg.n_heads * hd, cfg.d_model, False),
    }


def _qkv(p, x, key, policy, cfg, positions, path="attn"):
    B, T, _ = x.shape
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = dense(p["wq"], x, key, policy, 1, f"{path}.wq").reshape(B, T, H, hd)
    k = dense(p["wk"], x, key, policy, 2, f"{path}.wk").reshape(B, T, KV, hd)
    v = dense(p["wv"], x, key, policy, 3, f"{path}.wv").reshape(B, T, KV, hd)
    if cfg.rope == "standard":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask):
    """q: (B,T,KV,G,hd), k/v: (B,S,KV,hd), mask: broadcast (B,1,1,T,S)."""
    with fp_exempt("attn.sdpa",
                   "attention scores/probs GEMMs stay full precision — the "
                   "paper quantizes only linear layers (Sec. 2.1 setting)"):
        scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(q.dtype)
        scores = jnp.einsum("btkgh,bskh->bkgts", q * scale, k)
        scores = jnp.where(mask, scores, _NEG)
        probs = jax.nn.softmax(scores.astype(jnp.float32),
                               axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgts,bskh->btkgh", probs, v)
        return out


def _apply_attn_hint(q, k, v, sdpa_hint):
    """Context-parallel constraint (ShardingPlan.attn_shardings): q sharded
    over query-time on the model axis; k/v gathered.  Removes the score
    all-reduce GSPMD otherwise emits when heads don't divide the TP axis."""
    if sdpa_hint is None:
        return q, k, v
    hint = sdpa_hint(q.shape[0], q.shape[1], k.shape[1], q.shape[2],
                     k.shape[2], q.shape[3])
    if hint is None:
        return q, k, v
    q_sh, kv_sh = hint
    q = jax.lax.with_sharding_constraint(q, q_sh)
    k = jax.lax.with_sharding_constraint(k, kv_sh)
    v = jax.lax.with_sharding_constraint(v, kv_sh)
    return q, k, v


def attention(p: dict, x: jax.Array, key, policy: QuantPolicy,
              cfg: ArchConfig, positions: jax.Array,
              causal: bool = True,
              kv_override: Optional[tuple] = None,
              return_kv: bool = False, sdpa_hint=None, path: str = "attn"):
    """Full-sequence attention (train / prefill / encoder).

    kv_override: (k, v) of shape (B, S, KV, hd) — cross-attention.
    return_kv: also return the (rotated) k, v for cache initialization.
    path: logical position for per-layer policy resolution; the four
    projections resolve as ``{path}.wq/.wk/.wv/.wo``.
    """
    B, T, _ = x.shape
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    G = H // KV
    if kv_override is not None:
        q = dense(p["wq"], x, key, policy, 1, f"{path}.wq").reshape(B, T, H, hd)
        if cfg.rope == "standard":
            q = apply_rope(q, positions, cfg.rope_theta)
        elif cfg.rope == "mrope":
            q = apply_mrope(q, positions, cfg.rope_theta)
        k, v = kv_override
    else:
        q, k, v = _qkv(p, x, key, policy, cfg, positions, path)
    q, k, v = _apply_attn_hint(q, k, v, sdpa_hint)
    S = k.shape[1]
    if causal:
        mask = (jnp.arange(T)[:, None] >= jnp.arange(S)[None, :])
        mask = mask[None, None, None]
    else:
        mask = jnp.ones((1, 1, 1, T, S), bool)
    out = _sdpa(q.reshape(B, T, KV, G, hd), k, v, mask)
    out = out.reshape(B, T, H * hd)
    y = dense(p["wo"], out, key, policy, 4, f"{path}.wo")
    if return_kv:
        return y, (k, v)
    return y


def cross_attention_kv(p: dict, enc_out: jax.Array, key,
                       policy: QuantPolicy, cfg: ArchConfig,
                       path: str = "attn"):
    """Precompute the encoder-side K/V for decoder cross-attention."""
    B, S, _ = enc_out.shape
    hd, KV = cfg.hd, cfg.n_kv_heads
    k = dense(p["wk"], enc_out, key, policy, 2,
              f"{path}.wk").reshape(B, S, KV, hd)
    v = dense(p["wv"], enc_out, key, policy, 3,
              f"{path}.wv").reshape(B, S, KV, hd)
    return k, v


# ---------------------------------------------------------------------------
# Decode path (single new token, flattened KV cache)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ArchConfig, batch: int, max_seq: int,
                  dtype=jnp.float32) -> dict:
    flat = cfg.n_kv_heads * cfg.hd
    return {
        "k": jnp.zeros((batch, max_seq, flat), dtype),
        "v": jnp.zeros((batch, max_seq, flat), dtype),
    }


def init_kv_cache_quant(cfg: ArchConfig, batch: int, max_seq: int,
                        bits: int = 8) -> dict:
    """int8-quantized KV cache (core/kv_cache.py codec): each of k/v stores
    shifted-signed int8 codes plus one (scale, zero) pair per (batch,
    position) row — ~4x less HBM per resident slot than the fp32 cache.

    Fresh rows must dequantize to *exact* zeros (scale=1, zero=0, codes at
    ``kv_fresh_code`` = the shifted-signed zero point): the paged engine
    gathers unwritten pool rows and relies on ``0 * masked_prob == 0`` — a
    scale of 0 here would turn the masked garbage into inf/nan and poison
    the softmax of every co-resident slot.
    """
    flat = cfg.n_kv_heads * cfg.hd
    fresh = kv_fresh_code(bits)

    def one():
        return {"codes": jnp.full((batch, max_seq, flat), fresh, jnp.int8),
                "scale": jnp.ones((batch, max_seq), jnp.float32),
                "zero": jnp.zeros((batch, max_seq), jnp.float32)}
    return {"k": one(), "v": one()}


def init_paged_kv_pool(cfg: ArchConfig, n_pages: int, page_size: int,
                       bits: int = 8) -> dict:
    """One layer's shared page pool for the paged serving engine: the int8
    KV codec of :func:`init_kv_cache_quant` laid out as ``n_pages`` fixed
    ``page_size``-row pages instead of per-slot lanes.  Physical pages are
    handed to requests by the host-side allocator (serve/paged.py); this
    tensor never knows which request owns which page.

    Fresh pages dequantize to exact zeros (``kv_fresh_code`` + scale 1) —
    the gather path reads *every* table entry, including never-written
    garbage pages, and masked positions only stay harmless if their values
    are finite (``0 * inf`` would be NaN in the value mix).
    """
    flat = cfg.n_kv_heads * cfg.hd
    fresh = kv_fresh_code(bits)

    def one():
        return {"codes": jnp.full((n_pages, page_size, flat), fresh,
                                  jnp.int8),
                "scale": jnp.ones((n_pages, page_size), jnp.float32),
                "zero": jnp.zeros((n_pages, page_size), jnp.float32)}
    return {"k": one(), "v": one()}


def _is_quant_kv(cache: dict) -> bool:
    return isinstance(cache["k"], dict)


def decode_attention(p: dict, x: jax.Array, cache: dict, index: jax.Array,
                     key, policy: QuantPolicy, cfg: ArchConfig,
                     path: str = "attn", kv_quant=None):
    """One-token attention step. x: (B, 1, d).

    ``index``: scalar position shared by the whole batch (the classic
    decode loop) or a ``(B,)`` vector of per-slot positions (continuous
    batching — every slot sits at its own depth in its own sequence).

    ``cache`` is either the fp ``init_kv_cache`` layout or the int8
    ``init_kv_cache_quant`` layout (detected structurally); for the latter
    the new row is quantized on write and the resident cache dequantized on
    read through the execution backend selected by ``policy.backend``
    (``pallas`` = the fused ``kv_dequant_rows`` kernel).  ``kv_quant``
    optionally names the registered cache quantizer (default ``kv_int8:8``).

    Returns (y, new_cache). Each slot attends over positions <= its index.
    """
    B = x.shape[0]
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    G = H // KV
    pos = jnp.broadcast_to(jnp.asarray(index, jnp.int32).reshape(-1), (B,))
    positions = pos[:, None]
    if cfg.rope == "mrope":
        positions = jnp.broadcast_to(positions[None], (3, B, 1))
    q, k_new, v_new = _qkv(p, x, key, policy, cfg, positions, path)
    flat = KV * hd
    bidx = jnp.arange(B)
    rows_k = k_new.reshape(B, flat)
    rows_v = v_new.reshape(B, flat)
    if _is_quant_kv(cache):
        spec = resolve_kv_cache_spec(True if kv_quant is None else kv_quant)
        qz = get_quantizer(spec.name)
        bits = spec.bits or 8

        def put(side, rows):
            codes, scale, zero = qz.quantize_rows(rows, bits)
            return {"codes": side["codes"].at[bidx, pos].set(codes),
                    "scale": side["scale"].at[bidx, pos].set(scale),
                    "zero": side["zero"].at[bidx, pos].set(zero)}
        cache = {"k": put(cache["k"], rows_k), "v": put(cache["v"], rows_v)}
        S = cache["k"]["codes"].shape[1]

        def get(side):
            rows = qz.dequant_rows(side["codes"], side["scale"], side["zero"],
                                   bits, backend=policy.backend,
                                   interpret=policy.pallas_interpret)
            return rows.reshape(B, S, KV, hd).astype(x.dtype)
        k, v = get(cache["k"]), get(cache["v"])
    else:
        cache = {
            "k": cache["k"].at[bidx, pos].set(rows_k.astype(cache["k"].dtype)),
            "v": cache["v"].at[bidx, pos].set(rows_v.astype(cache["v"].dtype)),
        }
        S = cache["k"].shape[1]
        k = cache["k"].reshape(B, S, KV, hd).astype(x.dtype)
        v = cache["v"].reshape(B, S, KV, hd).astype(x.dtype)
    mask = (jnp.arange(S)[None, :] <= pos[:, None])          # (B, S)
    mask = mask[:, None, None, None, :]                      # (B,1,1,1,S)
    out = _sdpa(q.reshape(B, 1, KV, G, hd), k, v, mask)
    y = dense(p["wo"], out.reshape(B, 1, H * hd), key, policy, 4,
              f"{path}.wo")
    return y, cache


def paged_decode_attention(p: dict, x: jax.Array, pool: dict,
                           table: jax.Array, start: jax.Array, key,
                           policy: QuantPolicy, cfg: ArchConfig,
                           path: str = "attn", kv_quant=None):
    """Multi-token attention step over a paged int8 KV pool. x: (B, C, d).

    The one compute primitive of the paged serving engine — ``C`` is what
    varies by use, not the code path:

      * ``C = 1``      plain continuous-batching decode
      * ``C = chunk``  one chunked-prefill slab (long prompts stream in)
      * ``C = k + 1``  the speculative-decode verify pass

    ``pool``: one layer of :func:`init_paged_kv_pool`; ``table``: (B, nb)
    int32 physical page ids in logical-block order (pad unallocated blocks
    with the engine's garbage page); ``start``: (B,) int32 position of each
    row's first token.  Row ``c`` writes position ``start + c`` into its
    page (quantize-on-write, same codec as the dense decode path), then the
    whole table is gathered + dequantized — the Pallas backend streams
    pages via the block-table-prefetch kernel (kernels/kv_gather.py),
    simulate/native run its XLA twin — and position ``start + c`` attends
    over everything ``<= start + c``.  Because the chunk's own rows are
    scattered before the gather, intra-chunk causality falls out of the
    same position mask, and a ``C = 1`` step is arithmetically identical to
    the dense-lane :func:`decode_attention` step.

    Positions are clamped to the table's span ``nb * P - 1``; clamped
    (padding) rows write garbage to the last row, which stays masked until
    a real token is fed at that position — and that write happens before
    the mask ever exposes it.

    Returns (y (B, C, d_model), new pool).
    """
    B, C, _ = x.shape
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    G = H // KV
    nb = table.shape[1]
    P = pool["k"]["codes"].shape[1]
    S = nb * P
    start = jnp.asarray(start, jnp.int32).reshape(B)
    offs = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]  # (B, C)
    positions = offs
    if cfg.rope == "mrope":
        positions = jnp.broadcast_to(positions[None], (3, B, C))
    q, k_new, v_new = _qkv(p, x, key, policy, cfg, positions, path)

    spec = resolve_kv_cache_spec(True if kv_quant is None else kv_quant)
    qz = get_quantizer(spec.name)
    bits = spec.bits or 8
    flat = KV * hd
    offs_w = jnp.minimum(offs, S - 1)
    pids = jnp.take_along_axis(table, offs_w // P, axis=1)           # (B, C)
    rows = offs_w % P

    def put(side, rows_f):
        codes, scale, zero = qz.quantize_rows(rows_f.reshape(B, C, flat),
                                              bits)
        return {"codes": side["codes"].at[pids, rows].set(codes),
                "scale": side["scale"].at[pids, rows].set(scale),
                "zero": side["zero"].at[pids, rows].set(zero)}
    pool = {"k": put(pool["k"], k_new), "v": put(pool["v"], v_new)}

    if policy.backend == "pallas":
        from ..core.backend import resolve_interpret
        from ..kernels.kv_gather import kv_gather_pages
        interp = resolve_interpret(policy.pallas_interpret)

        def get(side):
            return kv_gather_pages(side["codes"], side["scale"],
                                   side["zero"], table, bits=bits,
                                   interpret=interp)
    else:
        from ..kernels.kv_gather import kv_gather_pages_xla

        def get(side):
            return kv_gather_pages_xla(side["codes"], side["scale"],
                                       side["zero"], table, bits=bits)
    k = get(pool["k"]).reshape(B, S, KV, hd).astype(x.dtype)
    v = get(pool["v"]).reshape(B, S, KV, hd).astype(x.dtype)

    mask = (jnp.arange(S, dtype=jnp.int32)[None, None, :]
            <= offs[:, :, None])                             # (B, C, S)
    mask = mask[:, None, None]                               # (B,1,1,C,S)
    out = _sdpa(q.reshape(B, C, KV, G, hd), k, v, mask)
    y = dense(p["wo"], out.reshape(B, C, H * hd), key, policy, 4,
              f"{path}.wo")
    return y, pool
