"""Shared layer utilities: key derivation, initializers, dense wrapper."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import QuantPolicy, fqt_matmul

__all__ = ["qkey", "init_dense", "dense", "he_init", "lecun_init"]


def qkey(key: jax.Array, tag: int) -> jax.Array:
    """Stable per-call-site PRNG key for backward-pass quantizers."""
    return jax.random.fold_in(key, tag)


def lecun_init(key, shape, in_axis_size=None):
    fan_in = in_axis_size or shape[0]
    return jax.random.normal(key, shape) * (1.0 / jnp.sqrt(fan_in))


def he_init(key, shape, in_axis_size=None):
    fan_in = in_axis_size or shape[0]
    return jax.random.normal(key, shape) * jnp.sqrt(2.0 / fan_in)


def init_dense(key, d_in: int, d_out: int, bias: bool = False,
               scale: float = 1.0) -> dict:
    p = {"w": lecun_init(key, (d_in, d_out)) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,))
    return p


def dense(p: dict, x: jax.Array, key: jax.Array, policy: QuantPolicy,
          tag: int = 0, path: str = "") -> jax.Array:
    """FQT linear layer: the paper's quantized GEMM + fp bias add.

    The GEMM executes on whichever backend ``policy.backend`` selects
    (simulate / native / pallas — core/backend.py), so every model layer
    built on ``dense`` trains on the fused Pallas kernels when asked to;
    nothing at this level knows about code layouts or epilogues.

    ``path`` is the layer's logical position (e.g. ``"layers.mlp.up"``) —
    a static string the policy's per-layer overrides resolve against
    (``QuantPolicy.resolve``).  Layer authors: extend your parent's path
    with ``.`` separators and one leaf name per GEMM; stacks scanned with
    ``lax.scan`` share a single trace, so paths name the *role within the
    stack* ("layers.attn.wq"), not a layer index.
    """
    y = fqt_matmul(x, p["w"], qkey(key, tag), policy, path=path)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y
