"""Top-k MoE with expert parallelism and capacity-based static dispatch.

Experts shard over the `model` mesh axis (DESIGN.md Sec. 4).  Dispatch uses
the classic capacity scheme (GShard-style) realized with scatter/gather so
every shape is static under jit; per-expert FFN GEMMs are vmapped FQT
matmuls — PSQ/BHQ rows inside an expert are the tokens routed to it, which is
exactly the sparse-outlier regime the paper's quantizers exploit
(DESIGN.md Sec. 5).

Returns an auxiliary load-balancing loss (Switch-style) alongside the output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import QuantPolicy
from .common import dense, init_dense, qkey
from .mlp import init_mlp, mlp

__all__ = ["init_moe", "moe_block", "expert_capacity"]


def expert_capacity(n_tokens: int, cfg: ArchConfig) -> int:
    c = int(cfg.moe_topk * n_tokens / cfg.moe_experts * cfg.moe_capacity)
    return max(c, 1)


def init_moe(key, cfg: ArchConfig) -> dict:
    kr, ke = jax.random.split(key)
    expert_keys = jax.random.split(ke, cfg.moe_experts)
    experts = jax.vmap(
        lambda k: init_mlp(k, cfg.d_model, cfg.d_ff, cfg.act))(expert_keys)
    return {"router": init_dense(kr, cfg.d_model, cfg.moe_experts),
            "experts": experts}


def moe_block(p: dict, x: jax.Array, key, policy: QuantPolicy,
              cfg: ArchConfig, tag_base: int = 0x20, moe_hint=None,
              path: str = "moe"):
    """x: (B, T, d) -> (y, aux_loss).

    moe_hint(E, C) -> optional NamedSharding for the (E, C, d) dispatch
    buffer (ShardingPlan.moe_dispatch_sharding): shards experts over the TP
    axis and capacity over the data axes (the canonical MoE all-to-all)."""
    B, T, d = x.shape
    N = B * T
    E, K = cfg.moe_experts, cfg.moe_topk
    C = expert_capacity(N, cfg)
    xt = x.reshape(N, d)

    logits = dense(p["router"], xt, key, policy, tag_base,
                   f"{path}.router")                                # (N, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_i = jax.lax.top_k(probs, K)                          # (N, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # --- capacity assignment (static shapes) -----------------------------
    flat_e = top_i.reshape(-1)                                      # (N*K,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)             # (N*K, E)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                              flat_e[:, None], axis=1)[:, 0]        # (N*K,)
    keep = (pos < C)
    dst = jnp.where(keep, flat_e * C + pos, E * C)                  # overflow slot

    # --- dispatch: scatter tokens into (E, C, d) --------------------------
    xr = jnp.repeat(xt, K, axis=0)                                  # (N*K, d)
    buf = jnp.zeros((E * C + 1, d), xt.dtype).at[dst].add(
        xr * keep[:, None].astype(xt.dtype))
    xe = buf[:-1].reshape(E, C, d)
    if moe_hint is not None:
        sh = moe_hint(E, C)
        if sh is not None:
            xe = jax.lax.with_sharding_constraint(xe, sh)

    # --- expert FFN (vmapped FQT GEMMs, per-expert quantizer stats) -------
    ekeys = jax.random.split(qkey(key, tag_base + 1), E)
    ye = jax.vmap(lambda ep, ex, ek: mlp(ep, ex, ek, policy, cfg.act,
                                         tag_base + 2, f"{path}.expert"))(
        p["experts"], xe, ekeys)                                    # (E, C, d)
    if moe_hint is not None:
        sh = moe_hint(E, C)
        if sh is not None:
            ye = jax.lax.with_sharding_constraint(ye, sh)

    # --- combine -----------------------------------------------------------
    out_slots = jnp.concatenate([ye.reshape(E * C, d),
                                 jnp.zeros((1, d), ye.dtype)])[dst]
    w = (top_p.reshape(-1) * keep.astype(jnp.float32))[:, None]
    y = jnp.sum((out_slots.astype(jnp.float32) * w).reshape(N, K, d),
                axis=1).reshape(B, T, d).astype(x.dtype)

    # Switch-style load-balance aux loss
    frac_tokens = jnp.mean(jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32),
                           axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return y, aux
