"""Feed-forward blocks: SwiGLU / GELU / squared-ReLU, all FQT GEMMs."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import QuantPolicy
from .common import dense, init_dense

__all__ = ["init_mlp", "mlp"]


def init_mlp(key, d_model: int, d_ff: int, act: str) -> dict:
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {"gate": init_dense(ks[0], d_model, d_ff),
                "up": init_dense(ks[1], d_model, d_ff),
                "down": init_dense(ks[2], d_ff, d_model)}
    return {"fc1": init_dense(ks[0], d_model, d_ff),
            "fc2": init_dense(ks[1], d_ff, d_model)}


def mlp(p: dict, x: jax.Array, key, policy: QuantPolicy, act: str,
        tag_base: int = 0x10, path: str = "mlp") -> jax.Array:
    if act == "swiglu":
        g = dense(p["gate"], x, key, policy, tag_base + 1, f"{path}.gate")
        u = dense(p["up"], x, key, policy, tag_base + 2, f"{path}.up")
        h = jax.nn.silu(g) * u
        return dense(p["down"], h, key, policy, tag_base + 3, f"{path}.down")
    h = dense(p["fc1"], x, key, policy, tag_base + 1, f"{path}.fc1")
    if act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(f"unknown act {act}")
    return dense(p["fc2"], h, key, policy, tag_base + 2, f"{path}.fc2")
