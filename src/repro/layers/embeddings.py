"""Token embeddings (Megatron-style padded vocab) and rotary embeddings
(standard RoPE + Qwen2-VL M-RoPE sectioned variant).

The embedding gather and the rotary elementwise math stay full-precision;
the LM head is a linear layer and therefore FQT-quantized.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import QuantPolicy, fqt_matmul
from .common import qkey

__all__ = ["init_embedding", "embed", "init_lm_head", "lm_head",
           "rope_freqs", "apply_rope", "apply_mrope", "sinusoidal_positions"]


def init_embedding(key, cfg: ArchConfig) -> dict:
    # padded vocab (DESIGN.md Sec. 4): pad rows never receive gradient
    # because token ids < vocab_size; the LM-head loss masks pad logits.
    return {"table": jax.random.normal(key, (cfg.padded_vocab, cfg.d_model))
            * 0.02}


def embed(p: dict, tokens: jax.Array) -> jax.Array:
    return p["table"][tokens]


def init_lm_head(key, cfg: ArchConfig) -> dict:
    return {"w": jax.random.normal(key, (cfg.d_model, cfg.padded_vocab))
            * (1.0 / jnp.sqrt(cfg.d_model))}


def lm_head(p: dict, x: jax.Array, key, policy: QuantPolicy,
            path: str = "lm_head") -> jax.Array:
    """Final projection — a linear layer, so quantized like every other.

    Resolves at ``path="lm_head"``, so ``overrides={r"lm_head": "exact"}``
    reproduces the common keep-the-head-full-precision recipe.
    """
    return fqt_matmul(x, p["w"], qkey(key, 0x1ead), policy, path=path)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10_000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2) / head_dim))


def _rotate(x, cos, sin):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, hd); positions: (B, T) int32."""
    freqs = rope_freqs(x.shape[-1], theta)                     # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs     # (B, T, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    return _rotate(x, cos, sin).astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections=(16, 24, 24)) -> jax.Array:
    """Qwen2-VL multimodal RoPE: positions (3, B, T) for (t, h, w) axes,
    rotary dims split into per-axis sections (over hd/2 frequency slots)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                              # (hd/2,)
    n = hd // 2
    # section boundaries scaled to this head_dim
    total = sum(sections)
    bounds, acc = [], 0
    for s in sections[:-1]:
        acc += round(n * s / total)
        bounds.append(acc)
    sec_id = jnp.zeros((n,), jnp.int32)
    for b in bounds:
        sec_id = sec_id + (jnp.arange(n) >= b)
    pos_sel = positions[sec_id]                                # (n, B, T)
    ang = jnp.moveaxis(pos_sel, 0, -1).astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    return _rotate(x, cos, sin).astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    """Whisper encoder fixed sinusoidal positions."""
    pos = jnp.arange(seq)[:, None]
    dim = jnp.arange(0, d, 2)[None, :]
    ang = pos / (10_000.0 ** (dim / d))
    out = jnp.zeros((seq, d))
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang))
    return out
