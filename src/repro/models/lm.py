"""Decoder-only LM covering the dense / MoE / VLM / RWKV6 / Zamba2-hybrid
families.  Per-layer parameters are stacked ``(L, ...)`` and the stack runs
under ``lax.scan`` so HLO size is depth-independent (DESIGN.md Sec. 4).

Three entry points per model:
  * loss      — full-sequence training loss (teacher forcing)
  * prefill   — full-sequence forward returning last-position logits + cache
  * decode    — one-token step with cache
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import QuantPolicy
from ..layers import (apply_norm, attention, decode_attention, dense, embed,
                      init_attention, init_embedding, init_kv_cache,
                      init_kv_cache_quant, init_lm_head, init_mamba2_layer,
                      init_mamba2_state, init_mlp, init_moe, init_norm,
                      init_paged_kv_pool, init_rwkv_layer, init_rwkv_state,
                      lm_head, mamba2_decode_step, mamba2_layer, mlp,
                      moe_block, paged_decode_attention, rwkv_decode_step,
                      rwkv_layer)

__all__ = ["init_lm_params", "lm_loss", "lm_prefill", "lm_decode",
           "init_lm_cache", "init_lm_cache_quant", "cross_entropy",
           "scan_or_loop", "init_lm_paged_pool", "lm_paged_decode"]


def scan_or_loop(body, carry, xs, unroll: bool):
    """lax.scan, or an unrolled python loop when ``unroll`` (dry-run probes:
    XLA cost analysis counts while-loop bodies once, so probes unroll)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a, i=i: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *ys)
    return carry, stacked


def _constrain(h, sharding):
    if sharding is not None:
        return jax.lax.with_sharding_constraint(h, sharding)
    return h


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_tx_layer(key, cfg: ArchConfig) -> dict:
    ka, km, k1, k2 = jax.random.split(key, 4)
    p = {"ln1": init_norm(cfg.d_model, cfg.norm),
         "attn": init_attention(ka, cfg),
         "ln2": init_norm(cfg.d_model, cfg.norm)}
    if cfg.moe_experts:
        p["moe"] = init_moe(km, cfg)
    else:
        p["mlp"] = init_mlp(km, cfg.d_model, cfg.d_ff, cfg.act)
    return p


def init_lm_params(key, cfg: ArchConfig) -> dict:
    ke, kl, kh, ks = jax.random.split(key, 4)
    params = {"embed": init_embedding(ke, cfg),
              "final_norm": init_norm(cfg.d_model, cfg.norm),
              "lm_head": init_lm_head(kh, cfg)}
    if cfg.family == "hybrid":
        n_outer = cfg.n_layers // cfg.hybrid_period
        inner = cfg.hybrid_period
        lkeys = jax.random.split(kl, n_outer * inner).reshape(n_outer, inner, -1)
        params["layers"] = jax.vmap(jax.vmap(
            lambda k: init_mamba2_layer(k, cfg)))(lkeys)
        fkeys = jax.random.split(jax.random.fold_in(kl, 1), n_outer)
        params["fuse"] = jax.vmap(
            lambda k: {"w": jax.random.normal(k, (2 * cfg.d_model, cfg.d_model))
                       * (0.5 / jnp.sqrt(cfg.d_model))})(fkeys)
        params["shared"] = _init_tx_layer(ks, cfg)     # ONE shared block
    elif cfg.ssm_kind == "rwkv6":
        lkeys = jax.random.split(kl, cfg.n_layers)
        params["layers"] = jax.vmap(lambda k: init_rwkv_layer(k, cfg))(lkeys)
    else:
        lkeys = jax.random.split(kl, cfg.n_layers)
        params["layers"] = jax.vmap(lambda k: _init_tx_layer(k, cfg))(lkeys)
    return params


# ---------------------------------------------------------------------------
# Layer application (full-sequence)
# ---------------------------------------------------------------------------

def _tx_layer(p, h, key, policy, cfg, positions, state=None, sdpa_hint=None,
              moe_hint=None, path="layers"):
    """(pre-norm attention + MLP/MoE). state: optional kv dict for prefill.

    path: policy-resolution prefix for this block's GEMMs — the scanned
    stack shares one trace, so all stacked layers resolve at the same
    ``layers.*`` paths (the hybrid model's shared block uses ``shared.*``).
    """
    x = apply_norm(p["ln1"], h, cfg.norm)
    if state is None:
        att = attention(p["attn"], x, key, policy, cfg, positions,
                        sdpa_hint=sdpa_hint, path=f"{path}.attn")
        kv = None
    else:
        att, (k, v) = attention(p["attn"], x, key, policy, cfg, positions,
                                return_kv=True, sdpa_hint=sdpa_hint,
                                path=f"{path}.attn")
        B, S = k.shape[0], k.shape[1]
        kv = {"k": k.reshape(B, S, -1), "v": v.reshape(B, S, -1)}
    h = h + att.astype(h.dtype)
    x = apply_norm(p["ln2"], h, cfg.norm)
    if cfg.moe_experts:
        y, aux = moe_block(p["moe"], x, key, policy, cfg, moe_hint=moe_hint,
                           path=f"{path}.moe")
    else:
        y, aux = mlp(p["mlp"], x, key, policy, cfg.act,
                     path=f"{path}.mlp"), 0.0
    return h + y.astype(h.dtype), aux, kv


def _forward_seq(params, h, key, policy: QuantPolicy, cfg: ArchConfig,
                 positions, want_cache: bool, remat: bool = False,
                 act_sharding=None, sdpa_hint=None, moe_hint=None):
    """Scan the layer stack over a full sequence.

    Returns (h, aux_loss, cache_or_None). h: (B, T, d).
    act_sharding: optional NamedSharding for the residual stream between
    layers — sequence parallelism (DESIGN.md Sec. 4): P(dp, "model", None)
    shards the token dim over the TP axis, cutting saved-activation memory
    and norm compute by the TP degree."""
    B = h.shape[0]
    h = _constrain(h, act_sharding)

    if cfg.family == "hybrid":
        return _forward_hybrid(params, h, key, policy, cfg, positions,
                               want_cache, remat, act_sharding, sdpa_hint)

    if cfg.ssm_kind == "rwkv6":
        def body(carry, xs):
            hh = carry
            lp, lk = xs
            hh, st = rwkv_layer(lp, hh, lk, policy, cfg, path="layers.rwkv")
            return _constrain(hh, act_sharding), (st if want_cache else 0)
        if remat:
            body = jax.checkpoint(body)
        keys = jax.random.split(key, cfg.n_layers)
        h, states = scan_or_loop(body, h, (params["layers"], keys),
                                 cfg.unroll_scan)
        return h, 0.0, (states if want_cache else None)

    def body(carry, xs):
        hh, aux = carry
        lp, lk = xs
        hh, a, kv = _tx_layer(lp, hh, lk, policy, cfg, positions,
                              state=({} if want_cache else None),
                              sdpa_hint=sdpa_hint, moe_hint=moe_hint)
        return (_constrain(hh, act_sharding), aux + a), (kv if want_cache else 0)
    if remat:
        body = jax.checkpoint(body)
    keys = jax.random.split(key, cfg.n_layers)
    (h, aux), kvs = scan_or_loop(body, (h, 0.0), (params["layers"], keys),
                                 cfg.unroll_scan)
    return h, aux, (kvs if want_cache else None)


def _forward_hybrid(params, h, key, policy, cfg, positions, want_cache,
                    remat=False, act_sharding=None, sdpa_hint=None):
    """Zamba2: scan of [hybrid_period x mamba2] + shared attn block."""
    n_outer = cfg.n_layers // cfg.hybrid_period
    h0 = h                                       # residual stream input
    shared = params["shared"]

    def outer_body(carry, xs):
        hh = carry
        (mp, fuse, okey) = xs
        ikeys = jax.random.split(okey, cfg.hybrid_period + 1)

        def inner_body(ih, ixs):
            lp, lk = ixs
            ih, st = mamba2_layer(lp, ih, lk, policy, cfg,
                                  path="layers.mamba")
            return _constrain(ih, act_sharding), (st if want_cache else 0)
        hh, msts = scan_or_loop(inner_body, hh,
                                (mp, ikeys[:cfg.hybrid_period]),
                                cfg.unroll_scan)
        # shared attention block on concat(h, h0), fused back to d_model.
        # The fuse projection is a linear layer like any other — it runs
        # through `dense` so FQT covers it (path "layers.fuse"; the first
        # quantization-contract audit flagged the old raw `@` as a leak).
        skey = ikeys[-1]
        z = dense(fuse, jnp.concatenate([hh, h0], axis=-1), skey, policy,
                  0x70, "layers.fuse")
        if want_cache:
            z2, _, kv = _tx_layer(shared, z, skey, policy, cfg, positions,
                                  state={}, sdpa_hint=sdpa_hint,
                                  path="shared")
        else:
            z2, _, kv = _tx_layer(shared, z, skey, policy, cfg, positions,
                                  sdpa_hint=sdpa_hint, path="shared")
        hh = hh + z2.astype(hh.dtype)
        return _constrain(hh, act_sharding), ((msts, kv) if want_cache else 0)

    if remat:
        outer_body = jax.checkpoint(outer_body)
    okeys = jax.random.split(key, n_outer)
    h, caches = scan_or_loop(outer_body, h,
                             (params["layers"], params["fuse"], okeys),
                             cfg.unroll_scan)
    return h, 0.0, (caches if want_cache else None)


# ---------------------------------------------------------------------------
# Embedding-or-token inputs
# ---------------------------------------------------------------------------

def _input_embed(params, batch, cfg: ArchConfig):
    if "embeds" in batch:                        # VLM stub frontend
        return batch["embeds"]
    return embed(params["embed"], batch["tokens"])


def _positions(batch, cfg, B, T):
    if "positions" in batch:
        return batch["positions"]
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    if cfg.rope == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, B, T))
    return pos


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab_size: int) -> jax.Array:
    """Mean next-token CE with padded-vocab masking."""
    vp = logits.shape[-1]
    if vp > vocab_size:
        neg = jnp.full((vp - vocab_size,), -1e30, logits.dtype)
        logits = logits.at[..., vocab_size:].set(neg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def _chunk_rows_sharding(act_sharding):
    """Sharding for flattened token rows, derived from the residual-stream
    sharding.  The (B,T,d)->(rows,d) reshape mixes the data- and model-axis
    shards, which breaks GSPMD propagation and silently REPLICATES the head
    GEMMs (measured 16x flops, EXPERIMENTS.md Perf iteration 1) — an explicit
    constraint on the chunked rows restores sharding."""
    if act_sharding is None:
        return None
    axes = []
    for part in tuple(act_sharding.spec)[:2]:
        if part is None:
            continue
        axes.extend(part if isinstance(part, (tuple, list)) else [part])
    if not axes:
        return None
    from jax.sharding import NamedSharding, PartitionSpec
    return NamedSharding(act_sharding.mesh,
                         PartitionSpec(None, tuple(axes), None))


def chunked_head_loss(params, h, labels, key, policy, cfg,
                      n_chunks: int, unroll: bool,
                      act_sharding=None) -> jax.Array:
    """lm_head projection + CE over token chunks.

    At 150-250k vocab, materializing full (tokens x vocab) logits plus the
    FQT backward's SR uniforms and codes for the head gradient dominates HBM
    (the dry-run profile showed ~40 GiB/device of head-path tensors).
    Chunking bounds every head-path tensor to tokens/n_chunks; the chunk loop
    is a scan, so the backward (including the quantized head-grad GEMMs)
    streams too.
    """
    d = h.shape[-1]
    h2 = h.reshape(-1, d)
    y2 = labels.reshape(-1)
    R = h2.shape[0]
    if n_chunks <= 1 or R % n_chunks != 0:
        logits = lm_head(params["lm_head"], h, key, policy)
        return cross_entropy(logits, labels, cfg.vocab_size)
    hc = h2.reshape(n_chunks, R // n_chunks, d)
    yc = y2.reshape(n_chunks, R // n_chunks)
    rows_sh = _chunk_rows_sharding(act_sharding)
    if rows_sh is not None:
        n_shards = 1
        for ax in tuple(rows_sh.spec)[1]:
            n_shards *= rows_sh.mesh.shape[ax]
        if (R // n_chunks) % n_shards == 0:
            hc = jax.lax.with_sharding_constraint(hc, rows_sh)

    def body(acc, xs):
        h_c, y_c, c_idx = xs
        # per-chunk fold: Theorem 1 needs the head-grad SR draws independent
        # across chunks — reusing `key` verbatim here made every chunk's
        # quantization noise identical (caught by repro.analysis soundness)
        logits = lm_head(params["lm_head"], h_c,
                         jax.random.fold_in(key, c_idx), policy)
        vp = logits.shape[-1]
        if vp > cfg.vocab_size:
            neg = jnp.full((vp - cfg.vocab_size,), -1e30, logits.dtype)
            logits = logits.at[..., cfg.vocab_size:].set(neg)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, y_c[:, None], axis=-1)[:, 0]
        return acc + jnp.sum(ll), 0

    total, _ = scan_or_loop(body, jnp.float32(0.0),
                            (hc, yc, jnp.arange(n_chunks)), unroll)
    return -total / R


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def lm_loss(params, batch, key, policy: QuantPolicy, cfg: ArchConfig,
            remat: bool = False, dtype=None, act_sharding=None,
            sdpa_hint=None, moe_hint=None, loss_chunks: int = 1):
    h = _input_embed(params, batch, cfg)
    if dtype is not None:
        h = h.astype(dtype)
    B, T = h.shape[0], h.shape[1]
    pos = _positions(batch, cfg, B, T)
    h, aux, _ = _forward_seq(params, h, key, policy, cfg, pos,
                             want_cache=False, remat=remat,
                             act_sharding=act_sharding, sdpa_hint=sdpa_hint,
                             moe_hint=moe_hint)
    h = apply_norm(params["final_norm"], h, cfg.norm)
    loss = chunked_head_loss(params, h, batch["labels"], key, policy, cfg,
                             loss_chunks, cfg.unroll_scan,
                             act_sharding=act_sharding)
    if cfg.moe_experts:
        loss = loss + 0.01 * aux / cfg.n_layers
    return loss, {"ce": loss, "aux": aux}


def init_lm_cache(cfg: ArchConfig, batch: int, max_seq: int,
                  dtype=jnp.float32):
    """Abstract-safe cache constructor (works under jax.eval_shape)."""
    if cfg.family == "hybrid":
        n_outer = cfg.n_layers // cfg.hybrid_period
        mam = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x, (n_outer, cfg.hybrid_period) + x.shape),
            init_mamba2_state(cfg, batch, dtype))
        kv = jax.tree.map(lambda x: jnp.broadcast_to(x, (n_outer,) + x.shape),
                          init_kv_cache(cfg, batch, max_seq, dtype))
        return {"mamba": mam, "kv": kv, "index": jnp.zeros((), jnp.int32)}
    if cfg.ssm_kind == "rwkv6":
        st = jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape),
                          init_rwkv_state(cfg, batch, dtype))
        return {"state": st, "index": jnp.zeros((), jnp.int32)}
    kv = jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape),
                      init_kv_cache(cfg, batch, max_seq, dtype))
    return {"kv": kv, "index": jnp.zeros((), jnp.int32)}


def init_lm_cache_quant(cfg: ArchConfig, batch: int, max_seq: int):
    """int8-quantized variant of :func:`init_lm_cache` (serving decode).

    Only the transformer families carry a KV cache to quantize; the
    recurrent state of rwkv6/hybrid models is read-modify-write every step
    and stays full precision.  ``index`` is a per-slot ``(batch,)`` vector —
    the continuous-batching engine steps every slot at its own position.
    """
    if cfg.family == "hybrid" or cfg.ssm_kind == "rwkv6":
        raise ValueError(
            f"{cfg.name}: quantized KV caches need a transformer KV cache; "
            f"family={cfg.family!r}/ssm_kind={cfg.ssm_kind!r} keeps dense "
            f"recurrent state")
    kv = jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape),
                      init_kv_cache_quant(cfg, batch, max_seq))
    return {"kv": kv, "index": jnp.zeros((batch,), jnp.int32)}


def init_lm_paged_pool(cfg: ArchConfig, n_pages: int, page_size: int):
    """Stacked ``(L, ...)`` paged int8 KV pool for the paged serving engine
    (serve/paged.py): one shared set of ``n_pages`` physical pages per
    layer, sliced alongside the layer stack by ``lax.scan``.  One block
    table indexes all layers — page id ``i`` names row ``i`` of every
    layer's pool, so the allocator hands out one id per logical block, not
    one per (layer, block).
    """
    if cfg.family == "hybrid" or cfg.ssm_kind == "rwkv6":
        raise ValueError(
            f"{cfg.name}: paged KV pools need a transformer KV cache; "
            f"family={cfg.family!r}/ssm_kind={cfg.ssm_kind!r} keeps dense "
            f"recurrent state")
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape),
        init_paged_kv_pool(cfg, n_pages, page_size))


def lm_paged_decode(params, pool, batch, policy: QuantPolicy,
                    cfg: ArchConfig, table, start, kv_quant=None):
    """Paged multi-token forward: the engine's one compute primitive.

    batch: ``tokens (B, C)``; ``table``: (B, nb) int32 block tables;
    ``start``: (B,) int32 position of each row's first token.  ``C = 1`` is
    plain decode, ``C = chunk`` is chunked prefill, ``C = k + 1`` is the
    speculative verify — see :func:`repro.layers.paged_decode_attention`.
    Returns (logits (B, C, Vp), new pool).
    """
    key = jax.random.PRNGKey(0)       # fwd quantizers are deterministic
    h = _input_embed(params, batch, cfg).astype(jnp.float32)

    def body(hh, xs):
        lp, pool_l, lk = xs
        x = apply_norm(lp["ln1"], hh, cfg.norm)
        att, pool_l = paged_decode_attention(
            lp["attn"], x, pool_l, table, start, lk, policy, cfg,
            path="layers.attn", kv_quant=kv_quant)
        hh = hh + att.astype(hh.dtype)
        x = apply_norm(lp["ln2"], hh, cfg.norm)
        if cfg.moe_experts:
            y, _ = moe_block(lp["moe"], x, lk, policy, cfg,
                             path="layers.moe")
        else:
            y = mlp(lp["mlp"], x, lk, policy, cfg.act, path="layers.mlp")
        return hh + y.astype(hh.dtype), pool_l
    keys = jax.random.split(key, cfg.n_layers)
    h, pools = scan_or_loop(body, h, (params["layers"], pool, keys),
                            cfg.unroll_scan)
    h = apply_norm(params["final_norm"], h, cfg.norm)
    logits = lm_head(params["lm_head"], h, key, policy)
    return logits, pools


def lm_prefill(params, batch, policy: QuantPolicy, cfg: ArchConfig,
               max_seq: Optional[int] = None, dtype=None, sdpa_hint=None,
               last_pos=None):
    """Forward the prompt; return (last-position logits, cache).

    ``last_pos``: optional ``(B,)`` int32 — take each row's logits at that
    position instead of ``T - 1`` (serving engines right-pad prompts into
    length buckets; the true last token then sits before the padding).
    """
    key = jax.random.PRNGKey(0)                   # fwd quantizers are deterministic
    h = _input_embed(params, batch, cfg)
    if dtype is not None:
        h = h.astype(dtype)
    B, T = h.shape[0], h.shape[1]
    max_seq = max_seq or T
    pos = _positions(batch, cfg, B, T)
    h, _, cache = _forward_seq(params, h, key, policy, cfg, pos,
                               want_cache=True, sdpa_hint=sdpa_hint)
    h = apply_norm(params["final_norm"], h, cfg.norm)
    h_last = (h[:, -1:] if last_pos is None
              else h[jnp.arange(B), last_pos][:, None])
    logits = lm_head(params["lm_head"], h_last, key, policy)

    index = jnp.asarray(T, jnp.int32)
    if cfg.family == "hybrid":
        msts, kvs = cache
        kvs = _pad_kv(kvs, max_seq)
        out = {"mamba": msts, "kv": kvs, "index": index}
    elif cfg.ssm_kind == "rwkv6":
        out = {"state": cache, "index": index}
    else:
        out = {"kv": _pad_kv(cache, max_seq), "index": index}
    return logits, out


def _pad_kv(kvs, max_seq):
    def pad(x):                                   # (L, B, T, f) -> (L, B, S, f)
        T = x.shape[2]
        if T == max_seq:
            return x
        return jnp.pad(x, ((0, 0), (0, 0), (0, max_seq - T), (0, 0)))
    return jax.tree.map(pad, kvs)


def _cache_dtype(cache):
    for _path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
        if leaf.dtype in (jnp.bfloat16, jnp.float32, jnp.float16):
            return leaf.dtype
    return jnp.float32


def lm_decode(params, cache, batch, policy: QuantPolicy, cfg: ArchConfig,
              positions=None, kv_quant=None):
    """One-token decode step: batch has `tokens` (B,1) or `embeds` (B,1,d).

    ``positions``: optional ``(B,)`` per-slot positions overriding the
    cache's own ``index`` — the continuous-batching serving engine owns the
    slot positions and passes them every step.  ``kv_quant`` names the cache
    quantizer when ``cache`` uses the int8 layout (``init_lm_cache_quant``).
    """
    key = jax.random.PRNGKey(0)
    h = _input_embed(params, batch, cfg).astype(_cache_dtype(cache))
    B = h.shape[0]
    index = cache["index"] if positions is None else positions

    if cfg.family == "hybrid":
        h0 = h
        shared = params["shared"]

        def outer(carry, xs):
            hh = carry
            mp, fuse, mst, kvc, okey = xs
            ikeys = jax.random.split(okey, cfg.hybrid_period + 1)

            def inner(ih, ixs):
                lp, lst, lk = ixs
                ih, st = mamba2_decode_step(lp, ih, lst, lk, policy, cfg,
                                            path="layers.mamba")
                return ih, st
            hh, msts = scan_or_loop(inner, hh,
                                    (mp, mst, ikeys[:cfg.hybrid_period]),
                                    cfg.unroll_scan)
            z = dense(fuse, jnp.concatenate([hh, h0], axis=-1), ikeys[-1],
                      policy, 0x70, "layers.fuse")
            x = apply_norm(shared["ln1"], z, cfg.norm)
            att, kvc = decode_attention(shared["attn"], x, kvc, index,
                                        ikeys[-1], policy, cfg,
                                        path="shared.attn")
            z = z + att.astype(z.dtype)
            x = apply_norm(shared["ln2"], z, cfg.norm)
            z = z + mlp(shared["mlp"], x, ikeys[-1], policy, cfg.act,
                        path="shared.mlp").astype(z.dtype)
            hh = hh + z
            return hh, (msts, kvc)
        n_outer = cfg.n_layers // cfg.hybrid_period
        okeys = jax.random.split(key, n_outer)
        h, (msts, kvs) = scan_or_loop(
            outer, h, (params["layers"], params["fuse"], cache["mamba"],
                       cache["kv"], okeys), cfg.unroll_scan)
        new_cache = {"mamba": msts, "kv": kvs, "index": index + 1}
    elif cfg.ssm_kind == "rwkv6":
        def body(hh, xs):
            lp, lst, lk = xs
            hh, st = rwkv_decode_step(lp, hh, lst, lk, policy, cfg,
                                      path="layers.rwkv")
            return hh, st
        keys = jax.random.split(key, cfg.n_layers)
        h, sts = scan_or_loop(body, h, (params["layers"], cache["state"],
                                        keys), cfg.unroll_scan)
        new_cache = {"state": sts, "index": index + 1}
    else:
        def body(hh, xs):
            lp, kvc, lk = xs
            x = apply_norm(lp["ln1"], hh, cfg.norm)
            att, kvc = decode_attention(lp["attn"], x, kvc, index, lk,
                                        policy, cfg, path="layers.attn",
                                        kv_quant=kv_quant)
            hh = hh + att.astype(hh.dtype)
            x = apply_norm(lp["ln2"], hh, cfg.norm)
            if cfg.moe_experts:
                y, _ = moe_block(lp["moe"], x, lk, policy, cfg,
                                 path="layers.moe")
            else:
                y = mlp(lp["mlp"], x, lk, policy, cfg.act,
                        path="layers.mlp")
            return hh + y.astype(hh.dtype), kvc
        keys = jax.random.split(key, cfg.n_layers)
        h, kvs = scan_or_loop(body, h, (params["layers"], cache["kv"], keys),
                              cfg.unroll_scan)
        new_cache = {"kv": kvs, "index": index + 1}

    h = apply_norm(params["final_norm"], h, cfg.norm)
    logits = lm_head(params["lm_head"], h, key, policy)
    return logits, new_cache
