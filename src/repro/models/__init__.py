from .api import Model, build_model, model_quant_paths
from .lm import (cross_entropy, init_lm_cache, init_lm_cache_quant,
                 init_lm_paged_pool, init_lm_params, lm_decode, lm_loss,
                 lm_paged_decode, lm_prefill)

__all__ = ["Model", "build_model", "model_quant_paths", "cross_entropy",
           "init_lm_params", "lm_loss", "lm_prefill", "lm_decode",
           "init_lm_cache", "init_lm_cache_quant", "init_lm_paged_pool",
           "lm_paged_decode"]
