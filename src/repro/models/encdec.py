"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

Per the assignment, the conv/audio frontend is a STUB: ``input_specs``
supplies precomputed frame embeddings (B, enc_seq, d).  The transformer
backbone (bidirectional encoder; decoder with causal self-attn + cross-attn)
is fully implemented with FQT GEMMs.  Shapes index the *decoder* sequence
(DESIGN.md Sec. 5); the real Whisper decoder caps at 448 positions — we extend
the learned position table mechanically to cover the assigned shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import QuantPolicy
from ..layers import (apply_norm, attention, cross_attention_kv,
                      decode_attention, embed, init_attention, init_embedding,
                      init_kv_cache, init_lm_head, init_mlp, init_norm,
                      lm_head, mlp, sinusoidal_positions)
from .lm import chunked_head_loss, scan_or_loop

__all__ = ["init_encdec_params", "encdec_loss", "encdec_prefill",
           "encdec_decode", "init_encdec_cache", "MAX_DECODER_POS"]

MAX_DECODER_POS = 32_768


def _init_enc_layer(key, cfg):
    ka, km = jax.random.split(key)
    return {"ln1": init_norm(cfg.d_model, cfg.norm),
            "attn": init_attention(ka, cfg),
            "ln2": init_norm(cfg.d_model, cfg.norm),
            "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, cfg.act)}


def _init_dec_layer(key, cfg):
    ka, kx, km = jax.random.split(key, 3)
    return {"ln1": init_norm(cfg.d_model, cfg.norm),
            "self_attn": init_attention(ka, cfg),
            "ln_x": init_norm(cfg.d_model, cfg.norm),
            "cross_attn": init_attention(kx, cfg),
            "ln2": init_norm(cfg.d_model, cfg.norm),
            "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, cfg.act)}


def init_encdec_params(key, cfg: ArchConfig) -> dict:
    ke, kd, kt, kh, kp = jax.random.split(key, 5)
    enc_keys = jax.random.split(ke, cfg.enc_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_norm": init_norm(cfg.d_model, cfg.norm),
        "embed": init_embedding(kt, cfg),
        "pos_embed": jax.random.normal(kp, (MAX_DECODER_POS, cfg.d_model)) * 0.01,
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "final_norm": init_norm(cfg.d_model, cfg.norm),
        "lm_head": init_lm_head(kh, cfg),
    }


def _encode(params, frames, key, policy, cfg, sdpa_hint=None):
    """frames: (B, S_enc, d) precomputed frame embeddings (stub frontend)."""
    B, S, d = frames.shape
    h = frames + sinusoidal_positions(S, d).astype(frames.dtype)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(hh, xs):
        lp, lk = xs
        x = apply_norm(lp["ln1"], hh, cfg.norm)
        hh = hh + attention(lp["attn"], x, lk, policy, cfg, pos,
                            causal=False, sdpa_hint=sdpa_hint,
                            path="encoder.layers.attn").astype(hh.dtype)
        x = apply_norm(lp["ln2"], hh, cfg.norm)
        return hh + mlp(lp["mlp"], x, lk, policy, cfg.act,
                        path="encoder.layers.mlp").astype(hh.dtype), 0
    keys = jax.random.split(key, cfg.enc_layers)
    h, _ = scan_or_loop(body, h, (params["enc_layers"], keys),
                        cfg.unroll_scan)
    return apply_norm(params["enc_norm"], h, cfg.norm)


def _decode_seq(params, tokens, enc_out, key, policy, cfg, want_cache=False,
                sdpa_hint=None):
    B, T = tokens.shape
    h = (embed(params["embed"], tokens)
         + params["pos_embed"][:T]).astype(enc_out.dtype)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def body(carry, xs):
        hh = carry
        lp, lk = xs
        # self- and cross-attention share qkey tags 1-4, so they need
        # distinct subkeys or their SR streams alias across the two modules
        # (caught by `repro.analysis soundness`, rule SND002)
        lk_self = jax.random.fold_in(lk, 1)
        lk_cross = jax.random.fold_in(lk, 2)
        x = apply_norm(lp["ln1"], hh, cfg.norm)
        if want_cache:
            att, (k, v) = attention(lp["self_attn"], x, lk_self, policy, cfg,
                                    pos, return_kv=True, sdpa_hint=sdpa_hint,
                                    path="decoder.layers.self_attn")
            skv = {"k": k.reshape(B, T, -1), "v": v.reshape(B, T, -1)}
        else:
            att = attention(lp["self_attn"], x, lk_self, policy, cfg, pos,
                            sdpa_hint=sdpa_hint,
                            path="decoder.layers.self_attn")
            skv = 0
        hh = hh + att.astype(hh.dtype)
        x = apply_norm(lp["ln_x"], hh, cfg.norm)
        ck, cv = cross_attention_kv(lp["cross_attn"], enc_out, lk_cross,
                                    policy, cfg,
                                    path="decoder.layers.cross_attn")
        hh = hh + attention(lp["cross_attn"], x, lk_cross, policy, cfg, pos,
                            causal=False, kv_override=(ck, cv),
                            sdpa_hint=sdpa_hint,
                            path="decoder.layers.cross_attn").astype(hh.dtype)
        x = apply_norm(lp["ln2"], hh, cfg.norm)
        hh = hh + mlp(lp["mlp"], x, lk, policy, cfg.act,
                      path="decoder.layers.mlp").astype(hh.dtype)
        Sx = enc_out.shape[1]
        xkv = ({"k": ck.reshape(B, Sx, -1), "v": cv.reshape(B, Sx, -1)}
               if want_cache else 0)
        return hh, (skv, xkv)
    keys = jax.random.split(key, cfg.n_layers)
    h, caches = scan_or_loop(body, h, (params["dec_layers"], keys),
                             cfg.unroll_scan)
    return apply_norm(params["final_norm"], h, cfg.norm), caches


def encdec_loss(params, batch, key, policy: QuantPolicy, cfg: ArchConfig,
                remat: bool = False, dtype=None, act_sharding=None,
                sdpa_hint=None, loss_chunks: int = 1):
    ke, kd = jax.random.split(key)
    frames = batch["frames"]
    if dtype is not None:
        frames = frames.astype(dtype)
    enc = _encode(params, frames, ke, policy, cfg, sdpa_hint)
    h, _ = _decode_seq(params, batch["tokens"], enc, kd, policy, cfg,
                       sdpa_hint=sdpa_hint)
    loss = chunked_head_loss(params, h, batch["labels"], kd, policy, cfg,
                             loss_chunks, cfg.unroll_scan,
                             act_sharding=act_sharding)
    return loss, {"ce": loss}


def init_encdec_cache(cfg: ArchConfig, batch: int, max_seq: int,
                      dtype=jnp.float32):
    L = cfg.n_layers
    self_kv = jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape),
                           init_kv_cache(cfg, batch, max_seq, dtype))
    cross_kv = jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape),
                            init_kv_cache(cfg, batch, cfg.enc_seq, dtype))
    return {"self_kv": self_kv, "cross_kv": cross_kv,
            "index": jnp.zeros((), jnp.int32)}


def encdec_prefill(params, batch, policy: QuantPolicy, cfg: ArchConfig,
                   max_seq=None, dtype=None, sdpa_hint=None, last_pos=None):
    """Encode audio + teacher-force the prompt; return logits + caches.

    ``last_pos``: optional ``(B,)`` int32 — per-row logit position (serving
    engines right-pad prompts into length buckets)."""
    key = jax.random.PRNGKey(0)
    frames = batch["frames"]
    if dtype is not None:
        frames = frames.astype(dtype)
    enc = _encode(params, frames, key, policy, cfg, sdpa_hint)
    tokens = batch["tokens"]
    B, T = tokens.shape
    max_seq = max_seq or T
    h, (skv, xkv) = _decode_seq(params, tokens, enc, key, policy, cfg,
                                want_cache=True, sdpa_hint=sdpa_hint)
    h_last = (h[:, -1:] if last_pos is None
              else h[jnp.arange(B), last_pos][:, None])
    logits = lm_head(params["lm_head"], h_last, key, policy)
    def pad(x):
        return jnp.pad(x, ((0, 0), (0, 0), (0, max_seq - x.shape[2]), (0, 0)))
    cache = {"self_kv": jax.tree.map(pad, skv), "cross_kv": xkv,
             "index": jnp.asarray(T, jnp.int32)}
    return logits, cache


def encdec_decode(params, cache, batch, policy: QuantPolicy, cfg: ArchConfig,
                  positions=None, kv_quant=None):
    """One-token decoder step.  ``positions``: optional ``(B,)`` per-slot
    positions overriding the cache's scalar ``index`` (continuous-batching
    slots each sit at their own depth)."""
    key = jax.random.PRNGKey(0)
    tokens = batch["tokens"]                                    # (B, 1)
    B = tokens.shape[0]
    index = cache["index"] if positions is None else positions
    pe = params["pos_embed"][index]         # scalar -> (d,), (B,) -> (B, d)
    pe = pe[None, None] if pe.ndim == 1 else pe[:, None]
    h = (embed(params["embed"], tokens) + pe).astype(
             cache["self_kv"]["k"].dtype)

    def body(hh, xs):
        lp, skv, xkv, lk = xs
        # same self/cross subkey split as _decode_seq (qkey tags collide)
        lk_self = jax.random.fold_in(lk, 1)
        lk_cross = jax.random.fold_in(lk, 2)
        x = apply_norm(lp["ln1"], hh, cfg.norm)
        att, skv = decode_attention(lp["self_attn"], x, skv, index, lk_self,
                                    policy, cfg,
                                    path="decoder.layers.self_attn",
                                    kv_quant=kv_quant)
        hh = hh + att.astype(hh.dtype)
        x = apply_norm(lp["ln_x"], hh, cfg.norm)
        Sx = xkv["k"].shape[1]
        ck = xkv["k"].reshape(B, Sx, cfg.n_kv_heads, cfg.hd).astype(hh.dtype)
        cv = xkv["v"].reshape(B, Sx, cfg.n_kv_heads, cfg.hd).astype(hh.dtype)
        pos = (jnp.zeros((B, 1), jnp.int32)
               + jnp.asarray(index, jnp.int32).reshape(-1, 1))
        hh = hh + attention(lp["cross_attn"], x, lk_cross, policy, cfg, pos,
                            causal=False, kv_override=(ck, cv),
                            path="decoder.layers.cross_attn").astype(hh.dtype)
        x = apply_norm(lp["ln2"], hh, cfg.norm)
        hh = hh + mlp(lp["mlp"], x, lk, policy, cfg.act,
                      path="decoder.layers.mlp").astype(hh.dtype)
        return hh, skv
    keys = jax.random.split(key, cfg.n_layers)
    h, skvs = scan_or_loop(body, h, (params["dec_layers"], cache["self_kv"],
                                     cache["cross_kv"], keys),
                           cfg.unroll_scan)
    h = apply_norm(params["final_norm"], h, cfg.norm)
    logits = lm_head(params["lm_head"], h, key, policy)
    new_cache = {"self_kv": skvs, "cross_kv": cache["cross_kv"],
                 "index": index + 1}
    return logits, new_cache
