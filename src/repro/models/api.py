"""Unified model API: ``build_model(cfg)`` -> :class:`Model`.

Bundles init / loss / prefill / decode plus ``input_specs`` — the
ShapeDtypeStruct stand-ins the multi-pod dry-run lowers against (no device
allocation; DESIGN.md Sec. 4, assignment step 2).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from . import encdec, lm

__all__ = ["Model", "build_model", "model_quant_paths"]

f32, i32 = jnp.float32, jnp.int32

_ATTN = ("wq", "wk", "wv", "wo")


def model_quant_paths(cfg: ArchConfig) -> tuple:
    """The logical paths of every quantized GEMM in ``cfg``'s model.

    These are the strings the layers pass as ``dense(..., path=...)``, i.e.
    what ``QuantPolicy.resolve`` / ``overrides`` match against.  Stacked
    layers run under ``lax.scan`` (one shared trace), so paths name the role
    within the stack (``layers.attn.wq``), not a per-layer index.  Used by
    ``QuantPolicy.spec_table`` to print/assert a config's per-layer
    precision table (examples/quickstart.py, tests/test_policy_tree.py).
    """
    mlp_names = (("gate", "up", "down") if cfg.act == "swiglu"
                 else ("fc1", "fc2"))

    def block(prefix):
        return ([f"{prefix}.attn.{w}" for w in _ATTN]
                + ([f"{prefix}.moe.router"]
                   + [f"{prefix}.moe.expert.{n}" for n in mlp_names]
                   if cfg.moe_experts
                   else [f"{prefix}.mlp.{n}" for n in mlp_names]))

    if cfg.family == "audio":
        paths = ([f"encoder.layers.attn.{w}" for w in _ATTN]
                 + [f"encoder.layers.mlp.{n}" for n in mlp_names]
                 + [f"decoder.layers.self_attn.{w}" for w in _ATTN]
                 + [f"decoder.layers.cross_attn.{w}" for w in _ATTN]
                 + [f"decoder.layers.mlp.{n}" for n in mlp_names])
    elif cfg.family == "hybrid":
        paths = ([f"layers.mamba.{n}" for n in
                  ("z_proj", "x_proj", "bc_proj", "dt_proj", "out_proj")]
                 + ["layers.fuse"]          # concat(h, h0) -> d_model projection
                 + block("shared"))
    elif cfg.ssm_kind == "rwkv6":
        paths = [f"layers.rwkv.{n}" for n in
                 ("wr", "wk", "wv", "wg", "wo", "cm_wk", "cm_wv", "cm_wr")]
    else:
        paths = block("layers")
    return tuple(paths + ["lm_head"])


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable                    # key -> params
    loss: Callable                    # (params, batch, key, policy) -> (loss, metrics)
    prefill: Callable                 # (params, batch, policy, max_seq) -> (logits, cache)
    decode: Callable                  # (params, cache, batch, policy, [pos]) -> (logits, cache)
    init_cache: Callable              # (cfg, batch, max_seq, dtype) -> cache
    # int8-KV variant of init_cache for serving (None where the family has
    # no transformer KV cache to quantize — see lm.init_lm_cache_quant)
    init_cache_quant: Callable = None
    # paged-pool serving entry points (serve/paged.py); None where the
    # family has no transformer KV cache to page
    paged_decode: Callable = None     # (params, pool, batch, policy, table, start) -> (lg, pool)
    init_paged_pool: Callable = None  # (cfg, n_pages, page_size) -> pool

    def quant_paths(self) -> tuple:
        """Logical paths of this model's quantized GEMMs (policy overrides
        resolve against these — see :func:`model_quant_paths`)."""
        return model_quant_paths(self.cfg)

    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeSpec, dtype=jnp.float32) -> Dict[str, Any]:
        """Abstract inputs for one (arch x shape) dry-run cell.

        train  -> kwargs for ``loss``;   prefill -> kwargs for ``prefill``;
        decode -> kwargs for ``decode`` (cache included).
        """
        cfg = self.cfg
        B, T = shape.global_batch, shape.seq_len
        sd = jax.ShapeDtypeStruct

        def tok(b, t):
            return sd((b, t), i32)

        if shape.kind == "train":
            batch = {"labels": tok(B, T)}
            if cfg.family == "vlm":
                batch["embeds"] = sd((B, T, cfg.d_model), dtype)
                batch["positions"] = sd((3, B, T), i32)
            elif cfg.family == "audio":
                batch["frames"] = sd((B, cfg.enc_seq, cfg.d_model), dtype)
                batch["tokens"] = tok(B, T)
            else:
                batch["tokens"] = tok(B, T)
            return {"batch": batch}

        if shape.kind == "prefill":
            batch = {}
            if cfg.family == "vlm":
                batch["embeds"] = sd((B, T, cfg.d_model), dtype)
                batch["positions"] = sd((3, B, T), i32)
            elif cfg.family == "audio":
                batch["frames"] = sd((B, cfg.enc_seq, cfg.d_model), dtype)
                batch["tokens"] = tok(B, T)
            else:
                batch["tokens"] = tok(B, T)
            return {"batch": batch}

        # decode: one new token against a cache of length T
        cache = jax.eval_shape(lambda: self.init_cache(cfg, B, T, dtype))
        batch = {}
        if cfg.family == "vlm":
            batch["embeds"] = sd((B, 1, cfg.d_model), dtype)
        else:
            batch["tokens"] = tok(B, 1)
        return {"cache": cache, "batch": batch}


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family == "audio":
        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_encdec_params(key, cfg),
            loss=lambda params, batch, key, policy, **kw: encdec.encdec_loss(
                params, batch, key, policy, cfg, **kw),
            prefill=lambda params, batch, policy, max_seq=None, **kw: encdec.encdec_prefill(
                params, batch, policy, cfg, max_seq, **kw),
            decode=lambda params, cache, batch, policy, **kw: encdec.encdec_decode(
                params, cache, batch, policy, cfg, **kw),
            init_cache=encdec.init_encdec_cache,
        )
    quantizable = not (cfg.family == "hybrid" or cfg.ssm_kind == "rwkv6")
    return Model(
        cfg=cfg,
        init=lambda key: lm.init_lm_params(key, cfg),
        loss=lambda params, batch, key, policy, **kw: lm.lm_loss(
            params, batch, key, policy, cfg, **kw),
        prefill=lambda params, batch, policy, max_seq=None, **kw: lm.lm_prefill(
            params, batch, policy, cfg, max_seq, **kw),
        decode=lambda params, cache, batch, policy, **kw: lm.lm_decode(
            params, cache, batch, policy, cfg, **kw),
        init_cache=lm.init_lm_cache,
        init_cache_quant=lm.init_lm_cache_quant if quantizable else None,
        paged_decode=(
            (lambda params, pool, batch, policy, table, start, **kw:
             lm.lm_paged_decode(params, pool, batch, policy, cfg, table,
                                start, **kw)) if quantizable else None),
        init_paged_pool=lm.init_lm_paged_pool if quantizable else None,
    )
