"""Sharded batch iteration + background prefetch.

``ShardedLoader`` slices the *global* batch into this host's shard and places
it on device with the plan's batch sharding (multi-host: each host feeds its
addressable shard — jax.make_array_from_process_local_data).  ``Prefetcher``
overlaps host-side batch synthesis with device compute via a worker thread —
the standard input-pipeline overlap trick at scale.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import jax

__all__ = ["ShardedLoader", "Prefetcher"]


class ShardedLoader:
    """Deterministic per-step global batches, sharded onto the mesh."""

    def __init__(self, batch_fn: Callable[[int], dict], shardings=None):
        """batch_fn(step) -> global batch dict (numpy/jnp).

        shardings: optional pytree of NamedSharding to place leaves with.
        """
        self.batch_fn = batch_fn
        self.shardings = shardings

    def get(self, step: int) -> dict:
        batch = self.batch_fn(step)
        if self.shardings is None:
            return batch
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s), batch, self.shardings)

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.get(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of up to ``depth`` batches."""

    _STOP = object()

    def __init__(self, loader: ShardedLoader, depth: int = 2,
                 start_step: int = 0):
        self.loader = loader
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            try:
                batch = self.loader.get(step)
            except Exception as e:                     # surface in consumer
                self.q.put(e)
                return
            self.q.put(batch)
            step += 1

    def next(self):
        item = self.q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def stop(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
