"""Deterministic synthetic LM data.

A seeded Markov-ish token stream with enough structure to be *learnable*
(the convergence benchmarks need the loss to actually move):

  token_{t+1} = (a * token_t + noise) mod vocab   with a few "easy" patterns

Determinism contract: batch(step, host, n_hosts) is a pure function — two
hosts never produce overlapping data for the same step, and restarting from a
checkpointed step reproduces the exact stream (fault-tolerance requirement).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig

__all__ = ["SyntheticLM", "make_batch_for"]


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    batch_size: int                 # host-local batch
    seed: int = 0
    easy_frac: float = 0.7          # fraction of positions with learnable rule

    def batch(self, step: int, host: int = 0, n_hosts: int = 1):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step),
            host)
        k1, k2, k3 = jax.random.split(key, 3)
        B, T, V = self.batch_size, self.seq_len, self.vocab_size
        base = jax.random.randint(k1, (B, 1), 0, V)
        mult = 31
        steps = jnp.arange(T + 1)
        seq = (base + mult * steps[None, :]) % V          # learnable ramp
        noise = jax.random.randint(k2, (B, T + 1), 0, V)
        use_noise = jax.random.uniform(k3, (B, T + 1)) > self.easy_frac
        seq = jnp.where(use_noise, noise, seq).astype(jnp.int32)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}


def make_batch_for(cfg: ArchConfig, batch_size: int, seq_len: int,
                   step: int = 0, seed: int = 0, host: int = 0,
                   n_hosts: int = 1):
    """Arch-aware batch: adds the stub-frontend inputs per family."""
    ds = SyntheticLM(cfg.vocab_size, seq_len, batch_size, seed)
    batch = ds.batch(step, host, n_hosts)
    key = jax.random.fold_in(jax.random.PRNGKey(seed + 7), step)
    if cfg.family == "vlm":
        # stub vision frontend: precomputed mixed patch/text embeddings
        batch = {
            "embeds": jax.random.normal(key, (batch_size, seq_len,
                                              cfg.d_model)) * 0.02,
            "labels": batch["labels"],
            "positions": jnp.broadcast_to(
                jnp.arange(seq_len, dtype=jnp.int32),
                (3, batch_size, seq_len)).copy(),
        }
    elif cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (batch_size, cfg.enc_seq, cfg.d_model)) * 0.02
    return batch
