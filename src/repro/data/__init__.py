from .synthetic import SyntheticLM, make_batch_for
from .pipeline import ShardedLoader, Prefetcher

__all__ = ["SyntheticLM", "make_batch_for", "ShardedLoader", "Prefetcher"]
