"""StatQuant-JAX: fully-quantized training (NeurIPS 2020 StatQuant) as a
production multi-pod JAX framework."""

__version__ = "1.0.0"
