"""Qwen2-VL-2B backbone: M-RoPE, vision tower STUBBED (precomputed patch
embeddings via input_specs) [arXiv:2409.12191; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b", family="vlm", n_layers=28, d_model=1536,
    n_heads=12, n_kv_heads=2, d_ff=8960, vocab_size=151_936,
    act="swiglu", qkv_bias=True, rope="mrope",
    source="arXiv:2409.12191; hf",
)
SMOKE = CONFIG.reduced()
