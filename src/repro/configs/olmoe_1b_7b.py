"""OLMoE-1B-7B: MoE 64 experts top-8 [arXiv:2409.02060; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1024, vocab_size=50_304,
    act="swiglu", qkv_bias=False, rope="standard",
    moe_experts=64, moe_topk=8,
    source="arXiv:2409.02060; hf",
)
SMOKE = CONFIG.reduced()
