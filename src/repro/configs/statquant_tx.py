"""The paper's own transformer (IWSLT14 En-De, fairseq transformer-small):
6+6 layer enc-dec in the paper; we expose the decoder-only analogue used for
variance/convergence experiments (Sec. 5.4 proxy)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="statquant-tx", family="dense", n_layers=6, d_model=512,
    n_heads=4, n_kv_heads=4, d_ff=1024, vocab_size=10_000,
    act="gelu", norm="layernorm", qkv_bias=True, rope="standard",
    source="paper Sec. 5.4 (fairseq IWSLT transformer)",
)
SMOKE = CONFIG.reduced()
