"""Zamba2-2.7B: Mamba2 backbone + shared attention blocks [arXiv:2411.15242; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=10240, vocab_size=32_000,
    act="swiglu", qkv_bias=False, rope="standard",
    ssm_kind="mamba2", ssm_state=64, ssm_conv=4, ssm_expand=2,
    ssm_headdim=64, hybrid_period=6,
    source="arXiv:2411.15242; hf",
)
SMOKE = CONFIG.reduced()
