"""Granite-3.0 1B-a400m: MoE 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=8, d_ff=512, vocab_size=49_155,
    act="swiglu", qkv_bias=False, rope="standard",
    moe_experts=32, moe_topk=8,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
SMOKE = CONFIG.reduced()
