"""Command-R 35B: GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b", family="dense", n_layers=40, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=22528, vocab_size=256_000,
    act="swiglu", qkv_bias=False, rope="standard",
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)
SMOKE = CONFIG.reduced()
