"""Config registry: ``get_config(name)`` / ``--arch <id>`` resolution."""

from .base import SHAPES, ArchConfig, ShapeSpec
from . import (command_r_35b, granite_3_2b, granite_moe_1b, minitron_4b,
               olmoe_1b_7b, qwen15_110b, qwen2_vl_2b, rwkv6_1_6b,
               statquant_tx, whisper_medium, zamba2_2_7b)

_REGISTRY = {
    m.CONFIG.name: m for m in (
        minitron_4b, command_r_35b, qwen15_110b, granite_3_2b, rwkv6_1_6b,
        whisper_medium, granite_moe_1b, olmoe_1b_7b, zamba2_2_7b, qwen2_vl_2b,
        statquant_tx,
    )
}

ARCH_NAMES = [n for n in _REGISTRY if n != "statquant-tx"]
ALL_NAMES = list(_REGISTRY)


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; choose from {ALL_NAMES}")
    mod = _REGISTRY[name]
    return mod.SMOKE if smoke else mod.CONFIG


def shape_grid(cfg: ArchConfig):
    """The assignment's shape cells applicable to this arch.

    long_500k only for sub-quadratic archs (DESIGN.md Sec. 5 skip list).
    """
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.is_subquadratic:
        names.append("long_500k")
    return [SHAPES[n] for n in names]


__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "get_config", "shape_grid",
           "ARCH_NAMES", "ALL_NAMES"]
