"""Architecture configuration schema + shape grid.

Every assigned architecture is one frozen :class:`ArchConfig`; smoke tests use
``reduced()`` variants of the same family.  Shapes come from the assignment's
per-arch grid (train_4k / prefill_32k / decode_32k / long_500k).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    act: str = "swiglu"              # swiglu | gelu | relu2
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    qkv_bias: bool = False
    rope: str = "standard"           # standard | mrope | none
    head_dim: Optional[int] = None   # default d_model // n_heads
    rope_theta: float = 10_000.0
    # MoE
    moe_experts: int = 0
    moe_topk: int = 0
    moe_capacity: float = 1.25
    # SSM (rwkv6 / mamba2)
    ssm_kind: str = ""               # "" | rwkv6 | mamba2
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_headdim: int = 64
    hybrid_period: int = 0           # zamba2: shared attn block every k layers
    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_seq: int = 0                 # precomputed frame embeddings length
    # misc
    tie_embeddings: bool = False
    vocab_pad_to: int = 256
    dtype: str = "float32"
    source: str = ""                 # provenance tag from the assignment
    # dry-run probes replace lax.scan with an unrolled loop so XLA cost
    # analysis (which counts while-bodies ONCE) can be composed exactly
    unroll_scan: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return (self.vocab_size + p - 1) // p * p

    @property
    def is_subquadratic(self) -> bool:
        """Supports the long_500k decode cell (SSM / linear-attn / hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper via its decoder)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/wiring, tiny dims."""
        heads = min(self.n_heads, 4)
        kv = min(self.n_kv_heads, max(1, heads // 2))
        layers = 4 if self.hybrid_period else 2
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=layers,
            d_model=64,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=16,
            d_ff=96 if not self.moe_experts else 32,
            vocab_size=509,          # deliberately non-multiple: tests padding
            vocab_pad_to=64,
            moe_experts=min(self.moe_experts, 4) if self.moe_experts else 0,
            moe_topk=min(self.moe_topk, 2) if self.moe_topk else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_kind else 64,
            hybrid_period=2 if self.hybrid_period else 0,
            enc_layers=2 if self.enc_layers else 0,
            enc_seq=16 if self.enc_seq else 0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}
