"""Qwen1.5-110B: QKV bias [hf:Qwen/Qwen1.5-0.5B scaling; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-110b", family="dense", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=49152, vocab_size=152_064,
    act="swiglu", qkv_bias=True, rope="standard",
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)
SMOKE = CONFIG.reduced()
