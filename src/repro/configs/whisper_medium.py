"""Whisper-medium: enc-dec, conv frontend STUBBED (precomputed frame
embeddings via input_specs) [arXiv:2212.04356]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="audio", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=51_865,
    act="gelu", norm="layernorm", qkv_bias=True, rope="none",
    enc_layers=24, enc_seq=1500,
    source="arXiv:2212.04356; unverified",
)
SMOKE = CONFIG.reduced()
