"""Pallas TPU kernel: int8 x int8 -> int32 GEMM with fused affine epilogue.

This is the deployed form of the paper's quantized GEMMs (forward Eq. 3 and
both backward GEMMs of Eq. 6).  The MXU consumes int8 tiles and accumulates
int32 in a VMEM scratch across the K sweep; the epilogue applies

    out[i,j] = acc[i,j]*rs_i*cs_j + r2_i*u_j + a_i + b_j

Writing each affine operand as  X^ = alpha_x * Cx + beta_x  (per-row) and
W^ = alpha_w * Cw + beta_w  (per-tensor/per-channel), the exact product is

    X^W^ = (alpha_x alpha_w) CxCw  +  alpha_x beta_w rowsum(Cx)   [a_i]
         +  beta_x (alpha_w colsum(Cw) + K beta_w)                [r2_i u_j]

so ONE epilogue form covers every scale/zero-point combination the paper's
recipe produces (ops.py wires it); ``b_j`` is free for fusing a layer bias.

Tiling: (bm x bk)@(bk x bn) MXU-aligned blocks, K innermost so the int32
accumulator stays VMEM-resident.  Default 128x512x512 tiles use ~0.8 MB of
the ~16 MB/core VMEM; bigger bn/bk raise arithmetic intensity.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .autotune import lookup_tiles
from .tiling import (check_tiles, pad2d as _pad2, round_up as _round_up)

__all__ = ["q8_matmul"]


def _kernel(x_ref, y_ref, rs_ref, cs_ref, r2_ref, u_ref, a_ref, b_ref,
            o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], y_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        o_ref[...] = (acc * (rs_ref[...] * cs_ref[...])
                      + r2_ref[...] * u_ref[...]
                      + a_ref[...] + b_ref[...])


def q8_matmul(x8: jax.Array, y8: jax.Array, rs: jax.Array, cs: jax.Array,
              r2: jax.Array, u: jax.Array, a: jax.Array, b: jax.Array,
              bm: int = None, bn: int = None, bk: int = None,
              interpret: bool = False) -> jax.Array:
    """x8: (M,K) int8; y8: (K,N) int8; rs/r2/a: (M,); cs/u/b: (N,) -> f32.

    Tiles default to the persisted autotuner cache for this (M, K, N)
    (``kernels/autotune.py``; explicit bm/bn/bk override it), shrink toward
    small dims (keeping MXU-friendly multiples), then every dim is
    zero-padded up to a tile multiple and the result sliced back.
    Zero-padding is exact — padded K codes contribute 0 to the accumulator
    and the epilogue coefficient vectors pad with zeros, so padded output
    rows/cols never leak.
    """
    M, K = x8.shape
    K2, N = y8.shape
    if K != K2:
        raise ValueError(f"q8_matmul: contraction mismatch — x8 {x8.shape} "
                         f"vs y8 {y8.shape}")
    tm, tn, tk = lookup_tiles("q8_matmul", (M, K, N))
    bm, bn, bk = (tm if bm is None else bm, tn if bn is None else bn,
                  tk if bk is None else bk)
    bm = min(bm, _round_up(M, 32))       # int8 sublane tile is 32
    bn = min(bn, _round_up(N, 128))      # lane dim is 128
    bk = min(bk, _round_up(K, 128))
    check_tiles("q8_matmul", (M, K, N), (bm, bn, bk), interpret=interpret,
                multiples=(32, 128, 128))
    return _q8_matmul(x8, y8, rs, cs, r2, u, a, b, bm=bm, bn=bn, bk=bk,
                      interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _q8_matmul(x8, y8, rs, cs, r2, u, a, b, *, bm, bn, bk, interpret):
    M, K = x8.shape
    N = y8.shape[1]
    Mp, Np, Kp = _round_up(M, bm), _round_up(N, bn), _round_up(K, bk)
    x8 = _pad2(x8, Mp, Kp)
    y8 = _pad2(y8, Kp, Np)
    nk = Kp // bk
    grid = (Mp // bm, Np // bn, nk)

    row = lambda i, j, k: (i, 0)
    col = lambda i, j, k: (0, j)
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, 1), row), pl.BlockSpec((1, bn), col),
            pl.BlockSpec((bm, 1), row), pl.BlockSpec((1, bn), col),
            pl.BlockSpec((bm, 1), row), pl.BlockSpec((1, bn), col),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x8, y8,
      _pad2(rs.reshape(M, 1), Mp, 1), _pad2(cs.reshape(1, N), 1, Np),
      _pad2(r2.reshape(M, 1), Mp, 1), _pad2(u.reshape(1, N), 1, Np),
      _pad2(a.reshape(M, 1), Mp, 1), _pad2(b.reshape(1, N), 1, Np))
    return out[:M, :N]
