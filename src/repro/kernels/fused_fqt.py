"""Fused quantize -> int8 GEMM -> affine-epilogue megakernels (Pallas TPU).

The unfused FQT pipeline materializes three HBM intermediates per GEMM:
the int8 code tensor from ``quantize_sr_*``, its scale/zero vectors, and
the int32-accumulated GEMM output before the epilogue.  These kernels fuse
the whole pipeline into the GEMM's K-sweep: each (bm x bk) tile of the
float operand is quantized *in VMEM* (deterministic round-to-nearest or
stochastic rounding against prefetched ``random.bits`` uniforms), fed to
the MXU as shifted-signed int8, and the affine epilogue of
``core/backend.py`` is applied in-register on the last K step — no int8
codes, scales, or pre-epilogue accumulators ever touch HBM.

Two kernel families cover the three GEMMs of the paper (Eq. 3 / Eq. 6):

  ``fused_qlhs_matmul``      quantize the LHS on the fly against a
                             *materialized* int8 RHS (the weight codes).
                             ``trans_b=False`` is the forward
                             ``Q_f(X) @ Q_theta(W)``; ``trans_b=True`` reads
                             the RHS transposed for the activation-grad
                             ``Q_b2(dY) @ Q_theta(W).T`` (PTQ or PSQ Q_b2 —
                             per-row scale/zero vectors come in as (M, 1)).
  ``fused_qboth_tn_matmul``  quantize BOTH operands on the fly, contracting
                             over the *storage rows* (A.T @ B): the
                             weight-grad ``Q_f(X).T @ Q_b1(dY)`` with
                             deterministic A and stochastic B, both
                             per-tensor.

Quantization inside the kernels uses the exact formulas of
``core/quantizers.py`` — ``SR(t) = floor(t + bits * 2^-32)``,
deterministic ``round(t)`` (round-half-even), ``clip [0, 2^b-1]``, shift
by ``-2^(b-1)`` — with scales/zeros computed *outside* on the unpadded
input, so codes are bit-identical to the unfused ``quantize_sr_*`` /
``quantize_ptq_*`` path for the same PRNG key.

Every kernel has an ``*_xla`` twin with identical quantizer math used (a)
as the ``native``-backend fused path and (b) as the test oracle.  The
twins pick the accumulation dtype per platform: int8 -> int32
``dot_general`` on TPU (the MXU path), f32 code-value GEMM elsewhere —
XLA's CPU/GPU int8 GEMMs are ~6x slower than their f32 ones (measured on
the bench host), and f32 accumulation of code products is exact up to
partial sums of 2^24 (codes are <= 2^8, products <= 2^14, so exact for
K <= 2^10 and within ~2^-24 relative beyond — noise next to quantization
error).

Tile shapes come from the persisted autotuner cache
(``kernels/autotune.py``) unless given explicitly; bad explicit tiles fail
fast in ``check_tiles`` with the shape and tile in the message.

Padding: float operands and epilogue vectors are zero-padded to tile
multiples; in-kernel masks zero the *codes* of padded contraction
rows/cols (``k*bk + iota < kdim``) so the accumulator and the row/col-sum
scratches only ever see real data.  Output rows/cols beyond the real shape
are sliced off by the wrapper.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .autotune import lookup_tiles
from .pack import codes_per_byte, max_safe_k_packed, unpack_tile
from .tiling import (check_bits, check_tiles, pad2d as _pad2,
                     pad_rows as _pad_rows, round_up as _round_up)

__all__ = [
    "fused_qlhs_matmul", "fused_qlhs_matmul_xla",
    "fused_qboth_tn_matmul", "fused_qboth_tn_matmul_xla",
    "fused_qlhs_packed_matmul", "fused_qlhs_packed_matmul_xla",
]

_U32_TO_UNIT = 1.0 / 4294967296.0          # bits * 2^-32, the one SR rule


def _opt_barrier(x):
    # schedule pin only — jax<0.5 can't vmap the primitive, and dropping
    # the barrier under vmap is always semantically safe
    try:
        return jax.lax.optimization_barrier(x)
    except NotImplementedError:
        return x


# ---------------------------------------------------------------------------
# LHS-quantizing kernel: forward GEMM and activation-grad GEMM
# ---------------------------------------------------------------------------

def _qlhs_kernel(*refs, nk: int, kdim: int, nbins: float, off: int, bk: int,
                 trans_b: bool, stochastic: bool):
    if stochastic:
        (xf_ref, sa_ref, za_ref, rb_ref, y8_ref, ab_ref, bb_ref, u_ref,
         o_ref, acc_ref, rsum_ref) = refs
    else:
        (xf_ref, sa_ref, za_ref, y8_ref, ab_ref, bb_ref, u_ref,
         o_ref, acc_ref, rsum_ref) = refs

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        rsum_ref[...] = jnp.zeros_like(rsum_ref)

    # quantize this (bm, bk) float tile in VMEM — never touches HBM
    t = sa_ref[...] * (xf_ref[...] - za_ref[...])
    if stochastic:
        u01 = rb_ref[...].astype(jnp.float32) * _U32_TO_UNIT
        q = jnp.floor(t + u01)
    else:
        q = jnp.round(t)
    c = jnp.clip(q, 0.0, nbins) - off
    # zero the codes of padded K columns so acc and rowsum stay exact
    col = pl.program_id(2) * bk + jax.lax.broadcasted_iota(
        jnp.int32, c.shape, 1)
    c8 = jnp.where(col < kdim, c, 0.0).astype(jnp.int8)

    dims = (((1,), (1,)) if trans_b else ((1,), (0,))), ((), ())
    acc_ref[...] += jax.lax.dot_general(c8, y8_ref[...], dims,
                                        preferred_element_type=jnp.int32)
    rsum_ref[...] += jnp.sum(c8.astype(jnp.int32), axis=1, keepdims=True)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        alpha_a = 1.0 / sa_ref[...]                       # (bm, 1)
        beta_a = off * alpha_a + za_ref[...]
        ab = ab_ref[0, 0]
        bb = bb_ref[0, 0]
        acc = acc_ref[...].astype(jnp.float32)
        a_i = (alpha_a * bb) * rsum_ref[...].astype(jnp.float32)
        o_ref[...] = acc * (alpha_a * ab) + beta_a * u_ref[...] + a_i


def fused_qlhs_matmul(xf: jax.Array, scale_a: jax.Array, zero_a: jax.Array,
                      rbits: Optional[jax.Array], y8: jax.Array,
                      alpha_b, beta_b, u_vec: jax.Array, *, bits: int,
                      trans_b: bool = False, bm: Optional[int] = None,
                      bn: Optional[int] = None, bk: Optional[int] = None,
                      interpret: bool = False,
                      tune_key: str = "fused_fwd") -> jax.Array:
    """``Q(xf) @ B-hat`` (or ``@ B-hat.T``) with the quantize fused in.

    xf: (M, K) f32; scale_a/zero_a: (M, 1) per-row (broadcast a per-tensor
    scalar to (M, 1)); rbits: (M, K) uint32 SR uniforms or ``None`` for
    deterministic round-to-nearest; y8: shifted int8 RHS codes, stored
    (K, N) or — ``trans_b=True`` — (N, K); alpha_b/beta_b: scalar affine
    factors of the RHS; u_vec: (N,) precomputed RHS epilogue column vector
    ``alpha_b * colsum(y8) + K * beta_b`` (colsum over the contraction).
    Returns (M, N) f32.  Tiles default to the autotuner cache under
    ``tune_key``.
    """
    check_bits("fused_qlhs_matmul", bits)
    M, K = xf.shape
    N, Kb = (y8.shape if trans_b else y8.shape[::-1])
    if Kb != K:
        raise ValueError(
            f"fused_qlhs_matmul: contraction mismatch — xf {xf.shape} vs "
            f"y8 {y8.shape} (trans_b={trans_b})")
    tm, tn, tk = lookup_tiles(tune_key, (M, K, N))
    bm, bn, bk = (tm if bm is None else bm, tn if bn is None else bn,
                  tk if bk is None else bk)
    bm = min(bm, _round_up(M, 8))        # f32 A tile: sublane 8
    bn = min(bn, _round_up(N, 128))
    bk = min(bk, _round_up(K, 128))
    check_tiles("fused_qlhs_matmul", (M, K, N), (bm, bn, bk),
                interpret=interpret, multiples=(8, 128, 128))
    Mp, Np, Kp = _round_up(M, bm), _round_up(N, bn), _round_up(K, bk)
    nk = Kp // bk
    nbins = float((1 << bits) - 1)
    off = 1 << (bits - 1)

    stochastic = rbits is not None
    row = lambda i, j, k: (i, 0)
    scalar = lambda i, j, k: (0, 0)
    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                pl.BlockSpec((bm, 1), row), pl.BlockSpec((bm, 1), row)]
    operands = [_pad2(xf.astype(jnp.float32), Mp, Kp),
                _pad_rows(scale_a.reshape(M, 1), Mp, edge=True),
                _pad_rows(zero_a.reshape(M, 1), Mp, edge=True)]
    if stochastic:
        in_specs.append(pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)))
        operands.append(_pad2(rbits, Mp, Kp))
    if trans_b:
        in_specs.append(pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)))
        operands.append(_pad2(y8, Np, Kp))
    else:
        in_specs.append(pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)))
        operands.append(_pad2(y8, Kp, Np))
    in_specs += [pl.BlockSpec((1, 1), scalar), pl.BlockSpec((1, 1), scalar),
                 pl.BlockSpec((1, bn), lambda i, j, k: (0, j))]
    operands += [jnp.asarray(alpha_b, jnp.float32).reshape(1, 1),
                 jnp.asarray(beta_b, jnp.float32).reshape(1, 1),
                 _pad2(u_vec.reshape(1, N), 1, Np)]

    out = pl.pallas_call(
        functools.partial(_qlhs_kernel, nk=nk, kdim=K, nbins=nbins, off=off,
                          bk=bk, trans_b=trans_b, stochastic=stochastic),
        grid=(Mp // bm, Np // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32),
                        pltpu.VMEM((bm, 1), jnp.int32)],
        interpret=interpret,
    )(*operands)
    return out[:M, :N]


# ---------------------------------------------------------------------------
# Both-quantizing transposed kernel: the weight-grad GEMM
# ---------------------------------------------------------------------------

def _qboth_tn_kernel(af_ref, sa_ref, za_ref, bf_ref, sb_ref, zb_ref, rb_ref,
                     a_ref, o_ref, acc_ref, csum_ref, *, nk: int, kdim: int,
                     nbins_a: float, off_a: int, nbins_b: float, off_b: int,
                     bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        csum_ref[...] = jnp.zeros_like(csum_ref)

    # A: (bk, bm) storage tile of X, deterministic per-tensor quantize; the
    # contraction runs over the storage rows (A.T @ B)
    ta = sa_ref[0, 0] * (af_ref[...] - za_ref[0, 0])
    ca = jnp.clip(jnp.round(ta), 0.0, nbins_a) - off_a
    row_a = pl.program_id(2) * bk + jax.lax.broadcasted_iota(
        jnp.int32, ca.shape, 0)
    ca8 = jnp.where(row_a < kdim, ca, 0.0).astype(jnp.int8)

    # B: (bk, bn) storage tile of dY, stochastic per-tensor quantize
    tb = sb_ref[0, 0] * (bf_ref[...] - zb_ref[0, 0])
    u01 = rb_ref[...].astype(jnp.float32) * _U32_TO_UNIT
    cb = jnp.clip(jnp.floor(tb + u01), 0.0, nbins_b) - off_b
    row_b = pl.program_id(2) * bk + jax.lax.broadcasted_iota(
        jnp.int32, cb.shape, 0)
    cb8 = jnp.where(row_b < kdim, cb, 0.0).astype(jnp.int8)

    acc_ref[...] += jax.lax.dot_general(
        ca8, cb8, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    csum_ref[...] += jnp.sum(cb8.astype(jnp.int32), axis=0, keepdims=True)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        alpha_a = 1.0 / sa_ref[0, 0]
        beta_a = off_a * alpha_a + za_ref[0, 0]
        alpha_b = 1.0 / sb_ref[0, 0]
        beta_b = off_b * alpha_b + zb_ref[0, 0]
        u_j = alpha_b * csum_ref[...].astype(jnp.float32) \
            + float(kdim) * beta_b                         # (1, bn)
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * (alpha_a * alpha_b)
                      + beta_a * u_j + a_ref[...])


def fused_qboth_tn_matmul(af: jax.Array, scale_a, zero_a, bf: jax.Array,
                          scale_b, zero_b, rbits: jax.Array,
                          a_vec: jax.Array, *, bits_a: int, bits_b: int,
                          bm: Optional[int] = None, bn: Optional[int] = None,
                          bk: Optional[int] = None, interpret: bool = False,
                          tune_key: str = "fused_dw") -> jax.Array:
    """``Q_det(af).T @ Q_sr(bf)`` with both quantizes fused into the K-sweep.

    af: (K, M) f32 storage (the GEMM contracts over the K storage rows);
    bf: (K, N) f32; scale/zero: per-tensor scalars computed on the unpadded
    inputs; rbits: (K, N) uint32 SR uniforms for the B operand; a_vec: (M,)
    precomputed epilogue row vector ``alpha_a * beta_b * colsum(ca8)``
    (colsum over K of A's shifted codes — rematerialized outside, since the
    kernel's A tile never sees a full column).  Returns (M, N) f32.
    """
    check_bits("fused_qboth_tn_matmul", bits_a)
    check_bits("fused_qboth_tn_matmul", bits_b)
    K, M = af.shape
    K2, N = bf.shape
    if K2 != K:
        raise ValueError(
            f"fused_qboth_tn_matmul: contraction mismatch — af {af.shape} "
            f"vs bf {bf.shape} (both contract over storage rows)")
    tm, tn, tk = lookup_tiles(tune_key, (M, K, N))
    bm, bn, bk = (tm if bm is None else bm, tn if bn is None else bn,
                  tk if bk is None else bk)
    # A tile is (bk, bm): bm lands on the lane dim (128), bk on the f32
    # sublane dim (8) — the transpose of the qlhs alignment
    bm = min(bm, _round_up(M, 128))
    bn = min(bn, _round_up(N, 128))
    bk = min(bk, _round_up(K, 8))
    check_tiles("fused_qboth_tn_matmul", (M, K, N), (bm, bn, bk),
                interpret=interpret, multiples=(128, 128, 8))
    Mp, Np, Kp = _round_up(M, bm), _round_up(N, bn), _round_up(K, bk)
    nk = Kp // bk
    scalar = lambda i, j, k: (0, 0)
    out = pl.pallas_call(
        functools.partial(
            _qboth_tn_kernel, nk=nk, kdim=K,
            nbins_a=float((1 << bits_a) - 1), off_a=1 << (bits_a - 1),
            nbins_b=float((1 << bits_b) - 1), off_b=1 << (bits_b - 1),
            bk=bk),
        grid=(Mp // bm, Np // bn, nk),
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((1, 1), scalar), pl.BlockSpec((1, 1), scalar),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), scalar), pl.BlockSpec((1, 1), scalar),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32),
                        pltpu.VMEM((1, bn), jnp.int32)],
        interpret=interpret,
    )(_pad2(af.astype(jnp.float32), Kp, Mp),
      jnp.asarray(scale_a, jnp.float32).reshape(1, 1),
      jnp.asarray(zero_a, jnp.float32).reshape(1, 1),
      _pad2(bf.astype(jnp.float32), Kp, Np),
      jnp.asarray(scale_b, jnp.float32).reshape(1, 1),
      jnp.asarray(zero_b, jnp.float32).reshape(1, 1),
      _pad2(rbits, Kp, Np),
      _pad2(a_vec.reshape(M, 1), Mp, 1))
    return out[:M, :N]


# ---------------------------------------------------------------------------
# Packed-weight LHS-quantizing kernel: the forward GEMM over bit-packed W
# ---------------------------------------------------------------------------

def _qlhs_packed_kernel(xf_ref, sa_ref, za_ref, p_ref, ab_ref, bb_ref,
                        u_ref, o_ref, acc_ref, rsum_ref, *, nk: int,
                        kdim: int, nbins: float, off_a: int, wbits: int,
                        bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        rsum_ref[...] = jnp.zeros_like(rsum_ref)

    # quantize this (bm, bk) float tile in VMEM (deterministic forward)
    t = sa_ref[...] * (xf_ref[...] - za_ref[...])
    c = jnp.clip(jnp.round(t), 0.0, nbins) - off_a
    col = pl.program_id(2) * bk + jax.lax.broadcasted_iota(
        jnp.int32, c.shape, 1)
    c8 = jnp.where(col < kdim, c, 0.0).astype(jnp.int8)

    # unpack the (bk/ppb, bn) packed weight tile in VMEM -> shifted int8
    off_b = 1 << (wbits - 1)
    w = unpack_tile(p_ref[...], wbits) - off_b
    row = pl.program_id(2) * bk + jax.lax.broadcasted_iota(
        jnp.int32, w.shape, 0)
    w8 = jnp.where(row < kdim, w, 0).astype(jnp.int8)

    acc_ref[...] += jax.lax.dot_general(c8, w8, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.int32)
    rsum_ref[...] += jnp.sum(c8.astype(jnp.int32), axis=1, keepdims=True)

    # epilogue identical to _qlhs_kernel (bit-exactness vs the unpacked
    # fused kernel rests on the matching expression tree)
    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        alpha_a = 1.0 / sa_ref[...]                       # (bm, 1)
        beta_a = off_a * alpha_a + za_ref[...]
        ab = ab_ref[0, 0]
        bb = bb_ref[0, 0]
        acc = acc_ref[...].astype(jnp.float32)
        a_i = (alpha_a * bb) * rsum_ref[...].astype(jnp.float32)
        o_ref[...] = acc * (alpha_a * ab) + beta_a * u_ref[...] + a_i


def fused_qlhs_packed_matmul(xf: jax.Array, scale_a: jax.Array,
                             zero_a: jax.Array, packed: jax.Array,
                             alpha_b, beta_b, u_vec: jax.Array, *,
                             bits: int, wbits: int,
                             bm: Optional[int] = None,
                             bn: Optional[int] = None,
                             bk: Optional[int] = None,
                             interpret: bool = False,
                             tune_key: str = "fused_packed") -> jax.Array:
    """``Q_det(xf) @ W-hat`` with W bit-packed in HBM: the forward megakernel
    of the ultra-low-bit track.  Quantizes the (bm, bk) activation tile AND
    unpacks the (bk/ppb, bn) weight tile in VMEM inside the K-sweep, so no
    unpacked weight codes ever touch HBM.

    xf: (M, K) f32; scale_a/zero_a: (M, 1) (broadcast a scalar); packed:
    (ceil(K/ppb), N) uint8 at ``wbits`` codes/byte (kernels/pack.py layout);
    alpha_b/beta_b: the weight's scalar affine factors; u_vec: (N,) the
    precomputed epilogue column vector ``alpha_b*colsum(w8) + K*beta_b``
    (the colsum is a fused unpack+reduce over the packed bytes — see
    ``core/backend.fused_fqt_fwd``).  Returns (M, N) f32.
    """
    check_bits("fused_qlhs_packed_matmul", bits)
    check_bits("fused_qlhs_packed_matmul", wbits, lo=1)
    ppb = codes_per_byte(wbits)
    M, K = xf.shape
    N = packed.shape[1]
    if packed.shape[0] != -(-K // ppb):
        raise ValueError(
            f"fused_qlhs_packed_matmul: packed rows {packed.shape[0]} != "
            f"ceil({K}/{ppb}) for {wbits}-bit codes")
    safe = max_safe_k_packed(bits, wbits)
    if K > safe:
        raise ValueError(
            f"fused_qlhs_packed_matmul: K={K} overflows the int32 "
            f"accumulator for int{bits} x int{wbits} codes "
            f"(max_safe_k={safe})")
    tm, tn, tk = lookup_tiles(tune_key, (M, K, N), dtype=f"int{wbits}")
    bm, bn, bk = (tm if bm is None else bm, tn if bn is None else bn,
                  tk if bk is None else bk)
    bm = min(bm, _round_up(M, 8))        # f32 A tile: sublane 8
    bn = min(bn, _round_up(N, 128))
    bk = min(bk, _round_up(K, 128))      # ppb | 128, so ppb | bk
    check_tiles("fused_qlhs_packed_matmul", (M, K, N), (bm, bn, bk),
                interpret=interpret, multiples=(8, 128, 128))
    Mp, Np, Kp = _round_up(M, bm), _round_up(N, bn), _round_up(K, bk)
    nk = Kp // bk
    row = lambda i, j, k: (i, 0)
    scalar = lambda i, j, k: (0, 0)
    out = pl.pallas_call(
        functools.partial(_qlhs_packed_kernel, nk=nk, kdim=K,
                          nbins=float((1 << bits) - 1),
                          off_a=1 << (bits - 1), wbits=wbits, bk=bk),
        grid=(Mp // bm, Np // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, 1), row), pl.BlockSpec((bm, 1), row),
            pl.BlockSpec((bk // ppb, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), scalar), pl.BlockSpec((1, 1), scalar),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32),
                        pltpu.VMEM((bm, 1), jnp.int32)],
        interpret=interpret,
    )(_pad2(xf.astype(jnp.float32), Mp, Kp),
      _pad_rows(scale_a.reshape(M, 1), Mp, edge=True),
      _pad_rows(zero_a.reshape(M, 1), Mp, edge=True),
      _pad2(packed, Kp // ppb, Np),
      jnp.asarray(alpha_b, jnp.float32).reshape(1, 1),
      jnp.asarray(beta_b, jnp.float32).reshape(1, 1),
      _pad2(u_vec.reshape(1, N), 1, Np))
    return out[:M, :N]


# ---------------------------------------------------------------------------
# XLA twins — the `native`-backend fused path and the test oracles
# ---------------------------------------------------------------------------

def _codes_dot(ca: jax.Array, cb: jax.Array, dims) -> jax.Array:
    """Code GEMM with platform-adaptive accumulation (see module docstring)."""
    if jax.default_backend() == "tpu":
        acc = jax.lax.dot_general(ca.astype(jnp.int8), cb.astype(jnp.int8),
                                  dims, preferred_element_type=jnp.int32)
        return acc.astype(jnp.float32)
    return jax.lax.dot_general(ca.astype(jnp.float32),
                               cb.astype(jnp.float32), dims,
                               preferred_element_type=jnp.float32)


def fused_qlhs_matmul_xla(xf: jax.Array, scale_a: jax.Array,
                          zero_a: jax.Array, rbits: Optional[jax.Array],
                          y8: jax.Array, alpha_b, beta_b, u_vec: jax.Array,
                          *, bits: int, trans_b: bool = False) -> jax.Array:
    """XLA twin of :func:`fused_qlhs_matmul` — identical quantizer math,
    single fused elementwise+GEMM graph, no HBM int8 codes by construction
    (XLA fuses the quantize into the GEMM read on TPU; on CPU the f32
    code-value GEMM dominates either way)."""
    check_bits("fused_qlhs_matmul_xla", bits)
    N, Kb = (y8.shape if trans_b else y8.shape[::-1])
    if Kb != xf.shape[-1]:
        raise ValueError(
            f"fused_qlhs_matmul_xla: contraction mismatch — xf {xf.shape} "
            f"vs y8 {y8.shape} (trans_b={trans_b})")
    nbins = float((1 << bits) - 1)
    off = float(1 << (bits - 1))
    t = scale_a * (xf.astype(jnp.float32) - zero_a)
    if rbits is None:
        q = jnp.round(t)
    else:
        q = jnp.floor(t + rbits.astype(jnp.float32) * _U32_TO_UNIT)
    c = jnp.clip(q, 0.0, nbins) - off
    # materialize the codes exactly once — both the GEMM and the row-sum
    # consume them, and XLA otherwise duplicates the quantize into each
    # consumer fusion (measured ~2% on the large bench shapes)
    c = _opt_barrier(c)
    dims = (((1,), (1,)) if trans_b else ((1,), (0,))), ((), ())
    acc = _codes_dot(c, y8, dims)
    alpha_a = 1.0 / scale_a                               # (M, 1)
    beta_a = off * alpha_a + zero_a
    ab = jnp.asarray(alpha_b, jnp.float32)
    bb = jnp.asarray(beta_b, jnp.float32)
    a_i = (alpha_a * bb) * jnp.sum(c, axis=1, keepdims=True)
    return acc * (alpha_a * ab) + beta_a * u_vec[None, :] + a_i


def fused_qboth_tn_matmul_xla(af: jax.Array, scale_a, zero_a, bf: jax.Array,
                              scale_b, zero_b, rbits: jax.Array,
                              a_vec: jax.Array, *, bits_a: int,
                              bits_b: int) -> jax.Array:
    """XLA twin of :func:`fused_qboth_tn_matmul`."""
    check_bits("fused_qboth_tn_matmul_xla", bits_a)
    check_bits("fused_qboth_tn_matmul_xla", bits_b)
    if bf.shape[0] != af.shape[0]:
        raise ValueError(
            f"fused_qboth_tn_matmul_xla: contraction mismatch — af "
            f"{af.shape} vs bf {bf.shape} (both contract over storage rows)")
    K = af.shape[0]
    nbins_a = float((1 << bits_a) - 1)
    off_a = float(1 << (bits_a - 1))
    nbins_b = float((1 << bits_b) - 1)
    off_b = float(1 << (bits_b - 1))
    sa = jnp.asarray(scale_a, jnp.float32)
    za = jnp.asarray(zero_a, jnp.float32)
    sb = jnp.asarray(scale_b, jnp.float32)
    zb = jnp.asarray(zero_b, jnp.float32)
    ca = jnp.clip(jnp.round(sa * (af.astype(jnp.float32) - za)),
                  0.0, nbins_a) - off_a
    u01 = rbits.astype(jnp.float32) * _U32_TO_UNIT
    cb = jnp.clip(jnp.floor(sb * (bf.astype(jnp.float32) - zb) + u01),
                  0.0, nbins_b) - off_b
    # single materialization of each code tensor (see fused_qlhs_matmul_xla)
    ca, cb = _opt_barrier((ca, cb))
    acc = _codes_dot(ca, cb, (((0,), (0,)), ((), ())))
    alpha_a = 1.0 / sa
    beta_a = off_a * alpha_a + za
    alpha_b = 1.0 / sb
    beta_b = off_b * alpha_b + zb
    u_j = alpha_b * jnp.sum(cb, axis=0) + float(K) * beta_b
    return acc * (alpha_a * alpha_b) + beta_a * u_j[None, :] + a_vec[:, None]


def fused_qlhs_packed_matmul_xla(xf: jax.Array, scale_a: jax.Array,
                                 zero_a: jax.Array, packed: jax.Array,
                                 alpha_b, beta_b, u_vec: jax.Array, *,
                                 bits: int, wbits: int) -> jax.Array:
    """XLA twin of :func:`fused_qlhs_packed_matmul` — identical quantizer
    and unpack math; the shift/mask unpack chain fuses into the GEMM
    operand read, so no unpacked weight tensor persists in HBM either.
    The expression tree mirrors :func:`fused_qlhs_matmul_xla` exactly."""
    check_bits("fused_qlhs_packed_matmul_xla", bits)
    check_bits("fused_qlhs_packed_matmul_xla", wbits, lo=1)
    ppb = codes_per_byte(wbits)
    M, K = xf.shape
    if packed.shape[0] != -(-K // ppb):
        raise ValueError(
            f"fused_qlhs_packed_matmul_xla: packed rows {packed.shape[0]} "
            f"!= ceil({K}/{ppb}) for {wbits}-bit codes")
    safe = max_safe_k_packed(bits, wbits)
    if K > safe:
        raise ValueError(
            f"fused_qlhs_packed_matmul_xla: K={K} overflows the int32 "
            f"accumulator for int{bits} x int{wbits} codes "
            f"(max_safe_k={safe})")
    nbins = float((1 << bits) - 1)
    off_a = float(1 << (bits - 1))
    off_b = 1 << (wbits - 1)
    t = scale_a * (xf.astype(jnp.float32) - zero_a)
    c = jnp.clip(jnp.round(t), 0.0, nbins) - off_a
    w8 = (unpack_tile(packed, wbits)[:K, :] - off_b).astype(jnp.int8)
    # one materialization each (see fused_qlhs_matmul_xla)
    c, w8 = _opt_barrier((c, w8))
    acc = _codes_dot(c, w8, (((1,), (0,)), ((), ())))
    alpha_a = 1.0 / scale_a                               # (M, 1)
    beta_a = off_a * alpha_a + zero_a
    ab = jnp.asarray(alpha_b, jnp.float32)
    bb = jnp.asarray(beta_b, jnp.float32)
    a_i = (alpha_a * bb) * jnp.sum(c, axis=1, keepdims=True)
    return acc * (alpha_a * ab) + beta_a * u_vec[None, :] + a_i
