"""Pallas TPU kernel: fused dynamic-range + scale + stochastic-round quantize.

One pass over the gradient implements the paper's PTQ/PSQ quantization step
(Sec. 3.3 / 4.1): per-row min/max reduction, affine transform, stochastic
rounding against supplied uniform bits, and int8 code emission — avoiding
three separate HBM round-trips (range pass, transform pass, round pass),
which is exactly the quantization overhead the paper measures in Sec. 4.3.

Random bits are an *input* (uint32 per element, generated with
``jax.random.bits`` outside) so the kernel is bit-exact reproducible and
interpret-testable on CPU; on hardware the input can be swapped for
``pltpu.prng_random_bits`` without changing the contract.

Per-tensor mode reuses the same kernel after a cheap global min/max reduce
(the scalar range is broadcast per row).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiling import (check_bits, pad2d as _pad2, pad2d_edge as _pad2_edge,
                     round_up as _round_up)

__all__ = ["quantize_sr_rows", "quantize_sr_tensor"]

_EPS = 1e-12


def _kernel(x_ref, bits_ref, codes_ref, scale_ref, zero_ref, *, B: int):
    x = x_ref[...]                                   # (bm, Np) — full rows
    # padded columns are EDGE replicas (tiling.pad2d_edge), so this min/max
    # over the padded row equals the real row's — zero padding here would
    # silently widen every row's range (and its scale) whenever the row
    # does not straddle 0
    lo = jnp.min(x, axis=1, keepdims=True)
    hi = jnp.max(x, axis=1, keepdims=True)
    scale = B / jnp.maximum(hi - lo, _EPS)           # (bm, 1)
    t = scale * (x - lo)
    # SR(t) = floor(t + u), u ~ U[0,1) from the supplied bits
    u = bits_ref[...].astype(jnp.float32) * (1.0 / 4294967296.0)
    q = jnp.clip(jnp.floor(t + u), 0.0, B)
    codes_ref[...] = (q - (B + 1) // 2).astype(jnp.int8)   # shifted signed
    scale_ref[...] = scale
    zero_ref[...] = lo


def quantize_sr_rows(x: jax.Array, rbits: jax.Array, bits: int = 8,
                     bm: int = 256, interpret: bool = False):
    """Per-row (PSQ) fused quantize. x: (M, N) f32; rbits: (M, N) uint32.

    Returns (codes int8 shifted by -2^(b-1), scale (M,1), zero (M,1)):
        x ~= (codes + 2^(b-1)) / scale + zero

    Arbitrary (M, N) works: the input is edge-padded up to a block-multiple
    row count and a lane-multiple (128) column count — edge replicas repeat
    values each row already contains, so the per-row min/max (and hence
    every code) are what the unpadded oracle computes — and the outputs are
    sliced back.
    """
    check_bits("quantize_sr_rows", bits)
    return _quantize_sr_rows(x, rbits, bits=bits, bm=bm, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "interpret"))
def _quantize_sr_rows(x, rbits, *, bits, bm, interpret):
    M, N = x.shape
    B = (1 << bits) - 1
    Np = _round_up(N, 128)
    bm = min(bm, M)
    # full rows must fit VMEM: bm * Np * (4 + 4 + 1) bytes
    while bm > 1 and bm * Np * 9 > 8 * 2**20:
        bm //= 2
    Mp = _round_up(M, bm)
    xp = _pad2_edge(x, Mp, Np)
    rp = _pad2(rbits, Mp, Np)
    grid = (Mp // bm,)
    codes, scale, zero = pl.pallas_call(
        functools.partial(_kernel, B=B),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, Np), lambda i: (i, 0)),
                  pl.BlockSpec((bm, Np), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, Np), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((Mp, Np), jnp.int8),
                   jax.ShapeDtypeStruct((Mp, 1), jnp.float32),
                   jax.ShapeDtypeStruct((Mp, 1), jnp.float32)],
        interpret=interpret,
    )(xp, rp)
    return codes[:M, :N], scale[:M], zero[:M]


def _tensor_kernel(x_ref, bits_ref, lo_ref, hi_ref, codes_ref, *, B: int):
    x = x_ref[...]
    scale = B / jnp.maximum(hi_ref[0, 0] - lo_ref[0, 0], _EPS)
    t = scale * (x - lo_ref[0, 0])
    u = bits_ref[...].astype(jnp.float32) * (1.0 / 4294967296.0)
    q = jnp.clip(jnp.floor(t + u), 0.0, B)
    codes_ref[...] = (q - (B + 1) // 2).astype(jnp.int8)


def quantize_sr_tensor(x: jax.Array, rbits: jax.Array, bits: int = 8,
                       bm: int = 256, interpret: bool = False):
    """Per-tensor (PTQ) fused quantize. Returns (codes, scale (), zero ()).

    The global min/max reduce over the *unpadded* input, so the edge
    padding used to reach block-multiple row and lane-multiple column
    counts never widens the range.
    """
    check_bits("quantize_sr_tensor", bits)
    return _quantize_sr_tensor(x, rbits, bits=bits, bm=bm,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "interpret"))
def _quantize_sr_tensor(x, rbits, *, bits, bm, interpret):
    M, N = x.shape
    B = (1 << bits) - 1
    lo = jnp.min(x).reshape(1, 1)
    hi = jnp.max(x).reshape(1, 1)
    Np = _round_up(N, 128)
    bm = min(bm, M)
    while bm > 1 and bm * Np * 9 > 8 * 2**20:
        bm //= 2
    Mp = _round_up(M, bm)
    codes = pl.pallas_call(
        functools.partial(_tensor_kernel, B=B),
        grid=(Mp // bm,),
        in_specs=[pl.BlockSpec((bm, Np), lambda i: (i, 0)),
                  pl.BlockSpec((bm, Np), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bm, Np), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.int8),
        interpret=interpret,
    )(_pad2_edge(x, Mp, Np), _pad2(rbits, Mp, Np), lo, hi)
    return codes[:M, :N], B / jnp.maximum(hi[0, 0] - lo[0, 0], _EPS), lo[0, 0]
