"""Tile-shape autotuner with a persisted per-(kernel, shape, backend) cache.

The Pallas GEMM kernels (q8_matmul, fused_fqt) hard-coded one tile shape
per kernel; the right (bm, bn, bk) depends on the problem shape (how much
reuse a bigger bn/bk buys vs. the ~16 MB/core VMEM ceiling) and on the
platform.  This module owns three things:

  * the **VMEM accounting** for every kernel family (``tile_vmem_bytes`` /
    ``q8_tile_vmem_bytes``), used both to prune candidates and by the bench
    harness to report the per-tile budget;
  * the **candidate sweep** (:func:`tile_candidates`): MXU-aligned
    (bm, bn, bk) triples under the VMEM budget, and :func:`autotune`, which
    times them through an injectable timer and records the winner;
  * the **persisted cache**: a JSON file keyed
    ``<kernel>/<MxKxN>/<dtype>/<platform>`` at ``~/.cache/repro/tuning.json``
    (override with ``$REPRO_TUNING_CACHE``).  Kernel wrappers consult it at
    trace time via :func:`lookup_tiles`; a missing or corrupt file falls
    back to :data:`SHIPPED_DEFAULTS` (pre-tuned entries for the bench
    shapes) and then to the per-kernel default — never an error.

Re-tune on a new platform/shape with ``python -m benchmarks.bench_kernels
--tune`` (tile choice only changes performance on TPU, where the Pallas
kernels compile natively; elsewhere the sweep exercises the plumbing and
the XLA paths ignore the tiles).
"""

from __future__ import annotations

import json
import os
import tempfile
import warnings
from typing import Callable, Dict, Iterable, Optional, Tuple

import jax

__all__ = [
    "DEFAULT_TILES", "SHIPPED_DEFAULTS", "VMEM_BUDGET_BYTES",
    "KERNEL_SPECS", "validate_entry",
    "tile_vmem_bytes", "q8_tile_vmem_bytes", "tile_candidates",
    "shape_key", "cache_key", "cache_path", "TuningCache", "get_cache",
    "reset_cache", "lookup_tiles", "record_tiles", "autotune",
]

Tiles = Tuple[int, int, int]

DEFAULT_TILES: Tiles = (128, 512, 512)

# Leave ~4 MB of the ~16 MB/core for double-buffered pipelining.
VMEM_BUDGET_BYTES = 12 * 2 ** 20

ENV_CACHE = "REPRO_TUNING_CACHE"
_DEFAULT_CACHE_PATH = os.path.join("~", ".cache", "repro", "tuning.json")

# MXU/VPU-aligned sweep axes: bm over the sublane dim (int8 packs 32
# sublanes; f32 operands need 8), bn/bk over the 128-wide lane dim.
_BM_CANDIDATES = (32, 64, 128, 256, 512)
_LANE_CANDIDATES = (128, 256, 512, 1024)


# ---------------------------------------------------------------------------
# VMEM accounting (single source — pruning, bench reporting, docs)
# ---------------------------------------------------------------------------

def tile_vmem_bytes(bm: int, bn: int, bk: int, kind: str = "q8") -> int:
    """Resident VMEM bytes for one grid step of a kernel family.

    ``q8``           int8 A + int8 B + f32 out + int32 acc + epilogue vectors
    ``fused_lhs``    f32 A tile + uint32 SR bits + int8 B + out/acc + rowsum
                     scratch + epilogue vectors (quantize-on-the-fly LHS)
    ``fused_tn``     f32 A + f32 B + uint32 bits + out/acc + colsum scratch
                     (both operands quantized on the fly; dW kernel)
    ``packed``       int8 A + bit-packed B bytes (worst case int4: bk*bn/2)
                     + int32 unpack scratch (the shift/mask planes
                     materialize an int32 (bk, bn) tile in VMEM before the
                     int8 cast) + out/acc + row/colsum scratch + vectors
    ``fused_packed`` f32 A + packed B + int32 unpack scratch + out/acc +
                     row/colsum scratch (quantize LHS and unpack RHS in one
                     K-sweep; the forward megakernel over packed weights)
    """
    vecs = 4 * (2 * bm + 3 * bn)            # scale/zero rows + cs/u/b cols
    out_acc = 4 * bm * bn + 4 * bm * bn     # f32 out block + int32 acc
    # packed kinds: worst packable width is int4 -> bk*bn/2 packed bytes;
    # the in-VMEM unpack goes through an int32 (bk, bn) intermediate
    unpack = bk * bn // 2 + 4 * bk * bn
    if kind == "q8":
        return bm * bk + bk * bn + out_acc + vecs
    if kind == "fused_lhs":
        return (4 * bm * bk + 4 * bm * bk + bk * bn
                + out_acc + 4 * bm + vecs)
    if kind == "fused_tn":
        return (4 * bk * bm + 4 * bk * bn + 4 * bk * bn
                + out_acc + 4 * bn + vecs)
    if kind == "packed":
        return bm * bk + unpack + out_acc + 4 * bm + 4 * bn + vecs
    if kind == "fused_packed":
        return (4 * bm * bk + unpack + out_acc + 4 * bm + 4 * bn + vecs)
    raise ValueError(f"unknown kernel kind {kind!r}; expected one of "
                     f"('q8', 'fused_lhs', 'fused_tn', 'packed', "
                     f"'fused_packed')")


def q8_tile_vmem_bytes(bm: int, bn: int, bk: int, fused: bool = False) -> int:
    """The historical bench entry point (``kernel/q8_tile_vmem_bytes``)."""
    return tile_vmem_bytes(bm, bn, bk, "fused_lhs" if fused else "q8")


# What each registered kernel requires of a tile entry — the single source
# the cache loader and the static checker (analysis/kernels.py) validate
# against.  ``kind`` feeds :func:`tile_vmem_bytes`; ``multiples`` mirrors the
# ``check_tiles(..., interpret=False)`` alignment each wrapper enforces
# (q8_matmul.py / fused_fqt.py); ``kv_dequant`` is the bm-only row kernel
# (kind "rows": bn/bk must be 0, VMEM accounting lives in the wrapper).
KERNEL_SPECS: Dict[str, Dict[str, object]] = {
    "q8_matmul": {"kind": "q8", "multiples": (32, 128, 128)},
    "fused_fwd": {"kind": "fused_lhs", "multiples": (8, 128, 128)},
    "fused_dx": {"kind": "fused_lhs", "multiples": (8, 128, 128)},
    "fused_dw": {"kind": "fused_tn", "multiples": (128, 128, 8)},
    "kv_dequant": {"kind": "rows", "multiples": (8, 0, 0)},
    # paged-pool gather twin of kv_dequant (kernels/kv_gather.py): bm is the
    # rows-per-page-step block, clamped to a divisor of the page size at
    # trace time, so the same "rows" validation applies
    "kv_gather": {"kind": "rows", "multiples": (8, 0, 0)},
    # bit-packed weight family (kernels/q4_matmul.py + the packed variant in
    # kernels/fused_fqt.py); cache keys carry the code width as the dtype
    # segment (int4/int2/int1) since the packed byte layout changes with it
    "q4_matmul": {"kind": "packed", "multiples": (32, 128, 128)},
    "fused_packed": {"kind": "fused_packed", "multiples": (8, 128, 128)},
}


def validate_entry(kernel: str, tiles: Tiles,
                   budget: int = VMEM_BUDGET_BYTES):
    """Statically validate one (kernel, tiles) cache entry.

    Returns a list of problem strings (empty = legal), or ``None`` when the
    kernel is not in :data:`KERNEL_SPECS` (nothing to validate against —
    callers keep such entries and may flag them separately).
    """
    spec = KERNEL_SPECS.get(kernel)
    if spec is None:
        return None
    problems = []
    try:
        bm, bn, bk = (int(t) for t in tiles)
    except (TypeError, ValueError):
        return [f"tiles {tiles!r} are not an (bm, bn, bk) int triple"]
    mm, mn, mk = spec["multiples"]
    if spec["kind"] == "rows":
        if bm <= 0 or bm % mm:
            problems.append(f"bm={bm} must be a positive multiple of {mm}")
        if bn or bk:
            problems.append(f"bn/bk must be 0 for the row kernel, "
                            f"got ({bn}, {bk})")
        return problems
    for name, v, mult in (("bm", bm, mm), ("bn", bn, mn), ("bk", bk, mk)):
        if v <= 0:
            problems.append(f"{name}={v} must be positive")
        elif v % mult:
            problems.append(f"{name}={v} not a multiple of {mult} "
                            f"(MXU alignment, tiling.check_tiles)")
    if not problems:
        vmem = tile_vmem_bytes(bm, bn, bk, spec["kind"])
        if vmem > budget:
            problems.append(
                f"tile ({bm}, {bn}, {bk}) needs {vmem / 2**20:.1f} MiB "
                f"VMEM > budget {budget / 2**20:.1f} MiB "
                f"(kind {spec['kind']!r})")
    return problems


def tile_candidates(m: int, k: int, n: int, kind: str = "q8",
                    budget: int = VMEM_BUDGET_BYTES) -> Tuple[Tiles, ...]:
    """MXU-aligned (bm, bn, bk) triples under the VMEM budget, no larger
    than the (rounded-up) problem dims — the autotuner's sweep space."""
    from .tiling import round_up
    out = []
    for bm in _BM_CANDIDATES:
        if bm > round_up(m, 32):
            continue
        for bn in _LANE_CANDIDATES:
            if bn > round_up(n, 128):
                continue
            for bk in _LANE_CANDIDATES:
                if bk > round_up(k, 128):
                    continue
                if tile_vmem_bytes(bm, bn, bk, kind) <= budget:
                    out.append((bm, bn, bk))
    return tuple(out) or (DEFAULT_TILES,)


# ---------------------------------------------------------------------------
# The persisted cache
# ---------------------------------------------------------------------------

def shape_key(*dims) -> str:
    # string dims name shape-agnostic entries (e.g. kv_dequant's "rows")
    return "x".join(d if isinstance(d, str) else str(int(d)) for d in dims)


def cache_key(kernel: str, shape, dtype: str = "int8",
              platform: Optional[str] = None) -> str:
    if platform is None:
        platform = jax.default_backend()
    return f"{kernel}/{shape_key(*shape)}/{dtype}/{platform}"


def cache_path() -> str:
    return os.path.expanduser(os.environ.get(ENV_CACHE)
                              or _DEFAULT_CACHE_PATH)


# Pre-tuned winners for the bench shapes (keys are platform-agnostic — they
# apply when the persisted cache has no platform-specific entry).  Chosen by
# VMEM/arithmetic-intensity analysis for the TPU target: the largest
# lane-aligned bn*bk under the budget, bm sized so the int8 A tile keeps the
# MXU fed without starving double-buffering.
SHIPPED_DEFAULTS: Dict[str, Tiles] = {
    "q8_matmul/512x1024x1024": (256, 512, 1024),
    "q8_matmul/1024x4096x1024": (256, 512, 1024),
    "q8_matmul/4096x1024x4096": (256, 1024, 512),
    "fused_fwd/512x1024x1024": (128, 512, 512),
    "fused_fwd/1024x4096x1024": (128, 512, 512),
    "fused_fwd/4096x1024x4096": (128, 1024, 512),
    # dx/dw keys are the GEMM-logical (M, K, N) the wrappers look up —
    # for a model GEMM (m, k, n): dx contracts n -> (m, n, k); dw contracts
    # m -> (k, m, n)
    "fused_dx/512x1024x1024": (128, 512, 512),
    "fused_dx/1024x1024x4096": (128, 512, 512),
    "fused_dx/4096x4096x1024": (128, 1024, 512),
    "fused_dw/1024x512x1024": (128, 512, 256),
    "fused_dw/4096x1024x1024": (128, 512, 256),
    "fused_dw/1024x4096x4096": (128, 512, 256),
    "kv_dequant/rows": (256, 0, 0),
    "kv_gather/rows": (256, 0, 0),
    # packed-weight family: the int32 unpack intermediate (4*bk*bn) is the
    # dominant VMEM term, so bk stays at 512 where q8_matmul could afford
    # 1024
    "q4_matmul/512x1024x1024": (256, 512, 512),
    "q4_matmul/1024x4096x1024": (256, 512, 512),
    "q4_matmul/4096x1024x4096": (256, 512, 512),
    "fused_packed/512x1024x1024": (128, 512, 512),
    "fused_packed/1024x4096x1024": (128, 512, 512),
    "fused_packed/4096x1024x4096": (128, 512, 512),
}


def _entry_tiles(entry) -> Optional[Tiles]:
    """(bm, bn, bk) from a cache entry dict, or None when malformed."""
    if not isinstance(entry, dict):
        return None
    try:
        return (int(entry["bm"]), int(entry["bn"]), int(entry["bk"]))
    except (KeyError, TypeError, ValueError):
        return None


class TuningCache:
    """Lazy-loaded JSON tile cache; corrupt or unreadable files degrade to
    an empty cache with a one-time warning (never an exception).

    Individual entries are validated on load: a malformed entry (not a
    ``{"bm", "bn", "bk"}`` dict) or one whose tiles are illegal for a
    registered kernel (:func:`validate_entry` — misaligned, over the VMEM
    budget) is DROPPED with a warning, so a stale or hand-edited cache can
    never feed an un-lowerable tile into ``lookup_tiles``.  Entries for
    kernels not in :data:`KERNEL_SPECS` are kept as-is (forward compat;
    ``python -m repro.analysis kernels`` flags them)."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or cache_path()
        self._data: Optional[dict] = None

    def _load(self) -> dict:
        if self._data is not None:
            return self._data
        data: dict = {}
        if os.path.exists(self.path):
            try:
                with open(self.path) as f:
                    raw = json.load(f)
                if not isinstance(raw, dict):
                    raise ValueError(f"expected a JSON object, got "
                                     f"{type(raw).__name__}")
                data = self._validate(raw)
            except (ValueError, OSError) as e:
                warnings.warn(
                    f"ignoring corrupt tuning cache {self.path!r} ({e}); "
                    f"falling back to shipped defaults — re-tune with "
                    f"`python -m benchmarks.bench_kernels --tune`",
                    stacklevel=2)
        self._data = data
        return data

    def _validate(self, raw: dict) -> dict:
        data: dict = {}
        dropped = []
        for key, entry in raw.items():
            tiles = _entry_tiles(entry)
            if tiles is None:
                dropped.append(f"{key}: entry {entry!r} is not a "
                               f"{{bm, bn, bk}} dict")
                continue
            problems = validate_entry(str(key).split("/", 1)[0], tiles)
            if problems:          # None (unknown kernel) and [] both pass
                dropped.append(f"{key}: " + "; ".join(problems))
                continue
            data[key] = entry
        if dropped:
            listing = "\n  ".join(dropped)
            warnings.warn(
                f"dropped {len(dropped)} illegal entr"
                f"{'y' if len(dropped) == 1 else 'ies'} from tuning cache "
                f"{self.path!r}:\n  {listing}\nre-tune with "
                f"`python -m benchmarks.bench_kernels --tune`",
                stacklevel=3)
        return data

    def lookup(self, key: str) -> Optional[Tiles]:
        return _entry_tiles(self._load().get(key))

    def record(self, key: str, tiles: Tiles,
               us_per_call: Optional[float] = None) -> None:
        bm, bn, bk = tiles
        entry = {"bm": int(bm), "bn": int(bn), "bk": int(bk)}
        if us_per_call is not None:
            entry["us_per_call"] = float(us_per_call)
        self._load()[key] = entry

    def save(self) -> str:
        """Atomic write (tmp + rename) so a killed tune never corrupts."""
        data = self._load()
        d = os.path.dirname(self.path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(data, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return self.path


_CACHE: Optional[TuningCache] = None


def get_cache() -> TuningCache:
    global _CACHE
    if _CACHE is None or _CACHE.path != cache_path():
        # re-resolve when $REPRO_TUNING_CACHE changes (tests use tmpdirs)
        _CACHE = TuningCache()
    return _CACHE


def reset_cache() -> None:
    global _CACHE
    _CACHE = None


def lookup_tiles(kernel: str, shape, default: Tiles = DEFAULT_TILES,
                 dtype: str = "int8") -> Tiles:
    """Trace-time tile resolution: persisted cache (platform-specific wins
    over platform-agnostic ``any``) > shipped defaults > ``default``."""
    cache = get_cache()
    for platform in (jax.default_backend(), "any"):
        hit = cache.lookup(cache_key(kernel, shape, dtype, platform))
        if hit is not None:
            return hit
    return SHIPPED_DEFAULTS.get(f"{kernel}/{shape_key(*shape)}", default)


def record_tiles(kernel: str, shape, tiles: Tiles,
                 us_per_call: Optional[float] = None, dtype: str = "int8",
                 platform: Optional[str] = None, save: bool = True) -> str:
    cache = get_cache()
    key = cache_key(kernel, shape, dtype, platform)
    cache.record(key, tiles, us_per_call)
    if save:
        cache.save()
    return key


def autotune(kernel: str, shape, run_us: Callable[[Tiles], float], *,
             candidates: Optional[Iterable[Tiles]] = None,
             dtype: str = "int8", save: bool = True,
             log: Optional[Callable[[str], None]] = None) -> Tiles:
    """Sweep ``candidates`` through ``run_us`` (a timer returning µs/call),
    persist the winner, and return it.

    ``run_us`` is injectable so unit tests drive the sweep with a fake
    timer; the bench harness passes a real ``time_us`` closure.  A candidate
    that raises is skipped (bad tile configs surfaced by the sweep are the
    wrappers' job to reject with a clear ValueError).
    """
    if candidates is None:
        m, k, n = shape
        candidates = tile_candidates(m, k, n)
    best: Optional[Tiles] = None
    best_us = float("inf")
    for tiles in candidates:
        try:
            us = float(run_us(tiles))
        except Exception as e:  # noqa: BLE001 — sweep must survive bad tiles
            if log:
                log(f"  {kernel}{tiles}: skipped ({type(e).__name__}: {e})")
            continue
        if log:
            log(f"  {kernel}{tiles}: {us:.1f} us")
        if us < best_us:
            best, best_us = tiles, us
    if best is None:
        raise ValueError(
            f"autotune({kernel!r}, {tuple(shape)}): every candidate failed; "
            f"check the kernel wrapper's tile validation")
    record_tiles(kernel, shape, best, best_us, dtype=dtype, save=save)
    return best
