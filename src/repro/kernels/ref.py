"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["q8_matmul_ref", "quantize_sr_rows_ref", "quantize_sr_tensor_ref"]

_EPS = 1e-12


def q8_matmul_ref(x8, y8, rs, cs, r2, u, a, b):
    """out[i,j] = (x8 @ y8)[i,j] * rs_i * cs_j + r2_i * u_j + a_i + b_j."""
    acc = (x8.astype(jnp.int32) @ y8.astype(jnp.int32)).astype(jnp.float32)
    return (acc * rs[:, None] * cs[None, :]
            + r2[:, None] * u[None, :] + a[:, None] + b[None, :])


def _sr(t, rbits):
    u = rbits.astype(jnp.float32) * (1.0 / 4294967296.0)
    return jnp.floor(t + u)


def quantize_sr_rows_ref(x, rbits, bits=8):
    B = (1 << bits) - 1
    lo = jnp.min(x, axis=1, keepdims=True)
    hi = jnp.max(x, axis=1, keepdims=True)
    scale = B / jnp.maximum(hi - lo, _EPS)
    q = jnp.clip(_sr(scale * (x - lo), rbits), 0, B)
    codes = (q - (B + 1) // 2).astype(jnp.int8)
    return codes, scale, lo


def quantize_sr_tensor_ref(x, rbits, bits=8):
    B = (1 << bits) - 1
    lo, hi = jnp.min(x), jnp.max(x)
    scale = B / jnp.maximum(hi - lo, _EPS)
    q = jnp.clip(_sr(scale * (x - lo), rbits), 0, B)
    codes = (q - (B + 1) // 2).astype(jnp.int8)
    return codes, scale, lo


def dequant_rows_ref(codes, scale, zero, bits=8):
    off = (1 << bits) // 2
    return (codes.astype(jnp.float32) + off) / scale + zero
