"""Pallas TPU kernels for the paper's compute hot-spots.

  q8_matmul.py     int8 x int8 -> int32 GEMM + fused affine epilogue
  q4_matmul.py     int8 x bit-packed sub-byte GEMM (unpack in VMEM)
  pack.py          bit-plane pack/unpack + the PackedTensor container
  fused_fqt.py     quantize -> GEMM -> epilogue megakernels (no HBM codes)
  quantize_sr.py   fused dynamic-range + scale + stochastic-round quantize
  kv_dequant.py    fused affine dequantize of int8 KV-cache rows
  kv_gather.py     block-table page gather + dequantize (paged serving)
  autotune.py      tile-shape autotuner + persisted per-shape cache
  ops.py           wrappers wiring kernels to the quantizer algebra
  ref.py           pure-jnp oracles (the allclose targets)

NOTE: ``ops`` is intentionally NOT imported here — it depends on
``repro.core.backend`` (which imports the kernel modules below), so eager
import would cycle.  Use ``from repro.kernels.ops import ...``.
"""

from .autotune import (autotune, lookup_tiles, q8_tile_vmem_bytes,
                       record_tiles, tile_candidates)
from .fused_fqt import (fused_qboth_tn_matmul, fused_qboth_tn_matmul_xla,
                        fused_qlhs_matmul, fused_qlhs_matmul_xla,
                        fused_qlhs_packed_matmul,
                        fused_qlhs_packed_matmul_xla)
from .kv_dequant import kv_dequant_rows
from .kv_gather import kv_gather_pages, kv_gather_pages_xla
from .pack import (PackedTensor, codes_per_byte, max_safe_k_packed,
                   pack_codes, pack_qtensor, packed_nbytes, unpack_codes)
from .q4_matmul import packed_matmul, packed_matmul_xla
from .q8_matmul import q8_matmul
from .quantize_sr import quantize_sr_rows, quantize_sr_tensor

__all__ = [
    "q8_matmul", "quantize_sr_rows", "quantize_sr_tensor", "kv_dequant_rows",
    "kv_gather_pages", "kv_gather_pages_xla",
    "fused_qlhs_matmul", "fused_qlhs_matmul_xla", "fused_qboth_tn_matmul",
    "fused_qboth_tn_matmul_xla", "fused_qlhs_packed_matmul",
    "fused_qlhs_packed_matmul_xla", "autotune", "lookup_tiles",
    "record_tiles", "tile_candidates", "q8_tile_vmem_bytes",
    "PackedTensor", "codes_per_byte", "pack_codes", "unpack_codes",
    "pack_qtensor", "packed_nbytes", "max_safe_k_packed",
    "packed_matmul", "packed_matmul_xla",
]
