"""Pallas TPU kernels for the paper's compute hot-spots.

  q8_matmul.py     int8 x int8 -> int32 GEMM + fused affine epilogue
  quantize_sr.py   fused dynamic-range + scale + stochastic-round quantize
  ops.py           wrappers wiring kernels to the quantizer algebra
  ref.py           pure-jnp oracles (the allclose targets)

NOTE: ``ops`` is intentionally NOT imported here — it depends on
``repro.core.backend`` (which imports the kernel modules below), so eager
import would cycle.  Use ``from repro.kernels.ops import ...``.
"""

from .q8_matmul import q8_matmul
from .quantize_sr import quantize_sr_rows, quantize_sr_tensor

__all__ = ["q8_matmul", "quantize_sr_rows", "quantize_sr_tensor"]
