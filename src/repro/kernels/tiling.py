"""Shared pad-and-slice helpers for the Pallas kernel wrappers.

Grids require block-multiple dims; these helpers round shapes up and pad
operands so arbitrary (ragged) inputs work, with the wrapper slicing the
result back.  One home for the rule so a padding/alignment fix lands once.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["round_up", "pad2d", "pad_rows"]


def round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def pad2d(z: jax.Array, rows: int, cols: int) -> jax.Array:
    """Zero-pad a 2D array up to (rows, cols) (no-op when already there)."""
    r, c = z.shape
    if r == rows and c == cols:
        return z
    return jnp.pad(z, ((0, rows - r), (0, cols - c)))


def pad_rows(x: jax.Array, rows: int, edge: bool = False) -> jax.Array:
    """Pad leading dim to ``rows``; ``edge=True`` replicates the last real
    row (keeps per-row min/max finite for quantize kernels)."""
    if x.shape[0] == rows:
        return x
    mode = "edge" if edge else "constant"
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, 0)), mode=mode)
