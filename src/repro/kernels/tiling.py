"""Shared pad-and-slice helpers for the Pallas kernel wrappers.

Grids require block-multiple dims; these helpers round shapes up and pad
operands so arbitrary (ragged) inputs work, with the wrapper slicing the
result back.  One home for the rule so a padding/alignment fix lands once.

Padding modes and why they differ
---------------------------------
``pad2d`` zero-pads.  Correct for *integer code* operands of a GEMM: padded
codes contribute 0 to the accumulator and padded rows/cols are sliced off.

``pad2d_edge`` edge-replicates in BOTH dims.  Required for *float* operands
that a quantize kernel will reduce per row (min/max -> scale): a zero-padded
column silently widens every real row's dynamic range whenever the row does
not straddle 0 (an all-positive row gains a false min of 0), so the per-row
scale — and therefore every SR code in that row — changes.  Edge replicas
repeat values the row already contains, so per-row (and global) min/max are
invariant under the padding.  This is exactly the ragged-shape interaction
the tile autotuner surfaces: lane-aligned tile candidates force column
padding of inputs whose row length is not a multiple of 128, and the
quantize kernels must stay bit-identical to the unpadded oracle
(tests/test_fused.py::test_pad_edge_preserves_row_ranges).

``pad_rows(edge=True)`` is the row-only special case (kept for the per-row
kernels whose block spans full rows).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["round_up", "pad2d", "pad2d_edge", "pad_rows", "check_tiles",
           "check_bits"]


def round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def pad2d(z: jax.Array, rows: int, cols: int) -> jax.Array:
    """Zero-pad a 2D array up to (rows, cols) (no-op when already there)."""
    r, c = z.shape
    if r == rows and c == cols:
        return z
    return jnp.pad(z, ((0, rows - r), (0, cols - c)))


def pad2d_edge(z: jax.Array, rows: int, cols: int) -> jax.Array:
    """Edge-replicate a 2D array up to (rows, cols).

    Range-inert padding for float operands of the quantize kernels: padded
    entries replicate the last real row/column, so per-row and per-tensor
    min/max computed over the padded array equal those of the real data.
    """
    r, c = z.shape
    if r == rows and c == cols:
        return z
    if r == 0 or c == 0:
        raise ValueError(
            f"cannot edge-pad an empty array of shape {z.shape} up to "
            f"({rows}, {cols}); quantize kernels need at least one real "
            f"row and column to replicate")
    return jnp.pad(z, ((0, rows - r), (0, cols - c)), mode="edge")


def pad_rows(x: jax.Array, rows: int, edge: bool = False) -> jax.Array:
    """Pad leading dim to ``rows``; ``edge=True`` replicates the last real
    row (keeps per-row min/max finite for quantize kernels)."""
    if x.shape[0] == rows:
        return x
    if edge and x.shape[0] == 0:
        raise ValueError(
            f"cannot edge-pad an empty array of shape {x.shape} up to "
            f"{rows} rows; there is no real row to replicate")
    mode = "edge" if edge else "constant"
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, 0)), mode=mode)


def check_tiles(kernel: str, shape, tiles, *, interpret: bool,
                multiples=(32, 128, 128)) -> None:
    """Up-front tile validation for the GEMM kernel wrappers.

    Rejects non-positive / non-integer tile dims always, and (on real TPU
    lowering, i.e. ``interpret=False``) tiles that are not MXU-aligned.
    ``multiples`` gives the required (bm, bn, bk) alignment per kernel
    family — the sublane count of the dim that lands on a tile's second-
    minor axis and 128 for every lane-dim axis (int8 A tiles need bm%32,
    f32 A tiles bm%8; the transposed-A dW kernel instead needs bm%128 and
    only bk%8).  A bad tile surfaced by the autotuner sweep fails here with
    the shape and tile in the message instead of deep inside Mosaic
    lowering.
    """
    bm, bn, bk = tiles
    sh = "x".join(str(int(d)) for d in shape)
    for name, v in (("bm", bm), ("bn", bn), ("bk", bk)):
        if not isinstance(v, int) or isinstance(v, bool) or v <= 0:
            raise ValueError(
                f"{kernel}: tile {name}={v!r} must be a positive int "
                f"(shape {sh}, tile ({bm}, {bn}, {bk}))")
    mm, mn, mk = multiples
    if not interpret and (bm % mm or bn % mn or bk % mk):
        raise ValueError(
            f"{kernel}: tile ({bm}, {bn}, {bk}) is not MXU-aligned for "
            f"shape {sh}: needs bm % {mm} == 0, bn % {mn} == 0, "
            f"bk % {mk} == 0; pass interpret=True to lift the alignment "
            f"requirement (CPU debugging only)")


def check_bits(kernel: str, bits, lo: int = 2) -> int:
    """Validate a quantization bitwidth: an int in [lo, 8].

    The in-kernel quantizers need at least 2 bits (a 1-bit SR grid has a
    single bin boundary the round/clip algebra degenerates on), so ``lo``
    defaults to 2; the bit-packed weight kernels consume *pre-quantized*
    codes and pass ``lo=1`` to admit binary sign planes.
    """
    if not isinstance(bits, int) or isinstance(bits, bool) or \
            not lo <= bits <= 8:
        raise ValueError(
            f"{kernel}: bits={bits!r} out of range; the int8 kernels "
            f"support bitwidths {lo}..8")
    return bits
