"""Bit-plane packing for sub-byte quantized tensors.

The registry's sub-8-bit specs (``QuantizerSpec.bits`` = 4/2/1) historically
materialized their codes in full uint8 lanes, so lower bitwidths bought zero
memory or bandwidth.  This module is the storage half of the ultra-low-bit
track: unsigned codes are packed along the *contraction* axis (axis ``-2`` of
a ``(K, N)`` weight) into dense uint8 bytes —

  * int4: 2 codes/byte   (``ppb = 2``)
  * int2: 4 codes/byte   (``ppb = 4``)
  * 1-bit: 8 codes/byte  (sign planes, ``ppb = 8``)

Byte row ``r`` of the packed array holds logical rows ``r*ppb .. r*ppb+ppb-1``;
logical row ``k`` lives in byte ``k // ppb`` at bitfield ``bits * (k % ppb)``
(little-endian within the byte).  The lane (column) axis is untouched, so the
TPU-friendly 128-lane alignment of the unpacked operand carries over to the
packed one.

Ragged shapes follow the repo-wide pad-and-slice convention: ``pack_codes``
zero-pads K up to a multiple of ``ppb`` and ``unpack_codes`` slices back.
Padding rows unpack to code 0 — which is *not* the zero point of the shifted
signed layout — so GEMM consumers must mask ``row >= kdim`` (the Pallas
kernels do, exactly like the fused-quantize kernels mask padded K columns).

``unpack_tile`` is the in-kernel primitive: it is pure ``jnp`` shift/mask
arithmetic on a VMEM-resident tile, so the packed GEMM kernels
(kernels/q4_matmul.py, the packed variant in kernels/fused_fqt.py) unpack
inside the K-sweep and the weight operand stays packed in HBM.

:class:`PackedTensor` mirrors :class:`~repro.core.quantizers.QTensor`'s
attribute surface (``int8_codes`` / ``scale`` / ``zero`` / ``bits`` /
``shape`` / ``dequant``), so backend code written against QTensor duck-types
over packed weights; only the GEMM dispatch itself special-cases packing.
This module imports nothing from ``repro.core`` — it sits below the backend
in the layer order.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = [
    "PACK_WIDTHS",
    "PackedTensor",
    "codes_per_byte",
    "pack_codes",
    "unpack_codes",
    "unpack_tile",
    "pack_qtensor",
    "packed_nbytes",
    "max_safe_k_packed",
]

# Bitwidths with a whole number of codes per byte.  bits == 8 degenerates to
# the identity packing (1 code/byte) and is accepted for uniformity.
PACK_WIDTHS = (1, 2, 4, 8)


def codes_per_byte(bits: int) -> int:
    """Codes packed per storage byte (8 // bits); validates ``bits``."""
    if bits not in PACK_WIDTHS:
        raise ValueError(f"bits={bits} is not packable; a byte holds a whole "
                         f"number of codes only for bits in {PACK_WIDTHS}")
    return 8 // bits


def _check_2d_plus(name: str, x: jax.Array) -> None:
    if x.ndim < 2:
        raise ValueError(f"{name} must have ndim >= 2 (pack axis is -2), "
                         f"got shape {x.shape}")


def pack_codes(codes: jax.Array, bits: int) -> jax.Array:
    """Pack unsigned codes ``(..., K, N)`` uint8 -> ``(..., ceil(K/ppb), N)``.

    Codes must already be in ``[0, 2^bits - 1]`` (the canonical unsigned
    QTensor layout); out-of-range bits would silently corrupt neighbouring
    fields, so callers quantize first.  K is zero-padded up to a multiple of
    ``ppb`` — the pad rows carry code 0 and are sliced away by
    :func:`unpack_codes` / masked by the packed GEMM kernels.
    """
    ppb = codes_per_byte(bits)
    _check_2d_plus("codes", codes)
    k, n = codes.shape[-2], codes.shape[-1]
    kp = -(-k // ppb) * ppb
    c = codes.astype(jnp.uint8)
    if kp != k:
        pad = [(0, 0)] * (codes.ndim - 2) + [(0, kp - k), (0, 0)]
        c = jnp.pad(c, pad)
    c = c.reshape(*codes.shape[:-2], kp // ppb, ppb, n).astype(jnp.uint32)
    out = jnp.zeros(c.shape[:-2] + (n,), jnp.uint32)
    for i in range(ppb):
        out = out | (c[..., i, :] << (bits * i))
    return out.astype(jnp.uint8)


def unpack_codes(packed: jax.Array, bits: int, kdim: int) -> jax.Array:
    """Inverse of :func:`pack_codes`: ``(..., KP, N)`` -> ``(..., kdim, N)``.

    ``kdim`` is the logical row count; rows ``kdim .. KP*ppb`` are padding
    and are sliced off.
    """
    ppb = codes_per_byte(bits)
    _check_2d_plus("packed", packed)
    kp_bytes, n = packed.shape[-2], packed.shape[-1]
    if not 0 < kdim <= kp_bytes * ppb:
        raise ValueError(f"kdim={kdim} incompatible with packed rows "
                         f"{kp_bytes} at {ppb} codes/byte")
    tile = unpack_tile(packed, bits)
    return tile[..., :kdim, :].astype(jnp.uint8)


def unpack_tile(packed: jax.Array, bits: int) -> jax.Array:
    """In-kernel unpack: ``(..., R, N)`` uint8 -> ``(..., R*ppb, N)`` int32.

    Pure shift/mask/reshape ``jnp`` arithmetic — safe inside a Pallas kernel
    body on a VMEM tile (the row interleave is a sublane shuffle; the lane
    axis is untouched).  Returns *unshifted* unsigned code values as int32;
    callers subtract the signed offset and apply their own K masking.
    """
    ppb = codes_per_byte(bits)
    mask = (1 << bits) - 1
    v = packed.astype(jnp.int32)
    planes = [(v >> (bits * i)) & mask for i in range(ppb)]
    if ppb == 1:
        return planes[0]
    st = jnp.stack(planes, axis=-2)                  # (..., R, ppb, N)
    return st.reshape(*packed.shape[:-2], packed.shape[-2] * ppb,
                      packed.shape[-1])


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedTensor:
    """Bit-packed affine-quantized tensor ``x ~= codes / scale + zero``.

    The packed counterpart of :class:`~repro.core.quantizers.QTensor` for
    weight operands: ``packed`` stores ``ppb = 8 // bits`` unsigned codes per
    byte along the contraction axis.  ``kdim`` (static) is the logical K so
    the trailing logical shape ``(kdim, N)`` survives pytree slicing — a
    stacked per-layer weight ``(L, K, N)`` packs to leaves with a leading
    ``L`` axis, and ``lax.scan`` slices those leaves while the static fields
    stay per-layer-correct.  ``scale``/``zero`` must broadcast against the
    unpacked codes (scalars per tensor; ``(L, 1, 1)`` when stacked).
    """

    packed: jax.Array         # (..., ceil(kdim/ppb), N) uint8
    scale: jax.Array          # S     — x ~= codes / S + Z
    zero: jax.Array           # Z
    bits: int = dataclasses.field(metadata=dict(static=True))
    kdim: int = dataclasses.field(metadata=dict(static=True))

    @property
    def shape(self) -> tuple:
        """Logical (unpacked) shape ``(..., kdim, N)``."""
        return tuple(self.packed.shape[:-2]) + (self.kdim,
                                                self.packed.shape[-1])

    @property
    def ndim(self) -> int:
        return self.packed.ndim

    @property
    def codes(self) -> jax.Array:
        """Unpacked unsigned codes in the canonical QTensor layout."""
        return unpack_codes(self.packed, self.bits, self.kdim)

    @property
    def int8_codes(self) -> jax.Array:
        """Unpacked codes shifted to signed int8 (``code - 2^(b-1)``)."""
        off = 1 << (self.bits - 1)
        tile = unpack_tile(self.packed, self.bits)[..., :self.kdim, :]
        return (tile - off).astype(jnp.int8)

    @property
    def int8_offset(self) -> int:
        return 1 << (self.bits - 1)

    def dequant(self) -> jax.Array:
        return self.codes.astype(jnp.float32) / self.scale + self.zero

    @property
    def nbytes(self) -> int:
        """Resident bytes of the packed representation (codes + affine)."""
        return int(self.packed.size * self.packed.dtype.itemsize
                   + self.scale.size * self.scale.dtype.itemsize
                   + self.zero.size * self.zero.dtype.itemsize)


def pack_qtensor(qt) -> PackedTensor:
    """Pack any QTensor-shaped object (codes/scale/zero/bits/shape) whose
    logical shape has ndim >= 2.  Codes are reshaped to the logical shape
    first — QTensor stores flattened-row codes for per-sample quantizers."""
    shape = tuple(qt.shape)
    if len(shape) < 2:
        raise ValueError(f"cannot pack a rank-{len(shape)} tensor; the pack "
                         f"axis is the contraction axis of a (K, N) operand")
    codes = qt.codes.reshape(shape)
    return PackedTensor(packed=pack_codes(codes, qt.bits),
                        scale=jnp.asarray(qt.scale), zero=jnp.asarray(qt.zero),
                        bits=qt.bits, kdim=shape[-2])


def packed_nbytes(shape, bits: int) -> int:
    """Code bytes for a logical ``shape`` packed at ``bits`` (no affine)."""
    ppb = codes_per_byte(bits)
    k, n = shape[-2], shape[-1]
    lead = 1
    for d in shape[:-2]:
        lead *= int(d)
    return lead * (-(-int(k) // ppb)) * int(n)


def max_safe_k_packed(lhs_bits: int, rhs_bits: int) -> int:
    """Largest contraction K with no int32 overflow for shifted-signed codes.

    Same bound as :func:`repro.analysis.ranges.max_safe_k` (kept local so the
    kernel layer does not import the analysis package; a tier-1 test pins the
    two to agree): worst-case per-element product is
    ``2^(a-1) * 2^(b-1)``, so ``K_max = (2^31 - 1) // that``.
    """
    if not (1 <= lhs_bits <= 32 and 1 <= rhs_bits <= 32):
        raise ValueError(f"bits out of range: {lhs_bits}, {rhs_bits}")
    prod = (1 << (lhs_bits - 1)) * (1 << (rhs_bits - 1))
    return (2**31 - 1) // prod
