"""Pallas TPU kernel: int8 x bit-packed sub-byte -> int32 GEMM + epilogue.

The packed execution path of the ultra-low-bit track: the weight operand
stays bit-packed in HBM (``kernels/pack.py`` layout — ``ppb = 8 // bits``
codes per byte along the contraction axis) and each (bk/ppb x bn) packed
tile is unpacked *in VMEM* inside the K-sweep into shifted-signed int8
lanes for the MXU.  At 4-bit this halves the weight bytes streamed per
GEMM versus int8 codes (4x at 2-bit, 8x at 1-bit) on top of the 4x/8x
resident-memory win the serve engine takes by packing weights once at
load.

Everything else deliberately mirrors ``q8_matmul`` term for term — same
epilogue form, same precomputed (rs, cs, r2, u, a, b) coefficient vectors
from ``core/backend.epilogue_coeffs`` — so the packed kernel and its XLA
twin are *bit-exact* against the unpack-then-``q8_matmul`` oracle: the
only difference in the compiled graph is the integer unpack feeding the
MXU operand, and integer arithmetic is exact.  (An earlier variant
accumulated the epilogue col/row sums in-kernel; the expression values
were identical but XLA's FMA placement differed between the two graph
shapes, costing ~1 ulp — structural identity is what buys bit-exactness.)
The coefficient vectors need ``colsum`` of the unpacked codes; the wrapper
computes it as a fused unpack+reduce over the packed bytes (O(K*N) shifts,
no unpacked tensor materialized in HBM).

The twin's f32 code GEMM is exact while per-element products * K stay
under 2^24 — at 4-bit weights that is K <= 2^14, far above every shipped
shape (see fused_fqt.py).

Padding: packed rows beyond the logical K unpack to code 0, which is *not*
the shifted zero code, so the kernel masks ``row < kdim`` exactly like the
fused-quantize kernels mask padded K columns.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .autotune import lookup_tiles
from .fused_fqt import _codes_dot, _opt_barrier
from .pack import codes_per_byte, max_safe_k_packed, unpack_tile
from .tiling import (check_bits, check_tiles, pad2d as _pad2,
                     round_up as _round_up)

__all__ = ["packed_matmul", "packed_matmul_xla"]


def _check_packed_gemm(name: str, x8, packed, wbits: int, kdim: int) -> int:
    """Shared shape/range validation; returns codes-per-byte."""
    ppb = codes_per_byte(wbits)
    check_bits(name, wbits, lo=1)
    if x8.shape[1] != kdim:
        raise ValueError(f"{name}: x8 {x8.shape} does not match kdim={kdim}")
    if packed.shape[0] != -(-kdim // ppb):
        raise ValueError(
            f"{name}: packed rows {packed.shape[0]} != ceil({kdim}/{ppb}) "
            f"for {wbits}-bit codes")
    safe = max_safe_k_packed(8, wbits)
    if kdim > safe:
        raise ValueError(
            f"{name}: K={kdim} overflows the int32 accumulator for "
            f"int8 x int{wbits} codes (max_safe_k={safe})")
    return ppb


def _kernel(x_ref, p_ref, rs_ref, cs_ref, r2_ref, u_ref, a_ref, b_ref,
            o_ref, acc_ref, *, nk: int, kdim: int, bits: int, bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # unpack this packed weight tile in VMEM: (bk/ppb, bn) bytes -> (bk, bn)
    # unsigned codes -> shifted signed int8, padded K rows masked to 0
    off = 1 << (bits - 1)
    w = unpack_tile(p_ref[...], bits) - off
    row = pl.program_id(2) * bk + jax.lax.broadcasted_iota(
        jnp.int32, w.shape, 0)
    w8 = jnp.where(row < kdim, w, 0).astype(jnp.int8)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w8, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        o_ref[...] = (acc * (rs_ref[...] * cs_ref[...])
                      + r2_ref[...] * u_ref[...]
                      + a_ref[...] + b_ref[...])


def packed_matmul(x8: jax.Array, packed: jax.Array, rs: jax.Array,
                  cs: jax.Array, r2: jax.Array, u: jax.Array, a: jax.Array,
                  b: jax.Array, *, wbits: int, kdim: int,
                  bm: Optional[int] = None, bn: Optional[int] = None,
                  bk: Optional[int] = None,
                  interpret: bool = False) -> jax.Array:
    """``q8_matmul`` with the RHS bit-packed: x8 (M, K) shifted int8 codes;
    packed (ceil(K/ppb), N) uint8 at ``wbits`` codes/byte; rs/r2/a: (M,);
    cs/u/b: (N,) — the standard epilogue coefficient vectors of
    ``core/backend.epilogue_coeffs`` (u's colsum runs over the *unpacked*
    codes).  Returns (M, N) f32.  Tiles default to the autotuner cache under
    ``q4_matmul`` keyed by the logical (M, K, N) and an ``int{wbits}``
    dtype tag.
    """
    ppb = _check_packed_gemm("packed_matmul", x8, packed, wbits, kdim)
    del ppb
    M, K = x8.shape
    N = packed.shape[1]
    tm, tn, tk = lookup_tiles("q4_matmul", (M, K, N), dtype=f"int{wbits}")
    bm, bn, bk = (tm if bm is None else bm, tn if bn is None else bn,
                  tk if bk is None else bk)
    bm = min(bm, _round_up(M, 32))       # int8 sublane tile is 32
    bn = min(bn, _round_up(N, 128))
    bk = min(bk, _round_up(K, 128))      # ppb | 128, so ppb | bk
    check_tiles("q4_matmul", (M, K, N), (bm, bn, bk), interpret=interpret,
                multiples=(32, 128, 128))
    return _packed_matmul(x8, packed, rs, cs, r2, u, a, b, wbits=wbits,
                          bm=bm, bn=bn, bk=bk, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("wbits", "bm", "bn", "bk", "interpret"))
def _packed_matmul(x8, packed, rs, cs, r2, u, a, b, *, wbits, bm, bn, bk,
                   interpret):
    ppb = codes_per_byte(wbits)
    M, K = x8.shape
    N = packed.shape[1]
    Mp, Np, Kp = _round_up(M, bm), _round_up(N, bn), _round_up(K, bk)
    nk = Kp // bk

    row = lambda i, j, k: (i, 0)
    col = lambda i, j, k: (0, j)
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, kdim=K, bits=wbits, bk=bk),
        grid=(Mp // bm, Np // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // ppb, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, 1), row), pl.BlockSpec((1, bn), col),
            pl.BlockSpec((bm, 1), row), pl.BlockSpec((1, bn), col),
            pl.BlockSpec((bm, 1), row), pl.BlockSpec((1, bn), col),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(_pad2(x8, Mp, Kp), _pad2(packed, Kp // ppb, Np),
      _pad2(rs.reshape(M, 1), Mp, 1), _pad2(cs.reshape(1, N), 1, Np),
      _pad2(r2.reshape(M, 1), Mp, 1), _pad2(u.reshape(1, N), 1, Np),
      _pad2(a.reshape(M, 1), Mp, 1), _pad2(b.reshape(1, N), 1, Np))
    return out[:M, :N]


def packed_matmul_xla(x8: jax.Array, packed: jax.Array, rs: jax.Array,
                      cs: jax.Array, r2: jax.Array, u: jax.Array,
                      a: jax.Array, b: jax.Array, *, wbits: int,
                      kdim: int) -> jax.Array:
    """XLA twin of :func:`packed_matmul` — the ``native``-backend packed
    path and the CPU test oracle.  Unpacks in-graph (XLA fuses the shift/
    mask chain into the GEMM operand read), identical epilogue expression
    tree, platform-adaptive accumulation via ``_codes_dot``.  Jitted
    internally (like ``_q8_matmul``) so the epilogue compiles as one fused
    expression — eager per-op dispatch forbids the FMA contraction the
    compiled oracle performs and costs the 1-ulp bit-exactness."""
    _check_packed_gemm("packed_matmul_xla", x8, packed, wbits, kdim)
    return _packed_matmul_xla(x8, packed, rs, cs, r2, u, a, b, wbits=wbits,
                              kdim=kdim)


@functools.partial(jax.jit, static_argnames=("wbits", "kdim"))
def _packed_matmul_xla(x8, packed, rs, cs, r2, u, a, b, *, wbits, kdim):
    M = x8.shape[0]
    N = packed.shape[1]
    off = 1 << (wbits - 1)
    w8 = (unpack_tile(packed, wbits)[:kdim, :] - off).astype(jnp.int8)
    w8 = _opt_barrier(w8)          # one materialization of the unpack chain
    acc = _codes_dot(x8, w8, (((1,), (0,)), ((), ())))
    # keep the epilogue a separate fusion from the GEMM — mirrors the tile-
    # computation boundary of the Pallas kernel, where the accumulator is
    # materialized in VMEM before the epilogue reads it
    acc = _opt_barrier(acc)
    return (acc * (rs.reshape(M, 1) * cs.reshape(1, N))
            + r2.reshape(M, 1) * u.reshape(1, N)
            + a.reshape(M, 1) + b.reshape(1, N))
