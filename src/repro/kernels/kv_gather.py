"""Pallas TPU kernel: block-table page gather + fused affine dequantize.

The paged serving engine (serve/paged.py) stores the int8 KV cache as
fixed-size pages in one shared pool; a request's logically-contiguous cache
is physically scattered wherever the allocator found free pages.  The decode
read therefore becomes a *gather*: walk the request's block table, pull each
page out of the pool, and widen the int8 codes back to float — and just like
the dense-slot read (kv_dequant.py), doing the widen as a separate pass
would re-materialize an f32 code tensor the size of the gathered cache.

This kernel fuses both: the block table rides the scalar-prefetch channel
(``pltpu.PrefetchScalarGridSpec``), so each grid step's *input DMA itself*
is table-driven — the codes BlockSpec's index map reads ``table[b, j]`` and
streams that physical page from HBM straight into VMEM, where the affine
rescale runs before the single output write.  No gathered-codes
intermediate ever exists in HBM.

Same codec contract as core/kv_cache.py: shifted-signed codes
(``c8 = code - 2^(b-1)``), per-row ``scale``/``zero`` with
``x ~= (c8 + 2^(b-1)) / scale + zero``, scales clamped away from zero so a
degenerate (freshly allocated, all-zero) page can never emit inf/nan, and
``interpret=True`` emulation for CPU tests.  ``kv_gather_pages_xla`` is the
exact XLA twin the simulate/native backends run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .autotune import lookup_tiles
from .tiling import check_bits, round_up as _round_up

__all__ = ["kv_gather_pages", "kv_gather_pages_xla"]

_EPS = 1e-12


def _kernel(tab_ref, codes_ref, scale_ref, zero_ref, out_ref, *, off: int):
    del tab_ref          # consumed by the index maps, not the body
    c = codes_ref[...].astype(jnp.float32) + off          # back to unsigned
    out_ref[...] = c / scale_ref[...] + zero_ref[...]     # (1,bm,Dp)/(1,bm,1)


def _row_block(P: int, bm: int) -> int:
    """Largest divisor of the page size <= the tuned row block (the grid
    must step through whole pages; a tile that straddles two pages would
    need two table lookups in one index map)."""
    bm = max(1, min(bm, P))
    while P % bm:
        bm -= 1
    return bm


def kv_gather_pages(codes: jax.Array, scale: jax.Array, zero: jax.Array,
                    table: jax.Array, bits: int = 8, bm: int = None,
                    interpret: bool = False) -> jax.Array:
    """Gather + dequantize paged int8 KV rows through a block table.

    codes: (n_pages, P, D) int8 shifted by ``-2^(b-1)``; scale/zero:
    (n_pages, P) f32; table: (B, nb) int32 physical page ids (logical block
    order).  Returns (B, nb*P, D) f32 — each request's cache, contiguous
    again.

    ``bm`` (rows per grid step, autotuner key ``kv_gather/rows``) is clamped
    to a divisor of the page size; on real TPUs page sizes should be
    multiples of 8 so the f32 sublane tiling holds.  Column dim is
    zero-padded to the 128 lane width and sliced back.
    """
    check_bits("kv_gather_pages", bits)
    if bm is None:
        bm = lookup_tiles("kv_gather", ("rows",), default=(256, 0, 0))[0]
    return _kv_gather_pages(codes, scale, zero, table, bits=bits, bm=bm,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "interpret"))
def _kv_gather_pages(codes, scale, zero, table, *, bits, bm, interpret):
    n_pages, P, D = codes.shape
    B, nb = table.shape
    Dp = _round_up(D, 128)
    bm = _row_block(P, bm)
    steps = P // bm
    if Dp != D:
        codes = jnp.pad(codes, ((0, 0), (0, 0), (0, Dp - D)))
    scale3 = jnp.maximum(scale, _EPS).reshape(n_pages, P, 1)
    zero3 = zero.reshape(n_pages, P, 1)

    def page(b, j, r, tab):
        return (tab[b, j], r, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nb, steps),
        in_specs=[pl.BlockSpec((1, bm, Dp), page),
                  pl.BlockSpec((1, bm, 1), page),
                  pl.BlockSpec((1, bm, 1), page)],
        out_specs=pl.BlockSpec(
            (1, bm, Dp), lambda b, j, r, tab: (b, j * steps + r, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, off=1 << (bits - 1)),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nb * P, Dp), jnp.float32),
        interpret=interpret,
    )(table.astype(jnp.int32), codes, scale3, zero3)
    return out[:, :, :D]


def kv_gather_pages_xla(codes: jax.Array, scale: jax.Array, zero: jax.Array,
                        table: jax.Array, bits: int = 8) -> jax.Array:
    """Pure-XLA twin of :func:`kv_gather_pages` (simulate/native backends,
    and the allclose oracle for the kernel tests)."""
    check_bits("kv_gather_pages_xla", bits)
    off = 1 << (bits - 1)
    g = codes[table]                                      # (B, nb, P, D)
    s = jnp.maximum(scale, _EPS)[table][..., None]
    z = zero[table][..., None]
    out = (g.astype(jnp.float32) + off) / s + z
    B, nb, P, D = out.shape
    return out.reshape(B, nb * P, D)
