"""Pallas TPU kernel: fused affine dequantization of int8 KV-cache rows.

The serving decode step reads the *whole* resident KV cache every token; with
the int8 cache (core/kv_cache.py) that read is ¼ the HBM traffic of fp32,
but the codes must be widened back to float before the attention math.  This
kernel fuses the widen + affine rescale into the single pass that streams the
codes out of HBM — one read of (codes, scale, zero), one write of the float
rows, no intermediate f32 code tensor.

Same contract style as ``quantize_sr_*``: shifted-signed int8 codes
(``c8 = code - 2^(b-1)``), per-row ``scale``/``zero`` with
``x ~= (c8 + 2^(b-1)) / scale + zero``, and ``interpret=True`` emulation for
CPU tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .autotune import lookup_tiles
from .tiling import (check_bits, pad2d as _pad2, pad_rows as _pad_rows,
                     round_up as _round_up)

__all__ = ["kv_dequant_rows"]


def _kernel(codes_ref, scale_ref, zero_ref, out_ref, *, off: int):
    c = codes_ref[...].astype(jnp.float32) + off          # back to unsigned
    out_ref[...] = c / scale_ref[...] + zero_ref[...]     # (bm, Np) / (bm, 1)


def kv_dequant_rows(codes8: jax.Array, scale: jax.Array, zero: jax.Array,
                    bits: int = 8, bm: int = None,
                    interpret: bool = False) -> jax.Array:
    """Dequantize per-row affine int8 codes. codes8: (M, N) int8 shifted by
    ``-2^(b-1)``; scale/zero: (M, 1) f32.  Returns (M, N) f32.

    Arbitrary (M, N) works: rows are edge-padded to a block multiple (edge
    padding keeps the padded scales finite), columns zero-padded to a lane
    multiple (dequantized garbage is sliced off), output sliced back.
    ``bm`` defaults to the autotuner cache's shape-agnostic ``rows`` entry.
    """
    check_bits("kv_dequant_rows", bits)
    if bm is None:
        bm = lookup_tiles("kv_dequant", ("rows",), default=(256, 0, 0))[0]
    return _kv_dequant_rows(codes8, scale, zero, bits=bits, bm=bm,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "interpret"))
def _kv_dequant_rows(codes8, scale, zero, *, bits, bm, interpret):
    M, N = codes8.shape
    Np = _round_up(N, 128)
    bm = min(bm, M)
    # block must fit VMEM: bm * Np * (1 + 4 + 4 + 4) bytes
    while bm > 1 and bm * Np * 13 > 8 * 2**20:
        bm //= 2
    Mp = _round_up(M, bm)
    out = pl.pallas_call(
        functools.partial(_kernel, off=1 << (bits - 1)),
        grid=(Mp // bm,),
        in_specs=[pl.BlockSpec((bm, Np), lambda i: (i, 0)),
                  pl.BlockSpec((bm, 1), lambda i: (i, 0)),
                  pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, Np), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        interpret=interpret,
    )(_pad2(codes8, Mp, Np),
      _pad_rows(scale.reshape(M, 1), Mp, edge=True),
      _pad_rows(zero.reshape(M, 1), Mp, edge=True))
    return out[:M, :N]
