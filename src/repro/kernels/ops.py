"""jit'd wrappers wiring the Pallas kernels to the paper's quantizer algebra.

``fqt_linear_fwd_kernel`` computes the forward ``Q_f(X) @ Q_theta(W)`` with
one fused int8 GEMM.  Given affine quantizations

    X^ = (Cx + ox)/sx + zx      (per-row scale sx_i, zero zx_i; ox = 2^(b-1))
    W^ = (Cw + ow)/sw + zw      (per-tensor)

the exact product expands into the kernel's epilogue form
out = acc*rs_i*cs_j + rs_i*u_j + a_i + b_j with

    rs_i = 1/sx_i,  cs_j = 1/sw
    u_j  = (colsum_Cw_j + K*ow)/sw * ox ... folded with zero terms (below)
    a_i  = zx_i * K * zw + ...            (all row-only terms)
    b_j  = zw-free col-only terms

(The full derivation is in the code — each term is tagged.)  On CPU the
kernels run under interpret=True; on TPU the same code lowers to Mosaic.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .q8_matmul import q8_matmul
from .quantize_sr import quantize_sr_rows, quantize_sr_tensor
from . import ref

__all__ = ["fused_qlinear", "fused_quantize_psq", "fused_quantize_ptq"]


def fused_qlinear(x: jax.Array, w: jax.Array, key: jax.Array,
                  act_bits: int = 8, weight_bits: int = 8,
                  interpret: bool = True, use_kernels: bool = True):
    """Forward FQT linear via the fused kernels.

    1. per-row (PSQ-style) stochastic quantize of x -> int8 codes
    2. per-tensor deterministic quantize of w       -> int8 codes
    3. fused int8 GEMM + affine epilogue            -> f32 output

    Matches ``ref``-path dequant matmul to fp32 tolerance (tests sweep
    shapes/dtypes).  Returns (y, aux dict with the code tensors).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    ox = 1 << (act_bits - 1)
    ow = 1 << (weight_bits - 1)
    Bw = (1 << weight_bits) - 1

    rbits = jax.random.bits(key, (M, K), jnp.uint32)
    if use_kernels:
        cx, sx, zx = quantize_sr_rows(x, rbits, act_bits, interpret=interpret)
    else:
        cx, sx, zx = ref.quantize_sr_rows_ref(x, rbits, act_bits)

    # deterministic per-tensor weight quantization (round-to-nearest)
    lo, hi = jnp.min(w), jnp.max(w)
    sw = Bw / jnp.maximum(hi - lo, 1e-12)
    cw = (jnp.clip(jnp.round(sw * (w - lo)), 0, Bw) - ow).astype(jnp.int8)
    zw = lo

    # Factor both operands affinely (kernel docstring):
    #   X^_ik = ax_i*Cx_ik + bx_i,   ax = 1/sx,  bx = ox/sx + zx
    #   W^_kj = aw  *Cw_kj + bw,     aw = 1/sw,  bw = ow/sw + zw
    # =>  X^W^ = (ax aw) CxCw + ax bw rowsum(Cx) + bx (aw colsum(Cw) + K bw)
    colsum_cw = jnp.sum(cw.astype(jnp.int32), axis=0).astype(jnp.float32)
    rowsum_cx = jnp.sum(cx.astype(jnp.int32), axis=1).astype(jnp.float32)
    ax = 1.0 / sx[:, 0]                                        # (M,)
    bx = ox * ax + zx[:, 0]                                    # (M,)
    aw = 1.0 / sw
    bw = ow * aw + zw
    rs, cs = ax, jnp.full((N,), aw, jnp.float32)
    r2, u = bx, aw * colsum_cw + K * bw
    a = ax * bw * rowsum_cx
    b = jnp.zeros((N,), jnp.float32)                           # free: bias slot

    if use_kernels:
        y = q8_matmul(cx, cw, rs, cs, r2, u, a, b, interpret=interpret)
    else:
        y = ref.q8_matmul_ref(cx, cw, rs, cs, r2, u, a, b)
    return y, {"cx": cx, "cw": cw, "sx": sx, "sw": sw}


def fused_quantize_psq(g: jax.Array, key: jax.Array, bits: int,
                       interpret: bool = True):
    """PSQ gradient quantize via the fused kernel; returns dequantized g
    (simulate path) — used by benchmarks to measure kernel-vs-ref parity."""
    M, N = g.shape
    rbits = jax.random.bits(key, (M, N), jnp.uint32)
    codes, scale, zero = quantize_sr_rows(g, rbits, bits, interpret=interpret)
    off = (1 << bits) // 2
    return (codes.astype(jnp.float32) + off) / scale + zero


def fused_quantize_ptq(g: jax.Array, key: jax.Array, bits: int,
                       interpret: bool = True):
    M, N = g.shape
    rbits = jax.random.bits(key, (M, N), jnp.uint32)
    codes, scale, zero = quantize_sr_tensor(g, rbits, bits,
                                            interpret=interpret)
    off = (1 << bits) // 2
    return (codes.astype(jnp.float32) + off) / scale + zero
