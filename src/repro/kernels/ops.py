"""jit'd wrappers wiring the Pallas kernels to the paper's quantizer algebra.

The affine-epilogue algebra lives in ONE place — ``core/backend.py``
(``affine_factors`` / ``epilogue_coeffs``); these wrappers only choose
operands and kernels.  ``fused_qlinear`` keeps the historical benchmark
contract (per-row stochastic activation quantize + per-tensor weights);
the *training* hot path routes through ``core.backend.qt_gemm*`` via the
``_fqt`` custom_vjp, and ``fused_qlinear_bwd`` exposes the two backward
GEMMs of Eq. 6 in the same standalone form for benchmarking.

On CPU the kernels run under interpret=True; on TPU the same code lowers
to Mosaic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.backend import (affine_factors, epilogue_coeffs, qt_gemm_nt,
                            qt_gemm_tn, quantize_sr_rows_qt,
                            quantize_sr_tensor_qt)
from ..core.bhq import quantize_bhq_stoch
from ..core.quantizers import quantize_ptq_det
from . import ref
from .q8_matmul import q8_matmul
from .quantize_sr import quantize_sr_rows

__all__ = ["fused_qlinear", "fused_qlinear_bwd", "fused_quantize_psq",
           "fused_quantize_ptq"]


def fused_qlinear(x: jax.Array, w: jax.Array, key: jax.Array,
                  act_bits: int = 8, weight_bits: int = 8,
                  interpret: bool = True, use_kernels: bool = True):
    """Forward FQT linear via the fused kernels.

    1. per-row (PSQ-style) stochastic quantize of x -> int8 codes
    2. per-tensor deterministic quantize of w       -> int8 codes
    3. fused int8 GEMM + affine epilogue            -> f32 output

    Matches ``ref``-path dequant matmul to fp32 tolerance (tests sweep
    shapes/dtypes).  Returns (y, aux dict with the code tensors).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    ow = 1 << (weight_bits - 1)
    Bw = (1 << weight_bits) - 1

    rbits = jax.random.bits(key, (M, K), jnp.uint32)
    if use_kernels:
        cx, sx, zx = quantize_sr_rows(x, rbits, act_bits, interpret=interpret)
    else:
        cx, sx, zx = ref.quantize_sr_rows_ref(x, rbits, act_bits)

    # deterministic per-tensor weight quantization (round-to-nearest)
    lo, hi = jnp.min(w), jnp.max(w)
    sw = Bw / jnp.maximum(hi - lo, 1e-12)
    cw = (jnp.clip(jnp.round(sw * (w - lo)), 0, Bw) - ow).astype(jnp.int8)

    ax, bx = affine_factors(sx, zx, act_bits)          # per-row (M, 1)
    aw, bw = affine_factors(sw, lo, weight_bits)       # per-tensor scalars
    coeffs = epilogue_coeffs(cx, ax, bx, cw, aw, bw)
    if use_kernels:
        y = q8_matmul(cx, cw, *coeffs, interpret=interpret)
    else:
        y = ref.q8_matmul_ref(cx, cw, *coeffs)
    return y, {"cx": cx, "cw": cw, "sx": sx, "sw": sw}


def fused_qlinear_bwd(x: jax.Array, w: jax.Array, g: jax.Array,
                      key: jax.Array, act_bits: int = 8, weight_bits: int = 8,
                      wgrad_bits: int = 8, grad_bits: int = 8,
                      grad_quantizer: str = "psq", bhq_block: int = 1024,
                      interpret: bool = True):
    """Both backward GEMMs of Eq. 6 through the fused Pallas kernels.

        dW = Q_f(X)ᵀ @ Q_b1(dY)      (Q_b1: fused per-tensor SR quantize)
        dX = Q_b2(dY) @ Q_theta(W)ᵀ  (Q_b2: ptq | psq fused SR / bhq + S⁻¹)

    Standalone benchmark form of what ``_fqt_bwd`` runs per training step.
    """
    k1, k2 = jax.random.split(key)
    xq = quantize_ptq_det(x, act_bits)
    wq = quantize_ptq_det(w, weight_bits)
    gq1 = quantize_sr_tensor_qt(g, k1, wgrad_bits, interpret)
    if grad_quantizer == "ptq":
        gq2 = quantize_sr_tensor_qt(g, k2, grad_bits, interpret)
    elif grad_quantizer == "psq":
        gq2 = quantize_sr_rows_qt(g, k2, grad_bits, interpret)
    else:
        gq2 = quantize_bhq_stoch(g, k2, grad_bits, block_rows=bhq_block)
    dw = qt_gemm_tn(xq, gq1, backend="pallas", interpret=interpret)
    dx = qt_gemm_nt(gq2, wq, backend="pallas", interpret=interpret)
    return dw, dx


def fused_quantize_psq(g: jax.Array, key: jax.Array, bits: int,
                       interpret: bool = True):
    """PSQ gradient quantize via the fused kernel; returns dequantized g
    (simulate path) — used by benchmarks to measure kernel-vs-ref parity."""
    return quantize_sr_rows_qt(g, key, bits, interpret).dequant()


def fused_quantize_ptq(g: jax.Array, key: jax.Array, bits: int,
                       interpret: bool = True):
    return quantize_sr_tensor_qt(g, key, bits, interpret).dequant()
