"""Optimizers built from scratch (no optax): SGD-momentum (the paper's
optimizer for ResNets), AdamW (for the LM archs), cosine schedule with linear
warmup (paper App. E), and global-norm clipping.

Functional API:  ``opt = sgd(momentum=0.9)``;
``state = opt.init(params)``; ``params, state = opt.apply(params, grads,
state, lr)``.  States are pytrees of the same structure as params, so the
sharding plan's param specs apply verbatim to optimizer state.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "sgd", "adamw", "cosine_schedule", "clip_by_global_norm",
           "global_norm"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    apply: Callable                 # (params, grads, state, lr) -> (params, state)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def sgd(momentum: float = 0.9, weight_decay: float = 0.0,
        nesterov: bool = False) -> Optimizer:
    """SGD with (heavy-ball) momentum — the paper's CIFAR/ImageNet setting."""

    def init(params):
        return {"mu": jax.tree.map(jnp.zeros_like, params)}

    def apply(params, grads, state, lr):
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p,
                                 grads, params)
        mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
        upd = (jax.tree.map(lambda m, g: momentum * m + g, mu, grads)
               if nesterov else mu)
        params = jax.tree.map(lambda p, u: p - lr * u, params, upd)
        return params, {"mu": mu}

    return Optimizer(init=init, apply=apply)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params),
                "v": jax.tree.map(jnp.zeros_like, params),
                "t": jnp.zeros((), jnp.int32)}

    def apply(params, grads, state, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g),
                         state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, m_, v_):
            step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            return p - lr * (step + weight_decay * p)
        params = jax.tree.map(upd, params, m, v)
        return params, {"m": m, "v": v, "t": t}

    return Optimizer(init=init, apply=apply)


def cosine_schedule(base_lr: float, total_steps: int,
                    warmup_steps: int = 0, final_frac: float = 0.0):
    """Linear warmup + cosine decay (paper App. E)."""

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, base_lr * cos)

    return lr
