"""Checkpointing: atomic, async, and elastic (mesh-reshardable).

Layout: ``<dir>/step_<N>/`` containing
  * ``arrays.npz``  — flat {escaped-path: np.ndarray} of every leaf
  * ``meta.msgpack``— step, treedef repr, leaf paths, shapes/dtypes

Write protocol (fault-tolerance):
  1. write into ``step_<N>.tmp/``
  2. fsync + atomic ``rename`` to ``step_<N>/``          (crash-safe)
  3. prune old checkpoints beyond ``keep``

Restore takes a target *sharding tree*: leaves are ``device_put`` with the
new mesh's NamedShardings, so a checkpoint written on a 16x16 mesh restores
onto 2x16x16 (or a 4-device test mesh) unchanged — elastic scaling.
Async mode runs step 1-3 on a worker thread after snapshotting to host RAM.
"""

from __future__ import annotations

import os
import shutil
import threading
from typing import Any, Optional

import jax
import msgpack
import numpy as np

__all__ = ["CheckpointManager"]

_SEP = "\x1f"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat = {}
    for path, leaf in leaves:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[key] = np.asarray(leaf)
    return flat, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[Exception] = None

    # ------------------------------------------------------------------
    def _write(self, step: int, flat: dict, extra: dict):
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        meta = {"step": step,
                "keys": list(flat.keys()),
                "shapes": {k: list(v.shape) for k, v in flat.items()},
                "dtypes": {k: str(v.dtype) for k, v in flat.items()},
                **extra}
        with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
            f.write(msgpack.packb(meta))
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)                          # atomic commit
        self._prune()

    def _prune(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             asynchronous: bool = False):
        """Checkpoint a pytree. With asynchronous=True, snapshot to host RAM
        then write on a worker thread (training continues)."""
        if self._error:
            raise self._error
        flat, _ = _flatten(jax.tree.map(np.asarray, tree))
        if not asynchronous:
            self._write(step, flat, extra or {})
            return
        self.wait()

        def work():
            try:
                self._write(step, flat, extra or {})
            except Exception as e:                     # pragma: no cover
                self._error = e
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            raise self._error

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: matching tree of NamedShardings —
        leaves are placed directly onto the (possibly different) mesh."""
        path = os.path.join(self.dir, f"step_{step}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        t_leaves, treedef = jax.tree_util.tree_flatten_with_path(target)
        s_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(t_leaves))
        out = []
        for (tpath, tleaf), sh in zip(t_leaves, s_leaves, strict=True):
            key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in tpath)
            if key not in flat:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = flat[key]
            if tuple(arr.shape) != tuple(tleaf.shape):
                raise ValueError(
                    f"shape mismatch for {key!r}: ckpt {arr.shape} vs "
                    f"target {tleaf.shape}")
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def load_meta(self, step: int) -> dict:
        path = os.path.join(self.dir, f"step_{step}", "meta.msgpack")
        with open(path, "rb") as f:
            return msgpack.unpackb(f.read())
