"""int8-quantized KV-cache codec: per-row affine codes for serving decode.

The paper's deployment story (Sec. 1) is that the deterministic forward
quantizers make int8 *inference* free; the KV cache is the serving-time
tensor that actually dominates HBM at scale, and the same per-row affine
scheme (one ``(scale, zero)`` pair per cached row, PSQ's transform without
the stochastic round) compresses it 4x — 4x more resident decode slots at
equal memory (benchmarks/bench_serve.py).

Quantization is **deterministic** (round-to-nearest): the cache sits on the
forward/inference path, where the framework requires deterministic
quantizers (Sec. 2.1) — stochastic rounding would inject fresh noise into
every later decode step that re-reads the row.

Layout convention: a cache row is the flattened ``n_kv * head_dim`` feature
vector of one (batch, position); codes are stored shifted-signed int8
(``c8 = code - 2^(b-1)``, the MXU/native layout) so the tensor is genuinely
1 byte/entry, with ``scale``/``zero`` per row:

    x ~= (c8 + 2^(b-1)) / scale + zero

Dequantization dispatches on the execution backend like every other
quantized op in the stack: ``simulate``/``native`` run the XLA elementwise
expression (there is no GEMM here — "native" and "simulate" coincide);
``pallas`` routes through the fused :func:`~repro.kernels.kv_dequant.
kv_dequant_rows` kernel.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels.kv_dequant import kv_dequant_rows
from .quantizers import num_bins

__all__ = ["quantize_kv_rows", "dequant_kv_rows", "kv_cache_bytes_per_row",
           "kv_fresh_code"]

_EPS = 1e-12


def kv_fresh_code(bits: int = 8) -> int:
    """The shifted-signed code a freshly allocated / padded row must hold so
    it dequantizes to *exactly* zero under the fresh affine pair
    ``(scale=1, zero=0)``: ``c8 = -2^(b-1)`` gives ``(c8 + 2^(b-1))/1 + 0 =
    0.0`` bit-exactly.  Cache and page-pool constructors fill codes with
    this value (a code of 0 would dequantize to ``2^(b-1)``, leaking large
    finite garbage into any path that reads an unwritten row)."""
    return -(1 << (bits - 1))


def quantize_kv_rows(x: jax.Array, bits: int = 8):
    """Per-row deterministic affine quantize over the last axis.

    x: (..., D) float.  Returns ``(codes (..., D) int8 shifted-signed,
    scale (...,) f32, zero (...,) f32)`` with one affine pair per leading
    index — for a KV cache that is one pair per (batch, position) row.
    """
    B = num_bins(bits)
    x = x.astype(jnp.float32)
    lo = jnp.min(x, axis=-1)
    hi = jnp.max(x, axis=-1)
    scale = B / jnp.maximum(hi - lo, _EPS)
    t = scale[..., None] * (x - lo[..., None])
    codes = jnp.clip(jnp.round(t), 0.0, B) - (1 << (bits - 1))
    return codes.astype(jnp.int8), scale, lo


def dequant_kv_rows(codes8: jax.Array, scale: jax.Array, zero: jax.Array,
                    bits: int = 8, *, backend: str = "simulate",
                    interpret: Optional[bool] = None) -> jax.Array:
    """Inverse of :func:`quantize_kv_rows`, dispatched per backend.

    codes8: (..., D) int8; scale/zero: (...,) matching the leading axes.
    Returns (..., D) f32.

    ``scale`` is clamped to ``_EPS`` before the divide: a zero (or negative)
    scale can only come from a degenerate row — an all-zero freshly
    allocated page, a zero-filled checkpoint, a hand-built cache — and
    dividing by it would turn one bad row into inf/nan that poisons the
    whole attention softmax.  Clamped, the degenerate row dequantizes to
    huge-but-finite values the position mask can still hide.
    """
    scale = jnp.maximum(scale.astype(jnp.float32), _EPS)
    if backend == "pallas":
        from .backend import resolve_interpret   # late: avoids import cycle
        d = codes8.shape[-1]
        out = kv_dequant_rows(codes8.reshape(-1, d),
                              scale.reshape(-1, 1), zero.reshape(-1, 1),
                              bits=bits, interpret=resolve_interpret(interpret))
        return out.reshape(codes8.shape)
    off = 1 << (bits - 1)
    return ((codes8.astype(jnp.float32) + off) / scale[..., None]
            + zero[..., None])


def kv_cache_bytes_per_row(d_flat: int, quantized: bool,
                           dtype_bytes: int = 4) -> int:
    """HBM bytes one cached row costs: the resident-slot arithmetic the
    serving benchmark reports (int8 row = codes + scale + zero)."""
    if quantized:
        return d_flat + 2 * 4
    return d_flat * dtype_bytes
