"""Beyond-paper: unbiased quantized gradient all-reduce for data parallelism.

The paper's Theorem 1 only needs ``Q_b`` unbiased and independent across
sources of randomness.  A *communication* quantizer satisfies the same
contract: if every device quantizes its chunk unbiasedly before the exchange,
the resulting SGD gradient remains an unbiased estimator of the QAT gradient,
and Theorem 2 gains one additive variance term (reported by
:func:`compression_variance_bound`).

Wire protocol (2-phase compressed all-reduce, DESIGN.md Sec. 4):

  1. range agreement: ``psum`` of per-chunk min/max (negligible bytes);
  2. ``all_to_all`` of **int8** codes — device j receives everyone's j-th
     chunk (int8 on the wire, no in-flight accumulation so no overflow);
  3. local dequant + sum in fp32; re-quantize the *sum* (again unbiased);
  4. ``all_gather`` of **int8** codes of the reduced chunks.

Wire bytes: 2 x size x 1B  vs fp32 ring all-reduce's 2 x size x 4B — a 4x
reduction on the cross-pod (DCI) axis, visible in the dry-run HLO.

Runs under ``shard_map``; the caller supplies the mesh axis (we use ``pod``).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .quantizers import num_bins, stochastic_round

__all__ = ["compressed_psum", "compressed_grad_allreduce",
           "compression_variance_bound"]

_EPS = 1e-12


def _shard_map(body, *, mesh, in_specs, out_specs):
    """shard_map with replication checks off, across jax versions: newer
    jax exposes ``jax.shard_map(check_vma=)``, 0.4.x has
    ``jax.experimental.shard_map.shard_map(check_rep=)``."""
    import inspect
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    flag = ("check_vma" if "check_vma" in inspect.signature(sm).parameters
            else "check_rep")
    return sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{flag: False})


def _quantize_chunks(x: jax.Array, lo: jax.Array, hi: jax.Array,
                     key: jax.Array, bits: int):
    """Per-chunk affine stochastic quantize; x: (n_chunks, chunk)."""
    B = num_bins(bits)
    scale = B / jnp.maximum(hi - lo, _EPS)                    # (n_chunks, 1)
    codes = stochastic_round(scale * (x - lo), key)
    codes = jnp.clip(codes, 0, B) - (1 << (bits - 1))
    return codes.astype(jnp.int8), scale


def _dequant(codes: jax.Array, scale: jax.Array, lo: jax.Array, bits: int):
    off = 1 << (bits - 1)
    return (codes.astype(jnp.float32) + off) / scale + lo


def compressed_psum(x: jax.Array, key: jax.Array, axis_name: str,
                    bits: int = 8) -> jax.Array:
    """Unbiased int8 all-reduce of ``x`` over ``axis_name``.

    Must be called inside shard_map with ``axis_name`` in scope.  ``x`` is the
    device-local gradient (replica view, same shape everywhere).
    """
    n = jax.lax.psum(1, axis_name)
    size = x.size
    pad = (-size) % n
    flat = jnp.pad(x.reshape(-1), (0, pad))
    chunks = flat.reshape(n, -1)                              # row j -> device j

    # phase 1: per-chunk range agreement (tiny fp32 psum)
    lo = jnp.min(chunks, axis=1, keepdims=True)
    hi = jnp.max(chunks, axis=1, keepdims=True)

    k1, k2 = jax.random.split(jax.random.fold_in(key, jax.lax.axis_index(axis_name)))
    codes, scale = _quantize_chunks(chunks, lo, hi, k1, bits)

    # phase 2: int8 all_to_all — device j collects everyone's chunk j
    codes_t = jax.lax.all_to_all(codes[:, None], axis_name, split_axis=0,
                                 concat_axis=1, tiled=False)   # (1, n, chunk)
    meta = jnp.concatenate([scale, lo], axis=1)                # (n, 2)
    meta_t = jax.lax.all_to_all(meta[:, None], axis_name, split_axis=0,
                                concat_axis=1)                 # (1, n, 2)

    # phase 3: local dequant-sum, re-quantize the reduced chunk
    deq = _dequant(codes_t[0], meta_t[0, :, 0:1], meta_t[0, :, 1:2], bits)
    red = jnp.sum(deq, axis=0, keepdims=True)                  # (1, chunk)
    rlo, rhi = jnp.min(red, axis=1, keepdims=True), jnp.max(red, axis=1, keepdims=True)
    rcodes, rscale = _quantize_chunks(red, rlo, rhi, k2, bits)

    # phase 4: int8 all_gather of reduced chunks + tiny meta gather
    all_codes = jax.lax.all_gather(rcodes[0], axis_name)       # (n, chunk)
    all_meta = jax.lax.all_gather(
        jnp.concatenate([rscale, rlo], axis=1)[0], axis_name)  # (n, 2)
    out = _dequant(all_codes, all_meta[:, 0:1], all_meta[:, 1:2], bits)
    return out.reshape(-1)[:size].reshape(x.shape)


def compressed_grad_allreduce(grads, mesh, axis_name: str, key: jax.Array,
                              bits: int = 8, mean: bool = True):
    """Apply compressed_psum to every leaf of a gradient pytree.

    Entry point used by the training step when ``policy.compress_dp_grads``;
    wraps shard_map over ``axis_name`` with all other axes replicated.
    """
    n = mesh.shape[axis_name]

    def per_leaf(path, g, k):
        def body(gl, kl):
            out = compressed_psum(gl, kl[0], axis_name, bits)
            return out / n if mean else out
        spec = P()  # replica view along the compression axis
        return _shard_map(
            body, mesh=mesh, in_specs=(spec, P(axis_name)),
            out_specs=spec)(g, jax.random.split(k, n))

    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = [per_leaf(i, g, k) for i, (g, k) in enumerate(zip(leaves, keys, strict=True))]
    return jax.tree.unflatten(treedef, out)


def compression_variance_bound(x: jax.Array, bits: int, n_devices: int):
    """Additive Theorem-2 style variance from the 2-phase compression.

    Each of the two SR stages contributes <= size * R^2 / (4 B^2) per chunk;
    ranges shrink per-chunk so this is loose but cheap.
    """
    B = num_bins(bits)
    r = jnp.max(x) - jnp.min(x)
    return 2.0 * x.size * (r ** 2) / (4.0 * B * B)
