"""Role-based quantizer API: first-class quantizers, a registry, role specs.

The paper's framework assigns a *distinct* quantizer to each tensor role of
the linear-layer training step (Sec. 2, Eq. 3/6):

  ``fwd_act``     Q_f      forward activations   (deterministic)
  ``fwd_weight``  Q_theta  forward weights       (deterministic)
  ``wgrad``       Q_b1     output-grad operand of the dW GEMM (stochastic)
  ``agrad``       Q_b2     output-grad operand of the dX GEMM (stochastic)

This module makes that assignment first-class:

  * :class:`Quantizer` — the pluggable object owning the quantize
    implementation *per execution backend* (simulate/native run the XLA
    quantizers; pallas routes through the fused ``quantize_sr_*`` kernels).
    The backend branching lives HERE, on the quantizer, not inside the
    ``_fqt`` custom_vjp — third-party quantizers plug in via
    :func:`register_quantizer` without touching core/fqt.py.
  * :class:`QuantizerSpec` — a hashable (name, bits, params) reference to a
    registered quantizer; partial specs (empty name / ``bits=None``) merge
    over defaults during per-layer policy resolution (core/policy.py).
  * :class:`GemmQuantConfig` — the four role specs plus the execution
    backend; the static (hashable) argument the ``_fqt`` custom_vjp
    dispatches on.  A ``None`` role means that operand stays full precision.

Built-in quantizers (registered at import): ``ptq_det`` (forward),
``ptq`` / ``psq`` / ``bhq`` (stochastic backward, paper Secs. 3.3/4.1/4.2).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .bhq import quantize_bhq_stoch
from .quantizers import (QTensor, quantize_psq_stoch, quantize_ptq_det,
                         quantize_ptq_stoch)

_EPS = 1e-12        # matches core/quantizers._EPS — one zero-range guard

__all__ = [
    "BACKENDS", "ROLES", "KV_CACHE_ROLE", "QuantizerSpec", "GemmQuantConfig",
    "Quantizer", "register_quantizer", "get_quantizer",
    "available_quantizers", "resolve_kv_cache_spec",
]

# The one backend registry — core/backend.py dispatches over the same tuple.
BACKENDS = ("simulate", "native", "pallas")

# The paper's four tensor roles, in (forward, forward, Q_b1, Q_b2) order.
ROLES = ("fwd_act", "fwd_weight", "wgrad", "agrad")

# The serving-time cache role: KV rows quantized on write, dequantized on
# every decode read (core/kv_cache.py).  Deliberately NOT part of ``ROLES``
# — it never enters a GemmQuantConfig; the serving engine resolves it via
# :func:`resolve_kv_cache_spec` and the attention decode path consumes the
# registered quantizer's ``quantize_rows``/``dequant_rows`` protocol.
KV_CACHE_ROLE = "kv_cache"

# Spec name that pins a role (or a whole layer) to full precision.
EXACT_NAME = "exact"


# ---------------------------------------------------------------------------
# QuantizerSpec — hashable reference to a registered quantizer
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuantizerSpec:
    """``(name, bits, params)`` reference into the quantizer registry.

    Hashable (params are a sorted tuple of items) so it can ride inside the
    static argument of a ``custom_vjp``.  Partial specs express overrides:
    ``name=""`` inherits the base spec's quantizer, ``bits=None`` inherits
    the base bits — see :meth:`merged_over`.
    """

    name: str = ""
    bits: Optional[int] = None
    params: tuple = ()                 # sorted ((key, value), ...)

    @classmethod
    def of(cls, value, **params) -> Optional["QuantizerSpec"]:
        """Coerce a spec-ish value: ``None``, a spec, ``"bhq"``, ``"bhq:4"``,
        ``("bhq", 4)``, or ``{"name": "bhq", "bits": 4, "block_rows": 32}``."""
        if value is None or isinstance(value, QuantizerSpec):
            return value
        if isinstance(value, str):
            name, _, bits = value.partition(":")
            return cls(name, int(bits) if bits else None,
                       tuple(sorted(params.items())))
        if isinstance(value, dict):
            d = dict(value)
            name, bits = d.pop("name", ""), d.pop("bits", None)
            d.update(params)
            return cls(name, bits, tuple(sorted(d.items())))
        if isinstance(value, (tuple, list)):
            name = value[0]
            bits = value[1] if len(value) > 1 else None
            extra = dict(value[2]) if len(value) > 2 else {}
            extra.update(params)
            return cls(name, bits, tuple(sorted(extra.items())))
        raise TypeError(f"cannot interpret {value!r} as a QuantizerSpec")

    def param(self, key: str, default=None):
        return dict(self.params).get(key, default)

    def with_bits(self, bits: int) -> "QuantizerSpec":
        return dataclasses.replace(self, bits=bits)

    def merged_over(self, base: Optional["QuantizerSpec"]) -> "QuantizerSpec":
        """Fill this partial spec from ``base`` (the policy default for the
        role): empty name and ``bits=None`` inherit; params merge over the
        base params only when the quantizer name is unchanged (another
        quantizer's params are meaningless)."""
        name = self.name or (base.name if base else EXACT_NAME)
        bits = self.bits if self.bits is not None else \
            (base.bits if base is not None else None)
        if base is not None and base.name == name:
            params = dict(base.params)
            params.update(self.params)
        else:
            params = dict(self.params)
        return QuantizerSpec(name, bits, tuple(sorted(params.items())))

    def describe(self) -> str:
        s = f"{self.name}:{self.bits if self.bits is not None else 8}"
        if self.params:
            s += "(" + ",".join(f"{k}={v}" for k, v in self.params) + ")"
        return s


def _spec_str(spec: Optional[QuantizerSpec]) -> str:
    return "-" if spec is None else spec.describe()


# ---------------------------------------------------------------------------
# GemmQuantConfig — the four roles of one quantized GEMM + execution backend
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GemmQuantConfig:
    """What ``_fqt`` consumes: one resolved spec per tensor role.

    ``None`` for a forward role disables quantization of the whole GEMM
    (both forward roles travel together — the backend GEMMs need integer
    codes on both operands); ``None`` for a backward role computes that
    gradient GEMM from the dequantized forward operands (QAT when both
    backward roles are ``None``, paper Eq. 4).
    """

    fwd_act: Optional[QuantizerSpec] = None
    fwd_weight: Optional[QuantizerSpec] = None
    wgrad: Optional[QuantizerSpec] = None
    agrad: Optional[QuantizerSpec] = None
    backend: str = "simulate"
    pallas_interpret: Optional[bool] = None
    # Fused quantize->GEMM->epilogue megakernels (kernels/fused_fqt.py):
    # None = auto (on for the pallas backend, off otherwise); True/False
    # force.  Only roles the fused kernels cover actually fuse (ptq_det
    # forward, ptq wgrad, ptq/psq agrad); the rest fall back per-role.
    fused: Optional[bool] = None

    @property
    def quantize_fwd(self) -> bool:
        return self.fwd_act is not None and self.fwd_weight is not None

    def validate(self) -> "GemmQuantConfig":
        """Reject configs that cannot execute faithfully.  Called after
        override application (transient intermediate states inside a single
        override are allowed) and on directly-passed configs.

        * Backward roles quantized while the forward is (partially) exact:
          the backward GEMMs consume the *quantized forward operands*
          (Eq. 6), so such a config would silently train exact — pin the
          whole layer ``"exact"`` or quantize both forward roles.
        * Out-of-range bits: codes are stored as (u)int8, so bits outside
          [2, 8] wrap mod 256 and produce garbage numerics silently — the
          same range the legacy ``QuantPolicy`` bit fields enforce.
        """
        if not self.quantize_fwd and (self.wgrad or self.agrad):
            raise ValueError(
                f"invalid role config {self.describe_roles()}: backward "
                f"roles are quantized but the forward is (partially) exact; "
                f"the backward GEMMs need quantized forward operands — pin "
                f"the whole layer 'exact' or set both fwd_act and fwd_weight")
        if (self.fwd_act is None) != (self.fwd_weight is None):
            # one forward operand exact would silently disable the whole
            # GEMM's quantization (the int GEMM needs codes on both sides)
            raise ValueError(
                f"invalid role config {self.describe_roles()}: the forward "
                f"roles travel together — set both fwd_act and fwd_weight, "
                f"or pin the whole layer 'exact'")
        for role in ROLES:
            spec = getattr(self, role)
            if spec is None or spec.bits is None:
                continue
            # the forward weight admits 1-bit (binary sign planes stored
            # bit-packed); every other role's quantizer needs >= 2 bits —
            # a 1-bit SR grid degenerates (see kernels/tiling.check_bits)
            lo = 1 if role == "fwd_weight" else 2
            if not (isinstance(spec.bits, int) and lo <= spec.bits <= 8):
                raise ValueError(
                    f"{role}={spec.describe()}: bits must be an int in "
                    f"[{lo}, 8] (codes are stored as int8; 1-bit is "
                    f"weight-only)")
        return self

    def describe_roles(self) -> str:
        return " ".join(f"{r}={_spec_str(getattr(self, r))}" for r in ROLES)

    def describe(self) -> str:
        if not self.quantize_fwd:
            return "exact"
        return (f"fwd={_spec_str(self.fwd_act)}/{_spec_str(self.fwd_weight)} "
                f"wgrad={_spec_str(self.wgrad)} agrad={_spec_str(self.agrad)}")


# ---------------------------------------------------------------------------
# The Quantizer protocol + registry
# ---------------------------------------------------------------------------

class Quantizer:
    """Base class for pluggable quantizers.

    Subclasses implement :meth:`quantize` and own their backend dispatch:
    the same object serves ``simulate``/``native`` (XLA quantize, integer
    codes consumed by the backend GEMM) and ``pallas`` (fused one-pass
    kernels) — core/fqt.py never branches on the backend again.

    ``key`` is ``None`` for the deterministic forward roles; stochastic
    quantizers may require it.  The return value must expose
    ``codes/scale/zero/bits/dequant()`` (a :class:`~repro.core.quantizers.
    QTensor` or :class:`~repro.core.bhq.BHQTensor`) so the backend GEMMs in
    core/backend.py can consume it.
    """

    name: str = ""
    stochastic: bool = True

    def quantize(self, x2d: jax.Array, key, spec: QuantizerSpec, *,
                 backend: str, interpret: Optional[bool] = None):
        raise NotImplementedError

    def __repr__(self):
        return f"<Quantizer {self.name or type(self).__name__}>"


_REGISTRY: dict = {}


def register_quantizer(name: str, quantizer: Quantizer,
                       overwrite: bool = False) -> Quantizer:
    """Register ``quantizer`` under ``name`` (``QuantizerSpec(name, ...)``)."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"quantizer {name!r} already registered; "
                         "pass overwrite=True to replace it")
    _REGISTRY[name] = quantizer
    return quantizer


def get_quantizer(name: str) -> Quantizer:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown quantizer {name!r}; registered: "
            f"{', '.join(sorted(_REGISTRY))}") from None


def available_quantizers() -> tuple:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Built-in quantizers (the paper's family)
# ---------------------------------------------------------------------------
# The fused-kernel wrappers live in core/backend.py (which imports this
# module for BACKENDS); they are imported lazily at trace time.

class DeterministicPTQ(Quantizer):
    """Q_f / Q_theta: deterministic per-tensor PTQ (paper Sec. 2.1).

    Forward-role quantizer: round-to-nearest, no PRNG key.  Runs in XLA on
    every backend (the pallas fusion targets the stochastic backward
    quantizers; the forward quantize is already one cheap pass).
    """

    name = "ptq_det"
    stochastic = False

    def quantize(self, x2d, key, spec, *, backend, interpret=None):
        return quantize_ptq_det(x2d, spec.bits or 8)


class StochasticPTQ(Quantizer):
    """Q_b1 / PTQ Q_b2: stochastic per-tensor PTQ (paper Sec. 3.3)."""

    name = "ptq"

    def quantize(self, x2d, key, spec, *, backend, interpret=None):
        bits = spec.bits or 8
        if backend == "pallas":
            from .backend import quantize_sr_tensor_qt
            return quantize_sr_tensor_qt(x2d, key, bits, interpret)
        return quantize_ptq_stoch(x2d, key, bits)


class StochasticPSQ(Quantizer):
    """PSQ Q_b2: stochastic per-sample quantizer (paper Sec. 4.1)."""

    name = "psq"

    def quantize(self, x2d, key, spec, *, backend, interpret=None):
        bits = spec.bits or 8
        if backend == "pallas":
            from .backend import quantize_sr_rows_qt
            return quantize_sr_rows_qt(x2d, key, bits, interpret)
        return quantize_psq_stoch(x2d, key, bits)


class BlockHouseholder(Quantizer):
    """BHQ Q_b2 (paper Sec. 4.2).  Params: ``block_rows`` (row-block size),
    ``g_search`` ("refined" | "paper").  The grouping/Householder transform
    stays in XLA on every backend; the GEMM it feeds — including the
    ``S^{-1}`` output epilogue — still routes through the selected backend
    (core/backend.py ``qt_gemm_nt``)."""

    name = "bhq"

    def quantize(self, x2d, key, spec, *, backend, interpret=None):
        return quantize_bhq_stoch(
            x2d, key, spec.bits or 8,
            block_rows=spec.param("block_rows", 1024),
            g_search=spec.param("g_search", "refined"))


class KVCacheInt8(Quantizer):
    """The ``kv_cache`` role: deterministic per-row affine int8 cache codec.

    Beyond the standard :meth:`quantize` protocol (returns a per-row
    ``QTensor``), cache quantizers expose the row-codec pair the decode
    attention path consumes — third-party cache codecs register an object
    with the same two methods:

      * :meth:`quantize_rows`  — x (..., D) -> (codes int8, scale, zero)
      * :meth:`dequant_rows`   — inverse, dispatched on the execution
        backend (``pallas`` uses the fused ``kv_dequant_rows`` kernel).
    """

    name = "kv_int8"
    stochastic = False

    def quantize(self, x2d, key, spec, *, backend, interpret=None):
        from .kv_cache import quantize_kv_rows
        bits = spec.bits or 8
        codes8, scale, zero = quantize_kv_rows(x2d, bits)
        from .quantizers import QTensor
        return QTensor.from_int8(codes8, scale[..., None], zero[..., None],
                                 bits, x2d.shape)

    def quantize_rows(self, x, bits: int = 8):
        from .kv_cache import quantize_kv_rows
        return quantize_kv_rows(x, bits)

    def dequant_rows(self, codes8, scale, zero, bits: int = 8, *,
                     backend: str = "simulate", interpret=None):
        from .kv_cache import dequant_kv_rows
        return dequant_kv_rows(codes8, scale, zero, bits,
                               backend=backend, interpret=interpret)


class PackedPTQWeight(Quantizer):
    """``int4w``: deterministic PTQ forward-weight quantizer with bit-packed
    storage (paper Sec. 2.1 quantizer, sub-byte codes).

    Identical code grid to ``ptq_det`` at the same bitwidth — the returned
    :class:`~repro.kernels.pack.PackedTensor` duck-types ``QTensor`` and the
    backend GEMMs unpack tiles in VMEM (``kernels/q4_matmul.py``), so the
    numerics are bit-exact vs ``ptq_det`` while the weight operand streams
    2x (4-bit) / 4x (2-bit) fewer HBM bytes.  Weight-role only: the packed
    kernels keep the weight on the RHS of the forward GEMM.
    """

    name = "int4w"
    stochastic = False
    packed_weights = True
    default_bits = 4

    def quantize(self, x2d, key, spec, *, backend, interpret=None):
        from ..kernels.pack import pack_qtensor
        bits = spec.bits if spec.bits is not None else self.default_bits
        if bits not in (2, 4):
            raise ValueError(
                f"int4w packs sub-byte PTQ codes; bits must be 4 or 2, got "
                f"{bits!r} (use 'ptq_det' for 8-bit, 'binary' for 1-bit)")
        return pack_qtensor(quantize_ptq_det(x2d, bits))


class BinaryWeight(Quantizer):
    """``binary``: 1-bit BWN-style weights ``w -> alpha * sign(w)`` with
    ``alpha = mean|w|`` (Binary-Weight-Networks, XNOR-Net Eq. 6 — the
    DoReFa-style W1 point of the ultra-low-bit matrix).

    Codes are the sign plane ``{0, 1}`` packed 8/byte; the affine pair
    ``scale = 1/(2 alpha)``, ``zero = -alpha`` makes ``dequant`` land on
    ``{-alpha, +alpha}`` exactly, so the standard epilogue algebra of
    core/backend.py needs no special case.
    """

    name = "binary"
    stochastic = False
    packed_weights = True
    default_bits = 1

    def quantize(self, x2d, key, spec, *, backend, interpret=None):
        from ..kernels.pack import pack_qtensor
        if spec.bits not in (None, 1):
            raise ValueError(
                f"binary is 1-bit by definition, got bits={spec.bits!r}")
        x = x2d.astype(jnp.float32)
        alpha = jnp.mean(jnp.abs(x))
        codes = (x > 0).astype(jnp.uint8)          # sign(0) -> -alpha
        scale = 1.0 / (2.0 * alpha + _EPS)
        return pack_qtensor(QTensor(codes=codes, scale=scale, zero=-alpha,
                                    bits=1, shape=tuple(x2d.shape)))


class TernaryWeight(Quantizer):
    """``ternary``: TWN-style weights ``w -> alpha * {-1, 0, +1}`` with
    threshold ``delta = 0.7 mean|w|`` and ``alpha = mean(|w| : |w|>delta)``
    (Ternary Weight Networks).

    Codes ``{0, 1, 2}`` ride the 2-bit pack (4/byte, one unused bin);
    ``scale = 1/alpha``, ``zero = -alpha`` puts ``dequant`` on
    ``{-alpha, 0, +alpha}`` exactly.
    """

    name = "ternary"
    stochastic = False
    packed_weights = True
    default_bits = 2

    def quantize(self, x2d, key, spec, *, backend, interpret=None):
        from ..kernels.pack import pack_qtensor
        if spec.bits not in (None, 2):
            raise ValueError(
                f"ternary stores {{-1,0,+1}} as 2-bit codes, got "
                f"bits={spec.bits!r}")
        x = x2d.astype(jnp.float32)
        ax = jnp.abs(x)
        delta = 0.7 * jnp.mean(ax)
        mask = ax > delta
        alpha = jnp.sum(jnp.where(mask, ax, 0.0)) / jnp.maximum(
            jnp.sum(mask.astype(jnp.float32)), 1.0)
        codes = jnp.where(mask, jnp.where(x > 0, 2, 0), 1).astype(jnp.uint8)
        scale = 1.0 / (alpha + _EPS)
        return pack_qtensor(QTensor(codes=codes, scale=scale, zero=-alpha,
                                    bits=2, shape=tuple(x2d.shape)))


def resolve_kv_cache_spec(value) -> Optional[QuantizerSpec]:
    """Coerce the serving engine's quantized-KV policy knob.

    ``None``/``False`` => full-precision cache; ``True`` => the default
    ``kv_int8:8``; otherwise any spec-ish value (``"kv_int8:8"``, a
    :class:`QuantizerSpec`, ...) naming a registered cache quantizer.
    """
    if value is None or value is False:
        return None
    if value is True:
        value = KVCacheInt8.name
    spec = QuantizerSpec.of(value)
    q = get_quantizer(spec.name or KVCacheInt8.name)
    if not hasattr(q, "quantize_rows") or not hasattr(q, "dequant_rows"):
        raise ValueError(
            f"quantizer {spec.name!r} cannot serve the {KV_CACHE_ROLE!r} "
            f"role: it lacks the quantize_rows/dequant_rows cache protocol")
    return spec if spec.name else dataclasses.replace(
        spec, name=KVCacheInt8.name)


register_quantizer("ptq_det", DeterministicPTQ())
register_quantizer("ptq", StochasticPTQ())
register_quantizer("psq", StochasticPSQ())
register_quantizer("bhq", BlockHouseholder())
register_quantizer("kv_int8", KVCacheInt8())
register_quantizer("int4w", PackedPTQWeight())
register_quantizer("binary", BinaryWeight())
register_quantizer("ternary", TernaryWeight())
