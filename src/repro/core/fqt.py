"""Fully-Quantized-Training matmul (paper Eq. 3/5/6) as a ``custom_vjp``.

This is the paper's computational primitive.  For a linear layer
``Y = X @ W``:

  forward   (Eq. 3):  ``Y = Q_f(X) @ Q_theta(W)``          (deterministic PTQ)
  backward  (Eq. 6, with gradient bifurcation of App. E):
      ``dW = Q_f(X)ᵀ @ Q_b1(dY)``   Q_b1 = stochastic per-tensor PTQ (8 bit)
      ``dX = Q_b2(dY) @ Q_theta(W)ᵀ``  Q_b2 ∈ {PTQ, PSQ, BHQ} (4-8 bit)

The custom_vjp is quantizer-agnostic: it consumes a
:class:`~repro.core.registry.GemmQuantConfig` naming one
:class:`~repro.core.registry.QuantizerSpec` per tensor role
``{fwd_act, fwd_weight, wgrad, agrad}`` and looks each up in the quantizer
registry (core/registry.py).  Each quantizer owns its per-backend
implementation (XLA vs the fused Pallas ``quantize_sr_*`` kernels), so
adding a quantizer means registering an object — not editing this file.
A ``None`` backward role computes that gradient GEMM from the dequantized
forward operands; both ``None`` is exactly QAT (Eq. 4).

GEMM execution is delegated to the pluggable backend layer
(core/backend.py): ``simulate`` (fp32 QDQ), ``native`` (XLA int8 dot +
affine epilogue) or ``pallas`` (fused kernels) for the forward GEMM *and
both backward GEMMs*.  The same quantizer algebra drives all three
backends, so they agree to fp32 tolerance (tests/test_backend.py).

STE (Eq. 4): the backward differentiates through the *quantized* operands —
no gradient flows into the quantizer itself.
"""

from __future__ import annotations

from functools import partial
from typing import Union

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.pack import PackedTensor
from .backend import (fused_fqt_dw, fused_fqt_dx, fused_fqt_fwd, qt_gemm,
                      qt_gemm_nt, qt_gemm_tn, requantize_det)
from .exempt import key_scope, quant_scope
from .policy import QuantPolicy
from .registry import GemmQuantConfig, QuantizerSpec, get_quantizer

__all__ = ["fqt_matmul"]


def _float0_like(x):
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


def _fused_roles(cfg: GemmQuantConfig):
    """(fwd, wgrad, agrad) eligibility for the fused megakernels.

    ``cfg.fused`` is the knob (None = auto: on for the pallas backend); a
    role only fuses when the fused kernels implement its quantizer — the
    deterministic-PTQ forward, per-tensor stochastic-PTQ wgrad, PTQ/PSQ
    agrad.  Everything else (BHQ agrad, custom quantizers) falls back to
    the unfused per-role path *within the same backward*, and the fused
    wgrad additionally needs the fused forward's (x, scale, zero) residuals.
    """
    if cfg.backend == "simulate" or not cfg.quantize_fwd:
        return False, False, False
    on = cfg.fused if cfg.fused is not None else (cfg.backend == "pallas")
    if not on:
        return False, False, False
    # packed-weight quantizers (int4w/binary/ternary) ride the fused forward
    # too — the fused packed kernel quantizes A in the K-sweep and unpacks
    # the weight tile in VMEM (kernels/fused_fqt.py)
    w_packed = bool(getattr(get_quantizer(cfg.fwd_weight.name),
                            "packed_weights", False))
    fwd = (cfg.fwd_act.name == "ptq_det"
           and (cfg.fwd_weight.name == "ptq_det" or w_packed))
    wg = fwd and cfg.wgrad is not None and cfg.wgrad.name == "ptq"
    ag = cfg.agrad is not None and cfg.agrad.name in ("ptq", "psq")
    return fwd, wg, ag


def _quantize_role(spec: QuantizerSpec, x2d: jax.Array, key,
                   cfg: GemmQuantConfig):
    """Registry dispatch for one tensor role (backend branching lives on the
    quantizer object, not here)."""
    q = get_quantizer(spec.name)
    if key is None and q.stochastic:
        # forward roles carry no PRNG key — the framework requires the
        # forward quantizers to be deterministic (paper Sec. 2.1)
        raise ValueError(
            f"quantizer {spec.name!r} is stochastic and cannot serve a "
            f"forward role (fwd_act/fwd_weight must be deterministic, "
            f"e.g. 'ptq_det')")
    return q.quantize(
        x2d, key, spec, backend=cfg.backend, interpret=cfg.pallas_interpret)


# ---------------------------------------------------------------------------
# The custom_vjp primitive
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _fqt(cfg: GemmQuantConfig, path: str, x: jax.Array, w: jax.Array,
         key: jax.Array):
    y, _ = _fqt_fwd(cfg, path, x, w, key)
    return y


def _fqt_fwd(cfg: GemmQuantConfig, path: str, x, w, key):
    lead = x.shape[:-1]
    dtype = x.dtype
    # quantizer math in fp32 regardless of activation dtype (bf16 streams)
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    # the q[path|fwd] marker scopes the whole quantize+GEMM so the jaxpr
    # auditor (repro.analysis) attributes every fwd equation to this layer
    with quant_scope(path, "fwd", True):
        wq = _quantize_role(cfg.fwd_weight, w.astype(jnp.float32), None, cfg)
        f_fwd, _, _ = _fused_roles(cfg)
        if f_fwd:
            # fused path: Q_f happens inside the GEMM's K-sweep — no int8
            # activation codes in HBM.  Residuals carry (x2, scale, zero);
            # the backward rematerializes the codes deterministically.
            y, sx, zx = fused_fqt_fwd(x2, wq, cfg.fwd_act.bits or 8,
                                      backend=cfg.backend,
                                      interpret=cfg.pallas_interpret)
            res = ((x2, sx, zx), wq, key, lead)
        else:
            xq = _quantize_role(cfg.fwd_act, x2, None, cfg)          # Q_f
            y = qt_gemm(xq, wq, backend=cfg.backend,
                        interpret=cfg.pallas_interpret)
            res = (xq, wq, key, lead)
    return y.reshape(*lead, w.shape[-1]).astype(dtype), res


def _fqt_bwd(cfg: GemmQuantConfig, path: str, res, g):
    xres, wq, key, lead = res
    dtype = g.dtype          # cotangent dtype == stream dtype (y = x.dtype)
    g2 = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    f_fwd, f_wg, f_ag = _fused_roles(cfg)
    bits_act = (cfg.fwd_act.bits or 8) if cfg.quantize_fwd else 8

    def xq_remat():
        # under the fused forward, the activation QTensor was never built —
        # rebuild it bit-identically from the (x2, scale, zero) residuals
        if f_fwd:
            x2, sx, zx = xres
            return requantize_det(x2, sx, zx, bits_act)
        return xres

    if cfg.wgrad is None and cfg.agrad is None:
        # QAT (Eq. 4): full-precision gradient through quantized operands.
        xq = xq_remat()
        with quant_scope(path, "wgrad", False):
            dw = xq.dequant().T @ g2
        with quant_scope(path, "agrad", False):
            dx = g2 @ wq.dequant().T
    else:
        # qk[path] marks the per-site key derivation (it happens before any
        # role scope opens) so the soundness pass can attribute key-lineage
        # findings to this layer
        with key_scope(path):
            k1, k2 = jax.random.split(jax.random.fold_in(key, 0x5151))
        if cfg.wgrad is None:
            with quant_scope(path, "wgrad", False):
                dw = xq_remat().dequant().T @ g2
        elif f_wg:
            with quant_scope(path, "wgrad", True):
                x2, sx, zx = xres
                dw = fused_fqt_dw(x2, sx, zx, bits_act, g2, k1,
                                  cfg.wgrad.bits or 8, backend=cfg.backend,
                                  interpret=cfg.pallas_interpret)
        else:
            with quant_scope(path, "wgrad", True):
                gq1 = _quantize_role(cfg.wgrad, g2, k1, cfg)         # Q_b1
                dw = qt_gemm_tn(xq_remat(), gq1, backend=cfg.backend,
                                interpret=cfg.pallas_interpret)
        if cfg.agrad is None:
            with quant_scope(path, "agrad", False):
                dx = g2 @ wq.dequant().T
        elif f_ag:
            with quant_scope(path, "agrad", True):
                dx = fused_fqt_dx(g2, k2, cfg.agrad, wq,
                                  backend=cfg.backend,
                                  interpret=cfg.pallas_interpret)
        else:
            # BHQ's Householder-transform matmuls count as quantized agrad
            # work — they execute only because this role is quantized
            with quant_scope(path, "agrad", True):
                gq2 = _quantize_role(cfg.agrad, g2, k2, cfg)         # Q_b2
                dx = qt_gemm_nt(gq2, wq, backend=cfg.backend,
                                interpret=cfg.pallas_interpret)
    dx = dx.reshape(*lead, -1).astype(dtype)   # activation-grad in stream dtype
    return dx, dw, _float0_like(key)           # weight-grad stays fp32 (master)


_fqt.defvjp(_fqt_fwd, _fqt_bwd)


def fqt_matmul(x: jax.Array, w: jax.Array, key: jax.Array,
               policy: Union[QuantPolicy, GemmQuantConfig],
               path: str = "") -> jax.Array:
    """``x @ w`` under the given quantization policy.

    x: (..., K) activations; w: (K, M) weights; key: PRNG key consumed by the
    backward-pass stochastic quantizers (ignored under exact/QAT policies).

    ``policy`` may be a :class:`QuantPolicy` — resolved against ``path``
    (the layer's logical position, e.g. ``"layers.mlp.up"``) through the
    policy's per-layer overrides — or an already-resolved
    :class:`GemmQuantConfig` for direct role-level control.  Resolution
    happens at trace time; ``path`` must be a static Python string.
    """
    if isinstance(w, PackedTensor):
        # serving path: the weight was quantized and bit-packed ONCE at
        # load time (serve/engine.py) — inference-only, no custom_vjp
        return _packed_fwd(x, w, policy, path)
    if isinstance(policy, QuantPolicy):
        if not policy.enabled:
            # qfp marker: policy-declared full precision.  The scope also
            # covers the autodiff transposes of this matmul, so the whole
            # exact GEMM (primal + both gradients) is attributable.
            with quant_scope(path, "fwd", False):
                return x @ w
        cfg = policy.resolve(path)           # validated at resolution
    else:
        cfg = policy.validate()
    if not cfg.quantize_fwd:        # layer pinned exact by an override
        with quant_scope(path, "fwd", False):
            return x @ w
    return _fqt(cfg, path, x, w, key)


def _packed_fwd(x: jax.Array, pt: PackedTensor,
                policy: Union[QuantPolicy, GemmQuantConfig],
                path: str) -> jax.Array:
    """Forward vs a pre-packed weight: ``Q_f(x) @ B-hat`` with B packed.

    The weight role was already applied when the weight was packed, so only
    the activation side of the resolved config executes here.  Inference-
    only by design — training with packed weights goes through the packed
    *quantizers* (int4w/binary/ternary) on fp32 master weights, where the
    STE backward needs the (transiently unpacked) codes.
    """
    lead = x.shape[:-1]
    dtype = x.dtype
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    if isinstance(policy, QuantPolicy):
        cfg = policy.resolve(path) if policy.enabled else None
    else:
        cfg = policy.validate() if policy is not None else None
    with quant_scope(path, "fwd", True):
        if cfg is None or not cfg.quantize_fwd or cfg.backend == "simulate":
            # weight-only quantization (or the simulate backend): exact /
            # dequantized activations against the dequantized packed weight
            if cfg is not None and cfg.quantize_fwd:
                xv = _quantize_role(cfg.fwd_act, x2, None, cfg).dequant()
            else:
                xv = x2
            y = xv @ pt.dequant().reshape(-1, pt.shape[-1])
        elif _fused_roles(cfg)[0]:
            y, _, _ = fused_fqt_fwd(x2, pt, cfg.fwd_act.bits or 8,
                                    backend=cfg.backend,
                                    interpret=cfg.pallas_interpret)
        else:
            xq = _quantize_role(cfg.fwd_act, x2, None, cfg)
            y = qt_gemm(xq, pt, backend=cfg.backend,
                        interpret=cfg.pallas_interpret)
    return y.reshape(*lead, pt.shape[-1]).astype(dtype)
