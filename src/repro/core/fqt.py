"""Fully-Quantized-Training matmul (paper Eq. 3/5/6) as a ``custom_vjp``.

This is the paper's computational primitive.  For a linear layer
``Y = X @ W``:

  forward   (Eq. 3):  ``Y = Q_f(X) @ Q_theta(W)``          (deterministic PTQ)
  backward  (Eq. 6, with gradient bifurcation of App. E):
      ``dW = Q_f(X)ᵀ @ Q_b1(dY)``   Q_b1 = stochastic per-tensor PTQ (8 bit)
      ``dX = Q_b2(dY) @ Q_theta(W)ᵀ``  Q_b2 ∈ {PTQ, PSQ, BHQ} (4-8 bit)

Two execution paths share the same quantizers:

  * ``simulate`` — quantize-dequantize in fp32, exactly the paper's GPU
    simulation (App. E).  Used for accuracy / variance experiments.
  * ``native``  — the integer codes feed ``lax.dot_general(int8, int8,
    preferred_element_type=int32)`` (TPU MXU int8) with affine zero-point
    corrections; scales fold *after* accumulation because the paper's recipe
    keeps them on non-contraction axes (DESIGN.md Sec. 3).  Used by the
    dry-run / deployment so roofline FLOP & byte counts reflect real int8
    execution.

STE (Eq. 4): the backward differentiates through the *quantized* operands —
no gradient flows into the quantizer itself.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .bhq import BHQTensor, quantize_bhq_stoch
from .policy import QuantPolicy
from .quantizers import (QTensor, quantize_psq_stoch, quantize_ptq_det,
                         quantize_ptq_stoch)

__all__ = ["fqt_matmul", "qdot"]


def _float0_like(x):
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


# ---------------------------------------------------------------------------
# Integer GEMM with affine corrections (native path)
# ---------------------------------------------------------------------------

def _codes_dot_f32(a_codes: jax.Array, b_codes: jax.Array,
                   bits_a: int, bits_b: int) -> jax.Array:
    """fp32 value of ``a_codes @ b_codes`` via an int8 MXU dot.

    Codes are unsigned in [0, 2^b-1]; we shift by 2^(b-1) into signed int8 so
    the accumulator stays within int32 even at K ~ 50k, then undo the shift
    with rank-1 corrections (exact in int32, summed in fp32).
    """
    off_a, off_b = 1 << (bits_a - 1), 1 << (bits_b - 1)
    a8 = (a_codes.astype(jnp.int16) - off_a).astype(jnp.int8)
    b8 = (b_codes.astype(jnp.int16) - off_b).astype(jnp.int8)
    acc = jax.lax.dot_general(
        a8, b8, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32).astype(jnp.float32)
    row_a = jnp.sum(a8.astype(jnp.int32), axis=1).astype(jnp.float32)   # (R,)
    col_b = jnp.sum(b8.astype(jnp.int32), axis=0).astype(jnp.float32)   # (M,)
    k = a_codes.shape[1]
    return (acc + off_b * row_a[:, None] + off_a * col_b[None, :]
            + float(k * off_a * off_b))


def qdot(a_codes, a_scale, a_zero, bits_a,
         b_codes, b_scale, b_zero, bits_b) -> jax.Array:
    """``Â @ B̂`` for affine-quantized operands, int8 GEMM main term.

    ``Â = a_codes/a_scale + a_zero`` with a_scale/a_zero scalar or (R, 1);
    ``B̂ = b_codes/b_scale + b_zero`` with b_scale/b_zero scalar (per-tensor).

        Â B̂ = [Ca Cb]/(sa sb) + za*colsum(Cb)/sb + zb*rowsum(Ca)/sa + K za zb
    """
    k = a_codes.shape[1]
    main = _codes_dot_f32(a_codes, b_codes, bits_a, bits_b)
    col_b = jnp.sum(b_codes.astype(jnp.float32), axis=0)        # (M,)
    row_a = jnp.sum(a_codes.astype(jnp.float32), axis=1)[:, None]  # (R,1)
    a_scale = jnp.asarray(a_scale)
    a_zero = jnp.asarray(a_zero)
    return (main / (a_scale * b_scale)
            + a_zero * (col_b / b_scale)[None, :]
            + b_zero * (row_a / a_scale)
            + k * a_zero * b_zero)


def _qt_matmul_native(aq: QTensor, bq: QTensor) -> jax.Array:
    """Â @ B̂ for two QTensors (a may be per-row; b must be per-tensor)."""
    a2 = aq.codes.reshape(-1, aq.shape[-1])
    return qdot(a2, aq.scale, aq.zero, aq.bits,
                bq.codes, bq.scale, bq.zero, bq.bits)


def _qt_matmul_tn_native(aq: QTensor, bq: QTensor) -> jax.Array:
    """Âᵀ @ B̂ (contraction over rows; both per-tensor)."""
    at = aq.codes.reshape(-1, aq.shape[-1]).T                    # (K, R)
    return qdot(at, aq.scale, aq.zero, aq.bits,
                bq.codes.reshape(-1, bq.shape[-1]), bq.scale, bq.zero, bq.bits)


def _qt_matmul_nt_native(aq, bq: QTensor) -> jax.Array:
    """Â @ B̂ᵀ where Â is a QTensor or BHQTensor, B̂ a per-tensor QTensor.

    For BHQ the S^{-1} epilogue commutes with the right-matmul
    (DESIGN.md Sec. 3): Q_b(g) @ B̂ᵀ = S^{-1}((codes + Z) @ B̂ᵀ).
    """
    bt = bq.codes.reshape(-1, bq.shape[-1]).T                    # (M, K)
    if isinstance(aq, BHQTensor):
        nb, blk, m = aq.codes.shape
        flat = aq.codes.reshape(nb * blk, m)
        zero = aq.zero.reshape(nb * blk, 1)
        t = qdot(flat, jnp.float32(1.0), zero, aq.bits,
                 bt, bq.scale, bq.zero, bq.bits)                 # (R, K)
        t = t.reshape(nb, blk, -1)
        return aq.dequant_epilogue(t).reshape(nb * blk, -1)
    a2 = aq.codes.reshape(-1, aq.shape[-1])
    return qdot(a2, aq.scale, aq.zero, aq.bits,
                bt, bq.scale, bq.zero, bq.bits)


# ---------------------------------------------------------------------------
# Gradient quantizer dispatch (Q_b2)
# ---------------------------------------------------------------------------

def _quantize_grad(g2d: jax.Array, key: jax.Array, policy: QuantPolicy):
    if policy.grad_quantizer == "ptq":
        return quantize_ptq_stoch(g2d, key, policy.grad_bits)
    if policy.grad_quantizer == "psq":
        return quantize_psq_stoch(g2d, key, policy.grad_bits)
    return quantize_bhq_stoch(g2d, key, policy.grad_bits,
                              block_rows=policy.bhq_block)


# ---------------------------------------------------------------------------
# The custom_vjp primitive
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fqt(policy: QuantPolicy, x: jax.Array, w: jax.Array, key: jax.Array):
    y, _ = _fqt_fwd(policy, x, w, key)
    return y


def _fqt_fwd(policy: QuantPolicy, x, w, key):
    lead = x.shape[:-1]
    dtype = x.dtype
    # quantizer math in fp32 regardless of activation dtype (bf16 streams)
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    xq = quantize_ptq_det(x2, policy.act_bits)
    wq = quantize_ptq_det(w.astype(jnp.float32), policy.weight_bits)
    if policy.mode == "native":
        y = _qt_matmul_native(xq, wq)
    else:
        y = xq.dequant() @ wq.dequant()
    return (y.reshape(*lead, w.shape[-1]).astype(dtype),
            (xq, wq, key, lead))


def _fqt_bwd(policy: QuantPolicy, res, g):
    xq, wq, key, lead = res
    dtype = g.dtype          # cotangent dtype == stream dtype (y = x.dtype)
    g2 = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    if not policy.quantize_bwd:
        # QAT (Eq. 4): full-precision gradient through quantized operands.
        dw = xq.dequant().T @ g2
        dx = g2 @ wq.dequant().T
    else:
        k1, k2 = jax.random.split(jax.random.fold_in(key, 0x5151))
        gq1 = quantize_ptq_stoch(g2, k1, policy.wgrad_bits)      # Q_b1
        gq2 = _quantize_grad(g2, k2, policy)                     # Q_b2
        if policy.mode == "native":
            dw = _qt_matmul_tn_native(xq, gq1)
            dx = _qt_matmul_nt_native(gq2, wq)
        else:
            dw = xq.dequant().T @ gq1.dequant()
            dx = gq2.dequant() @ wq.dequant().T
    dx = dx.reshape(*lead, -1).astype(dtype)   # activation-grad in stream dtype
    return dx, dw, _float0_like(key)           # weight-grad stays fp32 (master)


_fqt.defvjp(_fqt_fwd, _fqt_bwd)


def fqt_matmul(x: jax.Array, w: jax.Array, key: jax.Array,
               policy: QuantPolicy) -> jax.Array:
    """``x @ w`` under the given quantization policy.

    x: (..., K) activations; w: (K, M) weights; key: PRNG key consumed by the
    backward-pass stochastic quantizers (ignored under exact/QAT policies).
    """
    if not policy.enabled:
        return x @ w
    return _fqt(policy, x, w, key)
