"""Fully-Quantized-Training matmul (paper Eq. 3/5/6) as a ``custom_vjp``.

This is the paper's computational primitive.  For a linear layer
``Y = X @ W``:

  forward   (Eq. 3):  ``Y = Q_f(X) @ Q_theta(W)``          (deterministic PTQ)
  backward  (Eq. 6, with gradient bifurcation of App. E):
      ``dW = Q_f(X)ᵀ @ Q_b1(dY)``   Q_b1 = stochastic per-tensor PTQ (8 bit)
      ``dX = Q_b2(dY) @ Q_theta(W)ᵀ``  Q_b2 ∈ {PTQ, PSQ, BHQ} (4-8 bit)

Execution is delegated to the pluggable backend layer (core/backend.py):
``QuantPolicy.backend`` selects ``simulate`` (fp32 QDQ), ``native`` (XLA
int8 dot + affine epilogue) or ``pallas`` (fused Pallas kernels) for the
forward GEMM *and both backward GEMMs*; under ``pallas`` the backward
quantizers Q_b1/Q_b2 additionally run through the fused one-pass
``quantize_sr_*`` kernels (PTQ/PSQ — BHQ's grouping stays in XLA, its GEMM
and S⁻¹ epilogue still route through the backend).  The same quantizer
algebra drives all three backends, so they agree to fp32 tolerance
(tests/test_backend.py).

STE (Eq. 4): the backward differentiates through the *quantized* operands —
no gradient flows into the quantizer itself.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .backend import (qt_gemm, qt_gemm_nt, qt_gemm_tn, quantize_sr_rows_qt,
                      quantize_sr_tensor_qt)
from .bhq import quantize_bhq_stoch
from .policy import QuantPolicy
from .quantizers import (quantize_psq_stoch, quantize_ptq_det,
                         quantize_ptq_stoch)

__all__ = ["fqt_matmul"]


def _float0_like(x):
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


# ---------------------------------------------------------------------------
# Backward quantizer dispatch (Q_b1 / Q_b2)
# ---------------------------------------------------------------------------

def _quantize_wgrad(g2d: jax.Array, key: jax.Array, policy: QuantPolicy):
    """Q_b1: stochastic per-tensor PTQ; fused kernel under the pallas backend."""
    if policy.backend == "pallas":
        return quantize_sr_tensor_qt(g2d, key, policy.wgrad_bits,
                                     policy.pallas_interpret)
    return quantize_ptq_stoch(g2d, key, policy.wgrad_bits)


def _quantize_grad(g2d: jax.Array, key: jax.Array, policy: QuantPolicy):
    """Q_b2 per ``policy.grad_quantizer``; PTQ/PSQ use the fused one-pass
    kernels under the pallas backend (same codes bit-for-bit — both draw SR
    uniforms as ``random.bits * 2^-32``)."""
    if policy.grad_quantizer == "ptq":
        if policy.backend == "pallas":
            return quantize_sr_tensor_qt(g2d, key, policy.grad_bits,
                                         policy.pallas_interpret)
        return quantize_ptq_stoch(g2d, key, policy.grad_bits)
    if policy.grad_quantizer == "psq":
        if policy.backend == "pallas":
            return quantize_sr_rows_qt(g2d, key, policy.grad_bits,
                                       policy.pallas_interpret)
        return quantize_psq_stoch(g2d, key, policy.grad_bits)
    return quantize_bhq_stoch(g2d, key, policy.grad_bits,
                              block_rows=policy.bhq_block)


# ---------------------------------------------------------------------------
# The custom_vjp primitive
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fqt(policy: QuantPolicy, x: jax.Array, w: jax.Array, key: jax.Array):
    y, _ = _fqt_fwd(policy, x, w, key)
    return y


def _fqt_fwd(policy: QuantPolicy, x, w, key):
    lead = x.shape[:-1]
    dtype = x.dtype
    # quantizer math in fp32 regardless of activation dtype (bf16 streams)
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    xq = quantize_ptq_det(x2, policy.act_bits)
    wq = quantize_ptq_det(w.astype(jnp.float32), policy.weight_bits)
    y = qt_gemm(xq, wq, backend=policy.backend,
                interpret=policy.pallas_interpret)
    return (y.reshape(*lead, w.shape[-1]).astype(dtype),
            (xq, wq, key, lead))


def _fqt_bwd(policy: QuantPolicy, res, g):
    xq, wq, key, lead = res
    dtype = g.dtype          # cotangent dtype == stream dtype (y = x.dtype)
    g2 = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    if not policy.quantize_bwd:
        # QAT (Eq. 4): full-precision gradient through quantized operands.
        dw = xq.dequant().T @ g2
        dx = g2 @ wq.dequant().T
    else:
        k1, k2 = jax.random.split(jax.random.fold_in(key, 0x5151))
        gq1 = _quantize_wgrad(g2, k1, policy)                    # Q_b1
        gq2 = _quantize_grad(g2, k2, policy)                     # Q_b2
        dw = qt_gemm_tn(xq, gq1, backend=policy.backend,
                        interpret=policy.pallas_interpret)
        dx = qt_gemm_nt(gq2, wq, backend=policy.backend,
                        interpret=policy.pallas_interpret)
    dx = dx.reshape(*lead, -1).astype(dtype)   # activation-grad in stream dtype
    return dx, dw, _float0_like(key)           # weight-grad stays fp32 (master)


_fqt.defvjp(_fqt_fwd, _fqt_bwd)


def fqt_matmul(x: jax.Array, w: jax.Array, key: jax.Array,
               policy: QuantPolicy) -> jax.Array:
    """``x @ w`` under the given quantization policy.

    x: (..., K) activations; w: (K, M) weights; key: PRNG key consumed by the
    backward-pass stochastic quantizers (ignored under exact/QAT policies).
    """
    if not policy.enabled:
        return x @ w
    return _fqt(policy, x, w, key)
