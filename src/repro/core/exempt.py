"""Full-precision exemption registry + jaxpr-level quantization markers.

The paper's guarantees (Theorem 1 unbiasedness, the Eq. 6 variance
decomposition) only cover GEMMs that flow through the ``_fqt`` custom_vjp
under the resolved :class:`~repro.core.policy.QuantPolicy`.  Every other
matmul in the model is either an *intentional* full-precision computation
(attention scores/probs, the RWKV WKV recurrence, Mamba2 SSD state
contractions — the paper quantizes only linear layers) or a *leak* that
silently invalidates the bits-vs-variance story.

This module draws the machine-checked line between the two:

  * :func:`fp_exempt` — a context manager that (a) registers ``path`` with a
    human ``reason`` in a process-global registry and (b) opens a
    ``jax.named_scope`` marker ``fp[path]`` so every equation traced inside
    it is attributable in the jaxpr.  ``repro.analysis audit`` treats GEMMs
    under an ``fp[...]`` marker as declared-exempt; a GEMM under *no* marker
    is a contract violation.

  * :func:`quant_scope` — the marker the FQT primitive itself opens around
    each role's quantize+GEMM work: ``q[path|role]`` for quantized execution,
    ``qfp[path|role]`` for GEMMs the *resolved policy* runs in full precision
    (QAT backwards, ``None`` roles, exact-pinned layers).

Markers ride in ``eqn.source_info.name_stack`` and survive ``jax.grad``,
``custom_vjp``, ``scan``, ``remat``, ``vmap`` and ``pjit`` sub-jaxprs, so the
auditor can attribute every ``dot_general`` in a full training step without
any runtime cost — ``named_scope`` is trace-time metadata only.
"""

from __future__ import annotations

import contextlib
import re
import threading
from typing import Dict, Iterator

import jax

__all__ = ["fp_exempt", "quant_scope", "key_scope", "exemption_registry",
           "clear_exemptions", "MARKER_RE", "KEY_SCOPE_RE", "GEMM_ROLES"]

# Roles a quant_scope marker may claim.  "fwd" additionally covers the
# autodiff *transposes* of an exact-pinned forward GEMM (the whole matmul —
# primal and cotangents — is full precision there, so one marker scopes all
# of it).
GEMM_ROLES = ("fwd", "wgrad", "agrad")

# q[path|role] / qfp[path|role] / fp[path] inside a name-stack string.  The
# payload never contains ']' — enforced below — so the lazy body is safe.
MARKER_RE = re.compile(r"\b(qfp|q|fp)\[([^\]]*)\]")

# qk[path]: the key-lineage marker the FQT backward opens around its
# per-site PRNG derivation (fold_in/split), so the soundness pass can name
# the layer a key-aliasing finding belongs to even though that derivation
# happens before any role scope opens.  Deliberately NOT matched by
# MARKER_RE ('qk' is not in its alternation and \b cannot split 'qk'), so
# the contract auditor ignores it.
KEY_SCOPE_RE = re.compile(r"\bqk\[([^\]]*)\]")

_LOCK = threading.Lock()
_REGISTRY: Dict[str, str] = {}


def _check_static_str(name: str, value) -> str:
    if not isinstance(value, str) or not value:
        raise TypeError(f"{name} must be a non-empty static str, got "
                        f"{value!r}; exemption paths are trace-time metadata "
                        f"and cannot be traced values")
    if "]" in value or "[" in value:
        raise ValueError(f"{name}={value!r} may not contain '[' or ']' "
                         f"(they delimit the jaxpr marker)")
    return value


@contextlib.contextmanager
def fp_exempt(path: str, reason: str) -> Iterator[None]:
    """Declare the GEMMs traced inside as intentionally full precision.

    ``path`` is the logical name the audit reports group under (e.g.
    ``"attn.sdpa"``); ``reason`` is the human justification recorded in the
    exemption registry and printed in coverage reports.  Both must be static
    strings — the repo lint rule (``repro.analysis lint``) additionally
    requires them to be *literals* at every call site so the registry is
    statically enumerable.
    """
    _check_static_str("path", path)
    if not isinstance(reason, str) or not reason.strip():
        raise TypeError(f"fp_exempt({path!r}): reason must be a non-empty "
                        f"str explaining why these GEMMs stay full precision")
    with _LOCK:
        _REGISTRY.setdefault(path, reason)
    with jax.named_scope(f"fp[{path}]"):
        yield


def quant_scope(path: str, role: str, quantized: bool):
    """Marker scope for one GEMM role of the FQT primitive.

    ``quantized=True`` emits ``q[path|role]`` (the GEMM and its quantize/
    epilogue work execute under the quantized contract); ``False`` emits
    ``qfp[path|role]`` (the resolved policy runs this role in full
    precision — QAT backward, a ``None`` role, an exact-pinned layer).
    """
    if role not in GEMM_ROLES:
        raise ValueError(f"unknown GEMM role {role!r}; expected one of "
                         f"{GEMM_ROLES}")
    # path may legitimately be "" (direct fqt_matmul calls outside a model);
    # the auditor only enforces the declared model paths.
    if "]" in path or "[" in path:
        raise ValueError(f"path={path!r} may not contain '[' or ']'")
    tag = "q" if quantized else "qfp"
    return jax.named_scope(f"{tag}[{path}|{role}]")


def key_scope(path: str):
    """Marker scope ``qk[path]`` for per-site PRNG-key derivation.

    The FQT backward derives its two SR keys (``fold_in`` + ``split``)
    *before* opening the wgrad/agrad role scopes, so those equations would
    otherwise carry an empty name stack.  The soundness pass
    (repro.analysis.soundness) uses this marker to attribute key-lineage
    findings (aliased or scan-invariant SR keys) to a layer path.
    """
    if "]" in path or "[" in path:
        raise ValueError(f"path={path!r} may not contain '[' or ']'")
    return jax.named_scope(f"qk[{path}]")


def exemption_registry() -> Dict[str, str]:
    """Snapshot of the declared exemptions: {path: reason}."""
    with _LOCK:
        return dict(_REGISTRY)


def clear_exemptions() -> None:
    """Reset the registry (test isolation only)."""
    with _LOCK:
        _REGISTRY.clear()
