"""Quantization policy — the single config object threaded through the stack.

Mirrors the paper's experimental setup (Appendix E):

  * forward: ``Q_f``/``Q_theta`` deterministic 8-bit PTQ on every linear layer
  * backward, with gradient bifurcation (Banner et al. / paper Eq. in App. E):
      - weight-grad GEMM uses ``Q_b1`` = stochastic per-tensor PTQ at 8 bits
      - activation-grad GEMM uses ``Q_b2`` ∈ {PTQ, PSQ, BHQ} at 4-8 bits

Three canonical modes:
  ``exact()``  full-precision training        (paper's "Exact" rows)
  ``qat()``    quantized forward, FP backward (paper's "QAT" rows)
  ``fqt(...)`` fully quantized training       (paper's "b-bit FQT" rows)

Orthogonally, ``backend`` picks how every quantized GEMM executes
(core/backend.py owns the dispatch; the policy x backend matrix is fully
crossed):

  ``simulate``  fp32 quantize-dequantize matmul (the paper's GPU simulation)
  ``native``    XLA int8 ``dot_general`` + affine epilogue (TPU MXU int8)
  ``pallas``    fused Pallas kernels: one-pass quantize (kernels/quantize_sr)
                and int8 GEMM + epilogue (kernels/q8_matmul) for the forward
                AND both backward GEMMs

``backend`` is the single stored field; the factory methods still accept the
legacy ``mode=`` spelling and ``policy.mode`` reads as an alias.
``pallas_interpret`` forces/forbids Pallas interpret mode (None = auto:
interpret everywhere but TPU).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["QuantPolicy", "EXACT", "QAT", "FQT8_BHQ", "BACKENDS"]

# The one backend registry — core/backend.py dispatches over the same tuple.
BACKENDS = ("simulate", "native", "pallas")


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    enabled: bool = True           # False => full-precision ("exact")
    act_bits: int = 8              # Q_f bits
    weight_bits: int = 8           # Q_theta bits
    quantize_bwd: bool = True      # False => QAT (backward in full precision)
    wgrad_bits: int = 8            # Q_b1 bits (stochastic per-tensor)
    grad_bits: int = 8             # Q_b2 bits
    grad_quantizer: str = "bhq"    # Q_b2 type: "ptq" | "psq" | "bhq"
    bhq_block: int = 1024          # BHQ row-block size
    # --- execution backend (core/backend.py dispatch) ---
    backend: str = "simulate"      # "simulate" | "native" | "pallas"
    pallas_interpret: Optional[bool] = None  # None => auto (non-TPU interprets)
    # --- beyond-paper knobs ---
    compress_dp_grads: bool = False  # int8 unbiased gradient all-reduce
    dp_grad_bits: int = 8

    def __post_init__(self):
        assert self.grad_quantizer in ("ptq", "psq", "bhq")
        assert self.backend in BACKENDS, self.backend
        assert 2 <= self.grad_bits <= 8 and 2 <= self.act_bits <= 8

    @property
    def mode(self) -> str:
        """Legacy alias of ``backend`` (read-only; set via the factories)."""
        return self.backend

    @staticmethod
    def _resolve_backend(backend: str, mode: str) -> str:
        # `mode` is the legacy spelling; an explicit `backend` wins.
        return backend or mode or "simulate"

    @staticmethod
    def exact() -> "QuantPolicy":
        return QuantPolicy(enabled=False)

    @staticmethod
    def qat(act_bits: int = 8, weight_bits: int = 8,
            mode: str = "", backend: str = "", **kw) -> "QuantPolicy":
        return QuantPolicy(enabled=True, quantize_bwd=False,
                           act_bits=act_bits, weight_bits=weight_bits,
                           backend=QuantPolicy._resolve_backend(backend, mode),
                           **kw)

    @staticmethod
    def fqt(grad_quantizer: str = "bhq", grad_bits: int = 8,
            act_bits: int = 8, weight_bits: int = 8,
            mode: str = "", backend: str = "", **kw) -> "QuantPolicy":
        return QuantPolicy(enabled=True, quantize_bwd=True,
                           grad_quantizer=grad_quantizer, grad_bits=grad_bits,
                           act_bits=act_bits, weight_bits=weight_bits,
                           backend=QuantPolicy._resolve_backend(backend, mode),
                           **kw)


EXACT = QuantPolicy.exact()
QAT = QuantPolicy.qat()
FQT8_BHQ = QuantPolicy.fqt("bhq", 8)
