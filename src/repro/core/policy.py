"""Quantization policy — global defaults + per-layer overrides.

Mirrors the paper's experimental setup (Appendix E):

  * forward: ``Q_f``/``Q_theta`` deterministic 8-bit PTQ on every linear layer
  * backward, with gradient bifurcation (Banner et al. / paper Eq. in App. E):
      - weight-grad GEMM uses ``Q_b1`` = stochastic per-tensor PTQ at 8 bits
      - activation-grad GEMM uses ``Q_b2`` ∈ {PTQ, PSQ, BHQ} at 4-8 bits

Three canonical modes:
  ``exact()``  full-precision training        (paper's "Exact" rows)
  ``qat()``    quantized forward, FP backward (paper's "QAT" rows)
  ``fqt(...)`` fully quantized training       (paper's "b-bit FQT" rows)

The role-based layer (core/registry.py) sits underneath: the global fields
here are just *defaults* that :meth:`QuantPolicy.resolve` turns into a
:class:`~repro.core.registry.GemmQuantConfig` — one
:class:`~repro.core.registry.QuantizerSpec` per tensor role
``{fwd_act, fwd_weight, wgrad, agrad}``.  ``overrides`` maps path regexes to
partial role overrides, and every layer passes its logical path
(``dense(..., path="layers.mlp.up")``) so heterogeneous precision — exact
embeddings/lm_head, 8-bit attention, 4-bit-BHQ MLP agrad — is pure config:

    QuantPolicy.fqt("bhq", 8, overrides={
        r"lm_head|embed":  "exact",            # pin full precision
        r"layers\\.attn\\.": 8,                # all roles at 8 bits
        r"layers\\.mlp\\.":  {"agrad": ("bhq", 4)},   # partial role spec
    })

Matching is ``re.search``, applied in order — later matches win field-wise;
partial specs merge over the defaults (see ``QuantizerSpec.merged_over``).

Orthogonally, ``backend`` picks how every quantized GEMM executes
(core/backend.py owns the dispatch; the policy x backend matrix is fully
crossed):

  ``simulate``  fp32 quantize-dequantize matmul (the paper's GPU simulation)
  ``native``    XLA int8 ``dot_general`` + affine epilogue (TPU MXU int8)
  ``pallas``    fused Pallas kernels: one-pass quantize (kernels/quantize_sr)
                and int8 GEMM + epilogue (kernels/q8_matmul) for the forward
                AND both backward GEMMs

``backend`` is the single stored field; the factory methods still accept the
legacy ``mode=`` spelling and ``policy.mode`` reads as an alias.
``pallas_interpret`` forces/forbids Pallas interpret mode (None = auto:
interpret everywhere but TPU).
"""

from __future__ import annotations

import dataclasses
import functools
import re
from typing import Optional

from .registry import (BACKENDS, EXACT_NAME, ROLES, GemmQuantConfig,
                       QuantizerSpec, get_quantizer)

__all__ = ["QuantPolicy", "RoleOverride", "EXACT", "QAT", "FQT8_BHQ",
           "BACKENDS", "overrides_to_json", "overrides_from_json"]

_BIT_FIELDS = ("act_bits", "weight_bits", "wgrad_bits", "grad_bits",
               "dp_grad_bits")


# ---------------------------------------------------------------------------
# RoleOverride — one partial per-layer override
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RoleOverride:
    """Partial per-role settings merged over the policy defaults.

    ``exact=True`` pins the layer to full precision; ``bits`` rewrites the
    bitwidth of every role that stays quantized; the four role fields carry
    partial :class:`QuantizerSpec` values (``None`` = leave the role alone,
    spec name ``"exact"`` = pin just that role to full precision).
    """

    exact: bool = False
    bits: Optional[int] = None
    fwd_act: Optional[QuantizerSpec] = None
    fwd_weight: Optional[QuantizerSpec] = None
    wgrad: Optional[QuantizerSpec] = None
    agrad: Optional[QuantizerSpec] = None

    @classmethod
    def of(cls, value) -> "RoleOverride":
        """Coerce an override-ish value: ``"exact"``, an int (bits for all
        roles), a RoleOverride, or a dict of role -> spec-ish (plus the
        shorthand key ``"fwd"`` setting both forward roles and the scalar
        keys ``"exact"``/``"bits"``)."""
        if isinstance(value, RoleOverride):
            return value
        if value == EXACT_NAME:
            return cls(exact=True)
        if isinstance(value, int) and not isinstance(value, bool):
            return cls(bits=value)
        if isinstance(value, dict):
            d = dict(value)
            kw = {"exact": bool(d.pop("exact", False)),
                  "bits": d.pop("bits", None)}
            fwd = d.pop("fwd", None)
            if fwd is not None:
                d.setdefault("fwd_act", fwd)
                d.setdefault("fwd_weight", fwd)
            for role in ROLES:
                if role in d:
                    kw[role] = QuantizerSpec.of(d.pop(role))
            if d:
                raise ValueError(
                    f"unknown override keys {sorted(d)}; expected "
                    f"{('exact', 'bits', 'fwd') + ROLES}")
            return cls(**kw)
        raise TypeError(f"cannot interpret {value!r} as a RoleOverride")

    def apply(self, cfg: GemmQuantConfig) -> GemmQuantConfig:
        if self.exact:
            cfg = dataclasses.replace(cfg, fwd_act=None, fwd_weight=None,
                                      wgrad=None, agrad=None)
        # blanket `bits` rewrites first, so an explicit per-role spec in the
        # SAME override entry (more specific) wins over it
        if self.bits is not None:
            cfg = dataclasses.replace(cfg, **{
                role: getattr(cfg, role).with_bits(self.bits)
                for role in ROLES if getattr(cfg, role) is not None})
        for role in ROLES:
            part = getattr(self, role)
            if part is None:
                continue
            base = getattr(cfg, role)
            if not part.name and base is None:
                # nothing to merge over: the role is full-precision here
                # (QAT / an earlier exact pin) — silently dropping the
                # requested quantization would lie about the precision
                raise ValueError(
                    f"override for role {role!r} gives no quantizer name "
                    f"but the role has no quantizer to inherit (it is "
                    f"full-precision at this point); name one explicitly, "
                    f"e.g. {role}='psq:{part.bits or 8}'")
            spec = part.merged_over(base)
            cfg = dataclasses.replace(
                cfg, **{role: None if spec.name == EXACT_NAME else spec})
        return cfg


def _normalize_overrides(overrides) -> tuple:
    """dict / iterable-of-pairs -> hashable ((pattern, RoleOverride), ...)."""
    if not overrides:
        return ()
    items = overrides.items() if isinstance(overrides, dict) else overrides
    out = []
    for pattern, value in items:
        try:                           # fail loudly on a bad regex, up front
            re.compile(pattern)
        except re.error as e:
            raise ValueError(
                f"invalid override pattern {pattern!r}: {e}") from None
        out.append((pattern, RoleOverride.of(value)))
    return tuple(out)


@functools.lru_cache(maxsize=4096)
def _resolve(policy: "QuantPolicy", path: str) -> GemmQuantConfig:
    cfg = policy._default_gemm_config()
    for pattern, override in policy.overrides:
        if re.search(pattern, path):
            cfg = override.apply(cfg)
    return cfg.validate()


# ---------------------------------------------------------------------------
# QuantPolicy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    enabled: bool = True           # False => full-precision ("exact")
    act_bits: int = 8              # Q_f bits
    weight_bits: int = 8           # Q_theta bits
    quantize_bwd: bool = True      # False => QAT (backward in full precision)
    wgrad_bits: int = 8            # Q_b1 bits (stochastic per-tensor)
    grad_bits: int = 8             # Q_b2 bits
    grad_quantizer: str = "bhq"    # Q_b2 type: any registered quantizer name
    bhq_block: int = 1024          # BHQ row-block size
    # --- per-layer policy tree (core/registry.py role specs) ---
    overrides: tuple = ()          # ((path_regex, RoleOverride), ...) in order
    # --- execution backend (core/backend.py dispatch) ---
    backend: str = "simulate"      # "simulate" | "native" | "pallas"
    pallas_interpret: Optional[bool] = None  # None => auto (non-TPU interprets)
    fused: Optional[bool] = None   # fused megakernels: None => auto (pallas on)
    # --- beyond-paper knobs ---
    compress_dp_grads: bool = False  # int8 unbiased gradient all-reduce
    dp_grad_bits: int = 8

    def __post_init__(self):
        get_quantizer(self.grad_quantizer)   # ValueError if unregistered
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"expected one of {BACKENDS}")
        for field in _BIT_FIELDS:
            bits = getattr(self, field)
            if not (isinstance(bits, int) and 2 <= bits <= 8):
                raise ValueError(f"{field}={bits!r} out of range: "
                                 "bitwidths must be ints in [2, 8]")
        if not (isinstance(self.bhq_block, int) and self.bhq_block > 0):
            raise ValueError(f"bhq_block={self.bhq_block!r} must be a "
                             "positive int")
        object.__setattr__(self, "overrides",
                           _normalize_overrides(self.overrides))

    # -- role resolution (the PolicyTree layer) -------------------------

    def _default_gemm_config(self) -> GemmQuantConfig:
        """The global-field defaults as one GemmQuantConfig."""
        if not self.enabled:
            return GemmQuantConfig(backend=self.backend,
                                   pallas_interpret=self.pallas_interpret,
                                   fused=self.fused)
        wgrad = agrad = None
        if self.quantize_bwd:
            wgrad = QuantizerSpec("ptq", self.wgrad_bits)
            params = ()
            if self.grad_quantizer == "bhq":
                params = (("block_rows", self.bhq_block),)
            agrad = QuantizerSpec(self.grad_quantizer, self.grad_bits, params)
        return GemmQuantConfig(
            fwd_act=QuantizerSpec("ptq_det", self.act_bits),
            fwd_weight=QuantizerSpec("ptq_det", self.weight_bits),
            wgrad=wgrad, agrad=agrad,
            backend=self.backend, pallas_interpret=self.pallas_interpret,
            fused=self.fused)

    def resolve(self, path: str = "") -> GemmQuantConfig:
        """Per-layer role specs for the GEMM at ``path``.

        Defaults come from the global fields; every ``overrides`` entry whose
        regex ``re.search``-matches ``path`` is applied in order (later
        matches win field-wise, partial specs merge over what they override).
        Called at trace time — resolution is pure Python on static data and
        memoized, so it never costs anything inside jit.
        """
        return _resolve(self, path or "")

    def spec_table(self, paths) -> tuple:
        """((path, resolved-spec-description), ...) for a path list —
        the per-layer precision table of a config (tested + printed by
        examples/quickstart.py)."""
        return tuple((p, self.resolve(p).describe()) for p in paths)

    # -- legacy surface --------------------------------------------------

    @property
    def mode(self) -> str:
        """Legacy alias of ``backend`` (read-only; set via the factories)."""
        return self.backend

    @staticmethod
    def _resolve_backend(backend: str, mode: str) -> str:
        # `mode` is the legacy spelling; an explicit `backend` wins.
        chosen = backend or mode or "simulate"
        if chosen not in BACKENDS:
            which = "backend" if backend else "mode"
            raise ValueError(f"invalid {which}={chosen!r}; "
                             f"expected one of {BACKENDS}")
        return chosen

    @staticmethod
    def exact() -> "QuantPolicy":
        return QuantPolicy(enabled=False)

    @staticmethod
    def qat(act_bits: int = 8, weight_bits: int = 8,
            mode: str = "", backend: str = "", **kw) -> "QuantPolicy":
        return QuantPolicy(enabled=True, quantize_bwd=False,
                           act_bits=act_bits, weight_bits=weight_bits,
                           backend=QuantPolicy._resolve_backend(backend, mode),
                           **kw)

    @staticmethod
    def fqt(grad_quantizer: str = "bhq", grad_bits: int = 8,
            act_bits: int = 8, weight_bits: int = 8,
            mode: str = "", backend: str = "", **kw) -> "QuantPolicy":
        return QuantPolicy(enabled=True, quantize_bwd=True,
                           grad_quantizer=grad_quantizer, grad_bits=grad_bits,
                           act_bits=act_bits, weight_bits=weight_bits,
                           backend=QuantPolicy._resolve_backend(backend, mode),
                           **kw)


EXACT = QuantPolicy.exact()
QAT = QuantPolicy.qat()
FQT8_BHQ = QuantPolicy.fqt("bhq", 8)


# ---------------------------------------------------------------------------
# Override (de)serialization — the precision-planner interchange format
# ---------------------------------------------------------------------------

def _spec_to_json(spec: Optional[QuantizerSpec]):
    if spec is None:
        return None
    d = {"name": spec.name, "bits": spec.bits}
    d.update(dict(spec.params))
    return d


def overrides_to_json(overrides) -> list:
    """Overrides (any form ``QuantPolicy(overrides=...)`` accepts) -> a
    JSON-serializable ``[[pattern, {role: spec-dict, ...}], ...]`` list.

    Inverse of :func:`overrides_from_json`; the planner
    (``repro.analysis plan``) writes this format and
    ``launch/train.py --override-file`` reads it back.
    """
    out = []
    for pattern, ov in _normalize_overrides(overrides):
        d: dict = {}
        if ov.exact:
            d["exact"] = True
        if ov.bits is not None:
            d["bits"] = ov.bits
        for role in ROLES:
            spec = getattr(ov, role)
            if spec is not None:
                d[role] = _spec_to_json(spec)
        out.append([pattern, d])
    return out


def overrides_from_json(data) -> tuple:
    """JSON overrides -> the normalized tuple ``QuantPolicy(overrides=...)``
    consumes.  Accepts the list-of-pairs form :func:`overrides_to_json`
    emits, a ``{pattern: override}`` dict, or the full planner JSON document
    (uses its ``"overrides"`` key)."""
    if isinstance(data, dict) and "overrides" in data:
        data = data["overrides"]
    if isinstance(data, dict):
        pairs = list(data.items())
    else:
        pairs = [(p, v) for p, v in data]
    return _normalize_overrides(pairs)
