"""Block Householder Quantizer (BHQ) — StatQuant Sec. 4.2 / Appendix D.4-D.5.

The paper's construction, adapted to TPU/XLA static shapes (DESIGN.md Sec. 3):

  1. sort rows by magnitude ``M_i = ||g_i||_inf`` (descending);
  2. pick the number of groups ``G`` by minimizing the paper's variance proxy
     ``(sum_{i<=G} M_i)^2 / (N - G)`` — vectorized over *all* candidate G with
     one prefix sum instead of the paper's CPU loop;
  3. group ``i`` = the i-th largest row + ``~(N-G) * M_i / sum M`` small rows
     (largest-remainder integerization so sizes sum to N);
  4. scale rows by ``diag(s1, s2, ..., s2)`` with the Lagrangian-optimal
     ``s1 ∝ λ1^{-1/3} m^{1/6}``, ``s2 ∝ λ2^{-1/3} m^{1/6}`` (Appendix D.4),
     then apply the group Householder ``Q = I - 2 n nᵀ / ||n||²``,
     ``n = 1/√m - e1`` — realized as two ``segment_sum``s, never as a matrix;
  5. stochastically round with a per-group zero point.

``Q`` is symmetric and involutory, so dequantization applies the *same*
segment-sum Householder and divides by the row scales: unbiasedness
``E[Q_b(g)] = g`` holds exactly for any grouping (Theorem 1 requirement).

For large N (LM token rows) the grouping runs independently over row blocks of
``block_rows`` via ``vmap`` — bounding the sort cost and keeping the paper's
N≈128-row regime per group search.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .quantizers import num_bins, stochastic_round, row_dynamic_range

__all__ = ["BHQTensor", "quantize_bhq_stoch", "bhq_variance_bound"]

_EPS = 1e-12


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BHQTensor:
    """Quantized tensor under the block Householder transform.

    Dequantization is ``S^{-1}(codes + Z) = diag(1/s) · Q · (codes + Z)``
    where ``Q`` is the (involutory) per-group Householder mix.  All fields are
    flat over ``(n_blocks, block_rows, D)``.
    """

    codes: jax.Array        # (nb, n, D) uint8 in [0, B]
    zero: jax.Array         # (nb, n, 1) per-row zero (== its group zero)
    row_scale: jax.Array    # (nb, n, 1) s1 for large rows, s2 otherwise
    n_vec: jax.Array        # (nb, n, 1) Householder normal entry per row
    coef: jax.Array         # (nb, n, 1) 2/||n||² of the row's group (0 if m==1)
    seg: jax.Array          # (nb, n) group id per sorted row
    inv_perm: jax.Array     # (nb, n) maps sorted position -> original row
    bits: int = dataclasses.field(metadata=dict(static=True))
    shape: tuple = dataclasses.field(metadata=dict(static=True))

    def dequant(self) -> jax.Array:
        t = self.codes.astype(jnp.float32) + self.zero
        y = _apply_householder(t, self.seg, self.n_vec, self.coef)
        y = y / self.row_scale
        out = _unpermute(y, self.inv_perm)
        return out.reshape(self.shape)

    @property
    def int8_codes(self) -> jax.Array:
        offset = 1 << (self.bits - 1)
        return (self.codes.astype(jnp.int16) - offset).astype(jnp.int8)

    @property
    def int8_offset(self) -> int:
        return 1 << (self.bits - 1)

    def dequant_epilogue(self, t: jax.Array) -> jax.Array:
        """Apply ``S^{-1}`` + unpermute to ``t`` (same row layout as codes).

        Used by the native int8 GEMM path: ``Q_b(g) @ Wᵀ`` is computed as
        ``S^{-1}((codes + Z) @ Wᵀ)`` — the int GEMM runs on raw codes and this
        O(N·d) VPU epilogue mixes the *output* rows (DESIGN.md Sec. 3).
        """
        y = _apply_householder(t, self.seg, self.n_vec, self.coef)
        y = y / self.row_scale
        return _unpermute(y, self.inv_perm)


def _apply_householder(x: jax.Array, seg: jax.Array, n_vec: jax.Array,
                       coef: jax.Array) -> jax.Array:
    """y = Q x per group: y_j = x_j - n_j * coef_g * (nᵀ x)_g, via segment_sum.

    Shapes: x (nb, n, D), seg (nb, n), n_vec/coef (nb, n, 1).
    """
    def one(xb, segb, nb_, cb):
        n = xb.shape[0]
        # (nᵀ x)_g = sum_j n_j x_j  per group
        ntx = jax.ops.segment_sum(nb_ * xb, segb, num_segments=n)  # (n, D)
        return xb - nb_ * cb * ntx[segb]
    return jax.vmap(one)(x, seg, n_vec, coef)


def _unpermute(x: jax.Array, inv_perm: jax.Array) -> jax.Array:
    def one(xb, pb):
        return jnp.zeros_like(xb).at[pb].set(xb)
    return jax.vmap(one)(x, inv_perm)


def _largest_remainder(weights: jax.Array, total: jax.Array,
                       valid: jax.Array) -> jax.Array:
    """Integerize ``total * weights`` (sum over valid == total), static shape.

    weights: (n,) nonneg, zero where ~valid. Returns int32 sizes (n,).
    """
    n = weights.shape[0]
    wsum = jnp.maximum(jnp.sum(weights), _EPS)
    raw = total * weights / wsum
    base = jnp.floor(raw).astype(jnp.int32)
    base = jnp.where(valid, base, 0)
    rem = raw - base
    rem = jnp.where(valid, rem, -1.0)
    short = total - jnp.sum(base)
    # give +1 to the `short` largest remainders
    order = jnp.argsort(-rem)
    rank = jnp.zeros(n, jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    return base + jnp.where((rank < short) & valid, 1, 0)


def _g_candidates(n: int):
    """Static candidate group counts: 1, 2, 4, ... n//2, and n.

    G = n (singleton groups, Q = I) makes BHQ degrade exactly to PSQ —
    essential when row magnitudes are uniform (early training), where any
    grouping with m >= 2 *amplifies* variance ~m^2 (Appendix D.4 bound with
    lambda2 ~ lambda1).  Caught by tests/test_system.py."""
    cands, g = [], 1
    while g <= max(n // 2, 1):
        cands.append(g)
        g *= 2
    if n not in cands:
        cands.append(n)
    return cands


def _select_g(mag_s: jax.Array, rng_s: jax.Array, n: int, g_search: str):
    """Pick the number of groups G.

    ``paper``   — the paper's Appendix-D.5 proxy (sum_{i<=G} M_i)^2/(N-G),
                  which idealizes lambda2 ~ 0 and can badly mis-group when
                  several comparable outliers exist.
    ``refined`` — (default) score each candidate G with the *full* D.4 bound
                  per group, sum_i (l1_i^{2/3} m_i^{-1/3} + l2^{2/3} m_i^{2/3})^3
                  with l1_i = R(row_i), l2 = 2 M_{G+1}, m_i the heuristic
                  proportional group size.  O(N) per candidate, log2(N)
                  candidates.  DESIGN.md Sec. 6 records this adaptation.
    """
    if g_search == "paper":
        csum = jnp.cumsum(mag_s)
        gs_idx = jnp.arange(1, n, dtype=jnp.float32)
        score = (csum[:-1] ** 2) / (n - gs_idx)
        return jnp.argmin(score).astype(jnp.int32) + 1
    idx = jnp.arange(n, dtype=jnp.float32)
    scores = []
    cands = _g_candidates(n)
    for G in cands:
        mask = idx < G
        msum = jnp.maximum(jnp.sum(jnp.where(mask, mag_s, 0.0)), _EPS)
        m_i = 1.0 + (n - G) * mag_s / msum                    # heuristic sizes
        lam1 = jnp.maximum(rng_s, _EPS)
        lam2 = 2.0 * (mag_s[G] if G < n else 0.0) + _EPS
        term = (lam1 ** (2 / 3) * m_i ** (-1 / 3)
                + lam2 ** (2 / 3) * m_i ** (2 / 3)) ** 3
        scores.append(jnp.sum(jnp.where(mask, term, 0.0)))
    best = jnp.argmin(jnp.stack(scores))
    return jnp.asarray(cands, dtype=jnp.int32)[best]


def _bhq_block(g: jax.Array, key: jax.Array, bits: int, g_search: str):
    """BHQ over one (n, D) block. Returns fields for BHQTensor (block-local)."""
    B = float(num_bins(bits))
    n, d = g.shape

    # --- step 1: sort rows by infinity-norm magnitude, descending ----------
    mag = jnp.max(jnp.abs(g), axis=-1)                       # M_i
    perm = jnp.argsort(-mag)                                 # sorted -> original
    gs = g[perm]
    mag_s = mag[perm]

    # --- step 2: choose the number of groups G ------------------------------
    rng_s = row_dynamic_range(gs)
    G = _select_g(mag_s, rng_s, n, g_search)                 # traced scalar

    idx = jnp.arange(n, dtype=jnp.int32)
    is_large = idx < G

    # --- step 3: group sizes ∝ magnitude, largest-remainder -----------------
    w = jnp.where(is_large, mag_s, 0.0)
    extras = _largest_remainder(w, (n - G).astype(jnp.float32), is_large)
    # small row p (p = j - G in sorted order) joins group searchsorted(cum, p)
    cum = jnp.cumsum(extras)                                  # (n,)
    p = jnp.clip(idx - G, 0, n - 1)
    small_seg = jnp.searchsorted(cum, p, side="right").astype(jnp.int32)
    seg = jnp.where(is_large, idx, jnp.clip(small_seg, 0, n - 1))

    m = (extras + 1).astype(jnp.float32)                      # group sizes (valid < G)
    m = jnp.maximum(m, 1.0)

    # --- step 4: optimal scales (Appendix D.4) -------------------------------
    lam1 = jnp.maximum(rng_s, _EPS)                           # per sorted row; rows < G are the large ones
    lam1_g = jnp.where(is_large, lam1, 1.0)                   # (n,) valid for g < G
    small_mag = jnp.where(is_large, 0.0, mag_s)
    lam2_g = 2.0 * jax.ops.segment_max(small_mag, seg, num_segments=n)
    lam2_g = jnp.maximum(lam2_g, _EPS)

    m_g = jnp.maximum(jax.ops.segment_sum(jnp.ones(n), seg, num_segments=n), 1.0)
    denom = lam1_g ** (2 / 3) * m_g ** (-1 / 3) + lam2_g ** (2 / 3) * m_g ** (2 / 3)
    s1 = B * lam1_g ** (-1 / 3) * m_g ** (1 / 6) / denom
    s2 = B * lam2_g ** (-1 / 3) * m_g ** (1 / 6) / denom

    row_scale = jnp.where(is_large, s1[seg], s2[seg])[:, None]   # (n,1)

    # Householder normal: n_j = 1/sqrt(m) - [j is the group's large row]
    sqrt_m = jnp.sqrt(m_g)[seg]
    n_vec = (1.0 / sqrt_m - is_large.astype(jnp.float32))[:, None]
    # 2/||n||² = sqrt(m)/(sqrt(m)-1); zero for singleton groups (Q = I)
    coef_g = jnp.where(m_g > 1.5, jnp.sqrt(m_g) / jnp.maximum(jnp.sqrt(m_g) - 1.0, _EPS), 0.0)
    coef = coef_g[seg][:, None]

    # --- step 5: transform, per-group zero, stochastic round ----------------
    xs = row_scale * gs
    y = _apply_householder(xs[None], seg[None], n_vec[None], coef[None])[0]
    row_min = jnp.min(y, axis=-1)
    zero_g = jax.ops.segment_min(row_min, seg, num_segments=n)
    zero = zero_g[seg][:, None]
    codes = stochastic_round(y - zero, key)
    codes = jnp.clip(codes, 0.0, B).astype(jnp.uint8)

    inv_perm = perm  # y rows are in sorted order; scatter back via perm
    return codes, zero, row_scale, n_vec, coef, seg, inv_perm


def quantize_bhq_stoch(x: jax.Array, key: jax.Array, bits: int = 8,
                       block_rows: int = 1024,
                       g_search: str = "refined") -> BHQTensor:
    """BHQ over row blocks. x: (..., D) -> rows = prod(leading dims)."""
    shape = x.shape
    rows = x.reshape(-1, shape[-1])
    n = rows.shape[0]
    blk = block_rows if (n % block_rows == 0 and n > block_rows) else n
    nb = n // blk
    gb = rows.reshape(nb, blk, shape[-1])
    keys = jax.random.split(key, nb)
    codes, zero, rs, nv, cf, seg, ip = jax.vmap(
        partial(_bhq_block, bits=bits, g_search=g_search))(gb, keys)
    return BHQTensor(codes=codes, zero=zero, row_scale=rs, n_vec=nv, coef=cf,
                     seg=seg, inv_perm=ip, bits=bits, shape=shape)


def bhq_variance_bound(qt: BHQTensor) -> jax.Array:
    """Eq. (13): Var <= D/4 * ||S^{-1}||_F^2 = D/4 * sum_j (1/s_j)^2.

    (The Householder factor is orthogonal, so ||S^{-1}||_F = ||diag(1/s)||_F.)
    """
    d = qt.shape[-1]
    return d / 4.0 * jnp.sum(1.0 / qt.row_scale ** 2)
