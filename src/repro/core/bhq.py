"""Block Householder Quantizer (BHQ) — StatQuant Sec. 4.2 / Appendix D.4-D.5.

The paper's construction, adapted to TPU/XLA static shapes (DESIGN.md Sec. 3):

  1. sort rows by magnitude ``M_i = ||g_i||_inf`` (descending);
  2. pick the number of groups ``G`` by minimizing the paper's variance proxy
     ``(sum_{i<=G} M_i)^2 / (N - G)`` — vectorized over *all* candidate G with
     one prefix sum instead of the paper's CPU loop;
  3. group ``i`` = the i-th largest row + ``~(N-G) * M_i / sum M`` small rows
     (largest-remainder integerization so sizes sum to N);
  4. scale rows by ``diag(s1, s2, ..., s2)`` with the Lagrangian-optimal
     ``s1 ∝ λ1^{-1/3} m^{1/6}``, ``s2 ∝ λ2^{-1/3} m^{1/6}`` (Appendix D.4),
     then apply the group Householder ``Q = I - 2 n nᵀ / ||n||²``,
     ``n = 1/√m - e1`` — realized as two ``segment_sum``s, never as a matrix;
  5. stochastically round with a per-group zero point.

``Q`` is symmetric and involutory, so dequantization applies the *same*
segment-sum Householder and divides by the row scales: unbiasedness
``E[Q_b(g)] = g`` holds exactly for any grouping (Theorem 1 requirement).

For large N (LM token rows) the grouping runs independently over row blocks of
``block_rows`` via ``vmap`` — bounding the sort cost and keeping the paper's
N≈128-row regime per group search.  Ragged row counts (``n % block_rows != 0``)
are padded up to the next block multiple with all-zero rows: zero rows sort
last, carry zero grouping weight, and the per-block transform stays linear and
invertible, so unbiasedness of the *real* rows is exact; dequantization slices
the padding back off.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from .quantizers import num_bins, stochastic_round, row_dynamic_range

__all__ = ["BHQTensor", "quantize_bhq_stoch", "bhq_variance_bound",
           "bhq_exact_variance"]

_EPS = 1e-12


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BHQTensor:
    """Quantized tensor under the block Householder transform.

    Dequantization is ``S^{-1}(codes + Z) = diag(1/s) · Q · (codes + Z)``
    where ``Q`` is the (involutory) per-group Householder mix.  All fields are
    flat over ``(n_blocks, block_rows, D)``.
    """

    codes: jax.Array        # (nb, n, D) uint8 in [0, B]
    zero: jax.Array         # (nb, n, 1) per-row zero (== its group zero)
    row_scale: jax.Array    # (nb, n, 1) s1 for large rows, s2 otherwise
    n_vec: jax.Array        # (nb, n, 1) Householder normal entry per row
    coef: jax.Array         # (nb, n, 1) 2/||n||² of the row's group (0 if m==1)
    seg: jax.Array          # (nb, n) group id per sorted row
    inv_perm: jax.Array     # (nb, n) maps sorted position -> original row
    bits: int = dataclasses.field(metadata=dict(static=True))
    shape: tuple = dataclasses.field(metadata=dict(static=True))

    @property
    def n_rows(self) -> int:
        """Real (unpadded) row count — blocks may carry zero-padding rows."""
        return math.prod(self.shape[:-1]) if len(self.shape) > 1 else 1

    def dequant(self) -> jax.Array:
        t = self.codes.astype(jnp.float32) + self.zero
        y = _apply_householder(t, self.seg, self.n_vec, self.coef)
        y = y / self.row_scale
        out = _unpermute(y, self.inv_perm)
        return out.reshape(-1, self.shape[-1])[:self.n_rows].reshape(self.shape)

    @property
    def int8_codes(self) -> jax.Array:
        offset = 1 << (self.bits - 1)
        return (self.codes.astype(jnp.int16) - offset).astype(jnp.int8)

    @property
    def int8_offset(self) -> int:
        return 1 << (self.bits - 1)

    def dequant_epilogue(self, t: jax.Array) -> jax.Array:
        """Apply ``S^{-1}`` + unpermute to ``t`` (same row layout as codes).

        Used by the native int8 GEMM path: ``Q_b(g) @ Wᵀ`` is computed as
        ``S^{-1}((codes + Z) @ Wᵀ)`` — the int GEMM runs on raw codes and this
        O(N·d) VPU epilogue mixes the *output* rows (DESIGN.md Sec. 3).
        """
        y = _apply_householder(t, self.seg, self.n_vec, self.coef)
        y = y / self.row_scale
        return _unpermute(y, self.inv_perm)


def _apply_householder(x: jax.Array, seg: jax.Array, n_vec: jax.Array,
                       coef: jax.Array) -> jax.Array:
    """y = Q x per group: y_j = x_j - n_j * coef_g * (nᵀ x)_g, via segment_sum.

    Shapes: x (nb, n, D), seg (nb, n), n_vec/coef (nb, n, 1).
    """
    def one(xb, segb, nb_, cb):
        n = xb.shape[0]
        # (nᵀ x)_g = sum_j n_j x_j  per group
        ntx = jax.ops.segment_sum(nb_ * xb, segb, num_segments=n)  # (n, D)
        return xb - nb_ * cb * ntx[segb]
    return jax.vmap(one)(x, seg, n_vec, coef)


def _unpermute(x: jax.Array, inv_perm: jax.Array) -> jax.Array:
    def one(xb, pb):
        return jnp.zeros_like(xb).at[pb].set(xb)
    return jax.vmap(one)(x, inv_perm)


def _largest_remainder(weights: jax.Array, total: jax.Array,
                       valid: jax.Array) -> jax.Array:
    """Integerize ``total * weights`` (sum over valid == total), static shape.

    weights: (n,) nonneg, zero where ~valid. Returns int32 sizes (n,).
    """
    n = weights.shape[0]
    wsum = jnp.maximum(jnp.sum(weights), _EPS)
    raw = total * weights / wsum
    base = jnp.floor(raw).astype(jnp.int32)
    base = jnp.where(valid, base, 0)
    rem = raw - base
    rem = jnp.where(valid, rem, -1.0)
    short = total - jnp.sum(base)
    # give +1 to the `short` largest remainders
    order = jnp.argsort(-rem)
    rank = jnp.zeros(n, jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    return base + jnp.where((rank < short) & valid, 1, 0)


def _g_candidates(n: int):
    """Static candidate group counts: 1, 2, 4, ... n//2, and n.

    G = n (singleton groups, Q = I) makes BHQ degrade exactly to PSQ —
    essential when row magnitudes are uniform (early training), where any
    grouping with m >= 2 *amplifies* variance ~m^2 (Appendix D.4 bound with
    lambda2 ~ lambda1).  Caught by tests/test_system.py."""
    cands, g = [], 1
    while g <= max(n // 2, 1):
        cands.append(g)
        g *= 2
    if n not in cands:
        cands.append(n)
    return cands


def _select_g(mag_s: jax.Array, rng_s: jax.Array, n: int, g_search: str,
              n_valid=None):
    """Pick the number of groups G.

    ``n_valid``: traced count of real rows (<= the static block size n) —
    ragged blocks carry inert zero-padding rows that must not count as
    small-row budget in either proxy; candidates G > n_valid are masked out.

    ``paper``   — the paper's Appendix-D.5 proxy (sum_{i<=G} M_i)^2/(N-G)
                  for G < N, which idealizes lambda2 ~ 0 and can badly
                  mis-group when several comparable outliers exist.  The
                  PSQ-degenerate candidate G = N (no small rows — the proxy's
                  denominator vanishes) is scored with its *exact* variance
                  sum, sum_i R(x_i)^2 (singleton groups, Q = I: each row's
                  conditional SR variance is D/(4B^2) * R_i^2 and the shared
                  D/(4B^2) factor drops out of the argmin).
    ``refined`` — (default) score each candidate G with the *full* D.4 bound
                  per group, sum_i (l1_i^{2/3} m_i^{-1/3} + l2^{2/3} m_i^{2/3})^3
                  with l1_i = R(row_i), l2 = 2 M_{G+1}, m_i the heuristic
                  proportional group size.  O(N) per candidate, log2(N)
                  candidates.  DESIGN.md Sec. 6 records this adaptation.
    """
    nv = jnp.asarray(n if n_valid is None else n_valid, jnp.float32)
    if g_search == "paper":
        csum = jnp.cumsum(mag_s)
        gs_idx = jnp.arange(1, n, dtype=jnp.float32)
        score = (csum[:-1] ** 2) / jnp.maximum(nv - gs_idx, 1.0)
        score = jnp.where(gs_idx < nv, score, jnp.inf)   # G in [1, nv-1]
        score_n = jnp.sum(rng_s ** 2)[None]              # G = nv: exact (PSQ)
        score = jnp.concatenate([score, score_n])
        best = jnp.argmin(score).astype(jnp.int32)
        return jnp.where(best == n - 1, nv.astype(jnp.int32), best + 1)
    idx = jnp.arange(n, dtype=jnp.float32)
    scores = []
    cands = _g_candidates(n)
    for G in cands:
        mask = idx < G
        msum = jnp.maximum(jnp.sum(jnp.where(mask, mag_s, 0.0)), _EPS)
        m_i = 1.0 + jnp.maximum(nv - G, 0.0) * mag_s / msum   # heuristic sizes
        lam1 = jnp.maximum(rng_s, _EPS)
        lam2 = 2.0 * (mag_s[G] if G < n else 0.0) + _EPS
        term = (lam1 ** (2 / 3) * m_i ** (-1 / 3)
                + lam2 ** (2 / 3) * m_i ** (2 / 3)) ** 3
        score = jnp.sum(jnp.where(mask, term, 0.0))
        scores.append(jnp.where(G <= nv, score, jnp.inf))
    best = jnp.argmin(jnp.stack(scores))
    return jnp.asarray(cands, dtype=jnp.int32)[best]


def _bhq_transform(g: jax.Array, valid: jax.Array, bits: int, g_search: str):
    """The deterministic part of BHQ over one (n, D) block: sort, group,
    scale, Householder.  Returns ``(y, zero, row_scale, n_vec, coef, seg,
    perm)`` where ``y - zero`` is the tensor the stochastic round consumes —
    shared by :func:`_bhq_block` (quantize) and :func:`bhq_exact_variance`
    (exact conditional variance needs the pre-round values).

    ``valid``: (n,) mask of real rows.  Zero-padding rows (ragged inputs)
    sort last and sit in *singleton* groups of their own (Q = I, zero
    scaled value): mixing them into real groups would let a group's small
    rows be all-zero, collapsing its lambda2 and over-scaling the large row
    into deterministic clipping — a bias, not just variance.
    """
    B = float(num_bins(bits))
    n, d = g.shape

    # --- step 1: sort rows by infinity-norm magnitude, descending ----------
    mag = jnp.max(jnp.abs(g), axis=-1)                       # M_i
    mag = jnp.where(valid, mag, -1.0)                        # pads strictly last
    perm = jnp.argsort(-mag)                                 # sorted -> original
    gs = g[perm]
    mag_s = jnp.maximum(mag[perm], 0.0)
    n_valid = jnp.sum(valid.astype(jnp.int32))

    # --- step 2: choose the number of groups G ------------------------------
    rng_s = row_dynamic_range(gs)
    G = _select_g(mag_s, rng_s, n, g_search, n_valid)        # traced scalar
    G = jnp.minimum(G, n_valid)          # group only among the real rows

    idx = jnp.arange(n, dtype=jnp.int32)
    is_large = idx < G
    is_pad = idx >= n_valid

    # --- step 3: group sizes ∝ magnitude, largest-remainder -----------------
    w = jnp.where(is_large, mag_s, 0.0)
    n_small = jnp.maximum(n_valid - G, 0).astype(jnp.float32)
    extras = _largest_remainder(w, n_small, is_large)
    # small row p (p = j - G in sorted order) joins group searchsorted(cum, p)
    cum = jnp.cumsum(extras)                                  # (n,)
    p = jnp.clip(idx - G, 0, n - 1)
    small_seg = jnp.searchsorted(cum, p, side="right").astype(jnp.int32)
    seg = jnp.where(is_large, idx, jnp.clip(small_seg, 0, n - 1))
    seg = jnp.where(is_pad, idx, seg)                         # pads: singletons

    m = (extras + 1).astype(jnp.float32)                      # group sizes (valid < G)
    m = jnp.maximum(m, 1.0)

    # --- step 4: optimal scales (Appendix D.4) -------------------------------
    lam1 = jnp.maximum(rng_s, _EPS)                           # per sorted row; rows < G are the large ones
    lam1_g = jnp.where(is_large, lam1, 1.0)                   # (n,) valid for g < G
    small_mag = jnp.where(is_large, 0.0, mag_s)
    lam2_g = 2.0 * jax.ops.segment_max(small_mag, seg, num_segments=n)
    lam2_g = jnp.maximum(lam2_g, _EPS)

    m_g = jnp.maximum(jax.ops.segment_sum(jnp.ones(n), seg, num_segments=n), 1.0)
    denom = lam1_g ** (2 / 3) * m_g ** (-1 / 3) + lam2_g ** (2 / 3) * m_g ** (2 / 3)
    s1 = B * lam1_g ** (-1 / 3) * m_g ** (1 / 6) / denom
    s2 = B * lam2_g ** (-1 / 3) * m_g ** (1 / 6) / denom

    row_scale = jnp.where(is_large, s1[seg], s2[seg])[:, None]   # (n,1)

    # Householder normal: n_j = 1/sqrt(m) - [j is the group's large row]
    sqrt_m = jnp.sqrt(m_g)[seg]
    n_vec = (1.0 / sqrt_m - is_large.astype(jnp.float32))[:, None]
    # 2/||n||² = sqrt(m)/(sqrt(m)-1); zero for singleton groups (Q = I)
    coef_g = jnp.where(m_g > 1.5, jnp.sqrt(m_g) / jnp.maximum(jnp.sqrt(m_g) - 1.0, _EPS), 0.0)
    coef = coef_g[seg][:, None]

    # --- step 5: transform + per-group zero ---------------------------------
    xs = row_scale * gs
    y = _apply_householder(xs[None], seg[None], n_vec[None], coef[None])[0]
    row_min = jnp.min(y, axis=-1)
    zero_g = jax.ops.segment_min(row_min, seg, num_segments=n)
    zero = zero_g[seg][:, None]
    return y, zero, row_scale, n_vec, coef, seg, perm


def _bhq_block(g: jax.Array, key: jax.Array, valid: jax.Array, bits: int,
               g_search: str):
    """BHQ over one (n, D) block. Returns fields for BHQTensor (block-local)."""
    B = float(num_bins(bits))
    y, zero, row_scale, n_vec, coef, seg, perm = _bhq_transform(
        g, valid, bits, g_search)
    codes = stochastic_round(y - zero, key)
    codes = jnp.clip(codes, 0.0, B).astype(jnp.uint8)
    inv_perm = perm  # y rows are in sorted order; scatter back via perm
    return codes, zero, row_scale, n_vec, coef, seg, inv_perm


def _blocked_rows(x: jax.Array, block_rows: int):
    """Flatten to rows and zero-pad up to a ``block_rows`` multiple.

    Returns ``(blocks (nb, blk, D), valid (nb, blk), n_real)``.  A single
    short input (n <= block_rows) stays one unpadded block; larger ragged
    inputs pad so the per-block group search keeps the paper's
    ~block_rows-row regime instead of silently collapsing to one all-n
    block (unbounded sort cost).
    """
    rows = x.reshape(-1, x.shape[-1])
    n = rows.shape[0]
    blk = block_rows if n > block_rows else n
    n_pad = -(-n // blk) * blk
    if n_pad != n:
        rows = jnp.pad(rows, ((0, n_pad - n), (0, 0)))
    nb = n_pad // blk
    valid = (jnp.arange(n_pad) < n).reshape(nb, blk)
    return rows.reshape(nb, blk, x.shape[-1]), valid, n


def quantize_bhq_stoch(x: jax.Array, key: jax.Array, bits: int = 8,
                       block_rows: int = 1024,
                       g_search: str = "refined") -> BHQTensor:
    """BHQ over row blocks. x: (..., D) -> rows = prod(leading dims).

    Ragged row counts pad with zero rows (zero grouping weight; sliced off
    again by ``dequant``/``dequant_epilogue`` consumers) — unbiasedness of
    the real rows is exact for any grouping, padded or not.
    """
    shape = x.shape
    gb, valid, _ = _blocked_rows(x, block_rows)
    keys = jax.random.split(key, gb.shape[0])
    codes, zero, rs, nv, cf, seg, ip = jax.vmap(
        partial(_bhq_block, bits=bits, g_search=g_search))(gb, keys, valid)
    return BHQTensor(codes=codes, zero=zero, row_scale=rs, n_vec=nv, coef=cf,
                     seg=seg, inv_perm=ip, bits=bits, shape=shape)


def bhq_variance_bound(qt: BHQTensor) -> jax.Array:
    """Eq. (13): Var <= D/4 * ||S^{-1}||_F^2 = D/4 * sum_j (1/s_j)^2.

    (The Householder factor is orthogonal, so ||S^{-1}||_F = ||diag(1/s)||_F.)
    """
    d = qt.shape[-1]
    return d / 4.0 * jnp.sum(1.0 / qt.row_scale ** 2)


def _block_exact_variance(g: jax.Array, retained: jax.Array, *, bits: int,
                          g_search: str) -> jax.Array:
    """Exact conditional variance contributed by one (n, D) block.

    The dequantized noise is ``S^{-1} eps = diag(1/s) Q eps`` with independent
    SR noise ``Var[eps_kd] = p(1-p)``, ``p = frac(y - zero)`` (Proposition 4).
    Summing over the *retained* output rows j (zero-padding rows excluded):

        Var = sum_k w_k * colnorm_k
        w_k       = sum_d p(1-p)_kd
        colnorm_k = sum_{j ret} (Q_jk / s_j)^2
                  = ret_k (1 - 2 c n_k^2)/s_k^2 + c^2 n_k^2 sum_{j in g, ret} n_j^2/s_j^2

    using ``Q_jk = delta_jk - c n_j n_k`` within a group (0 across groups).
    """
    n = g.shape[0]
    y, zero, row_scale, n_vec, coef, seg, perm = _bhq_transform(
        g, retained > 0, bits, g_search)
    t = y - zero
    p = t - jnp.floor(t)
    w = jnp.sum(p * (1.0 - p), axis=-1)                       # (n,)
    s = row_scale[:, 0]
    nv = n_vec[:, 0]
    c = coef[:, 0]
    ret = retained[perm]                                      # sorted order
    a = jax.ops.segment_sum(ret * nv ** 2 / s ** 2, seg, num_segments=n)
    colnorm = ret * (1.0 - 2.0 * c * nv ** 2) / s ** 2 + c ** 2 * nv ** 2 * a[seg]
    return jnp.sum(w * colnorm)


def bhq_exact_variance(x: jax.Array, bits: int = 8, block_rows: int = 1024,
                       g_search: str = "refined") -> jax.Array:
    """Exact conditional ``Var[Q_b(x) | x]`` summed over entries.

    The BHQ transform is deterministic given ``x``; only the stochastic
    rounding injects noise, so the exact variance is the SR ``sum p(1-p)``
    (Proposition 4) pushed through the ``S^{-1}`` columns — see
    :func:`_block_exact_variance`.  Exact modulo the (rare) code clipping at
    the bin boundaries, the same caveat as :func:`~repro.core.quantizers.
    sr_variance_exact`.
    """
    gb, valid, _ = _blocked_rows(x, block_rows)
    per_block = jax.vmap(partial(_block_exact_variance, bits=bits,
                                 g_search=g_search))(
        gb, valid.astype(jnp.float32))
    return jnp.sum(per_block)
