"""Statistical instrumentation for Theorems 1 & 2.

Utilities to *measure* what the paper *proves*:

  * :func:`empirical_mean_and_variance` — Monte-Carlo E[Q(x)] and Var[Q(x)|x]
    for any stochastic quantizer (Theorem 1 / quantizer-variance checks);
  * :func:`quantizer_variance` — exact conditional variance of the SR noise
    given the transform (sum of p(1-p) over entries, Proposition 4);
  * :func:`fqt_gradient_stats` — bias/variance of the FQT gradient of an
    arbitrary model relative to its QAT gradient (Theorem 1/2 end-to-end);
  * :func:`theorem2_path_norms` — the deterministic weights
    ``sum_k ||gamma^{(k,l)}||_2^2`` for a small MLP, via exact Jacobians
    (used to evaluate the Theorem-2 upper bound Eq. 8 in tests/benchmarks).
"""

from __future__ import annotations

from typing import Callable, Dict, Sequence

import jax
import jax.flatten_util
import jax.numpy as jnp

from .bhq import bhq_exact_variance
from .quantizers import (_EPS, dynamic_range, num_bins, row_dynamic_range,
                         sr_variance_exact)

__all__ = [
    "empirical_mean_and_variance",
    "quantizer_variance",
    "fqt_gradient_stats",
    "theorem2_path_norms",
    "variance_of_tree",
]


def variance_of_tree(trees: Sequence) -> float:
    """Var[X] := sum of per-entry variances over a list of pytree samples."""
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
    var = jax.tree.map(lambda s: jnp.sum(jnp.var(s, axis=0)), stacked)
    return float(sum(jax.tree.leaves(var)))


def empirical_mean_and_variance(quant_fn: Callable, x: jax.Array,
                                key: jax.Array, n_samples: int = 256):
    """Monte-Carlo (E[Q(x)], Var[Q(x)|x]) for a stochastic quantizer.

    quant_fn(x, key) -> dequantized array.  Returns (mean, total_variance).
    """
    keys = jax.random.split(key, n_samples)
    samples = jax.lax.map(lambda k: quant_fn(x, k), keys)
    mean = jnp.mean(samples, axis=0)
    var = jnp.sum(jnp.var(samples, axis=0))
    return mean, var


def quantizer_variance(x: jax.Array, quantizer: str = "ptq", bits: int = 8,
                       **params) -> jax.Array:
    """Exact conditional variance ``Var[Q_b(x) | x]`` summed over entries.

    Proposition 4: the stochastic round contributes ``p(1-p)`` per entry,
    ``p = frac(S(x - Z))``; dequantization pushes that noise through the
    transform's inverse, so

      * ``ptq``  —  ``sum p(1-p) / S^2``             (one scalar scale)
      * ``psq``  —  ``sum_i [sum_d p(1-p)]_i / s_i^2``  (per-row scales)
      * ``bhq``  —  the same sum through the ``S^{-1} = diag(1/s) Q`` column
                    norms (:func:`~repro.core.bhq.bhq_exact_variance`);
                    accepts ``block_rows`` / ``g_search``.

    Exact modulo code clipping at the bin boundaries (rare by construction),
    the caveat :func:`~repro.core.quantizers.sr_variance_exact` carries.
    Deterministic — no PRNG key: the variance is a function of the transform
    alone, which is what lets tests cross-check it against
    :func:`empirical_mean_and_variance` without sharing randomness.
    """
    B = num_bins(bits)
    if quantizer == "ptq":
        scale = B / jnp.maximum(dynamic_range(x), _EPS)
        t = scale * (x - jnp.min(x))
        return sr_variance_exact(t) / scale ** 2
    if quantizer == "psq":
        rows = x.reshape(-1, x.shape[-1])
        scale = B / jnp.maximum(row_dynamic_range(rows)[:, None], _EPS)
        t = scale * (rows - jnp.min(rows, axis=-1, keepdims=True))
        p = t - jnp.floor(t)
        return jnp.sum(p * (1.0 - p) / scale ** 2)
    if quantizer == "bhq":
        return bhq_exact_variance(
            x, bits, block_rows=params.get("block_rows", 1024),
            g_search=params.get("g_search", "refined"))
    raise ValueError(f"unknown quantizer {quantizer!r}; "
                     "expected ptq | psq | bhq")


def fqt_gradient_stats(grad_fn: Callable, key: jax.Array,
                       n_samples: int = 64) -> Dict[str, jax.Array]:
    """Bias/variance of a stochastic gradient estimator.

    grad_fn(key) -> gradient pytree (the FQT gradient with quantizer
    randomness keyed by ``key``; the batch B is held fixed by the caller, so
    the returned stats are the *conditional-on-B* quantities of Theorems 1/2).

    The sampling loop runs under ``lax.map`` so ``grad_fn`` compiles once
    and the n_samples evaluations execute compiled (a Python loop here is
    ~50x slower — each eager call re-dispatches every op).
    """
    keys = jax.random.split(key, n_samples)
    stacked = jax.lax.map(grad_fn, keys)
    mean = jax.tree.map(lambda s: jnp.mean(s, axis=0), stacked)
    var = sum(jax.tree.leaves(
        jax.tree.map(lambda s: jnp.sum(jnp.var(s, axis=0), dtype=jnp.float32),
                     stacked)))
    return {"mean": mean, "variance": var}


def theorem2_path_norms(layer_fns: Sequence[Callable], params: Sequence,
                        x0: jax.Array):
    """``sum_{k<=l} ||gamma^{(k,l)}||_2^2`` for a feed-forward chain.

    layer_fns[l](h, params[l]) -> h_next.  Returns a list over l of the
    Theorem-2 weight multiplying the layer-l quantizer variance in Eq. (8).

    gamma^{(k,l)} = (prod_{i=l..k+1} J^{(i)}) K^{(k)} with
    J^(i) = d vec(H^i)/d vec(H^{i-1}),  K^(k) = d vec(H^k)/d vec(Theta^k).
    Exact Jacobians — only feasible for small test networks.
    """
    L = len(layer_fns)
    hs = [x0]
    for l in range(L):
        hs.append(layer_fns[l](hs[-1], params[l]))

    def flat_jac(f, arg):
        j = jax.jacobian(f)(arg)
        return j.reshape(-1, arg.size) if hasattr(arg, "size") else j

    js = []   # J^(l): d vec(h_l) / d vec(h_{l-1}),  (out, in)
    ks = []   # K^(l): d vec(h_l) / d vec(theta_l)
    for l in range(L):
        h_in, p = hs[l], params[l]
        jh = jax.jacobian(lambda h, fn=layer_fns[l], p=p: fn(h, p))(h_in)
        js.append(jh.reshape(hs[l + 1].size, h_in.size))
        p_flat, unravel = jax.flatten_util.ravel_pytree(p)
        jp = jax.jacobian(
            lambda pf, fn=layer_fns[l], h=h_in, un=unravel: fn(h, un(pf)))(
            p_flat)
        ks.append(jp.reshape(hs[l + 1].size, p_flat.size))

    # gamma^{(k,l)}: start from K^{(k)} and push forward through J's.
    # In the paper's row-vector convention vec(grad_H^l) gamma^{(k,l)};
    # with column Jacobians here gamma^{(k,l)} = K^(k)ᵀ prod J^ᵀ — norms match.
    weights = []
    for l in range(L):
        total = jnp.float32(0.0)
        for k in range(l + 1):
            gamma = ks[k].T                       # (theta_k, h_k)
            for i in range(k + 1, l + 1):
                gamma = gamma @ js[i].T           # push to h_l
            total = total + jnp.linalg.norm(gamma, ord=2) ** 2
        weights.append(total)
    return weights
