"""Pluggable quantized-GEMM execution backend: simulate | native | pallas.

This module is the *single source* of the affine-epilogue algebra that turns
integer GEMM accumulators back into real values (previously duplicated
between ``core/fqt.py:qdot`` and ``kernels/ops.py:fused_qlinear`` with two
incompatible code layouts).  The canonical code layout is the unsigned
``QTensor`` one (codes in ``[0, 2^b-1]``, uint8); the MXU consumes
shifted-signed codes ``c8 = codes - 2^(b-1)`` and the conversion happens
exactly once, at this boundary (``QTensor.int8_codes`` /
``QTensor.from_int8``).

Writing each affine operand over shifted-signed codes,

    A-hat_ik = alpha_a,i * a8_ik + beta_a,i     (per-row or per-tensor)
    B-hat_kj = alpha_b   * b8_kj + beta_b       (per-tensor)

the exact product expands into the one epilogue form every quantized GEMM of
the paper produces (forward Eq. 3 and both backward GEMMs of Eq. 6):

    (A-hat B-hat)_ij = acc_ij*rs_i*cs_j + r2_i*u_j + a_i + b_j

    rs_i = alpha_a,i                   cs_j = alpha_b
    r2_i = beta_a,i                    u_j  = alpha_b*colsum(b8)_j + K*beta_b
    a_i  = alpha_a,i*beta_b*rowsum(a8)_i          b_j = bias (free slot)

Three backends evaluate the same algebra:

  ``simulate``  quantize-dequantize fp32 matmul — the paper's GPU simulation
                (App. E), used for accuracy/variance experiments.
  ``native``    ``lax.dot_general(int8, int8, preferred_element_type=int32)``
                (TPU MXU int8 through XLA) + the epilogue as jnp ops.
  ``pallas``    the fused Pallas TPU kernel (``kernels/q8_matmul.py``):
                int32 accumulation and the epilogue in one VMEM-resident
                pass.  ``interpret=True`` emulates on CPU.

All three are dispatched from the ``_fqt`` custom_vjp (core/fqt.py), so the
*same* quantizer algebra drives the full training step — including the BHQ
``S^{-1}`` epilogue of ``BHQTensor.dequant_epilogue`` on the dX GEMM — not
just a forward benchmark.
"""

from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

from ..kernels.fused_fqt import (fused_qboth_tn_matmul,
                                 fused_qboth_tn_matmul_xla,
                                 fused_qlhs_matmul, fused_qlhs_matmul_xla,
                                 fused_qlhs_packed_matmul,
                                 fused_qlhs_packed_matmul_xla)
from ..kernels.pack import PackedTensor
from ..kernels.q4_matmul import packed_matmul, packed_matmul_xla
from ..kernels.q8_matmul import q8_matmul
from ..kernels.quantize_sr import quantize_sr_rows, quantize_sr_tensor
from .bhq import BHQTensor
from .registry import BACKENDS
from .quantizers import QTensor, tensor_min_max

__all__ = [
    "BACKENDS", "resolve_interpret", "affine_factors", "epilogue_coeffs",
    "apply_epilogue", "q8_gemm", "qt_gemm", "qt_gemm_tn", "qt_gemm_nt",
    "quantize_sr_rows_qt", "quantize_sr_tensor_qt", "requantize_det",
    "fused_fqt_fwd", "fused_fqt_dw", "fused_fqt_dx",
]

_EPS = 1e-12        # matches core/quantizers._EPS — one zero-range guard


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Pallas interpret mode: explicit policy knob, else CPU/GPU => emulate."""
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# The affine-epilogue algebra (single source)
# ---------------------------------------------------------------------------

def affine_factors(scale, zero, bits: int):
    """(alpha, beta) with ``x-hat = alpha*c8 + beta`` for shifted codes c8.

    ``x-hat = codes/scale + zero`` and ``c8 = codes - 2^(b-1)``, hence
    ``alpha = 1/scale`` and ``beta = 2^(b-1)/scale + zero``.  Shapes follow
    scale/zero: scalar (per-tensor) or (rows, 1) (per-sample).
    """
    off = 1 << (bits - 1)
    alpha = 1.0 / jnp.asarray(scale, jnp.float32)
    beta = off * alpha + jnp.asarray(zero, jnp.float32)
    return alpha, beta


def _vec(v, n: int) -> jax.Array:
    """Normalize a scalar / (n,) / (n,1) coefficient to a (n,) f32 vector."""
    v = jnp.asarray(v, jnp.float32).reshape(-1)
    return v if v.shape[0] == n else jnp.broadcast_to(v, (n,))


def epilogue_coeffs(a8: jax.Array, alpha_a, beta_a,
                    b8: jax.Array, alpha_b, beta_b, bias=None):
    """The epilogue coefficient vectors (rs, cs, r2, u, a, b).

    a8: (M, K) shifted int8 codes, per-row (or per-tensor) affine factors;
    b8: (K, N) shifted int8 codes, *per-tensor* factors (the transpose of a
    per-tensor operand is still per-tensor, which is what lets the same form
    serve A@B, A.T@B and A@B.T).  ``bias`` fills the free b_j slot.
    """
    m, kdim = a8.shape
    n = b8.shape[1]
    alpha_b = jnp.asarray(alpha_b, jnp.float32).reshape(())
    beta_b = jnp.asarray(beta_b, jnp.float32).reshape(())
    rowsum = jnp.sum(a8.astype(jnp.int32), axis=1).astype(jnp.float32)
    colsum = jnp.sum(b8.astype(jnp.int32), axis=0).astype(jnp.float32)
    rs = _vec(alpha_a, m)
    r2 = _vec(beta_a, m)
    cs = jnp.broadcast_to(alpha_b, (n,))
    u = alpha_b * colsum + float(kdim) * beta_b
    a = rs * beta_b * rowsum
    b = jnp.zeros((n,), jnp.float32) if bias is None else _vec(bias, n)
    return rs, cs, r2, u, a, b


def apply_epilogue(acc: jax.Array, rs, cs, r2, u, a, b) -> jax.Array:
    """out[i,j] = acc[i,j]*rs_i*cs_j + r2_i*u_j + a_i + b_j (f32)."""
    return (acc * rs[:, None] * cs[None, :]
            + r2[:, None] * u[None, :] + a[:, None] + b[None, :])


# ---------------------------------------------------------------------------
# Code-level GEMM dispatch
# ---------------------------------------------------------------------------

def q8_gemm(a8: jax.Array, alpha_a, beta_a, b8: jax.Array, alpha_b, beta_b,
            *, backend: str, interpret: Optional[bool] = None,
            bias=None) -> jax.Array:
    """fp32 value of ``A-hat @ B-hat`` from shifted int8 codes."""
    coeffs = epilogue_coeffs(a8, alpha_a, beta_a, b8, alpha_b, beta_b, bias)
    if backend == "pallas":
        return q8_matmul(a8, b8, *coeffs, interpret=resolve_interpret(interpret))
    if backend == "native":
        acc = jax.lax.dot_general(
            a8, b8, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32).astype(jnp.float32)
        return apply_epilogue(acc, *coeffs)
    raise ValueError(f"unknown int-GEMM backend {backend!r}; "
                     f"expected one of {BACKENDS[1:]}")


# ---------------------------------------------------------------------------
# QTensor-level GEMMs — the three GEMMs of the FQT step
# ---------------------------------------------------------------------------

def _codes2d(qt: QTensor) -> jax.Array:
    return qt.int8_codes.reshape(-1, qt.shape[-1])


def qt_gemm(aq: QTensor, bq: Union[QTensor, PackedTensor], *, backend: str,
            interpret: Optional[bool] = None) -> jax.Array:
    """Forward GEMM  ``A-hat @ B-hat``  (Eq. 3: ``Q_f(X) @ Q_theta(W)``).

    A :class:`PackedTensor` B-operand stays bit-packed in HBM on the
    native/pallas backends — the packed GEMM kernels unpack tiles in VMEM
    inside the K-sweep (kernels/q4_matmul.py); ``simulate`` dequantizes
    either container.
    """
    if backend == "simulate":
        return _codes_dequant2d(aq) @ _codes_dequant2d(bq)
    if isinstance(bq, PackedTensor):
        return _packed_gemm(aq, bq, backend=backend, interpret=interpret)
    alpha_a, beta_a = affine_factors(aq.scale, aq.zero, aq.bits)
    alpha_b, beta_b = affine_factors(bq.scale, bq.zero, bq.bits)
    return q8_gemm(_codes2d(aq), alpha_a, beta_a, _codes2d(bq),
                   alpha_b, beta_b, backend=backend, interpret=interpret)


def _packed_gemm(aq: QTensor, pt: PackedTensor, *, backend: str,
                 interpret: Optional[bool] = None, bias=None) -> jax.Array:
    """``A-hat @ B-hat`` with the B codes bit-packed (kernels/q4_matmul.py).

    The epilogue coefficient vectors need the *unpacked* colsum; computing
    them through ``pt.int8_codes`` keeps the unpack transient — XLA fuses
    the shift/mask chain into the reduce, so no unpacked weight tensor
    lands in HBM and the GEMM itself streams the packed bytes.
    """
    a8 = _codes2d(aq)
    alpha_a, beta_a = affine_factors(aq.scale, aq.zero, aq.bits)
    alpha_b, beta_b = affine_factors(pt.scale, pt.zero, pt.bits)
    coeffs = epilogue_coeffs(a8, alpha_a, beta_a,
                             pt.int8_codes.reshape(-1, pt.shape[-1]),
                             alpha_b, beta_b, bias)
    packed2d = pt.packed.reshape(-1, pt.packed.shape[-1])
    if backend == "pallas":
        return packed_matmul(a8, packed2d, *coeffs, wbits=pt.bits,
                             kdim=pt.kdim,
                             interpret=resolve_interpret(interpret))
    if backend == "native":
        return packed_matmul_xla(a8, packed2d, *coeffs, wbits=pt.bits,
                                 kdim=pt.kdim)
    raise ValueError(f"unknown int-GEMM backend {backend!r}; "
                     f"expected one of {BACKENDS[1:]}")


def qt_gemm_tn(aq: QTensor, bq: QTensor, *, backend: str,
               interpret: Optional[bool] = None) -> jax.Array:
    """Weight-grad GEMM  ``A-hat.T @ B-hat``  (``Q_f(X).T @ Q_b1(dY)``).

    Both operands per-tensor (the paper's Q_b1 recipe), so transposing A
    keeps the factors scalar.
    """
    if backend == "simulate":
        return _codes_dequant2d(aq).T @ _codes_dequant2d(bq)
    alpha_a, beta_a = affine_factors(aq.scale, aq.zero, aq.bits)
    alpha_b, beta_b = affine_factors(bq.scale, bq.zero, bq.bits)
    return q8_gemm(_codes2d(aq).T, alpha_a, beta_a, _codes2d(bq),
                   alpha_b, beta_b, backend=backend, interpret=interpret)


def qt_gemm_nt(aq: Union[QTensor, BHQTensor], bq: Union[QTensor,
               PackedTensor], *, backend: str,
               interpret: Optional[bool] = None) -> jax.Array:
    """Activation-grad GEMM  ``A-hat @ B-hat.T``  (``Q_b2(dY) @ Q_theta(W).T``).

    ``aq`` may be per-row (PSQ), per-tensor (PTQ) or a :class:`BHQTensor` —
    for BHQ the ``S^{-1}`` epilogue commutes with the right-matmul
    (DESIGN.md Sec. 3): ``Q_b(g) @ B-hat.T = S^{-1}((codes + Z) @ B-hat.T)``,
    so the int GEMM runs on raw codes and ``dequant_epilogue`` mixes the
    *output* rows afterwards.

    A :class:`PackedTensor` ``bq`` unpacks transiently here (duck-typed
    ``int8_codes``): the dX contraction runs over the *lane* axis of the
    packed layout, which the packed kernels do not cover — the unpack fuses
    into the transpose read, so no packed copy persists across steps.
    """
    if backend == "simulate":
        a = aq.dequant()
        return (a.reshape(-1, a.shape[-1])
                @ _codes_dequant2d(bq).T)
    bt8 = _codes2d(bq).T
    alpha_b, beta_b = affine_factors(bq.scale, bq.zero, bq.bits)
    if isinstance(aq, BHQTensor):
        nb, blk, _ = aq.codes.shape
        a8 = aq.int8_codes.reshape(nb * blk, -1)
        # Householder-domain value = codes + zero, i.e. alpha=1, beta=off+zero
        beta_a = float(aq.int8_offset) + aq.zero.reshape(nb * blk)
        t = q8_gemm(a8, 1.0, beta_a, bt8, alpha_b, beta_b,
                    backend=backend, interpret=interpret)
        t = t.reshape(nb, blk, -1)
        # ragged inputs carry zero-padding rows in the last block — slice
        # back to the real row count after the S^{-1} epilogue
        return aq.dequant_epilogue(t).reshape(nb * blk, -1)[:aq.n_rows]
    alpha_a, beta_a = affine_factors(aq.scale, aq.zero, aq.bits)
    return q8_gemm(_codes2d(aq), alpha_a, beta_a, bt8, alpha_b, beta_b,
                   backend=backend, interpret=interpret)


def _codes_dequant2d(qt) -> jax.Array:
    d = qt.dequant()
    return d.reshape(-1, d.shape[-1])


# ---------------------------------------------------------------------------
# Fused backward quantizers (Pallas quantize_sr kernels -> canonical QTensor)
# ---------------------------------------------------------------------------

def quantize_sr_rows_qt(x2d: jax.Array, key: jax.Array, bits: int,
                        interpret: Optional[bool] = None) -> QTensor:
    """PSQ stochastic quantize through the fused one-pass kernel.

    Bit-identical to ``quantize_psq_stoch(x2d, key, bits)``: both draw the
    SR uniforms as ``jax.random.bits(key, shape) * 2^-32``.
    """
    rbits = jax.random.bits(key, x2d.shape, jnp.uint32)
    c8, scale, zero = quantize_sr_rows(x2d, rbits, bits,
                                       interpret=resolve_interpret(interpret))
    return QTensor.from_int8(c8, scale, zero, bits, x2d.shape)


def quantize_sr_tensor_qt(x2d: jax.Array, key: jax.Array, bits: int,
                          interpret: Optional[bool] = None) -> QTensor:
    """PTQ stochastic quantize through the fused one-pass kernel."""
    rbits = jax.random.bits(key, x2d.shape, jnp.uint32)
    c8, scale, zero = quantize_sr_tensor(x2d, rbits, bits,
                                         interpret=resolve_interpret(interpret))
    return QTensor.from_int8(c8, scale, zero, bits, x2d.shape)


# ---------------------------------------------------------------------------
# Fully-fused FQT GEMMs (kernels/fused_fqt.py dispatch)
#
# The fused forward never materializes the activation's int8 codes, so its
# residuals are (x2, scale, zero); the backward *rematerializes* the codes
# deterministically when it needs them (``requantize_det`` — bit-identical
# because ptq_det is a pure function of (x, scale, zero)).
# ---------------------------------------------------------------------------

def _ptq_range(x2: jax.Array, bits: int):
    """Per-tensor (zero, scale) exactly as ``quantize_ptq_det``/``_stoch``."""
    B = float((1 << bits) - 1)
    zero, hi = tensor_min_max(x2)
    scale = B / jnp.maximum(hi - zero, _EPS)
    return zero, scale


def requantize_det(x2: jax.Array, scale, zero, bits: int) -> QTensor:
    """Rebuild the deterministic-PTQ QTensor from saved (scale, zero).

    Bit-identical to ``quantize_ptq_det(x2, bits)`` when (scale, zero) came
    from it — the backward's rematerialization of the fused forward's
    never-materialized codes (cheaper than re-reducing min/max)."""
    B = (1 << bits) - 1
    codes = jnp.clip(jnp.round(scale * (x2 - zero)), 0, B).astype(jnp.uint8)
    return QTensor(codes=codes, scale=jnp.asarray(scale),
                   zero=jnp.asarray(zero), bits=bits, shape=x2.shape)


def fused_fqt_fwd(x2: jax.Array, wq: Union[QTensor, PackedTensor],
                  bits_act: int, *, backend: str,
                  interpret: Optional[bool] = None):
    """Forward Eq. 3 ``Q_f(x2) @ W-hat`` with Q_f fused into the K-sweep.

    Returns (y, scale_x, zero_x) — the scale/zero are the residuals the
    backward uses to rematerialize the activation codes."""
    M, K = x2.shape
    zero, scale = _ptq_range(x2, bits_act)
    sa = jnp.broadcast_to(scale, (M, 1))
    za = jnp.broadcast_to(zero, (M, 1))
    w8 = wq.int8_codes.reshape(-1, wq.shape[-1])
    alpha_b, beta_b = affine_factors(wq.scale, wq.zero, wq.bits)
    colsum = jnp.sum(w8.astype(jnp.int32), axis=0).astype(jnp.float32)
    u = alpha_b * colsum + float(K) * beta_b
    if isinstance(wq, PackedTensor):
        # packed-weight fused forward: same u (the transient unpack above
        # fuses into the colsum reduce); the GEMM streams the packed bytes
        packed2d = wq.packed.reshape(-1, wq.packed.shape[-1])
        if backend == "pallas":
            y = fused_qlhs_packed_matmul(
                x2, sa, za, packed2d, alpha_b, beta_b, u, bits=bits_act,
                wbits=wq.bits, interpret=resolve_interpret(interpret))
        elif backend == "native":
            y = fused_qlhs_packed_matmul_xla(
                x2, sa, za, packed2d, alpha_b, beta_b, u, bits=bits_act,
                wbits=wq.bits)
        else:
            raise ValueError(f"unknown fused backend {backend!r}; "
                             f"expected one of {BACKENDS[1:]}")
        return y, scale, zero
    if backend == "pallas":
        y = fused_qlhs_matmul(x2, sa, za, None, w8, alpha_b, beta_b, u,
                              bits=bits_act, tune_key="fused_fwd",
                              interpret=resolve_interpret(interpret))
    elif backend == "native":
        y = fused_qlhs_matmul_xla(x2, sa, za, None, w8, alpha_b, beta_b, u,
                                  bits=bits_act)
    else:
        raise ValueError(f"unknown fused backend {backend!r}; "
                         f"expected one of {BACKENDS[1:]}")
    return y, scale, zero


def fused_fqt_dx(g2: jax.Array, key: jax.Array, spec, wq: QTensor, *,
                 backend: str, interpret: Optional[bool] = None,
                 rbits: Optional[jax.Array] = None) -> jax.Array:
    """Activation-grad GEMM ``Q_b2(g2) @ W-hat.T`` (Eq. 6) with Q_b2 (PTQ
    per-tensor or PSQ per-row) fused into the K-sweep.

    SR uniforms are the same ``random.bits(key, g2.shape)`` draw the
    unfused quantizers make for this key, so codes are bit-identical.
    ``rbits`` lets a caller prefetch that draw (it is a kernel input
    operand, not part of the quantize->GEMM->epilogue pipeline)."""
    bits = spec.bits or 8
    B = float((1 << bits) - 1)
    M, N = g2.shape
    if rbits is None:
        rbits = jax.random.bits(key, g2.shape, jnp.uint32)
    if spec.name == "psq":
        zg = jnp.min(g2, axis=-1, keepdims=True)
        sg = B / jnp.maximum(jnp.max(g2, axis=-1, keepdims=True) - zg, _EPS)
    else:                                   # per-tensor PTQ
        zg0, sg0 = _ptq_range(g2, bits)
        zg = jnp.broadcast_to(zg0, (M, 1))
        sg = jnp.broadcast_to(sg0, (M, 1))
    w8 = wq.int8_codes.reshape(-1, wq.shape[-1])          # (Kw, N) storage
    alpha_b, beta_b = affine_factors(wq.scale, wq.zero, wq.bits)
    # B-operand is w8.T: its colsum over the contraction (N) is w8's rowsum
    rowsum = jnp.sum(w8.astype(jnp.int32), axis=1).astype(jnp.float32)
    u = alpha_b * rowsum + float(N) * beta_b              # (Kw,)
    if backend == "pallas":
        return fused_qlhs_matmul(g2, sg, zg, rbits, w8, alpha_b, beta_b, u,
                                 bits=bits, trans_b=True, tune_key="fused_dx",
                                 interpret=resolve_interpret(interpret))
    if backend == "native":
        return fused_qlhs_matmul_xla(g2, sg, zg, rbits, w8, alpha_b, beta_b,
                                     u, bits=bits, trans_b=True)
    raise ValueError(f"unknown fused backend {backend!r}; "
                     f"expected one of {BACKENDS[1:]}")


def fused_fqt_dw(x2: jax.Array, scale_x, zero_x, bits_act: int,
                 g2: jax.Array, key: jax.Array, bits_wgrad: int, *,
                 backend: str, interpret: Optional[bool] = None,
                 rbits: Optional[jax.Array] = None) -> jax.Array:
    """Weight-grad GEMM ``Q_f(x2).T @ Q_b1(g2)`` (Eq. 6) with both
    quantizes fused into the K-sweep (deterministic X, stochastic per-tensor
    dY).  The epilogue's a_i row vector needs a full column sum of X's
    codes, which the K-sweep never holds — it is rematerialized here as one
    fused XLA reduce over x2 (no int8 tensor in HBM)."""
    bits_wgrad = int(bits_wgrad)
    Bb = float((1 << bits_wgrad) - 1)
    off_b = 1 << (bits_wgrad - 1)
    off_a = 1 << (bits_act - 1)
    Ba = float((1 << bits_act) - 1)
    zg, hg = tensor_min_max(g2)
    sg = Bb / jnp.maximum(hg - zg, _EPS)
    if rbits is None:
        rbits = jax.random.bits(key, g2.shape, jnp.uint32)
    ca = jnp.clip(jnp.round(scale_x * (x2 - zero_x)), 0.0, Ba) - off_a
    alpha_a = 1.0 / scale_x
    alpha_b = 1.0 / sg
    beta_b = off_b * alpha_b + zg
    a_vec = (alpha_a * beta_b) * jnp.sum(ca, axis=0)      # (Kw,)
    if backend == "pallas":
        return fused_qboth_tn_matmul(
            x2, scale_x, zero_x, g2, sg, zg, rbits, a_vec,
            bits_a=bits_act, bits_b=bits_wgrad, tune_key="fused_dw",
            interpret=resolve_interpret(interpret))
    if backend == "native":
        return fused_qboth_tn_matmul_xla(x2, scale_x, zero_x, g2, sg, zg,
                                         rbits, a_vec, bits_a=bits_act,
                                         bits_b=bits_wgrad)
    raise ValueError(f"unknown fused backend {backend!r}; "
                     f"expected one of {BACKENDS[1:]}")
