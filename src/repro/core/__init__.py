"""StatQuant core: the paper's contribution as a composable JAX module."""

from .backend import (BACKENDS, affine_factors, apply_epilogue,
                      epilogue_coeffs, q8_gemm, qt_gemm, qt_gemm_nt,
                      qt_gemm_tn, quantize_sr_rows_qt, quantize_sr_tensor_qt,
                      resolve_interpret)
from .bhq import (BHQTensor, bhq_exact_variance, bhq_variance_bound,
                  quantize_bhq_stoch)
from .compression import (compressed_grad_allreduce, compressed_psum,
                          compression_variance_bound)
from .exempt import (clear_exemptions, exemption_registry, fp_exempt,
                     quant_scope)
from .fqt import fqt_matmul
from .kv_cache import (dequant_kv_rows, kv_cache_bytes_per_row,
                       kv_fresh_code, quantize_kv_rows)
from .policy import EXACT, FQT8_BHQ, QAT, QuantPolicy, RoleOverride
from .quantizers import (QTensor, dynamic_range, num_bins,
                         psq_variance_bound, ptq_variance_bound,
                         quantize_psq_stoch, quantize_ptq_det,
                         quantize_ptq_stoch, row_dynamic_range, sr_uniform,
                         sr_variance_exact, stochastic_round)
from .registry import (KV_CACHE_ROLE, ROLES, GemmQuantConfig, Quantizer,
                       QuantizerSpec, available_quantizers, get_quantizer,
                       register_quantizer, resolve_kv_cache_spec)

__all__ = [
    "BHQTensor", "QTensor", "QuantPolicy", "RoleOverride", "EXACT", "QAT",
    "FQT8_BHQ",
    # role-based quantizer API (core/registry.py)
    "ROLES", "KV_CACHE_ROLE", "QuantizerSpec", "GemmQuantConfig", "Quantizer",
    "register_quantizer", "get_quantizer", "available_quantizers",
    "resolve_kv_cache_spec",
    # exemption registry + jaxpr markers (core/exempt.py, repro.analysis)
    "fp_exempt", "quant_scope", "exemption_registry", "clear_exemptions",
    "fqt_matmul", "num_bins", "dynamic_range", "row_dynamic_range",
    "sr_uniform", "stochastic_round", "quantize_ptq_det",
    "quantize_ptq_stoch", "quantize_psq_stoch", "quantize_bhq_stoch",
    "ptq_variance_bound", "psq_variance_bound", "bhq_variance_bound",
    "sr_variance_exact", "bhq_exact_variance",
    # int8 KV-cache codec (core/kv_cache.py, serving decode path)
    "quantize_kv_rows", "dequant_kv_rows", "kv_cache_bytes_per_row",
    "kv_fresh_code",
    "compressed_psum", "compressed_grad_allreduce",
    "compression_variance_bound",
    # backend seam (core/backend.py — the single source of epilogue algebra)
    "BACKENDS", "resolve_interpret", "affine_factors", "epilogue_coeffs",
    "apply_epilogue", "q8_gemm", "qt_gemm", "qt_gemm_tn", "qt_gemm_nt",
    "quantize_sr_rows_qt", "quantize_sr_tensor_qt",
]
