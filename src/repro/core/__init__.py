"""StatQuant core: the paper's contribution as a composable JAX module."""

from .bhq import BHQTensor, bhq_variance_bound, quantize_bhq_stoch
from .compression import (compressed_grad_allreduce, compressed_psum,
                          compression_variance_bound)
from .fqt import fqt_matmul, qdot
from .policy import EXACT, FQT8_BHQ, QAT, QuantPolicy
from .quantizers import (QTensor, dynamic_range, num_bins,
                         psq_variance_bound, ptq_variance_bound,
                         quantize_psq_stoch, quantize_ptq_det,
                         quantize_ptq_stoch, row_dynamic_range,
                         sr_variance_exact, stochastic_round)

__all__ = [
    "BHQTensor", "QTensor", "QuantPolicy", "EXACT", "QAT", "FQT8_BHQ",
    "fqt_matmul", "qdot", "num_bins", "dynamic_range", "row_dynamic_range",
    "stochastic_round", "quantize_ptq_det", "quantize_ptq_stoch",
    "quantize_psq_stoch", "quantize_bhq_stoch",
    "ptq_variance_bound", "psq_variance_bound", "bhq_variance_bound",
    "sr_variance_exact", "compressed_psum", "compressed_grad_allreduce",
    "compression_variance_bound",
]
