"""Quantizers from the StatQuant paper (NeurIPS 2020).

Implements the quantizer family of Sec. 2-4:

  * deterministic per-tensor quantizer (``Q_f``/``Q_theta``, forward pass)
  * stochastic per-tensor quantizer  PTQ  (baseline ``Q_b``; Sec. 3.3)
  * per-sample quantizer             PSQ  (Sec. 4.1)
  * block Householder quantizer      BHQ  (Sec. 4.2, in :mod:`repro.core.bhq`)

All stochastic quantizers are *unbiased*: ``E[Q_b(x)] = x`` (the basis of
Theorem 1).  Every quantizer returns a :class:`QTensor` carrying the integer
codes plus the affine metadata needed for exact dequantization, so callers can
either materialize the dequantized float tensor (``simulate`` path — what the
paper does on GPU, Sec. E) or feed the int8 codes straight into an int8 GEMM
(``native`` path — the deployed TPU MXU execution).

Row convention: for an input of shape ``(..., D)`` the "samples" of PSQ/BHQ
are all leading axes flattened, i.e. each length-``D`` row is one sample.  For
LMs that makes per-sample == per-token, which is where the gradient sparsity
the paper exploits lives (DESIGN.md Sec. 6).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = [
    "QTensor",
    "num_bins",
    "sr_uniform",
    "stochastic_round",
    "quantize_ptq_det",
    "quantize_ptq_stoch",
    "quantize_psq_stoch",
    "dynamic_range",
    "row_dynamic_range",
]

# Tiny epsilon guarding against zero dynamic range (constant rows quantize to
# a single code with zero variance; scale must stay finite).
_EPS = 1e-12


def num_bins(bits: int) -> int:
    """B = 2^b - 1 quantization bins (paper Sec. 3.3)."""
    return (1 << bits) - 1


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QTensor:
    """Affine-quantized tensor ``x ~= codes / scale + zero``.

    ``codes`` are stored as int8 biased by -128 when ``bits == 8`` would
    overflow signed range; we instead keep the *unbiased* integer code in
    ``int32`` on the simulate path and a shifted ``int8`` code (code - 2^(b-1))
    on the native path.  ``scale`` / ``zero`` broadcast against ``codes``:

      * per-tensor:  scalar scale, scalar zero
      * per-sample:  scale/zero of shape ``(rows, 1)`` against flattened rows

    Dequantization is exactly ``codes / scale + zero`` (paper Eq. in Sec. 3.3:
    ``Q_b(x) = SR(S (x - Z)) / S + Z``).
    """

    codes: jax.Array          # unsigned integer codes in [0, 2^b-1], uint8
    scale: jax.Array          # S
    zero: jax.Array           # Z
    bits: int = dataclasses.field(metadata=dict(static=True))
    shape: tuple = dataclasses.field(metadata=dict(static=True))

    def dequant(self) -> jax.Array:
        flat = self.codes.astype(jnp.float32) / self.scale + self.zero
        return flat.reshape(self.shape)

    @property
    def int8_codes(self) -> jax.Array:
        """Codes shifted to signed int8 for MXU consumption (code - 2^(b-1))."""
        offset = 1 << (self.bits - 1)
        return (self.codes.astype(jnp.int16) - offset).astype(jnp.int8)

    @property
    def int8_offset(self) -> int:
        return 1 << (self.bits - 1)

    @classmethod
    def from_int8(cls, codes8: jax.Array, scale, zero, bits: int,
                  shape) -> "QTensor":
        """Boundary conversion from the kernels' shifted-signed int8 layout
        (``c8 = code - 2^(b-1)``) to the canonical unsigned layout.

        A backend GEMM consuming this tensor shifts back via ``int8_codes``;
        the round-trip is deliberate — one canonical layout at every module
        boundary is the invariant this refactor exists for, and the paired
        elementwise shifts fuse into the adjacent XLA elementwise chain,
        noise next to the O(M*N*K) GEMM they bracket."""
        off = 1 << (bits - 1)
        codes = (codes8.astype(jnp.int16) + off).astype(jnp.uint8)
        return cls(codes=codes, scale=jnp.asarray(scale),
                   zero=jnp.asarray(zero), bits=bits, shape=tuple(shape))


def opt_barrier(x):
    """``jax.lax.optimization_barrier`` that degrades to identity under
    transforms that can't batch it (jax<0.5 has no vmap rule for the
    primitive).  The barrier only pins a faster XLA schedule — dropping it
    is always semantically safe."""
    try:
        return jax.lax.optimization_barrier(x)
    except NotImplementedError:
        return x


def tensor_min_max(x: jax.Array):
    """(min X, max X) in one fused sweep.

    Row-wise paired min/max reductions compile to a single pass over the
    tensor; the ``optimization_barrier`` stops XLA from re-associating the
    two-stage reduction back into two independent full-tensor sweeps
    (measured ~2.3x slower on CPU).  min-of-row-mins is exactly the flat
    min — no numerical change, only a faster schedule.
    """
    if x.ndim < 2:
        return jnp.min(x), jnp.max(x)
    r = x.reshape(-1, x.shape[-1])
    lo = jnp.min(r, axis=-1)
    hi = jnp.max(r, axis=-1)
    lo, hi = opt_barrier((lo, hi))
    return jnp.min(lo), jnp.max(hi)


def dynamic_range(x: jax.Array) -> jax.Array:
    """R(X) = max X - min X over the whole tensor (paper Sec. 3.3)."""
    lo, hi = tensor_min_max(x)
    return hi - lo


def row_dynamic_range(x2d: jax.Array) -> jax.Array:
    """Per-row dynamic range R(x_i) for an (N, D) matrix (paper Sec. 4.1)."""
    return jnp.max(x2d, axis=-1) - jnp.min(x2d, axis=-1)


def sr_uniform(key: jax.Array, shape, dtype=jnp.float32) -> jax.Array:
    """U[0,1) uniforms for SR, derived as ``random.bits * 2^-32``.

    This is the ONE convention for SR randomness across the stack: the
    fused Pallas quantize kernels (kernels/quantize_sr.py) take raw uint32
    bits and apply the same ``* 2^-32`` inside, so for a given key the
    ``simulate``/``native`` XLA quantizers and the ``pallas`` kernels emit
    bit-identical codes.
    """
    bits = jax.random.bits(key, shape, jnp.uint32)
    return bits.astype(dtype) * (1.0 / 4294967296.0)


def stochastic_round(x: jax.Array, key: jax.Array) -> jax.Array:
    """SR(x): ceil w.p. frac(x), floor otherwise — unbiased (paper Sec. 3.3).

    Implemented as floor(x + u), u ~ U[0,1): E[SR(x)] = x and
    Var[SR(x)] = p(1-p) <= 1/4 (Proposition 4).
    """
    u = sr_uniform(key, x.shape, x.dtype)
    return jnp.floor(x + u)


def _flatten_rows(x: jax.Array) -> jax.Array:
    return x.reshape(-1, x.shape[-1])


# ---------------------------------------------------------------------------
# Per-tensor quantizers
# ---------------------------------------------------------------------------

def quantize_ptq_det(x: jax.Array, bits: int = 8) -> QTensor:
    """Deterministic per-tensor quantizer (forward-pass Q_f / Q_theta).

    Round-to-nearest; biased in general but deterministic, as the framework
    requires for the forward pass (Sec. 2.1 assumption).
    """
    B = num_bins(bits)
    zero, hi = tensor_min_max(x)
    scale = B / jnp.maximum(hi - zero, _EPS)
    codes = jnp.clip(jnp.round(scale * (x - zero)), 0, B).astype(jnp.uint8)
    return QTensor(codes=codes, scale=scale, zero=zero, bits=bits, shape=x.shape)


def quantize_ptq_stoch(x: jax.Array, key: jax.Array, bits: int = 8) -> QTensor:
    """PTQ: stochastic per-tensor quantizer (paper Sec. 3.3).

    Q_b(x) = SR(S (x - Z)) / S + Z with Z = min x, S = B / R(x).
    Unbiased: E[Q_b(x)] = x. Variance <= N D R(x)^2 / (4 B^2)  (Eq. 9).
    """
    B = num_bins(bits)
    zero, hi = tensor_min_max(x)
    scale = B / jnp.maximum(hi - zero, _EPS)
    t = scale * (x - zero)                      # in [0, B] by construction
    codes = stochastic_round(t, key)            # SR keeps [0, B]: frac at B is 0
    codes = jnp.clip(codes, 0, B).astype(jnp.uint8)
    return QTensor(codes=codes, scale=scale, zero=zero, bits=bits, shape=x.shape)


def quantize_psq_stoch(x: jax.Array, key: jax.Array, bits: int = 8) -> QTensor:
    """PSQ: stochastic per-sample quantizer (paper Sec. 4.1).

    S = diag(s_1..s_N), s_i = B / R(x_i) — the optimum of problem (12) for
    diagonal S (Appendix D.3). Per-row zero z_i = min x_i.  Variance
    <= D/(4B^2) * sum_i R(x_i)^2 <= PTQ's N D R(X)^2/(4B^2).
    """
    B = num_bins(bits)
    rows = _flatten_rows(x)
    zero = jnp.min(rows, axis=-1, keepdims=True)            # (N, 1)
    rng = jnp.maximum(row_dynamic_range(rows)[:, None], _EPS)
    scale = B / rng                                          # (N, 1)
    t = scale * (rows - zero)
    codes = stochastic_round(t, key)
    codes = jnp.clip(codes, 0, B).astype(jnp.uint8)
    return QTensor(codes=codes, scale=scale, zero=zero, bits=bits, shape=x.shape)


# ---------------------------------------------------------------------------
# Conditional quantizer variance (for Theorem-2 bookkeeping / benchmarks)
# ---------------------------------------------------------------------------

def ptq_variance_bound(x: jax.Array, bits: int) -> jax.Array:
    """Eq. (9): Var[Q_b(X)|X] <= N D R(X)^2 / (4 B^2)."""
    B = num_bins(bits)
    n = x.size
    return n * dynamic_range(x) ** 2 / (4.0 * B * B)


def psq_variance_bound(x: jax.Array, bits: int) -> jax.Array:
    """Appendix D.3: Var <= D/(4B^2) * sum_i R(x_i)^2."""
    B = num_bins(bits)
    rows = _flatten_rows(x)
    d = rows.shape[-1]
    return d * jnp.sum(row_dynamic_range(rows) ** 2) / (4.0 * B * B)


def sr_variance_exact(t: jax.Array) -> jax.Array:
    """Exact SR variance sum: sum_ij p(1-p), p = frac(t) (Proposition 4)."""
    p = t - jnp.floor(t)
    return jnp.sum(p * (1.0 - p))
