"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state — the dry-run must set XLA_FLAGS before any jax init.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh", "mesh_kwargs"]


def mesh_kwargs(n_axes: int) -> dict:
    """``axis_types=(Auto,)*n`` where the installed jax supports it.

    ``jax.sharding.AxisType`` only exists on newer jax; older releases
    (e.g. 0.4.x) treat every axis as Auto already, so omitting the kwarg is
    semantically identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model); the `pod` axis is
    pure DP across the datacenter interconnect."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_kwargs(len(axes)))


def make_test_mesh(data: int = 2, model: int = 4):
    """Small mesh for CPU tests (requires >= data*model fake devices)."""
    return jax.make_mesh((data, model), ("data", "model"), **mesh_kwargs(2))
