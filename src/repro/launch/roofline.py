"""Roofline-term extraction from compiled AOT artifacts.

Per (arch x shape x mesh) cell (assignment Sec. ROOFLINE ANALYSIS):

  compute    = HLO_FLOPs_per_device  / peak_FLOP/s          (= F_g/(chips*peak))
  memory     = HLO_bytes_per_device  / HBM_bw
  collective = collective_bytes_per_device / link_bw

``cost_analysis``/``as_text`` of an SPMD-partitioned executable describe the
*per-device* program, so dividing by per-chip peaks is exactly the
spec's  global/(chips x peak)  formula.

collective_bytes is not in cost_analysis: we parse the optimized HLO and sum
the operand bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.
"""

from __future__ import annotations

import re
from typing import Dict

__all__ = ["HW", "collective_bytes", "roofline_terms", "model_flops",
           "peak_for_backend"]

# TPU v5e-class hardware constants (assignment-provided)
HW = {
    "peak_bf16": 197e12,      # FLOP/s per chip
    "peak_int8": 394e12,      # 2x bf16 on the MXU
    "hbm_bw": 819e9,          # B/s per chip
    "link_bw": 50e9,          # B/s per ICI link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

# result-type token(s): e.g. "f32[64,512]{1,0}" or "(s8[8,29], u32[2])"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by each collective family (+ op counts).

    Sums the *result* type of each collective instruction (for all-reduce
    result==operand size; for all-gather it is the gathered size — an upper
    bound on wire bytes; for reduce-scatter the scattered output — a lower
    bound; start/done pairs counted once via the `-start` form when present).
    """
    out = {k: 0 for k in _COLL_OPS}
    counts = {k: 0 for k in _COLL_OPS}
    seen_start = set()
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?([\w.\-]+)\s*=\s*(.*)", ls)
        if not m:
            continue
        rhs = m.group(2)
        for op in _COLL_OPS:
            # match "<type> all-reduce(" or "all-reduce-start("
            om = re.search(r"^(.*?)\s" + op + r"(-start)?\(", rhs)
            if om:
                if f" {op}-done(" in rhs:
                    break
                out[op] += _shape_bytes(om.group(1))
                counts[op] += 1
                break
    out["total"] = sum(out[k] for k in _COLL_OPS)
    out["counts"] = counts
    return out


def model_flops(n_params: int, n_tokens: int, kind: str,
                active_frac: float = 1.0) -> float:
    """The 6ND (train) / 2ND (forward) 'useful flops' yardstick.

    n_params: total matmul-visible params; active_frac: MoE top-k/E scaling
    on expert params folded in by the caller via `active params`."""
    per_tok = 6.0 if kind == "train" else 2.0
    return per_tok * n_params * active_frac * n_tokens


def peak_for_backend(backend: str) -> float:
    """Chip peak FLOP/s for a QuantPolicy.backend.

    ``native``/``pallas`` execute the GEMMs on int8 MXU (2x bf16 peak);
    ``simulate`` is the fp32 QDQ path, so the bf16 peak is the right
    denominator for its compute roofline term.
    """
    return HW["peak_bf16"] if backend == "simulate" else HW["peak_int8"]


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: int, int8: bool = True,
                   backend: str | None = None) -> dict:
    if backend is not None:
        peak = peak_for_backend(backend)
    else:
        peak = HW["peak_int8"] if int8 else HW["peak_bf16"]
    t_c = flops / peak
    t_m = bytes_accessed / HW["hbm_bw"]
    t_n = coll_bytes / HW["link_bw"]
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
             "compute_s_bf16peak": flops / HW["peak_bf16"]}
    dom = max(("compute_s", t_c), ("memory_s", t_m), ("collective_s", t_n),
              key=lambda kv: kv[1])
    terms["bottleneck"] = dom[0].replace("_s", "")
    terms["step_time_lb_s"] = dom[1]
    return terms
