import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any jax import: jax locks the device count
at first init, and the production meshes need 512 host-platform placeholder
devices.  (Tests/benches never import this module — they see 1 device.)

Per cell this script:
  1. builds abstract params (jax.eval_shape — no allocation),
  2. builds ShapeDtypeStruct inputs (model.input_specs, bf16 activations),
  3. jits train_step / prefill / decode with the sharding plan's
     in/out_shardings (+ sequence parallelism on the residual stream),
     ``.lower()`` s and ``.compile()`` s it,
  4. records memory_analysis / cost_analysis / parsed collective bytes,
  5. corrects the per-device FLOP/byte/collective totals for XLA's
     count-scan-bodies-once behaviour by compiling tiny UNROLLED probe
     variants (1 and 2 layers at full width) and composing
     total = stem + n_layers * body   — exact w.r.t. XLA's own counting,
  6. derives the three roofline terms (launch/roofline.py) and writes
     experiments/dryrun/<arch>__<shape>__<mesh>.json.

Failures (sharding mismatch, OOM at compile, unsupported collective) are
bugs in the system — the run aborts loudly.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# Persistent compilation cache: hillclimb iterations re-lower unchanged cells
# for free; cache key includes the HLO so edited cells recompile.
jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")
jax.config.update("jax_persistent_cache_min_compile_time_secs", 10)
# NOTE: rbg PRNG was evaluated for the SR uniforms and REJECTED: on the
# XLA:CPU AOT backend it blows buffer assignment up ~40x (2 TiB vs 50 GiB
# temp for minitron train_4k). threefry + loss-chunking is the right config;
# see EXPERIMENTS.md perf log.

from ..configs import ARCH_NAMES, SHAPES, get_config, shape_grid
from ..core import QuantPolicy
from ..engine import abstract_train_state, jit_step, make_step_fn
from ..models import build_model
from ..optim import sgd
from ..sharding import make_plan
from .mesh import make_production_mesh
from .roofline import collective_bytes, model_flops, roofline_terms

__all__ = ["run_cell", "main"]

ACT_DTYPE = jnp.bfloat16


def count_params(abstract_params) -> int:
    import math
    return sum(math.prod(l.shape) for l in jax.tree.leaves(abstract_params))


def active_param_frac(cfg) -> float:
    """MoE: fraction of expert params active per token (top-k / E)."""
    if not cfg.moe_experts:
        return 1.0
    d, ff, E, K = cfg.d_model, cfg.d_ff, cfg.moe_experts, cfg.moe_topk
    expert = (3 if cfg.act == "swiglu" else 2) * d * ff * E
    hd = cfg.hd
    attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd + cfg.n_heads * hd * d
    dense_part = attn + d * E
    total = expert + dense_part
    return (expert * (K / E) + dense_part) / total


def _act_sharding(plan, shape):
    """Sequence-parallel residual-stream sharding for train cells."""
    if shape.kind != "train":
        return None
    dp = plan._dp(shape.global_batch)
    if dp is None:
        return None
    return NamedSharding(plan.mesh, P(dp, plan.model_axis, None))


def _compile(cfg, shape, plan, policy, opt, sp: bool = True,
             extra_kwargs: dict | None = None):
    """Lower + compile one module; returns (compiled, abstract_params)."""
    extra_kwargs = extra_kwargs or {}
    model = build_model(cfg)
    abstract_params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_specs = plan.param_specs(abstract_params)
    specs_in = model.input_specs(shape, dtype=ACT_DTYPE)
    b_specs = plan.batch_specs(specs_in["batch"])

    if shape.kind == "train":
        act_sh = _act_sharding(plan, shape) if sp else None
        extra_kwargs = dict(extra_kwargs)
        compress_axis = extra_kwargs.pop("compress_axis", None)
        remat = extra_kwargs.pop("remat", True)
        accum_steps = extra_kwargs.pop("accum_steps", 1)
        astate = abstract_train_state(model, opt)
        step_fn = make_step_fn(
            model, policy, opt, lambda s: 1e-3, remat=remat,
            accum_steps=accum_steps, mesh=plan.mesh,
            compress_axis=compress_axis,
            loss_kwargs={"dtype": ACT_DTYPE, "act_sharding": act_sh,
                         "loss_chunks": 16, **extra_kwargs})
        jf = jit_step(step_fn, plan=plan, abstract_state=astate,
                      batch_shardings=plan.shardings(b_specs))
        lowered = jf.lower(astate, specs_in["batch"])
    elif shape.kind == "prefill":
        jf = jax.jit(
            lambda params, batch: model.prefill(params, batch, policy,
                                                dtype=ACT_DTYPE,
                                                **extra_kwargs),
            in_shardings=(plan.shardings(p_specs), plan.shardings(b_specs)))
        lowered = jf.lower(abstract_params, specs_in["batch"])
    else:
        c_specs = plan.cache_specs(specs_in["cache"])
        jf = jax.jit(
            lambda params, cache, batch: model.decode(params, cache, batch,
                                                      policy),
            in_shardings=(plan.shardings(p_specs), plan.shardings(c_specs),
                          plan.shardings(b_specs)),
            out_shardings=(None, plan.shardings(c_specs)),
            donate_argnums=(1,))
        lowered = jf.lower(abstract_params, specs_in["cache"],
                           specs_in["batch"])
    return lowered.compile(), abstract_params


def _metrics(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):       # older jax: one dict per device
        cost = cost[0] if cost else {}
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": {k: coll[k] for k in coll if k != "counts"},
            "coll_counts": coll["counts"]}


def _combine(stem, bodies):
    """corrected = stem + sum_i n_i * body_i  (elementwise over metrics)."""
    out = {"flops": stem["flops"], "bytes": stem["bytes"],
           "coll": dict(stem["coll"]),
           "coll_counts": dict(stem["coll_counts"])}
    for n, b in bodies:
        out["flops"] += n * b["flops"]
        out["bytes"] += n * b["bytes"]
        for k in out["coll"]:
            out["coll"][k] += n * b["coll"][k]
        for k in out["coll_counts"]:
            out["coll_counts"][k] += n * b["coll_counts"][k]
    return out


def _diff(m2, m1):
    return {"flops": m2["flops"] - m1["flops"],
            "bytes": m2["bytes"] - m1["bytes"],
            "coll": {k: m2["coll"][k] - m1["coll"][k] for k in m2["coll"]},
            "coll_counts": {k: m2["coll_counts"][k] - m1["coll_counts"][k]
                            for k in m2["coll_counts"]}}


def _probe_corrected(cfg, shape, plan, policy, opt, sp=True,
                     log=lambda *a: None, extra_kwargs=None):
    """Scan-corrected per-device metrics via unrolled 1/2-layer probes."""
    def probe(**over):
        pc = dataclasses.replace(cfg, unroll_scan=True, **over)
        t0 = time.time()
        compiled, _ = _compile(pc, shape, plan, policy, opt, sp=sp,
                               extra_kwargs=extra_kwargs)
        log(f"    probe {over} compiled in {time.time()-t0:.0f}s")
        return _metrics(compiled)

    if cfg.family == "audio":
        m11 = probe(n_layers=1, enc_layers=1)
        m21 = probe(n_layers=1, enc_layers=2)
        m12 = probe(n_layers=2, enc_layers=1)
        enc_b, dec_b = _diff(m21, m11), _diff(m12, m11)
        stem = _combine(m11, [(-1, enc_b), (-1, dec_b)])
        return _combine(stem, [(cfg.enc_layers, enc_b),
                               (cfg.n_layers, dec_b)])
    if cfg.family == "hybrid":
        p = cfg.hybrid_period
        m1 = probe(n_layers=p)
        m2 = probe(n_layers=2 * p)
        body = _diff(m2, m1)
        stem = _combine(m1, [(-1, body)])
        return _combine(stem, [(cfg.n_layers // p, body)])
    m1 = probe(n_layers=1)
    m2 = probe(n_layers=2)
    body = _diff(m2, m1)
    stem = _combine(m1, [(-1, body)])
    return _combine(stem, [(cfg.n_layers, body)])


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             policy: QuantPolicy | None = None, mesh=None,
             correct_scan: bool = True, sp: bool = True,
             verbose: bool = True, extra_kwargs: dict | None = None) -> dict:
    """Lower + compile one cell; return the roofline record."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    policy = policy or QuantPolicy.fqt("bhq", 5, backend="native",
                                       bhq_block=1024)
    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    n_chips = 1
    for v in mesh.shape.values():
        n_chips *= v
    plan = make_plan(mesh)
    opt = sgd(momentum=0.9)
    log = (lambda *a: print(*a, flush=True)) if verbose else (lambda *a: None)

    t0 = time.time()
    with mesh:
        compiled, aparams = _compile(cfg, shape, plan, policy, opt, sp=sp,
                                     extra_kwargs=extra_kwargs)
        t_full = time.time() - t0
        raw = _metrics(compiled)
        mem = compiled.memory_analysis()
        if correct_scan:
            m = _probe_corrected(cfg, shape, plan, policy, opt, sp=sp,
                                 log=log, extra_kwargs=extra_kwargs)
        else:
            m = raw

    n_params = count_params(aparams)
    n_tokens = (shape.global_batch * shape.seq_len
                if shape.kind in ("train", "prefill") else shape.global_batch)
    mf = model_flops(n_params, n_tokens,
                     "train" if shape.kind == "train" else "fwd",
                     active_frac=active_param_frac(cfg))
    terms = roofline_terms(m["flops"], m["bytes"], m["coll"]["total"],
                           backend=policy.backend)
    hbm_gb = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
              + mem.output_size_in_bytes - mem.alias_size_in_bytes) / 2**30
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "n_chips": n_chips, "n_params": n_params,
        "active_frac": active_param_frac(cfg),
        "per_device": {
            "flops": m["flops"], "bytes_accessed": m["bytes"],
            "collective_bytes": m["coll"]["total"],
            "collectives": {k: v for k, v in m["coll"].items()
                            if k != "total"},
            "collective_counts": m["coll_counts"],
            "raw_uncorrected": {"flops": raw["flops"], "bytes": raw["bytes"],
                                "collective_bytes": raw["coll"]["total"]},
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "hbm_gib": round(hbm_gb, 2),
        },
        "model_flops_global": mf,
        "useful_flops_ratio": (mf / n_chips / m["flops"]) if m["flops"] else None,
        "roofline": terms,
        "compile_s": round(t_full, 1),
        "scan_corrected": correct_scan,
        "seq_parallel": sp,
    }
    if verbose:
        log(f"[dryrun] {arch:22s} {shape_name:12s} {record['mesh']:8s} ok "
            f"c={terms['compute_s']*1e3:8.1f}ms m={terms['memory_s']*1e3:8.1f}ms "
            f"n={terms['collective_s']*1e3:8.1f}ms dom={terms['bottleneck']:10s} "
            f"hbm={hbm_gb:6.2f}GiB useful={record['useful_flops_ratio'] or 0:.3f} "
            f"(compile {t_full:.0f}s)")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--quant", default="bhq")
    ap.add_argument("--grad-bits", type=int, default=5)
    ap.add_argument("--backend", default="native",
                    choices=["simulate", "native", "pallas"],
                    help="quantized-GEMM execution backend (core/backend.py)")
    ap.add_argument("--no-sp", dest="sp", action="store_false")
    ap.add_argument("--no-correct", dest="correct", action="store_false")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    archs = ARCH_NAMES if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    policy = QuantPolicy.fqt(args.quant, args.grad_bits,
                             backend=args.backend, bhq_block=1024)

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([s.name for s in shape_grid(cfg)]
                  if args.shape == "all" else args.shape.split(","))
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape_name}__{'multi' if mp else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[dryrun] skip {tag} (exists)", flush=True)
                    continue
                try:
                    # roofline table is single-pod; multi-pod proves the pod
                    # axis shards (compile-only, no probes)
                    rec = run_cell(arch, shape_name, multi_pod=mp,
                                   policy=policy, sp=args.sp,
                                   correct_scan=(args.correct and not mp))
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((tag, f"{type(e).__name__}: {e}"))
                    print(f"[dryrun] FAIL {tag}", flush=True)
    if failures:
        print(f"\n[dryrun] {len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err[:200]}")
        raise SystemExit(1)
    print("\n[dryrun] all cells compiled OK")


if __name__ == "__main__":
    main()
