"""Launchers: mesh construction, multi-pod dry-run, train and serve drivers.

NOTE: import `dryrun` only as an entry point — it sets XLA_FLAGS at module
import (512 placeholder devices) and must run in a fresh process.
"""

from .mesh import make_production_mesh, make_test_mesh

__all__ = ["make_production_mesh", "make_test_mesh"]
