"""Training driver: step builder (used by dry-run, tests, examples) + CLI.

``make_train_step`` returns the pure jit-able function
``(params, opt_state, batch, step, key) -> (params, opt_state, metrics)``
with FQT quantization, optional remat, global-norm clipping, schedule, and
(optionally) the beyond-paper compressed cross-pod gradient all-reduce.

The CLI trains a reduced config on CPU end-to-end with checkpointing,
preemption handling, and prefetch — the same loop a production job runs.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager
from ..configs import get_config
from ..core import QuantPolicy
from ..core.compression import compressed_grad_allreduce
from ..data import Prefetcher, ShardedLoader, make_batch_for
from ..models import build_model
from ..optim import adamw, clip_by_global_norm, cosine_schedule, sgd
from ..runtime import PreemptionHandler

__all__ = ["make_train_step", "train_loop", "main"]


def make_train_step(model, policy: QuantPolicy, opt, lr_fn,
                    clip_norm: float = 1.0, remat: bool = True,
                    mesh=None, compress_axis: str | None = None,
                    loss_kwargs: dict | None = None):
    """Build the pure training step.

    compress_axis: mesh axis over which gradients are exchanged with the
    unbiased int8 compressed all-reduce instead of GSPMD's implicit fp32
    psum (beyond-paper, DESIGN.md Sec. 4).  Requires `mesh`.
    """

    def train_step(params, opt_state, batch, step, key):
        kstep = jax.random.fold_in(key, step)

        def loss_fn(p):
            loss, mets = model.loss(p, batch, kstep, policy, remat=remat,
                                    **(loss_kwargs or {}))
            return loss, mets

        (loss, mets), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        if compress_axis is not None:
            grads = compressed_grad_allreduce(
                grads, mesh, compress_axis,
                jax.random.fold_in(kstep, 0xC0),
                bits=policy.dp_grad_bits, mean=True)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(step)
        params, opt_state = opt.apply(params, grads, opt_state, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr, **mets}
        return params, opt_state, metrics

    return train_step


def train_loop(cfg, policy: QuantPolicy, *, steps: int, batch_size: int,
               seq_len: int, lr: float = 3e-3, opt_name: str = "adamw",
               ckpt_dir: str | None = None, ckpt_every: int = 100,
               log_every: int = 10, seed: int = 0, remat: bool = False,
               resume: bool = True, preemption: PreemptionHandler | None = None,
               log_fn=print):
    """Single-host training loop used by examples/tests."""
    model = build_model(cfg)
    opt = adamw() if opt_name == "adamw" else sgd(momentum=0.9)
    lr_fn = cosine_schedule(lr, steps, warmup_steps=max(steps // 20, 1))
    step_fn = jax.jit(make_train_step(model, policy, opt, lr_fn, remat=remat))

    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    opt_state = opt.init(params)
    start = 0

    ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if ckpt and resume and ckpt.latest_step() is not None:
        start = ckpt.latest_step()
        state = ckpt.restore(start, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        log_fn(f"[train] resumed from step {start}")

    loader = ShardedLoader(
        lambda s: make_batch_for(cfg, batch_size, seq_len, step=s, seed=seed))
    pf = Prefetcher(loader, depth=2, start_step=start)
    history = []
    t0 = time.time()
    try:
        for step in range(start, steps):
            batch = pf.next()
            params, opt_state, mets = step_fn(params, opt_state, batch,
                                              jnp.asarray(step), key)
            if step % log_every == 0 or step == steps - 1:
                loss = float(mets["loss"])
                history.append((step, loss))
                log_fn(f"[train] step {step:5d} loss {loss:8.4f} "
                       f"gnorm {float(mets['grad_norm']):8.3f} "
                       f"({time.time()-t0:.1f}s)")
            if ckpt and (step + 1) % ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state},
                          asynchronous=True)
            if preemption and preemption.should_stop:
                if ckpt:
                    ckpt.save(step + 1, {"params": params, "opt": opt_state})
                log_fn(f"[train] preempted at step {step+1}; checkpointed")
                break
    finally:
        pf.stop()
        if ckpt:
            ckpt.wait()
    return params, opt_state, history


def parse_override(text: str):
    """One ``--override`` CLI entry -> (path_regex, override-ish).

    Grammar (right-hand side of ``PATTERN=...``):
      ``exact``             pin every matching layer to full precision
      ``bits:B``            rewrite the bitwidth of every quantized role
      ``ROLE:QUANT[:B]``    set one role (fwd/fwd_act/fwd_weight/wgrad/agrad)
                            to a registered quantizer, e.g. ``agrad:bhq:4``
    e.g. ``--override 'lm_head|embed=exact' --override 'layers.mlp=agrad:bhq:4'``
    """
    pattern, sep, rhs = text.partition("=")
    if not sep or not pattern or not rhs:
        raise argparse.ArgumentTypeError(
            f"{text!r}: expected PATTERN=SPEC")
    if rhs == "exact":
        value = "exact"
    else:
        head, _, rest = rhs.partition(":")
        if head == "bits":
            value = int(rest)
        elif rest:
            value = {head: rest}      # "agrad:bhq:4" -> {"agrad": "bhq:4"}
        else:
            raise argparse.ArgumentTypeError(
                f"{text!r}: expected exact | bits:B | ROLE:QUANT[:B]")
    # validate eagerly (regex, role names, spec shape) so argparse turns a
    # bad value into a clean usage error, not a traceback at policy time
    from ..core.policy import _normalize_overrides
    try:
        _normalize_overrides(((pattern, value),))
    except ValueError as e:
        raise argparse.ArgumentTypeError(str(e)) from None
    return pattern, value


def main(argv=None):
    ap = argparse.ArgumentParser(description="FQT training driver")
    ap.add_argument("--arch", default="statquant-tx")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--opt", default="adamw", choices=["adamw", "sgd"])
    ap.add_argument("--quant", default="bhq", choices=["ptq", "psq", "bhq",
                                                       "qat", "exact"])
    ap.add_argument("--grad-bits", type=int, default=5)
    ap.add_argument("--backend", default="simulate",
                    choices=["simulate", "native", "pallas"],
                    help="quantized-GEMM execution backend (core/backend.py);"
                         " pallas = fused kernels for fwd AND both bwd GEMMs")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--override", action="append", default=[],
                    metavar="PATTERN=SPEC", type=parse_override,
                    help="per-layer policy override (repeatable, applied in "
                         "order): PATTERN=exact | PATTERN=bits:B | "
                         "PATTERN=ROLE:QUANT[:B]  e.g. 'lm_head=exact' "
                         "'layers.mlp=agrad:bhq:4'")
    args = ap.parse_args(argv)

    if args.quant == "exact":
        if args.override:
            ap.error("--override has no effect with --quant exact "
                     "(the policy quantizes nothing to override)")
        policy = QuantPolicy.exact()
    elif args.quant == "qat":
        policy = QuantPolicy.qat(backend=args.backend,
                                 overrides=tuple(args.override))
    else:
        policy = QuantPolicy.fqt(args.quant, args.grad_bits, bhq_block=256,
                                 backend=args.backend,
                                 overrides=tuple(args.override))

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.override:
        from ..models import model_quant_paths
        print("[train] resolved per-layer quantizer specs:")
        for path, desc in policy.spec_table(model_quant_paths(cfg)):
            print(f"  {path:32s} {desc}")
    prm = PreemptionHandler(install=True)
    train_loop(cfg, policy, steps=args.steps, batch_size=args.batch,
               seq_len=args.seq, lr=args.lr, opt_name=args.opt,
               ckpt_dir=args.ckpt_dir, preemption=prm)


if __name__ == "__main__":
    main()
