"""Training CLI on top of the engine (:mod:`repro.engine`).

The step/loop construction lives in ``repro.engine`` — this module only
parses arguments, resolves the policy, and drives ``Engine.run()``.

``train_loop`` is kept as a thin compatibility wrapper (same signature the
examples/tests/benches always used, plus ``mesh=``/``accum_steps=``/
``donate=``); ``make_train_step`` is gone — use
:func:`repro.engine.make_step_fn`, which takes/returns a
:class:`~repro.engine.TrainState`.
"""

from __future__ import annotations

import argparse

from ..configs import get_config
from ..core import QuantPolicy
from ..engine import Engine
from ..runtime import PreemptionHandler

__all__ = ["train_loop", "main"]


def train_loop(cfg, policy: QuantPolicy, *, steps: int, batch_size: int,
               seq_len: int, lr: float = 3e-3, opt_name: str = "adamw",
               ckpt_dir: str | None = None, ckpt_every: int = 100,
               log_every: int = 10, seed: int = 0, remat: bool = False,
               resume: bool = True, preemption: PreemptionHandler | None = None,
               log_fn=print, **engine_kwargs):
    """Compatibility wrapper over ``Engine(...).run()``.

    Returns ``(params, opt_state, history)`` like the pre-engine loop,
    except history now has one ``(step, loss)`` entry per *executed* step
    (the old loop sampled it at ``log_every``; logging is still sampled).
    Extra kwargs (``mesh=``, ``accum_steps=``, ``donate=``, ...) pass
    through to :class:`~repro.engine.Engine`.
    """
    eng = Engine(cfg, policy, steps=steps, batch_size=batch_size,
                 seq_len=seq_len, lr=lr, opt_name=opt_name,
                 ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
                 log_every=log_every, seed=seed, remat=remat, resume=resume,
                 preemption=preemption, log_fn=log_fn, **engine_kwargs)
    history = eng.run()
    return eng.state.params, eng.state.opt_state, history


def parse_override(text: str):
    """One ``--override`` CLI entry -> (path_regex, override-ish).

    Grammar (right-hand side of ``PATTERN=...``):
      ``exact``             pin every matching layer to full precision
      ``bits:B``            rewrite the bitwidth of every quantized role
      ``ROLE:QUANT[:B]``    set one role (fwd/fwd_act/fwd_weight/wgrad/agrad)
                            to a registered quantizer, e.g. ``agrad:bhq:4``
    e.g. ``--override 'lm_head|embed=exact' --override 'layers.mlp=agrad:bhq:4'``
    """
    pattern, sep, rhs = text.partition("=")
    if not sep or not pattern or not rhs:
        raise argparse.ArgumentTypeError(
            f"{text!r}: expected PATTERN=SPEC")
    if rhs == "exact":
        value = "exact"
    else:
        head, _, rest = rhs.partition(":")
        if head == "bits":
            value = int(rest)
        elif rest:
            value = {head: rest}      # "agrad:bhq:4" -> {"agrad": "bhq:4"}
        else:
            raise argparse.ArgumentTypeError(
                f"{text!r}: expected exact | bits:B | ROLE:QUANT[:B]")
    # validate eagerly (regex, role names, spec shape) so argparse turns a
    # bad value into a clean usage error, not a traceback at policy time
    from ..core.policy import _normalize_overrides
    try:
        _normalize_overrides(((pattern, value),))
    except ValueError as e:
        raise argparse.ArgumentTypeError(str(e)) from None
    return pattern, value


def parse_mesh(text: str):
    """``--mesh DATAxMODEL`` (e.g. ``2x2``) -> (data, model)."""
    try:
        data, model = (int(v) for v in text.lower().split("x"))
        if data < 1 or model < 1:
            raise ValueError
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"{text!r}: expected DATAxMODEL, e.g. 2x2") from None
    return data, model


def main(argv=None):
    ap = argparse.ArgumentParser(description="FQT training driver")
    ap.add_argument("--arch", default="statquant-tx")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8,
                    help="GLOBAL batch per optimizer step")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--opt", default="adamw", choices=["adamw", "sgd"])
    ap.add_argument("--quant", default="bhq", choices=["ptq", "psq", "bhq",
                                                       "qat", "exact"])
    ap.add_argument("--grad-bits", type=int, default=5)
    ap.add_argument("--backend", default="simulate",
                    choices=["simulate", "native", "pallas"],
                    help="quantized-GEMM execution backend (core/backend.py);"
                         " pallas = fused kernels for fwd AND both bwd GEMMs")
    ap.add_argument("--mesh", type=parse_mesh, default=None,
                    metavar="DATAxMODEL",
                    help="train sharded on a (data, model) mesh; needs that "
                         "many devices (CPU: XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches per step")
    ap.add_argument("--no-donate", dest="donate", action="store_false",
                    help="disable TrainState buffer donation (debugging)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--override", action="append", default=[],
                    metavar="PATTERN=SPEC", type=parse_override,
                    help="per-layer policy override (repeatable, applied in "
                         "order): PATTERN=exact | PATTERN=bits:B | "
                         "PATTERN=ROLE:QUANT[:B]  e.g. 'lm_head=exact' "
                         "'layers.mlp=agrad:bhq:4'")
    ap.add_argument("--override-file", default=None, metavar="PLAN.json",
                    help="load per-layer overrides from a JSON file — the "
                         "format `python -m repro.analysis plan --out` "
                         "writes (applied before any --override, so CLI "
                         "entries win)")
    args = ap.parse_args(argv)

    file_overrides = ()
    if args.override_file:
        import json

        from ..core.policy import overrides_from_json
        with open(args.override_file) as fh:
            doc = json.load(fh)
        try:
            file_overrides = overrides_from_json(doc)
        except (TypeError, ValueError, KeyError) as e:
            ap.error(f"--override-file {args.override_file}: {e}")
    overrides = tuple(file_overrides) + tuple(args.override)

    if args.quant == "exact":
        if overrides:
            ap.error("--override/--override-file have no effect with "
                     "--quant exact (the policy quantizes nothing)")
        policy = QuantPolicy.exact()
    elif args.quant == "qat":
        policy = QuantPolicy.qat(backend=args.backend, overrides=overrides)
    else:
        policy = QuantPolicy.fqt(args.quant, args.grad_bits, bhq_block=256,
                                 backend=args.backend, overrides=overrides)

    cfg = get_config(args.arch, smoke=args.smoke)
    if overrides:
        from ..models import model_quant_paths
        print("[train] resolved per-layer quantizer specs:")
        for path, desc in policy.spec_table(model_quant_paths(cfg)):
            print(f"  {path:32s} {desc}")

    mesh = None
    if args.mesh is not None:
        import jax
        from .mesh import make_test_mesh
        data, model = args.mesh
        if data * model > jax.device_count():
            ap.error(f"--mesh {data}x{model} needs {data*model} devices, "
                     f"have {jax.device_count()} (set XLA_FLAGS="
                     f"--xla_force_host_platform_device_count={data*model})")
        mesh = make_test_mesh(data, model)

    prm = PreemptionHandler(install=True)
    eng = Engine(cfg, policy, steps=args.steps, batch_size=args.batch,
                 seq_len=args.seq, lr=args.lr, opt_name=args.opt,
                 accum_steps=args.accum, mesh=mesh, donate=args.donate,
                 ckpt_dir=args.ckpt_dir, preemption=prm)
    eng.run()


if __name__ == "__main__":
    main()
