"""Serving driver: prefill + batched greedy decode with a quantized model.

Inference quantization (paper Sec. 1): weights/activations through the
deterministic forward quantizers; no gradient path.  The loop is the
standard two-phase serving pattern (prefill once, then step the decode jit),
with simple continuous-batching slots.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..core import QuantPolicy
from ..data import make_batch_for
from ..models import build_model

__all__ = ["generate", "main"]


def generate(model, params, batch, policy: QuantPolicy, *, max_new: int,
             max_seq: int, greedy: bool = True, key=None):
    """Prefill the prompt then decode ``max_new`` tokens. Returns (B, max_new)."""
    cfg = model.cfg
    prefill = jax.jit(lambda p, b: model.prefill(p, b, policy, max_seq))
    decode = jax.jit(lambda p, c, b: model.decode(p, c, b, policy),
                     donate_argnums=(1,))

    logits, cache = prefill(params, batch)
    out = []
    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)[:, None]
    for i in range(max_new):
        out.append(tok)
        dbatch = {"tokens": tok.astype(jnp.int32)}
        if cfg.family == "vlm":
            # stub frontend: decode steps feed token embeddings directly
            dbatch = {"embeds": params["embed"]["table"][tok[:, 0]][:, None]}
        logits, cache = decode(params, cache, dbatch)
        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)[:, None]
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser(description="quantized serving driver")
    ap.add_argument("--arch", default="statquant-tx")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    policy = QuantPolicy.qat()                      # fwd-only quantization
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch_for(cfg, args.batch, args.prompt_len)
    batch.pop("labels", None)

    t0 = time.time()
    toks = generate(model, params, batch, policy,
                    max_new=args.max_new,
                    max_seq=args.prompt_len + args.max_new + 1)
    dt = time.time() - t0
    n = args.batch * args.max_new
    print(f"[serve] generated {n} tokens in {dt:.2f}s "
          f"({n/dt:.1f} tok/s batched)")
    print("[serve] sample:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
