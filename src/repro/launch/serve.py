"""Serving CLI: a thin driver over the continuous-batching engine.

Inference quantization (paper Sec. 1): weights/activations run through the
deterministic forward quantizers; no gradient path.  The engine
(:mod:`repro.serve`) owns the scheduling — a fixed pool of decode slots kept
at full static batch, per-request prefill, EOS/length eviction — and the
optional int8 KV cache; this module parses arguments, builds (or restores)
the parameters, submits a mixed-length synthetic workload, and reports
throughput + per-token latency percentiles.  ``--paged`` swaps in the
paged-pool engine (block tables, prefix reuse, chunked prefill, optional
``--spec-decode`` self-speculative decoding — serve/paged.py).

``generate`` is the legacy static-batch helper (prefill once, decode the
whole batch in lockstep) kept for the examples; it now stops early once
every row has emitted ``eos_id`` instead of always burning ``max_new``
steps.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..core import QuantPolicy
from ..models import build_model
from ..serve import ServeEngine

__all__ = ["generate", "main"]


def generate(model, params, batch, policy: QuantPolicy, *, max_new: int,
             max_seq: int, greedy: bool = True, key=None, eos_id=None):
    """Prefill the prompt then greedy-decode up to ``max_new`` tokens.

    Returns (B, n) with n <= max_new: decoding stops as soon as every row
    has emitted ``eos_id`` (rows that finish early keep emitting ``eos_id``
    while the rest of the batch drains).  ``eos_id=None`` disables early
    stopping and always returns (B, max_new).
    """
    cfg = model.cfg
    prefill = jax.jit(lambda p, b: model.prefill(p, b, policy, max_seq))
    decode = jax.jit(lambda p, c, b: model.decode(p, c, b, policy),
                     donate_argnums=(1,))

    logits, cache = prefill(params, batch)
    out = []
    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)[:, None]
    B = tok.shape[0]
    finished = jnp.zeros((B,), bool)
    for _ in range(max_new):
        if eos_id is not None:
            finished = finished | (tok[:, 0] == eos_id)
            tok = jnp.where(finished[:, None], eos_id, tok)
        out.append(tok)
        if eos_id is not None and bool(finished.all()):
            break
        dbatch = {"tokens": tok.astype(jnp.int32)}
        if cfg.family == "vlm":
            # stub frontend: decode steps feed token embeddings directly
            dbatch = {"embeds": params["embed"]["table"][tok[:, 0]][:, None]}
        logits, cache = decode(params, cache, dbatch)
        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)[:, None]
    return jnp.concatenate(out, axis=1)


def _latency_stats(step_times):
    dts = np.asarray([dt for dt, n in step_times if n > 0])
    if dts.size == 0:
        return 0.0, 0.0
    return float(np.percentile(dts, 50)), float(np.percentile(dts, 95))


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="continuous-batching quantized serving driver")
    ap.add_argument("--arch", default="statquant-tx")
    ap.add_argument("--smoke", dest="smoke", action="store_true",
                    help="reduced config (default)")
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="full-size config")
    ap.set_defaults(smoke=True)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode-slot pool size (static decode batch)")
    ap.add_argument("--max-seq", type=int, default=64,
                    help="per-slot KV cache length")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--min-prompt", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="<= 0 => greedy")
    ap.add_argument("--top-k", type=int, default=0, help="<= 0 => disabled")
    ap.add_argument("--top-p", type=float, default=0.0,
                    help="nucleus sampling mass; outside (0,1) => disabled")
    ap.add_argument("--paged", action="store_true",
                    help="paged int8 KV engine (serve/paged.py): shared "
                         "page pool + block tables + prefix reuse instead "
                         "of one max-seq lane per slot")
    ap.add_argument("--page-size", type=int, default=8,
                    help="rows per KV page (paged mode)")
    ap.add_argument("--pages", type=int, default=None,
                    help="page-pool size; default sizes for slots lanes")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked-prefill width (paged mode); default = "
                         "whole-prompt prefill")
    ap.add_argument("--spec-decode", action="store_true",
                    help="self-speculative decoding (paged mode): draft = "
                         "same params under an aggressive low-bit policy")
    ap.add_argument("--spec-k", type=int, default=3,
                    help="draft tokens proposed per verify step")
    ap.add_argument("--eos", type=int, default=None,
                    help="EOS token id (evicts the slot on emission)")
    ap.add_argument("--kv-cache", choices=["int8", "fp32"], default="int8",
                    help="KV-cache storage: int8 = ~4x more resident slots "
                         "at equal HBM (core/kv_cache.py)")
    ap.add_argument("--weight-bits", type=int, default=None,
                    choices=[8, 4, 2],
                    help="bit-pack every dense kernel once at load "
                         "(kernels/pack.py): resident GEMM weights drop to "
                         "bits/32 of fp32 and decode unpacks tiles "
                         "in-kernel; omit to keep fp32 weights with "
                         "per-step forward quantization")
    ap.add_argument("--backend", default="simulate",
                    choices=["simulate", "native", "pallas"],
                    help="execution backend for the quantized ops, "
                         "including the int8-KV dequant")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params from an engine TrainState "
                         "checkpoint instead of random init")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    policy = QuantPolicy.qat(backend=args.backend)  # fwd-only quantization
    kv_quant = args.kv_cache == "int8"
    if args.paged and not kv_quant:
        ap.error("--paged requires --kv-cache int8 (pages store the codec)")
    for flag, name in ((args.spec_decode, "--spec-decode"),
                       (args.prefill_chunk, "--prefill-chunk"),
                       (args.pages, "--pages")):
        if flag and not args.paged:
            ap.error(f"{name} needs --paged")
    kw = dict(policy=policy, slots=args.slots, max_seq=args.max_seq,
              kv_quant=kv_quant, eos_id=args.eos, seed=args.seed,
              weight_bits=args.weight_bits)
    if args.paged:
        kw.update(paged=True, page_size=args.page_size, pages=args.pages,
                  prefill_chunk=args.prefill_chunk,
                  spec_decode=args.spec_decode, spec_k=args.spec_k)
    if args.ckpt_dir:
        eng = ServeEngine.from_checkpoint(cfg, args.ckpt_dir, **kw)
    else:
        params = build_model(cfg).init(jax.random.PRNGKey(args.seed))
        eng = ServeEngine(cfg, params, **kw)

    if args.weight_bits is not None:
        from ..serve.engine import weight_nbytes
        print(f"[serve] packed w{args.weight_bits} weights: "
              f"{weight_nbytes(eng.params)} resident bytes")

    # warmup: compile the decode step AND every prefill/insert length
    # bucket the workload can hit, off the clock
    hi = min(args.max_prompt, args.max_seq - 1)
    lo = min(args.min_prompt, hi)
    b = 1
    while b < hi:
        b *= 2
        if b >= lo:
            eng.submit([1] * min(b, hi), max_new=2)
    eng.submit([1], max_new=2)
    eng.run()
    eng.step_times.clear()

    rng = np.random.RandomState(args.seed)
    for _ in range(args.requests):
        plen = int(rng.randint(lo, hi + 1))
        prompt = rng.randint(0, cfg.vocab_size, size=plen)
        eng.submit(prompt, max_new=args.max_new,
                   temperature=args.temperature, top_k=args.top_k,
                   top_p=args.top_p)

    t0 = time.time()
    completions = eng.run()
    dt = time.time() - t0
    n_tok = sum(len(c.tokens) for c in completions.values())
    p50, p95 = _latency_stats(eng.step_times)
    print(f"[serve] {len(completions)} requests, {n_tok} tokens in "
          f"{dt:.2f}s ({n_tok / max(dt, 1e-9):.1f} tok/s, "
          f"kv={args.kv_cache}, slots={args.slots})")
    print(f"[serve] per-token latency p50 {p50 * 1e3:.2f}ms "
          f"p95 {p95 * 1e3:.2f}ms")
    by_reason = {}
    for c in completions.values():
        by_reason[c.reason] = by_reason.get(c.reason, 0) + 1
    print(f"[serve] finish reasons: {by_reason}")
    if args.paged:
        st = eng.pool_stats()
        print(f"[serve] paged: {st['pages_in_use']}/{st['n_pages']} pages "
              f"resident (peak {st['peak_pages_in_use']}), "
              f"prefix hits {st['prefix_hits']}, cow {st['cow_copies']}, "
              f"preemptions {st['preemptions']}")
        if args.spec_decode:
            sp = eng.spec_stats
            print(f"[serve] spec: {sp.spec_steps} rounds, acceptance "
                  f"{sp.acceptance_rate:.2f}, {sp.emitted} tokens emitted")
    if completions:
        rid0 = min(completions)
        print("[serve] sample:", completions[rid0].tokens[:16])


if __name__ == "__main__":
    main()
