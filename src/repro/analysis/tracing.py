"""Retrace and buffer-donation guards for the jitted training step.

Two silent performance regressions this module turns into assertions:

  * **Recompilation**: a step function that retraces every call (a Python
    scalar in the carry, an unhashable static arg, a fresh closure per
    step) still *works* — it just burns minutes of XLA compile time per
    step.  :class:`RetraceGuard` counts compilation-cache misses across a
    window of calls and fails if any call after the first compiles.

  * **Donation**: ``jit_step`` donates the TrainState (engine/step.py,
    ``donate_argnums=(0,)``) so the optimizer update reuses the parameter
    buffers instead of doubling peak HBM.  Donation silently degrades to a
    copy when shardings mismatch or a donated buffer is still referenced.
    :func:`check_donation` verifies the donated inputs were actually
    consumed (``is_deleted`` — true on every backend when donation took)
    and, where the platform exposes stable device pointers, that outputs
    alias the donated storage.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Tuple

import jax

__all__ = ["RetraceGuard", "DonationReport", "check_donation",
           "check_step_donation"]


def _cache_size(fn) -> Optional[int]:
    """Compilation-cache size of a jitted callable, or None when the JAX
    version does not expose it."""
    getter = getattr(fn, "_cache_size", None)
    if getter is None:
        return None
    try:
        return int(getter())
    except Exception:
        return None


@dataclasses.dataclass
class RetraceGuard:
    """Wrap a jitted callable and count compilation-cache misses.

    >>> step = jit_step(make_step_fn(...))
    >>> guard = RetraceGuard(step)
    >>> state, metrics = guard(state, batch)     # compiles (expected)
    >>> state, metrics = guard(state, batch)     # must hit the cache
    >>> guard.assert_no_retrace()

    ``compiles`` records the call indices that missed the cache.  The
    first call compiling is expected; any later miss means something in
    the call signature churns (dtype/shape drift between steps, a Python
    object in the carry, a re-wrapped closure).
    """

    fn: Callable
    calls: int = 0
    compiles: List[int] = dataclasses.field(default_factory=list)
    _supported: bool = dataclasses.field(default=True, repr=False)

    def __call__(self, *args, **kwargs):
        before = _cache_size(self.fn)
        out = self.fn(*args, **kwargs)
        after = _cache_size(self.fn)
        if before is None or after is None:
            self._supported = False
        elif after > before:
            self.compiles.append(self.calls)
        self.calls += 1
        return out

    @property
    def retraces(self) -> int:
        """Compilations beyond the expected first-call trace."""
        return sum(1 for i in self.compiles if i > 0)

    def assert_no_retrace(self) -> None:
        if not self._supported:
            return                      # cannot observe: do not fail falsely
        if self.retraces:
            raise AssertionError(
                f"jitted step retraced on call(s) "
                f"{[i for i in self.compiles if i > 0]} of {self.calls} "
                f"(cache misses at {self.compiles}); something in the call "
                f"signature churns between steps — a Python scalar in the "
                f"carry, shape/dtype drift, or a fresh closure per call")


# ---------------------------------------------------------------------------
# Donation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DonationReport:
    n_donated: int
    n_deleted: int
    aliased: Optional[bool]             # None when pointers are unobservable
    detail: str

    @property
    def ok(self) -> bool:
        return self.n_deleted == self.n_donated and self.aliased is not False


def _buffer_ptrs(tree) -> List[int]:
    ptrs = []
    for leaf in jax.tree.leaves(tree):
        try:
            ptrs.append(leaf.unsafe_buffer_pointer())
        except Exception:
            return []                   # backend does not expose pointers
    return ptrs


def check_donation(fn: Callable, donate_tree, *rest,
                   out_index: int = 0) -> Tuple[Any, DonationReport]:
    """Call ``fn(donate_tree, *rest)`` and verify the donation contract.

    ``fn`` must donate its first argument (``jit_step`` does).  Checks:

      1. every array leaf of ``donate_tree`` is deleted after the call —
         JAX invalidates donated buffers on every backend, so a live input
         means the donation was dropped (with an XLA warning nobody reads);
      2. where the backend exposes ``unsafe_buffer_pointer`` (TPU/GPU),
         the output at ``out_index`` (the new state) reuses at least one
         donated pointer — actual aliasing, not just invalidation.  On
         backends without stable pointers ``aliased`` is None (unchecked).

    Returns ``(fn's result, DonationReport)``.  The input tree is consumed.
    """
    leaves_in = [x for x in jax.tree.leaves(donate_tree)
                 if isinstance(x, jax.Array)]
    ptrs_in = set(_buffer_ptrs(leaves_in))
    out = fn(donate_tree, *rest)

    n_deleted = 0
    for leaf in leaves_in:
        try:
            deleted = leaf.is_deleted()
        except Exception:
            deleted = False
        n_deleted += bool(deleted)

    aliased: Optional[bool] = None
    if ptrs_in:
        new_state = out[out_index] if isinstance(out, (tuple, list)) else out
        ptrs_out = set(_buffer_ptrs(
            [x for x in jax.tree.leaves(new_state)
             if isinstance(x, jax.Array)]))
        if ptrs_out:
            aliased = bool(ptrs_in & ptrs_out)

    n = len(leaves_in)
    if n_deleted == n:
        detail = (f"all {n} donated buffers consumed"
                  + ("" if aliased is None else
                     f"; output {'aliases' if aliased else 'does NOT alias'}"
                     f" donated storage"))
    else:
        detail = (f"only {n_deleted}/{n} donated buffers deleted — donation "
                  f"was dropped (sharding mismatch or a live reference held "
                  f"across the call); peak HBM doubles")
    return out, DonationReport(n_donated=n, n_deleted=n_deleted,
                               aliased=aliased, detail=detail)


def check_step_donation(step_fn, state, batch) -> DonationReport:
    """Donation check specialized to the engine step signature
    ``step_fn(state, batch) -> (new_state, metrics)``."""
    (_, _), report = check_donation(step_fn, state, batch, out_index=0)
    return report
