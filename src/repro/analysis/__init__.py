"""``repro.analysis`` — static verification of the quantization contract.

The quantization layer (core/fqt.py + core/policy.py) promises "every
linear-layer GEMM runs under the resolved policy; everything else is
declared full-precision".  Nothing at runtime *checks* that promise: a
layer that silently calls ``jnp.dot`` trains fine, converges fine, and
reports FQT numbers that are quietly part-fp32.  This package closes the
loop without touching a device:

  ``audit``      (:mod:`.audit`)  trace to jaxpr, attribute every GEMM via
                 the ``q[path|role]``/``qfp``/``fp`` name-stack markers,
                 and diff against ``QuantPolicy.resolve`` + the
                 ``fp_exempt`` registry; FLOP-weighted coverage; mutation
                 self-test.
  ``soundness``  (:mod:`.soundness`)  abstract interpretation of the
                 traced graph verifying the Theorem 1 unbiasedness
                 preconditions: stochastic rounding on every gradient
                 path, independent SR key streams (no aliasing, no
                 scan-invariant microbatch/chunk/layer reuse), no double
                 quantization, deterministic forward (rules SND001-005);
                 its own red/green mutation self-test.
  ``planner``    (:mod:`.planner`)  variance-budget precision planner:
                 per-site (variance, bytes) candidates from the
                 Proposition 4 closed forms + the bench bytes-moved model,
                 solved greedily and by exact DP into ready-to-train
                 ``QuantPolicy.overrides`` JSON.
  ``ranges``     (:mod:`.ranges`)  int32-accumulator overflow bounds for
                 intM x intN GEMMs (asymmetric widths), scale-degeneracy
                 checks.
  ``kernels``    (:mod:`.kernels`) static validation of every Pallas tile
                 choice (shipped + persisted tuning cache).
  ``tracing``    (:mod:`.tracing`) retrace counter + donation verifier for
                 the jitted engine step.
  ``lint``       (:mod:`.lint`)    AST rules RPR001-003 over layers/models.

CLI: ``python -m repro.analysis {audit|soundness|plan|lint|kernels}``
(see __main__.py); every subcommand accepts ``--format json``; exits
non-zero on any violation, so CI gates on it.
"""

from .audit import (AuditReport, SelftestResult, Violation, audit_fn,
                    audit_model, audit_step, mutation_selftest)
from .graph import GemmSite, classify_stack, iter_gemm_sites, site_flops
from .kernels import KernelCheckReport, KernelFinding, check_kernels
from .lint import LintFinding, lint_file, lint_source, lint_tree
from .planner import (Candidate, Plan, PlanEntry, PlanSite,
                      collect_plan_sites, gemm_bytes_moved, legal_widths,
                      plan_model, site_candidates)
from .ranges import (RangeFinding, accumulator_bound, check_sites,
                     headroom_bits, max_safe_k, signed_code_bound)
from .soundness import (SoundnessFinding, SoundnessReport,
                        SoundnessSelftest, check_model, check_soundness_fn,
                        check_step, soundness_selftest)
from .tracing import (DonationReport, RetraceGuard, check_donation,
                      check_step_donation)

__all__ = [
    "AuditReport", "Violation", "SelftestResult",
    "audit_fn", "audit_model", "audit_step", "mutation_selftest",
    "GemmSite", "iter_gemm_sites", "site_flops", "classify_stack",
    "SoundnessFinding", "SoundnessReport", "SoundnessSelftest",
    "check_soundness_fn", "check_model", "check_step", "soundness_selftest",
    "Plan", "PlanEntry", "PlanSite", "Candidate", "plan_model",
    "collect_plan_sites", "site_candidates", "gemm_bytes_moved",
    "legal_widths",
    "RangeFinding", "check_sites", "accumulator_bound", "max_safe_k",
    "headroom_bits", "signed_code_bound",
    "KernelCheckReport", "KernelFinding", "check_kernels",
    "LintFinding", "lint_source", "lint_file", "lint_tree",
    "RetraceGuard", "DonationReport", "check_donation",
    "check_step_donation",
]
