"""Statistical-soundness verifier: are the FQT gradients actually unbiased?

The paper's central result (Theorem 1) — the FQT gradient is an unbiased
estimator of the QAT gradient — holds only under preconditions the
contract auditor (analysis/audit.py) never checks:

  1. every gradient-path quantization rounds **stochastically**,
  2. the SR draws are **independent** across sites and across microbatch /
     chunk / layer folds (distinct PRNG streams),
  3. nothing **re-quantizes an already-quantized tensor** (the second
     round adds variance the Eq. 8 budget never sees — and is biased
     whenever it rounds deterministically),
  4. the **forward** pass rounds deterministically (SR there adds variance
     with no bias to fix, paper Sec. 2.1).

This module checks all four *statically*, by abstract interpretation over
the traced jaxpr.  The interpreter assigns every intermediate an abstract
value carrying

  * **key lineage** — a symbolic expression over ``random_fold_in`` /
    ``random_split`` / slice chains rooted at the trace inputs, so two SR
    draws with structurally equal lineage provably consume the same key;
  * **loop variance** — the set of enclosing ``scan`` s whose iteration
    the value depends on (via carry or xs), so a key that is constant
    across a length->1 scan (microbatch accumulation, the layer stack, the
    chunked head loss) is detected as a reused stream;
  * **randomness taint** — which ``random_bits`` draws feed the value, so
    ``floor`` is classified SR vs deterministic;
  * **quantization taint** — whether the value is an affine/elementwise
    image of a quantizer's rounded codes (propagated only through
    value-preserving ops and scalar-ish affine factors; any GEMM or
    reduction clears it), so quantize-of-dequant chains are detected.

Rounding events are attributed to ``q[path|role]`` markers exactly like
the GEMM walk (analysis/graph.py); the ``qk[path]`` key-derivation marker
(core/exempt.py) attributes lineage findings that occur before a role
scope opens.  Everything runs at trace time — no device, no parameters.

Rules (all severity "error"):

  SND001  deterministic rounding on a wgrad/agrad path: a quantized
          gradient-role scope whose rounds are all deterministic.
  SND002  SR key aliasing: two SR draws with identical key lineage
          (or one uniform tensor consumed by two rounds).
  SND003  scan-invariant SR key: an SR draw inside a scan of length > 1
          whose key lineage does not vary with the iteration — the same
          noise is replayed every microbatch/chunk/layer.
  SND004  double quantization: a quantizer round whose input is already
          an affine image of another quantizer's codes.
  SND005  stochastic rounding in the forward pass.

``soundness_selftest`` proves the pass has teeth by mutating the live
quantizer registry / key plumbing (det-rounded agrad, aliased SR keys,
quantize-of-dequant, SR forward) and asserting each mutation turns the
pass red naming the offending site — mirroring PR 7's red/green pattern.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import jax

from ..core.exempt import KEY_SCOPE_RE
from .graph import classify_stack

try:
    from jax.extend.core import Literal as _Literal
except ImportError:                                   # pragma: no cover
    from jax.core import Literal as _Literal

__all__ = ["SoundnessFinding", "SoundnessReport", "check_soundness_fn",
           "check_model", "check_step", "soundness_selftest",
           "SoundnessSelftest"]

_GRAD_ROLES = ("wgrad", "agrad")

# ops through which a value keeps its identity (key lineage) and its
# quantization taint: pure layout / dtype changes
_PRESERVE = ("convert_element_type", "copy", "reshape", "squeeze",
             "expand_dims", "broadcast_in_dim", "transpose", "rev",
             "reduce_precision")

# ops that clear randomness AND quantization taint: the output is a
# contraction/selection over many inputs, not an affine image of one
_KILL = ("dot_general", "conv_general_dilated", "reduce_sum", "reduce_max",
         "reduce_min", "reduce_prod", "reduce_and", "reduce_or", "argmax",
         "argmin", "sort", "cumsum", "cumprod", "cummax", "cummin",
         "gather", "scatter", "scatter_add")


@dataclasses.dataclass
class _AVal:
    """Abstract value of one jaxpr intermediate."""

    lineage: tuple                  # symbolic identity (hashable)
    varies: frozenset = frozenset()   # ids of enclosing scans it varies with
    rand: frozenset = frozenset()     # BitsEvent ids it depends on
    taint: frozenset = frozenset()    # quantizer sites whose codes it images


@dataclasses.dataclass(frozen=True)
class _BitsEvent:
    """One ``random_bits`` draw (the uniform behind one SR round)."""

    eid: int
    lineage: tuple                  # key operand lineage
    varies: frozenset               # key operand loop-variance
    site: str                       # "path|role" / "path|qk" / "?"
    src: str
    scans: Tuple[Tuple[int, int], ...]   # enclosing (scan_id, length)


@dataclasses.dataclass(frozen=True)
class _RoundEvent:
    """One ``floor``/``round`` equation."""

    sr: bool                        # input depends on random bits
    kind: str                       # marker kind ("quantized"/"policy_fp"/..)
    path: str
    role: Optional[str]
    src: str
    bits: frozenset                 # BitsEvent ids feeding the input
    tainted_by: frozenset           # quantizer sites already imaged in input

    @property
    def site(self) -> str:
        return f"{self.path}|{self.role}" if self.role else (self.path or "?")


@dataclasses.dataclass(frozen=True)
class SoundnessFinding:
    rule: str                # SND001..SND005
    severity: str            # "error"
    path: str
    role: Optional[str]
    detail: str
    src: str

    def __str__(self):
        role = f"|{self.role}" if self.role else ""
        return f"[{self.rule}] {self.path}{role} ({self.src}): {self.detail}"


@dataclasses.dataclass(frozen=True)
class SoundnessReport:
    title: str
    findings: Tuple[SoundnessFinding, ...]
    n_sr_rounds: int         # stochastic rounding events in the graph
    n_det_rounds: int        # deterministic rounding events
    n_streams: int           # distinct SR key lineages
    n_grad_scopes: int       # quantized wgrad/agrad scopes seen

    @property
    def ok(self) -> bool:
        return not self.findings

    def format(self, verbose: bool = False) -> str:
        lines = [f"== soundness: {self.title} ==",
                 f"rounding events: {self.n_sr_rounds} stochastic / "
                 f"{self.n_det_rounds} deterministic; "
                 f"{self.n_streams} distinct SR key streams across "
                 f"{self.n_grad_scopes} quantized gradient scopes"]
        if self.findings:
            lines.append(f"VIOLATIONS ({len(self.findings)}):")
            lines.extend(f"  {f}" for f in self.findings)
        else:
            lines.append("soundness: OK (unbiasedness preconditions hold)")
        return "\n".join(lines)

    def to_dict(self) -> dict:
        return {
            "title": self.title, "ok": self.ok,
            "counters": {"sr_rounds": self.n_sr_rounds,
                         "det_rounds": self.n_det_rounds,
                         "sr_streams": self.n_streams,
                         "grad_scopes": self.n_grad_scopes},
            "findings": [dataclasses.asdict(f) for f in self.findings],
        }


# ---------------------------------------------------------------------------
# The abstract interpreter
# ---------------------------------------------------------------------------

def _src_of(eqn) -> str:
    try:
        for frame in eqn.source_info.traceback.frames:
            fn = frame.file_name
            if "/jax/" in fn or "site-packages" in fn or fn.startswith("<"):
                continue
            return f"{fn}:{frame.start_line}"
    except Exception:
        pass
    return "?"


def _site_of(stack: str) -> Tuple[str, str, Optional[str], str]:
    """(kind, path, role, site-string) from a full name-stack string.

    Falls back to the ``qk[path]`` key-derivation marker when no
    ``q``/``qfp``/``fp`` marker encloses the equation.
    """
    kind, path, role = classify_stack(stack)
    if kind == "unmarked":
        qk = None
        for m in KEY_SCOPE_RE.finditer(stack):
            qk = m
        if qk is not None:
            return "keyscope", qk.group(1), None, f"{qk.group(1)}|qk"
    site = f"{path}|{role}" if role else (path or "?")
    return kind, path or "?", role, site


class _Interp:
    def __init__(self):
        self._ids = itertools.count()
        self.bits: Dict[int, _BitsEvent] = {}
        self.rounds: List[_RoundEvent] = []

    # -- env helpers -----------------------------------------------------
    def fresh(self, tag: str = "op") -> tuple:
        return (tag, next(self._ids))

    def read(self, env, atom) -> _AVal:
        if isinstance(atom, _Literal):
            val = atom.val
            try:
                key = val.item() if hasattr(val, "item") else val
                hash(key)
            except Exception:
                key = None
            return _AVal(lineage=("lit", key))
        try:
            return env[atom]
        except KeyError:
            # unbound var (shouldn't happen; be forgiving in an analyzer)
            av = _AVal(lineage=self.fresh("unbound"))
            env[atom] = av
            return av

    # -- interprocedural run --------------------------------------------
    def run_closed(self, closed, in_avals, prefix, scans) -> List[_AVal]:
        jaxpr = getattr(closed, "jaxpr", closed)
        env: Dict[object, _AVal] = {}
        for cv in jaxpr.constvars:
            env[cv] = _AVal(lineage=self.fresh("const"))
        if len(jaxpr.invars) != len(in_avals):
            # arity mismatch (consts folded differently than expected):
            # degrade gracefully to fresh roots rather than crash the pass
            in_avals = [_AVal(lineage=self.fresh("arg"))
                        for _ in jaxpr.invars]
        for v, av in zip(jaxpr.invars, in_avals, strict=True):
            env[v] = av
        self.run_eqns(jaxpr, env, prefix, scans)
        return [self.read(env, v) for v in jaxpr.outvars]

    def run_eqns(self, jaxpr, env, prefix, scans) -> None:
        for eqn in jaxpr.eqns:
            stack = str(eqn.source_info.name_stack)
            full = (f"{prefix}/{stack}" if prefix and stack
                    else (prefix or stack))
            self.eqn(eqn, env, full, scans)

    # -- one equation ----------------------------------------------------
    def eqn(self, eqn, env, full, scans) -> None:
        prim = eqn.primitive.name
        ins = [self.read(env, a) for a in eqn.invars]
        varies = frozenset().union(*(a.varies for a in ins)) if ins \
            else frozenset()
        rand = frozenset().union(*(a.rand for a in ins)) if ins \
            else frozenset()

        handler = getattr(self, f"_p_{prim}", None)
        if handler is not None:
            handler(eqn, env, ins, full, scans, varies, rand)
            return
        if prim in ("pjit", "closed_call", "core_call", "remat2",
                    "checkpoint", "custom_jvp_call", "custom_vjp_call",
                    "custom_jvp_call_jaxpr", "custom_vjp_call_jaxpr"):
            self._call_like(eqn, env, ins, full, scans)
            return
        if prim == "pallas_call":
            self._pallas(eqn, env, ins, full, scans, varies, rand)
            return
        if prim in _PRESERVE and len(ins) == 1:
            env[eqn.outvars[0]] = _AVal(lineage=ins[0].lineage, varies=varies,
                                        rand=rand, taint=ins[0].taint)
            return
        if prim == "slice" and len(ins) == 1:
            start = tuple(int(s) for s in eqn.params.get("start_indices", ()))
            env[eqn.outvars[0]] = _AVal(
                lineage=("at", ins[0].lineage, start), varies=varies,
                rand=rand, taint=ins[0].taint)
            return
        kill = prim in _KILL
        taint = (frozenset() if kill or not ins
                 else frozenset().union(*(a.taint for a in ins)))
        for ov in eqn.outvars:
            env[ov] = _AVal(
                lineage=self.fresh(), varies=varies,
                rand=frozenset() if kill else rand, taint=taint)

    # -- PRNG primitives -------------------------------------------------
    def _p_random_wrap(self, eqn, env, ins, full, scans, varies, rand):
        env[eqn.outvars[0]] = _AVal(lineage=ins[0].lineage, varies=varies,
                                    rand=rand, taint=frozenset())

    _p_random_unwrap = _p_random_wrap

    def _p_random_fold_in(self, eqn, env, ins, full, scans, varies, rand):
        key_l = ins[0].lineage
        data_l = ins[1].lineage if len(ins) > 1 else ("lit", None)
        env[eqn.outvars[0]] = _AVal(lineage=("fold", key_l, data_l),
                                    varies=varies, rand=rand)

    def _p_random_split(self, eqn, env, ins, full, scans, varies, rand):
        env[eqn.outvars[0]] = _AVal(lineage=("split", ins[0].lineage),
                                    varies=varies, rand=rand)

    def _p_random_bits(self, eqn, env, ins, full, scans, varies, rand):
        eid = next(self._ids)
        _kind, _path, _role, site = _site_of(full)
        self.bits[eid] = _BitsEvent(
            eid=eid, lineage=ins[0].lineage, varies=ins[0].varies, site=site,
            src=_src_of(eqn),
            scans=tuple((sid, ln) for sid, ln in scans if ln > 1))
        env[eqn.outvars[0]] = _AVal(lineage=self.fresh("bits"),
                                    varies=varies, rand=frozenset({eid}))

    def _p_random_seed(self, eqn, env, ins, full, scans, varies, rand):
        env[eqn.outvars[0]] = _AVal(lineage=("seed", ins[0].lineage),
                                    varies=varies, rand=rand)

    # -- rounding --------------------------------------------------------
    def _round_event(self, eqn, env, ins, full, det: bool):
        kind, path, role, _site = _site_of(full)
        sr = bool(ins[0].rand) and not det
        self.rounds.append(_RoundEvent(
            sr=sr, kind=kind, path=path, role=role, src=_src_of(eqn),
            bits=ins[0].rand, tainted_by=ins[0].taint))
        taint = ins[0].taint
        if kind == "quantized":
            taint = taint | {f"{path}|{role}" if role else path}
        env[eqn.outvars[0]] = _AVal(lineage=self.fresh("round"),
                                    varies=ins[0].varies, rand=ins[0].rand,
                                    taint=taint)

    def _p_floor(self, eqn, env, ins, full, scans, varies, rand):
        self._round_event(eqn, env, ins, full, det=False)

    def _p_round(self, eqn, env, ins, full, scans, varies, rand):
        self._round_event(eqn, env, ins, full, det=True)

    # -- higher-order ----------------------------------------------------
    def _call_like(self, eqn, env, ins, full, scans) -> None:
        for pname in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            sub = eqn.params.get(pname)
            if sub is None:
                continue
            jaxpr = getattr(sub, "jaxpr", sub)
            if len(jaxpr.invars) != len(ins):
                continue
            outs = self.run_closed(sub, ins, full, scans)
            if len(outs) == len(eqn.outvars):
                for ov, av in zip(eqn.outvars, outs, strict=True):
                    env[ov] = av
                return
        self._opaque(eqn, env, ins)

    def _opaque(self, eqn, env, ins) -> None:
        varies = frozenset().union(*(a.varies for a in ins)) if ins \
            else frozenset()
        rand = frozenset().union(*(a.rand for a in ins)) if ins \
            else frozenset()
        for ov in eqn.outvars:
            env[ov] = _AVal(lineage=self.fresh("opaque"), varies=varies,
                            rand=rand)

    def _p_scan(self, eqn, env, ins, full, scans, varies, rand):
        closed = eqn.params["jaxpr"]
        body = getattr(closed, "jaxpr", closed)
        n_consts = int(eqn.params.get("num_consts", 0))
        n_carry = int(eqn.params.get("num_carry", 0))
        length = int(eqn.params.get("length", 1))
        sid = next(self._ids)
        body_in: List[_AVal] = []
        for i, av in enumerate(ins):
            if i < n_consts:
                body_in.append(av)
            elif i < n_consts + n_carry:
                body_in.append(_AVal(lineage=("carry", sid, i),
                                     varies=av.varies | {sid},
                                     rand=av.rand))
            else:
                body_in.append(_AVal(lineage=("xs", sid, av.lineage),
                                     varies=av.varies | {sid},
                                     rand=av.rand))
        outs = self.run_closed(closed, body_in, full,
                               scans + ((sid, length),))
        # scan outputs keep a lineage derived from the body outvar's, so two
        # outputs stacking the *same* body value (e.g. the per-site SR keys
        # the forward scan saves as residuals for the backward scan) stay
        # provably equal across the scan boundary.  Final-carry outputs and
        # stacked-ys outputs are distinct value classes even for one body
        # outvar, hence the separate tags.
        for j, ov in enumerate(eqn.outvars):
            if j < len(outs):
                o = outs[j]
                tag = "scanfin" if j < n_carry else "scanstack"
                env[ov] = _AVal(lineage=(tag, sid, o.lineage), varies=varies,
                                rand=rand | o.rand)
            else:
                env[ov] = _AVal(lineage=self.fresh("scan_out"),
                                varies=varies, rand=rand)

    def _p_while(self, eqn, env, ins, full, scans, varies, rand):
        body = eqn.params.get("body_jaxpr")
        cn = int(eqn.params.get("cond_nconsts", 0))
        bn = int(eqn.params.get("body_nconsts", 0))
        if body is not None:
            bj = getattr(body, "jaxpr", body)
            carry_ins = ins[cn + bn:]
            body_in = list(ins[cn:cn + bn]) + [
                _AVal(lineage=("wcarry", next(self._ids)),
                      varies=a.varies, rand=a.rand) for a in carry_ins]
            if len(bj.invars) == len(body_in):
                self.run_closed(body, body_in, full, scans)
        self._opaque(eqn, env, ins)

    def _p_cond(self, eqn, env, ins, full, scans, varies, rand):
        branch_rand = frozenset()
        for br in eqn.params.get("branches", ()):
            bj = getattr(br, "jaxpr", br)
            if len(bj.invars) == len(ins) - 1:
                outs = self.run_closed(br, ins[1:], full, scans)
                branch_rand |= frozenset().union(
                    *(o.rand for o in outs)) if outs else frozenset()
        for ov in eqn.outvars:
            env[ov] = _AVal(lineage=self.fresh("cond_out"), varies=varies,
                            rand=rand | branch_rand)

    def _pallas(self, eqn, env, ins, full, scans, varies, rand):
        """Opaque kernel heuristic: a Pallas kernel whose body floors and
        whose operands carry random bits is one fused SR round; the exact
        ref dataflow inside the kernel is not interpreted."""
        kernel = eqn.params.get("jaxpr")
        prims = set()

        def collect(j):
            jx = getattr(j, "jaxpr", j)
            for e in getattr(jx, "eqns", ()):
                prims.add(e.primitive.name)
                for v in e.params.values():
                    if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
                        collect(v)

        if kernel is not None:
            collect(kernel)
        kind, path, role, _site = _site_of(full)
        if "floor" in prims or "round" in prims:
            self.rounds.append(_RoundEvent(
                sr=bool(rand) and "floor" in prims, kind=kind, path=path,
                role=role, src=_src_of(eqn), bits=rand,
                tainted_by=frozenset().union(*(a.taint for a in ins))
                if ins else frozenset()))
        for ov in eqn.outvars:
            env[ov] = _AVal(lineage=self.fresh("pallas"), varies=varies)


# ---------------------------------------------------------------------------
# Rule evaluation
# ---------------------------------------------------------------------------

def _evaluate(interp: _Interp, title: str) -> SoundnessReport:
    findings: List[SoundnessFinding] = []

    # SND001: quantized gradient scope with only deterministic rounds
    scopes: Dict[Tuple[str, str], List[_RoundEvent]] = {}
    for ev in interp.rounds:
        if ev.kind == "quantized" and ev.role in _GRAD_ROLES:
            scopes.setdefault((ev.path, ev.role), []).append(ev)
    for (path, role), evs in sorted(scopes.items()):
        if not any(e.sr for e in evs):
            findings.append(SoundnessFinding(
                "SND001", "error", path, role,
                f"all {len(evs)} rounding op(s) in this quantized "
                f"gradient scope are deterministic — the {role} "
                f"quantization is biased (Theorem 1 needs stochastic "
                f"rounding on every gradient path)", evs[0].src))

    # SND002a: two SR draws with identical key lineage
    sr_bits = [interp.bits[b] for ev in interp.rounds if ev.sr
               for b in sorted(ev.bits) if b in interp.bits]
    seen_ids = set()
    by_lineage: Dict[tuple, List[_BitsEvent]] = {}
    for be in sr_bits:
        if be.eid in seen_ids:
            continue
        seen_ids.add(be.eid)
        by_lineage.setdefault(be.lineage, []).append(be)
    for lineage, group in sorted(by_lineage.items(),
                                 key=lambda kv: str(kv[0])):
        if len(group) > 1:
            sites = sorted({b.site for b in group})
            path = sites[0].split("|")[0]
            findings.append(SoundnessFinding(
                "SND002", "error", path, None,
                f"{len(group)} SR draws share one PRNG key (identical "
                f"fold_in/split lineage) across sites {sites} — their "
                f"rounding noise is correlated, breaking the independence "
                f"Theorem 1 assumes", group[0].src))

    # SND002b: one uniform tensor consumed by several rounding ops
    uses: Dict[int, List[_RoundEvent]] = {}
    for ev in interp.rounds:
        if not ev.sr:
            continue
        for b in ev.bits:
            uses.setdefault(b, []).append(ev)
    for eid, evs in sorted(uses.items()):
        direct = [e for e in evs if not e.tainted_by]
        if len(direct) > 1:
            sites = sorted({e.site for e in direct})
            findings.append(SoundnessFinding(
                "SND002", "error", sites[0].split("|")[0], None,
                f"one random_bits tensor feeds {len(direct)} rounding ops "
                f"at sites {sites} — SR draws must be fresh per tensor",
                direct[0].src))

    # SND003: SR key constant across an enclosing scan
    for be in sorted({b.eid for b in sr_bits}):
        ev = interp.bits[be]
        for sid, length in ev.scans:
            if sid not in ev.varies:
                path, _, role = ev.site.partition("|")
                findings.append(SoundnessFinding(
                    "SND003", "error", path, role or None,
                    f"SR key lineage is invariant across the {length} "
                    f"iterations of an enclosing scan — identical "
                    f"quantization noise is replayed every iteration "
                    f"(microbatch/chunk/layer fold reuse)", ev.src))
                break

    # SND004: quantize-of-dequant
    for ev in interp.rounds:
        if ev.kind == "quantized" and ev.tainted_by:
            findings.append(SoundnessFinding(
                "SND004", "error", ev.path, ev.role,
                f"double quantization: this round's input is already an "
                f"affine image of quantized codes from "
                f"{sorted(ev.tainted_by)} — re-quantizing adds variance "
                f"outside the Eq. 8 budget (and bias when deterministic)",
                ev.src))

    # SND005: stochastic rounding in the forward pass
    for ev in interp.rounds:
        if ev.kind == "quantized" and ev.role == "fwd" and ev.sr:
            findings.append(SoundnessFinding(
                "SND005", "error", ev.path, "fwd",
                "stochastic rounding in the forward pass — forward "
                "quantizers must be deterministic (SR here adds variance "
                "with no bias to correct, paper Sec. 2.1)", ev.src))

    n_sr = sum(1 for e in interp.rounds if e.sr)
    n_det = len(interp.rounds) - n_sr
    streams = {interp.bits[b].lineage for e in interp.rounds if e.sr
               for b in e.bits if b in interp.bits}
    return SoundnessReport(
        title=title, findings=tuple(findings), n_sr_rounds=n_sr,
        n_det_rounds=n_det, n_streams=len(streams),
        n_grad_scopes=len(scopes))


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def check_soundness_fn(fn, args, title: str = "fn") -> SoundnessReport:
    """Trace ``fn(*args)`` (args may be ShapeDtypeStructs) and verify the
    unbiasedness preconditions over the resulting jaxpr."""
    closed = jax.make_jaxpr(fn)(*args)
    interp = _Interp()
    roots = [_AVal(lineage=("arg", i)) for i in range(len(closed.jaxpr.invars))]
    env: Dict[object, _AVal] = {}
    for i, cv in enumerate(closed.jaxpr.constvars):
        env[cv] = _AVal(lineage=("const", i))
    for v, av in zip(closed.jaxpr.invars, roots, strict=True):
        env[v] = av
    interp.run_eqns(closed.jaxpr, env, "", ())
    return _evaluate(interp, title)


def check_model(cfg, policy, *, grad: bool = True, batch_size: int = 2,
                seq_len: int = 8, title: Optional[str] = None,
                loss_kwargs: Optional[dict] = None) -> SoundnessReport:
    """Soundness-check ``cfg``'s training graph under ``policy`` (loss fwd
    plus bwd when ``grad``).  Pure tracing, same harness as audit_model."""
    from ..models.api import build_model
    from .audit import _loss_args

    model = build_model(cfg)
    params, batch = _loss_args(model, batch_size, seq_len)
    key = jax.random.PRNGKey(0)
    kw = dict(loss_kwargs or {})

    def loss_fn(p, b):
        loss, _ = model.loss(p, b, key, policy, **kw)
        return loss

    fn = jax.grad(loss_fn) if grad else loss_fn
    return check_soundness_fn(
        fn, (params, batch),
        title=title or f"{cfg.name} [{policy.backend}"
                       f"{'' if grad else ', fwd-only'}]")


def check_step(cfg, policy, *, batch_size: int = 2, seq_len: int = 8,
               accum_steps: int = 2,
               title: Optional[str] = None) -> SoundnessReport:
    """Soundness-check a full engine step (engine/step.py) — the default
    ``accum_steps=2`` puts the microbatch ``fold_in`` keys inside a real
    accumulation scan so SND003 has something to check."""
    import jax.numpy as jnp

    from ..engine import TrainState, make_step_fn
    from ..models.api import build_model
    from ..optim import adamw, cosine_schedule
    from .audit import _loss_args

    model = build_model(cfg)
    opt = adamw()
    step_fn = make_step_fn(model, policy, opt, cosine_schedule(1e-3, 10),
                           remat=False, accum_steps=accum_steps)
    params, batch = _loss_args(model, batch_size * accum_steps, seq_len)
    state = jax.eval_shape(
        lambda p: TrainState(params=p, opt_state=opt.init(p),
                             step=jnp.zeros((), jnp.int32),
                             rng=jax.random.PRNGKey(0)), params)
    return check_soundness_fn(
        step_fn, (state, batch),
        title=title or f"{cfg.name} engine step "
                       f"[{policy.backend}, accum={accum_steps}]")


# ---------------------------------------------------------------------------
# Mutation self-test
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SoundnessSelftest:
    ok: bool
    detail: str
    clean: SoundnessReport
    mutated: Dict[str, SoundnessReport]


def _expect(report: SoundnessReport, rule: str, problems: List[str],
            mutation: str) -> None:
    hits = [f for f in report.findings if f.rule == rule]
    if not hits:
        problems.append(f"{mutation}: no {rule} finding "
                        f"(got {sorted({f.rule for f in report.findings})})")
    elif all(f.path in ("?", "") for f in hits):
        problems.append(f"{mutation}: {rule} fired but names no layer path")


def soundness_selftest(cfg, policy) -> SoundnessSelftest:
    """Four registry/plumbing mutations, each of which must turn the pass
    red with the matching rule naming a real site, while the unmutated
    graph stays green:

      det-agrad       swap the agrad quantizer's SR for round-to-nearest
      aliased-keys    make ``qkey`` ignore its per-site tag
      double-quant    re-quantize the agrad quantizer's own dequant
      sr-forward      give the forward quantizer a stochastic round
    """
    import importlib

    import jax.numpy as jnp

    from ..core.quantizers import quantize_ptq_det, quantize_ptq_stoch
    from ..core.registry import Quantizer, get_quantizer, register_quantizer
    from ..models.api import model_quant_paths

    paths = model_quant_paths(cfg)
    agrad_spec = policy.resolve(paths[0]).agrad
    if agrad_spec is None:
        raise ValueError("soundness_selftest needs an FQT policy "
                         "(the agrad role must be quantized)")
    aname = agrad_spec.name
    common = importlib.import_module(
        ".layers.common", package=__package__.rsplit(".", 1)[0])

    class _DetAgrad(Quantizer):
        name = aname
        stochastic = True          # still receives the key; ignores it

        def quantize(self, x2d, key, spec, *, backend, interpret=None):
            return quantize_ptq_det(x2d, spec.bits or 8)

    class _DoubleQuant(Quantizer):
        name = aname
        stochastic = True

        def quantize(self, x2d, key, spec, *, backend, interpret=None):
            inner = quantize_ptq_stoch(x2d, key, spec.bits or 8)
            return quantize_ptq_stoch(inner.dequant(),
                                      jax.random.fold_in(key, 1),
                                      spec.bits or 8)

    class _StochFwd(Quantizer):
        name = "ptq_det"
        stochastic = False         # fwd roles pass key=None; derive one

        def quantize(self, x2d, key, spec, *, backend, interpret=None):
            kk = jax.random.fold_in(jax.random.PRNGKey(0),
                                    x2d.ravel()[0].astype(jnp.int32))
            return quantize_ptq_stoch(x2d, kk, spec.bits or 8)

    clean = check_model(cfg, policy)
    problems: List[str] = []
    if not clean.ok:
        problems.append(
            "unmutated graph is red: "
            + "; ".join(str(f) for f in clean.findings[:3]))
    if clean.n_sr_rounds == 0:
        problems.append("unmutated graph shows no SR rounds — the policy "
                        "quantizes no gradients, nothing to verify")

    mutated: Dict[str, SoundnessReport] = {}

    def with_quantizer(qname, impostor, mutation):
        orig = get_quantizer(qname)
        register_quantizer(qname, impostor, overwrite=True)
        try:
            rep = check_model(cfg, policy,
                              title=f"{cfg.name} MUTATED({mutation})")
        finally:
            register_quantizer(qname, orig, overwrite=True)
        mutated[mutation] = rep
        return rep

    _expect(with_quantizer(aname, _DetAgrad(), "det-agrad"),
            "SND001", problems, "det-agrad")
    _expect(with_quantizer(aname, _DoubleQuant(), "double-quant"),
            "SND004", problems, "double-quant")
    _expect(with_quantizer("ptq_det", _StochFwd(), "sr-forward"),
            "SND005", problems, "sr-forward")

    real_qkey = common.qkey
    common.qkey = lambda key, tag: jax.random.fold_in(key, 0)
    try:
        rep = check_model(cfg, policy, title=f"{cfg.name} MUTATED(aliased)")
    finally:
        common.qkey = real_qkey
    mutated["aliased-keys"] = rep
    _expect(rep, "SND002", problems, "aliased-keys")

    ok = not problems
    detail = ("soundness self-test OK: det-agrad->SND001, "
              "aliased-keys->SND002, double-quant->SND004, "
              "sr-forward->SND005 all turn the pass red naming a site; "
              "clean graph green"
              if ok else "; ".join(problems))
    return SoundnessSelftest(ok=ok, detail=detail, clean=clean,
                             mutated=mutated)
