"""Repo lint rules for the quantization contract (AST-level, no imports).

Three rules, scoped to ``src/repro/layers/`` and ``src/repro/models/`` —
the code that is supposed to route every linear-layer GEMM through
``fqt_matmul`` and declare everything else with ``fp_exempt``:

  **RPR001**  ``dense(...)`` / ``fqt_matmul(...)`` called without a layer
              ``path``.  A pathless call resolves against the policy
              default only — per-layer overrides silently stop matching
              and the auditor cannot attribute the GEMM.

  **RPR002**  raw GEMM (``einsum`` / ``dot`` / ``matmul`` / ``tensordot``
              / ``dot_general`` / ``conv_general_dilated`` call, or the
              ``@`` operator) not lexically inside a
              ``with fp_exempt(...)`` block.  This is the *static* half of
              the leak check the jaxpr auditor enforces dynamically —
              it fires on code paths no smoke-config trace reaches
              (decode steps, rare branches).

  **RPR003**  ``fp_exempt(path, reason)`` called with non-literal
              arguments.  The registry and the markers are trace-time
              static strings; a computed path would make the audit
              nondeterministic and the exemption table unreadable.

RPR002 also runs in **kernel mode** over ``src/repro/kernels/``: there a
GEMM must be a ``dot_general`` with an explicit ``preferred_element_type``
(int32 accumulation is the quantization contract at the kernel layer —
an implicit accumulator dtype is exactly how a sub-byte code GEMM silently
widens to f32 and loses bit-exactness), and the ``@`` operator is banned
outright.  ``ref.py`` is exempt: the pure-jnp oracles are deliberately
naive.

The linter is syntactic by design: it never imports the modules it
checks, so it runs in CI before any JAX initialization and on files that
do not import cleanly.
"""

from __future__ import annotations

import ast
import dataclasses
import os
from typing import List, Optional, Sequence, Tuple

__all__ = ["LintFinding", "lint_source", "lint_file", "lint_tree",
           "default_roots", "kernel_default_roots", "GEMM_CALLS"]

GEMM_CALLS = ("einsum", "dot", "matmul", "tensordot", "dot_general",
              "conv_general_dilated")

# call name -> index of the positional `path` argument
_PATHED_CALLS = {"dense": 5, "fqt_matmul": 4}


@dataclasses.dataclass(frozen=True)
class LintFinding:
    file: str
    line: int
    rule: str
    message: str

    def __str__(self):
        return f"{self.file}:{self.line}: {self.rule} {self.message}"


def _call_name(func) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def _is_fp_exempt(node) -> bool:
    return (isinstance(node, ast.Call)
            and _call_name(node.func) == "fp_exempt")


def _str_literal(node) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    # implicit concatenation of string literals parses as a Constant
    # already; a JoinedStr (f-string) is NOT static
    return None


class _Checker(ast.NodeVisitor):
    def __init__(self, file: str):
        self.file = file
        self.findings: List[LintFinding] = []
        self._exempt_depth = 0

    def _emit(self, node, rule: str, message: str) -> None:
        self.findings.append(
            LintFinding(self.file, node.lineno, rule, message))

    # -- with fp_exempt(...) lexical scoping ----------------------------
    def visit_With(self, node: ast.With) -> None:
        exempting = any(_is_fp_exempt(item.context_expr)
                        for item in node.items)
        for item in node.items:
            if _is_fp_exempt(item.context_expr):
                self._check_rpr003(item.context_expr)
                # arguments of fp_exempt itself are not exempt code
                self.generic_visit(item.context_expr)
        if exempting:
            self._exempt_depth += 1
        for stmt in node.body:
            self.visit(stmt)
        if exempting:
            self._exempt_depth -= 1
        for item in node.items:
            if not _is_fp_exempt(item.context_expr):
                self.visit(item.context_expr)
            if item.optional_vars is not None:
                self.visit(item.optional_vars)

    visit_AsyncWith = visit_With

    # -- calls -----------------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        name = _call_name(node.func)
        if name in _PATHED_CALLS:
            self._check_rpr001(node, name, _PATHED_CALLS[name])
        elif name in GEMM_CALLS and not self._exempt_depth:
            self._emit(node, "RPR002",
                       f"raw GEMM `{name}(...)` outside any "
                       f"`with fp_exempt(path, reason):` block; route it "
                       f"through fqt_matmul or declare the exemption")
        elif name == "fp_exempt":
            # bare call (not as a context manager) still registers: check
            self._check_rpr003(node)
        self.generic_visit(node)

    def visit_BinOp(self, node: ast.BinOp) -> None:
        if isinstance(node.op, ast.MatMult) and not self._exempt_depth:
            self._emit(node, "RPR002",
                       "raw GEMM `@` outside any `with fp_exempt(path, "
                       "reason):` block; route it through fqt_matmul or "
                       "declare the exemption")
        self.generic_visit(node)

    # -- rules -----------------------------------------------------------
    def _check_rpr001(self, node: ast.Call, name: str, idx: int) -> None:
        path_arg = None
        for kw in node.keywords:
            if kw.arg == "path":
                path_arg = kw.value
            elif kw.arg is None:        # **kwargs: cannot see inside; pass
                return
        if path_arg is None and len(node.args) > idx:
            path_arg = node.args[idx]
        if path_arg is None:
            self._emit(node, "RPR001",
                       f"`{name}(...)` without a layer `path`; pathless "
                       f"GEMMs only match the policy defaults and the "
                       f"auditor cannot attribute them")
        elif _str_literal(path_arg) == "":
            self._emit(node, "RPR001",
                       f"`{name}(...)` with an empty `path` literal")

    def _check_rpr003(self, node: ast.Call) -> None:
        args = list(node.args) + [kw.value for kw in node.keywords
                                  if kw.arg in ("path", "reason")]
        if len(node.args) + len(node.keywords) < 2:
            self._emit(node, "RPR003",
                       "`fp_exempt(...)` needs both a path and a reason")
            return
        for arg in args:
            if _str_literal(arg) is None and not (
                    isinstance(arg, ast.Name) and arg.id.isupper()):
                # allow module-level UPPER_CASE constants (shared reasons)
                self._emit(node, "RPR003",
                           "`fp_exempt(...)` arguments must be string "
                           "literals (or UPPER_CASE module constants) so "
                           "the exemption registry is static")
                return


class _KernelChecker(ast.NodeVisitor):
    """RPR002 kernel mode (see module docstring)."""

    def __init__(self, file: str):
        self.file = file
        self.findings: List[LintFinding] = []

    def _emit(self, node, rule: str, message: str) -> None:
        self.findings.append(
            LintFinding(self.file, node.lineno, rule, message))

    def visit_Call(self, node: ast.Call) -> None:
        name = _call_name(node.func)
        if name in GEMM_CALLS:
            if name != "dot_general":
                self._emit(node, "RPR002",
                           f"kernel-layer GEMM `{name}(...)`; kernel "
                           f"modules must contract via lax.dot_general "
                           f"with an explicit preferred_element_type")
            elif not any(kw.arg == "preferred_element_type"
                         for kw in node.keywords):
                self._emit(node, "RPR002",
                           "`dot_general(...)` without "
                           "`preferred_element_type`; an implicit "
                           "accumulator dtype breaks the int32 "
                           "accumulation contract")
        self.generic_visit(node)

    def visit_BinOp(self, node: ast.BinOp) -> None:
        if isinstance(node.op, ast.MatMult):
            self._emit(node, "RPR002",
                       "`@` operator in a kernel module; use "
                       "lax.dot_general with preferred_element_type")
        self.generic_visit(node)


def lint_source(source: str, file: str = "<string>",
                mode: str = "contract") -> List[LintFinding]:
    try:
        tree = ast.parse(source, filename=file)
    except SyntaxError as e:
        return [LintFinding(file, e.lineno or 0, "RPR000",
                            f"syntax error: {e.msg}")]
    checker = _KernelChecker(file) if mode == "kernel" else _Checker(file)
    checker.visit(tree)
    return checker.findings


def lint_file(path: str, mode: str = "contract") -> List[LintFinding]:
    with open(path, encoding="utf-8") as f:
        return lint_source(f.read(), path, mode)


def default_roots() -> Tuple[str, ...]:
    """The directories the contract rules apply to."""
    pkg = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    return (os.path.join(pkg, "layers"), os.path.join(pkg, "models"))


def kernel_default_roots() -> Tuple[str, ...]:
    """The directories the kernel-mode RPR002 rule applies to."""
    pkg = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    return (os.path.join(pkg, "kernels"),)


# pure-jnp oracles are deliberately naive (`@` on int32 IS the reference)
_KERNEL_EXEMPT_FILES = ("ref.py",)


def _walk(roots: Sequence[str]) -> List[str]:
    paths: List[str] = []
    for root in roots:
        for dirpath, _dirnames, filenames in sorted(os.walk(root)):
            paths.extend(os.path.join(dirpath, fn) for fn in sorted(filenames)
                         if fn.endswith(".py"))
    return paths


def lint_tree(roots: Optional[Sequence[str]] = None,
              kernel_roots: Optional[Sequence[str]] = None
              ) -> List[LintFinding]:
    findings: List[LintFinding] = []
    for p in _walk(roots or default_roots()):
        findings.extend(lint_file(p))
    for p in _walk(kernel_default_roots()
                   if kernel_roots is None else kernel_roots):
        if os.path.basename(p) in _KERNEL_EXEMPT_FILES:
            continue
        findings.extend(lint_file(p, mode="kernel"))
    return findings
