"""CLI: ``python -m repro.analysis {audit|lint|kernels}``.

Exit status is the contract: 0 = clean, 1 = violations — CI gates on it
(.github/workflows/ci.yml ``analysis`` job).  Everything runs on CPU at
trace time; no accelerator, no parameter materialization.

  audit    jaxpr-level quantization-contract audit of one or more configs
           under a policy; ``--selftest`` additionally runs the mutation
           self-test (a deliberately leaked GEMM must turn the audit red);
           ``--step`` audits the full engine step instead of loss+grad.
  lint     AST rules RPR001-003 over src/repro/{layers,models}.
  kernels  static tile validation (shipped defaults + persisted tuning
           cache); ``--purge`` removes bad/stale persisted entries.
"""

from __future__ import annotations

import argparse
import sys


def _build_policy(name: str, backend: str):
    from ..core import QuantPolicy
    factories = {
        "exact": lambda: QuantPolicy.exact(),
        "qat": lambda: QuantPolicy.qat(backend=backend),
        "fqt8": lambda: QuantPolicy.fqt("bhq", 8, backend=backend),
        "fqt4": lambda: QuantPolicy.fqt("bhq", 4, backend=backend),
        "fqt2": lambda: QuantPolicy.fqt("bhq", 2, backend=backend),
    }
    if name not in factories:
        raise SystemExit(f"unknown policy {name!r}; "
                         f"choose from {sorted(factories)}")
    return factories[name]()


def _cmd_audit(ns) -> int:
    from ..configs import ALL_NAMES, get_config
    from .audit import audit_model, audit_step, mutation_selftest

    configs = ns.config or ["statquant-tx", "whisper-medium"]
    bad = [c for c in configs if c not in ALL_NAMES]
    if bad:
        raise SystemExit(f"unknown config(s) {bad}; choose from {ALL_NAMES}")
    policy = _build_policy(ns.policy, ns.backend)
    rc = 0
    for name in configs:
        cfg = get_config(name, smoke=not ns.full_size)
        if ns.step:
            report = audit_step(cfg, policy)
        else:
            report = audit_model(cfg, policy, grad=not ns.fwd_only)
        print(report.format(verbose=ns.verbose))
        print()
        if not report.ok:
            rc = 1
        if ns.selftest:
            result = mutation_selftest(cfg, policy)
            print(f"== mutation self-test: {name} ==")
            print(result.detail)
            if not result.ok:
                print(result.mutated.format())
                rc = 1
            print()
    return rc


def _cmd_lint(ns) -> int:
    from .lint import lint_tree

    findings = lint_tree(ns.root or None)
    for f in findings:
        print(f)
    n = len(findings)
    print(f"lint: {n} finding(s)" if n else "lint: OK")
    return 1 if findings else 0


def _cmd_kernels(ns) -> int:
    from .kernels import check_kernels, purge_bad_entries

    report = check_kernels(ns.cache)
    print(report.format(verbose=ns.verbose))
    if ns.purge:
        n = purge_bad_entries(report)
        print(f"purged {n} bad/stale cache entr{'y' if n == 1 else 'ies'}")
    return 0 if report.ok else 1


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis of the quantization contract.")
    sub = parser.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("audit", help="jaxpr quantization-contract audit")
    p.add_argument("--config", action="append",
                   help="arch config name (repeatable; default: the two "
                        "smoke configs statquant-tx + whisper-medium)")
    p.add_argument("--policy", default="fqt8",
                   choices=["exact", "qat", "fqt8", "fqt4", "fqt2"])
    p.add_argument("--backend", default="simulate",
                   choices=["simulate", "native", "pallas"])
    p.add_argument("--selftest", action="store_true",
                   help="also run the mutation self-test")
    p.add_argument("--step", action="store_true",
                   help="audit the full engine step (loss+grad+optimizer)")
    p.add_argument("--fwd-only", action="store_true",
                   help="trace the forward only (no gradient contract)")
    p.add_argument("--full-size", action="store_true",
                   help="use the full config instead of its smoke variant")
    p.add_argument("-v", "--verbose", action="store_true")
    p.set_defaults(fn=_cmd_audit)

    p = sub.add_parser("lint", help="AST contract rules RPR001-003")
    p.add_argument("--root", action="append",
                   help="directory to lint (repeatable; default: "
                        "src/repro/layers + src/repro/models)")
    p.set_defaults(fn=_cmd_lint)

    p = sub.add_parser("kernels", help="static Pallas tile validation")
    p.add_argument("--cache", default=None,
                   help="tuning-cache path (default: $REPRO_TUNING_CACHE "
                        "or ~/.cache/repro/tuning.json)")
    p.add_argument("--purge", action="store_true",
                   help="remove bad/stale persisted entries")
    p.add_argument("-v", "--verbose", action="store_true")
    p.set_defaults(fn=_cmd_kernels)

    ns = parser.parse_args(argv)
    return ns.fn(ns)


if __name__ == "__main__":
    sys.exit(main())
