"""CLI: ``python -m repro.analysis {audit|soundness|plan|lint|kernels}``.

Exit status is the contract: 0 = clean, 1 = violations — CI gates on it
(.github/workflows/ci.yml ``analysis`` job).  Everything runs on CPU at
trace time; no accelerator, no parameter materialization.  Every
subcommand accepts ``--format json`` for machine-readable findings
(rule id, path, severity); human text stays the default.

  audit      jaxpr-level quantization-contract audit of one or more
             configs under a policy; ``--selftest`` additionally runs the
             mutation self-test (a deliberately leaked GEMM must turn the
             audit red); ``--step`` audits the full engine step.
  soundness  statistical-soundness verifier: abstract interpretation of
             the traced graph checking the Theorem 1 unbiasedness
             preconditions — SR on every gradient path, independent SR
             key streams (no aliasing, no scan-invariant reuse), no
             double quantization, deterministic forward.  ``--selftest``
             mutates the quantizer registry / key plumbing and asserts
             each mutation turns the pass red naming the site.
  plan       variance-budget precision planner: per-site (variance,
             bytes) candidates from the closed-form quantizer variances
             + the bench bytes-moved model, solved under ``--budget-bytes``
             (greedy + exact DP); writes QuantPolicy.overrides JSON for
             ``launch/train.py --override-file``.
  lint       AST rules RPR001-003 over src/repro/{layers,models}.
  kernels    static tile validation (shipped defaults + persisted tuning
             cache); ``--purge`` removes bad/stale persisted entries.
"""

from __future__ import annotations

import argparse
import json
import sys


def _build_policy(name: str, backend: str):
    from ..core import QuantPolicy
    factories = {
        "exact": lambda: QuantPolicy.exact(),
        "qat": lambda: QuantPolicy.qat(backend=backend),
        "fqt8": lambda: QuantPolicy.fqt("bhq", 8, backend=backend),
        "fqt4": lambda: QuantPolicy.fqt("bhq", 4, backend=backend),
        "fqt2": lambda: QuantPolicy.fqt("bhq", 2, backend=backend),
    }
    if name not in factories:
        raise SystemExit(f"unknown policy {name!r}; "
                         f"choose from {sorted(factories)}")
    return factories[name]()


def _configs(ns, default):
    from ..configs import ALL_NAMES
    configs = ns.config or default
    bad = [c for c in configs if c not in ALL_NAMES]
    if bad:
        raise SystemExit(f"unknown config(s) {bad}; choose from {ALL_NAMES}")
    return configs


def _emit(ns, doc: dict, text: str) -> None:
    if getattr(ns, "format", "text") == "json":
        print(json.dumps(doc, indent=2, default=str))
    else:
        print(text)


def _cmd_audit(ns) -> int:
    from ..configs import get_config
    from .audit import audit_model, audit_step, mutation_selftest

    policy = _build_policy(ns.policy, ns.backend)
    rc = 0
    reports, texts = [], []
    for name in _configs(ns, ["statquant-tx", "whisper-medium"]):
        cfg = get_config(name, smoke=not ns.full_size)
        if ns.step:
            report = audit_step(cfg, policy)
        else:
            report = audit_model(cfg, policy, grad=not ns.fwd_only)
        findings = (
            [{"rule": f"audit/{v.kind}", "severity": "error", "path": v.path,
              "role": v.role, "detail": v.detail} for v in report.violations]
            + [{"rule": "range", "severity": f.severity, "path": f.path,
                "role": f.role, "detail": f.detail}
               for f in report.range_findings if not f.ok])
        reports.append({"title": report.title, "ok": report.ok,
                        "findings": findings})
        texts.append(report.format(verbose=ns.verbose))
        if not report.ok:
            rc = 1
        if ns.selftest:
            result = mutation_selftest(cfg, policy)
            reports[-1]["selftest"] = {"ok": result.ok,
                                       "detail": result.detail}
            texts.append(f"== mutation self-test: {name} ==\n{result.detail}")
            if not result.ok:
                texts.append(result.mutated.format())
                rc = 1
    _emit(ns, {"tool": "audit", "ok": rc == 0, "reports": reports},
          "\n\n".join(texts))
    return rc


def _cmd_soundness(ns) -> int:
    from ..configs import get_config
    from .soundness import check_model, check_step, soundness_selftest

    policy = _build_policy(ns.policy, ns.backend)
    rc = 0
    reports, texts = [], []
    for name in _configs(ns, ["statquant-tx", "whisper-medium"]):
        cfg = get_config(name, smoke=not ns.full_size)
        if ns.step:
            report = check_step(cfg, policy, accum_steps=ns.accum)
        else:
            report = check_model(cfg, policy)
        reports.append(report.to_dict())
        texts.append(report.format(verbose=ns.verbose))
        if not report.ok:
            rc = 1
        if ns.selftest:
            result = soundness_selftest(cfg, policy)
            reports[-1]["selftest"] = {
                "ok": result.ok, "detail": result.detail,
                "mutations": {k: v.to_dict()
                              for k, v in result.mutated.items()}}
            texts.append(f"== soundness self-test: {name} ==\n"
                         f"{result.detail}")
            if not result.ok:
                rc = 1
    _emit(ns, {"tool": "soundness", "ok": rc == 0, "reports": reports},
          "\n\n".join(texts))
    return rc


def _cmd_plan(ns) -> int:
    from ..configs import get_config
    from .planner import plan_model

    policy = _build_policy(ns.policy, ns.backend)
    [name] = _configs(ns, ["statquant-tx"])
    cfg = get_config(name, smoke=not ns.full_size)
    plan = plan_model(cfg, policy, budget_bytes=ns.budget_bytes,
                      budget_frac=ns.budget_frac, solver=ns.solver)
    if ns.out:
        with open(ns.out, "w") as fh:
            fh.write(plan.to_json() + "\n")
    _emit(ns, plan.to_dict(),
          plan.format() + (f"\nwrote {ns.out}" if ns.out else ""))
    return 0 if plan.feasible else 1


def _cmd_lint(ns) -> int:
    from .lint import lint_tree

    findings = lint_tree(ns.root or None)
    n = len(findings)
    doc = {"tool": "lint", "ok": not findings,
           "findings": [{"rule": f.rule, "severity": "error",
                         "path": f"{f.file}:{f.line}", "detail": f.message}
                        for f in findings]}
    text = "\n".join(str(f) for f in findings)
    text += ("\n" if text else "") + (f"lint: {n} finding(s)" if n
                                      else "lint: OK")
    _emit(ns, doc, text)
    return 1 if findings else 0


def _cmd_kernels(ns) -> int:
    from .kernels import check_kernels, purge_bad_entries

    report = check_kernels(ns.cache)
    text = report.format(verbose=ns.verbose)
    purged = None
    if ns.purge:
        purged = purge_bad_entries(report)
        text += (f"\npurged {purged} bad/stale cache "
                 f"entr{'y' if purged == 1 else 'ies'}")
    doc = {"tool": "kernels", "ok": report.ok,
           "findings": [{"rule": f"kernel/{f.severity}",
                         "severity": f.severity, "path": str(f.key),
                         "source": f.source, "detail": f.detail}
                        for f in report.findings],
           **({"purged": purged} if purged is not None else {})}
    _emit(ns, doc, text)
    return 0 if report.ok else 1


def _add_common(p, step_help: str):
    p.add_argument("--config", action="append",
                   help="arch config name (repeatable; default: the two "
                        "smoke configs statquant-tx + whisper-medium)")
    p.add_argument("--policy", default="fqt8",
                   choices=["exact", "qat", "fqt8", "fqt4", "fqt2"])
    p.add_argument("--backend", default="simulate",
                   choices=["simulate", "native", "pallas"])
    p.add_argument("--step", action="store_true", help=step_help)
    p.add_argument("--full-size", action="store_true",
                   help="use the full config instead of its smoke variant")
    p.add_argument("--format", default="text", choices=["text", "json"],
                   help="output format (json: rule id, path, severity)")
    p.add_argument("-v", "--verbose", action="store_true")


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis of the quantization contract: "
                    "contract audit, statistical-soundness verifier, "
                    "variance-budget precision planner, repo lint, kernel "
                    "tile validation.")
    sub = parser.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("audit", help="jaxpr quantization-contract audit")
    _add_common(p, "audit the full engine step (loss+grad+optimizer)")
    p.add_argument("--selftest", action="store_true",
                   help="also run the mutation self-test")
    p.add_argument("--fwd-only", action="store_true",
                   help="trace the forward only (no gradient contract)")
    p.set_defaults(fn=_cmd_audit)

    p = sub.add_parser(
        "soundness",
        help="statistical-soundness verifier (Theorem 1 preconditions)")
    _add_common(p, "verify the full engine step (microbatch fold keys)")
    p.add_argument("--accum", type=int, default=2,
                   help="accum_steps for --step (default 2: exercises the "
                        "microbatch fold_in scan)")
    p.add_argument("--selftest", action="store_true",
                   help="mutate the quantizer registry / key plumbing and "
                        "assert each mutation turns the pass red")
    p.set_defaults(fn=_cmd_soundness)

    p = sub.add_parser(
        "plan", help="variance-budget precision planner (one config)")
    _add_common(p, argparse.SUPPRESS)
    p.add_argument("--budget-bytes", type=float, default=None,
                   help="bytes-moved budget over all gradient GEMMs "
                        "(default: the uniform-8-bit plan's bytes)")
    p.add_argument("--budget-frac", type=float, default=None,
                   help="budget as a fraction of the uniform-8-bit bytes")
    p.add_argument("--solver", default="auto",
                   choices=["auto", "greedy", "dp"])
    p.add_argument("--out", default=None, metavar="FILE",
                   help="write the plan JSON here (consumed by "
                        "launch/train.py --override-file)")
    p.add_argument("--smoke", action="store_true",
                   help="use the smoke config variant (the default; "
                        "--full-size overrides)")
    p.set_defaults(fn=_cmd_plan)

    p = sub.add_parser("lint", help="AST contract rules RPR001-003")
    p.add_argument("--root", action="append",
                   help="directory to lint (repeatable; default: "
                        "src/repro/layers + src/repro/models)")
    p.add_argument("--format", default="text", choices=["text", "json"])
    p.set_defaults(fn=_cmd_lint)

    p = sub.add_parser("kernels", help="static Pallas tile validation")
    p.add_argument("--cache", default=None,
                   help="tuning-cache path (default: $REPRO_TUNING_CACHE "
                        "or ~/.cache/repro/tuning.json)")
    p.add_argument("--purge", action="store_true",
                   help="remove bad/stale persisted entries")
    p.add_argument("--format", default="text", choices=["text", "json"])
    p.add_argument("-v", "--verbose", action="store_true")
    p.set_defaults(fn=_cmd_kernels)

    ns = parser.parse_args(argv)
    return ns.fn(ns)


if __name__ == "__main__":
    sys.exit(main())
