"""Static numerics checks: int32 accumulator bounds + scale degeneracy.

The native/pallas backends execute every quantized GEMM as
``dot_general(int8, int8, preferred_element_type=int32)`` over
shifted-signed codes ``c = codes - 2^(b-1)`` with ``codes in [0, 2^b-1]``
(core/backend.py).  The worst-case partial sum after contracting K
elements is therefore

    K * max|c_lhs| * max|c_rhs|  =  K * 2^(b_l - 1) * 2^(b_r - 1)

and the GEMM is overflow-safe iff that stays <= 2^31 - 1.  For int8 x int8
that gives K <= 131071 — comfortably above every shipped config, but int4
wgrad/agrad experiments (paper Sec. 5) and long-context MLPs can approach
it, and *nothing at runtime checks*: XLA int32 accumulation wraps
silently.  These bounds are pure functions of (K, bits) read off the
traced graph, so the auditor enforces them at trace time.

The same module hosts the scale-degeneracy check the variance theory
assumes away: ``scale = B / max(R, _EPS)`` (core/quantizers.py) silently
maps a constant tensor (R = 0) to a single code, making the SR variance
``p(1-p)/S^2`` (Proposition 4, core/theory.py ``quantizer_variance``)
meaningless for that tensor.  ``check_scale_inputs`` flags ranges at the
``_EPS`` floor, where dequantization error is unbounded relative to R.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, List, Optional, Sequence, Tuple

from ..core.policy import QuantPolicy
from ..core.quantizers import _EPS, num_bins
from .graph import GemmSite

__all__ = ["signed_code_bound", "accumulator_bound", "max_safe_k",
           "headroom_bits", "RangeFinding", "check_sites",
           "scale_is_degenerate", "check_scale_inputs"]

INT32_MAX = 2**31 - 1
_DTYPE_BITS = {"int8": 8, "uint8": 8, "int4": 4, "int2": 2, "int1": 1,
               "int16": 16, "int32": 32}


def signed_code_bound(bits: int) -> int:
    """max |c| over shifted-signed b-bit codes ``c = q - 2^(b-1)``,
    ``q in [0, 2^b - 1]`` — attained at q=0.

    Admits 1-bit (binary sign planes, bound 1): the packed weight kernels
    contract 1-bit codes against int8 activations, and their overflow
    check goes through the same bound (kernels/pack.max_safe_k_packed is
    the kernel-layer duplicate a tier-1 test pins to this function).
    """
    if not 1 <= bits <= 32:
        raise ValueError(f"bits={bits} out of range")
    return 1 << (bits - 1)


def accumulator_bound(k: int, lhs_bits: int, rhs_bits: int) -> int:
    """Worst-case |partial sum| after contracting K products of shifted
    lhs_bits x rhs_bits codes."""
    return k * signed_code_bound(lhs_bits) * signed_code_bound(rhs_bits)


def max_safe_k(lhs_bits: int, rhs_bits: int, acc_bits: int = 32) -> int:
    """Largest contraction K with no possible accumulator overflow.

    int8 x int8 -> int32: 131071.  int4 x int4 -> int32: ~33.5M.
    """
    acc_max = (1 << (acc_bits - 1)) - 1
    return acc_max // (signed_code_bound(lhs_bits)
                       * signed_code_bound(rhs_bits))


def headroom_bits(k: int, lhs_bits: int, rhs_bits: int,
                  acc_bits: int = 32) -> float:
    """log2(acc_max / worst-case bound): >0 safe, <0 can overflow."""
    acc_max = (1 << (acc_bits - 1)) - 1
    return math.log2(acc_max / accumulator_bound(k, lhs_bits, rhs_bits))


def scale_is_degenerate(dyn_range: float) -> bool:
    """True when ``scale = B / max(R, _EPS)`` hits the eps floor — the
    quantizer degenerates to one code and its variance model is void."""
    return dyn_range <= _EPS


def check_scale_inputs(ranges: Iterable[Tuple[str, float]]) -> List[str]:
    """Flag (name, dynamic-range) pairs whose scales are degenerate."""
    return [f"{name}: dynamic range {r:.3g} <= _EPS={_EPS:g}; scale is at "
            f"the eps floor and dequantization error is unbounded"
            for name, r in ranges if scale_is_degenerate(r)]


# ---------------------------------------------------------------------------
# Site-level checks (driven by the auditor)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RangeFinding:
    ok: bool
    severity: str           # "overflow" | "headroom" | "info"
    path: str
    role: Optional[str]
    k: int
    lhs_bits: int
    rhs_bits: int
    detail: str

    def __str__(self):
        tag = "OK" if self.ok else self.severity.upper()
        role = f"|{self.role}" if self.role else ""
        return (f"[range:{tag}] {self.path}{role} "
                f"K={self.k} {self.lhs_bits}x{self.rhs_bits}b: {self.detail}")


def _role_bits(policy: QuantPolicy, path: str,
               role: str) -> Optional[Tuple[int, int]]:
    """(lhs_bits, rhs_bits) of the integer GEMM executing ``role`` at
    ``path`` under ``policy``, or None when that role runs in fp.

    Per core/fqt.py: fwd = Q_f(X) @ Q_theta(W); wgrad = Q_f(X)^T @ Q_b1(dY);
    agrad = Q_b2(dY) @ Q_theta(W)^T.
    """
    if not policy.enabled:
        return None
    cfg = policy.resolve(path)
    if not cfg.quantize_fwd:
        return None
    if role == "fwd":
        return _spec_bits(cfg.fwd_act), _spec_bits(cfg.fwd_weight)
    if role == "wgrad":
        return None if cfg.wgrad is None else (_spec_bits(cfg.fwd_act),
                                               _spec_bits(cfg.wgrad))
    if role == "agrad":
        return None if cfg.agrad is None else (_spec_bits(cfg.agrad),
                                               _spec_bits(cfg.fwd_weight))
    return None


def _spec_bits(spec) -> int:
    """Effective bitwidth of a resolved spec: explicit bits, else the
    registered quantizer's ``default_bits`` (int4w=4, binary=1, ternary=2),
    else the repo-wide 8-bit default — so a ``binary`` weight role is
    range-checked at its true 1-bit bound, not a phantom 8."""
    if spec.bits is not None:
        return spec.bits
    try:
        from ..core.registry import get_quantizer
        q = get_quantizer(spec.name) if spec.name else None
    except ValueError:
        q = None
    default = getattr(q, "default_bits", None)
    return default if default is not None else 8


def _check_one(path: str, role: Optional[str], k: int, lb: int, rb: int,
               native: bool) -> RangeFinding:
    safe_k = max_safe_k(lb, rb)
    hr = headroom_bits(k, lb, rb)
    if k > safe_k:
        how = ("int32 accumulation WILL wrap for worst-case codes"
               if native else
               "would wrap if executed as a native int GEMM (currently "
               "simulated in fp32)")
        return RangeFinding(False, "overflow", path, role, k, lb, rb,
                            f"K={k} > max_safe_k={safe_k}; {how}")
    if hr < 1.0:
        return RangeFinding(True, "headroom", path, role, k, lb, rb,
                            f"only {hr:.2f} bits of int32 headroom "
                            f"(max_safe_k={safe_k})")
    return RangeFinding(True, "info", path, role, k, lb, rb,
                        f"{hr:.1f} bits of int32 headroom "
                        f"(max_safe_k={safe_k})")


def check_sites(sites: Sequence[GemmSite],
                policy: QuantPolicy) -> List[RangeFinding]:
    """Accumulator-overflow findings for every quantized GEMM site.

    Two passes per site:
      * **native dtype check** — the site already contracts integer codes
        in the graph (native/pallas backends): bound by the *stored* dtype.
      * **policy bits check** — the site is marked ``q[path|role]`` (any
        backend, including fp32 simulate): bound by the *policy* bitwidths,
        so a simulate-backend trace still certifies the config would be
        safe run natively.  This is what catches int2/int4 configs before
        anyone burns TPU time on them.

    Only non-OK / low-headroom findings are returned, plus one info line
    for the worst-K quantized site so reports show the margin.
    """
    out: List[RangeFinding] = []
    worst: Optional[RangeFinding] = None
    for s in sites:
        checks: List[Tuple[int, int, bool]] = []
        if s.integer_gemm:
            lb = _DTYPE_BITS.get(s.lhs_dtype)
            rb = _DTYPE_BITS.get(s.rhs_dtype)
            if lb and rb and lb <= 16 and rb <= 16:
                checks.append((lb, rb, True))
        if s.kind == "quantized" and s.path and s.role:
            bits = _role_bits(policy, s.path, s.role)
            if bits is not None:
                checks.append((bits[0], bits[1], False))
        for lb, rb, native in checks:
            f = _check_one(s.path or "?", s.role, s.contract, lb, rb, native)
            if not f.ok or f.severity == "headroom":
                out.append(f)
            elif worst is None or f.k * 2 ** (f.lhs_bits + f.rhs_bits) > (
                    worst.k * 2 ** (worst.lhs_bits + worst.rhs_bits)):
                worst = f
    if worst is not None:
        out.append(worst)
    return out


def cross_check_variance_assumption(bits: int) -> Tuple[int, int]:
    """(num_bins, signed_code_bound) — ties the range model to the
    variance theory's bin count: codes span [0, B] with B = 2^b - 1
    (core/theory.py Proposition 4 machinery), so the shifted-signed bound
    is exactly (B + 1) / 2."""
    b = num_bins(bits)
    bound = signed_code_bound(bits)
    assert bound == (b + 1) // 2
    return b, bound
