"""Quantization-contract auditor: does the traced graph run the policy?

Traces a model's loss (and, by default, its gradient — the Eq. 6 backward
GEMMs are where FQT lives) to a ClosedJaxpr, walks every ``dot_general``
through ``scan``/``pjit``/``custom_vjp`` sub-jaxprs (analysis/graph.py),
and diffs what the graph *actually executes* against what
``QuantPolicy.resolve(path)`` *declares* for every path in
``model_quant_paths(cfg)``:

  * an unmarked GEMM (no ``q[..]``/``qfp[..]``/``fp[..]`` marker) is a
    **leak** — a matmul outside both the FQT primitive and the declared
    exemption registry (core/exempt.py);
  * a declared path whose marker is missing from the graph means the layer
    stopped routing through ``fqt_matmul`` — the audit names the path;
  * a path quantized in the graph but resolved exact (or vice versa) is a
    **contract mismatch**;
  * a marked path absent from ``model_quant_paths`` means the enumeration
    drifted from the model code.

The report carries FLOP-weighted coverage (fraction of non-exempt GEMM
FLOPs under the quantized contract, and a per-role breakdown) plus the
int32-accumulator range findings (analysis/ranges.py).

``mutation_selftest`` proves the auditor has teeth: it monkeypatches one
MLP ``dense`` call to a raw ``jnp.dot`` and asserts the audit turns red
naming that path, while the unmutated tree audits clean at 100% coverage.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax

from ..configs.base import ArchConfig, ShapeSpec
from ..core import QuantPolicy, exemption_registry
from ..models.api import build_model, model_quant_paths
from .graph import GemmSite, iter_gemm_sites
from .ranges import RangeFinding, check_sites

__all__ = ["Violation", "AuditReport", "audit_fn", "audit_model",
           "mutation_selftest", "SelftestResult"]

_GRAD_ROLES = ("wgrad", "agrad")


@dataclasses.dataclass(frozen=True)
class Violation:
    kind: str        # "unmarked-gemm"|"declared-missing"|"contract-mismatch"
                     # |"undeclared-path"
    path: str        # layer path ("?" for unmarked GEMMs)
    role: Optional[str]
    detail: str

    def __str__(self):
        role = f" role={self.role}" if self.role else ""
        return f"[{self.kind}] path={self.path!r}{role}: {self.detail}"


@dataclasses.dataclass(frozen=True)
class AuditReport:
    title: str
    sites: Tuple[GemmSite, ...]
    violations: Tuple[Violation, ...]
    range_findings: Tuple[RangeFinding, ...]
    exemptions: Dict[str, str]            # path -> reason (used in this trace)

    @property
    def ok(self) -> bool:
        return not self.violations and all(f.ok for f in self.range_findings)

    # -- coverage ---------------------------------------------------------
    def flops(self, kind: Optional[str] = None) -> float:
        return math.fsum(s.flops for s in self.sites
                         if kind is None or s.kind == kind)

    @property
    def coverage(self) -> float:
        """Quantized fraction of non-exempt GEMM FLOPs (1.0 = everything the
        policy could quantize is quantized)."""
        denom = self.flops() - self.flops("exempt")
        if denom <= 0:
            return 1.0
        return self.flops("quantized") / denom

    def role_flops(self) -> Dict[str, Dict[str, float]]:
        """{role: {"quantized": flops, "policy_fp": flops}}."""
        out: Dict[str, Dict[str, float]] = {}
        for s in self.sites:
            if s.role is None:
                continue
            bucket = out.setdefault(s.role, {"quantized": 0.0,
                                             "policy_fp": 0.0})
            if s.kind in bucket:
                bucket[s.kind] += s.flops
        return out

    # -- rendering --------------------------------------------------------
    def format(self, verbose: bool = False) -> str:
        lines = [f"== audit: {self.title} =="]
        n_by_kind: Dict[str, int] = {}
        for s in self.sites:
            n_by_kind[s.kind] = n_by_kind.get(s.kind, 0) + 1
        total = self.flops()
        lines.append(
            f"GEMMs: {len(self.sites)} "
            f"({', '.join(f'{k}={v}' for k, v in sorted(n_by_kind.items()))})"
            f"; total {total:.3g} FLOPs")
        lines.append(f"coverage: {100.0 * self.coverage:.1f}% of non-exempt "
                     f"GEMM FLOPs quantized")
        for role, fl in sorted(self.role_flops().items()):
            q, fp = fl["quantized"], fl["policy_fp"]
            pct = 100.0 * q / (q + fp) if q + fp else 0.0
            lines.append(f"  role {role:<6}: {pct:5.1f}% quantized "
                         f"({q:.3g} q / {fp:.3g} fp FLOPs)")
        if self.exemptions:
            lines.append(f"exempt paths ({len(self.exemptions)}):")
            for path, reason in sorted(self.exemptions.items()):
                fl = math.fsum(s.flops for s in self.sites
                               if s.kind == "exempt" and s.path == path)
                lines.append(f"  fp[{path}] ({fl:.3g} FLOPs): {reason}")
        for f in self.range_findings:
            if not f.ok or verbose:
                lines.append(f"  {f}")
        if self.violations:
            lines.append(f"VIOLATIONS ({len(self.violations)}):")
            lines.extend(f"  {v}" for v in self.violations)
        else:
            lines.append("contract: OK")
        return "\n".join(lines)


def _expected_roles(policy: QuantPolicy, path: str,
                    grad: bool) -> Dict[str, bool]:
    """{role: quantized?} the resolved policy declares for ``path``.

    A non-quantized forward (exact pin / disabled policy) emits a single
    ``qfp[path|fwd]`` marker that also scopes the autodiff transposes, so
    no wgrad/agrad markers are expected there.
    """
    cfg = policy.resolve(path) if policy.enabled else None
    fwd_q = bool(cfg is not None and cfg.quantize_fwd)
    expected = {"fwd": fwd_q}
    if grad and fwd_q:
        expected["wgrad"] = cfg.wgrad is not None
        expected["agrad"] = cfg.agrad is not None
    return expected


def audit_fn(fn, args, *, policy: QuantPolicy, paths: Sequence[str],
             grad_traced: bool = True, title: str = "fn") -> AuditReport:
    """Trace ``fn(*args)`` (args may be ShapeDtypeStructs) and audit it.

    ``paths`` is the declared GEMM enumeration (``model_quant_paths``);
    ``grad_traced`` says whether ``fn`` contains the backward pass (so the
    wgrad/agrad contract is enforceable).
    """
    closed = jax.make_jaxpr(fn)(*args)
    sites = iter_gemm_sites(closed)
    registry = exemption_registry()
    violations: List[Violation] = []

    # 1) leaks: GEMMs under no marker at all
    for s in sites:
        if s.kind == "unmarked":
            violations.append(Violation(
                "unmarked-gemm", "?", None,
                f"{s.primitive} ({s.flops:.3g} FLOPs, K={s.contract}) at "
                f"{s.src} runs outside fqt_matmul and outside any "
                f"fp_exempt(...) block [stack: {s.stack or '<empty>'}]"))
        elif s.kind == "exempt" and s.path not in registry:
            violations.append(Violation(
                "undeclared-path", s.path or "?", None,
                f"fp[{s.path}] marker at {s.src} has no entry in the "
                f"exemption registry"))

    # 2) two-way diff of declared paths vs markers in the graph
    seen: Dict[Tuple[str, str], set] = {}
    for s in sites:
        if s.kind in ("quantized", "policy_fp") and s.role is not None:
            seen.setdefault((s.path, s.role), set()).add(s.kind)

    declared = tuple(dict.fromkeys(paths))
    for path in declared:
        for role, want_q in _expected_roles(policy, path,
                                            grad_traced).items():
            kinds = seen.pop((path, role), None)
            want = "quantized" if want_q else "policy_fp"
            if kinds is None:
                violations.append(Violation(
                    "declared-missing", path, role,
                    f"policy resolves this GEMM as {want} but no "
                    f"{'q' if want_q else 'qfp'}[{path}|{role}] marker "
                    f"appears in the traced graph — the layer no longer "
                    f"routes through fqt_matmul"))
            elif want not in kinds:
                got = ", ".join(sorted(kinds))
                violations.append(Violation(
                    "contract-mismatch", path, role,
                    f"policy resolves {want} but the graph runs {got}"))
    for (path, role), kinds in sorted(seen.items()):
        violations.append(Violation(
            "undeclared-path", path, role,
            f"marker {sorted(kinds)} in the graph but the path is not in "
            f"model_quant_paths — the enumeration drifted from the model"))

    used_exempt = {p: registry[p] for p in
                   {s.path for s in sites if s.kind == "exempt"}
                   if p in registry}
    findings = check_sites(sites, policy)
    return AuditReport(title=title, sites=sites,
                       violations=tuple(violations),
                       range_findings=tuple(findings),
                       exemptions=used_exempt)


# ---------------------------------------------------------------------------
# Model-level entry points
# ---------------------------------------------------------------------------

def _loss_args(model, batch_size: int, seq_len: int):
    """(abstract params, abstract batch, key) for tracing model.loss."""
    spec = ShapeSpec("audit", seq_len, batch_size, "train")
    batch = model.input_specs(spec)["batch"]
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return params, batch


def audit_model(cfg: ArchConfig, policy: QuantPolicy, *, grad: bool = True,
                batch_size: int = 2, seq_len: int = 8,
                title: Optional[str] = None) -> AuditReport:
    """Audit ``cfg``'s training graph (loss fwd, plus bwd when ``grad``)
    under ``policy``.  Pure tracing — no parameters are materialized, no
    TPU (or any device compute) required."""
    model = build_model(cfg)
    params, batch = _loss_args(model, batch_size, seq_len)
    key = jax.random.PRNGKey(0)

    def loss_fn(p, b):
        loss, _ = model.loss(p, b, key, policy)
        return loss

    fn = jax.grad(loss_fn) if grad else loss_fn
    return audit_fn(fn, (params, batch), policy=policy,
                    paths=model_quant_paths(cfg), grad_traced=grad,
                    title=title or f"{cfg.name} [{policy.backend}"
                                   f"{'' if grad else ', fwd-only'}]")


def audit_step(cfg: ArchConfig, policy: QuantPolicy, *, batch_size: int = 2,
               seq_len: int = 8, accum_steps: int = 1,
               title: Optional[str] = None) -> AuditReport:
    """Audit a *full engine step* (engine/step.py): loss + grads +
    clipping + optimizer, exactly the graph ``jit_step`` compiles."""
    from ..engine import TrainState, make_step_fn
    from ..optim import adamw, cosine_schedule

    model = build_model(cfg)
    opt = adamw()
    step_fn = make_step_fn(model, policy, opt, cosine_schedule(1e-3, 10),
                           remat=False, accum_steps=accum_steps)
    params, batch = _loss_args(model, batch_size, seq_len)
    state = jax.eval_shape(
        lambda p: TrainState(params=p, opt_state=opt.init(p),
                             step=jax.numpy.zeros((), jax.numpy.int32),
                             rng=jax.random.PRNGKey(0)), params)
    return audit_fn(step_fn, (state, batch), policy=policy,
                    paths=model_quant_paths(cfg), grad_traced=True,
                    title=title or f"{cfg.name} engine step "
                                   f"[{policy.backend}, accum={accum_steps}]")


# ---------------------------------------------------------------------------
# Mutation self-test
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SelftestResult:
    ok: bool
    target_path: str
    clean: AuditReport
    mutated: AuditReport
    detail: str


def mutation_selftest(cfg: ArchConfig, policy: QuantPolicy,
                      target: Optional[str] = None) -> SelftestResult:
    """Swap one MLP ``dense`` call for a raw ``jnp.dot`` and verify the
    audit (a) fails naming the leaked path and (b) passes clean at 100%
    coverage on the unmutated tree."""
    import importlib

    import jax.numpy as jnp

    # `repro.layers.mlp` the *module* — the package re-exports a function
    # under the same name, so attribute access would grab the wrong object
    mlp_mod = importlib.import_module(
        ".layers.mlp", package=__package__.rsplit(".", 1)[0])

    paths = model_quant_paths(cfg)
    if target is None:
        target = next((p for p in paths if ".mlp." in p or ".expert." in p),
                      paths[0])

    real_dense = mlp_mod.dense

    def leaky_dense(p, x, key, policy, tag=0, path=""):
        if path == target:
            return jnp.dot(x, p["w"])          # raw, unmarked, unquantized
        return real_dense(p, x, key, policy, tag, path)

    mlp_mod.dense = leaky_dense
    try:
        mutated = audit_model(cfg, policy,
                              title=f"{cfg.name} MUTATED({target})")
    finally:
        mlp_mod.dense = real_dense
    clean = audit_model(cfg, policy)

    names_path = any(v.path == target for v in mutated.violations)
    leaks = any(v.kind == "unmarked-gemm" for v in mutated.violations)
    problems = []
    if mutated.ok:
        problems.append("mutated tree audited green")
    if not names_path:
        problems.append(f"no violation names the leaked path {target!r}")
    if not leaks:
        problems.append("raw jnp.dot not reported as an unmarked GEMM")
    if not clean.ok:
        problems.append("unmutated tree audited red")
    if clean.coverage < 1.0:
        problems.append(f"clean coverage {100 * clean.coverage:.1f}% < 100%")
    ok = not problems
    detail = ("mutation self-test OK: audit turns red naming "
              f"{target!r} and recovers green at 100% coverage"
              if ok else "; ".join(problems))
    return SelftestResult(ok=ok, target_path=target, clean=clean,
                          mutated=mutated, detail=detail)
