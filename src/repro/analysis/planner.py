"""Static variance-budget precision planner (paper Sec. 4, made a solver).

The paper's Eq. 6/8 decomposition says total FQT gradient variance is the
sum of independent per-site quantization variances, and Sec. 4 shows the
*quantizer family* (PTQ -> PSQ -> BHQ) and the *bitwidth* trade variance
against bytes moved per site.  That makes mixed-precision planning a
classic budgeted-allocation problem — and a *static* one: everything it
needs (GEMM shapes, scan trip counts, closed-form variances) is available
at trace time.

Per quantized gradient GEMM site this module combines

  * shape-derived dims (m, k, n, scan multiplicity) from the traced jaxpr
    (analysis/graph.py — the same walk the contract auditor uses),
  * the exact conditional variance of each candidate quantizer/width from
    :func:`repro.core.theory.quantizer_variance` (Proposition 4 closed
    forms), evaluated on a fixed-seed Gaussian proxy of the SR operand,
  * a bytes-moved cost model (:func:`gemm_bytes_moved`) shared with
    ``benchmarks/bench_kernels.py``'s bytes column,

into per-site (variance, bytes) candidates at each legal width {8, 4, 2},
prunes the Pareto-dominated ones, and solves

    minimize  sum_site Var[site]   s.t.  sum_site bytes[site] <= budget

with greedy marginal-variance-per-byte descent plus an exact
multiple-choice-knapsack DP for small models.  The result is a
ready-to-use ``QuantPolicy.overrides`` mapping; ``python -m repro.analysis
plan`` prints it and writes JSON that ``launch/train.py --override-file``
consumes directly.

Candidate legality follows the execution contract, not wishful thinking:
wgrad (``Q_b1``) must be per-tensor (``qt_gemm_tn`` contracts over the row
axis per-row scales live on — core/backend.py), so only PTQ; agrad
(``Q_b2``) admits PTQ/PSQ/BHQ; widths are clamped by the int32-accumulator
bound (:func:`repro.core.analysis.ranges.max_safe_k`).
"""

from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core.policy import QuantPolicy, overrides_to_json
from ..core.theory import quantizer_variance
from .graph import iter_gemm_sites
from .ranges import max_safe_k

__all__ = ["gemm_bytes_moved", "legal_widths", "PlanSite", "Candidate",
           "PlanEntry", "Plan", "collect_plan_sites", "site_candidates",
           "plan_model"]

_GRAD_ROLES = ("wgrad", "agrad")
_WIDTHS = (8, 4, 2)
# SR-operand sample cap: variance is evaluated on a fixed-seed Gaussian
# proxy no larger than this and scaled linearly to the true element count
# (iid entries => sum-variance is ~linear in size; the ~log(d) drift of the
# dynamic range is noise at planning precision)
_SAMPLE_CAP = 1 << 16


def gemm_bytes_moved(m: int, k: int, n: int, lhs_bits: int,
                     rhs_bits: int, out_bytes: int = 4) -> float:
    """HBM bytes one (m, k) x (k, n) GEMM moves: packed sub-byte operands
    in, fp32 (by default) result out.  This is the same model behind the
    ``bytes_moved`` column in ``benchmarks/bench_kernels.py`` (f32 = 32/32,
    int8 = 8/8, packed W4/W2/W1 = 8/wbits)."""
    return m * k * lhs_bits / 8.0 + k * n * rhs_bits / 8.0 + out_bytes * m * n


def legal_widths(role: str, k: int, *, partner_bits: int = 8,
                 widths: Sequence[int] = _WIDTHS) -> Tuple[int, ...]:
    """Widths from ``widths`` legal for ``role`` at contraction size ``k``.

    Backward roles admit [2, 8] (1-bit SR degenerates; only the forward
    weight may go binary — GemmQuantConfig.validate), and the int32
    accumulator must survive ``k`` worst-case products with the partner
    operand's width (analysis/ranges.max_safe_k).
    """
    lo = 1 if role == "fwd_weight" else 2
    out = []
    for b in widths:
        if not lo <= b <= 8:
            continue
        if role == "wgrad":
            pair = (partner_bits, b)        # lhs = saved fwd act, rhs = dY
        elif role == "agrad":
            pair = (b, partner_bits)        # lhs = dY, rhs = saved weight
        else:
            pair = (b, partner_bits)
        if k <= max_safe_k(*pair):
            out.append(b)
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class PlanSite:
    """One quantized gradient GEMM group: the main (max-FLOPs) GEMM of a
    (path, role) marker scope in the traced backward graph."""

    path: str
    role: str                 # "wgrad" | "agrad"
    m: int                    # GEMM output rows
    k: int                    # contraction size
    n: int                    # GEMM output cols
    mult: int                 # enclosing-scan trip count
    flops: float
    partner_bits: int = 8     # width of the non-SR operand (saved fwd tensor)

    @property
    def sr_shape(self) -> Tuple[int, int]:
        """Shape of the operand the plan's SR quantizer rounds (always the
        incoming gradient dY): wgrad contracts over it -> (k, n); agrad
        carries it on the lhs -> (m, k)."""
        return (self.k, self.n) if self.role == "wgrad" else (self.m, self.k)

    def bytes_at(self, bits: int) -> float:
        if self.role == "wgrad":
            lhs, rhs = self.partner_bits, bits
        else:
            lhs, rhs = bits, self.partner_bits
        return gemm_bytes_moved(self.m, self.k, self.n, lhs, rhs) * self.mult


@dataclasses.dataclass(frozen=True)
class Candidate:
    quantizer: str
    bits: int
    variance: float           # predicted total Var (x scan multiplicity)
    bytes_moved: float        # bytes for the whole group (x multiplicity)


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    path: str
    role: str
    quantizer: str
    bits: int
    variance: float
    bytes_moved: float


@dataclasses.dataclass(frozen=True)
class Plan:
    arch: str
    budget_bytes: float
    entries: Tuple[PlanEntry, ...]
    total_bytes: float
    total_variance: float
    baseline_bytes: float         # uniform 8-bit PTQ on every site
    baseline_variance: float
    solver: str                   # "greedy" | "dp"
    feasible: bool                # total_bytes <= budget_bytes

    def overrides(self) -> Dict[str, dict]:
        """``{pattern: {role: "name:bits"}}`` ready for
        ``QuantPolicy(overrides=...)`` — patterns are exact-match anchors
        over the layer path."""
        by_path: Dict[str, dict] = {}
        for e in self.entries:
            by_path.setdefault(f"^{re.escape(e.path)}$", {})[e.role] = \
                f"{e.quantizer}:{e.bits}"
        return by_path

    def to_dict(self) -> dict:
        return {
            "version": 1,
            "arch": self.arch,
            "solver": self.solver,
            "feasible": self.feasible,
            "budget_bytes": self.budget_bytes,
            "total_bytes": self.total_bytes,
            "predicted_variance": self.total_variance,
            "baseline": {"bytes": self.baseline_bytes,
                         "variance": self.baseline_variance,
                         "policy": "uniform ptq:8 on every gradient site"},
            "overrides": overrides_to_json(self.overrides()),
            "sites": [dataclasses.asdict(e) for e in self.entries],
        }

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), indent=kw.pop("indent", 2), **kw)

    def format(self) -> str:
        lines = [f"== precision plan: {self.arch} ==",
                 f"budget {self.budget_bytes:.3e} B | plan "
                 f"{self.total_bytes:.3e} B | uniform-8 baseline "
                 f"{self.baseline_bytes:.3e} B",
                 f"predicted grad variance {self.total_variance:.4e} "
                 f"(baseline {self.baseline_variance:.4e}, "
                 f"{'-' if self.total_variance <= self.baseline_variance else '+'}"
                 f"{abs(1 - self.total_variance / max(self.baseline_variance, 1e-30)) * 100:.1f}%)"
                 f" | solver={self.solver}"
                 f"{'' if self.feasible else ' | OVER BUDGET'}"]
        lines.append(f"{'path':<28}{'role':<7}{'quant':<6}{'bits':>4}"
                     f"{'bytes':>12}{'variance':>12}")
        for e in sorted(self.entries, key=lambda e: (e.path, e.role)):
            lines.append(f"{e.path:<28}{e.role:<7}{e.quantizer:<6}"
                         f"{e.bits:>4}{e.bytes_moved:>12.3e}"
                         f"{e.variance:>12.4e}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Site collection (trace -> PlanSite list)
# ---------------------------------------------------------------------------

def collect_plan_sites(cfg, policy: QuantPolicy, *, batch_size: int = 2,
                       seq_len: int = 8) -> Tuple[PlanSite, ...]:
    """Trace ``cfg``'s loss gradient under ``policy`` and distill one
    :class:`PlanSite` per quantized (path, role) gradient scope — the
    max-FLOPs GEMM of the scope (satellite quantize/epilogue dots in the
    same scope ride along with its choice)."""
    from ..models.api import build_model
    from .audit import _loss_args

    model = build_model(cfg)
    params, batch = _loss_args(model, batch_size, seq_len)
    key = jax.random.PRNGKey(0)

    def loss_fn(p, b):
        loss, _ = model.loss(p, b, key, policy)
        return loss

    closed = jax.make_jaxpr(jax.grad(loss_fn))(params, batch)
    best: Dict[Tuple[str, str], object] = {}
    for s in iter_gemm_sites(closed):
        if s.kind != "quantized" or s.role not in _GRAD_ROLES:
            continue
        if s.m <= 0 or s.n <= 0:
            continue
        gk = (s.path or "?", s.role)
        if gk not in best or s.flops > best[gk].flops:
            best[gk] = s
    sites = []
    for (path, role), s in sorted(best.items()):
        resolved = policy.resolve(path)
        partner = resolved.fwd_act if role == "wgrad" else resolved.fwd_weight
        sites.append(PlanSite(
            path=path, role=role, m=s.m, k=s.contract, n=s.n, mult=s.mult,
            flops=s.flops,
            partner_bits=(partner.bits or 8) if partner is not None else 8))
    return tuple(sites)


# ---------------------------------------------------------------------------
# Candidates
# ---------------------------------------------------------------------------

def _variance_proxy(shape: Tuple[int, int], quantizer: str, bits: int,
                    **params) -> float:
    """Closed-form Var[Q_b(g)|g] on a fixed-seed Gaussian proxy of the SR
    operand, scaled to the true element count when the proxy is capped."""
    rows, cols = shape
    sr = min(rows, max(1, _SAMPLE_CAP // max(cols, 1)))
    if sr * cols > _SAMPLE_CAP and cols > _SAMPLE_CAP:
        cols_s = _SAMPLE_CAP
    else:
        cols_s = cols
    x = jax.random.normal(jax.random.PRNGKey(0), (sr, cols_s), jnp.float32)
    v = float(quantizer_variance(x, quantizer, bits, **params))
    return v * (rows * cols) / (sr * cols_s)


def site_candidates(site: PlanSite, policy: QuantPolicy) -> \
        Tuple[Candidate, ...]:
    """Pareto-pruned (variance, bytes) candidates for one site.

    wgrad is PTQ-only (``qt_gemm_tn`` needs per-tensor scales on both
    operands — per-row scales would sit on the contraction axis); agrad
    ranges over PTQ/PSQ/BHQ.  Widths are accumulator-safe per
    :func:`legal_widths`.
    """
    names = ("ptq",) if site.role == "wgrad" else ("ptq", "psq", "bhq")
    resolved = policy.resolve(site.path)
    base = getattr(resolved, site.role)
    block_rows = base.param("block_rows", policy.bhq_block) \
        if base is not None else policy.bhq_block
    cands: List[Candidate] = []
    for bits in legal_widths(site.role, site.k,
                             partner_bits=site.partner_bits):
        nbytes = site.bytes_at(bits)
        for name in names:
            params = {"block_rows": block_rows} if name == "bhq" else {}
            var = _variance_proxy(site.sr_shape, name, bits, **params) \
                * site.mult
            cands.append(Candidate(name, bits, var, nbytes))
    # Pareto prune: drop any candidate beaten (<= on both axes, < on one)
    kept = [c for c in cands
            if not any((o.variance <= c.variance and
                        o.bytes_moved <= c.bytes_moved and
                        (o.variance < c.variance or
                         o.bytes_moved < c.bytes_moved))
                       for o in cands)]
    kept.sort(key=lambda c: (-c.bytes_moved, c.variance))
    return tuple(kept)


# ---------------------------------------------------------------------------
# Solvers
# ---------------------------------------------------------------------------

def _solve_greedy(tables: Sequence[Sequence[Candidate]],
                  budget: float) -> Tuple[List[int], bool]:
    """Start every site at its min-variance candidate, then repeatedly take
    the downgrade with the smallest marginal variance per byte saved until
    the plan fits."""
    choice = [min(range(len(t)), key=lambda j: (t[j].variance,
                                                t[j].bytes_moved))
              for t in tables]
    total = sum(t[c].bytes_moved for t, c in zip(tables, choice))
    while total > budget:
        best = None                   # (slope, i, j)
        for i, t in enumerate(tables):
            cur = t[choice[i]]
            for j, c in enumerate(t):
                saved = cur.bytes_moved - c.bytes_moved
                if saved <= 0:
                    continue
                slope = (c.variance - cur.variance) / saved
                if best is None or slope < best[0]:
                    best = (slope, i, j)
        if best is None:
            return choice, False      # nothing left to shrink: over budget
        _, i, j = best
        total -= tables[i][choice[i]].bytes_moved - tables[i][j].bytes_moved
        choice[i] = j
    return choice, True


def _solve_dp(tables: Sequence[Sequence[Candidate]], budget: float,
              resolution: int = 2048) -> Optional[List[int]]:
    """Exact multiple-choice knapsack on a discretized byte axis (each
    site's cost rounds *up* one unit, so the result never overshoots the
    real budget).  Returns None when infeasible at this resolution."""
    unit = max(1.0, budget / resolution)
    cap = int(budget // unit)
    inf = math.inf
    # dp[u] = (min variance using <= u units, back-pointers)
    var = [0.0] + [inf] * cap
    back: List[List[Optional[Tuple[int, int]]]] = \
        [[None] * (cap + 1)]
    for t in tables:
        nvar = [inf] * (cap + 1)
        nback: List[Optional[Tuple[int, int]]] = [None] * (cap + 1)
        costs = [int(math.ceil(c.bytes_moved / unit)) for c in t]
        for u in range(cap + 1):
            if var[u] is inf:
                continue
            for j, cu in enumerate(costs):
                u2 = u + cu
                if u2 > cap:
                    continue
                v2 = var[u] + t[j].variance
                if v2 < nvar[u2]:
                    nvar[u2] = v2
                    nback[u2] = (u, j)
        var = nvar
        back.append(nback)
    best_u = min((u for u in range(cap + 1) if var[u] is not inf),
                 key=lambda u: var[u], default=None)
    if best_u is None:
        return None
    choice: List[int] = []
    u = best_u
    for i in range(len(tables), 0, -1):
        prev_u, j = back[i][u]
        choice.append(j)
        u = prev_u
    choice.reverse()
    return choice


def plan_model(cfg, policy: Optional[QuantPolicy] = None, *,
               budget_bytes: Optional[float] = None,
               budget_frac: Optional[float] = None,
               batch_size: int = 2, seq_len: int = 8,
               solver: str = "auto") -> Plan:
    """Plan per-site gradient precision for ``cfg`` under a bytes budget.

    ``policy`` supplies the forward widths and BHQ block size the candidates
    assume (default: uniform 8-bit FQT).  The budget defaults to the
    uniform-8-bit plan's bytes (``budget_frac`` scales it; ``budget_bytes``
    overrides it outright) — at that default the planner must *beat* uniform
    variance at equal bytes, which is the paper's Sec. 4 claim.
    """
    if solver not in ("auto", "greedy", "dp"):
        raise ValueError(f"unknown solver {solver!r}")
    policy = policy or QuantPolicy.fqt("ptq", 8)
    sites = collect_plan_sites(cfg, policy, batch_size=batch_size,
                               seq_len=seq_len)
    if not sites:
        raise ValueError(
            f"no quantized gradient GEMMs found for {cfg.name!r} under this "
            f"policy — is the backward quantized (FQT, not QAT/exact)?")
    tables = [site_candidates(s, policy) for s in sites]

    # uniform 8-bit PTQ baseline (the paper's default recipe)
    base_b = base_v = 0.0
    for s, t in zip(sites, tables):
        cand = next((c for c in t if c.quantizer == "ptq" and c.bits == 8),
                    None)
        base_b += s.bytes_at(8)
        base_v += cand.variance if cand is not None else \
            _variance_proxy(s.sr_shape, "ptq", 8) * s.mult
    budget = float(budget_bytes) if budget_bytes is not None else \
        base_b * (budget_frac if budget_frac is not None else 1.0)

    g_choice, g_ok = _solve_greedy(tables, budget)
    choice, used, ok = g_choice, "greedy", g_ok
    if solver in ("auto", "dp") and len(sites) <= 32:
        d_choice = _solve_dp(tables, budget)
        if d_choice is not None:
            d_var = sum(t[j].variance for t, j in zip(tables, d_choice))
            g_var = sum(t[j].variance for t, j in zip(tables, g_choice))
            if solver == "dp" or not g_ok or d_var < g_var:
                choice, used, ok = d_choice, "dp", True
        elif solver == "dp":
            used = "dp"

    entries = tuple(
        PlanEntry(path=s.path, role=s.role, quantizer=t[j].quantizer,
                  bits=t[j].bits, variance=t[j].variance,
                  bytes_moved=t[j].bytes_moved)
        for s, t, j in zip(sites, tables, choice))
    total_b = sum(e.bytes_moved for e in entries)
    total_v = sum(e.variance for e in entries)
    return Plan(arch=cfg.name, budget_bytes=budget, entries=entries,
                total_bytes=total_b, total_variance=total_v,
                baseline_bytes=base_b, baseline_variance=base_v,
                solver=used, feasible=ok and total_b <= budget * (1 + 1e-9))
