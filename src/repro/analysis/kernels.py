"""Static checker for Pallas kernel tile configurations.

Walks every tile source a kernel wrapper can resolve at trace time —
:data:`~repro.kernels.autotune.SHIPPED_DEFAULTS` plus every entry of the
persisted tuning cache (``~/.cache/repro/tuning.json`` /
``$REPRO_TUNING_CACHE``) — and verifies, without compiling anything:

  * **VMEM budget**: ``tile_vmem_bytes(bm, bn, bk, kind)`` under
    ``VMEM_BUDGET_BYTES`` for the kernel's family (autotune.KERNEL_SPECS);
  * **tile divisibility**: the exact MXU alignment each wrapper enforces
    via ``tiling.check_tiles(..., interpret=False)``;
  * **key well-formedness**: cache keys parse as
    ``<kernel>/<MxKxN>/<dtype>/<platform>`` with a legal dtype;
  * **staleness**: entries naming kernels no registered wrapper resolves.

Bad persisted entries are reported (and purged with ``--purge``); the
loader already refuses to serve illegal entries for known kernels
(autotune.TuningCache._validate), so this checker is the part that
*explains* and *cleans*, and the CI gate that keeps SHIPPED_DEFAULTS
legal as the kernels evolve.
"""

from __future__ import annotations

import dataclasses
import os
from typing import List, Optional, Tuple

from ..kernels.autotune import (KERNEL_SPECS, SHIPPED_DEFAULTS,
                                VMEM_BUDGET_BYTES, TuningCache, cache_path,
                                tile_vmem_bytes, validate_entry)

__all__ = ["KernelFinding", "KernelCheckReport", "check_kernels",
           "purge_bad_entries"]

# int4/int2/int1 tag the *packed weight* cache keys (q4_matmul /
# fused_packed families key tiles per sub-byte width)
_LEGAL_DTYPES = ("int8", "uint8", "int4", "int2", "int1", "float32",
                 "bfloat16", "float16")


@dataclasses.dataclass(frozen=True)
class KernelFinding:
    severity: str          # "error" | "stale" | "info"
    source: str            # "shipped" | "cache"
    key: str
    tiles: Optional[Tuple[int, int, int]]
    detail: str

    def __str__(self):
        t = "" if self.tiles is None else f" tiles={self.tiles}"
        return (f"[{self.severity}:{self.source}] {self.key}{t}: "
                f"{self.detail}")


@dataclasses.dataclass(frozen=True)
class KernelCheckReport:
    findings: Tuple[KernelFinding, ...]
    n_shipped: int
    n_cache: int
    cache_file: str

    @property
    def errors(self) -> Tuple[KernelFinding, ...]:
        return tuple(f for f in self.findings if f.severity == "error")

    @property
    def ok(self) -> bool:
        return not self.errors

    def format(self, verbose: bool = False) -> str:
        lines = ["== kernel tile check =="]
        lines.append(f"shipped defaults: {self.n_shipped} entries; "
                     f"persisted cache: {self.n_cache} entries "
                     f"({self.cache_file}"
                     f"{'' if os.path.exists(self.cache_file) else ', absent'})")
        shown = [f for f in self.findings
                 if verbose or f.severity != "info"]
        lines.extend(f"  {f}" for f in shown)
        lines.append(f"tile check: "
                     f"{'OK' if self.ok else f'{len(self.errors)} error(s)'}"
                     f" ({len(self.findings)} finding(s), budget "
                     f"{VMEM_BUDGET_BYTES / 2**20:.0f} MiB)")
        return "\n".join(lines)


def _check_tiles(source: str, key: str, kernel: str,
                 tiles) -> List[KernelFinding]:
    problems = validate_entry(kernel, tiles)
    if problems is None:
        return [KernelFinding(
            "stale", source, key, tuple(tiles),
            f"kernel {kernel!r} has no registered wrapper "
            f"(KERNEL_SPECS: {', '.join(sorted(KERNEL_SPECS))}); entry is "
            f"dead weight")]
    if problems:
        return [KernelFinding("error", source, key, tuple(tiles), p)
                for p in problems]
    kind = KERNEL_SPECS[kernel]["kind"]
    if kind == "rows":
        detail = f"bm={tiles[0]} row kernel OK"
    else:
        vmem = tile_vmem_bytes(*tiles, kind)
        detail = (f"OK: {vmem / 2**20:.2f} MiB VMEM "
                  f"({100.0 * vmem / VMEM_BUDGET_BYTES:.0f}% of budget, "
                  f"kind {kind!r})")
    return [KernelFinding("info", source, key, tuple(tiles), detail)]


def _check_cache_key(key: str) -> Optional[str]:
    """Problem string when a persisted cache key is malformed, else None."""
    parts = key.split("/")
    if len(parts) != 4:
        return (f"key does not parse as <kernel>/<shape>/<dtype>/<platform> "
                f"({len(parts)} segment(s))")
    _, shape, dtype, _ = parts
    for d in shape.split("x"):
        if not (d.isdigit() or d.isidentifier()):
            return f"shape segment {shape!r} has a non-numeric, non-name dim"
    if dtype not in _LEGAL_DTYPES:
        return f"dtype {dtype!r} not in {_LEGAL_DTYPES}"
    return None


def check_kernels(path: Optional[str] = None) -> KernelCheckReport:
    """Validate shipped defaults + every persisted cache entry statically."""
    findings: List[KernelFinding] = []

    for key, tiles in sorted(SHIPPED_DEFAULTS.items()):
        kernel = key.split("/", 1)[0]
        findings.extend(_check_tiles("shipped", key, kernel, tiles))

    cache_file = os.path.expanduser(path) if path else cache_path()
    # raw read on purpose: the loader's _validate already drops illegal
    # entries, which would hide exactly what this checker must report
    import json
    raw: dict = {}
    if os.path.exists(cache_file):
        try:
            with open(cache_file) as f:
                loaded = json.load(f)
            raw = loaded if isinstance(loaded, dict) else {}
            if not isinstance(loaded, dict):
                findings.append(KernelFinding(
                    "error", "cache", cache_file, None,
                    f"cache is not a JSON object "
                    f"(got {type(loaded).__name__})"))
        except (ValueError, OSError) as e:
            findings.append(KernelFinding(
                "error", "cache", cache_file, None,
                f"unreadable cache: {e}"))

    for key, entry in sorted(raw.items()):
        key_problem = _check_cache_key(str(key))
        if key_problem:
            findings.append(KernelFinding("error", "cache", str(key), None,
                                          key_problem))
            continue
        try:
            tiles = (int(entry["bm"]), int(entry["bn"]), int(entry["bk"]))
        except (KeyError, TypeError, ValueError):
            findings.append(KernelFinding(
                "error", "cache", str(key), None,
                f"entry {entry!r} is not a {{bm, bn, bk}} dict"))
            continue
        findings.extend(
            _check_tiles("cache", str(key), key.split("/", 1)[0], tiles))

    return KernelCheckReport(findings=tuple(findings),
                             n_shipped=len(SHIPPED_DEFAULTS),
                             n_cache=len(raw), cache_file=cache_file)


def purge_bad_entries(report: KernelCheckReport) -> int:
    """Remove every cache entry the report marks error/stale; returns the
    number purged.  Writes atomically via TuningCache.save()."""
    bad_keys = {f.key for f in report.findings
                if f.source == "cache" and f.severity in ("error", "stale")}
    if not bad_keys:
        return 0
    import json
    raw: dict = {}
    if os.path.exists(report.cache_file):
        try:
            with open(report.cache_file) as f:
                loaded = json.load(f)
            if isinstance(loaded, dict):
                raw = loaded
        except (ValueError, OSError):
            raw = {}
    kept = {k: v for k, v in raw.items() if k not in bad_keys}
    cache = TuningCache(report.cache_file)
    cache._data = kept
    cache.save()
    return len(raw) - len(kept)
