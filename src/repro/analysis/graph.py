"""Recursive jaxpr walker: find every GEMM and attribute it to a marker.

``iter_gemm_sites(closed_jaxpr)`` walks a ClosedJaxpr — recursing through
``pjit``/``scan``/``while``/``cond``/``custom_vjp``/``remat`` sub-jaxprs —
and yields one :class:`GemmSite` per ``dot_general`` /
``conv_general_dilated`` equation, carrying:

  * FLOPs (2*M*N*K*batch, multiplied by the trip count of enclosing scans),
  * the contraction size K and operand dtypes (the range analysis needs
    them for int32-accumulator bounds),
  * the quantization marker parsed from ``eqn.source_info.name_stack``
    (``q[path|role]`` / ``qfp[path|role]`` / ``fp[path]`` — see
    core/exempt.py), innermost marker winning,
  * a user-code ``file:line`` for leak reports.

The walk never executes anything — it is pure metadata traversal, so
auditing a billion-parameter step trace costs trace time only.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator, List, Optional, Tuple

import jax

from ..core.exempt import MARKER_RE

__all__ = ["GemmSite", "iter_gemm_sites", "site_flops", "classify_stack"]

GEMM_PRIMS = ("dot_general", "conv_general_dilated")


@dataclasses.dataclass(frozen=True)
class GemmSite:
    """One GEMM equation found in the walked jaxpr."""

    primitive: str                 # "dot_general" | "conv_general_dilated"
    flops: float                   # 2*M*N*K*batch * scan-trip multiplier
    contract: int                  # K (product of contraction dims)
    mult: int                      # product of enclosing scan lengths
    lhs_dtype: str
    rhs_dtype: str
    stack: str                     # full name-stack string (outer + own)
    kind: str                      # "quantized"|"policy_fp"|"exempt"|"unmarked"
    path: Optional[str]            # marker path (None when unmarked)
    role: Optional[str]            # marker role for q/qfp (None otherwise)
    src: str                       # user-code "file:line" (best effort)
    m: int = 0                     # output rows (batch*M); 0 = unknown
    n: int = 0                     # output cols N; 0 = unknown

    @property
    def integer_gemm(self) -> bool:
        """True when both operands are integer codes (native int8 GEMM)."""
        return (self.lhs_dtype.startswith(("int", "uint"))
                and self.rhs_dtype.startswith(("int", "uint")))


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def _dot_general_stats(eqn) -> Tuple[float, int, int, int]:
    """(flops-per-execution, K, M, N) for one dot_general.

    M folds the batch dims in (it is "output rows the GEMM produces"), so
    the planner's bytes-moved model sees the same m*k / k*n / m*n products
    the bench bytes column uses.
    """
    (lc, rc), (lb, _rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval.shape, eqn.invars[1].aval.shape
    batch = _prod(lhs[i] for i in lb)
    k = _prod(lhs[i] for i in lc)
    m = _prod(d for i, d in enumerate(lhs) if i not in set(lb) | set(lc))
    n = _prod(d for i, d in enumerate(rhs) if i not in set(_rb) | set(rc))
    return 2.0 * batch * m * n * k, k, batch * m, n


def _conv_stats(eqn) -> Tuple[float, int, int, int]:
    """Approximate conv FLOPs: 2 * out-elements * (C_in/groups * K_spatial).

    (M, N) map a conv onto its implicit GEMM: N = output channels, M =
    output elements per channel — good enough for the bytes-moved model.
    """
    out = eqn.outvars[0].aval.shape
    rhs = eqn.invars[1].aval.shape            # (O, I/g, *spatial) canonical-ish
    k = _prod(rhs[1:])                        # contraction per output element
    n = int(rhs[0])
    m = max(1, _prod(out) // max(n, 1))
    return 2.0 * _prod(out) * k, int(k), m, n


def _classify(stack: str) -> Tuple[str, Optional[str], Optional[str]]:
    """(kind, path, role) from the innermost marker in a name-stack string."""
    last = None
    for m in MARKER_RE.finditer(stack):
        last = m
    if last is None:
        return "unmarked", None, None
    tag, payload = last.group(1), last.group(2)
    if tag == "fp":
        return "exempt", payload, None
    path, _, role = payload.rpartition("|")
    kind = "quantized" if tag == "q" else "policy_fp"
    return kind, path, role or None


def _src_of(eqn) -> str:
    try:
        for frame in eqn.source_info.traceback.frames:
            fn = frame.file_name
            if "/jax/" in fn or "site-packages" in fn or fn.startswith("<"):
                continue
            return f"{fn}:{frame.start_line}"
    except Exception:
        pass
    return "?"


def _sub_jaxprs(eqn) -> Iterator[Tuple[object, int]]:
    """(sub-jaxpr, trip-count multiplier) pairs hiding in eqn.params.

    ``scan`` multiplies by its static ``length``; ``while`` bodies have an
    unknown trip count and conservatively count once; ``cond`` branches all
    count (a leak in any branch is a leak).
    """
    mult = 1
    if eqn.primitive.name == "scan":
        mult = int(eqn.params.get("length", 1))
    for val in eqn.params.values():
        for sub in _as_jaxprs(val):
            yield sub, mult


def _as_jaxprs(val) -> Iterator[object]:
    if isinstance(val, jax.extend.core.ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, jax.extend.core.Jaxpr):
        yield val
    elif isinstance(val, (tuple, list)):
        for v in val:
            yield from _as_jaxprs(v)


def _walk(jaxpr, mult: int, prefix: str, out: List[GemmSite]) -> None:
    for eqn in jaxpr.eqns:
        stack = str(eqn.source_info.name_stack)
        full = f"{prefix}/{stack}" if prefix and stack else (prefix or stack)
        prim = eqn.primitive.name
        if prim in GEMM_PRIMS:
            if prim == "dot_general":
                flops, k, m, n = _dot_general_stats(eqn)
            else:
                flops, k, m, n = _conv_stats(eqn)
            kind, path, role = _classify(full)
            out.append(GemmSite(
                primitive=prim, flops=flops * mult, contract=k, mult=mult,
                lhs_dtype=str(eqn.invars[0].aval.dtype),
                rhs_dtype=str(eqn.invars[1].aval.dtype),
                stack=full, kind=kind, path=path, role=role,
                src=_src_of(eqn), m=m, n=n))
        for sub, m in _sub_jaxprs(eqn):
            _walk(sub, mult * m, full, out)


def iter_gemm_sites(closed_jaxpr) -> Tuple[GemmSite, ...]:
    """Every GEMM equation in ``closed_jaxpr`` (recursively), attributed."""
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    out: List[GemmSite] = []
    _walk(jaxpr, 1, "", out)
    return tuple(out)


# the soundness + planner passes attribute non-GEMM equations with the
# same innermost-marker rule the GEMM walk uses
classify_stack = _classify


def site_flops(sites, kind: Optional[str] = None) -> float:
    """Total FLOPs over ``sites``, optionally filtered by marker kind."""
    total = math.fsum(s.flops for s in sites
                      if kind is None or s.kind == kind)
    return total
