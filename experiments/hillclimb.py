"""Hillclimb runner: re-measure one cell with optimization overrides.

    PYTHONPATH=src python experiments/hillclimb.py --arch minitron-4b \
        --shape train_4k [--cp-attn] [--out experiments/hillclimb]

Each run writes <arch>__<shape>__<tag>.json next to the baseline artifacts
so before/after diffs land in EXPERIMENTS.md Sec. Perf.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json



def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--cp-attn", action="store_true",
                    help="context-parallel attention constraint (Perf it. 6)")
    ap.add_argument("--moe-dispatch", action="store_true",
                    help="expert x capacity dispatch sharding (Perf it. 7)")
    ap.add_argument("--compress-pods", action="store_true",
                    help="unbiased int8 gradient all-reduce over the pod axis")
    ap.add_argument("--no-remat", action="store_true",
                    help="disable activation checkpointing (uint8 FQT codes "
                         "are the residuals - cheap to keep)")
    ap.add_argument("--quant", default="bhq")
    ap.add_argument("--grad-bits", type=int, default=5)
    ap.add_argument("--tag", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/hillclimb")
    args = ap.parse_args()

    from repro.core import QuantPolicy
    from repro.launch.dryrun import run_cell
    from repro.launch.mesh import make_production_mesh
    from repro.sharding import make_plan

    policy = QuantPolicy.fqt(args.quant, args.grad_bits, mode="native",
                             bhq_block=1024)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    extra = {}
    tags = []
    plan = make_plan(mesh)
    if args.cp_attn:
        extra["sdpa_hint"] = plan.attn_shardings
        tags.append("cpattn")
    if args.moe_dispatch:
        extra["moe_hint"] = plan.moe_dispatch_sharding
        tags.append("moedisp")
    if args.compress_pods:
        extra["compress_axis"] = "pod"
        tags.append("int8ar")
    if args.no_remat:
        extra["remat"] = False
        tags.append("noremat")
    tag = args.tag or ("_".join(tags) if tags else "baseline")

    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   policy=policy, mesh=mesh, extra_kwargs=extra or None)
    rec["hillclimb_tag"] = tag
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"{args.arch}__{args.shape}__{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print("wrote", path)


if __name__ == "__main__":
    main()
