"""Build the EXPERIMENTS.md roofline tables from experiments/dryrun/*.json.

    PYTHONPATH=src python experiments/make_report.py [--dir experiments/dryrun]

Emits markdown to stdout: the single-pod roofline table (one row per
arch x shape), the multi-pod compile matrix, and summary statistics.
"""

import argparse
import glob
import json
import os


def fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def load(dirname):
    cells = {}
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        cells[(rec["arch"], rec["shape"], rec["mesh"])] = rec
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "dryrun"))
    args = ap.parse_args()
    cells = load(args.dir)

    singles = {(a, s): r for (a, s, m), r in cells.items() if m == "16x16"}
    multis = {(a, s): r for (a, s, m), r in cells.items() if m == "2x16x16"}

    print("### Roofline table — single-pod 16x16 (256 chips), per device\n")
    print("| arch | shape | kind | compute | memory | collective | bottleneck"
          " | HBM GiB | useful (6ND/HLO) | coll GB/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for (a, s), r in sorted(singles.items()):
        t = r["roofline"]
        pd = r["per_device"]
        print(f"| {a} | {s} | {r['kind']} | {fmt_s(t['compute_s'])} | "
              f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
              f"{t['bottleneck']} | {pd.get('hbm_gib', 0):.1f} | "
              f"{(r['useful_flops_ratio'] or 0):.3f} | "
              f"{pd['collective_bytes']/1e9:.2f} |")

    print("\n### Multi-pod 2x16x16 (512 chips) compile matrix\n")
    print("| arch | shape | compiled | compile_s | coll GB/dev (raw) |")
    print("|---|---|---|---|---|")
    for (a, s), r in sorted(multis.items()):
        raw = r["per_device"].get("raw_uncorrected", r["per_device"])
        print(f"| {a} | {s} | yes | {r['compile_s']} | "
              f"{raw.get('collective_bytes', 0)/1e9:.2f} |")

    n_expected_single = len(singles)
    print(f"\nsingle-pod cells: {len(singles)}; multi-pod cells: "
          f"{len(multis)}")

    # bottleneck histogram
    from collections import Counter
    hist = Counter(r["roofline"]["bottleneck"] for r in singles.values())
    print(f"bottleneck distribution: {dict(hist)}")


if __name__ == "__main__":
    main()
