"""Recompute n_params / model_flops / useful ratio for existing cell JSONs
(fixes an int32 overflow in the original count_params)."""
import glob, json, math, os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import jax
from repro.configs import get_config, SHAPES
from repro.models import build_model
from repro.launch.roofline import model_flops

cache = {}
for path in glob.glob(os.path.join(os.path.dirname(__file__), "*", "*.json")):
    rec = json.load(open(path))
    arch = rec["arch"]
    if arch not in cache:
        model = build_model(get_config(arch))
        ap = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
        cache[arch] = sum(math.prod(l.shape) for l in jax.tree.leaves(ap))
    n = cache[arch]
    shape = SHAPES[rec["shape"]]
    n_tok = (shape.global_batch * shape.seq_len
             if shape.kind in ("train", "prefill") else shape.global_batch)
    mf = model_flops(n, n_tok, "train" if shape.kind == "train" else "fwd",
                     active_frac=rec["active_frac"])
    rec["n_params"] = n
    rec["model_flops_global"] = mf
    fl = rec["per_device"]["flops"]
    rec["useful_flops_ratio"] = (mf / rec["n_chips"] / fl) if fl else None
    json.dump(rec, open(path, "w"), indent=1)
    print(f"fixed {os.path.basename(path)}: n={n/1e9:.2f}B useful={rec['useful_flops_ratio']:.3f}")
