"""Quickstart: fully-quantized training of a small LM in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Trains the paper's transformer (reduced) with 5-bit BHQ gradients — the
paper's headline configuration — and compares against QAT on the same data.
"""

import jax

from repro.configs import get_config
from repro.core import QuantPolicy
from repro.launch.train import train_loop


def main():
    cfg = get_config("statquant-tx", smoke=True)
    print(f"arch: {cfg.name}  d_model={cfg.d_model} layers={cfg.n_layers}")

    print("\n--- QAT (quantized forward, fp32 backward) ---")
    _, _, qat_hist = train_loop(cfg, QuantPolicy.qat(),
                                steps=60, batch_size=8, seq_len=32, lr=4e-3)

    print("\n--- FQT, 5-bit BHQ gradients (the paper's headline) ---")
    _, _, fqt_hist = train_loop(cfg, QuantPolicy.fqt("bhq", 5, bhq_block=32),
                                steps=60, batch_size=8, seq_len=32, lr=4e-3)

    print(f"\nfinal loss  QAT: {qat_hist[-1][1]:.4f}   "
          f"FQT/BHQ@5b: {fqt_hist[-1][1]:.4f}")
    print("(Theorem 1: both estimate the same gradient in expectation; "
          "Theorem 2: BHQ keeps the added variance small at 5 bits.)")


if __name__ == "__main__":
    main()
