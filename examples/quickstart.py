"""Quickstart: the role-based quantizer API end-to-end.

    PYTHONPATH=src python examples/quickstart.py            # full demo (trains)
    PYTHONPATH=src python examples/quickstart.py --dry-run  # CI import smoke

Three things in ~60 lines:

  1. register a custom quantizer — it plugs into the registry and the
     ``_fqt`` custom_vjp uses it without any core changes;
  2. build a mixed-precision policy tree: exact lm_head, 8-bit attention,
     4-bit BHQ MLP activation-grads (the paper's bifurcation, per-layer);
  3. print the resolved per-layer spec table, then train the paper's
     (reduced) transformer under it vs. QAT.
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (QuantPolicy, Quantizer, fqt_matmul,
                        quantize_ptq_stoch, register_quantizer)
from repro.models import model_quant_paths


# --- 1. a custom quantizer plugs in through the registry -------------------

class ClippedPTQ(Quantizer):
    """Toy: clip to k standard deviations, then stochastic per-tensor PTQ.

    Spec params: ``k`` (clip width, default 3.0).  Note the object owns its
    whole implementation — a real kernel author would branch on ``backend``
    here (as the built-ins do for the fused Pallas quantize kernels).
    """

    name = "clipped_ptq"

    def quantize(self, x2d, key, spec, *, backend, interpret=None):
        k = spec.param("k", 3.0)
        lim = k * jnp.std(x2d)
        return quantize_ptq_stoch(jnp.clip(x2d, -lim, lim), key,
                                  spec.bits or 8)


register_quantizer("clipped_ptq", ClippedPTQ())


# --- 2. a heterogeneous policy, purely from config -------------------------

def build_policy(backend: str = "simulate") -> QuantPolicy:
    return QuantPolicy.fqt("bhq", 5, bhq_block=32, backend=backend, overrides={
        r"lm_head|embed": "exact",                  # pin head full precision
        r"layers\.attn\.": 8,                       # attention at 8 bits
        r"layers\.mlp\.": {"agrad": ("bhq", 4)},    # 4-bit BHQ MLP agrad
        r"layers\.mlp\.fc2": {"wgrad": "clipped_ptq:6"},  # custom quantizer
    })


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="resolve + one matmul, no training (CI smoke)")
    args = ap.parse_args()

    cfg = get_config("statquant-tx", smoke=True)
    policy = build_policy()

    # --- 3. the resolved per-layer table ----------------------------------
    print(f"arch: {cfg.name}  d_model={cfg.d_model} layers={cfg.n_layers}")
    print("\nresolved per-layer quantizer specs:")
    for path, desc in policy.spec_table(model_quant_paths(cfg)):
        print(f"  {path:20s} {desc}")

    # the custom quantizer really runs (registry -> custom_vjp dispatch)
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 16)) * 0.3
    g = jax.grad(lambda a: jnp.sum(
        fqt_matmul(a, w, jax.random.PRNGKey(2), policy,
                   path="layers.mlp.fc2") ** 2))(x)
    print(f"\ncustom-quantizer backward OK: |dx| = {float(jnp.abs(g).sum()):.3f}")

    if args.dry_run:
        print("[dry-run] skipping training")
        return

    from repro.engine import Engine
    print("\n--- QAT (quantized forward, fp32 backward) ---")
    qat_hist = Engine(cfg, QuantPolicy.qat(), steps=60, batch_size=8,
                      seq_len=32, lr=4e-3).run()

    print("\n--- FQT, mixed-precision policy tree (5-bit BHQ default) ---")
    # accum_steps=2: the same 60 steps as two microbatches each, SR noise
    # independent per microbatch (the engine's lax.scan accumulation)
    fqt_hist = Engine(cfg, policy, steps=60, batch_size=8, seq_len=32,
                      lr=4e-3, accum_steps=2).run()

    print(f"\nfinal loss  QAT: {qat_hist[-1][1]:.4f}   "
          f"heterogeneous FQT: {fqt_hist[-1][1]:.4f}")
    print("(Theorem 1: every registered stochastic quantizer is unbiased, so "
          "both estimate the same gradient in expectation; Theorem 2: the "
          "per-layer bitwidths control the added variance.)")


if __name__ == "__main__":
    main()
