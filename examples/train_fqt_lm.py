"""End-to-end driver: train a ~100M-parameter LM with FQT for a few hundred
steps through the engine — checkpointing, preemption handling, prefetch,
gradient accumulation, and exact resume.

    PYTHONPATH=src python examples/train_fqt_lm.py \
        [--steps 300] [--quant bhq] [--grad-bits 5] [--accum 4]

This is the assignment's (b) end-to-end example: a real (non-smoke) model —
a 12-layer, d=768 decoder LM (~110M params with the 32k-padded vocab) — on
deterministic synthetic data, fully quantized forward+backward.  With
``--accum k`` the global batch is consumed as k microbatches under
``lax.scan`` (one microbatch of activation memory, independent SR draws per
microbatch).
"""

import argparse

from repro.configs.base import ArchConfig
from repro.core import QuantPolicy
from repro.engine import Engine
from repro.runtime import PreemptionHandler


def lm_100m() -> ArchConfig:
    return ArchConfig(
        name="fqt-lm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=3072, vocab_size=32_000,
        act="swiglu", rope="standard",
        source="examples/train_fqt_lm.py (GPT-2-small-class FQT demo)",
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8,
                    help="global batch per optimizer step")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--quant", default="bhq", choices=["ptq", "psq", "bhq"])
    ap.add_argument("--grad-bits", type=int, default=5)
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches per step")
    ap.add_argument("--ckpt-dir", default="/tmp/fqt_lm_100m_ckpt")
    args = ap.parse_args(argv)

    cfg = lm_100m()
    n_params = (cfg.padded_vocab * cfg.d_model * 2
                + cfg.n_layers * (cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv_heads)
                                  * cfg.hd + cfg.n_heads * cfg.hd * cfg.d_model
                                  + 3 * cfg.d_model * cfg.d_ff))
    print(f"model: {cfg.name}  ~{n_params/1e6:.0f}M params  "
          f"FQT={args.quant}@{args.grad_bits}b  accum={args.accum}")

    policy = QuantPolicy.fqt(args.quant, args.grad_bits, bhq_block=256)
    prm = PreemptionHandler(install=True)
    eng = Engine(cfg, policy, steps=args.steps, batch_size=args.batch,
                 seq_len=args.seq, lr=3e-3, opt_name="adamw",
                 accum_steps=args.accum, remat=True,
                 ckpt_dir=args.ckpt_dir, ckpt_every=100, log_every=10,
                 preemption=prm)
    eng.run()
    print("done — checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
