"""Reproduce the paper's Fig. 3(a): gradient-quantizer variance vs bitwidth.

    PYTHONPATH=src python examples/variance_analysis.py

Trains a small model to develop the sparse-outlier gradient structure, then
Monte-Carlo-estimates Var[Q_b(g)|g] for PTQ / PSQ / BHQ at 3..8 bits and
prints the table (the paper's findings to check: ~4x per bit; BHQ ~ PTQ with
3 fewer bits; ordering BHQ < PSQ < PTQ).
"""

import jax

from benchmarks.common import grad_snapshot
from repro.core import (quantize_bhq_stoch, quantize_psq_stoch,
                        quantize_ptq_stoch)
from repro.core.theory import empirical_mean_and_variance


def main():
    print("capturing gradient snapshot (brief training run)...")
    snaps = grad_snapshot()
    quants = {
        "ptq": lambda x, k, b: quantize_ptq_stoch(x, k, b).dequant(),
        "psq": lambda x, k, b: quantize_psq_stoch(x, k, b).dequant(),
        "bhq": lambda x, k, b: quantize_bhq_stoch(x, k, b,
                                                  block_rows=128).dequant(),
    }
    for gname, g in snaps:
        print(f"\ngradient tensor: {gname}  shape={tuple(g.shape)}")
        print(f"{'bits':>5} | " + " | ".join(f"{q:>12}" for q in quants))
        for bits in (8, 6, 5, 4, 3):
            vals = []
            for fn in quants.values():
                f = jax.jit(lambda x, k, b=bits, fq=fn: fq(x, k, b))
                _, var = empirical_mean_and_variance(
                    f, g, jax.random.PRNGKey(bits), n_samples=128)
                vals.append(float(var))
            print(f"{bits:>5} | " + " | ".join(f"{v:12.4g}" for v in vals))
        print("(expect: each row ~4x the one above; BHQ << PSQ << PTQ)")


if __name__ == "__main__":
    main()
