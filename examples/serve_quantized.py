"""Serving example: train with FQT, then serve with inference quantization.

    PYTHONPATH=src python examples/serve_quantized.py

Covers the full lifecycle: FQT training -> checkpoint -> restore -> batched
prefill+decode serving with deterministic 8-bit forward quantizers.
"""

import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import QuantPolicy
from repro.data import make_batch_for
from repro.launch.serve import generate
from repro.launch.train import train_loop
from repro.models import build_model


def main():
    cfg = get_config("statquant-tx", smoke=True)
    ckpt_dir = "/tmp/fqt_serve_demo"

    print("1) training with 6-bit PSQ FQT ...")
    params, _, _ = train_loop(cfg, QuantPolicy.fqt("psq", 6),
                              steps=60, batch_size=8, seq_len=32, lr=4e-3,
                              ckpt_dir=ckpt_dir, ckpt_every=30,
                              log_every=20, resume=False)

    print("2) restoring latest checkpoint ...")
    ckpt = CheckpointManager(ckpt_dir)
    step = ckpt.latest_step()
    model = build_model(cfg)
    restored = ckpt.restore(step, {"params": params,
                                   "opt": {"m": params, "v": params,
                                           "t": jnp.zeros((), jnp.int32)}})
    params = restored["params"]

    print("3) serving with 8-bit inference quantization ...")
    batch = make_batch_for(cfg, 4, 16)
    batch.pop("labels")
    toks = generate(model, params, batch, QuantPolicy.qat(),
                    max_new=12, max_seq=32)
    for i, row in enumerate(toks.tolist()):
        print(f"   request {i}: {row}")


if __name__ == "__main__":
    main()
