"""Serving example: train with FQT, then serve with continuous batching.

    PYTHONPATH=src python examples/serve_quantized.py

Covers the full lifecycle: FQT training -> TrainState checkpoint ->
ServeEngine.from_checkpoint (no conversion) -> mixed-length requests
streaming through a fixed pool of decode slots with an int8-quantized KV
cache and per-request sampling.
"""

import numpy as np

from repro.configs import get_config
from repro.core import QuantPolicy
from repro.launch.train import train_loop
from repro.serve import ServeEngine


def main():
    cfg = get_config("statquant-tx", smoke=True)
    ckpt_dir = "/tmp/fqt_serve_demo"

    print("1) training with 6-bit PSQ FQT ...")
    train_loop(cfg, QuantPolicy.fqt("psq", 6),
               steps=60, batch_size=8, seq_len=32, lr=4e-3,
               ckpt_dir=ckpt_dir, ckpt_every=30,
               log_every=20, resume=False)

    print("2) serving from the checkpoint (4 slots, int8 KV cache) ...")
    eng = ServeEngine.from_checkpoint(
        cfg, ckpt_dir, policy=QuantPolicy.qat(),   # 8-bit inference quant
        slots=4, max_seq=48, kv_quant=True, seed=0)

    rng = np.random.RandomState(0)
    for i in range(6):
        plen = int(rng.randint(4, 16))
        eng.submit(rng.randint(0, cfg.vocab_size, size=plen),
                   max_new=12,
                   temperature=0.0 if i % 2 else 0.7,
                   top_k=0 if i % 2 else 20)

    completions = eng.run()
    for rid in sorted(completions):
        c = completions[rid]
        print(f"   request {rid} ({c.reason}, prompt {c.prompt_len}): "
              f"{c.tokens}")


if __name__ == "__main__":
    main()
