"""Paper Fig. 3(a) + Fig. 5(a): quantizer variance vs bitwidth per quantizer.

Measures Monte-Carlo Var[Q_b(g)|g] on real gradient snapshots (partially
trained model) for PTQ / PSQ / BHQ at 3-8 bits, plus the paper-G vs
refined-G BHQ ablation (DESIGN.md Sec. 6).
"""

from __future__ import annotations

import jax

from repro.core import (quantize_bhq_stoch, quantize_psq_stoch,
                        quantize_ptq_stoch)
from repro.core.theory import empirical_mean_and_variance

from .common import grad_snapshot


def run(n_samples: int = 128):
    rows = []
    snaps = grad_snapshot()
    quants = {
        "ptq": lambda x, k, b: quantize_ptq_stoch(x, k, b).dequant(),
        "psq": lambda x, k, b: quantize_psq_stoch(x, k, b).dequant(),
        "bhq": lambda x, k, b: quantize_bhq_stoch(x, k, b,
                                                  block_rows=128).dequant(),
        "bhq_paperG": lambda x, k, b: quantize_bhq_stoch(
            x, k, b, block_rows=128, g_search="paper").dequant(),
    }
    for gname, g in snaps:
        for qname, qfn in quants.items():
            for bits in (3, 4, 5, 6, 8):
                fn = jax.jit(lambda x, k, b=bits, q=qfn: q(x, k, b))
                _, var = empirical_mean_and_variance(
                    fn, g, jax.random.PRNGKey(bits), n_samples)
                rows.append((f"fig3_var/{gname}/{qname}/{bits}b",
                             0.0, float(var)))
    # headline: bits BHQ saves vs PTQ at matched variance (paper: ~3 bits)
    def var_of(q, bits, g):
        fn = jax.jit(lambda x, k: quants[q](x, k, bits))
        return float(empirical_mean_and_variance(
            fn, g, jax.random.PRNGKey(0), n_samples)[1])
    g = snaps[0][1]
    v_ptq8 = var_of("ptq", 8, g)
    for bits in (8, 7, 6, 5, 4, 3):
        if var_of("bhq", bits, g) > v_ptq8:
            rows.append(("fig3_bits_saved/bhq_vs_ptq8", 0.0, float(8 - (bits + 1))))
            break
    else:
        rows.append(("fig3_bits_saved/bhq_vs_ptq8", 0.0, 5.0))
    return rows
