"""Serving-engine benchmark: throughput, per-token latency, and the
resident-slot arithmetic of the int8 KV cache.

Three measurements, dumped to ``BENCH_serve.json``:

  * ``variants`` — for fp32-cache vs int8-cache at several slot counts:
    sustained tok/s and p50/p95 per-token (step) latency through the full
    continuous-batching engine on a mixed-length workload (compile steps
    excluded via a warmup drain).
  * ``memory`` — per-slot KV-cache bytes for each variant and the resident
    slot counts a fixed HBM budget buys: the int8 cache stores 1 byte/entry
    plus one (scale, zero) pair per row vs 4 bytes/entry fp32, so at equal
    memory it holds ~4x the slots (>= 2x is the acceptance bar).
  * ``parity`` — stepwise decode vs prefill logits on all three execution
    backends: exact (fp) decode must match prefill to float tolerance, and
    the int8-KV drift must stay within a small multiple of the fp-path
    quantized-forward drift.
  * ``weight_memory`` — resident bytes of the dense GEMM kernels fp32 vs
    bit-packed (kernels/pack.py) at 8/4/2 bits; >= 4x reduction at 4-bit
    is the acceptance bar (4-bit packs 2 codes/byte -> ~8x vs fp32, plus
    one affine pair per tensor/layer).
  * ``paged`` — the paged engine (serve/paged.py): throughput/latency rows
    next to the dense-slot ones; an *equal-HBM residency* run (a paged
    pool of exactly the dense engine's KV bytes serving twice the lanes —
    peak concurrently-resident requests is the acceptance number, >= 2x);
    and a **Poisson open-loop overload** run — arrivals at ~2x the
    measured service rate on a virtual clock assembled from measured step
    wall times, reporting per-request p50/p95/p99 latency and page-pool
    utilization, with speculative decode off and on.  The Poisson
    percentiles characterize a latency *distribution* under a fixed
    arrival seed, not a head-to-head comparison, so they are single-pass
    (min-of-iters does not apply); the service-rate estimate feeding
    lambda is itself a full closed-loop drain.

Throughput/latency are min-of-iters: each variant's timed workload runs
``ITERS`` times and the best iteration is reported, so one scheduler hiccup
(GC, page cache, a noisy neighbour on the 1-core CI host) cannot invert a
comparison — a single-run version of this bench once showed int8-KV slower
than fp32 at slots=8 for exactly that reason.

Wall-clock numbers are XLA-path only (interpret-mode Pallas timing on CPU is
meaningless — see BENCH_kernels.json conventions); the pallas parity row
runs the fused dequant kernel in interpret mode for *numerics*, not speed.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import QuantPolicy, kv_cache_bytes_per_row
from repro.kernels.pack import PackedTensor
from repro.models import build_model
from repro.serve import ServeEngine
from repro.serve.engine import pack_dense_weights

BENCH_JSON = os.environ.get("BENCH_SERVE_JSON", "BENCH_serve.json")
SLOT_COUNTS = (2, 4, 8)
MAX_SEQ = 48
MAX_NEW = 16
REQUESTS_PER_SLOT = 3
ITERS = 3                      # timed repeats per variant; best one reported
HBM_BUDGET = 64 << 30          # 64 GiB: the resident-slot arithmetic budget
PAGE_SIZE = 8
N_POISSON = 32                 # completed requests per open-loop pass
POISSON_MAX_NEW = 8
OVERLOAD = 2.0                 # arrival rate as a multiple of service rate


def _submit_workload(eng, cfg, n_requests: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    for _ in range(n_requests):
        plen = int(rng.randint(4, 17))
        eng.submit(rng.randint(0, cfg.vocab_size, size=plen),
                   max_new=MAX_NEW)


def _run_variant(cfg, params, kv_quant: bool, slots: int,
                 weight_bits=None, paged: bool = False, **paged_kw) -> dict:
    kw = dict(page_size=PAGE_SIZE, **paged_kw) if paged else {}
    eng = ServeEngine(cfg, params, policy=QuantPolicy.qat(), slots=slots,
                      max_seq=MAX_SEQ, kv_quant=kv_quant, seed=0,
                      weight_bits=weight_bits, paged=paged, **kw)
    # warmup drain: compiles the decode step + the prefill/insert buckets
    _submit_workload(eng, cfg, slots, seed=1)
    eng.run()
    # min-of-iters: the same deterministic workload ITERS times; keep the
    # iteration with the smallest summed step time (see module docstring)
    best = None
    for _ in range(ITERS):
        eng.step_times.clear()
        _submit_workload(eng, cfg, REQUESTS_PER_SLOT * slots, seed=0)
        out = eng.run()
        dts = np.asarray([dt for dt, n in eng.step_times if n > 0])
        emitted = sum(n for _, n in eng.step_times)
        total = float(np.sum(dts)) if dts.size else 0.0
        if best is None or (total and total < best[0]):
            best = (total, dts, emitted, out)
    total, dts, emitted, out = best
    n_tok = sum(len(c.tokens) for c in out.values())
    row = {
        "slots": slots,
        "kv": ("paged_int8" if paged else "int8") if kv_quant else "fp32",
        "requests": len(out),
        "tokens": n_tok,
        "iters": ITERS,
        "tok_per_sec": emitted / total if total else 0.0,
        "p50_ms": float(np.percentile(dts, 50)) * 1e3 if dts.size else 0.0,
        "p95_ms": float(np.percentile(dts, 95)) * 1e3 if dts.size else 0.0,
    }
    if weight_bits is not None:
        row["weight_bits"] = weight_bits
    if paged:
        row["pool"] = eng.pool_stats()
        if eng.spec_decode:
            row["spec"] = eng.spec_stats.as_dict()
    return row


def _paged_residency_record(cfg, params, dense_slots: int = 4) -> dict:
    """Equal-HBM residency: give the paged engine EXACTLY the dense
    engine's KV byte budget (``dense_slots * max_seq`` rows, garbage page
    included) but twice the decode lanes, and drive a short-request
    workload through it.  The dense engine can never hold more than
    ``dense_slots`` requests in that budget — every lane pins ``max_seq``
    rows whether used or not; the paged engine holds whatever actually
    fits, and the measured peak concurrent residency is the acceptance
    number (>= 2x)."""
    nb = MAX_SEQ // PAGE_SIZE
    pool_pages = dense_slots * nb          # total rows == dense engine's
    eng = ServeEngine(cfg, params, policy=QuantPolicy.qat(),
                      slots=2 * dense_slots, max_seq=MAX_SEQ, kv_quant=True,
                      seed=0, paged=True, page_size=PAGE_SIZE,
                      pages=pool_pages)
    rng = np.random.RandomState(7)
    for _ in range(4 * dense_slots):
        plen = int(rng.randint(4, 13))
        eng.submit(rng.randint(0, cfg.vocab_size, size=plen), max_new=8)
    peak_resident = 0
    while eng.queued or eng.active_slots:
        eng.step()
        peak_resident = max(peak_resident, eng.active_slots)
    eng.run()
    stats = eng.pool_stats()
    return {
        "dense_resident_at_equal_hbm": dense_slots,
        "paged_peak_resident": peak_resident,
        "resident_ratio": peak_resident / dense_slots,
        "pool_rows": pool_pages * PAGE_SIZE,
        "dense_rows": dense_slots * MAX_SEQ,
        "peak_page_utilization": stats["peak_utilization"],
        "preemptions": stats["preemptions"],
    }


def _poisson_record(cfg, params, spec: bool) -> dict:
    """Open-loop Poisson arrivals at ``OVERLOAD``x the measured service
    rate, on a virtual clock: each engine step advances the clock by its
    measured wall time, and a request's latency is completion time minus
    its (virtual) arrival time.  Sustained overload means the backlog
    grows and the tail percentiles reflect queueing, not just service."""
    eng = ServeEngine(cfg, params, policy=QuantPolicy.qat(), slots=4,
                      max_seq=MAX_SEQ, kv_quant=True, seed=0, paged=True,
                      page_size=PAGE_SIZE, spec_decode=spec, spec_k=3)
    rng = np.random.RandomState(11)

    def prompt():
        return rng.randint(0, cfg.vocab_size, size=int(rng.randint(4, 13)))

    # warmup + service-rate estimate: closed-loop drain of a full pool
    for _ in range(8):
        eng.submit(prompt(), max_new=POISSON_MAX_NEW)
    eng.run()
    t0 = time.perf_counter()
    for _ in range(12):
        eng.submit(prompt(), max_new=POISSON_MAX_NEW)
    eng.run()
    service_rate = 12 / (time.perf_counter() - t0)     # requests / sec

    lam = OVERLOAD * service_rate
    arrivals = np.cumsum(rng.exponential(1.0 / lam, size=N_POISSON))
    eng.spec_stats = type(eng.spec_stats)()            # reset accounting
    eng.page_usage.clear()
    now, submitted, seen = 0.0, 0, set()
    arrival_of, latencies = {}, []
    while len(latencies) < N_POISSON:
        while submitted < N_POISSON and arrivals[submitted] <= now:
            rid = eng.submit(prompt(), max_new=POISSON_MAX_NEW)
            arrival_of[rid] = arrivals[submitted]
            submitted += 1
        if not eng.active_slots and not eng.queued:
            if submitted >= N_POISSON:
                break                                  # nothing left to do
            now = arrivals[submitted]                  # idle: jump ahead
            continue
        t0 = time.perf_counter()
        eng.step()
        now += time.perf_counter() - t0
        for rid in eng.completions():
            if rid not in seen:
                seen.add(rid)
                latencies.append(now - arrival_of[rid])
    eng.run()                                          # drain + clear
    lat = np.asarray(latencies) * 1e3                  # ms
    stats = eng.pool_stats()
    rec = {
        "spec_decode": spec,
        "requests": N_POISSON,
        "service_rate_req_per_s": service_rate,
        "arrival_rate_req_per_s": lam,
        "overload": OVERLOAD,
        "latency_p50_ms": float(np.percentile(lat, 50)),
        "latency_p95_ms": float(np.percentile(lat, 95)),
        "latency_p99_ms": float(np.percentile(lat, 99)),
        "mean_page_utilization": stats["mean_utilization"],
        "peak_page_utilization": stats["peak_utilization"],
        "preemptions": stats["preemptions"],
        "prefix_hits": stats["prefix_hits"],
    }
    if spec:
        rec["spec"] = eng.spec_stats.as_dict()
    return rec


def _memory_record(cfg) -> dict:
    flat = cfg.n_kv_heads * cfg.hd
    rows_per_slot = 2 * cfg.n_layers * MAX_SEQ          # k and v, every layer
    per_slot = {
        "fp32": rows_per_slot * kv_cache_bytes_per_row(flat, False),
        "int8": rows_per_slot * kv_cache_bytes_per_row(flat, True),
    }
    resident = {k: HBM_BUDGET // v for k, v in per_slot.items()}
    return {
        "kv_rows_per_slot": rows_per_slot,
        "bytes_per_slot": per_slot,
        "hbm_budget_bytes": HBM_BUDGET,
        "resident_slots_at_budget": resident,
        "slot_ratio_int8_over_fp32": resident["int8"] / resident["fp32"],
    }


def _weight_memory_record(cfg, params) -> dict:
    """Resident bytes of the dense GEMM kernels: fp32 vs bit-packed."""
    dense_fp = 0

    def walk(node):
        nonlocal dense_fp
        if isinstance(node, dict):
            for k, v in node.items():
                if k == "w" and getattr(v, "ndim", 0) >= 2:
                    dense_fp += int(v.nbytes)
                else:
                    walk(v)

    walk(params)
    packed = {}
    for bits in (8, 4, 2):
        pb = sum(int(leaf.nbytes) for leaf in jax.tree.leaves(
            pack_dense_weights(params, bits),
            is_leaf=lambda x: isinstance(x, PackedTensor))
            if isinstance(leaf, PackedTensor))
        packed[str(bits)] = {"bytes": pb,
                             "reduction_vs_fp32": dense_fp / pb}
    return {"dense_fp32_bytes": dense_fp, "packed": packed}


def _parity_record(cfg, params) -> dict:
    """Stepwise decode vs prefill logits, per backend, fp and int8-KV."""
    model = build_model(cfg)
    B, T = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, T), 0,
                              cfg.vocab_size)
    out = {}
    for backend in ("simulate", "native", "pallas"):
        pol = QuantPolicy.qat(backend=backend)
        exact = QuantPolicy(enabled=False, backend=backend)
        row = {}
        for name, policy in (("exact", exact), ("qat", pol)):
            lg_pre, _ = model.prefill(params, {"tokens": toks}, policy,
                                      max_seq=T + 2)
            scale = float(jnp.max(jnp.abs(lg_pre))) + 1e-9
            for kv, init in (("fp32", model.init_cache),
                             ("int8", model.init_cache_quant)):
                if kv == "int8":
                    cache = init(cfg, B, T + 2)
                else:
                    cache = init(cfg, B, T + 2)
                    cache["index"] = jnp.zeros((B,), jnp.int32)
                pos = jnp.zeros((B,), jnp.int32)
                lg = None
                for t in range(T):
                    lg, cache = model.decode(
                        params, cache, {"tokens": toks[:, t:t + 1]}, policy,
                        positions=pos)
                    pos = pos + 1
                # exact policy never touches the int8 cache quantizers'
                # forward GEMMs, but the cache codec still rounds — only
                # the fp cache must match to float tolerance
                row[f"{name}_{kv}_max_abs"] = float(
                    jnp.max(jnp.abs(lg - lg_pre)))
                row[f"{name}_{kv}_rel"] = float(
                    jnp.max(jnp.abs(lg - lg_pre))) / scale
        row["pass"] = (row["exact_fp32_max_abs"] < 1e-4
                       and row["qat_fp32_rel"] < 0.05
                       and row["qat_int8_rel"] < 0.10)
        out[backend] = row
    return out


def run():
    cfg = get_config("statquant-tx", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    record = {"arch": cfg.name, "max_seq": MAX_SEQ, "max_new": MAX_NEW,
              "variants": [], "memory": _memory_record(cfg),
              "weight_memory": _weight_memory_record(cfg, params),
              "parity": _parity_record(cfg, params)}
    rows = []
    for slots in SLOT_COUNTS:
        for kv_quant in (False, True):
            v = _run_variant(cfg, params, kv_quant, slots)
            record["variants"].append(v)
            rows.append((f"serve/{v['kv']}_slots={slots}",
                         v["p50_ms"] * 1e3, v["tok_per_sec"]))
    # packed-weight variant: int8 KV + 4-bit packed dense kernels
    v = _run_variant(cfg, params, True, 4, weight_bits=4)
    record["variants"].append(v)
    rows.append(("serve/int8_slots=4_w4", v["p50_ms"] * 1e3,
                 v["tok_per_sec"]))

    # paged engine: throughput rows, equal-HBM residency, Poisson overload
    paged_variants = []
    for slots in SLOT_COUNTS:
        v = _run_variant(cfg, params, True, slots, paged=True)
        paged_variants.append(v)
        rows.append((f"serve/paged_int8_slots={slots}", v["p50_ms"] * 1e3,
                     v["tok_per_sec"]))
    v = _run_variant(cfg, params, True, 4, paged=True, spec_decode=True,
                     spec_k=3)
    paged_variants.append(v)
    rows.append(("serve/paged_int8_slots=4_spec", v["p50_ms"] * 1e3,
                 v["tok_per_sec"]))
    residency = _paged_residency_record(cfg, params)
    poisson = {"spec_off": _poisson_record(cfg, params, spec=False),
               "spec_on": _poisson_record(cfg, params, spec=True)}
    record["paged"] = {"page_size": PAGE_SIZE, "variants": paged_variants,
                       "residency": residency, "poisson": poisson}
    rows.append(("serve/paged_poisson_p99_off",
                 poisson["spec_off"]["latency_p99_ms"] * 1e3,
                 residency["resident_ratio"]))
    rows.append(("serve/paged_poisson_p99_on",
                 poisson["spec_on"]["latency_p99_ms"] * 1e3,
                 poisson["spec_on"]["spec"]["acceptance_rate"]))

    ratio = record["memory"]["slot_ratio_int8_over_fp32"]
    w4 = record["weight_memory"]["packed"]["4"]["reduction_vs_fp32"]
    record["acceptance"] = {
        "slot_ratio_ge_2x": ratio >= 2.0,
        "packed_w4_reduction_ge_4x": w4 >= 4.0,
        "parity_all_backends": all(v["pass"]
                                   for v in record["parity"].values()),
        "paged_resident_ge_2x": residency["resident_ratio"] >= 2.0,
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=1)
    return rows
