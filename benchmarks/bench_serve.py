"""Serving-engine benchmark: throughput, per-token latency, and the
resident-slot arithmetic of the int8 KV cache.

Three measurements, dumped to ``BENCH_serve.json``:

  * ``variants`` — for fp32-cache vs int8-cache at several slot counts:
    sustained tok/s and p50/p95 per-token (step) latency through the full
    continuous-batching engine on a mixed-length workload (compile steps
    excluded via a warmup drain).
  * ``memory`` — per-slot KV-cache bytes for each variant and the resident
    slot counts a fixed HBM budget buys: the int8 cache stores 1 byte/entry
    plus one (scale, zero) pair per row vs 4 bytes/entry fp32, so at equal
    memory it holds ~4x the slots (>= 2x is the acceptance bar).
  * ``parity`` — stepwise decode vs prefill logits on all three execution
    backends: exact (fp) decode must match prefill to float tolerance, and
    the int8-KV drift must stay within a small multiple of the fp-path
    quantized-forward drift.
  * ``weight_memory`` — resident bytes of the dense GEMM kernels fp32 vs
    bit-packed (kernels/pack.py) at 8/4/2 bits; >= 4x reduction at 4-bit
    is the acceptance bar (4-bit packs 2 codes/byte -> ~8x vs fp32, plus
    one affine pair per tensor/layer).

Throughput/latency are min-of-iters: each variant's timed workload runs
``ITERS`` times and the best iteration is reported, so one scheduler hiccup
(GC, page cache, a noisy neighbour on the 1-core CI host) cannot invert a
comparison — a single-run version of this bench once showed int8-KV slower
than fp32 at slots=8 for exactly that reason.

Wall-clock numbers are XLA-path only (interpret-mode Pallas timing on CPU is
meaningless — see BENCH_kernels.json conventions); the pallas parity row
runs the fused dequant kernel in interpret mode for *numerics*, not speed.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import QuantPolicy, kv_cache_bytes_per_row
from repro.kernels.pack import PackedTensor
from repro.models import build_model
from repro.serve import ServeEngine
from repro.serve.engine import pack_dense_weights

BENCH_JSON = os.environ.get("BENCH_SERVE_JSON", "BENCH_serve.json")
SLOT_COUNTS = (2, 4, 8)
MAX_SEQ = 48
MAX_NEW = 16
REQUESTS_PER_SLOT = 3
ITERS = 3                      # timed repeats per variant; best one reported
HBM_BUDGET = 64 << 30          # 64 GiB: the resident-slot arithmetic budget


def _submit_workload(eng, cfg, n_requests: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    for _ in range(n_requests):
        plen = int(rng.randint(4, 17))
        eng.submit(rng.randint(0, cfg.vocab_size, size=plen),
                   max_new=MAX_NEW)


def _run_variant(cfg, params, kv_quant: bool, slots: int,
                 weight_bits=None) -> dict:
    eng = ServeEngine(cfg, params, policy=QuantPolicy.qat(), slots=slots,
                      max_seq=MAX_SEQ, kv_quant=kv_quant, seed=0,
                      weight_bits=weight_bits)
    # warmup drain: compiles the decode step + the prefill/insert buckets
    _submit_workload(eng, cfg, slots, seed=1)
    eng.run()
    # min-of-iters: the same deterministic workload ITERS times; keep the
    # iteration with the smallest summed step time (see module docstring)
    best = None
    for _ in range(ITERS):
        eng.step_times.clear()
        _submit_workload(eng, cfg, REQUESTS_PER_SLOT * slots, seed=0)
        out = eng.run()
        dts = np.asarray([dt for dt, n in eng.step_times if n > 0])
        emitted = sum(n for _, n in eng.step_times)
        total = float(np.sum(dts)) if dts.size else 0.0
        if best is None or (total and total < best[0]):
            best = (total, dts, emitted, out)
    total, dts, emitted, out = best
    n_tok = sum(len(c.tokens) for c in out.values())
    row = {
        "slots": slots,
        "kv": "int8" if kv_quant else "fp32",
        "requests": len(out),
        "tokens": n_tok,
        "iters": ITERS,
        "tok_per_sec": emitted / total if total else 0.0,
        "p50_ms": float(np.percentile(dts, 50)) * 1e3 if dts.size else 0.0,
        "p95_ms": float(np.percentile(dts, 95)) * 1e3 if dts.size else 0.0,
    }
    if weight_bits is not None:
        row["weight_bits"] = weight_bits
    return row


def _memory_record(cfg) -> dict:
    flat = cfg.n_kv_heads * cfg.hd
    rows_per_slot = 2 * cfg.n_layers * MAX_SEQ          # k and v, every layer
    per_slot = {
        "fp32": rows_per_slot * kv_cache_bytes_per_row(flat, False),
        "int8": rows_per_slot * kv_cache_bytes_per_row(flat, True),
    }
    resident = {k: HBM_BUDGET // v for k, v in per_slot.items()}
    return {
        "kv_rows_per_slot": rows_per_slot,
        "bytes_per_slot": per_slot,
        "hbm_budget_bytes": HBM_BUDGET,
        "resident_slots_at_budget": resident,
        "slot_ratio_int8_over_fp32": resident["int8"] / resident["fp32"],
    }


def _weight_memory_record(cfg, params) -> dict:
    """Resident bytes of the dense GEMM kernels: fp32 vs bit-packed."""
    dense_fp = 0

    def walk(node):
        nonlocal dense_fp
        if isinstance(node, dict):
            for k, v in node.items():
                if k == "w" and getattr(v, "ndim", 0) >= 2:
                    dense_fp += int(v.nbytes)
                else:
                    walk(v)

    walk(params)
    packed = {}
    for bits in (8, 4, 2):
        pb = sum(int(leaf.nbytes) for leaf in jax.tree.leaves(
            pack_dense_weights(params, bits),
            is_leaf=lambda x: isinstance(x, PackedTensor))
            if isinstance(leaf, PackedTensor))
        packed[str(bits)] = {"bytes": pb,
                             "reduction_vs_fp32": dense_fp / pb}
    return {"dense_fp32_bytes": dense_fp, "packed": packed}


def _parity_record(cfg, params) -> dict:
    """Stepwise decode vs prefill logits, per backend, fp and int8-KV."""
    model = build_model(cfg)
    B, T = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, T), 0,
                              cfg.vocab_size)
    out = {}
    for backend in ("simulate", "native", "pallas"):
        pol = QuantPolicy.qat(backend=backend)
        exact = QuantPolicy(enabled=False, backend=backend)
        row = {}
        for name, policy in (("exact", exact), ("qat", pol)):
            lg_pre, _ = model.prefill(params, {"tokens": toks}, policy,
                                      max_seq=T + 2)
            scale = float(jnp.max(jnp.abs(lg_pre))) + 1e-9
            for kv, init in (("fp32", model.init_cache),
                             ("int8", model.init_cache_quant)):
                if kv == "int8":
                    cache = init(cfg, B, T + 2)
                else:
                    cache = init(cfg, B, T + 2)
                    cache["index"] = jnp.zeros((B,), jnp.int32)
                pos = jnp.zeros((B,), jnp.int32)
                lg = None
                for t in range(T):
                    lg, cache = model.decode(
                        params, cache, {"tokens": toks[:, t:t + 1]}, policy,
                        positions=pos)
                    pos = pos + 1
                # exact policy never touches the int8 cache quantizers'
                # forward GEMMs, but the cache codec still rounds — only
                # the fp cache must match to float tolerance
                row[f"{name}_{kv}_max_abs"] = float(
                    jnp.max(jnp.abs(lg - lg_pre)))
                row[f"{name}_{kv}_rel"] = float(
                    jnp.max(jnp.abs(lg - lg_pre))) / scale
        row["pass"] = (row["exact_fp32_max_abs"] < 1e-4
                       and row["qat_fp32_rel"] < 0.05
                       and row["qat_int8_rel"] < 0.10)
        out[backend] = row
    return out


def run():
    cfg = get_config("statquant-tx", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    record = {"arch": cfg.name, "max_seq": MAX_SEQ, "max_new": MAX_NEW,
              "variants": [], "memory": _memory_record(cfg),
              "weight_memory": _weight_memory_record(cfg, params),
              "parity": _parity_record(cfg, params)}
    rows = []
    for slots in SLOT_COUNTS:
        for kv_quant in (False, True):
            v = _run_variant(cfg, params, kv_quant, slots)
            record["variants"].append(v)
            rows.append((f"serve/{v['kv']}_slots={slots}",
                         v["p50_ms"] * 1e3, v["tok_per_sec"]))
    # packed-weight variant: int8 KV + 4-bit packed dense kernels
    v = _run_variant(cfg, params, True, 4, weight_bits=4)
    record["variants"].append(v)
    rows.append(("serve/int8_slots=4_w4", v["p50_ms"] * 1e3,
                 v["tok_per_sec"]))

    ratio = record["memory"]["slot_ratio_int8_over_fp32"]
    w4 = record["weight_memory"]["packed"]["4"]["reduction_vs_fp32"]
    record["acceptance"] = {
        "slot_ratio_ge_2x": ratio >= 2.0,
        "packed_w4_reduction_ge_4x": w4 >= 4.0,
        "parity_all_backends": all(v["pass"]
                                   for v in record["parity"].values()),
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=1)
    return rows
