"""Paper Table 1 / Fig. 3(b,c) proxy: convergence vs (quantizer x bitwidth).

Trains the paper's own transformer (statquant-tx, reduced) on learnable
synthetic data under Exact / QAT / FQT x {PTQ, PSQ, BHQ} x {8, 5, 4, 3}
bits and reports final training loss.  The paper's qualitative claims to
reproduce: 8-bit FQT ~ QAT for all quantizers; as bits drop, PTQ degrades
first and BHQ last.

``wag_matrix`` is the DoReFa-style ultra-low-bit sweep: (W, A, G) triples
down to binary weights (the registry's ``binary``/``ternary``/``int4w``
packed-weight quantizers) with the per-row SR gradient quantizer.  Each
row's ``us_per_call`` slot carries the *theory overlay* — the predicted
relative SR gradient-quantization variance at G bits on a standard-normal
probe (core/theory.py ``quantizer_variance``; ~bin^2/12 scaling, so every
bit dropped quadruples it) — next to the measured final loss, which is the
paper's Theorem-2 story: convergence degrades with the variance the
gradient quantizer injects, while W can drop much further (weight rounding
is deterministic, biasing the forward only).
"""

from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import QuantPolicy, RoleOverride
from repro.core.theory import quantizer_variance
from repro.engine import Engine

STEPS = int(os.environ.get("BENCH_CONV_STEPS", "60"))

# W bits -> forward-weight role spec (1/2-bit are the sign-style
# quantizers; 4-bit is the packable deterministic PTQ)
_WSPEC = {8: "ptq_det:8", 4: "int4w:4", 2: "ternary:2", 1: "binary:1"}

# DoReFa-style (W, A, G) triples; G=0 means fp32 gradients (QAT)
WAG_TRIPLES = ((8, 8, 8), (4, 8, 8), (2, 8, 8), (1, 8, 8),
               (4, 4, 8), (2, 2, 8), (1, 2, 8),
               (1, 8, 4), (1, 8, 0))


def _run(policy, steps=STEPS, seed=0):
    cfg = get_config("statquant-tx", smoke=True)
    hist = Engine(cfg, policy, steps=steps, batch_size=8, seq_len=32,
                  lr=4e-3, seed=seed, log_fn=None).run()
    return hist[-1][1]


def run():
    rows = []
    rows.append(("table1_loss/exact", 0.0, _run(QuantPolicy.exact())))
    rows.append(("table1_loss/qat", 0.0, _run(QuantPolicy.qat())))
    for quant in ("ptq", "psq", "bhq"):
        for bits in (8, 5, 4, 3):
            loss = _run(QuantPolicy.fqt(quant, bits, bhq_block=32))
            rows.append((f"table1_loss/{quant}/{bits}b", 0.0, loss))
    return rows


def _wag_policy(w: int, a: int, g: int) -> QuantPolicy:
    base = (QuantPolicy.fqt("psq", g, act_bits=a) if g
            else QuantPolicy.qat(act_bits=a))
    ov = (("", RoleOverride.of({"fwd_act": f"ptq_det:{a}",
                                "fwd_weight": _WSPEC[w]})),)
    return dataclasses.replace(base, overrides=ov)


def _grad_rel_variance(g_bits: int, key=0, shape=(256, 256)) -> float:
    """Theory overlay: relative SR variance at ``g_bits`` on a N(0,1) probe
    (per-row PSQ, the wag gradient quantizer).  0 for fp gradients."""
    if not g_bits:
        return 0.0
    probe = jax.random.normal(jax.random.PRNGKey(key), shape)
    return float(quantizer_variance(probe, "psq", g_bits)
                 / jnp.sum(probe * probe))


def wag_matrix():
    """The ultra-low-bit (W, A, G) sweep — see module docstring."""
    rows = []
    for w, a, g in WAG_TRIPLES:
        loss = _run(_wag_policy(w, a, g))
        rows.append((f"wag_loss/w{w}a{a}g{g or 'fp'}",
                     _grad_rel_variance(g), loss))
    return rows
