"""Paper Table 1 / Fig. 3(b,c) proxy: convergence vs (quantizer x bitwidth).

Trains the paper's own transformer (statquant-tx, reduced) on learnable
synthetic data under Exact / QAT / FQT x {PTQ, PSQ, BHQ} x {8, 5, 4, 3}
bits and reports final training loss.  The paper's qualitative claims to
reproduce: 8-bit FQT ~ QAT for all quantizers; as bits drop, PTQ degrades
first and BHQ last.
"""

from __future__ import annotations

import os

from repro.configs import get_config
from repro.core import QuantPolicy
from repro.engine import Engine

STEPS = int(os.environ.get("BENCH_CONV_STEPS", "60"))


def _run(policy, steps=STEPS, seed=0):
    cfg = get_config("statquant-tx", smoke=True)
    hist = Engine(cfg, policy, steps=steps, batch_size=8, seq_len=32,
                  lr=4e-3, seed=seed, log_fn=None).run()
    return hist[-1][1]


def run():
    rows = []
    rows.append(("table1_loss/exact", 0.0, _run(QuantPolicy.exact())))
    rows.append(("table1_loss/qat", 0.0, _run(QuantPolicy.qat())))
    for quant in ("ptq", "psq", "bhq"):
        for bits in (8, 5, 4, 3):
            loss = _run(QuantPolicy.fqt(quant, bits, bhq_block=32))
            rows.append((f"table1_loss/{quant}/{bits}b", 0.0, loss))
    return rows
