"""Paper Fig. 4: quantization-bin-size distributions per quantizer.

The paper visualizes (i) quantized-code histograms (tail-bin utilization)
and (ii) the distribution of bin sizes.  We report the summary statistics
that the figure demonstrates:

  * max / median bin size (PTQ's single huge bin vs PSQ's per-row bins vs
    BHQ eliminating the large bins)
  * tail-bin utilization: fraction of codes outside the modal bin
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (num_bins, quantize_bhq_stoch, quantize_psq_stoch,
                        quantize_ptq_stoch, row_dynamic_range)

from .common import grad_snapshot


def _stats(codes, bin_sizes):
    codes = codes.reshape(-1)
    counts = jnp.bincount(codes, length=256)
    modal = jnp.max(counts)
    util = 1.0 - modal / codes.size
    return {
        "max_bin": float(jnp.max(bin_sizes)),
        "med_bin": float(jnp.median(bin_sizes)),
        "tail_util": float(util),
    }


def run(bits: int = 8):
    rows = []
    (gname, g), *_ = grad_snapshot()
    B = num_bins(bits)
    key = jax.random.PRNGKey(0)

    qt = quantize_ptq_stoch(g, key, bits)
    s = _stats(qt.codes, jnp.full((1,), 1.0 / qt.scale))
    for k, v in s.items():
        rows.append((f"fig4_bins/ptq/{k}", 0.0, v))

    qt = quantize_psq_stoch(g, key, bits)
    s = _stats(qt.codes, 1.0 / qt.scale.reshape(-1))
    for k, v in s.items():
        rows.append((f"fig4_bins/psq/{k}", 0.0, v))

    qt = quantize_bhq_stoch(g, key, bits, block_rows=128)
    s = _stats(qt.codes, 1.0 / qt.row_scale.reshape(-1))
    for k, v in s.items():
        rows.append((f"fig4_bins/bhq/{k}", 0.0, v))

    # row dynamic-range sparsity (the left panel of Fig. 4): ratio of the
    # 99th-percentile row range to the median row range
    rr = row_dynamic_range(g)
    rows.append(("fig4_row_range/p99_over_median", 0.0,
                 float(jnp.percentile(rr, 99) /
                       jnp.maximum(jnp.median(rr), 1e-12))))
    return rows
