"""Benchmark harness — one suite per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Suites:

  fig3_*     quantizer variance vs bitwidth            (paper Fig. 3a / 5a)
  fig4_*     quantization bin-size distributions       (paper Fig. 4)
  table1_*   convergence vs (quantizer x bits)         (paper Table 1 proxy)
  wag_*      ultra-low-bit (W, A, G) sweep w/ theory overlay (DoReFa-style)
  overhead_* quantization overhead vs GEMM             (paper Sec. 4.3)
  kernel_*   kernel timings + TPU-target properties
  train_*    engine step throughput (donation x accumulation)
  serve_*    continuous-batching serving (fp32 vs int8 KV cache)

Select suites with ``python -m benchmarks.run fig3 table1 ...`` (default all).
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (bench_bins, bench_convergence, bench_kernels,
                   bench_overhead, bench_serve, bench_train_step,
                   bench_variance)

    suites = {
        "fig3": bench_variance.run,
        "fig4": bench_bins.run,
        "table1": bench_convergence.run,
        "wag": bench_convergence.wag_matrix,
        "overhead": bench_overhead.run,
        "kernel": bench_kernels.run,
        "train": bench_train_step.run,
        "serve": bench_serve.run,
    }
    selected = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    failures = []
    for name in selected:
        if name not in suites:
            print(f"# unknown suite {name}", file=sys.stderr)
            continue
        try:
            for row, us, derived in suites[name]():
                print(f"{row},{us:.2f},{derived:.6g}", flush=True)
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        raise SystemExit(f"benchmark suites failed: {failures}")


if __name__ == "__main__":
    main()
