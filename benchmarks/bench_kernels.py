"""Kernel micro-benchmarks — forward AND backward GEMM paths.

On this CPU container the Pallas kernels execute in interpret mode (Python
emulation — wall time is meaningless for TPU), so the timed entries are the
XLA-compiled backend paths: the ``native`` unfused int8 GEMM + epilogue and
the fused quantize->GEMM->epilogue twins of kernels/fused_fqt.py (same
algebra as the Pallas megakernels; on a TPU host the same rows time the
Pallas kernels themselves).  The Pallas kernels are validated for
correctness in tests/test_kernels.py + tests/test_fused.py and
characterized here by their static VMEM/arithmetic-intensity properties.

Row semantics (``derived`` is the q8/f32 time ratio where it is a ratio):

  f32_gemm[_bwd]        the fp32 baselines (fwd GEMM; dW+dX GEMM pair)
  native_q8_fqt_fwd     e2e unfused ``fqt_matmul`` — quantize to HBM codes,
                        then int8-GEMM (the pre-megakernel path)
  fused_q8_fqt_fwd      the fused forward kernel: deterministic quantize of
                        X inside the GEMM + affine epilogue.  Kernel *inputs*
                        (per-tensor scale/zero, W codes, the epilogue u
                        vector) are prepared outside the timed region — they
                        are operands, exactly as the Pallas kernel prefetches
                        them on TPU.
  fused_q8_fqt_fwd_e2e  the whole fused ``fqt_matmul`` including range
                        finding and weight quantization (the honest
                        end-to-end number; bandwidth-bound prep dominates
                        the gap to ``fused_q8_fqt_fwd`` on this 1-core host)
  q8_gemm               kernel-only int8 GEMM + affine epilogue (codes and
                        coefficient vectors are prepped operands)
  packed_q4_gemm        same contraction with the weight bit-packed in HBM
  packed_q2_gemm        (kernels/pack.py + q4_matmul.py): 2 resp. 4 codes
                        per byte are unpacked per tile inside the K sweep.
                        ``bytes_moved`` on these three rows is the per-call
                        HBM traffic the packing shrinks; on this CPU host
                        the XLA twins time the unpack as extra ALU work, on
                        TPU the Pallas kernels trade it for bandwidth
  native_q8_fqt_bwd     e2e unfused backward (both Eq. 6 GEMMs)
  fused_q8_fqt_bwd      fused dW (TN megakernel: rematerialized-X det
                        quantize + SR quantize of dY in the K sweep) + fused
                        dX (SR quantize of dY + W-transposed GEMM), with
                        ranges and SR uniforms prefetched as operands
  sr_bits               one ``random.bits`` draw of dY's shape — the PRNG
                        cost the bwd kernels prefetch (slow threefry on CPU;
                        on TPU it overlaps with the MXU pipeline)

Timing is min-of-iters (noise-robust on shared hosts).  The whole table is
dumped to ``BENCH_kernels.json`` — fused/q8 GEMM rows carry the tile shapes
the autotuner would hand the Pallas kernels — and the committed copy is the
baseline for the CI regression gate (``--gate``).  ``--tune`` sweeps the
Pallas tile space and persists winners (see kernels/autotune.py).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import (QuantPolicy, fqt_matmul, quantize_psq_stoch,
                        quantize_ptq_det, quantize_ptq_stoch, qt_gemm_nt,
                        qt_gemm_tn)
from repro.core.backend import (_ptq_range, affine_factors, apply_epilogue,
                                epilogue_coeffs)
import repro.kernels.autotune  # noqa: F401 — registers the submodule
from repro.analysis.planner import gemm_bytes_moved
from repro.kernels import (fused_qboth_tn_matmul, fused_qboth_tn_matmul_xla,
                           fused_qlhs_matmul, fused_qlhs_matmul_xla,
                           lookup_tiles, pack_qtensor, packed_matmul,
                           packed_matmul_xla, q8_tile_vmem_bytes)
from repro.kernels.q8_matmul import q8_matmul

# the package re-exports the autotune *function*; grab the module itself
autotune_mod = sys.modules["repro.kernels.autotune"]

BENCH_JSON = os.environ.get("BENCH_KERNELS_JSON", "BENCH_kernels.json")

SHAPES = [(512, 1024, 1024), (1024, 4096, 1024), (4096, 1024, 4096)]

# rows the CI gate checks (derived = q8/f32 ratio, small bench shape)
GATE_ROWS = ("native_q8_fqt_fwd", "native_q8_fqt_bwd",
             "fused_q8_fqt_fwd", "fused_q8_fqt_bwd",
             "q8_gemm", "packed_q4_gemm")
GATE_FACTOR = 1.10


def min_time_us(fn, *args, iters: int = 10, warmup: int = 3) -> float:
    """Min-of-iters wall time — robust to other tenants on shared hosts
    (the mean-based ``common.time_us`` stays for the throughput suites)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _bwd_gemms(xq, wq, g, key, quant: str, backend: str):
    """The two backward GEMMs exactly as the unfused _fqt_bwd runs them.

    xq/wq are the forward-pass residuals (already quantized) — the timed
    region covers only what the backward actually executes: the gradient
    quantizers plus the two GEMMs.
    """
    k1, k2 = jax.random.split(key)
    gq1 = quantize_ptq_stoch(g, k1, 8)
    gq2 = (quantize_ptq_stoch(g, k2, 8) if quant == "ptq"
           else quantize_psq_stoch(g, k2, 8))
    dw = qt_gemm_tn(xq, gq1, backend=backend)
    dx = qt_gemm_nt(gq2, wq, backend=backend)
    return dw, dx


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def bench_shape(m: int, k: int, n: int, key, iters: int = 10):
    """All timed rows for one (M, K, N); returns [(name, us, derived, extra)]."""
    sfx = f"{m}x{k}x{n}"
    entries = []
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 0.1
    g = jax.random.normal(jax.random.fold_in(key, 2), (m, n))

    t_f32 = min_time_us(jax.jit(lambda a, b: a @ b), x, w, iters=iters)
    entries.append((f"kernel/f32_gemm/{sfx}", t_f32, 0.0,
                    {"bytes_moved": int(gemm_bytes_moved(m, k, n, 32, 32))}))

    pol = QuantPolicy.fqt("psq", 8, backend="native")
    t_q8 = min_time_us(jax.jit(
        lambda a, b: fqt_matmul(a, b, key, pol)), x, w, iters=max(3, iters // 2))
    entries.append((f"kernel/native_q8_fqt_fwd/{sfx}", t_q8,
                    t_q8 / t_f32, None))

    # ---- fused forward kernel (operands prepped; quantize inside) ----
    wq = jax.jit(quantize_ptq_det, static_argnums=1)(w, 8)
    w8i = wq.int8_codes.reshape(k, n)
    ab, bb = affine_factors(wq.scale, wq.zero, wq.bits)
    colsum = jnp.sum(w8i.astype(jnp.int32), axis=0).astype(jnp.float32)
    u = ab * colsum + float(k) * bb
    zx, sx = _ptq_range(x, 8)
    sa = jnp.broadcast_to(sx, (m, 1))
    za = jnp.broadcast_to(zx, (m, 1))
    # Pallas megakernel on TPU; its XLA twin elsewhere.  The CPU twin's
    # platform-adaptive GEMM consumes f32 code values, so the one-per-step
    # W-code conversion is prep, not kernel time.
    if _on_tpu():
        fwd_fn = jax.jit(lambda xf, ss, zz, y, uu: fused_qlhs_matmul(
            xf, ss, zz, None, y, ab, bb, uu, bits=8, tune_key="fused_fwd"))
        w8op = w8i
    else:
        fwd_fn = jax.jit(lambda xf, ss, zz, y, uu: fused_qlhs_matmul_xla(
            xf, ss, zz, None, y, ab, bb, uu, bits=8))
        w8op = w8i.astype(jnp.float32)
    sa, za, u, w8op = jax.block_until_ready((sa, za, u, w8op))
    t_fused = min_time_us(fwd_fn, x, sa, za, w8op, u, iters=iters)
    tiles_fwd = lookup_tiles("fused_fwd", (m, k, n))
    entries.append((f"kernel/fused_q8_fqt_fwd/{sfx}", t_fused,
                    t_fused / t_f32, {"tiles": list(tiles_fwd)}))

    # ---- fused forward end-to-end (range finding + W quantize included) --
    pol_f = QuantPolicy.fqt("psq", 8, backend="native", fused=True)
    t_fused_e2e = min_time_us(jax.jit(
        lambda a, b: fqt_matmul(a, b, key, pol_f)), x, w, iters=iters)
    entries.append((f"kernel/fused_q8_fqt_fwd_e2e/{sfx}", t_fused_e2e,
                    t_fused_e2e / t_f32, None))

    # ---- packed sub-byte GEMMs (weights stay bit-packed in HBM) ----
    # packed-vs-int8-vs-f32 on equal footing: codes and the epilogue
    # coefficient vectors are prepped operands for every row, so the timed
    # region is GEMM + unpack + epilogue only.  ``bytes_moved`` is the HBM
    # traffic per call — the quantity the packed layout shrinks (4-bit
    # weights stream at 2 codes/byte, 2-bit at 4).
    aq = jax.jit(quantize_ptq_det, static_argnums=1)(x, 8)
    a8 = aq.int8_codes.reshape(m, k)
    alpha_a, beta_a = affine_factors(aq.scale, aq.zero, aq.bits)
    coeffs8 = epilogue_coeffs(a8, alpha_a, beta_a, w8i, ab, bb)
    if _on_tpu():
        q8_fn = jax.jit(lambda a, b, *c: q8_matmul(a, b, *c))
    else:
        q8_fn = jax.jit(lambda a, b, *c: apply_epilogue(
            jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.int32
                                ).astype(jnp.float32), *c))
    a8, coeffs8 = jax.block_until_ready((a8, coeffs8))
    t_q8g = min_time_us(q8_fn, a8, w8i, *coeffs8, iters=iters)
    by_q8 = gemm_bytes_moved(m, k, n, 8, 8)
    entries.append((f"kernel/q8_gemm/{sfx}", t_q8g, t_q8g / t_f32,
                    {"bytes_moved": int(by_q8)}))
    for wbits in (4, 2):
        pt = pack_qtensor(jax.jit(quantize_ptq_det, static_argnums=1)(
            w, wbits))
        abp, bbp = affine_factors(pt.scale, pt.zero, pt.bits)
        coeffs_p = epilogue_coeffs(a8, alpha_a, beta_a,
                                   pt.int8_codes.reshape(k, n), abp, bbp)
        packed2d = pt.packed.reshape(-1, n)
        if _on_tpu():
            pfn = (lambda a, p, *c, wb=wbits:
                   packed_matmul(a, p, *c, wbits=wb, kdim=k))
        else:
            pfn = (lambda a, p, *c, wb=wbits:
                   packed_matmul_xla(a, p, *c, wbits=wb, kdim=k))
        packed2d, coeffs_p = jax.block_until_ready((packed2d, coeffs_p))
        t_p = min_time_us(pfn, a8, packed2d, *coeffs_p, iters=iters)
        by_p = gemm_bytes_moved(m, k, n, 8, wbits)
        tiles_p = lookup_tiles("q4_matmul", (m, k, n), dtype=f"int{wbits}")
        entries.append((f"kernel/packed_q{wbits}_gemm/{sfx}", t_p,
                        t_p / t_f32, {"bytes_moved": int(by_p),
                                      "tiles": list(tiles_p)}))

    # ---- backward ----
    xq = jax.jit(quantize_ptq_det, static_argnums=1)(x, 8)
    t_f32_bwd = min_time_us(jax.jit(
        lambda a, b, c: (a.T @ c, c @ b.T)), x, w, g, iters=iters)
    entries.append((f"kernel/f32_gemm_bwd/{sfx}", t_f32_bwd, 0.0, None))
    t_q8_bwd = min_time_us(jax.jit(
        lambda a, b, c: _bwd_gemms(a, b, c, key, "psq", "native")),
        xq, wq, g, iters=max(3, iters // 2))
    entries.append((f"kernel/native_q8_fqt_bwd/{sfx}", t_q8_bwd,
                    t_q8_bwd / t_f32_bwd, None))

    # fused backward kernels: dW (TN, both operands quantized in the K
    # sweep) + dX (SR LHS vs W-transposed).  Ranges, SR uniforms and the
    # epilogue vectors are kernel inputs — prepped outside the timed region
    # (the PRNG draw itself is the ``sr_bits`` row below).
    k1, k2 = jax.random.split(jax.random.fold_in(key, 0x5151))
    rb1 = jax.random.bits(k1, g.shape, jnp.uint32)
    rb2 = jax.random.bits(k2, g.shape, jnp.uint32)
    zg, sg = _ptq_range(g, 8)                       # Q_b1: per-tensor SR
    B = 255.0
    zr = jnp.min(g, axis=-1, keepdims=True)          # Q_b2 = PSQ: per-row
    sr = B / jnp.maximum(jnp.max(g, axis=-1, keepdims=True) - zr, 1e-12)
    ca = jnp.clip(jnp.round(sx * (x - zx)), 0.0, B) - 128.0
    a_vec = ((1.0 / sx) * (128.0 / sg + zg)) * jnp.sum(ca, axis=0)
    rowsum = jnp.sum(w8i.astype(jnp.int32), axis=1).astype(jnp.float32)
    u_dx = ab * rowsum + float(n) * bb
    if _on_tpu():
        bwd_fn = jax.jit(lambda xx, gg, r1, r2, av, yy, ud, ss, zz:
                         (fused_qboth_tn_matmul(xx, sx, zx, gg, sg, zg, r1,
                                                av, bits_a=8, bits_b=8,
                                                tune_key="fused_dw"),
                          fused_qlhs_matmul(gg, ss, zz, r2, yy, ab, bb, ud,
                                            bits=8, trans_b=True,
                                            tune_key="fused_dx")))
        w8bwd = w8i
    else:
        bwd_fn = jax.jit(lambda xx, gg, r1, r2, av, yy, ud, ss, zz:
                         (fused_qboth_tn_matmul_xla(xx, sx, zx, gg, sg, zg,
                                                    r1, av, bits_a=8,
                                                    bits_b=8),
                          fused_qlhs_matmul_xla(gg, ss, zz, r2, yy, ab, bb,
                                                ud, bits=8, trans_b=True)))
        w8bwd = w8i.astype(jnp.float32)
    rb1, rb2, a_vec, u_dx, sr, zr, w8bwd = jax.block_until_ready(
        (rb1, rb2, a_vec, u_dx, sr, zr, w8bwd))
    t_fused_bwd = min_time_us(bwd_fn, x, g, rb1, rb2, a_vec, w8bwd, u_dx,
                              sr, zr, iters=iters)
    tiles_bwd = {"dw": list(lookup_tiles("fused_dw", (k, m, n))),
                 "dx": list(lookup_tiles("fused_dx", (m, n, k)))}
    entries.append((f"kernel/fused_q8_fqt_bwd/{sfx}", t_fused_bwd,
                    t_fused_bwd / t_f32_bwd, {"tiles": tiles_bwd}))

    t_bits = min_time_us(jax.jit(
        lambda kk: jax.random.bits(kk, (m, n), jnp.uint32)), key,
        iters=max(3, iters // 2))
    entries.append((f"kernel/sr_bits/{sfx}", t_bits,
                    t_bits / t_f32_bwd, None))

    # arithmetic intensity of the int8 GEMM tile (TPU target property):
    # flops = 2 m k n; bytes = m k + k n (int8) + 4 m n (f32 out)
    fl = 2.0 * m * k * n
    by = m * k + k * n + 4.0 * m * n
    entries.append((f"kernel/q8_arith_intensity/{sfx}", 0.0, fl / by, None))
    # backward: dW = xqᵀ(k x m) @ gq1(m x n) and dX = gq2(m x n) @ wqᵀ(n x k)
    # int8 reads: xq (mk) + wq (kn) + the two quantized grads (2mn);
    # f32 writes: dW (kn) + dX (mk)
    fl_b = 2.0 * k * m * n + 2.0 * m * n * k
    by_b = (m * k + k * n + 2.0 * m * n) + 4.0 * (k * n + m * k)
    entries.append((f"kernel/q8_bwd_arith_intensity/{sfx}", 0.0,
                    fl_b / by_b, None))
    return entries


def _vmem_entries():
    bm, bn, bk = autotune_mod.DEFAULT_TILES
    return [
        ("kernel/q8_tile_vmem_bytes", 0.0,
         float(q8_tile_vmem_bytes(bm, bn, bk)), None),
        ("kernel/fused_tile_vmem_bytes", 0.0,
         float(q8_tile_vmem_bytes(bm, bn, bk, fused=True)), None),
    ]


def run(shapes=None, out: str = None, iters: int = 10):
    entries = []
    key = jax.random.PRNGKey(0)
    for (m, k, n) in (shapes or SHAPES):
        entries.extend(bench_shape(m, k, n, key, iters=iters))
    entries.extend(_vmem_entries())
    payload = {}
    for name, us, derived, extra in entries:
        payload[name] = {"us_per_call": us, "derived": derived}
        if extra:
            payload[name].update(extra)
    with open(out or BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=1)
    return [(name, us, derived) for name, us, derived, _ in entries]


# ---------------------------------------------------------------------------
# --tune: sweep the Pallas tile space and persist winners
# ---------------------------------------------------------------------------

def tune(shapes=None, iters: int = 3, log=print):
    """Autotune the Pallas kernels' (bm, bn, bk) for the given shapes.

    Tile choice only changes performance where Pallas compiles natively
    (TPU).  Elsewhere the kernels run in interpret mode, so the sweep is
    restricted to one tiny shape — it exercises the full autotune->persist->
    lookup plumbing without hours of Python emulation."""
    interpret = not _on_tpu()
    if interpret:
        log("# non-TPU host: Pallas runs in interpret mode — sweeping one "
            "tiny shape to exercise the plumbing (tile timings are not "
            "meaningful for the TPU target)")
        shapes = [(64, 128, 128)]
        candidates = [(32, 128, 128), (64, 128, 128)]
    else:
        shapes = shapes or SHAPES
        candidates = None
    key = jax.random.PRNGKey(0)
    winners = {}
    for (m, k, n) in shapes:
        x8 = jax.random.randint(key, (m, k), -128, 128, dtype=jnp.int8)
        y8 = jax.random.randint(key, (k, n), -128, 128, dtype=jnp.int8)
        rs = jnp.ones((m,), jnp.float32)
        cs = jnp.ones((n,), jnp.float32)
        zv_m = jnp.zeros((m,), jnp.float32)
        zv_n = jnp.zeros((n,), jnp.float32)

        def q8_run(tiles, *, x8=x8, y8=y8, rs=rs, cs=cs, zv_m=zv_m,
                   zv_n=zv_n):
            bm, bn, bk = tiles
            return min_time_us(
                lambda: q8_matmul(x8, y8, rs, cs, zv_m, zv_n, zv_m, zv_n,
                                  bm=bm, bn=bn, bk=bk, interpret=interpret),
                iters=iters, warmup=1)

        log(f"# tuning q8_matmul {m}x{k}x{n}")
        winners[f"q8_matmul/{m}x{k}x{n}"] = autotune_mod.autotune(
            "q8_matmul", (m, k, n), q8_run, candidates=candidates, log=log)

        xf = jax.random.normal(key, (m, k))
        sa = jnp.full((m, 1), 100.0, jnp.float32)
        za = jnp.full((m, 1), -1.0, jnp.float32)
        u = jnp.zeros((n,), jnp.float32)

        def fused_run(tiles, *, xf=xf, sa=sa, za=za, y8=y8, u=u):
            bm, bn, bk = tiles
            return min_time_us(
                lambda: fused_qlhs_matmul(xf, sa, za, None, y8, 0.01, 0.5,
                                          u, bits=8, bm=bm, bn=bn, bk=bk,
                                          interpret=interpret),
                iters=iters, warmup=1)

        log(f"# tuning fused_fwd {m}x{k}x{n}")
        winners[f"fused_fwd/{m}x{k}x{n}"] = autotune_mod.autotune(
            "fused_fwd", (m, k, n), fused_run, candidates=candidates,
            log=log)
    log(f"# tuning cache -> {autotune_mod.cache_path()}")
    return winners


# ---------------------------------------------------------------------------
# --gate: CI regression check vs. the committed BENCH_kernels.json
# ---------------------------------------------------------------------------

def gate(baseline: str = None, factor: float = GATE_FACTOR,
         iters: int = 10, log=print) -> bool:
    """Re-run the small bench shape and fail when any gated q8/f32 ratio
    regresses more than ``factor`` vs. the committed baseline."""
    path = baseline or BENCH_JSON
    with open(path) as f:
        base = json.load(f)
    m, k, n = SHAPES[0]
    sfx = f"{m}x{k}x{n}"
    fresh = {name: (us, derived)
             for name, us, derived, _ in bench_shape(m, k, n,
                                                     jax.random.PRNGKey(0),
                                                     iters=iters)}
    ok = True
    for row in GATE_ROWS:
        name = f"kernel/{row}/{sfx}"
        committed = base.get(name, {}).get("derived")
        if committed is None:
            log(f"GATE SKIP {row}: no committed baseline in {path}")
            continue
        ratio = fresh[name][1]
        limit = committed * factor
        status = "ok" if ratio <= limit else "REGRESSED"
        if ratio > limit:
            ok = False
        log(f"GATE {status:9s} {row}: ratio {ratio:.3f} "
            f"(committed {committed:.3f}, limit {limit:.3f})")
    return ok


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="kernel micro-benchmarks (see module docstring)")
    p.add_argument("--tune", action="store_true",
                   help="autotune Pallas tile shapes and persist winners")
    p.add_argument("--gate", action="store_true",
                   help="CI regression gate vs. the committed JSON")
    p.add_argument("--baseline", default=None,
                   help="baseline JSON for --gate (default: BENCH_kernels"
                        ".json / $BENCH_KERNELS_JSON)")
    p.add_argument("--out", default=None,
                   help="output JSON path (default run mode)")
    p.add_argument("--iters", type=int, default=10)
    args = p.parse_args(argv)
    if args.tune:
        tune(iters=max(1, args.iters // 3))
        return 0
    if args.gate:
        return 0 if gate(baseline=args.baseline, iters=args.iters) else 1
    for name, us, derived in run(out=args.out, iters=args.iters):
        print(f"{name},{us:.2f},{derived:.6g}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
