"""Kernel micro-benchmarks — forward AND backward GEMM paths.

On this CPU container the Pallas kernels execute in interpret mode (Python
emulation — wall time is meaningless for TPU), so the timed entries are the
XLA-compiled backend paths (``native`` int8 GEMM + epilogue, same algebra as
the Pallas kernels, via core/backend.py); the Pallas kernels are validated
for correctness in tests/test_kernels.py + tests/test_backend.py and
characterized here by their static VMEM/arithmetic-intensity properties
(the quantities that matter on the target).

Rows cover the three GEMMs of a training step (forward Eq. 3, dW and dX of
Eq. 6) plus the fused gradient-quantize step, and the whole table is also
dumped to ``BENCH_kernels.json`` so later perf PRs have a trajectory to
beat.
"""

from __future__ import annotations

import json
import os

import jax

from repro.core import (QuantPolicy, fqt_matmul, quantize_psq_stoch,
                        quantize_ptq_det, quantize_ptq_stoch, qt_gemm_nt,
                        qt_gemm_tn)

from .common import time_us

BENCH_JSON = os.environ.get("BENCH_KERNELS_JSON", "BENCH_kernels.json")

SHAPES = [(512, 1024, 1024), (1024, 4096, 1024), (4096, 1024, 4096)]


def _bwd_gemms(xq, wq, g, key, quant: str, backend: str):
    """The two backward GEMMs exactly as _fqt_bwd runs them.

    xq/wq are the forward-pass residuals (already quantized) — the timed
    region covers only what the backward actually executes: the gradient
    quantizers plus the two GEMMs.
    """
    k1, k2 = jax.random.split(key)
    gq1 = quantize_ptq_stoch(g, k1, 8)
    gq2 = (quantize_ptq_stoch(g, k2, 8) if quant == "ptq"
           else quantize_psq_stoch(g, k2, 8))
    dw = qt_gemm_tn(xq, gq1, backend=backend)
    dx = qt_gemm_nt(gq2, wq, backend=backend)
    return dw, dx


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    for (m, k, n) in SHAPES:
        x = jax.random.normal(key, (m, k))
        w = jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 0.1
        g = jax.random.normal(jax.random.fold_in(key, 2), (m, n))

        t_f32 = time_us(jax.jit(lambda a, b: a @ b), x, w, iters=5)
        rows.append((f"kernel/f32_gemm/{m}x{k}x{n}", t_f32, 0.0))

        pol = QuantPolicy.fqt("psq", 8, backend="native")
        t_q8 = time_us(jax.jit(
            lambda a, b: fqt_matmul(a, b, key, pol)), x, w, iters=5)
        rows.append((f"kernel/native_q8_fqt_fwd/{m}x{k}x{n}", t_q8,
                     t_q8 / t_f32))

        # backward: both GEMMs of Eq. 6 through the backend seam
        # (xq/wq precomputed — in training they are forward residuals)
        xq = jax.jit(quantize_ptq_det, static_argnums=1)(x, 8)
        wq = jax.jit(quantize_ptq_det, static_argnums=1)(w, 8)
        t_f32_bwd = time_us(jax.jit(
            lambda a, b, c: (a.T @ c, c @ b.T)), x, w, g, iters=5)
        rows.append((f"kernel/f32_gemm_bwd/{m}x{k}x{n}", t_f32_bwd, 0.0))
        t_q8_bwd = time_us(jax.jit(
            lambda a, b, c: _bwd_gemms(a, b, c, key, "psq", "native")),
            xq, wq, g, iters=5)
        rows.append((f"kernel/native_q8_fqt_bwd/{m}x{k}x{n}", t_q8_bwd,
                     t_q8_bwd / t_f32_bwd))

        # arithmetic intensity of the int8 GEMM tile (TPU target property):
        # flops = 2 m k n; bytes = m k + k n (int8) + 4 m n (f32 out)
        fl = 2.0 * m * k * n
        by = m * k + k * n + 4.0 * m * n
        rows.append((f"kernel/q8_arith_intensity/{m}x{k}x{n}", 0.0, fl / by))
        # backward: dW = xqᵀ(k x m) @ gq1(m x n) and dX = gq2(m x n) @ wqᵀ(n x k)
        # int8 reads: xq (mk) + wq (kn) + the two quantized grads (2mn);
        # f32 writes: dW (kn) + dX (mk)
        fl_b = 2.0 * k * m * n + 2.0 * m * n * k
        by_b = (m * k + k * n + 2.0 * m * n) + 4.0 * (k * n + m * k)
        rows.append((f"kernel/q8_bwd_arith_intensity/{m}x{k}x{n}", 0.0,
                     fl_b / by_b))

    # per-tile VMEM budget of the shipped tiling (128x512x512)
    bm, bn, bk = 128, 512, 512
    vmem = bm * bk + bk * bn + 4 * bm * bn + 4 * (2 * bm + 3 * bn)
    rows.append(("kernel/q8_tile_vmem_bytes", 0.0, float(vmem)))

    with open(BENCH_JSON, "w") as f:
        json.dump({name: {"us_per_call": us, "derived": derived}
                   for name, us, derived in rows}, f, indent=1)
    return rows
