"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode (Python
emulation — wall time is meaningless for TPU), so the timed entries are the
XLA-compiled reference paths; the Pallas kernels are validated for
correctness in tests/test_kernels.py and characterized here by their static
VMEM/arithmetic-intensity properties (the quantities that matter on the
target).  Derived column: arithmetic intensity (flops/byte) of the int8 GEMM
at that tiling.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import QuantPolicy, fqt_matmul
from repro.kernels import ref

from .common import time_us


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    for (m, k, n) in [(512, 1024, 1024), (1024, 4096, 1024),
                      (4096, 1024, 4096)]:
        x = jax.random.normal(key, (m, k))
        w = jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 0.1

        t_f32 = time_us(jax.jit(lambda a, b: a @ b), x, w, iters=5)
        rows.append((f"kernel/f32_gemm/{m}x{k}x{n}", t_f32, 0.0))

        pol = QuantPolicy.fqt("psq", 8, mode="native")
        t_q8 = time_us(jax.jit(
            lambda a, b: fqt_matmul(a, b, key, pol)), x, w, iters=5)
        rows.append((f"kernel/native_q8_fqt_fwd/{m}x{k}x{n}", t_q8,
                     t_q8 / t_f32))

        # arithmetic intensity of the int8 GEMM tile (TPU target property):
        # flops = 2 m k n; bytes = m k + k n (int8) + 4 m n (f32 out)
        fl = 2.0 * m * k * n
        by = m * k + k * n + 4.0 * m * n
        rows.append((f"kernel/q8_arith_intensity/{m}x{k}x{n}", 0.0, fl / by))

    # per-tile VMEM budget of the shipped tiling (128x512x512)
    bm, bn, bk = 128, 512, 512
    vmem = bm * bk + bk * bn + 4 * bm * bn + 4 * (2 * bm + 3 * bn)
    rows.append(("kernel/q8_tile_vmem_bytes", 0.0, float(vmem)))
    return rows
