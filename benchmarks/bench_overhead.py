"""Paper Sec. 4.3: quantization overhead relative to the GEMM it feeds.

The paper reports (CPU, AVX): conv 480ms; range pass 11ms (PTQ) / 24ms
(PSQ, BHQ); Householder transform 21ms — overhead small vs the GEMM.  We
reproduce the same measurement on this host: time the fp32 GEMM, the
range/scale/SR passes of each quantizer, and the BHQ grouping+transform.
Derived column = overhead as a fraction of GEMM time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (quantize_bhq_stoch, quantize_psq_stoch,
                        quantize_ptq_stoch)

from .common import time_us


def run(n: int = 1024, d: int = 1024, k: int = 1024):
    rows = []
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (n, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, k)) * 0.1

    mm = jax.jit(lambda a, b: a @ b)
    t_mm = time_us(mm, g, w)
    rows.append(("overhead/gemm_f32", t_mm, 1.0))

    for name, fn in [
        ("ptq", jax.jit(lambda x, kk: quantize_ptq_stoch(x, kk, 8).dequant())),
        ("psq", jax.jit(lambda x, kk: quantize_psq_stoch(x, kk, 8).dequant())),
        ("bhq", jax.jit(lambda x, kk: quantize_bhq_stoch(
            x, kk, 8, block_rows=128).dequant())),
    ]:
        t = time_us(fn, g, key)
        rows.append((f"overhead/quantize_{name}", t, t / t_mm))

    # range pass alone (the paper's 11ms/24ms analogue)
    t_range_t = time_us(jax.jit(lambda x: (jnp.min(x), jnp.max(x))), g)
    t_range_r = time_us(jax.jit(lambda x: (jnp.min(x, 1), jnp.max(x, 1))), g)
    rows.append(("overhead/range_per_tensor", t_range_t, t_range_t / t_mm))
    rows.append(("overhead/range_per_sample", t_range_r, t_range_r / t_mm))

    # TrainState donation win: the whole-state in-place update vs the
    # double-buffered one (engine step, chained-state timing — derived
    # column is the speedup of the donated variant)
    from .bench_train_step import time_step
    t_don = time_step(True, 1) * 1e6      # positional: shares the lru_cache
    t_nodon = time_step(False, 1) * 1e6   # key with bench_train_step.run()
    rows.append(("overhead/train_step_donated", t_don, t_nodon / t_don))
    rows.append(("overhead/train_step_undonated", t_nodon, t_nodon / t_don))
    return rows
