"""Engine step throughput: steps/sec and tokens/sec, with and without
TrainState buffer donation and gradient accumulation.

Donation (``jax.jit(..., donate_argnums=(0,))`` on the whole TrainState)
lets XLA update params + optimizer moments in place instead of
double-buffering them — the win this suite measures (and the dry-run's
``alias_bytes`` accounts for at production scale).  Accumulation trades
step latency for activation memory (lax.scan over microbatches).

Timing protocol: steps are *chained* (state_{t+1} = step(state_t, batch)),
matching how a donated step actually runs — a donated input buffer cannot
be fed twice, so the usual repeat-same-args timing would be invalid.

Results are dumped to ``BENCH_train.json`` so later perf PRs have a
trajectory to compare against (same convention as ``BENCH_kernels.json``).
"""

from __future__ import annotations

import functools
import json
import os
import time

import jax

from repro.configs import get_config
from repro.core import QuantPolicy
from repro.engine import Engine

BENCH_JSON = os.environ.get("BENCH_TRAIN_JSON", "BENCH_train.json")
STEPS = int(os.environ.get("BENCH_TRAIN_STEPS", "20"))
BATCH, SEQ = 8, 32


@functools.lru_cache(maxsize=None)   # bench_overhead reuses our variants
def time_step(donate: bool, accum: int, batch: int = BATCH, seq: int = SEQ,
              steps: int = STEPS) -> float:
    """Seconds per optimizer step, steady-state (chained states)."""
    cfg = get_config("statquant-tx", smoke=True)
    pol = QuantPolicy.fqt("bhq", 5, bhq_block=32)
    eng = Engine(cfg, pol, steps=steps, batch_size=batch, seq_len=seq,
                 donate=donate, accum_steps=accum, log_fn=None)
    state = eng.init_state()
    batches = [eng.loader.get(s) for s in range(2)]
    state, _ = eng.step_fn(state, batches[0])          # compile + warmup
    jax.block_until_ready(state.params)
    t0 = time.perf_counter()
    for s in range(steps):
        state, _ = eng.step_fn(state, batches[s % 2])
    jax.block_until_ready(state.params)
    return (time.perf_counter() - t0) / steps


def run():
    rows = []
    record = {"batch": BATCH, "seq": SEQ, "steps_timed": STEPS,
              "variants": {}}
    for donate in (True, False):
        for accum in (1, 2, 4):
            dt = time_step(donate, accum)
            name = f"donate={int(donate)}_accum={accum}"
            record["variants"][name] = {
                "sec_per_step": dt,
                "steps_per_sec": 1.0 / dt,
                "tokens_per_sec": BATCH * SEQ / dt,
            }
            rows.append((f"train_step/{name}", dt * 1e6, 1.0 / dt))
    base = record["variants"]["donate=1_accum=1"]["sec_per_step"]
    undon = record["variants"]["donate=0_accum=1"]["sec_per_step"]
    record["donation_speedup"] = undon / base
    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=1)
    return rows
