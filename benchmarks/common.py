"""Shared benchmark utilities: timing + gradient-snapshot capture."""

from __future__ import annotations

import time

import jax

from repro.configs import get_config
from repro.core import QuantPolicy
from repro.data import make_batch_for
from repro.launch.train import train_loop
from repro.layers import apply_norm
from repro.layers.embeddings import lm_head
from repro.models import build_model
from repro.models.lm import (_forward_seq, _input_embed, _positions,
                             cross_entropy)


def time_us(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def grad_snapshot(arch: str = "statquant-tx", steps: int = 15,
                  batch: int = 8, seq: int = 32, seed: int = 0):
    """Train briefly, then capture activation gradients.

    Returns [(name, grad_2d)] — the tensors Q_b2 quantizes.  This mirrors the
    paper's Fig. 3/4/5 protocol: gradients of a partially trained model,
    after the sparse-outlier structure (most tokens predicted ~correctly,
    a few outliers) has emerged.
    """
    cfg = get_config(arch, smoke=True)
    pol = QuantPolicy.qat()
    params, _, _ = train_loop(cfg, pol, steps=steps, batch_size=batch,
                              seq_len=seq, log_fn=lambda *a: None, seed=seed)
    model = build_model(cfg)
    b = make_batch_for(cfg, batch, seq, step=steps + 1, seed=seed)
    key = jax.random.PRNGKey(seed)

    def head_input(p):
        h = _input_embed(p, b, cfg)
        B, T = h.shape[0], h.shape[1]
        pos = _positions(b, cfg, B, T)
        h, _, _ = _forward_seq(p, h, key, pol, cfg, pos, want_cache=False)
        return apply_norm(p["final_norm"], h, cfg.norm)

    h_out = head_input(params)
    # (a) logits gradient: softmax - onehot — the paper's Sec. 4.1 example
    logits = lm_head(params["lm_head"], h_out, key, pol)
    g_logits = jax.grad(
        lambda lg: cross_entropy(lg, b["labels"], cfg.vocab_size))(logits)
    # (b) hidden-state gradient flowing into the backbone
    g_hidden = jax.grad(
        lambda h: cross_entropy(lm_head(params["lm_head"], h, key, pol),
                                b["labels"], cfg.vocab_size))(h_out)
    return [("logits_grad", g_logits.reshape(-1, g_logits.shape[-1])),
            ("hidden_grad", g_hidden.reshape(-1, g_hidden.shape[-1]))]
