"""Role-based quantizer API: registry, per-layer resolution, legacy surface.

The acceptance contract of the policy-tree redesign:

  * ``QuantPolicy.resolve(path)`` turns global defaults + ordered regex
    overrides into one ``GemmQuantConfig`` per layer — last match wins
    field-wise, partial specs merge over what they override;
  * third-party quantizers plug in through ``register_quantizer`` without
    touching core/fqt.py;
  * a heterogeneous policy (exact lm_head + 8-bit attention + 4-bit-BHQ MLP
    agrad) is constructible purely from config and trains a step on all
    three backends;
  * the legacy surface (``exact/qat/fqt`` factories, ``mode=``,
    ``grad_quantizer=``, ``policy.mode``) keeps working.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (GemmQuantConfig, QuantPolicy, Quantizer,
                        QuantizerSpec, RoleOverride, available_quantizers,
                        fqt_matmul, get_quantizer, quantize_ptq_stoch,
                        register_quantizer)
from repro.models import build_model, model_quant_paths

BACKENDS = ("simulate", "native", "pallas")


def hetero_policy(backend="simulate", interpret=None):
    """Exact lm_head/embed + 8-bit attention + 4-bit-BHQ MLP agrad."""
    return QuantPolicy.fqt("bhq", 8, bhq_block=16, backend=backend,
                           pallas_interpret=interpret, overrides={
                               r"lm_head|embed": "exact",
                               r"layers\.attn\.": 8,
                               r"layers\.mlp\.": {"agrad": ("bhq", 4)},
                           })


# ---------------------------------------------------------------------------
# resolve(): defaults, precedence, partial-spec merging
# ---------------------------------------------------------------------------

def test_resolve_defaults_match_global_fields():
    pol = QuantPolicy.fqt("psq", 5, act_bits=7, weight_bits=6, wgrad_bits=4)
    cfg = pol.resolve("anything.at.all")
    assert cfg.fwd_act == QuantizerSpec("ptq_det", 7)
    assert cfg.fwd_weight == QuantizerSpec("ptq_det", 6)
    assert cfg.wgrad == QuantizerSpec("ptq", 4)
    assert cfg.agrad == QuantizerSpec("psq", 5)
    assert cfg.backend == pol.backend


def test_resolve_no_path_and_no_match_keep_defaults():
    pol = hetero_policy()
    assert pol.resolve() == pol._default_gemm_config()
    assert pol.resolve("unmatched.path") == pol._default_gemm_config()


def test_resolve_last_match_wins_fieldwise():
    pol = QuantPolicy.fqt("bhq", 8, overrides=(
        (r"layers\.", {"agrad": ("psq", 6)}),
        (r"layers\.mlp", {"agrad": {"bits": 3}}),   # partial: name inherited
        (r"layers\.mlp\.up", "exact"),
    ))
    assert pol.resolve("layers.attn.wq").agrad == QuantizerSpec("psq", 6)
    # second override keeps psq (empty name inherits), rewrites bits only
    assert pol.resolve("layers.mlp.down").agrad == QuantizerSpec("psq", 3)
    # later exact pin beats both earlier matches
    assert pol.resolve("layers.mlp.up").describe() == "exact"
    assert not pol.resolve("layers.mlp.up").quantize_fwd


def test_partial_spec_merges_params_and_bits_over_default():
    pol = QuantPolicy.fqt("bhq", 6, bhq_block=64, overrides={
        # same quantizer: params merge over the default's block_rows
        r"mlp": {"agrad": {"bits": 4, "g_search": "paper"}},
    })
    spec = pol.resolve("layers.mlp.up").agrad
    assert spec.name == "bhq" and spec.bits == 4
    assert spec.param("block_rows") == 64          # inherited
    assert spec.param("g_search") == "paper"       # overridden
    # different quantizer: base params do NOT leak across names
    pol2 = QuantPolicy.fqt("bhq", 6, bhq_block=64,
                           overrides={r"mlp": {"agrad": "psq"}})
    spec2 = pol2.resolve("layers.mlp.up").agrad
    assert spec2 == QuantizerSpec("psq", 6)        # bits inherited, no params


def test_bits_override_applies_to_all_quantized_roles():
    pol = QuantPolicy.fqt("bhq", 8, overrides={r"attn": 5})
    cfg = pol.resolve("layers.attn.wq")
    assert {cfg.fwd_act.bits, cfg.fwd_weight.bits,
            cfg.wgrad.bits, cfg.agrad.bits} == {5}
    # QAT: backward roles stay None under a bits override
    qat = QuantPolicy.qat(overrides={r"attn": 5})
    cfg = qat.resolve("layers.attn.wq")
    assert cfg.fwd_act.bits == 5 and cfg.wgrad is None and cfg.agrad is None


def test_explicit_role_bits_beat_blanket_bits_in_same_override():
    pol = QuantPolicy.fqt("bhq", 8, overrides={
        r"mlp": {"bits": 4, "agrad": "psq:6"}})
    cfg = pol.resolve("layers.mlp.up")
    assert cfg.agrad == QuantizerSpec("psq", 6)    # most specific wins
    assert cfg.wgrad.bits == 4                     # blanket still applies
    # blanket bits feed a role spec that doesn't pin its own bits
    pol2 = QuantPolicy.fqt("bhq", 8, overrides={
        r"mlp": {"bits": 4, "agrad": "psq"}})
    assert pol2.resolve("layers.mlp.up").agrad == QuantizerSpec("psq", 4)


def test_stochastic_quantizer_rejected_on_forward_role():
    x, w, k = (jax.random.normal(jax.random.PRNGKey(0), (8, 16)),
               jax.random.normal(jax.random.PRNGKey(1), (16, 4)),
               jax.random.PRNGKey(2))
    pol = QuantPolicy.fqt("bhq", 8, overrides={r"mlp": {"fwd": "ptq"}})
    with pytest.raises(ValueError, match="stochastic.*forward role"):
        fqt_matmul(x, w, k, pol, path="layers.mlp.up")


def test_partial_forward_exact_with_quantized_backward_rejected():
    pol = QuantPolicy.fqt("bhq", 4, overrides={r"mlp": {"fwd_act": "exact"}})
    with pytest.raises(ValueError, match="backward roles are quantized"):
        pol.resolve("layers.mlp.up")
    # directly-passed configs are validated too (no silent exact no-op)
    x, w, k = _xwk(7)
    bad = GemmQuantConfig(agrad=QuantizerSpec("psq", 4))
    with pytest.raises(ValueError, match="backward roles are quantized"):
        fqt_matmul(x, w, k, bad)
    # a later whole-layer "exact" pin still repairs an earlier partial pin
    ok = QuantPolicy.fqt("bhq", 4, overrides=(
        (r"mlp", {"fwd_act": "exact"}), (r"mlp", "exact")))
    assert ok.resolve("layers.mlp.up").describe() == "exact"
    # QAT (no backward roles): one-sided forward exact is rejected too —
    # the forward roles travel together
    qat = QuantPolicy.qat(overrides={r"mlp": {"fwd_weight": "exact"}})
    with pytest.raises(ValueError, match="travel together"):
        qat.resolve("layers.mlp.up")


def test_out_of_range_spec_bits_rejected_at_resolution():
    for bad in (16, 1, 0):
        pol = QuantPolicy.fqt("bhq", 8, overrides={r"attn": bad})
        with pytest.raises(ValueError, match=r"bits must be an int"):
            pol.resolve("layers.attn.wq")
    pol = QuantPolicy.fqt("bhq", 8, overrides={r"mlp": {"agrad": "bhq:99"}})
    with pytest.raises(ValueError, match=r"agrad=bhq:99"):
        pol.resolve("layers.mlp.up")
    x, w, k = _xwk(8)
    bad_cfg = GemmQuantConfig(fwd_act=QuantizerSpec("ptq_det", 16),
                              fwd_weight=QuantizerSpec("ptq_det", 8))
    with pytest.raises(ValueError, match=r"fwd_act=ptq_det:16"):
        fqt_matmul(x, w, k, bad_cfg)


def test_nameless_override_on_unquantized_role_rejected():
    # QAT has no backward default: a bits-only agrad override can't merge
    qat = QuantPolicy.qat(overrides={r"mlp": {"agrad": {"bits": 4}}})
    with pytest.raises(ValueError, match="no quantizer to inherit"):
        qat.resolve("layers.mlp.up")
    # naming the quantizer makes the same request valid
    qat2 = QuantPolicy.qat(overrides={r"mlp": {"fwd": "ptq_det",
                                               "wgrad": "ptq:8",
                                               "agrad": "psq:4"}})
    assert qat2.resolve("layers.mlp.up").agrad == QuantizerSpec("psq", 4)


def test_role_override_coercions_and_errors():
    ov = RoleOverride.of({"fwd": ("ptq_det", 4), "agrad": "psq:3"})
    assert ov.fwd_act == ov.fwd_weight == QuantizerSpec("ptq_det", 4)
    assert ov.agrad == QuantizerSpec("psq", 3)
    with pytest.raises(ValueError, match="unknown override keys"):
        RoleOverride.of({"agard": "psq"})           # typo'd role name
    with pytest.raises(TypeError):
        RoleOverride.of(3.5)
    with pytest.raises(ValueError, match="invalid override pattern"):
        QuantPolicy.fqt(overrides={"(": "exact"})   # bad regex fails up front


def test_spec_table_is_asserted_form():
    pol = hetero_policy()
    table = dict(pol.spec_table(model_quant_paths(
        get_config("statquant-tx", smoke=True))))
    assert table["lm_head"] == "exact"
    assert table["layers.attn.wq"] == (
        "fwd=ptq_det:8/ptq_det:8 wgrad=ptq:8 agrad=bhq:8(block_rows=16)")
    assert table["layers.mlp.fc1"] == (
        "fwd=ptq_det:8/ptq_det:8 wgrad=ptq:8 agrad=bhq:4(block_rows=16)")


# ---------------------------------------------------------------------------
# fqt_matmul under per-layer resolution / direct GemmQuantConfig
# ---------------------------------------------------------------------------

def _xwk(seed=0):
    kx, kw, kk = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(kx, (16, 24)),
            jax.random.normal(kw, (24, 8)) * 0.3, kk)


def test_exact_pinned_path_is_plain_matmul():
    x, w, k = _xwk()
    pol = hetero_policy()
    np.testing.assert_allclose(
        np.asarray(fqt_matmul(x, w, k, pol, path="lm_head")),
        np.asarray(x @ w), rtol=1e-6)
    gx = jax.grad(lambda a: jnp.sum(
        fqt_matmul(a, w, k, pol, path="lm_head") ** 2))(x)
    gx_ref = jax.grad(lambda a: jnp.sum((a @ w) ** 2))(x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref), rtol=1e-5)


def test_direct_gemm_quant_config_and_partial_backward():
    """Role-level API without a QuantPolicy; single-sided backward quant."""
    x, w, k = _xwk(1)
    base = GemmQuantConfig(fwd_act=QuantizerSpec("ptq_det", 8),
                           fwd_weight=QuantizerSpec("ptq_det", 8))
    qat_dx = jax.grad(lambda a: jnp.sum(fqt_matmul(a, w, k, base) ** 2))(x)
    # quantize only wgrad: dX must stay the deterministic QAT gradient
    import dataclasses
    wonly = dataclasses.replace(base, wgrad=QuantizerSpec("ptq", 8))
    dx = jax.grad(lambda a: jnp.sum(fqt_matmul(a, w, k, wonly) ** 2))(x)
    np.testing.assert_array_equal(np.asarray(dx), np.asarray(qat_dx))
    # quantize only agrad: dW must stay the QAT gradient, dX stochastic
    aonly = dataclasses.replace(base, agrad=QuantizerSpec("psq", 4))
    qat_dw = jax.grad(lambda b: jnp.sum(fqt_matmul(x, b, k, base) ** 2))(w)
    dw = jax.grad(lambda b: jnp.sum(fqt_matmul(x, b, k, aonly) ** 2))(w)
    np.testing.assert_array_equal(np.asarray(dw), np.asarray(qat_dw))
    dx2 = jax.grad(lambda a: jnp.sum(fqt_matmul(a, w, k, aonly) ** 2))(x)
    assert not np.allclose(np.asarray(dx2), np.asarray(qat_dx))


# ---------------------------------------------------------------------------
# registry: third-party quantizers plug in without touching fqt.py
# ---------------------------------------------------------------------------

class _Identity8(Quantizer):
    name = "test_id8"

    def quantize(self, x2d, key, spec, *, backend, interpret=None):
        return quantize_ptq_stoch(x2d, key, spec.bits or 8)


def test_register_and_use_custom_quantizer():
    register_quantizer("test_id8", _Identity8(), overwrite=True)
    assert "test_id8" in available_quantizers()
    x, w, k = _xwk(2)
    pol = QuantPolicy.fqt("test_id8", 6)           # as the global default
    g = jax.grad(lambda a: jnp.sum(fqt_matmul(a, w, k, pol) ** 2))(x)
    assert bool(jnp.all(jnp.isfinite(g)))
    # and per-layer, through an override
    pol2 = QuantPolicy.fqt("bhq", 8,
                           overrides={r"mlp": {"agrad": "test_id8:5"}})
    assert pol2.resolve("layers.mlp.up").agrad == QuantizerSpec("test_id8", 5)
    g2 = jax.grad(lambda a: jnp.sum(
        fqt_matmul(a, w, k, pol2, path="layers.mlp.up") ** 2))(x)
    assert bool(jnp.all(jnp.isfinite(g2)))


def test_registry_errors():
    with pytest.raises(ValueError, match="already registered"):
        register_quantizer("bhq", _Identity8())
    with pytest.raises(ValueError, match="registered:"):
        get_quantizer("definitely_not_registered")


# ---------------------------------------------------------------------------
# heterogeneous policy trains a step on all three backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_heterogeneous_policy_trains_one_step(backend):
    import dataclasses as dc
    # shrunk below even the smoke config: the pallas case runs the whole
    # backward in interpret mode, and tier-1 must stay fast (memory rule)
    cfg = dc.replace(get_config("statquant-tx", smoke=True),
                     d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                     d_ff=48, vocab_size=127, vocab_pad_to=64)
    model = build_model(cfg)
    pol = hetero_policy(backend, interpret=True if backend == "pallas" else None)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 8), jnp.int32),
             "labels": jnp.ones((2, 8), jnp.int32)}
    loss, grads = jax.value_and_grad(
        lambda p: model.loss(p, batch, jax.random.PRNGKey(1), pol)[0])(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves)
    if backend == "simulate":
        # the exact pin is live through the model: the lm_head gradient is
        # h.T @ dlogits with both operands deterministic, so it must be
        # key-independent, while a quantized layer's wgrad (stochastic Q_b1)
        # must change with the key
        grads2 = jax.grad(
            lambda p: model.loss(p, batch, jax.random.PRNGKey(2), pol)[0])(params)
        np.testing.assert_array_equal(np.asarray(grads["lm_head"]["w"]),
                                      np.asarray(grads2["lm_head"]["w"]))
        # wv (not wq: uniform test tokens leave score grads ~0)
        assert not np.allclose(np.asarray(grads["layers"]["attn"]["wv"]["w"]),
                               np.asarray(grads2["layers"]["attn"]["wv"]["w"]))


# ---------------------------------------------------------------------------
# legacy surface
# ---------------------------------------------------------------------------

def test_legacy_factories_and_mode_alias():
    x, w, k = _xwk(3)
    assert not QuantPolicy.exact().enabled
    np.testing.assert_allclose(
        np.asarray(fqt_matmul(x, w, k, QuantPolicy.exact())),
        np.asarray(x @ w), rtol=1e-6)
    pol = QuantPolicy.fqt(grad_quantizer="psq", grad_bits=5, mode="native")
    assert pol.backend == "native" and pol.mode == "native"
    assert pol.resolve("").agrad == QuantizerSpec("psq", 5)
    qat = QuantPolicy.qat(mode="simulate")
    assert not qat.quantize_bwd and qat.mode == "simulate"
    # explicit backend= wins over legacy mode=
    assert QuantPolicy.fqt(backend="pallas", mode="native").backend == "pallas"


def test_invalid_legacy_mode_raises_named_valueerror():
    with pytest.raises(ValueError, match=r"mode='gpu'"):
        QuantPolicy.fqt("bhq", 5, mode="gpu")
    with pytest.raises(ValueError, match=r"backend='tpu_magic'"):
        QuantPolicy.qat(backend="tpu_magic")
    with pytest.raises(ValueError, match="unknown backend"):
        QuantPolicy(backend="cuda")


@pytest.mark.parametrize("field", ["act_bits", "weight_bits", "wgrad_bits",
                                   "grad_bits", "dp_grad_bits"])
@pytest.mark.parametrize("bad", [1, 9, 0, "8"])
def test_all_bit_fields_validated(field, bad):
    with pytest.raises(ValueError, match=field):
        QuantPolicy(**{field: bad})


def test_bhq_block_and_grad_quantizer_validated():
    with pytest.raises(ValueError, match="bhq_block"):
        QuantPolicy(bhq_block=0)
    with pytest.raises(ValueError, match="bhq_block"):
        QuantPolicy(bhq_block=-64)
    with pytest.raises(ValueError, match="unknown quantizer"):
        QuantPolicy(grad_quantizer="nope")
