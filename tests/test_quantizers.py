"""Property-based tests (hypothesis) for the quantizer invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is an optional dev dependency (pyproject extra "test");
# without it this module must skip cleanly, not kill collection.
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (num_bins, quantize_bhq_stoch, quantize_psq_stoch,
                        quantize_ptq_det, quantize_ptq_stoch,
                        stochastic_round)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


shapes = st.tuples(st.integers(2, 24), st.integers(2, 48))
bits_st = st.integers(2, 8)
seeds = st.integers(0, 2**30)


def _rand(shape, seed, scale):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


@given(shapes, bits_st, seeds, st.floats(1e-3, 1e3))
def test_roundtrip_error_bounded_ptq(shape, bits, seed, scale):
    """|dequant(Q(x)) - x| <= bin size = R(x)/B for every entry."""
    x = _rand(shape, seed, scale)
    qt = quantize_ptq_stoch(x, jax.random.PRNGKey(seed + 1), bits)
    binsize = float(jnp.max(x) - jnp.min(x)) / num_bins(bits)
    err = float(jnp.max(jnp.abs(qt.dequant() - x)))
    assert err <= binsize * 1.001 + 1e-6


@given(shapes, bits_st, seeds, st.floats(1e-3, 1e3))
def test_roundtrip_error_bounded_psq(shape, bits, seed, scale):
    """Per-row: error bounded by that row's bin size."""
    x = _rand(shape, seed, scale)
    qt = quantize_psq_stoch(x, jax.random.PRNGKey(seed + 1), bits)
    rb = (jnp.max(x, 1) - jnp.min(x, 1)) / num_bins(bits)
    err = jnp.max(jnp.abs(qt.dequant() - x), axis=1)
    assert bool(jnp.all(err <= rb * 1.001 + 1e-6))


@given(shapes, bits_st, seeds)
def test_codes_in_range(shape, bits, seed):
    x = _rand(shape, seed, 1.0)
    for qt in (quantize_ptq_stoch(x, jax.random.PRNGKey(seed), bits),
               quantize_psq_stoch(x, jax.random.PRNGKey(seed), bits)):
        assert qt.codes.dtype == jnp.uint8
        assert int(jnp.max(qt.codes)) <= num_bins(bits)
        assert int(jnp.min(qt.codes)) >= 0


@given(shapes, seeds)
def test_deterministic_quantizer_is_deterministic(shape, seed):
    """Framework assumption (Sec. 2.1): forward quantizers are deterministic."""
    x = _rand(shape, seed, 1.0)
    a = quantize_ptq_det(x, 8).dequant()
    b = quantize_ptq_det(x, 8).dequant()
    assert bool(jnp.all(a == b))


@given(st.integers(0, 2**30))
def test_stochastic_round_unbiased_and_integer(seed):
    x = jax.random.uniform(jax.random.PRNGKey(seed), (64,)) * 10 - 5
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), 512)
    samples = jax.vmap(lambda k: stochastic_round(x, k))(keys)
    assert bool(jnp.all(samples == jnp.round(samples)))       # integers
    assert bool(jnp.all(jnp.abs(samples - x) < 1.0 + 1e-5))   # adjacent ints
    mean = jnp.mean(samples, 0)
    assert float(jnp.max(jnp.abs(mean - x))) < 0.1            # ~unbiased


@given(st.integers(8, 64), st.integers(2, 16), bits_st, seeds)
def test_bhq_roundtrip_and_structure(n, d, bits, seed):
    x = _rand((n, d), seed, 1.0).at[0].mul(50.0)
    qt = quantize_bhq_stoch(x, jax.random.PRNGKey(seed + 1), bits)
    assert qt.codes.dtype == jnp.uint8
    assert int(jnp.max(qt.codes)) <= num_bins(bits)
    deq = qt.dequant()
    assert deq.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(deq)))
    # involution check: applying the Householder transform twice = identity
    from repro.core.bhq import _apply_householder
    t = jax.random.normal(jax.random.PRNGKey(seed + 2), qt.codes.shape)
    once = _apply_householder(t, qt.seg, qt.n_vec, qt.coef)
    twice = _apply_householder(once, qt.seg, qt.n_vec, qt.coef)
    assert float(jnp.max(jnp.abs(twice - t))) < 1e-3 * (1 + float(jnp.max(jnp.abs(t))))


@given(seeds)
def test_bhq_block_partition(seed):
    """Block mode must equal concatenating per-block BHQ (independence)."""
    x = _rand((32, 8), seed, 1.0)
    key = jax.random.PRNGKey(seed + 1)
    qt = quantize_bhq_stoch(x, key, 8, block_rows=16)
    assert qt.codes.shape[0] == 2                    # two blocks
    deq = qt.dequant()
    assert deq.shape == (32, 8)
    # unbiasedness per block still holds
    keys = jax.random.split(jax.random.PRNGKey(seed + 2), 256)
    mean = jnp.mean(jax.lax.map(
        lambda k: quantize_bhq_stoch(x, k, 8, block_rows=16).dequant(), keys), 0)
    assert float(jnp.max(jnp.abs(mean - x))) < 0.05 * float(jnp.max(jnp.abs(x))) + 0.05


def test_constant_input_exact():
    """Zero dynamic range: quantizer must return the constant exactly-ish."""
    x = jnp.full((8, 8), 3.25)
    for qt in (quantize_ptq_stoch(x, jax.random.PRNGKey(0), 4),
               quantize_psq_stoch(x, jax.random.PRNGKey(0), 4)):
        assert float(jnp.max(jnp.abs(qt.dequant() - x))) < 1e-5
