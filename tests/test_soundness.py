"""Statistical-soundness verifier (analysis/soundness.py).

Three things make the pass trustworthy, and each gets pinned here:
green on the real training graphs (model + engine step, every SR round
its own stream), red with the right rule when a Theorem 1 precondition
is broken (registry/plumbing mutations + synthetic repros of the bugs
the pass has caught), and the engine's concrete PRNG fold chain really
producing the distinct keys the static pass certifies.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import (check_model, check_soundness_fn, check_step,
                            soundness_selftest)
from repro.configs import get_config
from repro.core import QuantPolicy
from repro.core.exempt import quant_scope
from repro.core.quantizers import quantize_ptq_stoch

FQT8 = QuantPolicy.fqt("bhq", 8)


# ---------------------------------------------------------------------------
# Green on the real graphs
# ---------------------------------------------------------------------------

def test_model_grad_is_sound():
    cfg = get_config("statquant-tx", smoke=True)
    rep = check_model(cfg, FQT8)
    assert rep.ok, rep.format(verbose=True)
    assert rep.n_sr_rounds > 0
    assert rep.n_det_rounds > 0          # the deterministic forward rounds
    # every SR round consumes its own PRNG stream
    assert rep.n_streams == rep.n_sr_rounds


def test_engine_step_microbatch_keys_are_sound():
    """Full engine step with accum_steps=2: the microbatch ``fold_in``
    keys inside the accumulation scan must vary with the iteration
    (SND003) and stay distinct across microbatches x sites (SND002)."""
    cfg = get_config("statquant-tx", smoke=True)
    rep = check_step(cfg, FQT8, accum_steps=2)
    assert rep.ok, rep.format(verbose=True)
    assert rep.n_sr_rounds > 0
    assert rep.n_streams == rep.n_sr_rounds


def test_whisper_self_cross_attention_keys_independent():
    """Regression: the decoder once passed one layer key to both self- and
    cross-attention, whose per-site qkey tags collide — SND002 caught it.
    The fixed graph must give every SR round a distinct stream."""
    cfg = get_config("whisper-medium", smoke=True)
    rep = check_model(cfg, FQT8)
    assert rep.ok, rep.format(verbose=True)
    assert rep.n_streams == rep.n_sr_rounds


# ---------------------------------------------------------------------------
# Red on mutations (the pass has teeth)
# ---------------------------------------------------------------------------

def test_mutation_selftest_turns_red_with_right_rules():
    cfg = get_config("statquant-tx", smoke=True)
    st = soundness_selftest(cfg, FQT8)
    assert st.ok, st.detail
    assert st.clean.ok
    expected = {"det-agrad": "SND001", "aliased-keys": "SND002",
                "double-quant": "SND004", "sr-forward": "SND005"}
    assert set(st.mutated) == set(expected)
    for mutation, rule in expected.items():
        rep = st.mutated[mutation]
        assert not rep.ok, mutation
        hits = [f for f in rep.findings if f.rule == rule]
        assert hits, (mutation, rule, rep.format(verbose=True))
        # findings must name a real layer path, not "?"
        assert any(f.path not in ("?", "") for f in hits), mutation


def test_shared_key_across_sites_is_snd002():
    """Two SR draws from the very same key alias their noise — the exact
    bug class the whisper self/cross-attention fix addressed."""
    def bad(x, key):
        with quant_scope("toy.a", "agrad", True):
            qa = quantize_ptq_stoch(x, key, 8)
        with quant_scope("toy.b", "agrad", True):
            qb = quantize_ptq_stoch(2.0 * x, key, 8)
        return qa.dequant().sum() + qb.dequant().sum()

    x = jnp.linspace(-1.0, 1.0, 64).reshape(8, 8)
    rep = check_soundness_fn(bad, (x, jax.random.PRNGKey(0)), "shared-key")
    assert not rep.ok
    hits = [f for f in rep.findings if f.rule == "SND002"]
    assert hits and any("toy." in f.path for f in hits), \
        rep.format(verbose=True)

    def good(x, key):
        with quant_scope("toy.a", "agrad", True):
            qa = quantize_ptq_stoch(x, jax.random.fold_in(key, 0), 8)
        with quant_scope("toy.b", "agrad", True):
            qb = quantize_ptq_stoch(2.0 * x, jax.random.fold_in(key, 1), 8)
        return qa.dequant().sum() + qb.dequant().sum()

    assert check_soundness_fn(good, (x, jax.random.PRNGKey(0)), "split").ok


def test_scan_invariant_key_is_snd003():
    """Regression for the chunked-head-loss bug: an SR key that is constant
    across a scan replays the same noise every chunk."""
    xs = jnp.linspace(-1.0, 1.0, 4 * 64).reshape(4, 8, 8)

    def bad(xs, key):
        def body(c, xc):
            with quant_scope("toy.head", "agrad", True):
                q = quantize_ptq_stoch(xc, key, 8)   # same key every chunk
            return c + q.dequant().sum(), ()
        out, _ = jax.lax.scan(body, jnp.float32(0.0), xs)
        return out

    rep = check_soundness_fn(bad, (xs, jax.random.PRNGKey(0)), "scan-reuse")
    assert not rep.ok
    assert any(f.rule == "SND003" for f in rep.findings), \
        rep.format(verbose=True)

    def good(xs, key):
        def body(c, ix):
            i, xc = ix
            with quant_scope("toy.head", "agrad", True):
                q = quantize_ptq_stoch(xc, jax.random.fold_in(key, i), 8)
            return c + q.dequant().sum(), ()
        out, _ = jax.lax.scan(body, jnp.float32(0.0),
                              (jnp.arange(xs.shape[0]), xs))
        return out

    assert check_soundness_fn(good, (xs, jax.random.PRNGKey(0)),
                              "scan-fold").ok


def test_det_round_on_gradient_path_is_snd001():
    from repro.core.quantizers import quantize_ptq_det

    def bad(x):
        with quant_scope("toy.w", "wgrad", True):
            q = quantize_ptq_det(x, 8)
        return q.dequant().sum()

    rep = check_soundness_fn(bad, (jnp.linspace(-1.0, 1.0, 64).reshape(8, 8),),
                             "det-wgrad")
    assert not rep.ok
    assert any(f.rule == "SND001" and f.path == "toy.w"
               for f in rep.findings), rep.format(verbose=True)


# ---------------------------------------------------------------------------
# Concrete key independence (the fold chain the engine actually runs)
# ---------------------------------------------------------------------------

def _key_fingerprint(k):
    try:
        data = jax.random.key_data(k)
    except TypeError:
        data = jnp.asarray(k)
    return tuple(int(v) for v in np.asarray(data).ravel())


def test_fold_in_grid_has_no_collisions():
    """fold_in(fold_in(seed, rid), token_idx) over an 8x64 grid: all 512
    derived keys (and their uniform-bits streams) are distinct."""
    seed = jax.random.PRNGKey(0)
    fingerprints, streams = set(), set()
    for rid in range(8):
        kr = jax.random.fold_in(seed, rid)
        for t in range(64):
            k = jax.random.fold_in(kr, t)
            fingerprints.add(_key_fingerprint(k))
            streams.add(tuple(np.asarray(
                jax.random.bits(k, (2,), jnp.uint32)).tolist()))
    assert len(fingerprints) == 8 * 64
    assert len(streams) == 8 * 64


def test_engine_fold_chain_distinct_across_microbatches_and_sites():
    """The engine's concrete derivation — split(rng) -> fold_in(microbatch)
    -> split(layers) -> qkey tag -> _fqt_bwd split — yields pairwise
    distinct keys and distinct random-bits streams over the whole
    microbatches x layers x sites x legs grid."""
    from repro.layers.common import qkey

    base = jax.random.split(jax.random.PRNGKey(7), 3)[0]
    keys = []
    for micro in range(2):
        mk = jax.random.fold_in(base, micro)
        for lk in jax.random.split(mk, 2):          # two layers
            for tag in (1, 2, 3, 4, 0x10):          # attn + mlp sites
                site = qkey(lk, tag)
                k1, k2 = jax.random.split(jax.random.fold_in(site, 0x5151))
                keys.extend([k1, k2])
    fingerprints = {_key_fingerprint(k) for k in keys}
    assert len(fingerprints) == len(keys)
    streams = {tuple(np.asarray(jax.random.bits(k, (2,), jnp.uint32)).tolist())
               for k in keys}
    assert len(streams) == len(keys)


# ---------------------------------------------------------------------------
# Report plumbing
# ---------------------------------------------------------------------------

def test_report_serializes_to_json():
    cfg = get_config("statquant-tx", smoke=True)
    rep = check_model(cfg, FQT8, grad=False)
    doc = rep.to_dict()
    assert doc["ok"] is True
    json.dumps(doc)   # must be JSON-serializable for --format json


def test_cli_soundness_json(capsys):
    from repro.analysis.__main__ import main
    rc = main(["soundness", "--config", "statquant-tx", "--format", "json"])
    doc = json.loads(capsys.readouterr().out)
    assert rc == 0
    assert doc["tool"] == "soundness" and doc["ok"]
    assert all(r["ok"] for r in doc["reports"])
