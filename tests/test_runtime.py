"""Fault-tolerance runtime: straggler detection, retry, elastic policy."""

import signal

import pytest

from repro.runtime import (ElasticController, PreemptionHandler,
                           StragglerMonitor, retry)


def test_preemption_programmatic():
    prm = PreemptionHandler()
    assert not prm.should_stop
    prm.request_stop()
    assert prm.should_stop


def test_preemption_signal():
    prm = PreemptionHandler(install=True, signals=(signal.SIGUSR1,))
    assert not prm.should_stop
    signal.raise_signal(signal.SIGUSR1)
    assert prm.should_stop


def test_straggler_detection():
    mon = StragglerMonitor(n_hosts=4, threshold=2.0, patience=3)
    normal = [1.0, 1.0, 1.0, 1.0]
    slow = [1.0, 1.0, 1.0, 5.0]
    for _ in range(2):
        mon.record(slow)
    assert mon.stragglers() == []          # not patient enough yet
    mon.record(slow)
    assert mon.stragglers() == [3]
    mon.record(normal)                     # recovery clears the streak
    assert mon.stragglers() == []


def test_straggler_needs_consistency():
    mon = StragglerMonitor(n_hosts=3, threshold=2.0, patience=2)
    mon.record([1.0, 1.0, 9.0])
    mon.record([1.0, 9.0, 1.0])            # different host each time
    assert mon.stragglers() == []


def test_retry_succeeds_after_failures():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    assert retry(flaky, max_attempts=5, sleep=lambda s: None) == "ok"
    assert calls["n"] == 3


def test_retry_exhausts():
    def always():
        raise OSError("down")
    with pytest.raises(OSError):
        retry(always, max_attempts=2, sleep=lambda s: None)


def test_retry_does_not_catch_other_exceptions():
    def typo():
        raise ValueError("bug")
    with pytest.raises(ValueError):
        retry(typo, max_attempts=3, sleep=lambda s: None)


def test_elastic_controller():
    ec = ElasticController(model_parallel=16)
    plan = ec.plan_mesh(healthy_chips=256)
    assert plan == {"data": 16, "model": 16}
    # lose a host worth of chips -> shrink DP
    plan = ec.plan_mesh(healthy_chips=240)
    assert plan == {"data": 15, "model": 16}
    assert ec.should_rescale(current_dp=16, healthy_chips=240)
    assert not ec.should_rescale(current_dp=15, healthy_chips=240)
    with pytest.raises(RuntimeError):
        ec.plan_mesh(healthy_chips=8)
