"""Validation of the paper's theoretical claims (Theorems 1 & 2, Sec. 3.3).

These tests ARE the faithful-reproduction evidence for the paper's math:
unbiasedness, the variance decomposition, the 4x-per-bit law, and the
PTQ > PSQ > BHQ variance ordering (DESIGN.md Sec. 7 experiment index).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (QuantPolicy, bhq_variance_bound, fqt_matmul,
                        psq_variance_bound, ptq_variance_bound,
                        quantize_bhq_stoch, quantize_psq_stoch,
                        quantize_ptq_stoch)
from repro.core.theory import (empirical_mean_and_variance,
                               fqt_gradient_stats, quantizer_variance,
                               theorem2_path_norms)


def sparse_outlier_grad(key, n=128, d=64, outliers=4, ratio=1e3):
    """The paper's regime (Fig. 4): most rows near zero, few outliers."""
    g = jax.random.normal(key, (n, d)) * (1.0 / ratio)
    return g.at[:outliers].mul(ratio)


QUANTS = {
    "ptq": lambda x, k, b: quantize_ptq_stoch(x, k, b).dequant(),
    "psq": lambda x, k, b: quantize_psq_stoch(x, k, b).dequant(),
    "bhq": lambda x, k, b: quantize_bhq_stoch(x, k, b).dequant(),
}


# ---------------------------------------------------------------------------
# Theorem 1: unbiasedness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quant", list(QUANTS))
def test_quantizer_unbiased(quant):
    g = sparse_outlier_grad(jax.random.PRNGKey(0))
    fn = jax.jit(lambda x, k: QUANTS[quant](x, k, 4))
    mean, var = empirical_mean_and_variance(fn, g, jax.random.PRNGKey(1),
                                            n_samples=1024)
    # per-entry SEM bound: sqrt(max per-entry var / n); allow 5 sigma
    sem = jnp.sqrt(var / g.size / 1024)
    assert float(jnp.max(jnp.abs(mean - g))) < 5 * float(jnp.sqrt(var)) / 8, \
        f"{quant} biased beyond sampling noise"
    # mean bias across entries should be tiny relative to signal
    assert float(jnp.mean(jnp.abs(mean - g))) < 0.05 * float(jnp.max(jnp.abs(g)))


def test_fqt_gradient_unbiased_end_to_end():
    """Theorem 1 through a 2-layer net: E[FQT grad | B] == QAT grad."""
    key = jax.random.PRNGKey(7)
    kx, k1, k2 = jax.random.split(key, 3)
    x = jax.random.normal(kx, (16, 8))
    w1 = jax.random.normal(k1, (8, 8)) * 0.5
    w2 = jax.random.normal(k2, (8, 4)) * 0.5
    fqt = QuantPolicy.fqt("bhq", 4, bhq_block=16)
    qat = QuantPolicy.qat()

    def loss(w1_, w2_, pol, k):
        h = jax.nn.relu(fqt_matmul(x, w1_, k, pol))
        y = fqt_matmul(h, w2_, jax.random.fold_in(k, 1), pol)
        return jnp.sum(y ** 2)

    qat_grad = jax.grad(loss, (0, 1))(w1, w2, qat, jax.random.PRNGKey(0))
    stats = fqt_gradient_stats(
        lambda k: jax.grad(loss, (0, 1))(w1, w2, fqt, k),
        jax.random.PRNGKey(3), n_samples=512)
    for m, q in zip(stats["mean"], qat_grad, strict=True):
        scale = float(jnp.max(jnp.abs(q))) + 1e-6
        sem = float(jnp.sqrt(stats["variance"] / q.size / 512))
        assert float(jnp.max(jnp.abs(m - q))) < max(6 * sem, 0.02 * scale)


# ---------------------------------------------------------------------------
# Variance: bounds, ordering, 4x-per-bit
# ---------------------------------------------------------------------------

def test_variance_bounds_hold():
    g = sparse_outlier_grad(jax.random.PRNGKey(2))
    key = jax.random.PRNGKey(3)
    for bits in (3, 5, 8):
        _, v = empirical_mean_and_variance(
            jax.jit(lambda x, k, b=bits: QUANTS["ptq"](x, k, b)), g, key, 256)
        assert float(v) <= float(ptq_variance_bound(g, bits)) * 1.05
        _, v = empirical_mean_and_variance(
            jax.jit(lambda x, k, b=bits: QUANTS["psq"](x, k, b)), g, key, 256)
        assert float(v) <= float(psq_variance_bound(g, bits)) * 1.05
        qt = quantize_bhq_stoch(g, key, bits)
        _, v = empirical_mean_and_variance(
            jax.jit(lambda x, k, b=bits: QUANTS["bhq"](x, k, b)), g, key, 256)
        assert float(v) <= float(bhq_variance_bound(qt)) * 1.2


@pytest.mark.parametrize("quant", list(QUANTS))
def test_quantizer_variance_exact_matches_empirical(quant):
    """Proposition 4: quantizer_variance (sum p(1-p) through the transform
    inverse) equals the Monte-Carlo conditional variance."""
    g = sparse_outlier_grad(jax.random.PRNGKey(12))
    kw = {"block_rows": 32} if quant == "bhq" else {}
    fn = (lambda x, k: quantize_bhq_stoch(x, k, 4, block_rows=32).dequant()) \
        if quant == "bhq" else (lambda x, k: QUANTS[quant](x, k, 4))
    _, v_emp = empirical_mean_and_variance(jax.jit(fn), g,
                                           jax.random.PRNGKey(13), 512)
    v_exact = float(quantizer_variance(g, quant, 4, **kw))
    assert v_exact > 0
    # MC variance of a variance estimate: ~sqrt(2/n) ≈ 6% rel; allow 15%
    assert abs(float(v_emp) - v_exact) < 0.15 * v_exact, (v_emp, v_exact)


def test_quantizer_variance_exported_and_validates():
    from repro.core import theory
    assert "quantizer_variance" in theory.__all__
    with pytest.raises(ValueError):
        quantizer_variance(jnp.ones((4, 4)), "nope", 4)


def test_variance_ordering_bhq_psq_ptq():
    """Fig. 3(a) / Sec. 4: Var BHQ < Var PSQ < Var PTQ on sparse grads."""
    g = sparse_outlier_grad(jax.random.PRNGKey(4))
    key = jax.random.PRNGKey(5)
    var = {}
    for name in QUANTS:
        _, v = empirical_mean_and_variance(
            jax.jit(lambda x, k, n=name: QUANTS[n](x, k, 4)), g, key, 256)
        var[name] = float(v)
    assert var["bhq"] < var["psq"] < var["ptq"]
    assert var["psq"] < 0.25 * var["ptq"], "PSQ gain should be large here"
    assert var["bhq"] < 0.5 * var["psq"], "BHQ gain should be large here"


def test_four_x_variance_per_bit():
    """Sec. 3.3: each fewer bit multiplies quantizer variance by ~4."""
    g = jax.random.normal(jax.random.PRNGKey(6), (64, 32))
    key = jax.random.PRNGKey(7)
    vs = []
    for bits in (6, 5, 4, 3):
        _, v = empirical_mean_and_variance(
            jax.jit(lambda x, k, b=bits: QUANTS["ptq"](x, k, b)), g, key, 512)
        vs.append(float(v))
    for lo, hi in zip(vs[:-1], vs[1:], strict=True):
        assert 2.5 < hi / lo < 6.0, f"4x-per-bit law violated: {vs}"


def test_bhq_single_outlier_scaling():
    """Sec. 4.2 extreme case: BHQ variance ~ O(lambda1^2/N) vs PSQ O(lambda1^2)."""
    key = jax.random.PRNGKey(8)
    g = jax.random.normal(key, (128, 32)) * 1e-4
    g = g.at[0].mul(1e4)
    kk = jax.random.PRNGKey(9)
    _, v_psq = empirical_mean_and_variance(
        jax.jit(lambda x, k: QUANTS["psq"](x, k, 4)), g, kk, 256)
    _, v_bhq = empirical_mean_and_variance(
        jax.jit(lambda x, k: QUANTS["bhq"](x, k, 4)), g, kk, 256)
    # O(1/N) spread: expect close to an order of magnitude at N=128
    assert float(v_bhq) < float(v_psq) / 5.0


# ---------------------------------------------------------------------------
# Theorem 2: variance decomposition on a tiny MLP
# ---------------------------------------------------------------------------

def test_theorem2_upper_bound():
    """Empirical Var[FQT grad | B] <= sum_l Var[Q_b(g_l)] * sum_k ||gamma||^2
    (Eq. 8), with exact Jacobian path norms on a tiny linear chain."""
    key = jax.random.PRNGKey(10)
    kx, k1, k2 = jax.random.split(key, 3)
    x = jax.random.normal(kx, (4, 3))
    params = [jax.random.normal(k1, (3, 3)) * 0.7,
              jax.random.normal(k2, (3, 2)) * 0.7]
    layer_fns = [lambda h, w: h @ w, lambda h, w: h @ w]
    weights = theorem2_path_norms(layer_fns, params, x)   # per-layer gamma sums

    pol = QuantPolicy.fqt("ptq", 3)

    def loss(ws, k):
        h = fqt_matmul(x, ws[0], k, pol)
        y = fqt_matmul(h, ws[1], jax.random.fold_in(k, 1), pol)
        return jnp.sum(y)

    stats = fqt_gradient_stats(lambda k: jax.grad(loss)(params, k),
                               jax.random.PRNGKey(11), n_samples=512)
    empirical = float(stats["variance"])

    # quantizer variances of the actual backward gradients (QAT reference)
    qat = QuantPolicy.qat()
    def qat_loss(ws, k):
        h = fqt_matmul(x, ws[0], k, qat)
        y = fqt_matmul(h, ws[1], jax.random.fold_in(k, 1), qat)
        return jnp.sum(y)
    # activation grads at each layer via jvp bookkeeping: use vjp intermediates
    # crude but sufficient: bound quantizer variance by Eq. 9 on observed grads
    h1 = x @ params[0]
    g2 = jnp.ones((4, 2))                                 # dL/dy for sum loss
    g1 = g2 @ params[1].T
    bound = (float(ptq_variance_bound(g2, 3)) * float(weights[1])
             + float(ptq_variance_bound(g1, 3)) * float(weights[0]))
    # Eq. 8 upper bound must hold with slack (plus Q_b1 contributions, which
    # the bound's derivation also covers via the wgrad path at 8 bits: small)
    assert empirical <= bound * 1.5 + 1e-3, (empirical, bound)


def test_qat_equals_exact_when_disabled():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 4))
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 4))
    y_exact = fqt_matmul(x, w, jax.random.PRNGKey(2), QuantPolicy.exact())
    assert jnp.allclose(y_exact, x @ w)
