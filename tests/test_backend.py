"""Backend parity: simulate / native / pallas(interpret) must agree.

The acceptance contract of the pluggable backend layer (core/backend.py):
for every policy the paper's recipe produces (ptq/psq/bhq gradient
quantizers, QAT), the forward GEMM and BOTH backward GEMMs run through the
selected backend and agree with the fp32 ``simulate`` path to fp32
tolerance — on tile-aligned and ragged (non-tile-multiple) shapes.  The
quantizer *codes* are bit-identical across backends (shared
``random.bits * 2^-32`` SR convention), so the only divergence is GEMM
accumulation order.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantPolicy, fqt_matmul, qt_gemm, quantize_ptq_det

ALIGNED = (32, 16, 8)      # tile multiples all the way down
RAGGED = (33, 17, 9)       # exercises pad-and-slice in every kernel wrapper


def _xwk(mkn, seed=0):
    m, k, n = mkn
    kx, kw, kk = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(kx, (m, k)),
            jax.random.normal(kw, (k, n)) * 0.3,
            kk)


def _value_and_grads(pol, x, w, key):
    y = fqt_matmul(x, w, key, pol)
    gx, gw = jax.grad(
        lambda a, b: jnp.sum(fqt_matmul(a, b, key, pol) ** 2), (0, 1))(x, w)
    return y, gx, gw


@pytest.mark.parametrize("mkn", [ALIGNED, RAGGED],
                         ids=["aligned", "ragged"])
@pytest.mark.parametrize("quant", ["ptq", "psq", "bhq"])
def test_fqt_backend_parity(quant, mkn):
    """fwd + dX + dW agree across all three backends for every Q_b2."""
    x, w, key = _xwk(mkn)
    ref = None
    for backend in ("simulate", "native", "pallas"):
        pol = QuantPolicy.fqt(quant, 5, backend=backend, bhq_block=16,
                              pallas_interpret=True)
        out = _value_and_grads(pol, x, w, key)
        if ref is None:
            ref = out
            continue
        for name, got, want in zip(("y", "dx", "dw"), out, ref, strict=True):
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-3, atol=5e-3,
                err_msg=f"{backend}/{quant}/{name} diverged from simulate")


@pytest.mark.parametrize("mkn", [ALIGNED, RAGGED],
                         ids=["aligned", "ragged"])
def test_qat_backend_parity(mkn):
    """QAT: quantized forward through each backend, fp backward — parity."""
    x, w, key = _xwk(mkn, seed=1)
    ref = None
    for backend in ("simulate", "native", "pallas"):
        pol = QuantPolicy.qat(backend=backend, pallas_interpret=True)
        out = _value_and_grads(pol, x, w, key)
        if ref is None:
            ref = out
            continue
        for name, got, want in zip(("y", "dx", "dw"), out, ref, strict=True):
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-3, atol=5e-3,
                err_msg=f"{backend}/qat/{name} diverged from simulate")


def test_pallas_codes_bit_identical_to_xla():
    """The fused SR kernels and the XLA quantizers share one uniform stream:
    same key => identical codes (the basis of backend parity)."""
    from repro.core import (quantize_psq_stoch, quantize_ptq_stoch,
                            quantize_sr_rows_qt, quantize_sr_tensor_qt)
    g = jax.random.normal(jax.random.PRNGKey(3), (33, 20)) * 2.0
    key = jax.random.PRNGKey(4)
    a = quantize_psq_stoch(g, key, 6)
    b = quantize_sr_rows_qt(g, key, 6, interpret=True)
    np.testing.assert_array_equal(np.asarray(a.codes), np.asarray(b.codes))
    c = quantize_ptq_stoch(g, key, 6)
    d = quantize_sr_tensor_qt(g, key, 6, interpret=True)
    np.testing.assert_array_equal(np.asarray(c.codes), np.asarray(d.codes))


def test_qdot_duplicate_removed():
    """The epilogue algebra has exactly one home: core/backend.py."""
    import repro.core.fqt as fqt_mod
    assert not hasattr(fqt_mod, "qdot")
    from repro.core import backend
    assert callable(backend.epilogue_coeffs)


def test_pallas_fwd_matches_exact_float():
    """8-bit pallas forward ~= exact float matmul within quantization error."""
    x, w, key = _xwk((40, 24, 12), seed=2)
    pol = QuantPolicy.qat(backend="pallas", pallas_interpret=True)
    y = np.asarray(fqt_matmul(x, w, key, pol))
    exact = np.asarray(x @ w)
    rel = np.max(np.abs(y - exact)) / np.max(np.abs(exact))
    assert rel < 0.05


def test_qt_gemm_rejects_unknown_backend():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 8))
    q = quantize_ptq_det(x, 8)
    with pytest.raises(ValueError):
        qt_gemm(q, q, backend="tpu_magic")
