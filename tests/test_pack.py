"""Bit-packed sub-byte weights: pack/unpack, packed GEMMs, registry, serve.

The load-bearing claim is *bit-exactness*: the packed GEMM (Pallas kernel
and its XLA twin) must reproduce the unpack-then-``q8_matmul`` oracle to
the last ulp on ragged shapes — same int32 accumulation, same affine
epilogue, same FMA placement.  Everything downstream (training parity,
the serve engine's load-time packing, the audit contract) rides on that.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import audit_fn
from repro.analysis.ranges import max_safe_k, signed_code_bound
from repro.configs import get_config
from repro.core import QuantPolicy, RoleOverride, fqt_matmul, quantize_ptq_det
from repro.core.backend import affine_factors, epilogue_coeffs
from repro.core.registry import (GemmQuantConfig, QuantizerSpec,
                                 get_quantizer)
from repro.kernels import (PackedTensor, codes_per_byte, max_safe_k_packed,
                           pack_codes, pack_qtensor, packed_matmul,
                           packed_matmul_xla, unpack_codes)
from repro.kernels.q8_matmul import q8_matmul
from repro.models import build_model, model_quant_paths
from repro.serve import ServeEngine
from repro.serve.engine import pack_dense_weights, weight_nbytes

PACK_BITS = (1, 2, 4, 8)
RAGGED = [(1, 1), (3, 5), (33, 65), (70, 17), (129, 2)]


# ---------------------------------------------------------------------------
# pack/unpack roundtrip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", PACK_BITS)
@pytest.mark.parametrize("shape", RAGGED)
def test_roundtrip_ragged(bits, shape):
    k, n = shape
    rng = np.random.default_rng(bits * 100 + k)
    codes = jnp.asarray(rng.integers(0, 1 << bits, size=(k, n)), jnp.uint8)
    packed = pack_codes(codes, bits)
    ppb = codes_per_byte(bits)
    assert packed.shape == (-(-k // ppb), n)
    assert packed.dtype == jnp.uint8
    out = unpack_codes(packed, bits, k)
    assert out.shape == (k, n)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))


def test_roundtrip_stacked_leading_axes():
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 16, size=(3, 2, 11, 5)), jnp.uint8)
    out = unpack_codes(pack_codes(codes, 4), 4, 11)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))


def test_rejected_widths():
    with pytest.raises(ValueError):
        codes_per_byte(3)
    with pytest.raises(ValueError):
        pack_codes(jnp.zeros((4, 4), jnp.uint8), 5)


# ---------------------------------------------------------------------------
# PackedTensor container
# ---------------------------------------------------------------------------

def test_packed_tensor_duck_types_qtensor():
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.standard_normal((19, 8)), jnp.float32)
    qt = quantize_ptq_det(w, 4)
    pt = pack_qtensor(qt)
    assert isinstance(pt, PackedTensor)
    assert pt.shape == (19, 8) and pt.kdim == 19 and pt.bits == 4
    np.testing.assert_array_equal(
        np.asarray(pt.codes), np.asarray(qt.codes.reshape(19, 8)))
    np.testing.assert_array_equal(
        np.asarray(pt.int8_codes), np.asarray(qt.int8_codes.reshape(19, 8)))
    np.testing.assert_allclose(np.asarray(pt.dequant()),
                               np.asarray(qt.dequant()), rtol=1e-6)
    # 4-bit: 2 codes/byte -> the packed container beats fp32 by ~8x
    assert pt.nbytes < w.nbytes / 4


def test_packed_tensor_scans_like_stacked_params():
    """(L, K, N) packed leaves must slice per layer under lax.scan — the
    LM's stacked-params idiom — which needs bits/kdim static but the
    leading axis dynamic."""
    L, K, N = 3, 10, 4
    rng = np.random.default_rng(1)
    codes = jnp.asarray(rng.integers(0, 16, size=(L, K, N)), jnp.uint8)
    pt = PackedTensor(packed=pack_codes(codes, 4),
                      scale=jnp.ones((L, 1, 1)), zero=jnp.zeros((L, 1, 1)),
                      bits=4, kdim=K)
    leaves, treedef = jax.tree_util.tree_flatten(pt)
    assert jax.tree_util.tree_unflatten(treedef, leaves).bits == 4

    def body(carry, layer):
        assert layer.shape == (K, N)           # static fields survived
        return carry + jnp.sum(layer.dequant()), None

    total, _ = jax.lax.scan(body, jnp.zeros(()), pt)
    ref = sum(float(jnp.sum(codes[i].astype(jnp.float32))) for i in range(L))
    np.testing.assert_allclose(float(total), ref, rtol=1e-6)


# ---------------------------------------------------------------------------
# packed GEMM: bit-exact vs the unpack-then-q8_matmul oracle
# ---------------------------------------------------------------------------

def _packed_case(m, k, n, wbits, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    aq = quantize_ptq_det(x, 8)
    pt = pack_qtensor(quantize_ptq_det(w, wbits))
    a8 = aq.int8_codes.reshape(m, k)
    alpha_a, beta_a = affine_factors(aq.scale, aq.zero, 8)
    alpha_b, beta_b = affine_factors(pt.scale, pt.zero, wbits)
    w8 = pt.int8_codes.reshape(k, n)
    coeffs = epilogue_coeffs(a8, alpha_a, beta_a, w8, alpha_b, beta_b)
    packed2d = pt.packed.reshape(-1, n)
    oracle = q8_matmul(a8, w8, *coeffs, interpret=True)
    return a8, packed2d, coeffs, oracle


@pytest.mark.parametrize("wbits", (4, 2, 1))
def test_packed_matmul_bit_exact(wbits):
    m, k, n = 33, 70, 65
    a8, packed2d, coeffs, oracle = _packed_case(m, k, n, wbits)
    pallas = packed_matmul(a8, packed2d, *coeffs, wbits=wbits, kdim=k,
                           interpret=True)
    xla = packed_matmul_xla(a8, packed2d, *coeffs, wbits=wbits, kdim=k)
    np.testing.assert_array_equal(np.asarray(pallas), np.asarray(oracle))
    np.testing.assert_array_equal(np.asarray(xla), np.asarray(oracle))


def test_packed_matmul_bit_exact_large_ragged():
    m, k, n = 130, 257, 129
    a8, packed2d, coeffs, oracle = _packed_case(m, k, n, 4, seed=3)
    xla = packed_matmul_xla(a8, packed2d, *coeffs, wbits=4, kdim=k)
    np.testing.assert_array_equal(np.asarray(xla), np.asarray(oracle))


def test_packed_matmul_rejects_unsafe_k():
    k_bad = max_safe_k_packed(8, 8) + 1
    a8 = jnp.zeros((1, k_bad), jnp.int8)
    packed = jnp.zeros((k_bad, 1), jnp.uint8)
    z1 = jnp.zeros((1,), jnp.float32)
    with pytest.raises(ValueError):
        packed_matmul_xla(a8, packed, z1, z1, z1, z1, z1, z1,
                          wbits=8, kdim=k_bad)


# ---------------------------------------------------------------------------
# overflow bounds: kernel-layer duplicate pins to analysis/ranges
# ---------------------------------------------------------------------------

def test_max_safe_k_packed_agrees_with_ranges():
    for lhs in (8, 4, 2, 1):
        for rhs in (8, 4, 2, 1):
            assert max_safe_k_packed(lhs, rhs) == max_safe_k(lhs, rhs)
    # int4 x int8 and int2 x int8: the packed-weight operating points
    assert max_safe_k_packed(8, 4) == (2**31 - 1) // (128 * 8)
    assert max_safe_k_packed(8, 2) == (2**31 - 1) // (128 * 2)
    assert signed_code_bound(1) == 1
    with pytest.raises(ValueError):
        signed_code_bound(0)


# ---------------------------------------------------------------------------
# registry: sub-byte weight quantizers
# ---------------------------------------------------------------------------

def test_binary_weight_quantizer_bwn_algebra():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    pt = get_quantizer("binary").quantize(x, None, QuantizerSpec.of("binary"),
                                          backend="native")
    assert isinstance(pt, PackedTensor) and pt.bits == 1
    alpha = float(jnp.mean(jnp.abs(x)))
    deq = np.asarray(pt.dequant())
    np.testing.assert_allclose(np.unique(np.round(deq, 5)),
                               np.round([-alpha, alpha], 5), atol=1e-5)
    np.testing.assert_array_equal(deq > 0, np.asarray(x) > 0)


def test_ternary_weight_quantizer_twn_algebra():
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    pt = get_quantizer("ternary").quantize(
        x, None, QuantizerSpec.of("ternary"), backend="native")
    assert isinstance(pt, PackedTensor) and pt.bits == 2
    ax = np.abs(np.asarray(x))
    delta = 0.7 * ax.mean()
    alpha = ax[ax > delta].mean()
    deq = np.asarray(pt.dequant())
    np.testing.assert_allclose(np.sort(np.unique(np.round(deq, 5))),
                               np.round([-alpha, 0.0, alpha], 5), atol=1e-5)
    np.testing.assert_array_equal(deq == 0, ax <= delta)


def test_validate_one_bit_is_weight_only():
    GemmQuantConfig(fwd_act=QuantizerSpec.of("ptq_det:8"),
                    fwd_weight=QuantizerSpec.of("binary:1")).validate()
    with pytest.raises(ValueError):
        GemmQuantConfig(fwd_act=QuantizerSpec.of("ptq_det:1"),
                        fwd_weight=QuantizerSpec.of("binary:1")).validate()
    with pytest.raises(ValueError):
        get_quantizer("int4w").quantize(
            jnp.zeros((4, 4)), None, QuantizerSpec.of("int4w:8"),
            backend="native")


# ---------------------------------------------------------------------------
# training + pre-packed inference through fqt_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wspec", ["int4w:4", "binary:1"])
def test_training_parity_subbyte_weights(wspec):
    """Sub-byte weight quantizers train: grads through native/simulate
    agree (the simulate backend is the straight-line dequant reference)."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((9, 33)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((33, 17)), jnp.float32)
    key = jax.random.PRNGKey(0)
    grads = {}
    for backend in ("simulate", "native"):
        cfg = GemmQuantConfig(fwd_act=QuantizerSpec.of("ptq_det:8"),
                              fwd_weight=QuantizerSpec.of(wspec),
                              wgrad=QuantizerSpec.of("ptq:8"),
                              agrad=QuantizerSpec.of("psq:8"),
                              backend=backend)

        def loss(x, w):
            return jnp.sum(fqt_matmul(x, w, key, cfg, "l0") ** 2)

        v, g = jax.value_and_grad(loss, (0, 1))(x, w)
        grads[backend] = (float(v), np.asarray(g[0]), np.asarray(g[1]))
    np.testing.assert_allclose(grads["native"][0], grads["simulate"][0],
                               rtol=2e-5)
    np.testing.assert_allclose(grads["native"][1], grads["simulate"][1],
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(grads["native"][2], grads["simulate"][2],
                               rtol=2e-4, atol=2e-4)


def test_prepacked_weight_forward_matches_fp_weight():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((9, 33)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((33, 17)), jnp.float32)
    key = jax.random.PRNGKey(0)
    pt = pack_qtensor(quantize_ptq_det(w, 4))
    for backend in ("simulate", "native"):
        cfg = GemmQuantConfig(fwd_act=QuantizerSpec.of("ptq_det:8"),
                              fwd_weight=QuantizerSpec.of("int4w:4"),
                              backend=backend)
        y = fqt_matmul(x, pt, key, cfg, "l0")
        ref = fqt_matmul(x, w, key, cfg, "l0")
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=2e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# serve engine: pack once at load
# ---------------------------------------------------------------------------

CFG = get_config("statquant-tx", smoke=True)
PARAMS = build_model(CFG).init(jax.random.PRNGKey(0))


def _dense_bytes(params):
    total = 0

    def walk(node):
        nonlocal total
        if isinstance(node, dict):
            for key, v in node.items():
                if key == "w" and getattr(v, "ndim", 0) >= 2:
                    total += int(v.nbytes)
                else:
                    walk(v)

    walk(params)
    return total


def test_pack_dense_weights_reduction_and_structure():
    packed = pack_dense_weights(PARAMS, 4)
    packed_leaves = [leaf for leaf in jax.tree.leaves(
        packed, is_leaf=lambda x: isinstance(x, PackedTensor))
        if isinstance(leaf, PackedTensor)]
    assert packed_leaves, "no dense kernels were packed"
    pb = sum(int(leaf.nbytes) for leaf in packed_leaves)
    assert _dense_bytes(PARAMS) >= 4 * pb       # the ISSUE acceptance bar
    # embeddings/norms/biases untouched
    assert packed["embed"]["table"].dtype == jnp.float32
    assert weight_nbytes(packed) < weight_nbytes(PARAMS)
    with pytest.raises(ValueError):
        pack_dense_weights(PARAMS, 3)


def test_serve_engine_weight_bits_decode():
    base = ServeEngine(CFG, PARAMS, slots=2, max_seq=32, seed=0)
    rid = base.submit(list(range(1, 9)), max_new=6)
    ref = base.run()[rid].tokens
    # 8-bit packing uses the same deterministic quantizer the fp engine
    # applies per step, so greedy tokens must match exactly
    eng8 = ServeEngine(CFG, PARAMS, slots=2, max_seq=32, seed=0,
                       weight_bits=8)
    rid = eng8.submit(list(range(1, 9)), max_new=6)
    assert eng8.run()[rid].tokens == ref
    eng4 = ServeEngine(CFG, PARAMS, slots=2, max_seq=32, seed=0,
                       weight_bits=4)
    rid = eng4.submit(list(range(1, 9)), max_new=6)
    out = eng4.run()[rid].tokens
    assert len(out) == 6 and all(0 <= t < CFG.vocab_size for t in out)
    with pytest.raises(ValueError):
        ServeEngine(CFG, PARAMS, slots=2, max_seq=32, weight_bits=5)


# ---------------------------------------------------------------------------
# audit: packed-weight model green, leaked jnp.dot red
# ---------------------------------------------------------------------------

def _packed_policy(bits=4):
    return dataclasses.replace(
        QuantPolicy.qat(), overrides=(
            ("", RoleOverride.of({"fwd_weight": f"int4w:{bits}"})),))


def test_audit_packed_model_green_and_leak_red():
    model = build_model(CFG)
    policy = _packed_policy()
    packed = pack_dense_weights(PARAMS, 4)
    paths = model_quant_paths(CFG)
    key = jax.random.PRNGKey(0)
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32),
             "labels": jnp.zeros((2, 8), jnp.int32)}

    def loss_fn(p, b):
        loss, _ = model.loss(p, b, key, policy)
        return loss

    report = audit_fn(loss_fn, (packed, batch), policy=policy, paths=paths,
                      grad_traced=False, title="packed lm")
    assert report.ok, report.format()
    assert report.coverage == 1.0

    # leak one packed GEMM around fqt_matmul: the audit must turn red
    # naming the path
    target = next(p for p in paths if ".mlp." in p)

    def leaky_loss(p, b):
        import importlib

        # the package re-exports a *function* named mlp; grab the module
        mlp_mod = importlib.import_module("repro.layers.mlp")
        real = mlp_mod.dense

        def leaky(pp, x, k, pol, tag=0, path=""):
            if path == target:
                return jnp.dot(x, pp["w"].dequant())
            return real(pp, x, k, pol, tag, path)

        mlp_mod.dense = leaky
        try:
            loss, _ = model.loss(p, b, key, policy)
        finally:
            mlp_mod.dense = real
        return loss

    red = audit_fn(leaky_loss, (packed, batch), policy=policy, paths=paths,
                   grad_traced=False, title="packed lm leaked")
    assert not red.ok
    assert any(v.kind == "unmarked-gemm" for v in red.violations)
    assert any(v.path == target for v in red.violations)
