"""FQT custom_vjp: modes, paths, STE semantics, compression module."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EXACT, QAT, QuantPolicy, fqt_matmul


@pytest.fixture
def xwk():
    key = jax.random.PRNGKey(0)
    kx, kw, kk = jax.random.split(key, 3)
    return (jax.random.normal(kx, (32, 16)),
            jax.random.normal(kw, (16, 8)) * 0.3,
            kk)


def test_exact_mode_is_plain_matmul(xwk):
    x, w, k = xwk
    assert jnp.allclose(fqt_matmul(x, w, k, EXACT), x @ w)
    gx = jax.grad(lambda a: jnp.sum(fqt_matmul(a, w, k, EXACT) ** 2))(x)
    gx_ref = jax.grad(lambda a: jnp.sum((a @ w) ** 2))(x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref), rtol=1e-5)


def test_qat_forward_quantized_backward_exact_ste(xwk):
    """QAT: forward through quantized operands; backward = exact gradients of
    the quantized-forward function (STE, Eq. 4)."""
    x, w, k = xwk
    y = fqt_matmul(x, w, k, QAT)
    assert not jnp.allclose(y, x @ w)           # forward is quantized
    rel = float(jnp.max(jnp.abs(y - x @ w)) / jnp.max(jnp.abs(x @ w)))
    assert rel < 0.05                           # ... but 8-bit close
    # QAT backward is deterministic: same grads across keys
    g1 = jax.grad(lambda a: jnp.sum(fqt_matmul(a, w, jax.random.PRNGKey(1),
                                               QAT) ** 2))(x)
    g2 = jax.grad(lambda a: jnp.sum(fqt_matmul(a, w, jax.random.PRNGKey(2),
                                               QAT) ** 2))(x)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


@pytest.mark.parametrize("quant", ["ptq", "psq", "bhq"])
def test_fqt_backward_is_stochastic(xwk, quant):
    x, w, _ = xwk
    pol = QuantPolicy.fqt(quant, 4, bhq_block=16)
    g1 = jax.grad(lambda a: jnp.sum(
        fqt_matmul(a, w, jax.random.PRNGKey(1), pol) ** 2))(x)
    g2 = jax.grad(lambda a: jnp.sum(
        fqt_matmul(a, w, jax.random.PRNGKey(2), pol) ** 2))(x)
    assert not jnp.allclose(g1, g2)
    # same key -> identical (reproducibility)
    g3 = jax.grad(lambda a: jnp.sum(
        fqt_matmul(a, w, jax.random.PRNGKey(1), pol) ** 2))(x)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g3))


@pytest.mark.parametrize("quant", ["ptq", "psq", "bhq"])
def test_native_matches_simulate(xwk, quant):
    x, w, k = xwk
    ps = QuantPolicy.fqt(quant, 5, mode="simulate", bhq_block=16)
    pn = QuantPolicy.fqt(quant, 5, mode="native", bhq_block=16)
    ys, yn = fqt_matmul(x, w, k, ps), fqt_matmul(x, w, k, pn)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(yn),
                               rtol=1e-4, atol=1e-3)
    gs = jax.grad(lambda a, b: jnp.sum(fqt_matmul(a, b, k, ps) ** 2),
                  (0, 1))(x, w)
    gn = jax.grad(lambda a, b: jnp.sum(fqt_matmul(a, b, k, pn) ** 2),
                  (0, 1))(x, w)
    for a, b in zip(gs, gn, strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=5e-3)


def test_native_emits_int8_dot(xwk):
    """The native path must lower to an s8 x s8 -> s32 dot (MXU int8)."""
    x, w, k = xwk
    pol = QuantPolicy.fqt("psq", 5, mode="native")
    txt = jax.jit(lambda a, b: fqt_matmul(a, b, k, pol)).lower(x, w) \
        .compile().as_text()
    assert "s8[" in txt and "s32[" in txt


def test_bf16_stream_dtypes(xwk):
    x, w, k = xwk
    x16 = x.astype(jnp.bfloat16)
    pol = QuantPolicy.fqt("psq", 5)
    y = fqt_matmul(x16, w, k, pol)
    assert y.dtype == jnp.bfloat16
    gx, gw = jax.grad(
        lambda a, b: jnp.sum(fqt_matmul(a, b, k, pol).astype(jnp.float32) ** 2),
        (0, 1))(x16, w)
    assert gx.dtype == jnp.bfloat16         # activation grads in stream dtype
    assert gw.dtype == jnp.float32          # master-weight grads stay fp32


def test_vmap_over_experts(xwk):
    """fqt_matmul under vmap (MoE expert GEMMs) — per-expert quantizer stats."""
    _, _, k = xwk
    E = 4
    xs = jax.random.normal(jax.random.PRNGKey(3), (E, 8, 16))
    ws = jax.random.normal(jax.random.PRNGKey(4), (E, 16, 8))
    keys = jax.random.split(k, E)
    pol = QuantPolicy.fqt("psq", 6)
    ys = jax.vmap(lambda a, b, kk: fqt_matmul(a, b, kk, pol))(xs, ws, keys)
    assert ys.shape == (E, 8, 8)
    one = fqt_matmul(xs[1], ws[1], keys[1], pol)
    np.testing.assert_allclose(np.asarray(ys[1]), np.asarray(one), atol=1e-5)


def test_grad_through_scan(xwk):
    """fqt inside lax.scan (the layer stack) differentiates correctly."""
    x, w, k = xwk
    ws = jnp.stack([w @ jnp.ones((8, 16)) * 0.1] * 3)      # (3, 16, 16)? shape fix
    ws = jax.random.normal(jax.random.PRNGKey(5), (3, 16, 16)) * 0.2
    pol = QuantPolicy.fqt("bhq", 5, bhq_block=16)

    def f(ws_):
        def body(h, xs):
            wl, kl = xs
            return fqt_matmul(h, wl, kl, pol), 0
        h, _ = jax.lax.scan(body, x, (ws_, jax.random.split(k, 3)))
        return jnp.sum(h ** 2)

    g = jax.grad(f)(ws)
    assert g.shape == ws.shape
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.max(jnp.abs(g))) > 0


def test_wide_contraction_no_overflow():
    """int8 shifted-code accumulation must stay exact at K ~ 50k."""
    K = 49_152
    x = jnp.ones((2, K)) * 0.5
    w = jnp.ones((K, 2)) * 0.5
    pol = QuantPolicy.fqt("ptq", 8, mode="native")
    y = fqt_matmul(x, w, jax.random.PRNGKey(0), pol)
    expect = 0.25 * K
    assert abs(float(y[0, 0]) - expect) / expect < 0.02
