"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import fused_qlinear, fused_quantize_psq
from repro.kernels.q8_matmul import q8_matmul
from repro.kernels.quantize_sr import quantize_sr_rows, quantize_sr_tensor

SHAPES = [(8, 16, 8), (128, 128, 128), (64, 256, 128), (256, 64, 512),
          (32, 512, 32)]


@pytest.mark.parametrize("mkn", SHAPES)
def test_q8_matmul_vs_ref(mkn):
    M, K, N = mkn
    key = jax.random.PRNGKey(M * 31 + N)
    ks = jax.random.split(key, 8)
    x8 = jax.random.randint(ks[0], (M, K), -128, 128, jnp.int8)
    y8 = jax.random.randint(ks[1], (K, N), -128, 128, jnp.int8)
    rs = jax.random.uniform(ks[2], (M,)) + 0.1
    cs = jax.random.uniform(ks[3], (N,)) + 0.1
    r2 = jax.random.normal(ks[4], (M,))
    u = jax.random.normal(ks[5], (N,))
    a = jax.random.normal(ks[6], (M,))
    b = jax.random.normal(ks[7], (N,))
    out = q8_matmul(x8, y8, rs, cs, r2, u, a, b, interpret=True)
    expect = ref.q8_matmul_ref(x8, y8, rs, cs, r2, u, a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("tile", [(8, 8, 8), (16, 16, 16), (128, 64, 32)])
def test_q8_matmul_tilings(tile):
    """Different BlockSpec tilings must give identical results."""
    M, K, N = 128, 128, 128
    key = jax.random.PRNGKey(0)
    x8 = jax.random.randint(key, (M, K), -128, 128, jnp.int8)
    y8 = jax.random.randint(jax.random.fold_in(key, 1), (K, N), -128, 128,
                            jnp.int8)
    z = jnp.zeros
    ones = jnp.ones
    full = q8_matmul(x8, y8, ones((M,)), ones((N,)), z((M,)), z((N,)),
                     z((M,)), z((N,)), interpret=True)
    bm, bn, bk = tile
    tiled = q8_matmul(x8, y8, ones((M,)), ones((N,)), z((M,)), z((N,)),
                      z((M,)), z((N,)), bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(tiled))


@pytest.mark.parametrize("shape", [(16, 32), (64, 128), (256, 64), (8, 512)])
@pytest.mark.parametrize("bits", [4, 8])
def test_quantize_sr_rows_vs_ref(shape, bits):
    M, N = shape
    x = jax.random.normal(jax.random.PRNGKey(1), (M, N)) * 3
    rbits = jax.random.bits(jax.random.PRNGKey(2), (M, N), jnp.uint32)
    ck, cs, cz = quantize_sr_rows(x, rbits, bits, interpret=True)
    rk, rs_, rz = ref.quantize_sr_rows_ref(x, rbits, bits)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(rk))
    np.testing.assert_allclose(np.asarray(cs), np.asarray(rs_), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(cz), np.asarray(rz), rtol=1e-6)


@pytest.mark.parametrize("shape", [(16, 32), (128, 64)])
def test_quantize_sr_tensor_vs_ref(shape):
    M, N = shape
    x = jax.random.normal(jax.random.PRNGKey(3), (M, N))
    rbits = jax.random.bits(jax.random.PRNGKey(4), (M, N), jnp.uint32)
    ck, cs, cz = quantize_sr_tensor(x, rbits, 8, interpret=True)
    rk, rs_, rz = ref.quantize_sr_tensor_ref(x, rbits, 8)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(rk))
    assert abs(float(cs) - float(rs_)) < 1e-6 * abs(float(rs_))


@pytest.mark.parametrize("mkn", [(16, 32, 16), (64, 128, 64), (128, 256, 128)])
def test_fused_qlinear_matches_float(mkn):
    """End-to-end fused path ~= exact float matmul within quantization error,
    and exactly == the composed ref path."""
    M, K, N = mkn
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (M, K))
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N)) * 0.2
    yk, _ = fused_qlinear(x, w, key, interpret=True, use_kernels=True)
    yr, _ = fused_qlinear(x, w, key, interpret=True, use_kernels=False)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=1e-4, atol=1e-3)
    exact = np.asarray(x @ w)
    rel = np.max(np.abs(np.asarray(yk) - exact)) / np.max(np.abs(exact))
    assert rel < 0.05, f"8-bit fused GEMM should be ~1% off, got {rel}"


def test_fused_psq_unbiased():
    g = jax.random.normal(jax.random.PRNGKey(9), (32, 64))
    outs = [fused_quantize_psq(g, jax.random.PRNGKey(100 + i), 6)
            for i in range(128)]
    mean = jnp.mean(jnp.stack(outs), 0)
    assert float(jnp.max(jnp.abs(mean - g))) < 0.05
