"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import (fused_qlinear, fused_qlinear_bwd,
                               fused_quantize_psq)
from repro.kernels.q8_matmul import q8_matmul
from repro.kernels.quantize_sr import quantize_sr_rows, quantize_sr_tensor

# tile-aligned, small-tile, and ragged (pad-and-slice) shapes
SHAPES = [(8, 16, 8), (128, 128, 128), (64, 256, 128),
          (33, 17, 9), (130, 70, 258)]
SLOW_SHAPES = [(256, 64, 512), (32, 512, 32)]


def _q8_case(mkn):
    M, K, N = mkn
    key = jax.random.PRNGKey(M * 31 + N)
    ks = jax.random.split(key, 8)
    x8 = jax.random.randint(ks[0], (M, K), -128, 128, jnp.int8)
    y8 = jax.random.randint(ks[1], (K, N), -128, 128, jnp.int8)
    rs = jax.random.uniform(ks[2], (M,)) + 0.1
    cs = jax.random.uniform(ks[3], (N,)) + 0.1
    r2 = jax.random.normal(ks[4], (M,))
    u = jax.random.normal(ks[5], (N,))
    a = jax.random.normal(ks[6], (M,))
    b = jax.random.normal(ks[7], (N,))
    return x8, y8, rs, cs, r2, u, a, b


@pytest.mark.parametrize("mkn", SHAPES)
def test_q8_matmul_vs_ref(mkn):
    args = _q8_case(mkn)
    out = q8_matmul(*args, interpret=True)
    expect = ref.q8_matmul_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-3)


@pytest.mark.slow
@pytest.mark.parametrize("mkn", SLOW_SHAPES)
def test_q8_matmul_vs_ref_slow(mkn):
    args = _q8_case(mkn)
    out = q8_matmul(*args, interpret=True)
    expect = ref.q8_matmul_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("tile", [(8, 8, 8), (16, 16, 16), (128, 64, 32)])
def test_q8_matmul_tilings(tile):
    """Different BlockSpec tilings must give identical results."""
    M, K, N = 128, 128, 128
    key = jax.random.PRNGKey(0)
    x8 = jax.random.randint(key, (M, K), -128, 128, jnp.int8)
    y8 = jax.random.randint(jax.random.fold_in(key, 1), (K, N), -128, 128,
                            jnp.int8)
    z = jnp.zeros
    ones = jnp.ones
    full = q8_matmul(x8, y8, ones((M,)), ones((N,)), z((M,)), z((N,)),
                     z((M,)), z((N,)), interpret=True)
    bm, bn, bk = tile
    tiled = q8_matmul(x8, y8, ones((M,)), ones((N,)), z((M,)), z((N,)),
                      z((M,)), z((N,)), bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(tiled))


@pytest.mark.parametrize("shape", [(16, 32), (64, 128), (33, 20), (7, 96)])
@pytest.mark.parametrize("bits", [4, 8])
def test_quantize_sr_rows_vs_ref(shape, bits):
    M, N = shape
    x = jax.random.normal(jax.random.PRNGKey(1), (M, N)) * 3
    rbits = jax.random.bits(jax.random.PRNGKey(2), (M, N), jnp.uint32)
    ck, cs, cz = quantize_sr_rows(x, rbits, bits, interpret=True)
    rk, rs_, rz = ref.quantize_sr_rows_ref(x, rbits, bits)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(rk))
    np.testing.assert_allclose(np.asarray(cs), np.asarray(rs_), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(cz), np.asarray(rz), rtol=1e-6)


@pytest.mark.parametrize("bm", [8, 16])
def test_quantize_sr_pad_and_slice(bm):
    """Row counts that are NOT block multiples hit the edge-pad path and
    must still match the oracle exactly."""
    M, N = 33, 20
    x = jax.random.normal(jax.random.PRNGKey(5), (M, N)) * 2
    rbits = jax.random.bits(jax.random.PRNGKey(6), (M, N), jnp.uint32)
    ck, cs, cz = quantize_sr_rows(x, rbits, 8, bm=bm, interpret=True)
    rk, rs_, rz = ref.quantize_sr_rows_ref(x, rbits, 8)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(rk))
    np.testing.assert_allclose(np.asarray(cs), np.asarray(rs_), rtol=1e-6)
    tk, ts, tz = quantize_sr_tensor(x, rbits, 8, bm=bm, interpret=True)
    rk2, rs2, rz2 = ref.quantize_sr_tensor_ref(x, rbits, 8)
    np.testing.assert_array_equal(np.asarray(tk), np.asarray(rk2))
    assert abs(float(ts) - float(rs2)) < 1e-6 * abs(float(rs2))
    assert float(tz) == float(rz2)


@pytest.mark.parametrize("shape", [(16, 32), (128, 64)])
def test_quantize_sr_tensor_vs_ref(shape):
    M, N = shape
    x = jax.random.normal(jax.random.PRNGKey(3), (M, N))
    rbits = jax.random.bits(jax.random.PRNGKey(4), (M, N), jnp.uint32)
    ck, cs, cz = quantize_sr_tensor(x, rbits, 8, interpret=True)
    rk, rs_, rz = ref.quantize_sr_tensor_ref(x, rbits, 8)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(rk))
    assert abs(float(cs) - float(rs_)) < 1e-6 * abs(float(rs_))


@pytest.mark.parametrize("mkn", [(16, 32, 16), (64, 128, 64), (33, 50, 9)])
def test_fused_qlinear_matches_float(mkn):
    """End-to-end fused path ~= exact float matmul within quantization error,
    and exactly == the composed ref path."""
    M, K, N = mkn
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (M, K))
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N)) * 0.2
    yk, _ = fused_qlinear(x, w, key, interpret=True, use_kernels=True)
    yr, _ = fused_qlinear(x, w, key, interpret=True, use_kernels=False)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=1e-4, atol=1e-3)
    exact = np.asarray(x @ w)
    rel = np.max(np.abs(np.asarray(yk) - exact)) / np.max(np.abs(exact))
    assert rel < 0.05, f"8-bit fused GEMM should be ~1% off, got {rel}"


def test_fused_psq_unbiased():
    g = jax.random.normal(jax.random.PRNGKey(9), (32, 64))
    outs = [fused_quantize_psq(g, jax.random.PRNGKey(100 + i), 6)
            for i in range(128)]
    mean = jnp.mean(jnp.stack(outs), 0)
    assert float(jnp.max(jnp.abs(mean - g))) < 0.05


@pytest.mark.parametrize("quant", ["ptq", "psq", "bhq"])
@pytest.mark.parametrize("mkn", [(32, 16, 24), (33, 17, 9)])
def test_fused_qlinear_bwd_matches_simulate(quant, mkn):
    """Both backward GEMMs via the Pallas wrappers == the fp32 QDQ
    composition of the same quantizers (codes are bit-identical; only GEMM
    accumulation differs)."""
    from repro.core import (quantize_bhq_stoch, quantize_psq_stoch,
                            quantize_ptq_det, quantize_ptq_stoch)
    M, K, N = mkn
    key = jax.random.PRNGKey(11)
    x = jax.random.normal(key, (M, K))
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N)) * 0.2
    g = jax.random.normal(jax.random.fold_in(key, 2), (M, N))
    kb = jax.random.fold_in(key, 3)
    dw, dx = fused_qlinear_bwd(x, w, g, kb, grad_quantizer=quant,
                               bhq_block=16, interpret=True)
    k1, k2 = jax.random.split(kb)
    gq1 = quantize_ptq_stoch(g, k1, 8)
    gq2 = {"ptq": lambda: quantize_ptq_stoch(g, k2, 8),
           "psq": lambda: quantize_psq_stoch(g, k2, 8),
           "bhq": lambda: quantize_bhq_stoch(g, k2, 8, block_rows=16)}[quant]()
    dw_ref = quantize_ptq_det(x, 8).dequant().T @ gq1.dequant()
    dx_ref = gq2.dequant() @ quantize_ptq_det(w, 8).dequant().T
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_ref),
                               rtol=1e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_ref),
                               rtol=1e-3, atol=5e-3)
