"""Checkpoint manager: atomic writes, async, prune, elastic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (4, 8)),
                       "layers": {"ln": jnp.ones((3,))}},
            "opt": {"mu": {"w": jnp.zeros((4, 8)),
                           "layers": {"ln": jnp.zeros((3,))}}},
            "step": jnp.asarray(7)}


def test_roundtrip(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    tree = _tree()
    ckpt.save(10, tree)
    assert ckpt.latest_step() == 10
    out = ckpt.restore(10, jax.tree.map(np.asarray, tree))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_wait(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    tree = _tree(1)
    ckpt.save(1, tree, asynchronous=True)
    ckpt.wait()
    assert ckpt.latest_step() == 1
    out = ckpt.restore(1, tree)
    np.testing.assert_allclose(np.asarray(out["params"]["w"]),
                               np.asarray(tree["params"]["w"]))


def test_prune_keeps_latest(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ckpt.save(s, _tree(s))
    assert ckpt.all_steps() == [3, 4]


def test_atomicity_no_tmp_left(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(5, _tree())
    names = os.listdir(str(tmp_path))
    assert not any(n.endswith(".tmp") for n in names)
    assert "step_5" in names


def test_restore_shape_mismatch_raises(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        ckpt.restore(1, {"w": jnp.zeros((3, 3))})


def test_restore_missing_leaf_raises(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(KeyError):
        ckpt.restore(1, {"w": jnp.zeros((2, 2)), "extra": jnp.zeros(1)})


def test_meta(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(3, _tree(), extra={"lr": 0.1})
    meta = ckpt.load_meta(3)
    assert meta["step"] == 3 and meta["lr"] == pytest.approx(0.1)


def test_train_resume_after_preemption(tmp_path):
    """End-to-end fault-tolerance: preempt mid-run, resume, same stream."""
    from repro.configs import get_config
    from repro.core import QuantPolicy
    from repro.launch.train import train_loop
    from repro.runtime import PreemptionHandler

    cfg = get_config("statquant-tx", smoke=True)
    pol = QuantPolicy.fqt("psq", 6)

    class StopAt(PreemptionHandler):
        def __init__(self, at):
            super().__init__()
            self.at = at
            self.count = 0

        @property
        def should_stop(self):
            self.count += 1
            return self.count >= self.at

    # run 1: preempt after a few steps
    train_loop(cfg, pol, steps=20, batch_size=2, seq_len=8,
               ckpt_dir=str(tmp_path), ckpt_every=5,
               preemption=StopAt(4), log_fn=lambda *a: None)
    step1 = CheckpointManager(str(tmp_path)).latest_step()
    assert step1 is not None and step1 >= 4
    # run 2: resumes from the checkpoint and finishes
    _, _, hist = train_loop(cfg, pol, steps=10, batch_size=2, seq_len=8,
                            ckpt_dir=str(tmp_path), ckpt_every=100,
                            log_fn=lambda *a: None)
    assert hist[-1][0] == 9
