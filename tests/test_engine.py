"""Engine tests: TrainState lifecycle, exact resume (data + rng streams),
gradient accumulation, and the sharded/donated step on fake-device meshes.

Mesh tests run in SUBPROCESSES because XLA_FLAGS device-count must be set
before jax initializes (same convention as tests/test_distribution.py).
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import QuantPolicy
from repro.data import make_batch_for
from repro.engine import Engine, TrainState, split_microbatches

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 4, timeout=900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


# ---------------------------------------------------------------------------
# single-device: state, microbatching, resume
# ---------------------------------------------------------------------------

def test_split_microbatches():
    batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
             "labels": jnp.zeros((8, 16), jnp.int32),
             "positions": jnp.zeros((3, 8, 16), jnp.int32)}  # vlm m-rope
    micro = split_microbatches(batch, 4)
    assert micro["tokens"].shape == (4, 2, 16)
    assert micro["positions"].shape == (4, 3, 2, 16)
    with pytest.raises(ValueError, match="not divisible"):
        split_microbatches({"tokens": jnp.zeros((6, 4))}, 4)


def test_train_state_checkpoint_roundtrip(tmp_path):
    """TrainState's dict form round-trips through CheckpointManager with
    step and rng intact (the fields exact resume depends on)."""
    from repro.checkpoint import CheckpointManager
    state = TrainState(params={"w": jnp.ones((4, 2))},
                       opt_state={"mu": {"w": jnp.zeros((4, 2))}},
                       step=jnp.asarray(7, jnp.int32),
                       rng=jax.random.PRNGKey(3))
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(7, state.as_dict())
    out = TrainState.from_dict(
        ckpt.restore(7, jax.tree.map(np.asarray, state.as_dict())))
    assert int(out.step) == 7
    np.testing.assert_array_equal(np.asarray(out.rng), np.asarray(state.rng))
    np.testing.assert_array_equal(np.asarray(out.params["w"]),
                                  np.asarray(state.params["w"]))


def _engine(batch_fn=None, **kw):
    cfg = get_config("statquant-tx", smoke=True)
    pol = QuantPolicy.fqt("bhq", 5, bhq_block=16)
    args = dict(steps=6, batch_size=4, seq_len=16, log_every=100,
                log_fn=None, batch_fn=batch_fn)
    args.update(kw)
    return Engine(cfg, pol, **args)


def _recording_batch_fn(log):
    cfg = get_config("statquant-tx", smoke=True)

    def fn(step):
        log.append(step)
        return make_batch_for(cfg, 4, 16, step=step, seed=0)
    return fn


def test_resume_is_bit_identical_and_data_continuous(tmp_path):
    """run-6-steps == run-3-save + restore-run-3, bit for bit.

    Covers both resume bugs at once: the rng stream lives in TrainState (so
    SR draws replay identically) and the loader position is restored from
    the checkpointed step (so the stream continues at batch 3, not batch 0).
    """
    rec_a, rec_b1, rec_b2 = [], [], []
    full = _engine(_recording_batch_fn(rec_a)).run()

    e1 = _engine(_recording_batch_fn(rec_b1),
                 ckpt_dir=str(tmp_path), ckpt_every=3)
    h1 = e1.run(steps=3)
    e2 = _engine(_recording_batch_fn(rec_b2),
                 ckpt_dir=str(tmp_path), ckpt_every=100)
    h2 = e2.run()

    assert full == h1 + h2          # losses bit-identical, steps contiguous
    assert [s for s, _ in h1 + h2] == list(range(6))
    # loader position: the resumed engine never re-reads batches 0..2
    # (prefetch may read ahead past the end, so assert the prefix + floor)
    assert rec_a[:6] == list(range(6))
    assert rec_b1[:3] == [0, 1, 2]
    assert rec_b2[:3] == [3, 4, 5]
    assert min(rec_b2) == 3
    assert int(e2.state.step) == 6


def test_accumulation_matches_full_batch_exact_policy():
    """accum=2 vs accum=1 under the exact policy: same data, no quantization
    noise, so the mean-of-microbatch gradients equal the full-batch gradient
    up to fp32 reduction order — losses track within tolerance."""
    cfg = get_config("statquant-tx", smoke=True)
    kw = dict(steps=3, batch_size=8, seq_len=16, log_every=100, log_fn=None)
    h1 = Engine(cfg, QuantPolicy.exact(), accum_steps=1, **kw).run()
    h2 = Engine(cfg, QuantPolicy.exact(), accum_steps=2, **kw).run()
    np.testing.assert_allclose([l for _, l in h1], [l for _, l in h2],
                               rtol=1e-4, atol=1e-4)


def test_engine_rejects_bad_accum():
    with pytest.raises(ValueError, match="not divisible"):
        _engine(accum_steps=5)


def test_legacy_checkpoint_migrates(tmp_path):
    """A pre-engine checkpoint ({params, opt} only) resumes: step comes from
    the checkpoint index, the rng stream restarts instead of KeyError-ing."""
    from repro.checkpoint import CheckpointManager
    e = _engine()
    st = e.init_state()
    CheckpointManager(str(tmp_path)).save(
        2, {"params": st.params, "opt": st.opt_state})
    msgs = []
    e2 = _engine(ckpt_dir=str(tmp_path), log_fn=msgs.append)
    h = e2.run(steps=4)
    assert [s for s, _ in h] == [2, 3]
    assert int(e2.state.step) == 4
    assert any("legacy checkpoint" in m for m in msgs)


def test_straggler_probe_flags_slow_host():
    """With an injected fleet-times probe (what scheduler heartbeats supply
    on a real cluster), a persistently slow host is flagged and logged."""
    from repro.runtime import StragglerMonitor
    msgs = []
    eng = _engine(straggler=StragglerMonitor(n_hosts=4, patience=2),
                  straggler_probe=lambda dt: [dt, dt, dt, dt * 10],
                  log_fn=msgs.append)
    eng.run(steps=3)
    assert eng.straggler.stragglers() == [3]
    assert any("stragglers: [3]" in m for m in msgs)


# ---------------------------------------------------------------------------
# fake-device meshes (subprocesses)
# ---------------------------------------------------------------------------

_PARITY_CODE = r"""
import numpy as np
from repro.configs import get_config
from repro.core import QuantPolicy
from repro.engine import Engine
from repro.launch.mesh import make_test_mesh

cfg = get_config("statquant-tx", smoke=True)
for backend in BACKENDS:
    pol = QuantPolicy.fqt("bhq", 5, bhq_block=16, backend=backend, overrides={
        r"lm_head": "exact",
        r"layers\.attn\.": 8,
        r"layers\.mlp\.": {"agrad": ("bhq", 4)},
    })
    kw = dict(steps=3, batch_size=8, seq_len=16, accum_steps=2,
              log_every=1, log_fn=None)
    h_mesh = Engine(cfg, pol, mesh=make_test_mesh(2, 2), **kw).run()
    h_flat = Engine(cfg, pol, **kw).run()
    assert len(h_mesh) == 3
    # step 0 sees identical params + identical SR draws: pure GSPMD
    # reduction-order noise.  Later steps amplify it through discrete SR
    # boundary flips, so the trajectory tolerance is looser.
    np.testing.assert_allclose(h_mesh[0][1], h_flat[0][1], rtol=1e-4,
                               err_msg=backend)
    np.testing.assert_allclose([l for _, l in h_mesh],
                               [l for _, l in h_flat], rtol=2e-3, atol=2e-3,
                               err_msg=backend)
    print("PARITY", backend, [round(l, 4) for _, l in h_mesh])
"""


def test_sharded_accum_matches_unsharded_simulate():
    """Acceptance: a heterogeneous-policy LM trains 3 steps through
    Engine.run() on a 2x2 mesh with accum=2, loss within fp32 tolerance of
    the unsharded run (same microbatching, so identical SR draws)."""
    out = run_sub('BACKENDS = ("simulate",)\n' + _PARITY_CODE)
    assert "PARITY simulate" in out


@pytest.mark.slow
def test_sharded_accum_matches_unsharded_native_pallas():
    """Same acceptance check on the native int8 and (interpreted) Pallas
    backends — exhaustive sweep, excluded from tier-1."""
    out = run_sub('BACKENDS = ("native", "pallas")\n' + _PARITY_CODE,
                  timeout=1800)
    assert "PARITY native" in out and "PARITY pallas" in out


def test_plan_divisibility_fallback_tiny_mesh():
    """Every config resolves a full TrainState sharding plan on a mesh whose
    model axis (3) divides almost nothing — the fallback must replicate
    instead of erroring — and smoke states actually place on it."""
    out = run_sub(r"""
import jax
from repro.configs import ALL_NAMES, get_config
from repro.engine import (abstract_train_state, init_train_state,
                          state_shardings, state_specs)
from repro.models import build_model
from repro.optim import sgd
from repro.sharding import make_plan
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh(2, 3)
plan = make_plan(mesh)
opt = sgd(0.9)
for arch in ALL_NAMES:
    cfg = get_config(arch)                    # FULL configs
    model = build_model(cfg)
    astate = abstract_train_state(model, opt)
    specs = state_specs(plan, astate)
    flat_p = jax.tree_util.tree_leaves_with_path(astate)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "index"))
    assert len(flat_p) == len(flat_s)
    for (path, leaf), spec in zip(flat_p, flat_s, strict=True):
        for dim, ax in zip(leaf.shape, tuple(spec), strict=False):
            if ax is not None:
                assert dim % mesh.shape[ax] == 0, (arch, path, leaf.shape,
                                                   spec)
# actual placement (uneven sharding would raise at device_put)
for arch in ("statquant-tx", "granite-moe-1b-a400m", "rwkv6-1.6b"):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    st = init_train_state(model, opt, seed=0)
    sh = state_shardings(plan, abstract_train_state(model, opt))
    placed = jax.device_put(st, sh)
    jax.block_until_ready(placed.params)
print("FALLBACK OK")
""", devices=6)
    assert "FALLBACK OK" in out


def test_engine_compressed_allreduce_runs():
    """The beyond-paper int8 compressed DP all-reduce composes with the
    engine step (shard_map inside the jitted, donated, accumulated step) —
    also covers the jax-version shard_map shim in core/compression.py."""
    out = run_sub("""
import math
from repro.configs import get_config
from repro.core import QuantPolicy
from repro.engine import Engine
from repro.launch.mesh import make_test_mesh

cfg = get_config("statquant-tx", smoke=True)
pol = QuantPolicy.fqt("bhq", 5, bhq_block=16)
eng = Engine(cfg, pol, steps=2, batch_size=8, seq_len=16, accum_steps=2,
             mesh=make_test_mesh(2, 2), compress_axis="data", log_fn=None)
h = eng.run()
assert len(h) == 2 and all(math.isfinite(l) for _, l in h), h
print("COMPRESSED OK", [round(l, 4) for _, l in h])
""")
    assert "COMPRESSED OK" in out


def test_elastic_resume_across_mesh_shapes(tmp_path):
    """Engine checkpoints on a 2x2 mesh; a second engine on a 4x1 mesh
    restores the same TrainState (CheckpointManager reshards on device_put)
    and continues training."""
    out = run_sub(f"""
import jax, math
from repro.configs import get_config
from repro.core import QuantPolicy
from repro.engine import Engine
from repro.launch.mesh import make_test_mesh

cfg = get_config("statquant-tx", smoke=True)
pol = QuantPolicy.fqt("bhq", 5, bhq_block=16)
kw = dict(steps=3, batch_size=8, seq_len=16, accum_steps=2, log_fn=None,
          ckpt_dir="{tmp_path}", ckpt_every=2)
e1 = Engine(cfg, pol, mesh=make_test_mesh(2, 2), **kw)
h1 = e1.run(steps=2)
e2 = Engine(cfg, pol, mesh=make_test_mesh(4, 1), **kw)
h2 = e2.run()
assert [s for s, _ in h2] == [2], h2
assert int(e2.state.step) == 3
assert jax.tree.leaves(e2.state.params)[0].sharding.mesh == e2.mesh
assert all(math.isfinite(l) for _, l in h1 + h2)
print("ELASTIC ENGINE OK")
""")
    assert "ELASTIC ENGINE OK" in out


@pytest.mark.slow
def test_cli_engine_smoke_4dev_mesh():
    """The CI smoke job, as a test: the training CLI runs the engine 3 steps
    on a 2x2 fake-CPU mesh with accumulation."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--steps", "3",
         "--batch", "8", "--seq", "16", "--mesh", "2x2", "--accum", "2"],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "step     2" in out.stdout
