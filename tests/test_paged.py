"""Paged int8 KV serving suite (ISSUE-10 acceptance surface).

  * codec degeneracy: fresh pages dequantize to exact 0.0 and a zero scale
    can never produce inf/NaN (the masked-garbage soundness condition)
  * page-gather kernel: Pallas block-table gather vs its XLA twin, bitwise
  * paged engine vs dense engine: token-for-token identical completions at
    equal seeds, on all three backends
  * chunked prefill and prefix reuse (shared pages, copy-on-write) leave
    tokens unchanged; refcount/table invariants hold under churn,
    preemption, and LRU eviction
  * self-speculative decode emits exactly the target's greedy tokens
  * top-p sampling semantics + the (seed, rid, token-idx) determinism
    contract

Pallas cases run in interpret mode and are slow-marked per repo
convention.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import QuantPolicy, dequant_kv_rows, kv_fresh_code, \
    quantize_kv_rows
from repro.kernels import kv_gather_pages, kv_gather_pages_xla
from repro.models import build_model
from repro.serve import PagedServeEngine, PagePool, PrefixCache, \
    ServeEngine, greedy_accept, sample_tokens, slot_keys
from repro.serve.paged import GARBAGE_PAGE

CFG = get_config("statquant-tx", smoke=True)
MODEL = build_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))

BACKENDS = [("simulate", ()), ("native", ()),
            ("pallas", (pytest.mark.slow,))]
EXACT = QuantPolicy.exact()


def _prompts(sizes, seed=0, shared=0):
    rng = np.random.default_rng(seed)
    sys_p = list(rng.integers(0, CFG.vocab_size, size=shared)) if shared \
        else []
    return [sys_p + list(rng.integers(0, CFG.vocab_size, size=n))
            for n in sizes]


def _run(paged, prompts, policy=EXACT, slots=2, max_seq=32, seed=0,
         max_new=6, temperature=0.0, check=True, **kw):
    eng = ServeEngine(CFG, PARAMS, policy=policy, slots=slots,
                      max_seq=max_seq, kv_quant=True, seed=seed,
                      paged=paged, **kw)
    for p in prompts:
        eng.submit(p, max_new=max_new, temperature=temperature, top_k=8)
    out = eng.run()
    if paged and check:
        eng.check_invariants()
    tokens = {r: out[r].tokens for r in sorted(out)}
    return (tokens, eng) if paged else tokens


# ---------------------------------------------------------------------------
# Codec degeneracy: fresh pages and zero scales
# ---------------------------------------------------------------------------

def test_fresh_code_dequants_to_exact_zero():
    """A fresh page (codes = kv_fresh_code, scale = 1, zero = 0) must
    dequantize to exactly 0.0 — masked lanes still enter the attention
    matmul, and 0 * finite is the only safe product."""
    for bits in (8, 4, 2):
        codes = jnp.full((3, 5), kv_fresh_code(bits), jnp.int8)
        out = dequant_kv_rows(codes, jnp.ones((3,)), jnp.zeros((3,)),
                              bits=bits)
        assert float(jnp.max(jnp.abs(out))) == 0.0


def test_zero_scale_never_inf():
    """scale == 0 (all-constant row, or an uninitialized page row) must
    clamp, not divide to inf: one inf times a zero mask weight is NaN and
    poisons the whole attention row."""
    codes = jnp.zeros((4, 8), jnp.int8)
    out = dequant_kv_rows(codes, jnp.zeros((4,)), jnp.full((4,), 2.0))
    assert bool(jnp.all(jnp.isfinite(out)))
    # constant rows round-trip through quantize -> dequant to their value
    x = jnp.full((2, 8), 3.25)
    q = quantize_kv_rows(x)
    back = dequant_kv_rows(*q)
    assert float(jnp.max(jnp.abs(back - x))) < 1e-2


# ---------------------------------------------------------------------------
# Page-gather kernel: Pallas vs XLA twin
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("P,D,bm", [(8, 32, None), (16, 48, 4), (4, 8, 64)])
def test_kv_gather_pallas_matches_xla(P, D, bm):
    rng = np.random.default_rng(3)
    n_pages, B, nb = 10, 3, 4
    codes = jnp.asarray(rng.integers(-128, 128, (n_pages, P, D)), jnp.int8)
    scale = jnp.asarray(rng.uniform(0.5, 2.0, (n_pages, P)), jnp.float32)
    zero = jnp.asarray(rng.normal(size=(n_pages, P)), jnp.float32)
    # include page 0 repeats and a zero scale row: both must stay finite
    scale = scale.at[0].set(0.0)
    table = jnp.asarray(rng.integers(0, n_pages, (B, nb)), jnp.int32)
    table = table.at[0, 0].set(0)
    got = kv_gather_pages(codes, scale, zero, table, bm=bm, interpret=True)
    ref = kv_gather_pages_xla(codes, scale, zero, table)
    assert got.shape == (B, nb * P, D)
    assert bool(jnp.all(jnp.isfinite(got)))
    assert float(jnp.max(jnp.abs(got - ref))) == 0.0


# ---------------------------------------------------------------------------
# Paged <-> dense engine parity
# ---------------------------------------------------------------------------

def test_paged_dispatch():
    eng = ServeEngine(CFG, PARAMS, policy=EXACT, slots=2, max_seq=16,
                      kv_quant=True, paged=True)
    assert isinstance(eng, PagedServeEngine)
    assert not isinstance(ServeEngine(CFG, PARAMS, policy=EXACT, slots=2,
                                      max_seq=16, kv_quant=True),
                          PagedServeEngine)


def test_paged_requires_kv_codec():
    with pytest.raises(ValueError, match="kv_quant"):
        ServeEngine(CFG, PARAMS, policy=EXACT, slots=2, max_seq=16,
                    kv_quant=False, paged=True)


@pytest.mark.parametrize("backend", [pytest.param(b, marks=m)
                                     for b, m in BACKENDS])
def test_paged_matches_dense_tokens(backend):
    """The acceptance bar: paged=True is token-for-token identical to the
    dense-slot engine at equal seeds (greedy and temperature lanes)."""
    pol = QuantPolicy(enabled=False, backend=backend)
    prompts = _prompts((3, 7, 5, 9), seed=1)
    dense = _run(False, prompts, policy=pol, temperature=0.7)
    paged, _ = _run(True, prompts, policy=pol, temperature=0.7)
    assert paged == dense


def test_paged_matches_dense_greedy_many_requests():
    prompts = _prompts((4, 11, 2, 8, 6, 13), seed=2)
    dense = _run(False, prompts, slots=3, max_new=8)
    paged, eng = _run(True, prompts, slots=3, max_new=8, page_size=8)
    assert paged == dense
    # after the drain only registry-held prompt pages stay resident
    eng._prefix.clear(eng.pool_host)
    assert eng.pool_host.in_use == 0


# ---------------------------------------------------------------------------
# Chunked prefill / prefix reuse / preemption
# ---------------------------------------------------------------------------

def test_chunked_prefill_same_tokens():
    prompts = _prompts((13, 9, 17), seed=3)
    dense = _run(False, prompts)
    for chunk in (4, 8):
        paged, eng = _run(True, prompts, prefill_chunk=chunk, page_size=4)
        assert paged == dense, f"chunk={chunk}"
        assert eng.pool_stats()["n_pages"] == eng.n_pages


def test_prefix_reuse_and_cow_same_tokens():
    """Prompts sharing a 13-token system prefix (page_size 4 => full-page
    sharing at the 12 boundary) plus prompts extending an earlier prompt
    past a partial page (forcing copy-on-write of the divergence page)
    must still emit dense-identical tokens."""
    rng = np.random.default_rng(4)
    base = list(rng.integers(0, CFG.vocab_size, size=13))
    prompts = [base + list(rng.integers(0, CFG.vocab_size, size=n))
               for n in (4, 6)]
    # extensions of prompts[0] (len 17, 17 % 4 != 0): adopting its
    # full-prompt registry entry crosses a partial boundary -> COW
    prompts += [prompts[0] + list(rng.integers(0, CFG.vocab_size, size=n))
                for n in (3, 5)]
    dense = _run(False, prompts)
    paged, eng = _run(True, prompts, page_size=4)
    assert paged == dense
    stats = eng.pool_stats()
    assert stats["prefix_hits"] >= 2
    # 13 % 4 != 0: at least one adoption crosses a partial boundary
    assert stats["cow_copies"] >= 1


def test_preemption_under_tiny_pool_same_tokens():
    """A pool that can only hold ~one request forces preemption churn; the
    resume path must still produce dense-identical completions."""
    prompts = _prompts((7, 12, 5), seed=5, shared=0)
    dense = _run(False, prompts, max_new=10)
    paged, eng = _run(True, prompts, max_new=10, page_size=4,
                      pages=1 + 8, prefill_chunk=4)
    assert paged == dense
    assert eng.pool_stats()["preemptions"] >= 1


def test_pool_too_small_raises():
    eng = ServeEngine(CFG, PARAMS, policy=EXACT, slots=1, max_seq=16,
                      kv_quant=True, paged=True, page_size=4, pages=2)
    eng.submit(list(range(1, 12)), max_new=4)
    with pytest.raises(RuntimeError, match="page pool"):
        eng.run()


# ---------------------------------------------------------------------------
# PagePool / PrefixCache invariants
# ---------------------------------------------------------------------------

def test_page_pool_refcounts():
    pool = PagePool(6, 4)
    a, b = pool.alloc(), pool.alloc()
    assert a != GARBAGE_PAGE and b != GARBAGE_PAGE and a != b
    assert pool.in_use == 2
    pool.incref(a)
    pool.decref(a)
    assert pool.in_use == 2                   # still held once
    pool.decref(a)
    assert pool.in_use == 1                   # freed
    # garbage page ref ops are no-ops
    pool.incref(GARBAGE_PAGE)
    pool.decref(GARBAGE_PAGE)
    # exhaustion returns None, freed pages come back
    got = [pool.alloc() for _ in range(10)]
    assert got.count(None) == 6              # 4 real pages, then dry
    pool.check([[b]], [tuple(p for p in got if p is not None)])


def test_prefix_cache_lru_and_refcounts():
    pool = PagePool(10, 4)
    cache = PrefixCache(max_entries=2)
    pages = [pool.alloc() for _ in range(3)]
    cache.register((1, 2, 3, 4), (pages[0],), pool)
    cache.register((1, 2, 3, 4, 5, 6, 7, 8), (pages[0], pages[1]), pool)
    assert pool.refs[pages[0]] == 3          # owner + two entries
    # longest strict-prefix lookup (m <= len(ctx) - 1)
    m, got = cache.lookup((1, 2, 3, 4, 5, 6, 7, 8, 9))
    assert m == 8 and got == (pages[0], pages[1])
    m, _ = cache.lookup((1, 2, 3, 4, 5))
    assert m == 4
    assert cache.lookup((9, 9, 9, 9, 9))[0] == 0
    # capacity eviction decrefs
    cache.register((7, 7, 7, 7), (pages[2],), pool)   # evicts LRU
    assert len(cache.entries) == 2
    cache.clear(pool)
    assert pool.refs[pages[0]] == 1 and pool.refs[pages[1]] == 1
    for p in pages:
        pool.decref(p)
    assert pool.in_use == 0


def test_churn_invariants():
    """Heavy mixed workload (sharing + tiny pool + chunking): after every
    drain the refcount cross-check must pass and the pool must be empty
    except for registry-held pages."""
    eng = ServeEngine(CFG, PARAMS, policy=EXACT, slots=3, max_seq=32,
                      kv_quant=True, seed=0, paged=True, page_size=4,
                      pages=1 + 14, prefill_chunk=8, prefix_entries=4)
    rng = np.random.default_rng(6)
    shared = list(rng.integers(0, CFG.vocab_size, size=9))
    for round_ in range(3):
        for n in (3, 6, 2, 9):
            eng.submit(shared + list(rng.integers(0, CFG.vocab_size,
                                                  size=n)), max_new=5)
        out = eng.run()
        assert len(out) == 4
        eng.check_invariants()
    registry_pages = {p for pages in eng._prefix.registered_pages()
                      for p in pages}
    assert eng.pool_host.in_use == len(registry_pages)


# ---------------------------------------------------------------------------
# Self-speculative decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 3])
def test_spec_decode_matches_target_greedy(k):
    prompts = _prompts((5, 9, 3), seed=7)
    dense = _run(False, prompts, max_new=9)
    paged, eng = _run(True, prompts, max_new=9, spec_decode=True, spec_k=k)
    assert paged == dense
    st = eng.spec_stats
    assert st.spec_steps > 0
    assert 0.0 <= st.acceptance_rate <= 1.0
    assert st.emitted >= st.spec_steps        # every round emits >= 1


def test_spec_decode_temperature_lanes_match_plain():
    """Temperature slots inside a spec batch take exactly one token from
    the verify logits under the standard (rid, count) key — identical to
    the plain paged engine's sampling."""
    prompts = _prompts((5, 6), seed=8)
    plain, _ = _run(True, prompts, max_new=6, temperature=0.9)
    spec, _ = _run(True, prompts, max_new=6, temperature=0.9,
                   spec_decode=True, spec_k=2)
    assert spec == plain


def test_greedy_accept_semantics():
    assert greedy_accept(np.array([5, 6]), np.array([5, 6, 7])) == [5, 6, 7]
    assert greedy_accept(np.array([5, 6]), np.array([5, 9, 7])) == [5, 9]
    assert greedy_accept(np.array([4, 6]), np.array([5, 6, 7])) == [5]


def test_spec_qat_runs_clean():
    """Under a quantized target policy the draft disagrees more (that is
    the point); the engine must still drain with sane acceptance."""
    prompts = _prompts((6, 4), seed=9)
    tokens, eng = _run(True, prompts, policy=QuantPolicy.qat(), max_new=6,
                       spec_decode=True, spec_k=2)
    assert all(len(t) == 6 for t in tokens.values())
    assert eng.spec_stats.proposed > 0


# ---------------------------------------------------------------------------
# Top-p sampling
# ---------------------------------------------------------------------------

def _sample_batch(logits, top_p, seed=0, temp=1.0, top_k=0, n=256):
    B, V = logits.shape
    outs = []
    for i in range(n):
        keys = slot_keys(jax.random.PRNGKey(seed), jnp.full((B,), i,
                                                            jnp.int32),
                         jnp.zeros((B,), jnp.int32))
        outs.append(np.asarray(sample_tokens(
            logits, keys, jnp.full((B,), temp), jnp.full((B,), top_k,
                                                         jnp.int32),
            V, jnp.full((B,), top_p))))
    return np.stack(outs)


def test_top_p_restricts_support():
    # token 0 holds ~73% mass, token 1 ~27%; top_p = 0.5 keeps only token 0
    logits = jnp.asarray([[2.0, 1.0, -3.0, -3.0]])
    assert set(_sample_batch(logits, 0.5).ravel()) == {0}
    # top_p = 0.9 needs two tokens to cover the mass
    support = set(_sample_batch(logits, 0.9).ravel())
    assert support == {0, 1}
    # out-of-range values disable the filter entirely: identical draws to
    # the no-top_p path (same keys, same uniforms)
    B, V = logits.shape
    keys = slot_keys(jax.random.PRNGKey(0), jnp.arange(B, dtype=jnp.int32),
                     jnp.zeros((B,), jnp.int32))
    none = sample_tokens(logits, keys, jnp.ones((B,)),
                         jnp.zeros((B,), jnp.int32), V)
    for off in (0.0, 1.0, 1.5, -0.2):
        got = sample_tokens(logits, keys, jnp.ones((B,)),
                            jnp.zeros((B,), jnp.int32), V,
                            jnp.full((B,), off))
        assert np.array_equal(np.asarray(got), np.asarray(none)), off


def test_top_p_deterministic_and_composes_with_top_k():
    logits = jnp.asarray(np.random.default_rng(10).normal(size=(2, 16)),
                         jnp.float32)
    a = _sample_batch(logits, 0.8, n=32)
    b = _sample_batch(logits, 0.8, n=32)
    assert np.array_equal(a, b)
    # top-k=1 forces greedy regardless of top-p
    g = _sample_batch(logits, 0.8, top_k=1, n=8)
    assert np.array_equal(g, np.broadcast_to(
        np.asarray(jnp.argmax(logits, -1)), g.shape))


def test_top_p_through_engine_deterministic():
    prompts = _prompts((5, 7), seed=11)
    kw = dict(max_new=6, temperature=0.8, check=False)
    eng1 = ServeEngine(CFG, PARAMS, policy=EXACT, slots=2, max_seq=32,
                       kv_quant=True, seed=3, paged=True)
    eng2 = ServeEngine(CFG, PARAMS, policy=EXACT, slots=2, max_seq=32,
                       kv_quant=True, seed=3, paged=True)
    for eng in (eng1, eng2):
        for p in prompts:
            eng.submit(p, max_new=6, temperature=0.8, top_p=0.7)
    o1, o2 = eng1.run(), eng2.run()
    assert {r: o1[r].tokens for r in o1} == {r: o2[r].tokens for r in o2}
