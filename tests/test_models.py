"""Per-architecture smoke tests (assignment deliverable f).

Each of the 10 assigned architectures instantiates its REDUCED config and
runs one forward/train step + prefill + decode on CPU, asserting output
shapes and no NaNs, under full FQT quantization.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config, shape_grid
from repro.core import QuantPolicy
from repro.models import build_model

B, T = 2, 8
POLICY = QuantPolicy.fqt("bhq", 5, bhq_block=16)

# Tier-1 keeps one arch per distinct code path (dense tx / MoE / recurrent /
# hybrid / VLM / audio enc-dec); the remaining configs exercise the same
# layers with different hyperparameters and run in the slow sweep.
FAST_ARCHS = {"granite-3-2b", "olmoe-1b-7b", "rwkv6-1.6b", "zamba2-2.7b",
              "qwen2-vl-2b", "whisper-medium"}
ARCH_PARAMS = [pytest.param(a, marks=() if a in FAST_ARCHS
                            else (pytest.mark.slow,)) for a in ARCH_NAMES]


def make_smoke_batch(cfg, key, with_labels=True):
    batch = {}
    if cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(key, (B, T, cfg.d_model))
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(T, dtype=jnp.int32), (3, B, T)).copy()
    elif cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
        batch["tokens"] = jnp.ones((B, T), jnp.int32)
    else:
        batch["tokens"] = jnp.ones((B, T), jnp.int32)
    if with_labels:
        batch["labels"] = jnp.ones((B, T), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = make_smoke_batch(cfg, key)
    (loss, mets), grads = jax.value_and_grad(
        lambda p: model.loss(p, batch, key, POLICY), has_aux=True)(params)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    for g in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(g))), arch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    batch = make_smoke_batch(cfg, key, with_labels=False)
    logits, cache = model.prefill(params, batch, POLICY, max_seq=T + 4)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    db = ({"embeds": jax.random.normal(key, (B, 1, cfg.d_model))}
          if cfg.family == "vlm" else {"tokens": jnp.ones((B, 1), jnp.int32)})
    for _ in range(2):
        logits, cache = model.decode(params, cache, db, POLICY)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["index"]) == T + 2


@pytest.mark.parametrize("arch", ["rwkv6-1.6b",
                                  pytest.param("zamba2-2.7b",
                                               marks=pytest.mark.slow)])
def test_ssm_prefill_decode_consistency(arch):
    """For recurrent archs: prefill-then-decode == decode-everything."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    pol = QuantPolicy.exact()           # exact mode: paths must agree closely
    toks = jax.random.randint(key, (B, 4), 0, cfg.vocab_size)

    lg_a, cache = model.prefill(params, {"tokens": toks}, pol, max_seq=8)
    # token-by-token decode path
    cache_b = model.init_cache(cfg, B, 8)
    lg_b = None
    for t in range(4):
        lg_b, cache_b = model.decode(params, cache_b,
                                     {"tokens": toks[:, t:t + 1]}, pol)
    a = lg_a[:, -1, :cfg.vocab_size]
    b = lg_b[:, -1, :cfg.vocab_size]
    assert float(jnp.max(jnp.abs(a - b))) < 5e-3 * (
        1 + float(jnp.max(jnp.abs(a)))), arch


def test_input_specs_cover_grid():
    """Every (arch x shape) cell provides well-formed abstract inputs."""
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        model = build_model(cfg)
        cells = shape_grid(cfg)
        kinds = {s.kind for s in cells}
        assert "train" in kinds and "decode" in kinds
        if cfg.is_subquadratic:
            assert any(s.name == "long_500k" for s in cells)
        else:
            assert all(s.name != "long_500k" for s in cells)
        for shape in cells:
            specs = model.input_specs(shape)
            assert "batch" in specs
            for leaf in jax.tree.leaves(specs):
                assert hasattr(leaf, "shape") and hasattr(leaf, "dtype")
            if shape.kind == "decode":
                assert "cache" in specs


def test_vocab_padding():
    cfg = get_config("granite-3-2b")
    assert cfg.vocab_size == 49155
    assert cfg.padded_vocab % 256 == 0
    assert cfg.padded_vocab >= cfg.vocab_size


def test_smoke_loss_decreases_quickly():
    """One arch: a few FQT steps on learnable synthetic data reduce loss."""
    from repro.launch.train import train_loop
    cfg = get_config("statquant-tx", smoke=True)
    _, _, hist = train_loop(cfg, QuantPolicy.fqt("psq", 6, bhq_block=16),
                            steps=30, batch_size=4, seq_len=16, lr=5e-3,
                            log_every=29, log_fn=lambda *a: None)
    first, last = hist[0][1], hist[-1][1]
    assert last < first - 0.1, (first, last)
