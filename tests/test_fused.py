"""Fused quantize->GEMM->epilogue megakernels + the tiling autotuner.

Three layers of evidence:

  * kernel level — the Pallas megakernels (interpret mode) and their XLA
    twins against the *composed* oracle (quantize to a QTensor, int8 GEMM,
    affine epilogue) on ragged shapes.  Tolerances are fp32-roundoff tight:
    both sides consume bit-identical codes (same ``bits * 2^-32`` SR
    uniforms), so the only difference is accumulation order.
  * integration level — value + gradient parity of the full ``_fqt``
    custom_vjp under ``fused=True`` across simulate/native/pallas, and a
    *tight* fused-vs-unfused check on the native backend (same codes, same
    f32 accumulation — this is the bit-identical-SR evidence: a single
    differing uniform would shift a code by a full bin).
  * autotuner — sweep/persist/lookup plumbing with a fake timer and a
    tmpdir cache, including corrupt-cache fallback and lookup precedence.
"""

import importlib
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantPolicy, fqt_matmul
from repro.core.backend import (affine_factors, apply_epilogue,
                                epilogue_coeffs, requantize_det)
from repro.core.quantizers import (quantize_psq_stoch, quantize_ptq_det,
                                   quantize_ptq_stoch)
# the package re-exports the autotune *function*; import the module itself
at = importlib.import_module("repro.kernels.autotune")
from repro.kernels.fused_fqt import (fused_qboth_tn_matmul,
                                     fused_qboth_tn_matmul_xla,
                                     fused_qlhs_matmul, fused_qlhs_matmul_xla)
from repro.kernels.q8_matmul import q8_matmul
from repro.kernels.quantize_sr import quantize_sr_rows
from repro.kernels.tiling import pad2d_edge

RAGGED = [(33, 17, 9), (64, 128, 32)]
RAGGED_SLOW = [(130, 70, 258)]


def _compose(aq, b8, alpha_b, beta_b, trans_b=False):
    """The unfused reference: materialized codes -> GEMM -> epilogue."""
    a8 = aq.int8_codes.reshape(-1, aq.shape[-1])
    alpha_a, beta_a = affine_factors(aq.scale, aq.zero, aq.bits)
    bt = (b8.T if trans_b else b8)
    coeffs = epilogue_coeffs(a8, alpha_a, beta_a, bt, alpha_b, beta_b)
    acc = a8.astype(jnp.float32) @ bt.astype(jnp.float32)
    return apply_epilogue(acc, *coeffs)


def _fwd_case(mkn):
    M, K, N = mkn
    kx, kw, kg = jax.random.split(jax.random.PRNGKey(M * 7 + N), 3)
    x = jax.random.normal(kx, (M, K))
    w = jax.random.normal(kw, (K, N)) * 0.3
    g = jax.random.normal(kg, (M, N)) * 2.0
    return x, w, g


def _check_fwd(mkn):
    M, K, N = mkn
    x, w, _ = _fwd_case(mkn)
    wq = quantize_ptq_det(w, 8)
    w8 = wq.int8_codes
    ab, bb = affine_factors(wq.scale, wq.zero, wq.bits)
    xq = quantize_ptq_det(x, 8)
    sa = jnp.broadcast_to(xq.scale, (M, 1))
    za = jnp.broadcast_to(xq.zero, (M, 1))
    u = (ab * jnp.sum(w8.astype(jnp.int32), axis=0).astype(jnp.float32)
         + float(K) * bb)
    want = _compose(xq, w8, ab, bb)
    got_xla = fused_qlhs_matmul_xla(x, sa, za, None, w8, ab, bb, u, bits=8)
    got_pl = fused_qlhs_matmul(x, sa, za, None, w8, ab, bb, u, bits=8,
                               interpret=True)
    np.testing.assert_allclose(got_xla, want, rtol=2e-6, atol=2e-5)
    np.testing.assert_allclose(got_pl, want, rtol=2e-6, atol=2e-5)


def _check_dx(mkn):
    """SR LHS (per-row PSQ scales) against W.T — bit-identical uniforms."""
    M, K, N = mkn
    _, w, g = _fwd_case(mkn)
    wq = quantize_ptq_det(w, 8)
    w8 = wq.int8_codes
    ab, bb = affine_factors(wq.scale, wq.zero, wq.bits)
    kk = jax.random.PRNGKey(M * 13 + N)
    gq = quantize_psq_stoch(g, kk, 6)
    rbits = jax.random.bits(kk, g.shape, jnp.uint32)
    B = float((1 << 6) - 1)
    zg = jnp.min(g, axis=-1, keepdims=True)
    sg = B / jnp.maximum(jnp.max(g, axis=-1, keepdims=True) - zg, 1e-12)
    u = (ab * jnp.sum(w8.astype(jnp.int32), axis=1).astype(jnp.float32)
         + float(N) * bb)
    want = _compose(gq, w8, ab, bb, trans_b=True)
    got_xla = fused_qlhs_matmul_xla(g, sg, zg, rbits, w8, ab, bb, u,
                                    bits=6, trans_b=True)
    got_pl = fused_qlhs_matmul(g, sg, zg, rbits, w8, ab, bb, u, bits=6,
                               trans_b=True, interpret=True)
    np.testing.assert_allclose(got_xla, want, rtol=2e-6, atol=2e-5)
    np.testing.assert_allclose(got_pl, want, rtol=2e-6, atol=2e-5)


def _check_dw(mkn):
    """TN megakernel: det A + SR B quantized inside the contraction sweep."""
    M, K, N = mkn
    x, _, g = _fwd_case(mkn)
    kk = jax.random.PRNGKey(M * 29 + N)
    gq1 = quantize_ptq_stoch(g, kk, 8)
    rbits = jax.random.bits(kk, g.shape, jnp.uint32)
    xq = quantize_ptq_det(x, 8)
    aa, _ = affine_factors(xq.scale, xq.zero, 8)
    ag, bg = affine_factors(gq1.scale, gq1.zero, 8)
    coeffs = epilogue_coeffs(xq.int8_codes.T, aa,
                             affine_factors(xq.scale, xq.zero, 8)[1],
                             gq1.int8_codes, ag, bg)
    want = apply_epilogue(
        xq.int8_codes.astype(jnp.float32).T
        @ gq1.int8_codes.astype(jnp.float32), *coeffs)
    a_vec = (aa * bg) * jnp.sum(xq.int8_codes.astype(jnp.float32), axis=0)
    got_xla = fused_qboth_tn_matmul_xla(x, xq.scale, xq.zero, g, gq1.scale,
                                        gq1.zero, rbits, a_vec,
                                        bits_a=8, bits_b=8)
    got_pl = fused_qboth_tn_matmul(x, xq.scale, xq.zero, g, gq1.scale,
                                   gq1.zero, rbits, a_vec, bits_a=8,
                                   bits_b=8, interpret=True)
    np.testing.assert_allclose(got_xla, want, rtol=2e-6, atol=2e-4)
    np.testing.assert_allclose(got_pl, want, rtol=2e-6, atol=2e-4)


@pytest.mark.parametrize("mkn", RAGGED)
def test_fused_fwd_vs_composed(mkn):
    _check_fwd(mkn)


@pytest.mark.parametrize("mkn", RAGGED)
def test_fused_dx_vs_composed(mkn):
    _check_dx(mkn)


@pytest.mark.parametrize("mkn", RAGGED)
def test_fused_dw_vs_composed(mkn):
    _check_dw(mkn)


@pytest.mark.slow
@pytest.mark.parametrize("mkn", RAGGED_SLOW)
def test_fused_kernels_vs_composed_slow(mkn):
    _check_fwd(mkn)
    _check_dx(mkn)
    _check_dw(mkn)


def test_requantize_det_bit_identical():
    """The fused forward's residual contract: (x, scale, zero) rebuilds the
    exact codes the unfused path would have materialized."""
    x = jax.random.normal(jax.random.PRNGKey(3), (37, 21))
    xq = quantize_ptq_det(x, 8)
    re = requantize_det(x, xq.scale, xq.zero, 8)
    np.testing.assert_array_equal(np.asarray(xq.codes), np.asarray(re.codes))


# ---------------------------------------------------------------------------
# Integration: the full custom_vjp under fused=True
# ---------------------------------------------------------------------------

def _value_and_grads(pol, x, w, key):
    y = fqt_matmul(x, w, key, pol)
    gx, gw = jax.grad(
        lambda a, b: jnp.sum(fqt_matmul(a, b, key, pol) ** 2), (0, 1))(x, w)
    return y, gx, gw


@pytest.mark.parametrize("quant", ["ptq", "psq"])
def test_fqt_fused_gradient_parity(quant):
    m, k, n = 33, 17, 9
    kx, kw, kk = jax.random.split(jax.random.PRNGKey(m), 3)
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n)) * 0.3
    ref = _value_and_grads(
        QuantPolicy.fqt(quant, 5, backend="simulate"), x, w, kk)
    for backend in ("native", "pallas"):
        pol = QuantPolicy.fqt(quant, 5, backend=backend,
                              pallas_interpret=True, fused=True)
        out = _value_and_grads(pol, x, w, kk)
        for nm, got, want in zip(("y", "dx", "dw"), out, ref, strict=True):
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-3, atol=5e-3,
                err_msg=f"{backend}/fused/{quant}/{nm}")
    # fused vs unfused on the same backend: bit-identical codes (same SR
    # uniforms), f32 accumulation both sides -> roundoff-tight
    a = _value_and_grads(
        QuantPolicy.fqt(quant, 5, backend="native", fused=True), x, w, kk)
    b = _value_and_grads(
        QuantPolicy.fqt(quant, 5, backend="native", fused=False), x, w, kk)
    for nm, got, want in zip(("y", "dx", "dw"), a, b, strict=True):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-5, atol=5e-4,
                                   err_msg=f"tight fused-vs-unfused {nm}")


def test_fqt_fused_bhq_falls_back():
    """BHQ has no fused agrad kernel — the role falls back to the unfused
    path inside the same backward and still matches simulate."""
    m, k, n = 32, 16, 8
    kx, kw, kk = jax.random.split(jax.random.PRNGKey(5), 3)
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n)) * 0.3
    ref = _value_and_grads(
        QuantPolicy.fqt("bhq", 5, backend="simulate", bhq_block=16),
        x, w, kk)
    out = _value_and_grads(
        QuantPolicy.fqt("bhq", 5, backend="native", bhq_block=16,
                        fused=True), x, w, kk)
    for nm, got, want in zip(("y", "dx", "dw"), out, ref, strict=True):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-3, atol=5e-3, err_msg=nm)


def test_fqt_fused_qat_remat():
    """QAT under fused=True: forward fuses, backward rematerializes the
    activation codes from the (x, scale, zero) residuals."""
    m, k, n = 33, 17, 9
    kx, kw, kk = jax.random.split(jax.random.PRNGKey(9), 3)
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n)) * 0.3
    ref = _value_and_grads(QuantPolicy.qat(backend="simulate"), x, w, kk)
    out = _value_and_grads(
        QuantPolicy.qat(backend="native", fused=True), x, w, kk)
    for nm, got, want in zip(("y", "dx", "dw"), out, ref, strict=True):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-3, atol=5e-3, err_msg=nm)


# ---------------------------------------------------------------------------
# Validation errors
# ---------------------------------------------------------------------------

def test_q8_matmul_contraction_mismatch():
    x8 = jnp.zeros((8, 16), jnp.int8)
    y8 = jnp.zeros((17, 8), jnp.int8)
    v = jnp.zeros((8,)), jnp.zeros((8,))
    with pytest.raises(ValueError, match="contraction mismatch"):
        q8_matmul(x8, y8, v[0], v[1], v[0], v[1], v[0], v[1])


def test_q8_matmul_rejects_misaligned_tiles():
    x8 = jnp.zeros((64, 256), jnp.int8)
    y8 = jnp.zeros((256, 128), jnp.int8)
    m = jnp.zeros((64,))
    n = jnp.zeros((128,))
    with pytest.raises(ValueError) as ei:
        q8_matmul(x8, y8, m, n, m, n, m, n, bm=48, bn=128, bk=128)
    msg = str(ei.value)
    assert "64x256x128" in msg and "48" in msg  # shape + tile in message
    # interpret mode lifts the MXU alignment requirement
    q8_matmul(x8, y8, m, n, m, n, m, n, bm=48, bn=128, bk=128,
              interpret=True)


def test_q8_matmul_rejects_nonpositive_tiles():
    x8 = jnp.zeros((8, 128), jnp.int8)
    y8 = jnp.zeros((128, 128), jnp.int8)
    m = jnp.zeros((8,))
    n = jnp.zeros((128,))
    with pytest.raises(ValueError, match="positive"):
        q8_matmul(x8, y8, m, n, m, n, m, n, bm=0, interpret=True)


@pytest.mark.parametrize("bits", [1, 9, 0])
def test_bits_range_rejected(bits):
    x = jnp.zeros((8, 16))
    rb = jnp.zeros((8, 16), jnp.uint32)
    with pytest.raises(ValueError, match="bits"):
        quantize_sr_rows(x, rb, bits=bits, interpret=True)


def test_fused_qlhs_contraction_mismatch():
    x = jnp.zeros((8, 16))
    w8 = jnp.zeros((17, 8), jnp.int8)
    s = jnp.ones((8, 1))
    with pytest.raises(ValueError, match="contraction mismatch"):
        fused_qlhs_matmul_xla(x, s, s, None, w8, 1.0, 0.0,
                              jnp.zeros((8,)), bits=8)


# ---------------------------------------------------------------------------
# pad2d_edge / ragged-shape range regression
# ---------------------------------------------------------------------------

def test_pad2d_edge_is_range_inert():
    x = jnp.arange(1., 13.).reshape(3, 4)
    p = pad2d_edge(x, 5, 7)
    assert p.shape == (5, 7)
    np.testing.assert_array_equal(np.asarray(jnp.max(p, axis=1)[:3]),
                                  np.asarray(jnp.max(x, axis=1)))
    # zero padding would have dragged per-row min to 0 for these rows
    np.testing.assert_array_equal(np.asarray(jnp.min(p, axis=1)[:3]),
                                  np.asarray(jnp.min(x, axis=1)))
    # padded tail replicates the last real row — per-tensor range unchanged
    assert float(jnp.min(p)) == float(jnp.min(x))
    assert float(jnp.max(p)) == float(jnp.max(x))
    with pytest.raises(ValueError, match="edge-pad"):
        pad2d_edge(jnp.zeros((0, 4)), 5, 7)


def test_quantize_sr_rows_ragged_positive_rows():
    """Regression: per-row min/max inside the kernel must see edge padding,
    not zeros — all-positive rows at a ragged (non-lane-multiple) width
    would otherwise get min=0 and shifted codes."""
    key = jax.random.PRNGKey(11)
    x = jax.random.uniform(key, (5, 33)) + 2.0        # strictly positive
    rbits = jax.random.bits(key, x.shape, jnp.uint32)
    c8, scale, zero = quantize_sr_rows(x, rbits, bits=8, interpret=True)
    # oracle: the unfused per-row PSQ math on the unpadded input
    B = 255.0
    lo = jnp.min(x, axis=-1, keepdims=True)
    hi = jnp.max(x, axis=-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(zero).reshape(-1, 1),
                               np.asarray(lo), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(scale).reshape(-1, 1),
                               np.asarray(B / jnp.maximum(hi - lo, 1e-12)),
                               rtol=1e-6)
    t = jnp.asarray(scale).reshape(-1, 1) * (x - lo)
    u01 = rbits.astype(jnp.float32) * (1.0 / 4294967296.0)
    want = jnp.clip(jnp.floor(t + u01), 0.0, B) - 128.0
    np.testing.assert_array_equal(np.asarray(c8, dtype=np.int32),
                                  np.asarray(want, dtype=np.int32))


# ---------------------------------------------------------------------------
# Autotuner
# ---------------------------------------------------------------------------

@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    path = tmp_path / "tuning.json"
    monkeypatch.setenv(at.ENV_CACHE, str(path))
    at.reset_cache()
    yield path
    at.reset_cache()


def test_autotune_picks_fastest_and_persists(tmp_cache):
    calls = []

    def fake_timer(tiles):
        calls.append(tiles)
        return {(32, 128, 128): 50.0, (64, 128, 128): 10.0,
                (128, 128, 128): 99.0}[tiles]

    best = at.autotune("q8_matmul", (64, 128, 128), fake_timer,
                       candidates=[(32, 128, 128), (64, 128, 128),
                                   (128, 128, 128)])
    assert best == (64, 128, 128)
    assert len(calls) == 3
    assert tmp_cache.exists()
    # a fresh cache object reads the persisted winner back
    at.reset_cache()
    assert at.lookup_tiles("q8_matmul", (64, 128, 128)) == (64, 128, 128)
    data = json.loads(tmp_cache.read_text())
    [key] = data
    assert key.startswith("q8_matmul/64x128x128/int8/")
    assert data[key]["us_per_call"] == 10.0


def test_autotune_skips_raising_candidates(tmp_cache):
    def flaky(tiles):
        if tiles[0] == 32:
            raise RuntimeError("bad tile")
        return 1.0

    best = at.autotune("q8_matmul", (64, 128, 128), flaky,
                       candidates=[(32, 128, 128), (64, 128, 128)])
    assert best == (64, 128, 128)
    with pytest.raises(ValueError, match="every candidate failed"):
        at.autotune("q8_matmul", (64, 128, 128),
                    lambda t: (_ for _ in ()).throw(RuntimeError("x")),
                    candidates=[(32, 128, 128)])


def test_corrupt_cache_falls_back(tmp_cache):
    tmp_cache.write_text("{not json")
    at.reset_cache()
    with pytest.warns(UserWarning, match="corrupt tuning cache"):
        tiles = at.lookup_tiles("q8_matmul", (512, 1024, 1024))
    # shipped default still reachable through the degraded cache
    assert tiles == at.SHIPPED_DEFAULTS["q8_matmul/512x1024x1024"]


def test_lookup_precedence(tmp_cache):
    shape = (512, 1024, 1024)
    # shipped default applies with an empty cache
    assert at.lookup_tiles("q8_matmul", shape) == \
        at.SHIPPED_DEFAULTS["q8_matmul/512x1024x1024"]
    # platform-agnostic "any" beats shipped
    at.record_tiles("q8_matmul", shape, (64, 128, 128), platform="any")
    assert at.lookup_tiles("q8_matmul", shape) == (64, 128, 128)
    # platform-specific beats "any"
    at.record_tiles("q8_matmul", shape, (32, 256, 128),
                    platform=jax.default_backend())
    assert at.lookup_tiles("q8_matmul", shape) == (32, 256, 128)
    # unknown shape/kernel falls through to the caller's default
    assert at.lookup_tiles("q8_matmul", (7, 7, 7), default=(1, 2, 3)) == \
        (1, 2, 3)


def test_tile_candidates_respect_budget():
    cands = at.tile_candidates(4096, 4096, 4096, kind="fused_tn")
    assert cands
    for bm, bn, bk in cands:
        assert at.tile_vmem_bytes(bm, bn, bk, "fused_tn") \
            <= at.VMEM_BUDGET_BYTES
        assert bn % 128 == 0 and bk % 128 == 0
    # small problems only get tiles that fit them (rounded up)
    small = at.tile_candidates(16, 128, 128)
    assert all(bm <= 32 for bm, _, _ in small)


def test_vmem_accounting_matches_bench_row():
    bm, bn, bk = 128, 512, 512
    vecs = 4 * (2 * bm + 3 * bn)
    q8 = bm * bk + bk * bn + 8 * bm * bn + vecs
    assert at.q8_tile_vmem_bytes(bm, bn, bk) == q8
    # the fused LHS tile holds f32 X + uint32 bits instead of int8 X
    assert at.q8_tile_vmem_bytes(bm, bn, bk, fused=True) > q8
    assert at.q8_tile_vmem_bytes(bm, bn, bk, fused=True) \
        <= at.VMEM_BUDGET_BYTES


@pytest.mark.slow
def test_tune_sweep_plumbing(tmp_cache):
    """End-to-end --tune on the tiny non-TPU shape: sweeps interpret-mode
    Pallas kernels, persists winners, and lookup_tiles serves them."""
    from benchmarks.bench_kernels import tune
    winners = tune(log=lambda *a, **k: None, iters=1)
    assert winners
    at.reset_cache()
    for key_name, tiles in winners.items():
        kernel, shape = key_name.split("/")
        dims = tuple(int(d) for d in shape.split("x"))
        assert at.lookup_tiles(kernel, dims) == tuple(tiles)
