"""Optimizers, schedules, data pipeline determinism, prefetch."""

import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import Prefetcher, ShardedLoader, SyntheticLM, make_batch_for
from repro.optim import (adamw, clip_by_global_norm, cosine_schedule,
                         global_norm, sgd)


def test_sgd_momentum_matches_reference():
    """Hand-rolled momentum recursion vs the optimizer."""
    opt = sgd(momentum=0.9)
    p = {"w": jnp.ones((3,))}
    st = opt.init(p)
    g = {"w": jnp.asarray([1.0, 2.0, 3.0])}
    mu = np.zeros(3)
    w = np.ones(3)
    for _ in range(5):
        p, st = opt.apply(p, g, st, 0.1)
        mu = 0.9 * mu + np.asarray(g["w"])
        w = w - 0.1 * mu
    np.testing.assert_allclose(np.asarray(p["w"]), w, rtol=1e-6)


def test_adamw_converges_quadratic():
    opt = adamw(weight_decay=0.0)
    p = {"w": jnp.asarray(5.0)}
    st = opt.init(p)
    for _ in range(300):
        g = {"w": 2.0 * p["w"]}
        p, st = opt.apply(p, g, st, 0.05)
    assert abs(float(p["w"])) < 0.05


def test_adamw_weight_decay_decoupled():
    opt = adamw(weight_decay=0.5)
    p = {"w": jnp.asarray(1.0)}
    st = opt.init(p)
    p2, _ = opt.apply(p, {"w": jnp.asarray(0.0)}, st, 0.1)
    # zero gradient: only decay acts: w -= lr * wd * w
    assert float(p2["w"]) == pytest.approx(1.0 - 0.1 * 0.5 * 1.0, rel=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, 100, warmup_steps=10)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0, abs=1e-6)
    assert float(lr(55)) < 1.0
    assert float(lr(100)) == pytest.approx(0.0, abs=1e-6)
    # monotone rise through warmup
    assert float(lr(5)) == pytest.approx(0.5, abs=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((9,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # no-op when under the limit
    clipped2, _ = clip_by_global_norm(g, 1e9)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), np.asarray(g["a"]))


def test_synthetic_determinism_and_host_disjointness():
    ds = SyntheticLM(vocab_size=101, seq_len=16, batch_size=4, seed=3)
    a = ds.batch(step=5, host=0)
    b = ds.batch(step=5, host=0)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = ds.batch(step=5, host=1)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    d = ds.batch(step=6, host=0)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(d["tokens"]))
    # labels are next-token shifted
    full_a = ds.batch(step=5, host=0)
    assert full_a["labels"].shape == full_a["tokens"].shape


def test_make_batch_for_families():
    from repro.configs import get_config
    for arch, key_name in [("qwen2-vl-2b", "embeds"),
                           ("whisper-medium", "frames"),
                           ("minitron-4b", "tokens")]:
        cfg = get_config(arch, smoke=True)
        b = make_batch_for(cfg, 2, 8)
        assert key_name in b and "labels" in b


def test_prefetcher_orders_and_stops():
    loader = ShardedLoader(lambda s: {"step": jnp.asarray(s)})
    pf = Prefetcher(loader, depth=2, start_step=3)
    assert int(pf.next()["step"]) == 3
    assert int(pf.next()["step"]) == 4
    pf.stop()


def test_prefetcher_propagates_errors():
    def bad(step):
        if step >= 1:
            raise RuntimeError("boom")
        return {"x": jnp.zeros(1)}
    pf = Prefetcher(ShardedLoader(bad), depth=1)
    pf.next()
    with pytest.raises(RuntimeError):
        pf.next()
    pf.stop()
