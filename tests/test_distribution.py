"""Distribution tests on 8 placeholder devices.

These run in SUBPROCESSES because XLA_FLAGS device-count must be set before
jax initializes, and the assignment forbids setting it globally for the test
session (smoke tests must see 1 device).
"""

import os
import subprocess
import sys


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, timeout=900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_sharded_train_step_compiles_and_runs():
    """Smoke config, 2x4 (data, model) mesh: the full sharded train step
    (FQT + SP + sdpa hint) compiles AND executes with finite loss."""
    out = run_sub("""
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.core import QuantPolicy
from repro.engine import (abstract_train_state, init_train_state,
                          jit_step, make_step_fn)
from repro.models import build_model
from repro.optim import sgd
from repro.sharding import make_plan
from repro.launch.mesh import make_test_mesh
from repro.data import make_batch_for

mesh = make_test_mesh(2, 4)
plan = make_plan(mesh)
cfg = get_config("granite-3-2b", smoke=True)
model = build_model(cfg)
pol = QuantPolicy.fqt("bhq", 5, bhq_block=16)
opt = sgd(0.9)
state = init_train_state(model, opt, seed=0)
batch = make_batch_for(cfg, 4, 16)
astate = abstract_train_state(model, opt)
step = make_step_fn(model, pol, opt, lambda s: 1e-3, remat=True,
                    loss_kwargs={"sdpa_hint": plan.attn_shardings})
with mesh:
    jf = jit_step(step, plan=plan, abstract_state=astate)
    state2, mets = jf(state, batch)
assert bool(jnp.isfinite(mets["loss"])), mets
assert int(state2.step) == 1
assert jax.tree.leaves(state2.params)[0].sharding.mesh == mesh
print("LOSS", float(mets["loss"]))
""")
    assert "LOSS" in out


def test_compressed_allreduce_unbiased_int8_wire():
    out = run_sub("""
import inspect
import jax, jax.numpy as jnp, re
from jax.sharding import PartitionSpec as P
from repro.core.compression import compressed_psum
from repro.launch.mesh import mesh_kwargs
mesh = jax.make_mesh((8,), ("pod",), **mesh_kwargs(1))
gw = jax.random.normal(jax.random.PRNGKey(0), (8, 33, 7))
def run(gl, key):
    return compressed_psum(gl[0], key[0], "pod", bits=8)[None] / 8
shard_map = getattr(jax, "shard_map", None)
if shard_map is None:
    from jax.experimental.shard_map import shard_map
params = inspect.signature(shard_map).parameters
nocheck = ({"check_vma": False} if "check_vma" in params
           else {"check_rep": False})
f = jax.jit(shard_map(run, mesh=mesh, in_specs=(P("pod"), P("pod")),
                      out_specs=P("pod"), **nocheck))
ks = jax.random.split(jax.random.PRNGKey(2), 8)
out = f(gw, ks)
exact = jnp.mean(gw, axis=0)
rel = float(jnp.max(jnp.abs(out - exact[None])) / jnp.max(jnp.abs(exact)))
assert rel < 0.05, rel
outs = [f(gw, jax.random.split(jax.random.PRNGKey(100+s), 8))[0] for s in range(48)]
m = jnp.mean(jnp.stack(outs), 0)
bias = float(jnp.max(jnp.abs(m - exact)))
sem = float(jnp.max(jnp.std(jnp.stack(outs), 0))) / (48 ** 0.5)
assert bias < 6 * sem + 1e-3, (bias, sem)
hlo = f.lower(gw, ks).compile().as_text()
assert re.search(r"= s8.*all-gather", hlo), "int8 must be on the wire"
print("OK rel", rel)
""")
    assert "OK" in out


def test_plan_divisibility_all_archs():
    """Every full-config param shards evenly on a model=4 mesh axis; specs
    never request non-divisible sharding."""
    out = run_sub("""
import jax, jax.numpy as jnp
from repro.configs import ARCH_NAMES, get_config
from repro.models import build_model
from repro.sharding import make_plan
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh(2, 4)
plan = make_plan(mesh)
for arch in ARCH_NAMES:
    cfg = get_config(arch)                  # FULL configs
    model = build_model(cfg)
    ap = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = plan.param_specs(ap)
    flat_p = jax.tree_util.tree_leaves_with_path(ap)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "index"))
    import jax.sharding as shd
    for (path, leaf), spec in zip(flat_p, flat_s, strict=True):
        for dim, ax in zip(leaf.shape, tuple(spec), strict=False):
            if ax is not None:
                size = mesh.shape[ax] if isinstance(ax, str) else 1
                assert dim % size == 0, (arch, path, leaf.shape, spec)
print("DIVISIBLE")
""")
    assert "DIVISIBLE" in out


def test_elastic_restore_across_meshes(tmp_path):
    """Save sharded state on a 2x4 mesh, restore onto 4x2 and 8x1 — elastic."""
    out = run_sub(f"""
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import CheckpointManager
from repro.sharding import make_plan
from repro.launch.mesh import make_test_mesh
from jax.sharding import NamedSharding, PartitionSpec as P

tree = {{"w": jax.random.normal(jax.random.PRNGKey(0), (8, 16))}}
mesh_a = make_test_mesh(2, 4)
sh_a = NamedSharding(mesh_a, P("data", "model"))
placed = jax.device_put(tree["w"], sh_a)
ckpt = CheckpointManager("{tmp_path}")
ckpt.save(1, {{"w": placed}})
for shape in [(4, 2), (8, 1), (1, 8)]:
    mesh_b = make_test_mesh(*shape)
    sh_b = NamedSharding(mesh_b, P("data", "model"))
    out = ckpt.restore(1, {{"w": jax.ShapeDtypeStruct((8, 16), jnp.float32)}},
                       shardings={{"w": sh_b}})
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert out["w"].sharding == sh_b
print("ELASTIC")
""")
    assert "ELASTIC" in out


def test_production_mesh_shapes():
    """make_production_mesh on 512 fake devices (separate process)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", """
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
assert dict(m1.shape) == {"data": 16, "model": 16}, m1.shape
m2 = make_production_mesh(multi_pod=True)
assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}, m2.shape
print("MESH OK")
"""], env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MESH OK" in out.stdout
