"""repro.analysis: contract auditor, range analysis, kernel checker, lint.

The auditor's own correctness is established adversarially: the mutation
self-test plants a raw ``jnp.dot`` in an MLP and the audit must turn red
*naming that layer path*, then recover green at 100% coverage on the
unmutated tree.  Range bounds are cross-checked against brute-force
extreme-value integer GEMMs (real int32/int16 wraparound, not a model of
it).
"""

import functools
import importlib
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (RetraceGuard, audit_fn, audit_model, audit_step,
                            check_donation, check_kernels, lint_source,
                            lint_tree, mutation_selftest)
from repro.analysis.kernels import purge_bad_entries
from repro.analysis.ranges import (accumulator_bound, check_scale_inputs,
                                   headroom_bits, max_safe_k,
                                   signed_code_bound)
from repro.configs import get_config
from repro.core import QuantPolicy, fp_exempt, quant_scope
at = importlib.import_module("repro.kernels.autotune")

FQT8 = QuantPolicy.fqt("bhq", 8)

sd = jax.ShapeDtypeStruct
f32 = jnp.float32


# ---------------------------------------------------------------------------
# Auditor: clean trees across families and backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["simulate", "native", "pallas"])
def test_audit_lm_clean_all_backends(backend):
    cfg = get_config("statquant-tx", smoke=True)
    report = audit_model(cfg, QuantPolicy.fqt("bhq", 8, backend=backend))
    assert report.ok, report.format()
    assert report.coverage == 1.0
    # all three roles present and fully quantized
    roles = report.role_flops()
    assert set(roles) == {"fwd", "wgrad", "agrad"}
    assert all(v["policy_fp"] == 0.0 for v in roles.values())
    # the declared sdpa exemption is the only fp GEMM
    assert set(report.exemptions) == {"attn.sdpa"}


@pytest.mark.parametrize("arch", [
    pytest.param("whisper-medium", marks=pytest.mark.slow),
    "olmoe-1b-7b",
])
def test_audit_families_clean(arch):
    cfg = get_config(arch, smoke=True)
    report = audit_model(cfg, FQT8)
    assert report.ok, report.format()
    assert report.coverage == 1.0


def test_audit_exact_and_qat():
    cfg = get_config("statquant-tx", smoke=True)
    exact = audit_model(cfg, QuantPolicy.exact())
    assert exact.ok, exact.format()
    assert exact.flops("quantized") == 0.0

    qat = audit_model(cfg, QuantPolicy.qat())
    assert qat.ok, qat.format()
    roles = qat.role_flops()
    # QAT: forward quantized, both backward GEMMs declared full precision
    assert roles["fwd"]["policy_fp"] == 0.0
    assert roles["fwd"]["quantized"] > 0.0
    assert roles["wgrad"]["quantized"] == 0.0
    assert roles["agrad"]["quantized"] == 0.0


@pytest.mark.slow
def test_audit_engine_step_clean():
    cfg = get_config("statquant-tx", smoke=True)
    report = audit_step(cfg, FQT8)
    assert report.ok, report.format()
    assert report.coverage == 1.0


def test_mutation_selftest():
    cfg = get_config("statquant-tx", smoke=True)
    result = mutation_selftest(cfg, FQT8)
    assert result.ok, result.detail
    # red run names the leaked path explicitly
    assert any(v.path == result.target_path
               for v in result.mutated.violations)
    assert any(v.kind == "unmarked-gemm" for v in result.mutated.violations)
    assert result.clean.coverage == 1.0


# ---------------------------------------------------------------------------
# Auditor: violation taxonomy on synthetic functions
# ---------------------------------------------------------------------------

def test_audit_fn_flags_unmarked_gemm():
    def f(x, w):
        return x @ w

    report = audit_fn(f, (sd((4, 8), f32), sd((8, 4), f32)),
                      policy=FQT8, paths=(), grad_traced=False)
    assert not report.ok
    [v] = report.violations
    assert v.kind == "unmarked-gemm"
    assert "fp_exempt" in v.detail


def test_audit_fn_accepts_exempt_gemm():
    def f(x, w):
        with fp_exempt("test.block", "synthetic exemption for the test"):
            return x @ w

    report = audit_fn(f, (sd((4, 8), f32), sd((8, 4), f32)),
                      policy=FQT8, paths=(), grad_traced=False)
    assert report.ok, report.format()
    assert report.exemptions["test.block"].startswith("synthetic")
    assert report.coverage == 1.0            # no non-exempt GEMMs at all


def test_audit_fn_contract_mismatch_and_missing():
    def f(x, w):
        with quant_scope("p1", "fwd", quantized=False):  # graph says fp
            return x @ w

    report = audit_fn(f, (sd((4, 8), f32), sd((8, 4), f32)),
                      policy=FQT8, paths=("p1", "p2"), grad_traced=False)
    kinds = {(v.kind, v.path) for v in report.violations}
    # p1 runs fp while the policy resolves quantized; p2 never appears
    assert ("contract-mismatch", "p1") in kinds
    assert ("declared-missing", "p2") in kinds


def test_audit_fn_undeclared_path():
    def f(x, w):
        with quant_scope("ghost", "fwd", quantized=True):
            return x @ w

    report = audit_fn(f, (sd((4, 8), f32), sd((8, 4), f32)),
                      policy=FQT8, paths=(), grad_traced=False)
    assert any(v.kind == "undeclared-path" and v.path == "ghost"
               for v in report.violations)


# ---------------------------------------------------------------------------
# Range analysis vs brute-force extreme-value GEMMs
# ---------------------------------------------------------------------------

def test_max_safe_k_int8():
    assert signed_code_bound(8) == 128
    assert max_safe_k(8, 8) == 131071
    assert accumulator_bound(131071, 8, 8) <= 2**31 - 1
    assert accumulator_bound(131072, 8, 8) > 2**31 - 1
    assert headroom_bits(131071, 8, 8) >= 0.0 > headroom_bits(131072, 8, 8)


def test_int32_wraparound_at_predicted_k():
    """The bound is exact: K = max_safe_k is the last safe contraction for
    worst-case int8 codes; K+1 wraps the int32 accumulator in a real
    dot_general."""
    k_safe = max_safe_k(8, 8)
    dims = (((1,), (0,)), ((), ()))

    def worst(k):
        a = jnp.full((1, k), -128, jnp.int8)
        b = jnp.full((k, 1), -128, jnp.int8)
        return int(jax.lax.dot_general(
            a, b, dims, preferred_element_type=jnp.int32)[0, 0])

    assert worst(k_safe) == accumulator_bound(k_safe, 8, 8)   # no wrap
    assert worst(k_safe + 1) < 0                              # wrapped


def test_int32_wraparound_at_predicted_k_asymmetric_8x4():
    """Same exactness for the W4A8-style asymmetric pair: max_safe_k(8, 4)
    is the last safe contraction for int8 codes against worst-case 4-bit
    codes (|c| = 8), and K+1 wraps for real."""
    k_safe = max_safe_k(8, 4)
    assert k_safe == (2**31 - 1) // (128 * 8) == 16 * max_safe_k(8, 8) + 15
    dims = (((1,), (0,)), ((), ()))

    def worst(k):
        a = jnp.full((1, k), -128, jnp.int8)
        b = jnp.full((k, 1), -signed_code_bound(4), jnp.int8)
        return int(jax.lax.dot_general(
            a, b, dims, preferred_element_type=jnp.int32)[0, 0])

    assert worst(k_safe) == accumulator_bound(k_safe, 8, 4)   # no wrap
    assert worst(k_safe + 1) < 0                              # wrapped


def test_w4a8_widens_the_checked_bound():
    """check_sites bounds each role by the *policy* widths: a contraction
    that overflows an 8x8 agrad GEMM is certified safe once the weights go
    4-bit (W4A8), without retracing anything."""
    from repro.analysis import GemmSite
    from repro.analysis.ranges import check_sites

    k = max_safe_k(8, 8) + 1
    assert k <= max_safe_k(8, 4)
    site = GemmSite(primitive="dot_general", flops=2.0 * k, contract=k,
                    mult=1, lhs_dtype="float32", rhs_dtype="float32",
                    stack="q[layers.0.mlp|agrad]", kind="quantized",
                    path="layers.0.mlp", role="agrad", src="test", m=4, n=4)
    red = check_sites([site], QuantPolicy.fqt("bhq", 8))
    assert any(f.severity == "overflow" and not f.ok
               and (f.lhs_bits, f.rhs_bits) == (8, 8) for f in red)
    green = check_sites([site], QuantPolicy.fqt("bhq", 8, weight_bits=4))
    assert all(f.ok for f in green)
    assert any((f.lhs_bits, f.rhs_bits) == (8, 4) for f in green)


def test_int16_wraparound_brute_force_low_bits():
    """Same bound at 4 bits against a int16 accumulator, checked by numpy
    wraparound — exercises the acc_bits generality."""
    k_safe = max_safe_k(4, 4, acc_bits=16)
    assert k_safe == (2**15 - 1) // (8 * 8)
    prod = np.int16(signed_code_bound(4)) * np.int16(signed_code_bound(4))
    safe = np.full(k_safe, prod, np.int16).sum(dtype=np.int16)
    assert int(safe) == accumulator_bound(k_safe, 4, 4)
    wrapped = np.full(k_safe + 1, prod, np.int16).sum(dtype=np.int16)
    assert int(wrapped) < 0


def test_int2_int4_bounds_scale():
    # lower bitwidths buy quadratically more contraction headroom
    assert max_safe_k(4, 4) == (2**31 - 1) // 64
    assert max_safe_k(2, 2) == (2**31 - 1) // 4
    assert max_safe_k(4, 8) == (2**31 - 1) // (8 * 128)


def test_scale_degeneracy():
    flagged = check_scale_inputs([("w", 0.0), ("x", 1e-13), ("ok", 0.5)])
    assert len(flagged) == 2
    assert flagged[0].startswith("w:") and flagged[1].startswith("x:")


def test_range_check_rides_the_audit():
    """An int-dtype GEMM with K over the bound turns the audit red even
    when the marker contract is satisfied."""
    k_bad = max_safe_k(8, 8) + 1

    def f(a, b):
        with fp_exempt("test.intgemm", "stress the accumulator bound"):
            return jax.lax.dot_general(
                a, b, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)

    report = audit_fn(f, (sd((1, k_bad), jnp.int8), sd((k_bad, 1), jnp.int8)),
                      policy=FQT8, paths=(), grad_traced=False)
    assert not report.ok
    assert any(f_.severity == "overflow" and not f_.ok
               for f_ in report.range_findings)


# ---------------------------------------------------------------------------
# Kernel tile checker + hardened cache loading
# ---------------------------------------------------------------------------

@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    path = tmp_path / "tuning.json"
    monkeypatch.setenv(at.ENV_CACHE, str(path))
    at.reset_cache()
    yield path
    at.reset_cache()


_BAD_CACHE = {
    # legal: aligned, under budget
    "q8_matmul/64x128x128/int8/any": {"bm": 64, "bn": 128, "bk": 128},
    # illegal: bm not a multiple of 32 for the int8 A tile
    "q8_matmul/64x128x128/int8/cpu": {"bm": 48, "bn": 128, "bk": 128},
    # illegal: blows the 12 MiB VMEM budget (fused_tn accounting)
    "fused_dw/1024x512x1024/int8/any": {"bm": 512, "bn": 1024, "bk": 1024},
    # malformed entry shape
    "fused_fwd/512x1024x1024/int8/any": [128, 512, 512],
    # unknown kernel: kept by the loader, flagged stale by the checker
    "mystery_kernel/8x8x8/int8/any": {"bm": 8, "bn": 8, "bk": 8},
}


def test_loader_drops_illegal_entries_with_warning(tmp_cache):
    tmp_cache.write_text(json.dumps(_BAD_CACHE))
    at.reset_cache()
    with pytest.warns(UserWarning, match="dropped 3 illegal entries"):
        tiles = at.lookup_tiles("q8_matmul", (64, 128, 128))
    assert tiles == (64, 128, 128)           # the legal "any" entry survives
    # the illegal platform-specific entry was dropped, not served
    cache = at.get_cache()
    assert cache.lookup("q8_matmul/64x128x128/int8/cpu") is None
    assert cache.lookup("fused_dw/1024x512x1024/int8/any") is None
    # unknown-kernel entry is kept (forward compat)
    assert cache.lookup("mystery_kernel/8x8x8/int8/any") == (8, 8, 8)


def test_validate_entry():
    assert at.validate_entry("q8_matmul", (64, 128, 128)) == []
    assert at.validate_entry("nope", (64, 128, 128)) is None
    assert at.validate_entry("q8_matmul", (48, 128, 128))      # misaligned
    assert at.validate_entry("fused_dw", (512, 1024, 1024))    # over budget
    assert at.validate_entry("kv_dequant", (256, 0, 0)) == []
    assert at.validate_entry("kv_dequant", (256, 128, 0))      # bn must be 0


def test_kernel_checker_and_purge(tmp_cache):
    tmp_cache.write_text(json.dumps(dict(
        _BAD_CACHE, **{"q8_matmul/8x8": {"bm": 32, "bn": 128, "bk": 128}})))
    report = check_kernels(str(tmp_cache))
    assert not report.ok
    bad = {f.key for f in report.findings if f.severity == "error"}
    assert "q8_matmul/64x128x128/int8/cpu" in bad          # misaligned
    assert "fused_dw/1024x512x1024/int8/any" in bad        # over budget
    assert "fused_fwd/512x1024x1024/int8/any" in bad       # malformed
    assert "q8_matmul/8x8" in bad                          # bad key shape
    stale = {f.key for f in report.findings if f.severity == "stale"}
    assert "mystery_kernel/8x8x8/int8/any" in stale

    n = purge_bad_entries(report)
    assert n == 5
    clean = check_kernels(str(tmp_cache))
    assert clean.ok and clean.n_cache == 1                 # only the good one


def test_shipped_defaults_are_legal():
    report = check_kernels("/nonexistent/tuning.json")
    assert report.ok, report.format()
    assert report.n_shipped == len(at.SHIPPED_DEFAULTS)


# ---------------------------------------------------------------------------
# Retrace + donation guards
# ---------------------------------------------------------------------------

def test_retrace_guard():
    guard = RetraceGuard(jax.jit(lambda x: x * 2))
    x = jnp.ones((4,))
    guard(x)
    guard(x)
    guard.assert_no_retrace()                 # first compile is expected
    assert guard.compiles in ([0], [])        # [] only if cache pre-warmed

    guard(jnp.ones((8,)))                     # new shape => retrace
    assert guard.retraces == 1
    with pytest.raises(AssertionError, match="retraced on call"):
        guard.assert_no_retrace()


def test_check_donation_consumes_buffers():
    @functools.partial(jax.jit, donate_argnums=(0,))
    def step(state, dx):
        return jax.tree.map(lambda x: x + dx, state), jnp.sum(state["w"])

    state = {"w": jnp.ones((8, 8)), "m": jnp.zeros((8, 8))}
    (_new, _aux), report = check_donation(step, state, 1.0)
    assert report.n_donated == 2
    assert report.ok, report.detail


def test_check_donation_detects_dropped_donation():
    # no donation: the inputs stay alive and the report says so
    @jax.jit
    def step(state, dx):
        return jax.tree.map(lambda x: x + dx, state), 0.0

    state = {"w": jnp.ones((8, 8))}
    _, report = check_donation(step, state, 1.0)
    assert report.n_deleted == 0
    assert not report.ok
    assert "dropped" in report.detail


# ---------------------------------------------------------------------------
# Lint rules
# ---------------------------------------------------------------------------

def test_lint_repo_tree_is_clean():
    findings = lint_tree()
    assert findings == [], "\n".join(str(f) for f in findings)


def test_lint_rpr001_pathless_dense():
    src = """
def layer(p, x, key, policy):
    a = dense(p["w1"], x, key, policy, 1, "layers.up")      # ok
    b = dense(p["w2"], x, key, policy, 2)                   # missing path
    c = dense(p["w3"], x, key, policy, 3, path="")          # empty path
    d = fqt_matmul(x, p["w4"], key, policy)                 # missing path
    return a + b + c + d
"""
    rules = [f.rule for f in lint_source(src)]
    assert rules == ["RPR001", "RPR001", "RPR001"]


def test_lint_rpr002_raw_gemm():
    src = """
import jax.numpy as jnp

def bad(x, w):
    return jnp.einsum("ij,jk->ik", x, w) + x @ w

def good(x, w):
    with fp_exempt("m.block", "documented reason"):
        return jnp.dot(x, w) + x @ w
"""
    findings = lint_source(src)
    assert [f.rule for f in findings] == ["RPR002", "RPR002"]
    assert all(f.line == 5 for f in findings)


def test_lint_rpr003_nonliteral_exempt():
    src = """
def f(x, w, name):
    with fp_exempt("a." + name, "reason"):       # computed path
        return x @ w

def g(x, w):
    with fp_exempt("a.b"):                        # missing reason
        return x @ w

def h(x, w):
    with fp_exempt("a.c", SHARED_REASON):         # UPPER constant ok
        return x @ w
"""
    rules = [f.rule for f in lint_source(src)]
    assert rules == ["RPR003", "RPR003"]


def test_lint_syntax_error_reported():
    [f] = lint_source("def broken(:\n")
    assert f.rule == "RPR000"


def test_lint_kernel_mode_contract():
    src = """
import jax.numpy as jnp
from jax import lax

def good(a, b):
    return lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                           preferred_element_type=jnp.int32)

def widened(a, b):
    return lax.dot_general(a, b, (((1,), (0,)), ((), ())))  # implicit acc

def raw(a, b):
    return jnp.matmul(a, b) + a @ b
"""
    rules = [f.rule for f in lint_source(src, mode="kernel")]
    assert rules == ["RPR002", "RPR002", "RPR002"]
    # contract mode would also demand fp_exempt; kernel mode accepts a
    # bare dot_general as long as the accumulator dtype is explicit
    assert lint_source(
        "def f(a, b):\n"
        "    return dot_general(a, b, d,"
        " preferred_element_type=jnp.int32)\n", mode="kernel") == []


# ---------------------------------------------------------------------------
# CLI --format json
# ---------------------------------------------------------------------------

def test_cli_lint_json(capsys):
    from repro.analysis.__main__ import main
    rc = main(["lint", "--format", "json"])
    doc = json.loads(capsys.readouterr().out)
    assert doc["tool"] == "lint" and doc["ok"] == (rc == 0)
    assert isinstance(doc["findings"], list)


def test_cli_kernels_json(tmp_cache, capsys):
    from repro.analysis.__main__ import main
    rc = main(["kernels", "--format", "json"])
    doc = json.loads(capsys.readouterr().out)
    assert doc["tool"] == "kernels" and doc["ok"] == (rc == 0)
    for f in doc["findings"]:
        assert {"rule", "severity", "path", "detail"} <= set(f)
