"""Serving correctness suite (continuous batching + int8 KV cache).

Covers the ISSUE-4 acceptance surface:
  * prefill-vs-stepwise logit parity (exact to float tolerance; quantized
    forward within quantizer-noise tolerance) on all three backends
  * int8-KV vs fp32-KV perplexity drift on the smoke LM
  * scheduler invariants: no slot leak, mixed-length requests all complete,
    EOS eviction, deterministic output under a fixed seed (and invariant to
    the slot-pool size)
  * checkpoint-driven startup from an engine TrainState checkpoint
  * sampling semantics (greedy / temperature / top-k / padded vocab)

Pallas-backend cases run the kernels in interpret mode and are slow-marked
per repo convention.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import QuantPolicy
from repro.models import build_model
from repro.serve import ServeEngine
from repro.serve.sampling import sample_tokens, slot_keys

CFG = get_config("statquant-tx", smoke=True)
MODEL = build_model(CFG)
PARAMS = MODEL.init(jax.random.PRNGKey(0))
B, T = 2, 8


def stepwise_logits(policy, toks, quant_cache: bool, max_seq=None):
    """Feed the prompt one token at a time; return last logits."""
    b, t = toks.shape
    max_seq = max_seq or t + 2
    if quant_cache:
        cache = MODEL.init_cache_quant(CFG, b, max_seq)
    else:
        cache = MODEL.init_cache(CFG, b, max_seq)
        cache["index"] = jnp.zeros((b,), jnp.int32)
    step = jax.jit(lambda c, tok, pos: MODEL.decode(
        PARAMS, c, {"tokens": tok}, policy, positions=pos))
    pos = jnp.zeros((b,), jnp.int32)
    lg = None
    for i in range(t):
        lg, cache = step(cache, toks[:, i:i + 1], pos)
        pos = pos + 1
    return lg


def make_toks(key=1):
    return jax.random.randint(jax.random.PRNGKey(key), (B, T), 0,
                              CFG.vocab_size)


# ---------------------------------------------------------------------------
# Prefill vs stepwise decode parity
# ---------------------------------------------------------------------------

BACKENDS = [("simulate", ()), ("native", ()),
            ("pallas", (pytest.mark.slow,))]


@pytest.mark.parametrize("backend", [pytest.param(b, marks=m)
                                     for b, m in BACKENDS])
def test_prefill_stepwise_parity_exact(backend):
    """fp path: token-by-token decode reproduces prefill logits exactly."""
    pol = QuantPolicy(enabled=False, backend=backend)
    toks = make_toks()
    lg_pre, _ = MODEL.prefill(PARAMS, {"tokens": toks}, pol, max_seq=T + 2)
    lg_step = stepwise_logits(pol, toks, quant_cache=False)
    assert float(jnp.max(jnp.abs(lg_pre - lg_step))) < 1e-4


@pytest.mark.parametrize("backend", [pytest.param(b, marks=m)
                                     for b, m in BACKENDS])
def test_prefill_stepwise_parity_quantized_fwd(backend):
    """Quantized forward: per-tensor Q_f sees different ranges for the full
    prompt vs one-token slices, so parity holds to quantizer-noise
    tolerance, not float tolerance."""
    pol = QuantPolicy.qat(backend=backend)
    toks = make_toks()
    lg_pre, _ = MODEL.prefill(PARAMS, {"tokens": toks}, pol, max_seq=T + 2)
    scale = float(jnp.max(jnp.abs(lg_pre)))
    lg_step = stepwise_logits(pol, toks, quant_cache=False)
    assert float(jnp.max(jnp.abs(lg_pre - lg_step))) < 0.05 * scale


@pytest.mark.parametrize("backend", [pytest.param(b, marks=m)
                                     for b, m in BACKENDS])
def test_int8_kv_stepwise_close_to_prefill(backend):
    """int8-KV decode stays within a small extra margin of the fp path."""
    pol = QuantPolicy.qat(backend=backend)
    toks = make_toks()
    lg_pre, _ = MODEL.prefill(PARAMS, {"tokens": toks}, pol, max_seq=T + 2)
    scale = float(jnp.max(jnp.abs(lg_pre)))
    lg_q = stepwise_logits(pol, toks, quant_cache=True)
    assert float(jnp.max(jnp.abs(lg_pre - lg_q))) < 0.10 * scale


def test_prefill_last_pos_matches_unpadded():
    """Right-padded prompts + last_pos reproduce the unpadded logits
    (the engine's prompt length-bucketing correctness)."""
    pol = QuantPolicy(enabled=False)
    toks = make_toks()
    lg_a, _ = MODEL.prefill(PARAMS, {"tokens": toks}, pol, max_seq=T)
    padded = jnp.pad(toks, ((0, 0), (0, 5)))
    lg_b, _ = MODEL.prefill(PARAMS, {"tokens": padded}, pol, max_seq=T + 5,
                            last_pos=jnp.full((B,), T - 1, jnp.int32))
    assert float(jnp.max(jnp.abs(lg_a - lg_b))) < 1e-4


# ---------------------------------------------------------------------------
# int8-KV perplexity drift
# ---------------------------------------------------------------------------

def _stepwise_ce(policy, toks, labels, quant_cache):
    """Teacher-forced CE through the decode path (one token at a time)."""
    b, t = toks.shape
    if quant_cache:
        cache = MODEL.init_cache_quant(CFG, b, t + 1)
    else:
        cache = MODEL.init_cache(CFG, b, t + 1)
        cache["index"] = jnp.zeros((b,), jnp.int32)
    step = jax.jit(lambda c, tok, pos: MODEL.decode(
        PARAMS, c, {"tokens": tok}, policy, positions=pos))
    pos = jnp.zeros((b,), jnp.int32)
    total = 0.0
    for i in range(t):
        lg, cache = step(cache, toks[:, i:i + 1], pos)
        pos = pos + 1
        logp = jax.nn.log_softmax(
            lg[:, -1, :CFG.vocab_size].astype(jnp.float32), axis=-1)
        total += float(-jnp.mean(
            jnp.take_along_axis(logp, labels[:, i:i + 1], axis=-1)))
    return total / t


def test_int8_kv_perplexity_drift():
    from repro.data import make_batch_for
    batch = make_batch_for(CFG, 4, 12)
    pol = QuantPolicy.qat()
    ce_fp = _stepwise_ce(pol, batch["tokens"], batch["labels"], False)
    ce_q = _stepwise_ce(pol, batch["tokens"], batch["labels"], True)
    # ppl ratio = exp(delta CE); int8 cache must not move ppl more than ~3%
    assert abs(ce_q - ce_fp) < 0.03, (ce_fp, ce_q)


# ---------------------------------------------------------------------------
# Scheduler invariants
# ---------------------------------------------------------------------------

def _workload(eng, n, seed=0, max_new=5, temperature=0.0, top_k=0):
    rng = np.random.RandomState(seed)
    rids = []
    for _ in range(n):
        plen = int(rng.randint(2, 12))
        rids.append(eng.submit(rng.randint(0, CFG.vocab_size, size=plen),
                               max_new=max_new, temperature=temperature,
                               top_k=top_k))
    return rids


@pytest.mark.parametrize("kv_quant", [False, True])
def test_scheduler_mixed_lengths_all_complete(kv_quant):
    eng = ServeEngine(CFG, PARAMS, slots=3, max_seq=32, kv_quant=kv_quant,
                      seed=0)
    rids = _workload(eng, 8)
    out = eng.run()
    assert sorted(out) == sorted(rids)            # every request completed
    assert eng.active_slots == 0 and eng.queued == 0   # no slot leak
    for c in out.values():
        assert 1 <= len(c.tokens) <= 5
        assert c.reason in ("eos", "length")
        assert all(0 <= t < CFG.vocab_size for t in c.tokens)


def test_scheduler_deterministic_and_slot_invariant():
    outs = []
    for slots in (2, 4, 4):
        eng = ServeEngine(CFG, PARAMS, slots=slots, max_seq=32, seed=0)
        _workload(eng, 6, max_new=4, temperature=0.8, top_k=8)
        outs.append({r: c.tokens for r, c in eng.run().items()})
    assert outs[1] == outs[2]                     # same seed => identical
    # pool-size invariance: the key streams are traffic-independent by
    # construction; under per-tensor Q_f the logits couple co-resident
    # slots at quantization-noise level, which this fixed workload does
    # not push across a sampling decision boundary (deterministic arrays,
    # so this cannot flake — but it is workload-dependent, not a law)
    assert outs[0] == outs[1]


def test_scheduler_eos_eviction():
    # learn what greedy emits, then declare the 2nd token EOS: the engine
    # must evict at that point with reason "eos" instead of burning max_new
    eng = ServeEngine(CFG, PARAMS, slots=2, max_seq=32, seed=0)
    prompt = list(range(1, 7))
    rid = eng.submit(prompt, max_new=8)
    free_run = eng.run()[rid].tokens
    assert len(free_run) == 8
    eos = free_run[2]
    eng2 = ServeEngine(CFG, PARAMS, slots=2, max_seq=32, eos_id=eos, seed=0)
    rid2 = eng2.submit(prompt, max_new=8)
    c = eng2.run()[rid2]
    assert c.reason == "eos"
    assert c.tokens == free_run[:3]               # stops at (and keeps) EOS
    assert eng2.active_slots == 0


def test_scheduler_cache_full_evicts_by_length():
    eng = ServeEngine(CFG, PARAMS, slots=2, max_seq=12, seed=0)
    rid = eng.submit(list(range(1, 9)), max_new=100)   # 8 prompt + 4 room
    c = eng.run()[rid]
    assert c.reason == "length"
    # capacity: 1 token off the prefill logits + one per free cache row
    assert len(c.tokens) == (12 - 8) + 1
    with pytest.raises(ValueError):
        eng.submit(list(range(20)))               # prompt too long for lane


def test_engine_rejects_recurrent_families():
    rcfg = get_config("rwkv6-1.6b", smoke=True)
    rmodel = build_model(rcfg)
    with pytest.raises(ValueError):
        ServeEngine(rcfg, rmodel.init(jax.random.PRNGKey(0)), slots=2,
                    max_seq=16)


# ---------------------------------------------------------------------------
# Checkpoint-driven startup
# ---------------------------------------------------------------------------

def test_serve_from_trainstate_checkpoint(tmp_path):
    from repro.engine import Engine
    eng = Engine(CFG, QuantPolicy.qat(), steps=2, batch_size=2, seq_len=8,
                 ckpt_dir=str(tmp_path), ckpt_every=2, log_fn=None)
    eng.run()
    serve = ServeEngine.from_checkpoint(CFG, str(tmp_path), slots=2,
                                        max_seq=16, kv_quant=True)
    trained = jax.tree.leaves(eng.state.params)
    restored = jax.tree.leaves(serve.params)
    assert all(np.allclose(a, b) for a, b in zip(trained, restored, strict=True))
    rid = serve.submit([1, 2, 3], max_new=3)
    out = serve.run()
    assert len(out[rid].tokens) == 3


def test_serve_from_checkpoint_missing(tmp_path):
    with pytest.raises(FileNotFoundError):
        ServeEngine.from_checkpoint(CFG, str(tmp_path / "empty"))


# ---------------------------------------------------------------------------
# Sampling
# ---------------------------------------------------------------------------

def test_sampling_semantics():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (4, 32))
    keys = slot_keys(key, jnp.arange(4, dtype=jnp.int32),
                     jnp.zeros((4,), jnp.int32))
    zero = jnp.zeros((4,))
    # greedy == argmax
    tok = sample_tokens(logits, keys, zero, jnp.zeros((4,), jnp.int32), 32)
    assert (np.asarray(tok) == np.asarray(jnp.argmax(logits, -1))).all()
    # top_k=1 forces greedy even at high temperature
    tok1 = sample_tokens(logits, keys, jnp.full((4,), 5.0),
                         jnp.ones((4,), jnp.int32), 32)
    assert (np.asarray(tok1) == np.asarray(jnp.argmax(logits, -1))).all()
    # temperature sampling respects the top-k set
    k = 4
    topk_sets = np.argsort(-np.asarray(logits), axis=-1)[:, :k]
    for i in range(50):
        ks = slot_keys(key, jnp.arange(4, dtype=jnp.int32),
                       jnp.full((4,), i, jnp.int32))
        tk = sample_tokens(logits, ks, jnp.full((4,), 1.0),
                           jnp.full((4,), k, jnp.int32), 32)
        for row, t in enumerate(np.asarray(tk)):
            assert t in topk_sets[row]


def test_sampling_never_emits_padded_vocab():
    # padding columns carry huge logits; mask must win for every mode
    logits = jnp.zeros((2, 16)).at[:, 10:].set(1e9)
    keys = slot_keys(jax.random.PRNGKey(1), jnp.arange(2, dtype=jnp.int32),
                     jnp.zeros((2,), jnp.int32))
    for temp in (0.0, 1.0):
        tok = sample_tokens(logits, keys, jnp.full((2,), temp),
                            jnp.zeros((2,), jnp.int32), vocab_size=10)
        assert (np.asarray(tok) < 10).all()


# ---------------------------------------------------------------------------
# BHQ ragged shapes (the blocking bugfix swept up with this PR)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,blk", [(37, 16), (129, 64), (5, 16), (48, 16)])
def test_bhq_ragged_roundtrip_and_unbiased(n, blk):
    """n % block_rows != 0 must pad (not collapse to one all-n block) and
    the unpadded rows must stay unbiased, with the exact conditional
    variance (quantizer_variance) matching Monte-Carlo — the sharp signal
    that the padding rows are inert (same tolerances as the no-pad
    48/16 and 5/16 control cases)."""
    from repro.core import bhq_exact_variance, quantize_bhq_stoch
    from repro.core.bhq import _blocked_rows
    x = jax.random.normal(jax.random.PRNGKey(n), (n, 8))
    gb, valid, n_real = _blocked_rows(x, blk)
    assert n_real == n
    assert int(valid.sum()) == n
    assert gb.shape[1] == min(blk, n)             # sort cost stays bounded
    qt = quantize_bhq_stoch(x, jax.random.PRNGKey(1), 8, block_rows=blk)
    assert qt.dequant().shape == (n, 8)
    ks = jax.random.split(jax.random.PRNGKey(2), 256)
    samp = jax.lax.map(
        lambda k: quantize_bhq_stoch(x, k, 4, block_rows=blk).dequant(), ks)
    scale = float(jnp.max(jnp.abs(x)))
    bias = jnp.abs(jnp.mean(samp, 0) - x)
    assert float(jnp.max(bias)) < 0.05 * scale
    assert float(jnp.mean(bias)) < 0.01 * scale
    v_emp = float(jnp.sum(jnp.var(samp, axis=0)))
    v_exact = float(bhq_exact_variance(x, 4, block_rows=blk))
    assert abs(v_emp - v_exact) < 0.15 * v_exact, (v_emp, v_exact)


def test_bhq_ragged_through_fqt_backward():
    """The dX GEMM consumes the padded BHQTensor — gradient shape and
    finiteness must survive the unpad slice on every backend."""
    from repro.core import fqt_matmul
    x = jax.random.normal(jax.random.PRNGKey(3), (3, 7, 8))   # 21 rows
    w = jax.random.normal(jax.random.PRNGKey(4), (8, 6))
    for backend in ("simulate", "native"):
        pol = QuantPolicy.fqt("bhq", 5, bhq_block=4, backend=backend)
        dx = jax.grad(lambda a, pol=pol: (fqt_matmul(
            a, w, jax.random.PRNGKey(5), pol) ** 2).sum())(x)
        assert dx.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(dx)))


def test_bhq_paper_g_search_reaches_psq_degenerate():
    """'paper' mode must be able to select G = n (PSQ fallback): constant
    rows have zero dynamic range, so the exact G = n score (sum R_i^2 = 0)
    beats every grouped candidate."""
    from repro.core.bhq import _select_g
    from repro.core.quantizers import row_dynamic_range
    mag = jnp.linspace(5.0, 1.0, 8)
    rows = jnp.broadcast_to(mag[:, None], (8, 16))
    G = _select_g(jnp.sort(mag)[::-1], row_dynamic_range(rows), 8, "paper")
    assert int(G) == 8
    # and plain noise still groups aggressively (the idealized proxy)
    g = jax.random.normal(jax.random.PRNGKey(6), (8, 16))
    mag_s = jnp.sort(jnp.max(jnp.abs(g), 1))[::-1]
    assert int(_select_g(mag_s, row_dynamic_range(g), 8, "paper")) < 8


def test_generate_stops_at_eos():
    from repro.launch.serve import generate
    toks = make_toks(5)
    batch = {"tokens": toks}
    pol = QuantPolicy.qat()
    free = generate(MODEL, PARAMS, batch, pol, max_new=8, max_seq=T + 9)
    assert free.shape == (B, 8)
    eos = int(free[0, 2])
    stopped = generate(MODEL, PARAMS, batch, pol, max_new=8, max_seq=T + 9,
                       eos_id=eos)
    assert stopped.shape[1] <= 8
    # once a row hits EOS it keeps emitting EOS while the batch drains
    row = np.asarray(stopped[0])
    hit = np.where(row == eos)[0]
    assert hit.size > 0
    assert (row[hit[0]:] == eos).all()
