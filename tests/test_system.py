"""End-to-end behaviour tests for the FQT system (paper reproduction).

The paper's central empirical claims, at smoke scale:
  * FQT@8bit trains as well as QAT (Table 1, 8-bit rows)
  * low-bit PTQ degrades/diverges where BHQ keeps training (Table 1, 4-5 bit)
  * the serving path generates coherently after training
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import QuantPolicy
from repro.launch.serve import generate
from repro.launch.train import train_loop
from repro.models import build_model


def _final_loss(policy, steps=40, seed=0, lr=4e-3):
    cfg = get_config("statquant-tx", smoke=True)
    _, _, hist = train_loop(cfg, policy, steps=steps, batch_size=4,
                            seq_len=16, lr=lr, log_every=5, seed=seed,
                            log_fn=lambda *a: None)
    return hist[0][1], hist[-1][1]


def test_exact_and_qat_and_fqt8_all_learn():
    """All three regimes reduce loss on learnable synthetic data, and FQT@8
    tracks QAT closely (Theorem 1 consequence at eta -> small)."""
    first_e, last_e = _final_loss(QuantPolicy.exact())
    first_q, last_q = _final_loss(QuantPolicy.qat())
    first_f, last_f = _final_loss(QuantPolicy.fqt("bhq", 8, bhq_block=16))
    assert last_e < first_e - 0.2
    assert last_q < first_q - 0.2
    assert last_f < first_f - 0.2
    # FQT@8bit within a modest margin of QAT (paper: indistinguishable)
    assert last_f < last_q + 0.4, (last_f, last_q)


def test_low_bit_bhq_beats_ptq():
    """Paper Table 1 directionally: at very low bits, PTQ's gradient variance
    exceeds BHQ's (the mechanism behind the accuracy gap), and BHQ training
    stays in the same loss ballpark or better.  The tiny-proxy loss itself is
    noise-dominated, so the hard assertion is on the variance ordering."""
    from benchmarks.common import grad_snapshot
    from repro.core import quantize_bhq_stoch, quantize_ptq_stoch
    from repro.core.theory import empirical_mean_and_variance
    (_, g), *_ = grad_snapshot(steps=10, batch=4, seq=16)
    _, v_ptq = empirical_mean_and_variance(
        jax.jit(lambda x, k: quantize_ptq_stoch(x, k, 3).dequant()),
        g, jax.random.PRNGKey(0), 128)
    _, v_bhq = empirical_mean_and_variance(
        jax.jit(lambda x, k: quantize_bhq_stoch(
            x, k, 3, block_rows=64).dequant()),
        g, jax.random.PRNGKey(0), 128)
    assert float(v_bhq) < float(v_ptq), (float(v_bhq), float(v_ptq))
    losses = {}
    for quant in ("ptq", "bhq"):
        _, last = _final_loss(QuantPolicy.fqt(quant, 3, bhq_block=16),
                              steps=60)
        losses[quant] = last
    assert losses["bhq"] <= losses["ptq"] + 0.5, losses


def test_trained_model_generates():
    cfg = get_config("statquant-tx", smoke=True)
    pol = QuantPolicy.fqt("psq", 6)
    params, _, _ = train_loop(cfg, pol, steps=20, batch_size=4, seq_len=16,
                              log_fn=lambda *a: None)
    model = build_model(cfg)
    batch = {"tokens": jnp.ones((2, 8), jnp.int32)}
    toks = generate(model, params, batch, QuantPolicy.qat(),
                    max_new=4, max_seq=16)
    assert toks.shape == (2, 4)
    assert bool(jnp.all((toks >= 0) & (toks < cfg.padded_vocab)))


@pytest.mark.slow
def test_deterministic_training_given_seed():
    """Two identical seeds give bit-identical training (compiles the whole
    train step twice — slow sweep only)."""
    pol = QuantPolicy.fqt("bhq", 6, bhq_block=16)
    _, a = _final_loss(pol, steps=10, seed=5)
    _, b = _final_loss(pol, steps=10, seed=5)
    assert a == b
