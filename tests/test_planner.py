"""Variance-budget precision planner (analysis/planner.py).

Pins the three claims the planner rests on: the bytes-moved cost model is
the bench's, the predicted per-site variances are the Proposition 4
closed forms (cross-checked against Monte-Carlo), and the solvers honour
the budget while beating the uniform-8-bit baseline at equal bytes.  The
end product — overrides JSON — must round-trip through QuantPolicy and
pass the contract audit.
"""

import json

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (audit_model, check_model, collect_plan_sites,
                            gemm_bytes_moved, legal_widths, plan_model,
                            site_candidates)
from repro.analysis.planner import _variance_proxy
from repro.analysis.ranges import max_safe_k
from repro.configs import get_config
from repro.core import QuantPolicy
from repro.core.bhq import quantize_bhq_stoch
from repro.core.policy import overrides_from_json
from repro.core.quantizers import quantize_psq_stoch, quantize_ptq_stoch
from repro.core.theory import empirical_mean_and_variance

CFG = get_config("statquant-tx", smoke=True)
PTQ8 = QuantPolicy.fqt("ptq", 8)


# ---------------------------------------------------------------------------
# Cost model + width legality
# ---------------------------------------------------------------------------

def test_bytes_moved_matches_bench_columns():
    m, k, n = 96, 128, 64
    # f32 GEMM: both operands 4B, result 4B
    assert gemm_bytes_moved(m, k, n, 32, 32) == 4 * (m * k + k * n + m * n)
    # int8 x int8: 1B operands, f32 out
    assert gemm_bytes_moved(m, k, n, 8, 8) == m * k + k * n + 4 * m * n
    # packed W4: activations int8, weights half a byte
    assert gemm_bytes_moved(m, k, n, 8, 4) == m * k + k * n / 2 + 4 * m * n
    assert gemm_bytes_moved(m, k, n, 8, 2) == m * k + k * n / 4 + 4 * m * n


def test_legal_widths_accumulator_and_role_bounds():
    assert legal_widths("agrad", 64) == (8, 4, 2)
    # past the 8x8 bound only narrower SR widths survive
    k = max_safe_k(8, 8) + 1
    assert legal_widths("agrad", k) == (4, 2)
    assert legal_widths("wgrad", k) == (4, 2)
    # backward roles never go binary; the forward weight may
    assert 1 not in legal_widths("wgrad", 64, widths=(8, 4, 2, 1))
    assert 1 in legal_widths("fwd_weight", 64, widths=(8, 4, 2, 1))


# ---------------------------------------------------------------------------
# Predicted variance vs Monte-Carlo (the numbers the solver ranks by)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quantizer,bits,params,fn", [
    ("psq", 8, {}, lambda x, k: quantize_psq_stoch(x, k, 8).dequant()),
    ("bhq", 4, {"block_rows": 32},
     lambda x, k: quantize_bhq_stoch(x, k, 4, block_rows=32).dequant()),
    ("ptq", 2, {}, lambda x, k: quantize_ptq_stoch(x, k, 2).dequant()),
])
def test_variance_proxy_matches_monte_carlo(quantizer, bits, params, fn):
    """The planner's per-site variance is quantizer_variance on a fixed
    Gaussian proxy; Monte-Carlo on the same sample must agree."""
    shape = (64, 32)
    pred = _variance_proxy(shape, quantizer, bits, **params)
    # the proxy is uncapped at this size: reconstruct its exact sample
    x = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
    _, mc = empirical_mean_and_variance(jax.jit(fn), x,
                                        jax.random.PRNGKey(5), 512)
    assert pred > 0
    # sqrt(2/512) ~ 6% MC noise on a variance estimate; allow 15%
    assert abs(float(mc) - pred) < 0.15 * pred, (float(mc), pred)


def test_candidates_are_pareto_and_legal():
    sites = collect_plan_sites(CFG, PTQ8)
    assert sites, "statquant-tx must expose quantized gradient GEMMs"
    for s in sites:
        assert s.role in ("wgrad", "agrad")
        cands = site_candidates(s, PTQ8)
        assert cands
        for c in cands:
            if s.role == "wgrad":
                assert c.quantizer == "ptq"   # qt_gemm_tn needs per-tensor
            assert c.bits in legal_widths(s.role, s.k,
                                          partner_bits=s.partner_bits)
        # Pareto: no candidate dominated on both axes
        for a in cands:
            assert not any(
                o.variance <= a.variance and o.bytes_moved <= a.bytes_moved
                and (o.variance < a.variance or o.bytes_moved < a.bytes_moved)
                for o in cands)


# ---------------------------------------------------------------------------
# Solving
# ---------------------------------------------------------------------------

def test_plan_beats_uniform_at_equal_bytes():
    """Paper Sec. 4: at the uniform-8-bit byte budget, mixing quantizer
    families must strictly reduce predicted gradient variance."""
    plan = plan_model(CFG, PTQ8)
    assert plan.feasible
    assert plan.total_bytes <= plan.baseline_bytes * (1 + 1e-9)
    assert plan.total_variance < plan.baseline_variance
    # the win comes from upgrading agrad sites beyond plain PTQ
    assert any(e.role == "agrad" and e.quantizer != "ptq"
               for e in plan.entries)


def test_constrained_budget_downgrades_bits():
    sites = collect_plan_sites(CFG, PTQ8)
    tables = [site_candidates(s, PTQ8) for s in sites]
    floor = sum(min(c.bytes_moved for c in t) for t in tables)
    baseline = sum(s.bytes_at(8) for s in sites)
    assert floor < baseline
    budget = (floor + baseline) / 2
    plan = plan_model(CFG, PTQ8, budget_bytes=budget)
    assert plan.feasible
    assert plan.total_bytes <= budget * (1 + 1e-9)
    assert any(e.bits < 8 for e in plan.entries)


def test_auto_solver_picks_best_of_greedy_and_dp():
    """Forced DP solves a ceil-discretized (slightly tighter) budget, so
    either solver can win near a steep variance cliff; ``auto`` must take
    whichever is better, and neither may overshoot the budget."""
    sites = collect_plan_sites(CFG, PTQ8)
    tables = [site_candidates(s, PTQ8) for s in sites]
    floor = sum(min(c.bytes_moved for c in t) for t in tables)
    baseline = sum(s.bytes_at(8) for s in sites)
    budget = (floor + baseline) / 2
    pg = plan_model(CFG, PTQ8, budget_bytes=budget, solver="greedy")
    pd = plan_model(CFG, PTQ8, budget_bytes=budget, solver="dp")
    pa = plan_model(CFG, PTQ8, budget_bytes=budget, solver="auto")
    assert pg.feasible and pd.feasible and pa.feasible
    for p in (pg, pd, pa):
        assert p.total_bytes <= budget * (1 + 1e-9)
    best = min(pg.total_variance, pd.total_variance)
    assert pa.total_variance <= best * (1 + 1e-9)


def test_impossible_budget_flagged_not_crashed():
    plan = plan_model(CFG, PTQ8, budget_bytes=1.0)
    assert not plan.feasible
    assert plan.total_bytes > plan.budget_bytes
    # best-effort plan still shrinks everything it can
    floor_bits = {min(c.bits for c in site_candidates(s, PTQ8))
                  for s in collect_plan_sites(CFG, PTQ8)}
    assert {e.bits for e in plan.entries} <= floor_bits


# ---------------------------------------------------------------------------
# Overrides JSON: plan -> policy -> audited model
# ---------------------------------------------------------------------------

def test_plan_roundtrips_through_policy_and_audit():
    plan = plan_model(CFG, PTQ8)
    doc = json.loads(plan.to_json())
    assert doc["version"] == 1 and doc["feasible"]
    overrides = overrides_from_json(doc)
    policy = QuantPolicy.fqt("ptq", 8, overrides=overrides)
    # resolved specs match the plan exactly
    for e in plan.entries:
        spec = getattr(policy.resolve(e.path), e.role)
        assert spec is not None
        assert (spec.name, spec.bits) == (e.quantizer, e.bits), e
    # the planned policy passes the quantization-contract audit...
    rep = audit_model(CFG, policy)
    assert rep.ok, rep.format(verbose=True)
    # ...and the soundness verifier
    snd = check_model(CFG, policy)
    assert snd.ok, snd.format(verbose=True)


def test_cli_plan_writes_consumable_json(tmp_path, capsys):
    from repro.analysis.__main__ import main
    out = tmp_path / "plan.json"
    rc = main(["plan", "--config", "statquant-tx", "--format", "json",
               "--out", str(out)])
    assert rc == 0
    doc = json.loads(capsys.readouterr().out)
    saved = json.loads(out.read_text())
    assert saved["overrides"] == doc["overrides"]
    # exactly what launch/train.py --override-file does with the file
    overrides = overrides_from_json(saved)
    policy = QuantPolicy.fqt("bhq", 8, overrides=overrides)
    first = saved["sites"][0]
    spec = getattr(policy.resolve(first["path"]), first["role"])
    assert (spec.name, spec.bits) == (first["quantizer"], first["bits"])
